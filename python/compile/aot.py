"""AOT lowering: jax -> HLO *text* artifacts for the Rust PJRT runtime.

Emits, per (app, variant) pair, three artifacts:

    artifacts/{app}_{variant}_{predict,update,solve}.hlo.txt

plus ``artifacts/manifest.json`` describing every artifact's input/output
shapes so the Rust runtime can validate what it loads.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Python runs only here, at build time (``make artifacts``); the Rust
binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .spec import all_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    ``print_large_constants=True`` is essential: the default HLO printer
    elides big constants as ``constant({...})``, which the text parser on
    the Rust side silently reads back as *zeros* — the monomial selection
    matrices baked into the predictor would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def shape_sig(args) -> list[dict]:
    return [{"shape": list(np.shape(a)), "dtype": str(np.asarray(a).dtype)}
            for a in args]


def lower_bundle(bundle, out_dir: str) -> dict:
    entries = {}
    for op in ("predict", "update", "solve"):
        args = bundle.example_args(op)
        lowered = jax.jit(bundle.fn(op)).lower(*args)
        text = to_hlo_text(lowered)
        name = f"{bundle.spec.name}_{bundle.variant}_{op}"
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(bundle.fn(op), *args)
        flat, _ = jax.tree_util.tree_flatten(out_shapes)
        entries[name] = {
            "file": os.path.basename(path),
            "app": bundle.spec.name,
            "variant": bundle.variant,
            "op": op,
            "inputs": shape_sig(args),
            "outputs": [{"shape": list(s.shape), "dtype": str(s.dtype)}
                        for s in flat],
            "num_groups": bundle.num_groups,
            "feature_pad": bundle.spec.feature_pad,
            "candidate_pad": bundle.spec.candidate_pad,
            "num_vars": bundle.spec.num_vars,
        }
        print(f"  wrote {path} ({len(text)} chars)")
    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts",
                        help="output dir OR a single .hlo.txt path whose "
                             "parent dir is used (Makefile convenience)")
    args = parser.parse_args()
    out_dir = args.out
    if out_dir.endswith(".hlo.txt"):
        out_dir = os.path.dirname(out_dir) or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest: dict = {"artifacts": {}, "apps": {}}
    for spec in all_specs():
        manifest["apps"][spec.name] = {
            "num_vars": spec.num_vars,
            "num_groups": spec.num_groups,
            "feature_pad": spec.feature_pad,
            "candidate_pad": spec.candidate_pad,
            "structured_features": spec.structured_feature_count(),
            "unstructured_features": spec.unstructured_feature_count(),
        }
        for variant in model_mod.VARIANTS:
            print(f"lowering {spec.name}/{variant} ...")
            bundle = model_mod.build(spec, variant)
            manifest["artifacts"].update(lower_bundle(bundle, out_dir))

    # Sentinel the Makefile tracks + human-readable inventory.
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
