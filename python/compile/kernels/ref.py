"""Pure-jnp oracle for the L1 Pallas kernels.

Everything here is the *reference semantics*: the Pallas kernels in
``poly.py`` / ``ogd.py`` must match these functions to float32 tolerance
(pytest + hypothesis enforce it), and the Rust native learner mirrors the
same math (golden files cross-check the monomial order).

Shapes (per app/variant artifact, all static):
  N = candidate_pad (64)   padded candidate batch
  V = num_vars (5)         raw knobs; u_aug has V+1 with trailing 1.0
  F = feature_pad (64)     padded monomial feature dim
  G = number of groups (unstructured: 1)
  D = polynomial degree (3)
"""

from __future__ import annotations

import jax.numpy as jnp


def expand(u_aug, idx, valid):
    """Monomial feature expansion via gather products.

    u_aug : [..., V+1]  normalized knobs with trailing constant 1.0
    idx   : [D, F] int32 gather indices into the V+1 axis
    valid : [F] 0/1 mask for real (non-padded) monomials
    returns phi : [..., F]
    """
    phi = jnp.ones(u_aug.shape[:-1] + (idx.shape[1],), dtype=u_aug.dtype)
    for d in range(idx.shape[0]):
        phi = phi * jnp.take(u_aug, idx[d], axis=-1)
    return phi * valid


def predict_groups(u_aug, weights, idx, valid):
    """Per-group latency predictions for a candidate batch.

    u_aug   : [N, V+1]
    weights : [G, F]
    idx     : [G, D, F]   per-group gather indices (groups expand only
                          their own variable subsets; Sec 2.3/3.3)
    valid   : [G, F]
    returns pred : [N, G]
    """
    cols = []
    for g in range(weights.shape[0]):
        phi = expand(u_aug, idx[g], valid[g])          # [N, F]
        cols.append(phi @ weights[g])                  # [N]
    return jnp.stack(cols, axis=-1)


def combine(pred, seq_vec, branch_mat, offset):
    """Critical-path combination (paper Eq. 9 generalized).

    pred       : [N, G] per-group predicted latencies
    seq_vec    : [G]    1.0 for sequential (non-branch) groups
    branch_mat : [B, G] membership of groups in parallel branches (B may be 0)
    offset     : scalar moving-average latency of all non-critical stages
    returns c : [N] end-to-end latency prediction
    """
    c = pred @ seq_vec + offset
    if branch_mat.shape[0] > 0:
        per_branch = pred @ branch_mat.T               # [N, B]
        c = c + jnp.max(per_branch, axis=-1)
    return c


def predict(u_aug, weights, idx, valid, seq_vec, branch_mat, offset):
    """End-to-end latency prediction for a candidate batch -> [N]."""
    pred = predict_groups(u_aug, weights, idx, valid)
    return combine(pred, seq_vec, branch_mat, offset)


def ogd_update(weights, u_aug, y, idx, valid, eta, gamma, eps_ins,
               pa_damping=0.5):
    """One PA-clipped online-gradient step on the eps-insensitive SVR
    loss (Eq. 6-8; see rust/src/learner/ogd.rs for the clipping argument).

    weights : [G, F]      current per-group weights
    u_aug   : [V+1]       the action just played (normalized, aug)
    y       : [G]         observed per-group latency targets (normalized
                          latency units — the L3 backend divides ms by
                          LATENCY_SCALE_MS)
    eta     : scalar      learning rate ceiling (eta_t = eta0/sqrt(t))
    gamma   : scalar      L2 regularization (paper: 0.01)
    eps_ins : scalar      insensitivity zone (normalized units)
    returns weights' : [G, F]

    step_g = min(eta, damping * max(|err_g|-eps, 0)/||phi_g||^2) * sign(err_g)
    w_g'   = (w_g - step_g*phi_g - eta*2*gamma*w_g) * valid_g

    The step never overshoots the current sample (passive-aggressive
    clip); the ``valid`` mask doubles as the subspace projection P(.) of
    Eq. 6: padded/foreign monomial slots stay exactly zero.
    """
    G = weights.shape[0]
    phis = jnp.stack([expand(u_aug, idx[g], valid[g]) for g in range(G)])  # [G,F]
    pred = jnp.sum(weights * phis, axis=-1)                                # [G]
    err = pred - y
    loss = jnp.maximum(jnp.abs(err) - eps_ins, 0.0)
    phi_norm2 = jnp.maximum(jnp.sum(phis * phis, axis=-1), 1e-12)
    tau = jnp.minimum(eta, pa_damping * loss / phi_norm2)                  # [G]
    step = tau * jnp.sign(err)
    return (weights - step[:, None] * phis - eta * 2.0 * gamma * weights) * valid


def solve(u_aug, weights, idx, valid, seq_vec, branch_mat, offset,
          reward, cand_valid, bound):
    """Constrained argmax (paper Eq. 2) over the candidate batch.

    reward     : [N] known fidelity of each candidate (paper Sec 3.1
                 assumes r is known)
    cand_valid : [N] 0/1 padding mask over candidates
    bound      : scalar latency bound L (ms)
    returns (best_idx : i32 scalar, c : [N] predicted latencies)

    If no candidate is feasible, falls back to the valid candidate with
    the smallest predicted latency.
    """
    c = predict(u_aug, weights, idx, valid, seq_vec, branch_mat, offset)
    feasible = (c <= bound) & (cand_valid > 0.5)
    score = jnp.where(feasible, reward, -jnp.inf)
    any_feasible = jnp.any(feasible)
    fallback = jnp.where(cand_valid > 0.5, c, jnp.inf)
    idx_best = jnp.where(
        any_feasible, jnp.argmax(score), jnp.argmin(fallback)
    ).astype(jnp.int32)
    return idx_best, c
