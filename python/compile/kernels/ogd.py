"""L1 Pallas kernel: fused epsilon-insensitive OGD weight update.

One PA-clipped online-gradient step (paper Eq. 6-8; clipping rationale in
rust/src/learner/ogd.rs) for all per-group regressors at once:

    phi     = monomial_expand(u)                          # [F]
    err_g   = <w_g, phi> - y_g                            # [G]
    tau_g   = min(eta, max(|err_g|-eps, 0) / ||phi_g||^2)
    w_g'    = (w_g - tau_g*sign(err_g)*phi_g - eta*2*gamma*w_g) * support_g

The support mask is the projection onto each group's monomial subspace
(structured predictors only own the monomials of their variable subset —
paper Sec 3.3), and simultaneously keeps the padded feature slots at
exactly zero. Targets are in normalized latency units (1 unit = 100 ms;
the L3 backend converts). Fusing expansion + subgradient + shrink +
projection means one VMEM round trip for the whole update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def ogd_update(weights, u_aug, y, eta, *, idx, support,
               gamma=0.01, eps_ins=0.01, pa_damping=0.5, interpret=True):
    """One OGD step on the eps-insensitive SVR loss.

    weights : [G, F] float32
    u_aug   : [V+1]  float32 normalized action + trailing 1.0
    y       : [G]    float32 observed per-group latency targets (ms)
    eta     : []     float32 learning rate (schedule lives in L3)
    idx     : np.ndarray [D, F] int32 static gather indices
    support : np.ndarray [G, F] float32 static subspace masks
    returns weights' : [G, F]
    """
    from .poly import selection_matrices

    support = np.asarray(support, dtype=np.float32)
    g, f = weights.shape
    vp = u_aug.shape[0]
    # gather-free expansion (see poly.py): valid == union of supports
    valid = (support.sum(axis=0) > 0.0).astype(np.float32)
    sel = selection_matrices(idx, vp, valid)
    d = sel.shape[0]

    def kernel(w_ref, u_ref, y_ref, eta_ref, sel_ref, sup_ref, o_ref):
        u = u_ref[...]                                    # [V+1]
        sel_m = sel_ref[...]
        phi = u @ sel_m[0]
        for dd in range(1, d):                            # static degree loop
            phi = phi * (u @ sel_m[dd])
        w = w_ref[...]                                    # [G, F]
        sup = sup_ref[...]
        eta = eta_ref[0]
        phis = phi[None, :] * sup                         # per-group masked phi
        err = jnp.sum(w * phis, axis=-1) - y_ref[...]     # [G]
        loss = jnp.maximum(jnp.abs(err) - eps_ins, 0.0)
        phi_norm2 = jnp.maximum(jnp.sum(phis * phis, axis=-1), 1e-12)
        tau = jnp.minimum(eta, pa_damping * loss / phi_norm2)  # damped PA clip
        step = tau * jnp.sign(err)                        # [G]
        o_ref[...] = (w - step[:, None] * phis - eta * 2.0 * gamma * w) * sup

    return pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((g, f), lambda: (0, 0)),
            pl.BlockSpec((vp,), lambda: (0,)),
            pl.BlockSpec((g,), lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
            pl.BlockSpec((d, vp, f), lambda: (0, 0, 0)),
            pl.BlockSpec((g, f), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((g, f), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, f), weights.dtype),
        interpret=interpret,
    )(weights, u_aug, y, jnp.reshape(eta, (1,)), sel, support)
