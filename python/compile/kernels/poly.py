"""L1 Pallas kernel: fused polynomial feature expansion + per-group matvec.

This is the tuner's per-frame hot spot: predicting the latency of every
candidate operating point (paper Eq. 2 needs \\hat c(x, k) for the whole
action space each exploitation step). The kernel fuses

    phi   = monomial_expand(u)          # [block_n, F]
    pred  = phi @ W.T                   # [block_n, G]

into a single VMEM-resident block so the expansion never round-trips to
HBM. The monomial gather indices are *compile-time constants* (they are a
property of the app spec, not data), so the expansion lowers to registers
+ element-wise products feeding the MXU matmul.

The expansion is *gather-free*: the static monomial indices are encoded
as one-hot selection matrices S_d ∈ {0,1}^[(V+1) x F], so each factor is
the matmul ``u @ S_d`` and phi is their elementwise product. Besides
being the MXU-native formulation, this avoids `gather` ops entirely —
the pinned xla_extension 0.5.1 runtime mis-executes the NaN-fill gather
that ``jnp.take`` lowers to.

TPU mapping (DESIGN.md Sec 2): grid tiles the candidate batch; the weight
matrix is broadcast-resident in VMEM (G x F x 4 B = a few KB). On this
image we run interpret=True (CPU) — structure is what we optimize.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def selection_matrices(idx, vp, valid):
    """One-hot encode gather indices: S[d, v, j] = 1 iff idx[d, j] == v.

    The validity mask is folded into S[0] (padded feature slots select
    nothing -> phi = 0 there).
    """
    idx = np.asarray(idx, dtype=np.int64)
    d, f = idx.shape
    sel = np.zeros((d, vp, f), dtype=np.float32)
    for dd in range(d):
        sel[dd, idx[dd], np.arange(f)] = 1.0
    sel[0] *= np.asarray(valid, np.float32)[None, :]
    return sel


def expand_block(u, sel):
    """Monomial expansion of a [n, V+1] block -> [n, F] via one-hot
    selection matmuls (gather-free)."""
    phi = u @ sel[0]
    for d in range(1, sel.shape[0]):
        phi = phi * (u @ sel[d])
    return phi


def poly_predict(u_aug, weights, *, idx, valid, block_n=32, interpret=True):
    """Per-group latency predictions for a padded candidate batch.

    u_aug   : [N, V+1] float32, normalized knobs + trailing 1.0
    weights : [G, F]   float32 per-group weights (support-masked)
    idx     : np.ndarray [D, F] int32 — gather indices (spec-derived; loop
              bound over the degree axis is compile-time static)
    valid   : np.ndarray [F] float32 — monomial validity mask
    returns pred : [N, G]

    N must be a multiple of ``block_n``.
    """
    n, vp = u_aug.shape
    g, f = weights.shape
    sel = selection_matrices(idx, vp, valid)
    d = sel.shape[0]
    if n % block_n != 0:
        raise ValueError(f"candidate batch {n} not a multiple of {block_n}")

    def kernel(u_ref, w_ref, sel_ref, o_ref):
        u = u_ref[...]                            # [block_n, V+1]
        phi = expand_block(u, sel_ref[...])       # [block_n, F]
        o_ref[...] = phi @ w_ref[...].T           # MXU-shaped matmul

    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, vp), lambda i: (i, 0)),
            pl.BlockSpec((g, f), lambda i: (0, 0)),      # weights broadcast
            pl.BlockSpec((d, vp, f), lambda i: (0, 0, 0)),  # selection bcast
        ],
        out_specs=pl.BlockSpec((block_n, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, g), u_aug.dtype),
        interpret=interpret,
    )(u_aug, weights, sel)


@functools.partial(jax.jit, static_argnames=("block_n",))
def _noop(x, block_n=32):  # pragma: no cover - keeps jit cache warm in tests
    return x
