"""Shared application spec loading + monomial feature enumeration.

The spec JSON files in ``specs/`` are the single source of truth for the
tunable-parameter tables (paper Tables 1 and 2), the data-flow graphs
(paper Figures 1 and 4), and the structured-learner group decomposition
(paper Section 2.3). The Rust side (``rust/src/apps``, ``rust/src/learner``)
parses the same files; the monomial enumeration order defined here is
golden-tested against the Rust implementation.
"""

from __future__ import annotations

import itertools
import json
import math
import os
from dataclasses import dataclass, field

SPEC_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "specs")

# The predictor kernels operate in *normalized latency units*: the Rust
# backend divides millisecond targets by LATENCY_SCALE_MS before the OGD
# update and multiplies predictions back (standard eps-SVR target
# normalization; with raw-ms targets the gamma*||f||^2 shrinkage would
# bias the bounded subgradient steps).
LATENCY_SCALE_MS = 100.0
# epsilon-insensitive zone of the SVR loss: 1 ms, in normalized units
# (paper Sec 3.2).
EPS_INSENSITIVE = 1.0 / LATENCY_SCALE_MS
# L2 regularization weight gamma (paper: "In all of our experiments, 0.01").
GAMMA = 0.01
# Damping of the passive-aggressive step clip (see rust/src/learner/ogd.rs).
PA_DAMPING = 0.5


def monomials(num_vars: int, degree: int) -> list[tuple[int, ...]]:
    """All monomials of total degree <= degree over ``num_vars`` variables.

    Order: graded (by total degree ascending), then lexicographic over the
    non-decreasing variable-index tuples. Degree 0 is the constant term
    ``()``. This exact order is mirrored by ``learner::features`` in Rust.

    >>> monomials(2, 2)
    [(), (0,), (1,), (0, 0), (0, 1), (1, 1)]
    """
    out: list[tuple[int, ...]] = [()]
    for d in range(1, degree + 1):
        out.extend(itertools.combinations_with_replacement(range(num_vars), d))
    return out


def monomial_count(num_vars: int, degree: int) -> int:
    """C(num_vars + degree, degree) — e.g. 5 vars, cubic -> 56."""
    return math.comb(num_vars + degree, degree)


def monomial_index_arrays(
    var_subset: list[int], num_vars: int, degree: int, feature_pad: int
) -> tuple[list[int], ...]:
    """Gather-index encoding of the monomial expansion for a Pallas kernel.

    Each monomial is encoded as exactly ``degree`` indices into the
    augmented parameter vector ``u_aug = concat(u, [1.0])`` (length
    ``num_vars + 1``); missing factors point at the trailing 1.0 so that
    ``phi[j] = prod_d u_aug[idx[d][j]]`` holds for every degree. Padded
    feature slots (beyond the subset's monomial count) index a *zero*: we
    return a separate ``valid`` 0/1 mask for them.

    The expansion is computed over the monomials of the *subset* variables
    only (this is what makes the structured predictor's feature space
    smaller: 10 + 20 = 30 vs 56 for MotionSIFT, paper Sec 4.3), but the
    indices refer to positions in the full parameter vector so every group
    kernel can consume the same input.
    """
    one = num_vars  # index of the constant 1.0 slot in u_aug
    monos = monomials(len(var_subset), degree)
    idx = [[one] * feature_pad for _ in range(degree)]
    valid = [0.0] * feature_pad
    if len(monos) > feature_pad:
        raise ValueError(
            f"feature_pad={feature_pad} too small for {len(monos)} monomials"
        )
    for j, mono in enumerate(monos):
        valid[j] = 1.0
        for d, local_var in enumerate(mono):
            idx[d][j] = var_subset[local_var]
    return (*idx, valid)


@dataclass
class Param:
    name: str
    symbol: str
    kind: str
    min: float
    max: float
    default: float
    log: bool
    description: str

    def normalize(self, k: float) -> float:
        """Map a raw knob value into [0, 1] (log scale where flagged)."""
        if self.log:
            lo, hi = math.log(self.min), math.log(self.max)
            return (math.log(max(k, self.min)) - lo) / (hi - lo)
        return (k - self.min) / (self.max - self.min)


@dataclass
class Group:
    name: str
    stages: list[str]
    params: list[int]
    branch: int | None


@dataclass
class Stage:
    name: str
    deps: list[str]
    critical: bool
    params: list[int]


@dataclass
class AppSpec:
    name: str
    title: str
    latency_bounds_ms: list[float]
    params: list[Param]
    stages: list[Stage]
    groups: list[Group]
    degree: int
    candidate_pad: int
    feature_pad: int
    raw: dict = field(repr=False, default_factory=dict)

    @property
    def num_vars(self) -> int:
        return len(self.params)

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def branches(self) -> list[int]:
        """Sorted distinct branch ids among the groups (may be empty)."""
        return sorted({g.branch for g in self.groups if g.branch is not None})

    def normalize(self, ks: list[float]) -> list[float]:
        return [p.normalize(k) for p, k in zip(self.params, ks)]

    def structured_feature_count(self) -> int:
        """Total compact features of the structured predictor (30 for
        MotionSIFT; the paper's Sec 4.3 economics)."""
        return sum(monomial_count(len(g.params), self.degree) for g in self.groups)

    def unstructured_feature_count(self) -> int:
        return monomial_count(self.num_vars, self.degree)

    def combine_matrices(self) -> tuple[list[float], list[list[float]]]:
        """(seq_vector[G], branch_matrix[B][G]) for critical-path combine.

        end_to_end = offset + pred @ seq_vector
                     + max_b (pred @ branch_matrix[b])      (if B > 0)
        """
        seq = [1.0 if g.branch is None else 0.0 for g in self.groups]
        bmat = [
            [1.0 if g.branch == b else 0.0 for g in self.groups]
            for b in self.branches
        ]
        return seq, bmat


def load_spec(name: str) -> AppSpec:
    path = os.path.join(SPEC_DIR, f"{name}.json")
    with open(path) as f:
        raw = json.load(f)
    return AppSpec(
        name=raw["name"],
        title=raw["title"],
        latency_bounds_ms=raw["latency_bounds_ms"],
        params=[Param(**p) for p in raw["params"]],
        stages=[Stage(**s) for s in raw["stages"]],
        groups=[Group(**g) for g in raw["groups"]],
        degree=raw["degree"],
        candidate_pad=raw["candidate_pad"],
        feature_pad=raw["feature_pad"],
        raw=raw,
    )


def all_specs() -> list[AppSpec]:
    return [load_spec("pose"), load_spec("motion_sift")]
