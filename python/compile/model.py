"""L2: the JAX compute graph of the auto-tuner's predictor, per app/variant.

For every (application, variant) pair this module assembles three jittable
functions out of the L1 Pallas kernels:

  predict(u_aug, weights, offset)                  -> c_hat[N]
  update(weights, u_aug, y, eta)                   -> weights'
  solve(u_aug, weights, offset, reward, valid, L)  -> (best_idx, c_hat[N])

``unstructured`` learns one cubic regressor of all five knobs against the
end-to-end latency (56 features for 5 vars); ``structured`` keeps one
regressor per critical-stage group over that group's knob subset (paper
Sec 2.3/3.3 — 10 + 20 = 30 compact features for MotionSIFT) and combines
group predictions along the critical path: sum over sequential groups,
max over parallel branches (Eq. 9), plus a moving-average offset for the
non-critical stages supplied by the Rust coordinator.

All monomials are enumerated in the *full* variable space (graded-lex) and
groups carry support masks over that space; this keeps every artifact's
shapes uniform while preserving exactly the structured math. The compact
30-feature economics are exercised by the Rust native learner and the
structure benches.

These functions are lowered once by ``aot.py`` into HLO text artifacts;
Python never runs on the request path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .kernels import ogd as ogd_k
from .kernels import poly as poly_k
from .spec import EPS_INSENSITIVE, GAMMA, PA_DAMPING, AppSpec, monomials

VARIANTS = ("unstructured", "structured")


def full_space_encoding(spec: AppSpec):
    """(idx[D, Fpad], valid[Fpad], monos) for the full variable space."""
    monos = monomials(spec.num_vars, spec.degree)
    if len(monos) > spec.feature_pad:
        raise ValueError("feature_pad too small")
    one = spec.num_vars
    idx = np.full((spec.degree, spec.feature_pad), one, dtype=np.int32)
    valid = np.zeros((spec.feature_pad,), dtype=np.float32)
    for j, mono in enumerate(monos):
        valid[j] = 1.0
        for d, var in enumerate(mono):
            idx[d, j] = var
    return idx, valid, monos


def group_support(spec: AppSpec, variant: str, monos, valid):
    """Per-group subspace masks over the full monomial space -> [G, Fpad]."""
    if variant == "unstructured":
        return valid[None, :].copy()
    rows = []
    for grp in spec.groups:
        allowed = set(grp.params)
        row = np.zeros_like(valid)
        for j, mono in enumerate(monos):
            if set(mono) <= allowed:
                row[j] = 1.0
        rows.append(row)
    return np.stack(rows)


def combine_arrays(spec: AppSpec, variant: str):
    """(seq_vec[G], branch_mat[B, G]) as float32 numpy arrays."""
    if variant == "unstructured":
        return np.ones((1,), np.float32), np.zeros((0, 1), np.float32)
    seq, bmat = spec.combine_matrices()
    return (
        np.asarray(seq, np.float32),
        np.asarray(bmat, np.float32).reshape(len(bmat), spec.num_groups),
    )


@dataclass
class ModelBundle:
    """The three jittable tuner functions plus their static metadata."""

    spec: AppSpec
    variant: str
    num_groups: int
    idx: np.ndarray         # [D, Fpad]
    valid: np.ndarray       # [Fpad]
    support: np.ndarray     # [G, Fpad]
    seq_vec: np.ndarray     # [G]
    branch_mat: np.ndarray  # [B, G]

    def predict(self, u_aug, weights, offset):
        """End-to-end latency prediction for a padded candidate batch."""
        pred = poly_k.poly_predict(
            u_aug, weights, idx=self.idx, valid=self.valid
        )                                                   # [N, G]
        c = pred @ jnp.asarray(self.seq_vec) + offset[0]
        if self.branch_mat.shape[0] > 0:
            per_branch = pred @ jnp.asarray(self.branch_mat).T
            c = c + jnp.max(per_branch, axis=-1)
        return c

    def update(self, weights, u_aug, y, eta):
        """One fused OGD step (L1 kernel)."""
        return ogd_k.ogd_update(
            weights, u_aug, y, eta,
            idx=self.idx, support=self.support,
            gamma=GAMMA, eps_ins=EPS_INSENSITIVE, pa_damping=PA_DAMPING,
        )

    def solve(self, u_aug, weights, offset, reward, cand_valid, bound):
        """Constrained argmax over candidates (paper Eq. 2) + predictions."""
        c = self.predict(u_aug, weights, offset)
        feasible = (c <= bound[0]) & (cand_valid > 0.5)
        score = jnp.where(feasible, reward, -jnp.inf)
        fallback = jnp.where(cand_valid > 0.5, c, jnp.inf)
        idx_best = jnp.where(
            jnp.any(feasible), jnp.argmax(score), jnp.argmin(fallback)
        ).astype(jnp.int32)
        return jnp.reshape(idx_best, (1,)), c

    # --- example arguments for AOT lowering (static shapes) -------------
    def example_args(self, op: str):
        n = self.spec.candidate_pad
        vp = self.spec.num_vars + 1
        g, f = self.num_groups, self.spec.feature_pad
        f32 = np.float32
        u_batch = np.zeros((n, vp), f32)
        w = np.zeros((g, f), f32)
        one = np.zeros((1,), f32)
        if op == "predict":
            return (u_batch, w, one)
        if op == "update":
            return (w, np.zeros((vp,), f32), np.zeros((g,), f32), one)
        if op == "solve":
            return (u_batch, w, one, np.zeros((n,), f32), np.zeros((n,), f32), one)
        raise ValueError(op)

    def fn(self, op: str):
        return {"predict": self.predict, "update": self.update,
                "solve": self.solve}[op]


def build(spec: AppSpec, variant: str) -> ModelBundle:
    if variant not in VARIANTS:
        raise ValueError(variant)
    idx, valid, monos = full_space_encoding(spec)
    support = group_support(spec, variant, monos, valid)
    seq_vec, branch_mat = combine_arrays(spec, variant)
    return ModelBundle(
        spec=spec,
        variant=variant,
        num_groups=support.shape[0],
        idx=idx,
        valid=valid,
        support=support,
        seq_vec=seq_vec,
        branch_mat=branch_mat,
    )
