"""Behavioral mirror for the live path's epoch protocols (rust:
``scheduler/live.rs``): simulates the barrier and frontier accounting
over deterministic arrival schedules and validates the >= 3x
straggler-isolation threshold the Rust regression test
(``rust/tests/frontier_live.rs``) and the CI ``live-smoke`` job assert.

Pure stdlib — no jax/hypothesis required.

Model: ``n`` tenants each deliver ``frames`` frames; tenant ``i``'s
k-th frame arrives at time ``k * delay[i]``. A decision at epoch ``e``
counts a *completed epoch* for a tenant iff it folded a full fresh
``epoch_frames`` batch of that tenant's frames — the decision-cadence
metric ``completed_epochs`` in the Rust reports.
"""

import heapq


def arrivals(n, frames, delays):
    """Merged (time, tenant) arrival stream, stable on ties by tenant."""
    heap = [(delays[i], i, 1) for i in range(n)]
    heapq.heapify(heap)
    out = []
    while heap:
        t, i, k = heapq.heappop(heap)
        out.append((t, i))
        if k < frames:
            heapq.heappush(heap, (t + delays[i], i, k + 1))
    return out


def barrier_completed(n, frames, ef, delays):
    """Legacy protocol: fold eagerly, fire when every tenant passes the
    frame-count boundary; a stalled boundary gulps banked frames in bulk."""
    seen = [0] * n
    last = [0] * n
    completed = [0] * n
    boundary = ef
    for _, i in arrivals(n, frames, delays):
        seen[i] += 1
        if boundary < frames and all(s >= min(boundary, frames) for s in seen):
            for a in range(n):
                if seen[a] - last[a] >= ef:
                    completed[a] += 1
                last[a] = seen[a]
            boundary += ef
    return completed, seen


def frontier_completed(n, frames, ef, delays):
    """Frontier protocol: per-tenant clocks, decisions fire as the lower
    envelope advances, each folding exactly one fresh epoch batch per
    tenant (surplus arrivals wait in the per-tenant buffer)."""
    delivered = [0] * n
    folded = [0] * n
    last = [0] * n
    target = [min(ef, frames)] * n
    completed = [0] * n
    next_decision = 1
    for _, i in arrivals(n, frames, delays):
        delivered[i] += 1
        while next_decision * ef < frames and all(
            d // ef > next_decision - 1 or d >= frames for d in delivered
        ):
            for a in range(n):
                folded[a] = max(folded[a], min(target[a], delivered[a]))
                if folded[a] - last[a] >= ef:
                    completed[a] += 1
                last[a] = folded[a]
                target[a] = min(target[a] + ef, frames)
            next_decision += 1
    return completed, delivered


def test_frontier_isolates_stragglers_at_3x():
    # tenant 0 is the straggler; the slower it is relative to the rest,
    # the harder the barrier collapses the fast tenants' decision
    # cadence, while the frontier keeps them at one epoch per decision
    n, frames, ef = 3, 300, 30
    decisions = (frames - 1) // ef  # epochs 1..9 fire inside the window
    for ratio in (10.0, 100.0, 1000.0):
        delays = [ratio] + [1.0] * (n - 1)
        bar, bar_seen = barrier_completed(n, frames, ef, delays)
        fro, fro_seen = frontier_completed(n, frames, ef, delays)
        assert bar_seen == [frames] * n, "barrier lost frames"
        assert fro_seen == [frames] * n, "frontier lost frames"
        for i in range(1, n):
            assert fro[i] == decisions, (ratio, i, fro)
            assert fro[i] >= 3 * max(bar[i], 1), (
                f"ratio {ratio}: frontier {fro[i]} vs barrier {bar[i]} "
                f"for non-straggler {i} — the 3x threshold the Rust "
                f"regression asserts does not hold in the mirror"
            )


def test_both_protocols_agree_without_stragglers():
    n, frames, ef = 3, 300, 30
    delays = [1.0] * n
    bar, _ = barrier_completed(n, frames, ef, delays)
    fro, _ = frontier_completed(n, frames, ef, delays)
    decisions = (frames - 1) // ef
    assert bar == [decisions] * n
    assert fro == [decisions] * n


def test_barrier_collapse_threshold_is_shallow():
    # even a 2x straggler already costs the barrier's fast tenants
    # completions once their stream ends mid-window; document the
    # monotone collapse as the ratio grows
    n, frames, ef = 3, 300, 30
    prev = None
    for ratio in (2.0, 5.0, 20.0, 200.0):
        bar, _ = barrier_completed(n, frames, ef, [ratio, 1.0, 1.0])
        fast = bar[1]
        assert prev is None or fast <= prev, "collapse must be monotone"
        prev = fast
    assert prev <= 1, f"at 200x the barrier should be fully collapsed: {bar}"
