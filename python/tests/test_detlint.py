"""Tests for ``scripts/detlint.py`` — the determinism-invariant static
analysis pass behind the blocking ``static-analysis`` CI job.

Each rule gets a positive fixture (the hazard in a product-reachable
module must flag) and a negative one (the safe spelling, or the same
line out of reach, must not). Fixture trees have no ``lib.rs``, so
detlint's fixture fallback names every file by its path — ``trace.rs``
becomes the product root ``trace``, ``util/bench.rs`` the bench-only
module ``util::bench`` — which makes reachability scenarios one file
write each. The final test self-checks the real tree: ``rust/src`` must
lint clean, which is exactly what CI enforces.

Pure stdlib — no jax/hypothesis required.
"""

import importlib.util
import json
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
DETLINT = REPO / "scripts" / "detlint.py"

_spec = importlib.util.spec_from_file_location("detlint", DETLINT)
detlint = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(detlint)


def lint(tree):
    """Run detlint over an in-memory fixture tree; return (exit, report)."""
    with tempfile.TemporaryDirectory() as td:
        root = Path(td)
        for rel, text in tree.items():
            p = root / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(text)
        out = root / "report.json"
        code = detlint.run(root, json_out=out)
        report = json.loads(out.read_text())
    return code, report


def rules_hit(report):
    return sorted({v["rule"] for v in report["violations"]})


# ---------------------------------------------------------------------------
# per-rule positives and negatives
# ---------------------------------------------------------------------------

def test_hash_iter_flagged_in_product_code():
    code, rep = lint({"trace.rs": (
        "use std::collections::HashMap;\n"
        "pub fn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n"
    )})
    assert code == 1
    assert "hash-iter" in rules_hit(rep)


def test_btree_is_the_accepted_spelling():
    code, rep = lint({"trace.rs": (
        "use std::collections::BTreeMap;\n"
        "pub fn f() { let m: BTreeMap<u32, u32> = BTreeMap::new(); }\n"
    )})
    assert code == 0
    assert rep["violations"] == []


def test_wallclock_flagged_outside_allowlist():
    code, rep = lint({"scheduler.rs": (
        "pub fn f() -> std::time::Instant { std::time::Instant::now() }\n"
    )})
    assert code == 1
    assert "wallclock" in rules_hit(rep)


def test_wallclock_allowed_in_bench_module():
    # trace depends on util::bench, pulling it into the product set —
    # but util::bench is on the wall-clock allowlist.
    code, rep = lint({
        "trace.rs": "use crate::util::bench;\npub fn f() { bench::go(); }\n",
        "util/bench.rs": "pub fn go() { let _ = std::time::Instant::now(); }\n",
    })
    assert "util::bench" in rep["reachable_modules"]
    assert code == 0, rep["violations"]


def test_thread_id_flagged():
    code, rep = lint({"engine.rs": (
        "pub fn f() -> std::thread::ThreadId { std::thread::current().id() }\n"
    )})
    assert code == 1
    assert "thread-id" in rules_hit(rep)


def test_float_eq_flagged_epsilon_compare_clean():
    code, rep = lint({"learner.rs": (
        "pub fn bad(x: f64) -> bool { x == 0.5 }\n"
        "pub fn good(x: f64) -> bool { (x - 0.5).abs() < 1e-9 }\n"
    )})
    assert code == 1
    flagged = [v["line"] for v in rep["violations"] if v["rule"] == "float-eq"]
    assert flagged == [1], rep["violations"]


def test_lossy_cast_flags_float_to_int_not_widening():
    code, rep = lint({"fleet.rs": (
        "pub fn bad(x: f64) -> usize { x.round() as usize }\n"
        "pub fn narrow(y: f64) -> f32 { y as f32 }\n"
        "pub fn fine(n: usize) -> u64 { n as u64 }\n"
    )})
    assert code == 1
    flagged = sorted(v["line"] for v in rep["violations"] if v["rule"] == "lossy-cast")
    assert flagged == [1, 2], rep["violations"]


def test_unwrap_flagged_idioms_and_tests_exempt():
    code, rep = lint({"tuner.rs": (
        "pub fn bad(v: &[u32]) -> u32 { *v.first().unwrap() }\n"
        "pub fn idiom(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    #[test]\n"
        "    fn t() { Some(1).unwrap(); }\n"
        "}\n"
    )})
    assert code == 1
    flagged = [v["line"] for v in rep["violations"] if v["rule"] == "unwrap"]
    assert flagged == [1], rep["violations"]


def test_strings_and_comments_never_flag():
    code, rep = lint({"obs.rs": (
        'pub fn f() -> &\'static str { "HashMap Instant::now unwrap()" }\n'
        "// HashMap in a comment is fine\n"
        "/* Instant::now in a block comment too */\n"
    )})
    assert code == 0, rep["violations"]


# ---------------------------------------------------------------------------
# suppression annotations
# ---------------------------------------------------------------------------

def test_trailing_allow_with_reason_suppresses():
    code, rep = lint({"trace.rs": (
        "pub fn f(v: &[u32]) -> u32 { *v.first().unwrap() } "
        "// detlint: allow(unwrap) — caller guarantees non-empty\n"
    )})
    assert code == 0
    assert len(rep["suppressed"]) == 1
    assert rep["suppressed"][0]["reason"] == "caller guarantees non-empty"


def test_standalone_allow_covers_next_line():
    code, rep = lint({"trace.rs": (
        "// detlint: allow(unwrap) — seeded at construction\n"
        "pub fn f(v: &[u32]) -> u32 { *v.first().unwrap() }\n"
    )})
    assert code == 0
    assert [s["line"] for s in rep["suppressed"]] == [2]


def test_reasonless_allow_is_an_error():
    code, rep = lint({"trace.rs": (
        "// detlint: allow(unwrap)\n"
        "pub fn f(v: &[u32]) -> u32 { *v.first().unwrap() }\n"
    )})
    assert code == 1
    assert rep["annotation_errors"], rep
    # and the unsuppressed violation still stands
    assert "unwrap" in rules_hit(rep)


def test_unknown_rule_in_allow_is_an_error():
    code, rep = lint({"trace.rs": (
        "// detlint: allow(made-up-rule) — whatever\n"
        "pub fn f() {}\n"
    )})
    assert code == 1
    assert "unknown rule" in rep["annotation_errors"][0]["error"]


def test_stale_allow_is_reported_not_fatal():
    code, rep = lint({"trace.rs": (
        "// detlint: allow(unwrap) — nothing to suppress here\n"
        "pub fn f() {}\n"
    )})
    assert code == 0
    assert [s["rule"] for s in rep["stale_allows"]] == ["unwrap"]


# ---------------------------------------------------------------------------
# module-graph reachability
# ---------------------------------------------------------------------------

def test_bench_only_module_is_out_of_reach():
    # The identical hash container: harmless in a module no product root
    # depends on, a violation inside trace/.
    hazard = "use std::collections::HashMap;\npub type M = HashMap<u32, u32>;\n"
    code, rep = lint({"util/scratch.rs": hazard})
    assert code == 0
    assert "util::scratch" not in rep["reachable_modules"]

    code2, rep2 = lint({"trace.rs": hazard})
    assert code2 == 1
    assert "hash-iter" in rules_hit(rep2)


def test_reachability_follows_use_edges():
    # trace -> util::helper makes the helper product-reachable, and its
    # hazard flags; an unreferenced sibling stays invisible.
    tree = {
        "trace.rs": "use crate::util::helper;\npub fn f() { helper::g(); }\n",
        "util/helper.rs": "pub fn g() { let _ = std::time::Instant::now(); }\n",
        "util/orphan.rs": "pub fn h() { let _ = std::time::Instant::now(); }\n",
    }
    code, rep = lint(tree)
    assert code == 1
    files = {v["file"] for v in rep["violations"]}
    assert "util/helper.rs" in files
    assert "util/orphan.rs" not in files


def test_test_only_dependency_does_not_reach():
    # A dependency used solely from #[cfg(test)] must not pull the
    # target into the product set.
    tree = {
        "trace.rs": (
            "pub fn f() {}\n"
            "#[cfg(test)]\n"
            "mod tests {\n"
            "    use crate::util::scratch;\n"
            "    #[test]\n"
            "    fn t() { scratch::h(); }\n"
            "}\n"
        ),
        "util/scratch.rs": "pub fn h() { let _ = std::time::Instant::now(); }\n",
    }
    code, rep = lint(tree)
    assert code == 0, rep["violations"]
    assert "util::scratch" not in rep["reachable_modules"]


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------

def test_real_tree_is_clean():
    """The acceptance criterion CI enforces: rust/src lints clean, and
    every suppression carries a reasoned annotation."""
    src = REPO / "rust" / "src"
    with tempfile.TemporaryDirectory() as td:
        out = Path(td) / "report.json"
        code = detlint.run(src, json_out=out)
        rep = json.loads(out.read_text())
    assert code == 0, rep["violations"] or rep["annotation_errors"]
    assert rep["violations"] == []
    assert rep["annotation_errors"] == []
    assert rep["stale_allows"] == []
    assert all(s["reason"] for s in rep["suppressed"])
    # the determinism roots must actually resolve to modules
    for root in ("trace", "obs", "scheduler", "learner", "fleet", "engine"):
        assert root in rep["reachable_modules"], root
