"""Behavioral mirror for the streaming latency histogram (rust:
``obs/hist.rs``): re-implements bucketing, merge, and quantile
resolution with pure stdlib and validates the edge cases the Rust unit
tests assert — empty/single-sample/saturating quantiles, boundary
samples landing in the upper bucket, and merge == concatenation.

The constants are parsed out of ``hist.rs`` so the mirror cannot drift
silently, and the bucket edges are produced by the *same repeated f64
multiplication* the Rust walk uses (CPython floats are IEEE-754 doubles,
so every edge is bit-identical and every `edge <= v` comparison agrees).
"""

import bisect
import math
import pathlib
import re

HIST_RS = pathlib.Path(__file__).resolve().parents[2] / "rust" / "src" / "obs" / "hist.rs"


def _const(name, cast):
    text = HIST_RS.read_text()
    m = re.search(rf"pub const {name}: \w+ = ([0-9.]+);", text)
    assert m, f"{name} not found in {HIST_RS}"
    return cast(m.group(1))


HIST_MIN_MS = _const("HIST_MIN_MS", float)
HIST_GROWTH = _const("HIST_GROWTH", float)
HIST_BUCKETS = _const("HIST_BUCKETS", int)

# Finite bucket edges by repeated multiplication — the exact sequence the
# Rust record() walk generates.
EDGES = []
_edge = HIST_MIN_MS
for _ in range(HIST_BUCKETS):
    EDGES.append(_edge)
    _edge *= HIST_GROWTH


class Histogram:
    """Mirror of obs::hist::Histogram."""

    def __init__(self):
        self.counts = [0] * (HIST_BUCKETS + 1)
        self.count = 0
        self.sum_ms = 0.0
        self.min_ms = math.inf
        self.max_ms = -math.inf

    def record(self, ms):
        v = ms if (math.isfinite(ms) and ms > 0.0) else 0.0
        # number of edges <= v == the Rust early-exit walk's index
        idx = bisect.bisect_right(EDGES, v)
        self.counts[idx] += 1
        self.count += 1
        self.sum_ms += v
        self.min_ms = min(self.min_ms, v)
        self.max_ms = max(self.max_ms, v)

    def merge(self, other):
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.sum_ms += other.sum_ms
        self.min_ms = min(self.min_ms, other.min_ms)
        self.max_ms = max(self.max_ms, other.max_ms)

    def quantile(self, q):
        if self.count == 0:
            return None
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                upper = math.inf if i == HIST_BUCKETS else EDGES[i]
                return min(upper, self.max_ms)
        return self.max_ms


def hist_of(samples):
    h = Histogram()
    for s in samples:
        h.record(s)
    return h


def test_constants_span_interactive_latencies():
    assert 0.0 < HIST_MIN_MS < 1.0
    assert 1.0 < HIST_GROWTH < 2.0
    assert EDGES[-1] > 10_000.0, "top edge must exceed 10 s"


def test_empty_histogram_has_no_quantiles():
    h = Histogram()
    assert h.count == 0
    for q in (0.5, 0.95, 0.99, 1.0):
        assert h.quantile(q) is None


def test_single_sample_quantiles_are_exact():
    h = hist_of([12.34])
    # the bucket upper edge is clamped to the observed max, so every
    # quantile of a one-sample histogram is the sample itself
    for q in (0.01, 0.5, 0.99, 1.0):
        assert h.quantile(q) == 12.34


def test_boundary_sample_lands_in_upper_bucket():
    # an exact edge value v has edge <= v true, so it counts one more
    # edge and lands in the bucket *above* the edge — mirror and Rust
    # must agree on this tie direction
    for k in (0, 1, 17, HIST_BUCKETS - 1):
        edge = EDGES[k]
        h = hist_of([edge])
        assert h.counts[k + 1] == 1, f"edge {k} must land in bucket {k + 1}"
    just_below = EDGES[17] * (1 - 1e-12)
    h = hist_of([just_below])
    assert h.counts[17] == 1


def test_saturating_top_bucket_clamps_to_max():
    h = hist_of([1e9, 2e9])  # way past the top finite edge
    assert h.counts[HIST_BUCKETS] == 2
    # both samples share the saturating bucket, whose upper edge is inf;
    # the clamp to the observed max keeps every quantile finite
    assert h.quantile(0.5) == 2e9
    assert h.quantile(1.0) == 2e9
    single = hist_of([7e7])
    assert single.quantile(0.99) == 7e7


def test_degenerate_samples_clamp_to_bucket_zero():
    h = hist_of([-5.0, 0.0, math.nan, math.inf])
    assert h.counts[0] == 4
    assert h.quantile(0.5) == 0.0


def test_merge_equals_concatenation():
    a_s = [0.01 * (i % 37) + 0.3 * i for i in range(200)]
    b_s = [5.0 + 1.7 * i for i in range(113)]
    a, b = hist_of(a_s), hist_of(b_s)
    a.merge(b)
    both = hist_of(a_s + b_s)
    assert a.counts == both.counts
    assert a.count == both.count
    assert a.sum_ms == both.sum_ms
    assert (a.min_ms, a.max_ms) == (both.min_ms, both.max_ms)
    for q in (0.5, 0.95, 0.99):
        assert a.quantile(q) == both.quantile(q)


def test_quantiles_are_monotone_and_bounded():
    h = hist_of([0.07 + 0.91 * i for i in range(500)])
    qs = [h.quantile(q) for q in (0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0)]
    assert qs == sorted(qs)
    assert all(v <= h.max_ms for v in qs)
    assert h.quantile(1.0) == h.max_ms


def test_quantile_never_underestimates_true_percentile():
    # upper-edge resolution: the reported quantile is >= the true sample
    # at that rank (conservative for SLO checking), within one bucket
    samples = sorted(0.11 * (i**1.3) + 0.06 for i in range(1, 400))
    h = hist_of(samples)
    for q in (0.5, 0.9, 0.95, 0.99):
        rank = max(1, math.ceil(q * len(samples)))
        true_v = samples[rank - 1]
        got = h.quantile(q)
        assert got >= true_v * 0.999999
        assert got <= true_v * (HIST_GROWTH * 1.000001)
