"""L2 model bundle tests: predict/update/solve per app/variant vs ref.py,
combination semantics (Eq. 9), and solver feasibility (Eq. 2)."""

import jax.numpy as jnp
import numpy as np
import pytest

# container images may lack hypothesis (only CI installs it) — skip
# cleanly instead of erroring at collection (see requirements-dev.txt)
pytest.importorskip("hypothesis")
from hypothesis import settings

from compile import model as M
from compile.kernels import ref
from compile.spec import all_specs, load_spec

settings.register_profile("model", deadline=None, max_examples=5)
settings.load_profile("model")

BUNDLES = [(s, v) for s in all_specs() for v in M.VARIANTS]
IDS = [f"{s.name}-{v}" for s, v in BUNDLES]


@pytest.fixture(scope="module")
def bundles():
    return {(s.name, v): M.build(s, v) for s, v in BUNDLES}


def rand_inputs(b, seed):
    rng = np.random.default_rng(seed)
    n = b.spec.candidate_pad
    v = b.spec.num_vars
    g, f = b.support.shape
    u = np.concatenate(
        [rng.random((n, v)).astype(np.float32), np.ones((n, 1), np.float32)],
        axis=1)
    w = rng.standard_normal((g, f)).astype(np.float32) * b.support
    return rng, u, w


class TestPredict:
    @pytest.mark.parametrize("key", IDS)
    def test_matches_ref(self, bundles, key):
        app, variant = key.rsplit("-", 1)
        b = bundles[(app, variant)]
        rng, u, w = rand_inputs(b, 42)
        off = np.asarray([5.0], np.float32)
        got = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                   jnp.asarray(off)))
        g = b.support.shape[0]
        bm = (b.branch_mat if b.branch_mat.shape[0]
              else np.zeros((0, g), np.float32))
        want = np.asarray(ref.predict(
            jnp.asarray(u), jnp.asarray(w),
            jnp.asarray(np.stack([b.idx] * g)), jnp.asarray(b.support),
            jnp.asarray(b.seq_vec), jnp.asarray(bm), 5.0))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_offset_shifts_prediction(self, bundles):
        b = bundles[("pose", "unstructured")]
        _, u, w = rand_inputs(b, 0)
        c0 = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                  jnp.asarray(np.asarray([0.0], np.float32))))
        c9 = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                  jnp.asarray(np.asarray([9.0], np.float32))))
        np.testing.assert_allclose(c9 - c0, 9.0, rtol=1e-5)

    def test_motion_sift_structured_is_max_of_branches(self, bundles):
        """Paper Eq. 9: f = max(f_L, f_R) for the two-branch graph."""
        b = bundles[("motion_sift", "structured")]
        rng, u, w = rand_inputs(b, 1)
        off = np.asarray([0.0], np.float32)
        c = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                 jnp.asarray(off)))
        from compile.kernels.poly import poly_predict
        pg = np.asarray(poly_predict(jnp.asarray(u), jnp.asarray(w),
                                     idx=b.idx, valid=b.valid))
        np.testing.assert_allclose(c, np.maximum(pg[:, 0], pg[:, 1]),
                                   rtol=1e-4, atol=1e-4)


class TestUpdate:
    @pytest.mark.parametrize("key", IDS)
    def test_matches_ref(self, bundles, key):
        app, variant = key.rsplit("-", 1)
        b = bundles[(app, variant)]
        rng, u, w = rand_inputs(b, 7)
        g = b.support.shape[0]
        y = (rng.random(g) * 80).astype(np.float32)
        got = np.asarray(b.update(jnp.asarray(w), jnp.asarray(u[0]),
                                  jnp.asarray(y),
                                  jnp.asarray(np.float32(0.03))))
        want = np.asarray(ref.ogd_update(
            jnp.asarray(w), jnp.asarray(u[0]), jnp.asarray(y),
            jnp.asarray(np.stack([b.idx] * g)), jnp.asarray(b.support),
            np.float32(0.03), 0.01, 0.01))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("seed", [0, 7])
    def test_learns_a_linear_target(self, bundles, seed):
        """OGD on the unstructured pose model fits y = 1 + 3*u0 (targets
        in normalized latency units; 1 unit = 100 ms)."""
        b = bundles[("pose", "unstructured")]
        rng = np.random.default_rng(seed)
        v = b.spec.num_vars
        w = np.zeros_like(b.support)
        for t in range(1, 200):
            u = np.concatenate([rng.random(v).astype(np.float32),
                                [np.float32(1.0)]])
            y = np.asarray([1.0 + 3.0 * u[0]], np.float32)
            w = np.asarray(b.update(jnp.asarray(w), jnp.asarray(u),
                                    jnp.asarray(y),
                                    jnp.asarray(np.float32(1.0 / np.sqrt(t)))))
        # probe
        errs = []
        for _ in range(50):
            u = np.concatenate([rng.random(v).astype(np.float32),
                                [np.float32(1.0)]])[None, :]
            c = np.asarray(b.predict(
                jnp.asarray(np.repeat(u, b.spec.candidate_pad, 0)),
                jnp.asarray(w),
                jnp.asarray(np.asarray([0.0], np.float32))))[0]
            errs.append(abs(c - (1.0 + 3.0 * u[0, 0])))
        assert np.mean(errs) < 0.5


class TestSolve:
    @pytest.mark.parametrize("key", IDS)
    def test_feasible_choice(self, bundles, key):
        """Solver never returns an infeasible action when one is feasible."""
        app, variant = key.rsplit("-", 1)
        b = bundles[(app, variant)]
        rng, u, w = rand_inputs(b, 11)
        n = b.spec.candidate_pad
        off = np.asarray([0.0], np.float32)
        r = rng.random(n).astype(np.float32)
        cv = np.ones(n, np.float32)
        c = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                 jnp.asarray(off)))
        bound = float(np.percentile(c, 60))
        i, c2 = b.solve(jnp.asarray(u), jnp.asarray(w), jnp.asarray(off),
                        jnp.asarray(r), jnp.asarray(cv),
                        jnp.asarray(np.asarray([bound], np.float32)))
        i = int(np.asarray(i)[0])
        np.testing.assert_allclose(np.asarray(c2), c, rtol=1e-5)
        assert c[i] <= bound + 1e-3
        feas = c <= bound
        assert r[i] == pytest.approx(float(r[feas].max()))

    def test_fallback_to_min_latency(self, bundles):
        """With no feasible candidate, pick the min predicted latency."""
        b = bundles[("motion_sift", "unstructured")]
        rng, u, w = rand_inputs(b, 13)
        n = b.spec.candidate_pad
        off = np.asarray([0.0], np.float32)
        r = rng.random(n).astype(np.float32)
        cv = np.ones(n, np.float32)
        c = np.asarray(b.predict(jnp.asarray(u), jnp.asarray(w),
                                 jnp.asarray(off)))
        bound = float(c.min()) - 100.0
        i, _ = b.solve(jnp.asarray(u), jnp.asarray(w), jnp.asarray(off),
                       jnp.asarray(r), jnp.asarray(cv),
                       jnp.asarray(np.asarray([bound], np.float32)))
        assert int(np.asarray(i)[0]) == int(np.argmin(c))

    def test_padding_mask_respected(self, bundles):
        """Padded candidates (valid=0) are never selected."""
        b = bundles[("pose", "structured")]
        rng, u, w = rand_inputs(b, 17)
        n = b.spec.candidate_pad
        off = np.asarray([0.0], np.float32)
        r = np.zeros(n, np.float32)
        r[-8:] = 10.0                     # juicy rewards on padded slots
        cv = np.ones(n, np.float32)
        cv[-8:] = 0.0                     # ... which are invalid
        i, _ = b.solve(jnp.asarray(u), jnp.asarray(w), jnp.asarray(off),
                       jnp.asarray(r), jnp.asarray(cv),
                       jnp.asarray(np.asarray([1e9], np.float32)))
        assert int(np.asarray(i)[0]) < n - 8


class TestStructuredEconomics:
    def test_motion_sift_30_vs_56(self):
        s = load_spec("motion_sift")
        assert s.structured_feature_count() == 30
        assert s.unstructured_feature_count() == 56

    def test_support_masks_match_counts(self, bundles):
        from compile.spec import monomial_count
        for s in all_specs():
            b = bundles[(s.name, "structured")]
            per_group = b.support.sum(axis=1)
            want = [monomial_count(len(g.params), s.degree) for g in s.groups]
            np.testing.assert_array_equal(per_group, want)
