"""Make the ``python/`` source dir importable regardless of pytest's
invocation directory, so ``from compile import ...`` resolves whether the
suite runs as ``pytest python/tests`` from the repo root (CI) or from
inside ``python/``."""

import sys
from pathlib import Path

_PY_ROOT = str(Path(__file__).resolve().parents[1])
if _PY_ROOT not in sys.path:
    sys.path.insert(0, _PY_ROOT)
