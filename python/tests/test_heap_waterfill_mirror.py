"""Behavioral mirror for the PR 8 heap water-filler (rust:
``scheduler/mod.rs`` ``allocate_v2``): reimplements both the legacy
full-scan allocator and the priority-heap allocator over the same
semantics and validates, on random instances,

* **exact equivalence** — the heap fill must reproduce the scan's rung
  vector bit-for-bit, including the tie-break order (gain descending,
  app ascending, target rung ascending; top-up by lowest allocation,
  then lowest index). Instances quantize utilities so exact float ties
  actually occur.
* **sub-linear per-tenant cost** — the op-count of one heap epoch,
  divided by the tenant count, may grow at most 1.5x between 1k and
  100k tenants on the bench-shaped instance family
  (``allocate_v2/{1k,100k}_tenants`` in ``rust/benches/scheduler.rs``).
  This is the acceptance bound behind the
  ``allocate_v2/per_tenant_ratio_100k_over_1k`` side metric recorded
  in ``ci/bench-trajectory.json``; the legacy scan's per-tenant cost
  grows ~linearly (O(moves x tenants x rungs) total), which is exactly
  what the tripwire exists to catch.

Pure stdlib — no jax/hypothesis required.
"""

import heapq
import math
import random


# ---------------------------------------------------------------------------
# mirrors of rust/src/scheduler/mod.rs
# ---------------------------------------------------------------------------

def core_levels(total, apps, floor, rungs, boost):
    """Mirror of ``core_levels``: shared rung ladder (sorted, distinct)."""
    even = max(total // max(apps, 1), 1)
    floor = min(max(floor, 1), even)
    cap = max(min(math.ceil(even * boost),
                  max(total - (apps - 1) * floor, 0)), even)
    levels = {floor, even, cap}
    if rungs > 1 and cap > floor:
        ratio = cap / floor
        for i in range(rungs):
            lvl = round(floor * ratio ** (i / (rungs - 1)))
            levels.add(min(max(lvl, floor), cap))
    return sorted(levels)


def _adj(curves, weights, prev, hysteresis, a, l):
    u = weights[a] * curves[a][l]
    if hysteresis > 0.0 and prev is not None and prev[a] == l:
        u += hysteresis
    return u


def allocate_v2_scan(curves, levels, total, weights, prev, hysteresis):
    """The legacy full-scan water-filler, both phases, verbatim
    semantics (the pre-PR8 ``allocate_v2`` body)."""
    napps = len(curves)
    lvl = [0] * napps
    used = napps * levels[0]
    assert used <= total, "floor rung oversubscribes the cluster"

    def adj(a, l):
        return _adj(curves, weights, prev, hysteresis, a, l)

    while True:
        best = None  # (gain/core, app, rung)
        for a in range(napps):
            for j in range(lvl[a] + 1, len(levels)):
                if used - levels[lvl[a]] + levels[j] > total:
                    continue
                du = adj(a, j) - adj(a, lvl[a])
                if du <= 1e-12:
                    continue
                g = du / (levels[j] - levels[lvl[a]])
                if best is None or g > best[0]:
                    best = (g, a, j)
        if best is None:
            break
        _, a, j = best
        used = used - levels[lvl[a]] + levels[j]
        lvl[a] = j

    even = total // napps
    while True:
        cand = None  # (cores, app, rung)
        for a in range(napps):
            j = lvl[a] + 1
            if j >= len(levels) or levels[j] > even:
                continue
            if used - levels[lvl[a]] + levels[j] > total:
                continue
            if cand is None or levels[lvl[a]] < cand[0]:
                cand = (levels[lvl[a]], a, j)
        if cand is None:
            break
        _, a, j = cand
        used = used - levels[lvl[a]] + levels[j]
        lvl[a] = j
    return lvl


def allocate_v2_heap(curves, levels, total, weights, prev, hysteresis):
    """The PR 8 priority-heap water-filler. Returns ``(lvl, ops)`` where
    ``ops`` counts elementary work: one per candidate rung examined in a
    best-jump scan, plus ``ceil(log2(len + 1))`` per heap push/pop (the
    comparison cost a binary heap pays). The Rust heap orders jumps by
    (gain desc, app asc, rung asc); ``heapq`` is a min-heap, so entries
    are ``(-gain, app, rung)`` — tuple order then matches exactly
    (gains are positive finite, where IEEE total order and the normal
    float order agree)."""
    napps = len(curves)
    lvl = [0] * napps
    used = napps * levels[0]
    assert used <= total, "floor rung oversubscribes the cluster"
    assert all(a < b for a, b in zip(levels, levels[1:])), \
        "heap path requires a strictly increasing ladder"
    ops = 0

    def adj(a, l):
        return _adj(curves, weights, prev, hysteresis, a, l)

    def heap_cost(heap):
        return max(1, math.ceil(math.log2(len(heap) + 1)))

    def best_jump(a):
        nonlocal ops
        best = None  # (gain, rung)
        for j in range(lvl[a] + 1, len(levels)):
            ops += 1
            if used - levels[lvl[a]] + levels[j] > total:
                continue
            du = adj(a, j) - adj(a, lvl[a])
            if du <= 1e-12:
                continue
            g = du / (levels[j] - levels[lvl[a]])
            if best is None or g > best[0]:
                best = (g, j)
        if best is None:
            return None
        return (-best[0], a, best[1])

    heap = []
    for a in range(napps):
        e = best_jump(a)
        if e is not None:
            heap.append(e)
    heapq.heapify(heap)
    ops += len(heap)  # heapify is linear
    while heap:
        ops += heap_cost(heap)
        neg_gain, a, rung = heapq.heappop(heap)
        if used - levels[lvl[a]] + levels[rung] > total:
            e = best_jump(a)
            if e is not None:
                ops += heap_cost(heap)
                heapq.heappush(heap, e)
            continue
        used = used - levels[lvl[a]] + levels[rung]
        lvl[a] = rung
        e = best_jump(a)
        if e is not None:
            ops += heap_cost(heap)
            heapq.heappush(heap, e)

    even = total // napps

    def eligible(a):
        j = lvl[a] + 1
        return j < len(levels) and levels[j] <= even

    heap = [(levels[lvl[a]], a) for a in range(napps) if eligible(a)]
    heapq.heapify(heap)
    ops += len(heap)
    while heap:
        ops += heap_cost(heap)
        _, a = heapq.heappop(heap)
        j = lvl[a] + 1
        if used - levels[lvl[a]] + levels[j] > total:
            continue  # used only grows: never feasible again, drop for good
        used = used - levels[lvl[a]] + levels[j]
        lvl[a] = j
        if eligible(a):
            ops += heap_cost(heap)
            heapq.heappush(heap, (levels[lvl[a]], a))
    return lvl, ops


# ---------------------------------------------------------------------------
# instance generators
# ---------------------------------------------------------------------------

def random_instance(rng):
    """Mirror of the Rust regression test's generator: small random
    fleets with quantized (exact-tie) curves, flat tops, weight tiers,
    optional incumbents and hysteresis."""
    napps = 1 + rng.randrange(24)
    nlevels = 2 + rng.randrange(7)
    floor = 1 + rng.randrange(4)
    levels, cur = [], floor
    for _ in range(nlevels):
        levels.append(cur)
        cur += 1 + rng.randrange(9)
    hi = napps * levels[-1]
    lo = napps * levels[0]
    total = lo + rng.randrange(hi - lo + 1)
    curves = []
    for _ in range(napps):
        u = sorted(rng.random() for _ in range(nlevels))
        if rng.random() < 0.5:  # quantize: manufacture exact ties
            u = [math.floor(x * 8.0) / 8.0 for x in u]
        if rng.random() < 0.3 and nlevels >= 2:  # flat top
            u[nlevels - 1] = u[nlevels - 2]
        curves.append(u)
    weights = [1.0 if rng.random() < 0.5 else float(1 + rng.randrange(4))
               for _ in range(napps)]
    prev = ([rng.randrange(nlevels) for _ in range(napps)]
            if rng.random() < 0.5 else None)
    hysteresis = 0.0 if rng.random() < 0.5 else rng.random() * 0.2
    return curves, levels, total, weights, prev, hysteresis


def bench_instance(n, seed):
    """The ``allocate_v2/{n}_tenants`` bench shape: pool of 3 cores per
    tenant, floor-1 8-rung ladder, sorted quantized curves, three
    weight tiers, incumbent rungs, hysteresis 0.05."""
    rng = random.Random(seed)
    pool = 3 * n
    levels = core_levels(pool, n, 1, 8, 3.0)
    curves = [sorted(math.floor(rng.random() * 64.0) / 64.0
                     for _ in range(len(levels)))
              for _ in range(n)]
    weights = [1.0 + (i % 3) for i in range(n)]
    prev = [i % len(levels) for i in range(n)]
    return curves, levels, pool, weights, prev, 0.05


# ---------------------------------------------------------------------------
# tests
# ---------------------------------------------------------------------------

def test_heap_matches_scan_on_random_instances():
    rng = random.Random(0x8EA9)
    for case in range(250):
        curves, levels, total, weights, prev, hyst = random_instance(rng)
        want = allocate_v2_scan(curves, levels, total, weights, prev, hyst)
        got, _ = allocate_v2_heap(curves, levels, total, weights, prev, hyst)
        assert got == want, (
            f"case {case}: heap {got} != scan {want} "
            f"(levels={levels} total={total} hyst={hyst})"
        )


def test_tie_break_order_is_exact():
    # Two apps with IDENTICAL curves: every jump gain ties exactly, so
    # the result is decided purely by (app asc, rung asc) — app 0 must
    # climb first, and within an app the LOWEST rung achieving the max
    # gain must win (strict-> first-wins over an ascending rung scan).
    levels = [1, 2, 4, 8]
    curve = [0.0, 0.5, 0.75, 1.0]
    curves = [list(curve), list(curve)]
    weights = [1.0, 1.0]
    for total in range(2, 17):
        want = allocate_v2_scan(curves, levels, total, weights, None, 0.0)
        got, _ = allocate_v2_heap(curves, levels, total, weights, None, 0.0)
        assert got == want, (total, got, want)
    # with budget for exactly one jump, app 0 takes it
    got, _ = allocate_v2_heap(curves, levels, 3, weights, None, 0.0)
    assert got == [1, 0], got


def test_invariants_on_bench_shape():
    curves, levels, pool, weights, prev, hyst = bench_instance(2000, 7)
    got, _ = allocate_v2_heap(curves, levels, pool, weights, prev, hyst)
    used = sum(levels[l] for l in got)
    assert used <= pool, (used, pool)
    assert all(0 <= l < len(levels) for l in got)
    want = allocate_v2_scan(curves, levels, pool, weights, prev, hyst)
    assert got == want


def test_per_tenant_cost_sublinear_1k_to_100k():
    small_n, big_n = 1_000, 100_000
    _, ops_small = allocate_v2_heap(*bench_instance(small_n, 11))
    _, ops_big = allocate_v2_heap(*bench_instance(big_n, 11))
    per_small = ops_small / small_n
    per_big = ops_big / big_n
    ratio = per_big / per_small
    assert ratio <= 1.5, (
        f"per-tenant epoch cost grew {ratio:.3f}x from {small_n} to "
        f"{big_n} tenants ({per_small:.1f} -> {per_big:.1f} ops/tenant); "
        "the heap water-fill must stay sub-linear (<= 1.5x)"
    )
