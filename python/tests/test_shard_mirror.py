"""Behavioral mirror of the sharded reallocation epoch (rust:
``fleet/shard.rs`` + ``scheduler/coordinator.rs``): tenants are
partitioned contiguously across S shards, each shard runs the existing
admission/water-fill machinery over its own tenant slice, and a global
coordinator drives the cross-shard sequencing with a token-passing
protocol that is EXACT — not approximate — by construction:

* every global tie-break in the single-pool algorithms ends on "index
  ascending"; a contiguous partition turns global index order into
  (shard asc, local index asc), so any globally-ordered scan is a
  concatenation of per-shard segments;
* the admission scan is segmented by rank bucket (weight desc, class,
  streak): shards report their bucket keys + member counts + demand
  totals (the per-priority-tier demand histogram of the shard Summary),
  the coordinator walks buckets in rank order and passes the running
  ``used`` token through the owning shards — per-tenant reservations
  never leave the shard;
* both water-fill phases keep one priority heap per shard; the
  coordinator repeatedly hands the fill token to the shard holding the
  globally-best top along with a ``boundary`` (the best rival top), and
  the shard drains its heap while its top still beats the boundary — a
  lazy heap partitioned across shards, stale tops and all;
* the reservation top-up is segmented by (weight desc, shard asc) with
  the same ``used`` token; report stats (float utility sum, FNV quota
  fingerprint) are folded in shard-major order, which is exactly the
  single-pool accumulation order.

``test_sharded_run_equals_single_pool`` is the proof obligation behind
the Rust ``scale`` shard tests and the CI ``shard-smoke`` job
(byte-identical ``alloc-epoch`` reports for S in {1,2,4}). The
fleet-holdback tests underwrite the PR 9 finding fix (``reserve_top_up``
at the full pool is provably a no-op; the 2% holdback makes it live)
adopted by ``fleet/mod.rs`` and ``scheduler/live.rs``.

Pure stdlib — no jax/hypothesis required.
"""

import heapq
import random

import test_heap_waterfill_mirror as wf
import test_scale_epoch_mirror as se


def shard_bounds(n, shards):
    """Contiguous balanced partition: shard s owns [s*n//S, (s+1)*n//S)."""
    return [(s * n // shards, (s + 1) * n // shards) for s in range(shards)]


# ---------------------------------------------------------------------------
# admission: segmented scan over rank buckets
# ---------------------------------------------------------------------------

class TenantShard:
    """One shard's admission state + per-epoch data (mirror of the Rust
    ``TenantShard`` server in scheduler/coordinator.rs)."""

    def __init__(self, sid, lo, hi, bound, hysteresis):
        self.sid = sid
        self.lo = lo
        self.hi = hi
        n = hi - lo
        self.bound = max(bound, 1)
        self.hysteresis = hysteresis
        self.admitted = [True] * n
        self.parked_streak = [0] * n
        self.admitted_streak = [0] * n
        self.decided = False
        self.prev_rung = [0] * n
        self.prev_admitted = [False] * n

    def load_epoch(self, curves, demands, weights):
        self.curves = curves
        self.demands = demands
        self.weights = weights

    def admission_summary(self):
        """Bucket local tenants by rank key (-weight, class, streak) and
        report (count, demand total) per bucket — the compact Summary."""
        n = self.hi - self.lo
        overdue = [
            self.decided and not self.admitted[k]
            and self.parked_streak[k] + 1 >= self.bound
            for k in range(n)
        ]
        buckets = {}
        for k in range(n):
            c = 0 if overdue[k] else (1 if self.admitted[k] else 2)
            streak = self.admitted_streak[k] if c == 1 else -self.parked_streak[k]
            buckets.setdefault((-self.weights[k], c, streak), []).append(k)
        self._buckets = buckets
        self._next = [False] * n
        self._fresh = {}
        return {key: (len(ks), sum(self.demands[k] for k in ks))
                for key, ks in buckets.items()}

    def admit_segment(self, key, used, total):
        """Scan this shard's members of one rank bucket in local index
        order, applying the exact packing rule with the global token."""
        admitted = 0
        fresh = []
        for k in self._buckets.get(key, ()):
            r = min(max(self.demands[k], 1), max(total, 1))
            slack = self.hysteresis if (self.decided and key[1] == 2) else 0
            if used + r + slack <= total:
                self._next[k] = True
                used += r
                admitted += 1
            elif self.admitted[k] or not self.decided:
                fresh.append(k)
        self._fresh[key] = fresh
        return used, admitted

    def force_first(self, key):
        """Coordinator fallback when nothing fit: admit global order[0]."""
        k0 = self._buckets[key][0]
        self._next[k0] = True
        f = self._fresh.get(key)
        if f and f[0] == k0:
            f.pop(0)

    def fresh_count(self, key):
        return len(self._fresh.get(key, ()))

    def assign_fresh(self, key, offset, m, gpe):
        """Staggered parked_streak over the global fresh cohort; this
        shard's members of the bucket occupy [offset, offset+count)."""
        for j, k in enumerate(self._fresh.get(key, ())):
            self.parked_streak[k] = (m - 1 - (offset + j)) // gpe
            self.admitted_streak[k] = 0

    def finalize_admission(self):
        n = self.hi - self.lo
        fresh_set = set()
        for ks in self._fresh.values():
            fresh_set.update(ks)
        for k in range(n):
            if self._next[k]:
                self.parked_streak[k] = 0
                self.admitted_streak[k] += 1
            elif k not in fresh_set:
                self.parked_streak[k] += 1
                self.admitted_streak[k] = 0
        self.admitted = list(self._next)
        self.decided = True
        return sum(self.admitted)


def decide_sharded(shards, total):
    """Coordinator driver for one admission decision. Returns the global
    admitted count; per-tenant flags stay on the shards."""
    summaries = [s.admission_summary() for s in shards]
    keys = sorted(set().union(*map(set, summaries)))
    used = 0
    n_admitted = 0
    for key in keys:
        for s in shards:
            if s._buckets.get(key):
                used, adm = s.admit_segment(key, used, total)
                n_admitted += adm
    if n_admitted == 0:
        for key in keys:
            owner = next((s for s in shards if s._buckets.get(key)), None)
            if owner is not None:
                owner.force_first(key)
                n_admitted = 1
                break
    m = sum(s.fresh_count(key) for key in keys for s in shards)
    bound = shards[0].bound
    gpe = max(-(-m // bound), 1)
    off = 0
    for key in keys:
        for s in shards:
            c = s.fresh_count(key)
            if c:
                s.assign_fresh(key, off, m, gpe)
                off += c
    total_admitted = 0
    for s in shards:
        total_admitted += s.finalize_admission()
    assert total_admitted == n_admitted
    return n_admitted


# ---------------------------------------------------------------------------
# water-fill: one lazy heap per shard, token + boundary protocol
# ---------------------------------------------------------------------------

class FillShard:
    """One shard's slice of the admitted sub-instance, with local heaps
    for both allocate_v2 phases and the segmented top-up."""

    def __init__(self, sid, curves, weights, prev, levels, hysteresis):
        self.sid = sid
        self.curves = curves
        self.weights = weights
        self.prev = prev
        self.levels = levels
        self.hysteresis = hysteresis
        self.lvl = [0] * len(curves)

    def _adj(self, a, l):
        u = self.weights[a] * self.curves[a][l]
        if self.hysteresis > 0.0 and self.prev is not None and self.prev[a] == l:
            u += self.hysteresis
        return u

    def _best_jump(self, a, used, total):
        best = None
        cur = self.levels[self.lvl[a]]
        for j in range(self.lvl[a] + 1, len(self.levels)):
            if used - cur + self.levels[j] > total:
                continue
            du = self._adj(a, j) - self._adj(a, self.lvl[a])
            if du <= 1e-12:
                continue
            g = du / (self.levels[j] - cur)
            if best is None or g > best[0]:
                best = (g, j)
        return (-best[0], a, best[1]) if best else None

    # -- phase 1: marginal-utility fill ---------------------------------
    def heap_init(self, used, total):
        self.heap = []
        for a in range(len(self.curves)):
            e = self._best_jump(a, used, total)
            if e is not None:
                self.heap.append(e)
        heapq.heapify(self.heap)
        return self.top()

    def top(self):
        return -self.heap[0][0] if self.heap else None

    def fill(self, used, total, boundary):
        """Drain the local heap while its top beats the best rival top
        (gain desc, shard asc) — the pop sequence this shard produces is
        exactly the run of global pops the single heap would take."""
        while self.heap:
            g = -self.heap[0][0]
            if boundary is not None and not (
                g > boundary[0] or (g == boundary[0] and self.sid < boundary[1])
            ):
                break
            _, a, rung = heapq.heappop(self.heap)
            cur = self.levels[self.lvl[a]]
            if used - cur + self.levels[rung] > total:
                e = self._best_jump(a, used, total)  # stale: recompute
                if e is not None:
                    heapq.heappush(self.heap, e)
                continue
            used = used - cur + self.levels[rung]
            self.lvl[a] = rung
            e = self._best_jump(a, used, total)
            if e is not None:
                heapq.heappush(self.heap, e)
        return used

    # -- phase 2: even-share raise --------------------------------------
    def raise_init(self, even):
        self.even = even
        self.heap2 = [(self.levels[self.lvl[a]], a)
                      for a in range(len(self.curves)) if self._eligible(a)]
        heapq.heapify(self.heap2)
        return self.top2()

    def _eligible(self, a):
        j = self.lvl[a] + 1
        return j < len(self.levels) and self.levels[j] <= self.even

    def top2(self):
        return self.heap2[0][0] if self.heap2 else None

    def raise_fill(self, used, total, boundary):
        while self.heap2:
            c = self.heap2[0][0]
            if boundary is not None and not (
                c < boundary[0] or (c == boundary[0] and self.sid < boundary[1])
            ):
                break
            _, a = heapq.heappop(self.heap2)
            j = self.lvl[a] + 1
            if used - self.levels[self.lvl[a]] + self.levels[j] > total:
                continue  # used only grows: drop for good (matches Rust)
            used = used - self.levels[self.lvl[a]] + self.levels[j]
            self.lvl[a] = j
            if self._eligible(a):
                heapq.heappush(self.heap2, (self.levels[self.lvl[a]], a))
        return used

    # -- reservation top-up ----------------------------------------------
    def top_up_segment(self, w, reservations, even, total, used):
        """This shard's members of one weight tier, local index order."""
        for a in range(len(self.curves)):
            if self.weights[a] != w:
                continue
            want = min(reservations[a], even)
            while (
                self.lvl[a] + 1 < len(self.levels)
                and self.levels[self.lvl[a]] < want
                and self.levels[self.lvl[a] + 1] <= want
                and used - self.levels[self.lvl[a]] + self.levels[self.lvl[a] + 1] <= total
            ):
                used += self.levels[self.lvl[a] + 1] - self.levels[self.lvl[a]]
                self.lvl[a] += 1
        return used


def run_fill(fshards, used, total):
    """Coordinator phase-1 driver: hand the token to the shard with the
    globally-best top, passing the best rival top as the boundary."""
    tops = [s.heap_init(used, total) for s in fshards]
    while True:
        sid = None
        for s in fshards:
            g = tops[s.sid]
            if g is not None and (sid is None or g > tops[sid]):
                sid = s.sid
        if sid is None:
            break
        boundary = None
        for s in fshards:
            g = tops[s.sid]
            if s.sid != sid and g is not None and (
                boundary is None or g > boundary[0]
            ):
                boundary = (g, s.sid)
        used = fshards[sid].fill(used, total, boundary)
        tops[sid] = fshards[sid].top()
    return used


def run_raise(fshards, used, total, even):
    """Coordinator phase-2 driver (min-token: cores asc, shard asc)."""
    tops = [s.raise_init(even) for s in fshards]
    while True:
        sid = None
        for s in fshards:
            c = tops[s.sid]
            if c is not None and (sid is None or c < tops[sid]):
                sid = s.sid
        if sid is None:
            break
        boundary = None
        for s in fshards:
            c = tops[s.sid]
            if s.sid != sid and c is not None and (
                boundary is None or c < boundary[0]
            ):
                boundary = (c, s.sid)
        used = fshards[sid].raise_fill(used, total, boundary)
        tops[sid] = fshards[sid].top2()
    return used


def run_top_up(fshards, reservations_parts, even, total, used):
    """Segmented reserve_top_up: (weight desc, shard asc, local asc)."""
    tiers = sorted({w for s in fshards for w in s.weights}, reverse=True)
    for w in tiers:
        for s in fshards:
            used = s.top_up_segment(w, reservations_parts[s.sid], even, total, used)
    return used


def sharded_allocate(parts, levels, total, hysteresis):
    """Run phases 1+2 of allocate_v2 over pre-partitioned shard inputs.
    ``parts``: list of (curves, weights, prev) whose concatenation is the
    global sub-instance in index order. Returns per-shard rung lists."""
    fshards = [FillShard(s, c, w, p, levels, hysteresis)
               for s, (c, w, p) in enumerate(parts)]
    napps = sum(len(c) for c, _, _ in parts)
    used = napps * levels[0]
    assert used <= total, "floor rung oversubscribes the cluster"
    used = run_fill(fshards, used, total)
    run_raise(fshards, used, total, total // napps)
    return [f.lvl for f in fshards]


# ---------------------------------------------------------------------------
# the full sharded scale run (mirror of fleet/shard.rs run_sharded)
# ---------------------------------------------------------------------------

def run_epochs_sharded(tenants, shards, epochs=3, seed=42, rungs=8,
                       cores_per_tenant=3, demand_confidence=0):
    n = tenants
    pool = n * max(cores_per_tenant, 1)
    alloc_pool = pool - pool // 50
    levels = wf.core_levels(pool, n, 1, max(rungs, 2), 3.0)
    even = max(pool // n, 1)
    tshards = [TenantShard(s, lo, hi, 4, even)
               for s, (lo, hi) in enumerate(shard_bounds(n, shards))]
    out = []
    for e in range(epochs):
        for t in tshards:
            # shards generate their own tenants: curves never cross
            pairs = [se.synth_tenant(seed, e, g, levels, even, demand_confidence)
                     for g in range(t.lo, t.hi)]
            t.load_epoch(
                [c for c, _ in pairs], [d for _, d in pairs],
                [4.0 if g % 5 == 0 else 2.0 if g % 5 in (1, 2) else 1.0
                 for g in range(t.lo, t.hi)],
            )
        n_adm = decide_sharded(tshards, pool)
        fshards = []
        idx_parts = []
        res_parts = []
        for t in tshards:
            idx = [k for k in range(t.hi - t.lo) if t.admitted[k]]
            idx_parts.append(idx)
            res_parts.append([t.demands[k] for k in idx])
            fshards.append(FillShard(
                t.sid,
                [t.curves[k] for k in idx],
                [t.weights[k] for k in idx],
                [t.prev_rung[k] if t.prev_admitted[k] else 0 for k in idx],
                levels, 0.02,
            ))
        used = n_adm * levels[0]
        assert used <= alloc_pool, "floor rung oversubscribes the cluster"
        used = run_fill(fshards, used, alloc_pool)
        used = run_raise(fshards, used, alloc_pool, alloc_pool // n_adm)
        pre = [list(f.lvl) for f in fshards]
        run_top_up(fshards, res_parts, even, pool, used)
        # stats token: fold in shard-major order = global index order
        util = 0.0
        top_up = 0
        moved = 0
        quota_all = []
        n_admitted = 0
        for t, f, p, idx in zip(tshards, fshards, pre, idx_parts):
            quota = [0] * (t.hi - t.lo)
            for s_local, k in enumerate(idx):
                quota[k] = levels[f.lvl[s_local]]
                util += t.weights[k] * f.curves[s_local][f.lvl[s_local]]
                if t.prev_admitted[k] and f.lvl[s_local] != t.prev_rung[k]:
                    moved += 1
                t.prev_rung[k] = f.lvl[s_local]
            top_up += sum(levels[g] - levels[q] for g, q in zip(f.lvl, p))
            quota_all.extend(quota)
            n_admitted += len(idx)
            t.prev_admitted = list(t.admitted)
        out.append({
            "epoch": e, "admitted": n_admitted, "parked": n - n_admitted,
            "used_cores": sum(quota_all), "top_up_cores": top_up,
            "moved_tenants": moved, "weighted_utility": util,
            "quota_fingerprint": se.fnv_quota(quota_all),
        })
    return {"tenants": n, "pool": pool, "levels": levels, "epochs": out}


# ---------------------------------------------------------------------------
# tests — shard protocol exactness
# ---------------------------------------------------------------------------

def test_sharded_run_equals_single_pool():
    """The headline bar: the sharded run reproduces the single-pool
    report exactly — same admission, same budgets, same float utility,
    same fingerprint — for every shard count, in both demand modes.
    Underwrites the Rust ``scale`` shard tests and CI shard-smoke."""
    for n, dc in ((400, 0), (400, 2), (600, 0)):
        want = se.run_epochs(n, epochs=3, demand_confidence=dc)
        for s in (1, 2, 3, 4):
            got = run_epochs_sharded(n, s, epochs=3, demand_confidence=dc)
            assert got == want, (n, s, dc)


def test_sharded_waterfill_matches_heap_on_random_instances():
    """Phases 1+2 of the token protocol vs the single heap, on the PR 8
    random instance family, across shard counts."""
    rng = random.Random(0x51A2D)
    for case in range(120):
        curves, levels, total, weights, prev, hyst = wf.random_instance(rng)
        want, _ = wf.allocate_v2_heap(curves, levels, total, weights, prev, hyst)
        napps = len(curves)
        for s in (2, 3, 4):
            parts = []
            for lo, hi in shard_bounds(napps, s):
                parts.append((
                    curves[lo:hi], weights[lo:hi],
                    prev[lo:hi] if prev is not None else None,
                ))
            got = sharded_allocate(parts, levels, total, hyst)
            flat = [l for part in got for l in part]
            assert flat == want, (case, s, flat, want)


def test_sharded_top_up_matches_reserve_top_up():
    """The segmented (weight desc, shard asc) top-up vs the global scan."""
    rng = random.Random(0x701A)
    for _ in range(80):
        curves, levels, total, weights, prev, hyst = wf.random_instance(rng)
        napps = len(curves)
        reservations = [rng.randrange(1, levels[-1] + 2) for _ in range(napps)]
        even = max(total // napps, 1)
        want, _ = wf.allocate_v2_heap(curves, levels, total, weights, prev, hyst)
        full = total + total // 10 + 1  # headroom so the top-up has work
        se.reserve_top_up(want, levels, full, [True] * napps, reservations,
                          even, weights)
        s = 1 + rng.randrange(4)
        parts = []
        for lo, hi in shard_bounds(napps, s):
            parts.append((curves[lo:hi], weights[lo:hi],
                          prev[lo:hi] if prev is not None else None))
        got = sharded_allocate(parts, levels, total, hyst)
        fshards = [FillShard(i, c, w, p, levels, hyst)
                   for i, (c, w, p) in enumerate(parts)]
        for f, part in zip(fshards, got):
            f.lvl = part
        used = sum(levels[l] for part in got for l in part)
        res_parts = [reservations[lo:hi] for lo, hi in shard_bounds(napps, s)]
        run_top_up(fshards, res_parts, even, full, used)
        flat = [l for f in fshards for l in f.lvl]
        assert flat == want, (s, flat, want)


def test_sharded_admission_matches_epoch_admission():
    """Multi-epoch admission equivalence, including parking, overdue
    promotion, fresh-cohort staggering and the hysteresis slack."""
    rng = random.Random(0xAD31)
    for trial in range(20):
        n = 5 + rng.randrange(40)
        bound = 2 + rng.randrange(4)
        hyst = rng.randrange(3)
        total = max(n // 2, 1) * 2  # tight: forces parking churn
        weights = [float(1 + rng.randrange(4)) for _ in range(n)]
        ref = se.EpochAdmission(n, bound, hysteresis=hyst)
        s = 1 + rng.randrange(4)
        tshards = [TenantShard(i, lo, hi, bound, hyst)
                   for i, (lo, hi) in enumerate(shard_bounds(n, s))]
        for _epoch in range(6):
            demands = [1 + rng.randrange(4) for _ in range(n)]
            want = ref.decide(total, weights, demands)
            for t in tshards:
                t.load_epoch([None] * (t.hi - t.lo), demands[t.lo:t.hi],
                             weights[t.lo:t.hi])
            decide_sharded(tshards, total)
            got = [a for t in tshards for a in t.admitted]
            assert got == want, (trial, _epoch, s, got, want)
            assert [v for t in tshards for v in t.parked_streak] == ref.parked_streak
            assert [v for t in tshards for v in t.admitted_streak] == ref.admitted_streak


def test_sharded_admission_force_first():
    """When nothing fits, the sharded scan must force-admit the same
    global order[0] the single scan picks."""
    n, bound = 7, 3
    weights = [1.0, 4.0, 2.0, 4.0, 1.0, 2.0, 4.0]
    demands = [50] * n
    total = 10  # every reservation clamps to 10; used+10 <= 10 admits one
    ref = se.EpochAdmission(n, bound)
    want = ref.decide(total, weights, demands)
    for s in (1, 2, 3):
        tshards = [TenantShard(i, lo, hi, bound, 0)
                   for i, (lo, hi) in enumerate(shard_bounds(n, s))]
        for t in tshards:
            t.load_epoch([None] * (t.hi - t.lo), demands[t.lo:t.hi],
                         weights[t.lo:t.hi])
        decide_sharded(tshards, total)
        got = [a for t in tshards for a in t.admitted]
        assert got == want, (s, got, want)
    # second epoch with total=0: the force-first fallback proper
    demands2 = [50] * n
    want2 = ref.decide(0, weights, demands2)
    tshards = [TenantShard(i, lo, hi, bound, 0)
               for i, (lo, hi) in enumerate(shard_bounds(n, 2))]
    for t in tshards:
        t.load_epoch([None] * (t.hi - t.lo), demands[t.lo:t.hi],
                     weights[t.lo:t.hi])
    decide_sharded(tshards, 10)
    for t in tshards:
        t.load_epoch([None] * (t.hi - t.lo), demands2[t.lo:t.hi],
                     weights[t.lo:t.hi])
    decide_sharded(tshards, 0)
    got2 = [a for t in tshards for a in t.admitted]
    assert got2 == want2 and sum(got2) == 1, (got2, want2)


def test_hand_built_two_shard_budgets():
    """Exact budgets on a hand-built 2-shard instance (the satellite
    acceptance case). Ladder [1,2,4], pool 10, no hysteresis. Floors use
    4 cores. Pop order by marginal gain: t0 1->2 (0.5/core, used 5),
    t2 1->4 (0.3/core, used 8), t0 2->4 (0.25/core, 8-2+4 = 10 fits,
    used 10). t1/t3 are flat and stay at floor; phase 2's raise for
    them (1 -> 2 cores <= even 2) is infeasible at used 10 and drops.
    Budgets: shard 0 (t0,t1) = 4+1 = 5, shard 1 (t2,t3) = 4+1 = 5 —
    and the shard-0/shard-1 split is decided by the cross-shard token
    hand-offs (t0, then t2, then t0 again)."""
    levels = [1, 2, 4]
    curves = [
        [0.0, 0.5, 1.0],   # t0: 0.5/core to rung 1, then 0.25/core
        [0.0, 0.0, 0.0],   # t1: flat
        [0.0, 0.1, 0.9],   # t2: best jump 0->2 at 0.3/core
        [0.0, 0.0, 0.0],   # t3: flat
    ]
    weights = [1.0, 1.0, 1.0, 1.0]
    parts = [(curves[0:2], weights[0:2], None),
             (curves[2:4], weights[2:4], None)]
    got = sharded_allocate(parts, levels, 10, 0.0)
    assert got[0] == [2, 0], got  # shard 0: t0 at 4 cores, t1 at floor
    assert got[1] == [2, 0], got  # shard 1: t2 at 4 cores, t3 at floor
    budgets = [sum(levels[l] for l in part) for part in got]
    assert budgets == [5, 5], budgets
    want, _ = wf.allocate_v2_heap(curves, levels, 10, weights, None, 0.0)
    assert [l for part in got for l in part] == want


# ---------------------------------------------------------------------------
# tests — the fleet fairness-holdback fix (PR 9 finding)
# ---------------------------------------------------------------------------

def _holdback(total, napps, floor):
    """Mirror of the fleet/live holdback: 2% of the pool, clamped so the
    admitted floors still fit (allocate_v2 asserts napps*floor <= total)."""
    return min(total // 50, max(total - napps * floor, 0))


def test_top_up_at_full_pool_is_noop():
    """The PR 9 finding: after allocate_v2 at the FULL pool, the top-up
    cannot move — phase 2's raise condition dominates the top-up's."""
    rng = random.Random(0xF1EE7)
    for _ in range(150):
        curves, levels, total, weights, prev, hyst = wf.random_instance(rng)
        napps = len(curves)
        got, _ = wf.allocate_v2_heap(curves, levels, total, weights, prev, hyst)
        before = list(got)
        reservations = [rng.randrange(1, levels[-1] + 2) for _ in range(napps)]
        se.reserve_top_up(got, levels, total, [True] * napps, reservations,
                          max(total // napps, 1), weights)
        assert got == before, "top-up moved at the full pool"


def test_holdback_makes_top_up_live():
    """With the 2% holdback the optimizer leaves headroom the top-up can
    spend on reserved-but-underserved tenants, and the floors always
    survive the clamp. Fleet-shaped instances (the fleet/mod.rs and
    scheduler/live.rs epoch paths adopt exactly this split)."""
    fired = 0
    for n in (40, 50, 64):
        pool = 3 * n
        levels = wf.core_levels(pool, n, 1, 8, 3.0)
        rng = random.Random(n)
        curves = [sorted(rng.random() for _ in range(len(levels)))
                  for _ in range(n)]
        weights = [1.0 + (i % 3) for i in range(n)]
        even = max(pool // n, 1)
        reservations = [max(even, levels[-1] // 2) for _ in range(n)]
        hold = _holdback(pool, n, levels[0])
        assert n * levels[0] <= pool - hold, "holdback broke the floor"
        got, _ = wf.allocate_v2_heap(curves, levels, pool - hold, weights,
                                     None, 0.0)
        before = list(got)
        se.reserve_top_up(got, levels, pool, [True] * n, reservations,
                          even, weights)
        assert all(g >= b for g, b in zip(got, before))
        assert sum(levels[l] for l in got) <= pool
        fired += sum(levels[g] - levels[b] for g, b in zip(got, before))
    assert fired > 0, "holdback never gave the top-up any work"


def test_holdback_floor_guard_tight_pool():
    """When the pool barely covers the floors, the guard zeroes the
    holdback instead of tripping allocate_v2's floor assert."""
    levels = [2, 3, 5]
    napps = 10
    total = napps * levels[0] + 1  # 21: 2% would steal the last core...
    hold = _holdback(total, napps, levels[0])
    assert hold == 0  # total//50 == 0 here; now force the clamp branch:
    total = 60
    hold = _holdback(total, 29, 2)  # floors need 58 of 60; 2% = 1 fits
    assert hold == 1 and 29 * 2 <= total - hold
    hold = _holdback(total, 30, 2)  # floors need all 60: clamp to 0
    assert hold == 0
