"""AOT lowering smoke tests: every bundle lowers to parseable HLO text and
the manifest inventory is consistent. Also guards against ops the pinned
xla_extension 0.5.1 runtime cannot execute (gather-with-fill)."""

import json
import os

import jax
import pytest

from compile import aot, model as M
from compile.spec import all_specs


@pytest.mark.parametrize("spec", all_specs(), ids=lambda s: s.name)
@pytest.mark.parametrize("variant", M.VARIANTS)
@pytest.mark.parametrize("op", ["predict", "update", "solve"])
def test_lowering_produces_hlo_text(spec, variant, op):
    b = M.build(spec, variant)
    lowered = jax.jit(b.fn(op)).lower(*b.example_args(op))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # static-shape sanity: candidate batch shows up in predict/solve
    if op in ("predict", "solve"):
        assert f"f32[{spec.candidate_pad}," in text
    # the pinned PJRT runtime (xla_extension 0.5.1) mis-executes the
    # NaN-fill gather jnp.take lowers to — artifacts must be gather-free
    assert " gather(" not in text, "gather op leaked into an artifact"
    # elided constants parse back as zeros on the Rust side
    assert "constant({...})" not in text, "large constant elided in HLO text"


def test_full_emit_and_manifest(tmp_path):
    out = str(tmp_path)
    manifest = {"artifacts": {}, "apps": {}}
    for spec in all_specs():
        b = M.build(spec, "structured")
        manifest["artifacts"].update(aot.lower_bundle(b, out))
    files = os.listdir(out)
    assert len([f for f in files if f.endswith(".hlo.txt")]) == 6
    for name, entry in manifest["artifacts"].items():
        assert os.path.exists(os.path.join(out, entry["file"]))
        assert entry["op"] in name
        assert all(s["dtype"] == "float32" for s in entry["inputs"])


def test_repo_manifest_if_built():
    """If `make artifacts` has run, validate the checked-out inventory."""
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        manifest = json.load(f)
    assert len(manifest["artifacts"]) == 12
    ms = manifest["apps"]["motion_sift"]
    assert ms["structured_features"] == 30
    assert ms["unstructured_features"] == 56
    for name, entry in manifest["artifacts"].items():
        apath = os.path.join(os.path.dirname(path), entry["file"])
        assert os.path.exists(apath), name
        with open(apath) as f:
            assert f.read(9) == "HloModule"
