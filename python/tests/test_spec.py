"""Spec loading, normalization, and monomial enumeration tests.

Includes the golden monomial-order vectors that the Rust implementation
(``learner::features``) is cross-checked against.
"""

import math

import pytest

from compile.spec import (
    all_specs,
    load_spec,
    monomial_count,
    monomial_index_arrays,
    monomials,
)


class TestMonomials:
    def test_counts_match_binomial(self):
        for v in range(1, 7):
            for d in range(1, 5):
                assert len(monomials(v, d)) == monomial_count(v, d)

    def test_paper_counts(self):
        # Sec 4.3: "it takes 30 and 56 features to describe the structured
        # and unstructured spaces" for MotionSIFT, cubic.
        assert monomial_count(5, 3) == 56
        assert monomial_count(2, 3) == 10
        assert monomial_count(3, 3) == 20

    def test_golden_order_2v2d(self):
        assert monomials(2, 2) == [(), (0,), (1,), (0, 0), (0, 1), (1, 1)]

    def test_golden_order_3v3d_prefix(self):
        m = monomials(3, 3)
        assert m[:10] == [
            (), (0,), (1,), (2,),
            (0, 0), (0, 1), (0, 2), (1, 1), (1, 2), (2, 2),
        ]
        assert m[10] == (0, 0, 0)
        assert m[-1] == (2, 2, 2)

    def test_graded_order(self):
        m = monomials(5, 3)
        degrees = [len(t) for t in m]
        assert degrees == sorted(degrees)

    def test_all_unique(self):
        m = monomials(5, 3)
        assert len(set(m)) == len(m)

    def test_nondecreasing_within_monomial(self):
        for t in monomials(5, 3):
            assert list(t) == sorted(t)


class TestIndexArrays:
    def test_padding_points_at_one_slot(self):
        i0, i1, i2, valid = monomial_index_arrays([0, 1], 5, 3, 16)
        n_real = monomial_count(2, 3)
        assert sum(valid) == n_real
        # constant monomial: all factors are the 1.0 slot (index 5)
        assert i0[0] == i1[0] == i2[0] == 5
        # padded tail also all-ones
        assert all(i0[j] == 5 for j in range(n_real, 16))

    def test_subset_mapping(self):
        i0, i1, i2, valid = monomial_index_arrays([2, 4], 5, 3, 16)
        # first-degree monomials are the subset vars themselves
        assert (i0[1], i1[1], i2[1]) == (2, 5, 5)
        assert (i0[2], i1[2], i2[2]) == (4, 5, 5)

    def test_overflow_raises(self):
        with pytest.raises(ValueError):
            monomial_index_arrays([0, 1, 2, 3, 4], 5, 3, 8)


class TestSpecs:
    def test_both_specs_load(self):
        names = [s.name for s in all_specs()]
        assert names == ["pose", "motion_sift"]

    def test_table1_pose_knobs(self):
        """Paper Table 1, row by row."""
        s = load_spec("pose")
        assert [p.symbol for p in s.params] == ["K1", "K2", "K3", "K4", "K5"]
        k1, k2, k3, k4, k5 = s.params
        assert (k1.kind, k1.min, k1.max, k1.default) == ("continuous", 1, 10, 1)
        assert (k2.kind, k2.min, k2.max) == ("continuous", 1, 2**31)
        assert k2.default == 2**31
        assert (k3.kind, k3.min, k3.max, k3.default) == ("discrete", 1, 96, 1)
        assert (k4.kind, k4.min, k4.max, k4.default) == ("discrete", 1, 10, 1)
        assert (k5.kind, k5.min, k5.max, k5.default) == ("discrete", 1, 10, 1)

    def test_table2_motion_sift_knobs(self):
        """Paper Table 2, row by row."""
        s = load_spec("motion_sift")
        k1, k2, k3, k4, k5 = s.params
        for k in (k1, k2):
            assert (k.kind, k.min, k.max, k.default) == ("continuous", 1, 10, 1)
        assert (k3.kind, k3.min, k3.max, k3.default) == ("discrete", 0, 1, 0)
        for k in (k4, k5):
            assert (k.kind, k.min, k.max, k.default) == ("discrete", 1, 96, 1)

    def test_motion_sift_structured_features_30(self):
        s = load_spec("motion_sift")
        assert s.structured_feature_count() == 30
        assert s.unstructured_feature_count() == 56

    def test_normalization_bounds(self):
        for s in all_specs():
            for p in s.params:
                assert p.normalize(p.min) == pytest.approx(0.0)
                assert p.normalize(p.max) == pytest.approx(1.0)
                mid = p.normalize((p.min + p.max) / 2)
                assert 0.0 <= mid <= 1.0

    def test_log_normalization(self):
        s = load_spec("pose")
        thr = s.params[1]
        assert thr.log
        assert thr.normalize(math.sqrt(thr.min * thr.max)) == pytest.approx(0.5)

    def test_graph_is_dag_and_connected(self):
        for s in all_specs():
            names = [st.name for st in s.stages]
            assert len(set(names)) == len(names)
            seen = set()
            for st in s.stages:  # stages listed in topological order
                for dep in st.deps:
                    assert dep in seen, f"{s.name}: {st.name} dep {dep}"
                seen.add(st.name)

    def test_motion_sift_has_two_branches(self):
        s = load_spec("motion_sift")
        assert s.branches == [0, 1]
        seq, bmat = s.combine_matrices()
        assert seq == [0.0, 0.0]
        assert bmat == [[1.0, 0.0], [0.0, 1.0]]

    def test_pose_is_a_chain(self):
        s = load_spec("pose")
        assert s.branches == []
        seq, bmat = s.combine_matrices()
        assert all(x == 1.0 for x in seq)

    def test_group_params_cover_all_tunables(self):
        # Every knob must be owned by at least one structured group,
        # otherwise the structured solver could not react to it.
        for s in all_specs():
            owned = set()
            for g in s.groups:
                owned.update(g.params)
            assert owned == set(range(s.num_vars))
