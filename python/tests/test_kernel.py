"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compiled artifacts: hypothesis
sweeps shapes (variable counts, group counts, degrees, block sizes) and
asserts allclose between the fused kernels and the reference semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# container images may lack hypothesis (only CI installs it) — skip
# cleanly instead of erroring at collection (see requirements-dev.txt)
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ogd as ogd_k
from compile.kernels import poly as poly_k
from compile.kernels import ref
from compile.spec import monomial_index_arrays, monomials

settings.register_profile("kernels", deadline=None, max_examples=8)
settings.load_profile("kernels")


def full_encoding(v, d, f):
    """Full-space gather encoding (mirrors model.full_space_encoding)."""
    monos = monomials(v, d)
    assert len(monos) <= f
    idx = np.full((d, f), v, dtype=np.int32)
    valid = np.zeros((f,), dtype=np.float32)
    for j, m in enumerate(monos):
        valid[j] = 1.0
        for dd, var in enumerate(m):
            idx[dd, j] = var
    return idx, valid, monos


def aug(rng, n, v):
    u = rng.random((n, v), dtype=np.float64).astype(np.float32)
    return np.concatenate([u, np.ones((n, 1), np.float32)], axis=1)


def manual_expand(u_row, monos, f):
    """Monomial expansion straight from the definition, no gathers."""
    phi = np.zeros(f, np.float32)
    for j, m in enumerate(monos):
        val = 1.0
        for var in m:
            val *= u_row[var]
        phi[j] = val
    return phi


class TestExpandAgainstDefinition:
    @given(v=st.integers(1, 6), d=st.integers(1, 3), seed=st.integers(0, 999))
    def test_ref_expand_matches_definition(self, v, d, seed):
        f = len(monomials(v, d)) + 3
        idx, valid, monos = full_encoding(v, d, f)
        rng = np.random.default_rng(seed)
        u = aug(rng, 1, v)
        got = np.asarray(ref.expand(jnp.asarray(u), jnp.asarray(idx),
                                    jnp.asarray(valid)))[0]
        want = manual_expand(u[0], monos, f)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @given(seed=st.integers(0, 999))
    def test_subset_encoding_matches_definition(self, seed):
        rng = np.random.default_rng(seed)
        subset = sorted(rng.choice(5, size=3, replace=False).tolist())
        i0, i1, i2, valid = monomial_index_arrays(subset, 5, 3, 32)
        idx = np.asarray([i0, i1, i2], np.int32)
        valid = np.asarray(valid, np.float32)
        u = aug(rng, 1, 5)
        got = np.asarray(ref.expand(jnp.asarray(u), jnp.asarray(idx),
                                    jnp.asarray(valid)))[0]
        # definition over the subset variables
        monos = monomials(3, 3)
        want = np.zeros(32, np.float32)
        for j, m in enumerate(monos):
            val = 1.0
            for lv in m:
                val *= u[0][subset[lv]]
            want[j] = val
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_selection_matrices_equal_gather(self):
        """The gather-free one-hot encoding (what the artifacts use — see
        poly.py) reproduces the gather expansion exactly."""
        rng = np.random.default_rng(0)
        for v in (2, 5):
            f = len(monomials(v, 3)) + 5
            idx, valid, monos = full_encoding(v, 3, f)
            sel = poly_k.selection_matrices(idx, v + 1, valid)
            u = aug(rng, 4, v)
            got = np.asarray(poly_k.expand_block(jnp.asarray(u), jnp.asarray(sel)))
            want = np.stack([manual_expand(u[i], monos, f) for i in range(4)])
            np.testing.assert_allclose(got, want, rtol=1e-6)


class TestPolyPredictKernel:
    @given(
        v=st.integers(2, 6),
        g=st.integers(1, 5),
        seed=st.integers(0, 10_000),
        block_n=st.sampled_from([8, 16, 32]),
    )
    def test_matches_ref(self, v, g, seed, block_n):
        d = 3
        f = len(monomials(v, d)) + 8  # pad past the real monomial count
        idx, valid, _ = full_encoding(v, d, f)
        rng = np.random.default_rng(seed)
        n = block_n * int(rng.integers(1, 4))
        u = aug(rng, n, v)
        w = (rng.standard_normal((g, f)).astype(np.float32)) * valid
        got = np.asarray(poly_k.poly_predict(
            jnp.asarray(u), jnp.asarray(w), idx=idx, valid=valid,
            block_n=block_n))
        idx_g = np.stack([idx] * g)
        valid_g = np.stack([valid] * g)
        want = np.asarray(ref.predict_groups(
            jnp.asarray(u), jnp.asarray(w), jnp.asarray(idx_g),
            jnp.asarray(valid_g)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_ragged_batch(self):
        idx, valid, _ = full_encoding(3, 3, 32)
        u = np.ones((33, 4), np.float32)
        w = np.ones((2, 32), np.float32)
        with pytest.raises(ValueError):
            poly_k.poly_predict(jnp.asarray(u), jnp.asarray(w),
                                idx=idx, valid=valid, block_n=32)

    def test_zero_weights_zero_output(self):
        idx, valid, _ = full_encoding(5, 3, 64)
        u = aug(np.random.default_rng(0), 32, 5)
        w = np.zeros((3, 64), np.float32)
        out = np.asarray(poly_k.poly_predict(
            jnp.asarray(u), jnp.asarray(w), idx=idx, valid=valid))
        assert np.all(out == 0.0)


class TestOgdUpdateKernel:
    @given(
        v=st.integers(2, 6),
        g=st.integers(1, 5),
        seed=st.integers(0, 10_000),
        eta=st.floats(1e-4, 0.5),
    )
    def test_matches_ref(self, v, g, seed, eta):
        d = 3
        f = len(monomials(v, d)) + 8
        idx, valid, _ = full_encoding(v, d, f)
        rng = np.random.default_rng(seed)
        # random per-group support sub-masks of valid
        support = np.stack([
            valid * (rng.random(f) < 0.8).astype(np.float32)
            for _ in range(g)
        ])
        u = aug(rng, 1, v)[0]
        w = rng.standard_normal((g, f)).astype(np.float32) * support
        y = (rng.random(g) * 50).astype(np.float32)
        got = np.asarray(ogd_k.ogd_update(
            jnp.asarray(w), jnp.asarray(u), jnp.asarray(y),
            jnp.asarray(np.float32(eta)), idx=idx, support=support,
            gamma=0.01, eps_ins=0.05))
        idx_g = np.stack([idx] * g)
        want = np.asarray(ref.ogd_update(
            jnp.asarray(w), jnp.asarray(u), jnp.asarray(y),
            jnp.asarray(idx_g), jnp.asarray(support),
            np.float32(eta), 0.01, 0.05))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_stays_in_subspace(self):
        """The projection P(.) of Eq. 6: weights never leave the support."""
        d, f, g, v = 3, 64, 3, 5
        idx, valid, _ = full_encoding(v, d, f)
        rng = np.random.default_rng(7)
        support = np.stack([
            valid * (rng.random(f) < 0.5).astype(np.float32) for _ in range(g)
        ])
        w = np.zeros((g, f), np.float32)
        for t in range(20):
            u = aug(rng, 1, v)[0]
            y = (rng.random(g) * 100).astype(np.float32)
            w = np.asarray(ogd_k.ogd_update(
                jnp.asarray(w), jnp.asarray(u), jnp.asarray(y),
                jnp.asarray(np.float32(0.1)), idx=idx, support=support))
        assert np.all(w[support == 0.0] == 0.0)

    def test_no_update_inside_insensitive_zone(self):
        """|err| <= eps and w = 0 -> step and shrink are exactly zero."""
        d, f, g, v = 3, 64, 2, 4
        idx, valid, _ = full_encoding(v, d, f)
        support = np.stack([valid] * g)
        w = np.zeros((g, f), np.float32)
        u = np.concatenate([np.full(v, 0.5, np.float32), [1.0]]).astype(np.float32)
        y = np.zeros(g, np.float32)  # pred = 0, err = 0 -> inside zone
        w2 = np.asarray(ogd_k.ogd_update(
            jnp.asarray(w), jnp.asarray(u), jnp.asarray(y),
            jnp.asarray(np.float32(0.1)), idx=idx, support=support))
        np.testing.assert_array_equal(w2, w)

    def test_converges_on_fixed_target(self):
        """The PA-clipped step fits a repeated sample in a few updates."""
        d, f, g, v = 3, 64, 1, 5
        idx, valid, _ = full_encoding(v, d, f)
        support = valid[None, :]
        rng = np.random.default_rng(3)
        u = aug(rng, 1, v)[0]
        y = np.asarray([4.2], np.float32)
        w = np.zeros((g, f), np.float32)
        for t in range(1, 25):
            w = np.asarray(ogd_k.ogd_update(
                jnp.asarray(w), jnp.asarray(u), jnp.asarray(y),
                jnp.asarray(np.float32(1.0 / np.sqrt(t))), idx=idx,
                support=support))
        phi = manual_expand(u, monomials(v, d), f)
        assert abs(float(phi @ w[0]) - 4.2) < 0.1
