"""Behavioral mirror of the alloc-epoch synthetic scale run (rust:
``fleet/scale.rs`` ``run``), post PR 9 fold: demand reservations pass
through the confidence gate (``demand_cores_confident``) when
``demand_confidence > 0``, and every epoch finishes with a
``reserve_top_up`` pass spending idle cores on under-served admitted
tenants.

The water-filler runs over ``pool - pool // 50``: a 2% fairness reserve
held back from the utility optimizer and spent by ``reserve_top_up``
(against the full pool). Without the holdback the top-up is provably a
no-op after ``allocate_v2`` — the filler's even-share phase raise
condition (next rung <= pool // admitted, same feasibility check)
strictly dominates the top-up's (next rung <= min(reservation, even)),
so phase 2 reaches a fixed point the top-up cannot improve.

The container has no Rust toolchain, so the Rust-side test assertions
("some epoch tops up", "the confidence gate changes the report",
"byte-identity still holds") are validated here against a faithful
reimplementation: xoshiro256** + SplitMix64 (``util/rng.rs``), the
synthetic tenant curves, ``EpochAdmission::decide``, the heap
water-filler (imported from the PR 8 mirror), and ``reserve_top_up``.
Anything asserted by ``rust/src/fleet/scale.rs`` tests about report
*values* is first proven here on the same seeds and tenant counts.

Pure stdlib — no jax/hypothesis required.
"""

import math

import test_heap_waterfill_mirror as wf

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15
OBS_SALT = 0x0B5E_C04E_7A11_E57A


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Rng:
    """Mirror of ``util/rng.rs``: xoshiro256** seeded via SplitMix64."""

    def __init__(self, seed):
        sm = seed & MASK
        s = []
        for _ in range(4):
            sm = (sm + GOLDEN) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            z ^= z >> 31
            s.append(z)
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & MASK, 7) * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        # (x >> 11) < 2^53, so the int -> float conversion is exact
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        return (self.next_u64() * n) >> 64

    def fork(self, tag):
        return Rng(self.next_u64() ^ ((tag * GOLDEN) & MASK))


def _round_half_away(x):
    """Rust ``f64::round`` for the non-negative values used here."""
    return math.floor(x + 0.5)


def demand_cores(curve, levels, fallback):
    mx = max(curve)
    if not mx > 0.0:
        return fallback
    for l, u in enumerate(curve):
        if u >= mx - 1e-12:
            return levels[l]
    return levels[-1]


def demand_cores_confident(curve, levels, fallback, obs, min_obs):
    if min_obs == 0:
        return demand_cores(curve, levels, fallback)
    masked = [u if c >= min_obs else 0.0 for u, c in zip(curve, obs)]
    return demand_cores(masked, levels, fallback)


def synth_obs(seed, epoch, tenant, nlv):
    rng = Rng(seed ^ OBS_SALT).fork(((tenant << 32) | epoch) & MASK)
    return [rng.below(4 + (nlv - 1 - l) * 2) for l in range(nlv)]


def synth_tenant(seed, epoch, tenant, levels, even, min_obs):
    rng = Rng(seed).fork(((tenant << 32) | epoch) & MASK)
    nlv = len(levels)

    def reserve(c):
        if min_obs == 0:
            return demand_cores(c, levels, even)
        obs = synth_obs(seed, epoch, tenant, nlv)
        return demand_cores_confident(c, levels, even, obs, min_obs)

    if rng.f64() < 0.03:
        c = [0.0] * nlv
        return c, reserve(c)
    sat = 1 + rng.below(nlv)
    top = 0.3 + 0.7 * rng.f64()
    acc = 0.0
    c = []
    for l in range(nlv):
        if l < sat:
            acc += 0.05 + rng.f64()
        c.append(acc)
    mx = max(acc, 1e-9)
    c = [_round_half_away(top * v / mx * 64.0) / 64.0 for v in c]
    return c, reserve(c)


class EpochAdmission:
    """Mirror of ``scheduler/mod.rs`` ``EpochAdmission`` (rank + decide)."""

    def __init__(self, apps, bound, hysteresis=0):
        self.bound = max(bound, 1)
        self.admitted = [True] * apps
        self.parked_streak = [0] * apps
        self.admitted_streak = [0] * apps
        self.decided = False
        self.hysteresis = hysteresis

    def _overdue(self):
        return [
            self.decided and not self.admitted[i] and self.parked_streak[i] + 1 >= self.bound
            for i in range(len(self.admitted))
        ]

    def _rank(self, weights):
        overdue = self._overdue()

        def clazz(i):
            if overdue[i]:
                return 0
            return 1 if self.admitted[i] else 2

        def key(i):
            c = clazz(i)
            streak = self.admitted_streak[i] if c == 1 else -self.parked_streak[i]
            return (-weights[i], c, streak, i)

        return sorted(range(len(weights)), key=key)

    def decide(self, total, weights, reservations):
        n = len(self.admitted)
        order = self._rank(weights)
        overdue = self._overdue()
        nxt = [False] * n
        used = 0
        for i in order:
            r = min(max(reservations[i], 1), max(total, 1))
            slack = (
                self.hysteresis
                if self.decided and not self.admitted[i] and not overdue[i]
                else 0
            )
            if used + r + slack <= total:
                nxt[i] = True
                used += r
        if not any(nxt):
            nxt[order[0]] = True
        fresh = [i for i in order if not nxt[i] and (self.admitted[i] or not self.decided)]
        m = len(fresh)
        gpe = max((m + self.bound - 1) // self.bound, 1)
        is_fresh = [False] * n
        for j, i in enumerate(fresh):
            self.parked_streak[i] = (m - 1 - j) // gpe
            self.admitted_streak[i] = 0
            is_fresh[i] = True
        for i in range(n):
            if nxt[i]:
                self.parked_streak[i] = 0
                self.admitted_streak[i] += 1
            elif not is_fresh[i]:
                self.parked_streak[i] += 1
                self.admitted_streak[i] = 0
        self.admitted = list(nxt)
        self.decided = True
        return list(nxt)


def reserve_top_up(rungs, levels, total, admitted, reservations, even, weights):
    """Mirror of ``scheduler/mod.rs`` ``reserve_top_up``."""
    order = sorted(range(len(rungs)), key=lambda i: (-weights[i], i))
    used = sum(levels[rungs[i]] for i in range(len(rungs)) if admitted[i])
    for i in order:
        if not admitted[i]:
            continue
        want = min(reservations[i], even)
        while (
            rungs[i] + 1 < len(levels)
            and levels[rungs[i]] < want
            and levels[rungs[i] + 1] <= want
            and used - levels[rungs[i]] + levels[rungs[i] + 1] <= total
        ):
            used = used - levels[rungs[i]] + levels[rungs[i] + 1]
            rungs[i] += 1


def fnv_quota(quota):
    h = 0xCBF29CE484222325
    for q in quota:
        for b in (q & MASK).to_bytes(8, "little"):
            h ^= b
            h = (h * 0x100000001B3) & MASK
    return h


def run_epochs(tenants, epochs=3, seed=42, rungs=8, cores_per_tenant=3,
               demand_confidence=0):
    """Mirror of ``fleet/scale.rs`` ``run`` — per-epoch aggregates."""
    n = tenants
    pool = n * max(cores_per_tenant, 1)
    alloc_pool = pool - pool // 50  # the 2% fairness reserve
    levels = wf.core_levels(pool, n, 1, max(rungs, 2), 3.0)
    even = max(pool // n, 1)
    weights = [4.0 if i % 5 == 0 else 2.0 if i % 5 in (1, 2) else 1.0
               for i in range(n)]
    adm = EpochAdmission(n, 4, hysteresis=even)
    prev_rung = [0] * n
    prev_admitted = [False] * n
    out = []
    for e in range(epochs):
        pairs = [synth_tenant(seed, e, t, levels, even, demand_confidence)
                 for t in range(n)]
        curves = [c for c, _ in pairs]
        demands = [d for _, d in pairs]
        admitted = adm.decide(pool, weights, demands)
        idx = [i for i in range(n) if admitted[i]]
        sub_curves = [curves[i] for i in idx]
        sub_weights = [weights[i] for i in idx]
        sub_prev = [prev_rung[i] if prev_admitted[i] else 0 for i in idx]
        grant, _ops = wf.allocate_v2_heap(
            sub_curves, levels, alloc_pool, sub_weights, sub_prev, 0.02)
        pre = list(grant)
        sub_res = [demands[i] for i in idx]
        reserve_top_up(grant, levels, pool, [True] * len(idx), sub_res,
                       even, sub_weights)
        top_up = sum(levels[g] - levels[p] for g, p in zip(grant, pre))
        assert all(g >= p for g, p in zip(grant, pre)), "top-up reduced a grant"
        quota = [0] * n
        util = 0.0
        moved = 0
        for s, i in enumerate(idx):
            quota[i] = levels[grant[s]]
            util += weights[i] * sub_curves[s][grant[s]]
            if prev_admitted[i] and grant[s] != prev_rung[i]:
                moved += 1
            prev_rung[i] = grant[s]
        used = sum(quota)
        out.append({
            "epoch": e, "admitted": len(idx), "parked": n - len(idx),
            "used_cores": used, "top_up_cores": top_up,
            "moved_tenants": moved, "weighted_utility": util,
            "quota_fingerprint": fnv_quota(quota),
        })
        prev_admitted = admitted
    return {"tenants": n, "pool": pool, "levels": levels, "epochs": out}


# ---------------------------------------------------------------------------
# tests — each named after the Rust assertion it underwrites
# ---------------------------------------------------------------------------

def test_epoch_invariants_hold():
    """Underwrites ``scale::tests::epoch_invariants_hold`` (n=400, e=4)."""
    rep = run_epochs(400, epochs=4)
    for e in rep["epochs"]:
        assert e["admitted"] + e["parked"] == 400
        assert e["used_cores"] <= rep["pool"]
        assert e["admitted"] > 0
        assert math.isfinite(e["weighted_utility"])


def test_parking_happens_at_500():
    """Underwrites ``scale::tests::parking_actually_happens`` (n=500)."""
    rep = run_epochs(500, epochs=3)
    assert sum(e["parked"] for e in rep["epochs"]) > 0


def test_top_up_fires_on_default_shape():
    """Underwrites the Rust ``top_up_spends_the_fairness_reserve``
    assertion: with the 2% holdback, demand pressure above the even share
    leaves under-served tenants every epoch, so the top-up always finds
    work (mirror values at n=400: 12/24/20 cores across the 3 epochs)."""
    for n in (400, 500, 600):
        rep = run_epochs(n, epochs=3)
        for e in rep["epochs"]:
            assert e["top_up_cores"] > 0, (n, e)
        assert all(e["used_cores"] <= rep["pool"] for e in rep["epochs"])


def test_confidence_gate_changes_reservations():
    """Underwrites the Rust ``demand_confidence_gates_reservations``
    assertion: masking unconfident rungs changes some demands, which
    changes admission packing and the quota fingerprints (n=400)."""
    base = run_epochs(400, epochs=3)
    conf = run_epochs(400, epochs=3, demand_confidence=2)
    assert base != conf
    # the divergence reaches the fingerprint, not just a count
    assert any(
        b["quota_fingerprint"] != c["quota_fingerprint"]
        for b, c in zip(base["epochs"], conf["epochs"])
    )


def test_confidence_gate_masks_some_demands():
    """The gate is live at the demand layer itself: with min_obs=2 a real
    fraction of tenants reserve differently than the optimistic path."""
    n = 400
    pool = n * 3
    levels = wf.core_levels(pool, n, 1, 8, 3.0)
    even = max(pool // n, 1)
    diff = sum(
        1 for t in range(n)
        if synth_tenant(42, 0, t, levels, even, 0)[1]
        != synth_tenant(42, 0, t, levels, even, 2)[1]
    )
    assert diff > 0, "confidence gate never changed a reservation"
    # curves must be untouched (independent obs stream)
    for t in range(0, n, 37):
        assert (synth_tenant(42, 0, t, levels, even, 0)[0]
                == synth_tenant(42, 0, t, levels, even, 2)[0])


def test_top_up_respects_pool_and_reservations():
    """Direct unit check of the reserve_top_up mirror semantics: never
    exceeds the pool, never raises past min(reservation, even)."""
    levels = [1, 2, 3, 5, 9]
    rungs = [0, 0, 0, 0]
    admitted = [True, True, False, True]
    reservations = [9, 2, 9, 3]
    weights = [1.0, 4.0, 2.0, 2.0]
    even = 3
    total = 8
    reserve_top_up(rungs, levels, total, admitted, reservations, even, weights)
    used = sum(levels[r] for r, a in zip(rungs, admitted) if a)
    assert used <= total
    # tenant 1 (top priority): reservation 2 < even -> capped at 2 cores
    assert levels[rungs[1]] <= 2
    # parked tenant untouched
    assert rungs[2] == 0
    # tenant 0: want = min(9, even) = 3, raised only while cores remain
    assert levels[rungs[0]] <= 3
