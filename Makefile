# iptune build orchestration.
#
#   make artifacts   — AOT-lower the JAX/Pallas predictor bundles to HLO
#                      text artifacts (artifacts/*.hlo.txt + manifest.json)
#                      for the Rust PJRT runtime. Requires the Python dev
#                      deps (python/requirements-dev.txt). Python runs
#                      only here, at build time — never on the request path.
#   make build       — release build of the Rust workspace.
#   make test        — tier-1 gate (cargo build --release && cargo test).
#   make parity      — the XLA parity suite that is runnable without the
#                      vendored `xla` crate: the artifact inventory checks
#                      (python/tests/test_aot.py) validating every lowered
#                      HLO artifact against the specs. The Rust-side
#                      numeric parity (rust/tests/integration_runtime.rs)
#                      requires `cargo test --features pjrt`, which only
#                      artifact-building environments with the vendored
#                      crate can compile — without it the XLA stub makes
#                      those tests skip, so running them here would be
#                      vacuous.

ARTIFACT_DIR := artifacts

.PHONY: artifacts build test parity clean-artifacts

artifacts:
	cd python && python compile/aot.py --out ../$(ARTIFACT_DIR)

build:
	cd rust && cargo build --release

test: build
	cd rust && cargo test -q

parity:
	python -m pytest python/tests/test_aot.py -q
	@echo "note: Rust-side numeric parity needs 'cd rust && cargo test --features pjrt'"
	@echo "      (vendored xla crate required; the default stub skips those tests)"

clean-artifacts:
	rm -rf $(ARTIFACT_DIR)
