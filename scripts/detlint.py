#!/usr/bin/env python3
"""detlint — a determinism-invariant static analysis pass over rust/src.

The repo's one non-negotiable bar is that reports and timelines are
byte-identical across thread counts, pacing and stragglers (ROADMAP
"determinism bar"). CI enforces that *dynamically* with cmp-based smoke
jobs, which only catch a nondeterminism bug after someone has written
the exact scenario that triggers it. detlint shifts the bar left: it
statically forbids the known nondeterminism *sources* in every module
whose behavior can reach product output.

How it works (pure stdlib, no toolchain needed):

1. **File model.** Every ``.rs`` file is split into lines; a small state
   machine strips string/char literals and ``//`` / ``/* */`` comments
   (raw strings, byte literals and nested block comments included) so
   rule regexes never fire inside text. ``#[cfg(test)]`` items are
   located by brace matching and excluded — test code may use wall
   clocks and unwraps freely.

2. **Module graph.** Modules are discovered by walking ``mod`` / ``pub
   mod`` declarations from ``lib.rs`` and ``main.rs`` (honoring
   ``#[path]``). A declaration gated on ``#[cfg(feature = ...)]`` is
   *not* part of the default build (e.g. ``runtime/xla.rs`` behind
   ``pjrt``; the stub compiles instead) — such files are skipped and
   recorded in the report. Dependency edges come from ``use crate::`` /
   ``use super::`` declarations and inline ``crate::a::b`` paths.

3. **Reachability.** The *product set* is every module transitively
   reachable from the product-output roots — report/trace/timeline
   serialization, scheduler decisions, learner updates (prefixes in
   ``ROOT_PREFIXES``). A hash iteration in a module no root depends on
   (say, a bench-only helper) is harmless; the same line in ``trace/``
   is a correctness bug. Rules only fire inside the product set.

4. **Rules.** See ``RULES`` below. Where a rule needs type information
   a token-level pass cannot have, it over-approximates and documents
   the approximation (e.g. any ``HashMap`` mention is flagged: a hash
   container in product code is a standing hazard even before anyone
   iterates it — the fix is ``BTreeMap``).

5. **Suppressions.** A violation is suppressed only by an inline
   annotation carrying a reason::

       // detlint: allow(unwrap) — receiver is checked non-empty above

   either trailing on the offending line or standing alone on the
   line(s) directly above it. Several rules may be listed:
   ``allow(unwrap, lossy-cast)``. Annotations without a reason are
   themselves errors; annotations that suppress nothing are reported
   as stale (warning). Every suppression lands in the JSON report, so
   the allow inventory is machine-auditable.

Exit status: 0 clean, 1 violations (or reasonless annotations), 2 usage
error. ``--json FILE`` writes the machine-readable report the
``static-analysis`` CI job uploads as an artifact.
"""

import argparse
import json
import re
import sys
from pathlib import Path

# --------------------------------------------------------------------------
# configuration
# --------------------------------------------------------------------------

# Modules whose behavior reaches product output: report / trace /
# timeline serialization, scheduler decisions, learner updates, the
# engine that stamps records, the experiment harness that writes figure
# JSON. Matched as path prefixes against module paths like
# "scheduler::live".
ROOT_PREFIXES = (
    "trace",
    "obs",
    "scheduler",
    "learner",
    "fleet",
    "engine",
    "tuner",
    "experiments",
)

# Modules allowed to read wall clocks: bench harnesses time things by
# definition, and the test-dir helper stamps unique directory names.
# Nothing here may feed product output with the value it reads — that
# property is what the reachability walk + per-site annotations protect
# elsewhere.
WALLCLOCK_ALLOW = (
    "util::bench",
    "util::testdir",
)

# Test-infrastructure modules: unwraps in the bench/testdir harnesses
# abort a measurement run, never a product run.
TESTINFRA = (
    "util::bench",
    "util::testdir",
)

# Built-in idiom exemptions for the unwrap rule (documented, auditable):
# lock/wait poisoning and channel disconnect unwraps are fatal-by-design
# in this codebase (a dead worker must take the run down, not limp), and
# partial_cmp unwraps sit on floats already asserted finite. The
# receiver may be on the previous line of a wrapped method chain.
UNWRAP_IDIOMS = re.compile(
    r"\.(lock|wait|read|write|join|send|recv|try_recv|partial_cmp)\s*\("
)

FLOAT_LIT = r"(?:\d+\.\d*(?:[eE][+-]?\d+)?|\.\d+|f(?:32|64)::(?:NAN|INFINITY|NEG_INFINITY|EPSILON))"
INT_TYPES = r"(?:u8|u16|u32|u64|u128|usize|i8|i16|i32|i64|i128|isize)"
FLOAT_EVIDENCE = re.compile(
    r"f64|f32|\d\.\d|\.\d+\b|\b(?:round|floor|ceil|sqrt|fract|powi|powf|ln|exp)\b"
)

RULES = {
    "hash-iter": (
        "HashMap/HashSet in product code: iteration order is seeded per "
        "process and can silently reach a collection or serializer — use "
        "BTreeMap/BTreeSet"
    ),
    "wallclock": (
        "wall-clock read (Instant::now / SystemTime) outside allowlisted "
        "pacing/bench/testdir modules: product decisions must be functions "
        "of logical clocks only"
    ),
    "thread-id": (
        "thread::current() / ThreadId dependence: worker identity must "
        "never influence product output"
    ),
    "float-eq": (
        "float == / != comparison: exact float equality is representation-"
        "dependent; compare against an epsilon or document the exact-"
        "representation invariant"
    ),
    "lossy-cast": (
        "lossy `as` cast in accounting arithmetic (float->int truncation "
        "or f32 narrowing): use checked/rounded conversions or annotate "
        "the bound that makes it exact"
    ),
    "unwrap": (
        "unwrap()/expect() in library code: panics tear down product runs; "
        "return Result, or annotate the invariant that makes the value "
        "present"
    ),
}

ANNOT_RE = re.compile(
    r"//\s*detlint:\s*allow\(\s*([a-z0-9_,\s\-]+?)\s*\)\s*(?:[—:-]+\s*(.*?))?\s*$"
)


# --------------------------------------------------------------------------
# lexical model
# --------------------------------------------------------------------------

def strip_code(text):
    """Return ``text`` with comments removed and string/char literal
    bodies blanked (structure — line count and column positions — is
    preserved so reported line numbers match the file). Handles nested
    block comments, raw strings ``r#".."#``, byte strings/literals and
    escapes. Tolerant by construction: on a lexing surprise it degrades
    to copying characters through, never crashes."""
    out = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        # line comment
        if c == "/" and i + 1 < n and text[i + 1] == "/":
            j = text.find("\n", i)
            if j == -1:
                j = n
            i = j
            continue
        # block comment (nested)
        if c == "/" and i + 1 < n and text[i + 1] == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    if text[j] == "\n":
                        out.append("\n")
                    j += 1
            i = j
            continue
        # raw string r"..." / r#"..."# / br#"..."#
        m = re.match(r'(?:b?r)(#*)"', text[i:])
        if m and (i == 0 or not text[i - 1].isalnum() and text[i - 1] != "_"):
            hashes = m.group(1)
            close = '"' + hashes
            j = text.find(close, i + len(m.group(0)))
            j = n if j == -1 else j + len(close)
            out.append(m.group(0) + close)
            out.extend("\n" for k in range(i, j) if text[k] == "\n")
            i = j
            continue
        # string / byte string
        if c == '"' or (c == "b" and i + 1 < n and text[i + 1] == '"'):
            j = i + (2 if c == "b" else 1)
            while j < n and text[j] != '"':
                if text[j] == "\\":
                    j += 1
                if j < n and text[j] == "\n":
                    out.append("\n")
                j += 1
            out.append('""')
            i = j + 1
            continue
        # char / byte-char literal ('a', '\n', b'['), NOT lifetimes ('a)
        if c == "'" or (c == "b" and i + 1 < n and text[i + 1] == "'"):
            m2 = re.match(r"b?'(\\.|\\x[0-9a-fA-F]{2}|\\u\{[0-9a-fA-F]+\}|[^'\\])'", text[i:])
            if m2:
                out.append("' '" if c == "'" else "b' '")
                i += len(m2.group(0))
                continue
        out.append(c)
        i += 1
    return "".join(out)


def test_regions(code_lines):
    """Line numbers (1-based, inclusive) covered by ``#[cfg(test)]``
    items, found by matching the braces of the item that follows the
    attribute."""
    covered = set()
    opens = [i for i, l in enumerate(code_lines) if "#[cfg(test)]" in l or "#[cfg(all(test" in l]
    for start in opens:
        depth = 0
        entered = False
        for j in range(start, len(code_lines)):
            for ch in code_lines[j]:
                if ch == "{":
                    depth += 1
                    entered = True
                elif ch == "}":
                    depth -= 1
            if entered and depth <= 0:
                covered.update(range(start + 1, j + 2))
                break
        else:
            covered.update(range(start + 1, len(code_lines) + 1))
    return covered


class SourceFile:
    def __init__(self, path, rel):
        self.path = path
        self.rel = rel
        self.raw_lines = path.read_text().split("\n")
        self.code_lines = strip_code(path.read_text()).split("\n")
        self.tests = test_regions(self.code_lines)
        # annotations: line -> {rule: reason}; standalone annotation
        # lines attach to the next non-annotation line.
        self.allows = {}
        self.annot_errors = []
        pending = {}
        for ln, raw in enumerate(self.raw_lines, 1):
            m = ANNOT_RE.search(raw)
            if not m:
                if pending and raw.strip():
                    self.allows[ln] = dict(pending)
                    pending = {}
                continue
            rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
            reason = (m.group(2) or "").strip()
            bad = [r for r in rules if r not in RULES]
            if bad:
                self.annot_errors.append(
                    (ln, f"unknown rule(s) {bad} in detlint annotation"))
                continue
            if not reason:
                self.annot_errors.append(
                    (ln, "detlint annotation carries no reason — write "
                         "`// detlint: allow(rule) — why it is safe`"))
                continue
            entry = {r: reason for r in rules}
            if raw.strip().startswith("//"):
                pending.update(entry)          # standalone: covers next line
            else:
                self.allows.setdefault(ln, {}).update(entry)  # trailing


# --------------------------------------------------------------------------
# module graph
# --------------------------------------------------------------------------

MOD_DECL = re.compile(r"^\s*(?:pub(?:\([a-z:\s]*\))?\s+)?mod\s+([A-Za-z0-9_]+)\s*;")
ATTR = re.compile(r"^\s*#\[")
CFG_FEATURE = re.compile(r"#\[\s*cfg\s*\(\s*(?!not\s*\()[^)]*feature\s*=")
PATH_ATTR = re.compile(r'#\[\s*path\s*=\s*"([^"]+)"\s*\]')


def discover_modules(src_root):
    """Walk ``mod`` declarations from lib.rs / main.rs, honoring
    ``#[path]`` and skipping declarations gated on ``#[cfg(feature)]``
    (not part of the default build). Returns ``(modules, gated)`` where
    ``modules`` maps module path -> SourceFile and ``gated`` lists
    skipped files."""
    modules, gated = {}, []
    seeds = []
    for name, modpath in (("lib.rs", "crate"), ("main.rs", "main")):
        p = src_root / name
        if p.exists():
            seeds.append((p, modpath))
    if not seeds:  # fixture trees without lib/main: every file is a module
        for p in sorted(src_root.rglob("*.rs")):
            rel = p.relative_to(src_root)
            parts = list(rel.with_suffix("").parts)
            if parts[-1] == "mod":
                parts = parts[:-1]
            modules["::".join(parts) or "crate"] = SourceFile(p, str(rel))
        return modules, gated

    queue = list(seeds)
    seen = set()
    while queue:
        path, modpath = queue.pop()
        if path in seen:
            continue
        seen.add(path)
        sf = SourceFile(path, str(path.relative_to(src_root)))
        modules[modpath] = sf
        moddir = path.parent if path.name in ("mod.rs", "lib.rs", "main.rs") \
            else path.parent / path.stem
        attr_buf = []
        for raw in sf.code_lines:
            if ATTR.match(raw):
                attr_buf.append(raw)
                continue
            m = MOD_DECL.match(raw)
            if not m:
                if raw.strip():
                    attr_buf = []
                continue
            name = m.group(1)
            attrs = " ".join(attr_buf)
            attr_buf = []
            child = name if modpath in ("crate", "main") else f"{modpath}::{name}"
            pm = PATH_ATTR.search(attrs)
            if CFG_FEATURE.search(attrs):
                # non-default build; record the file it would pull in
                target = moddir / pm.group(1) if pm else None
                if target is None:
                    for cand in (moddir / f"{name}.rs", moddir / name / "mod.rs"):
                        if cand.exists():
                            target = cand
                            break
                if target and target.exists():
                    gated.append(str(target.relative_to(src_root)))
                continue
            if pm:
                target = moddir / pm.group(1)
            else:
                target = None
                for cand in (moddir / f"{name}.rs", moddir / name / "mod.rs"):
                    if cand.exists():
                        target = cand
                        break
            if target is None or not target.exists():
                continue  # inline `mod x;` without a file (or inline mod)
            queue.append((target, child))
    return modules, gated


USE_RE = re.compile(r"\buse\s+(crate|super)::([A-Za-z0-9_:{},\s*]+?)\s*;")
INLINE_RE = re.compile(r"\bcrate::([A-Za-z0-9_]+(?:::[A-Za-z0-9_]+)*)")
# relative imports/re-exports (`pub use rng::Rng;` inside util/mod.rs):
# the first segment is resolved against the module's declared children.
REL_USE_RE = re.compile(
    r"\buse\s+(?:self::)?([a-z_][A-Za-z0-9_]*)\s*(?:::|;)")


def dep_edges(modules):
    """module path -> set of module paths it depends on (non-test lines
    only: a dependency used solely from tests does not make the target
    product-reachable)."""
    known = set(modules)
    edges = {m: set() for m in modules}

    def resolve(segs):
        """Longest known-module prefix of a ``::`` path."""
        for k in range(len(segs), 0, -1):
            cand = "::".join(segs[:k])
            if cand in known:
                return cand
        return None

    for mod, sf in modules.items():
        for ln, line in enumerate(sf.code_lines, 1):
            if ln in sf.tests:
                continue
            for kind, rest in USE_RE.findall(line):
                rest = rest.strip()
                base = [] if kind == "crate" else mod.split("::")[:-1]
                if kind == "super" and mod in ("crate", "main"):
                    base = []
                # expand one level of {a, b::c} grouping
                gm = re.match(r"([A-Za-z0-9_:]*)\{(.*)\}", rest)
                tails = ([t.strip() for t in gm.group(2).split(",")]
                         if gm else [rest])
                prefix = (gm.group(1).rstrip(":").split("::")
                          if gm and gm.group(1).rstrip(":") else [])
                for t in tails:
                    segs = base + prefix + [s for s in t.split("::") if s and s != "*"]
                    tgt = resolve([s for s in segs if s not in ("self",)])
                    if tgt and tgt != mod:
                        edges[mod].add(tgt)
            for path in INLINE_RE.findall(line):
                tgt = resolve(path.split("::"))
                if tgt and tgt != mod:
                    edges[mod].add(tgt)
            for seg in REL_USE_RE.findall(line):
                child = seg if mod in ("crate", "main") else f"{mod}::{seg}"
                if child in known and child != mod:
                    edges[mod].add(child)
    return edges


def reachable_set(modules, edges):
    roots = [m for m in modules
             if any(m == p or m.startswith(p + "::") or m == p.rstrip("::")
                    for p in ROOT_PREFIXES)]
    seen = set(roots)
    stack = list(roots)
    while stack:
        m = stack.pop()
        for d in edges.get(m, ()):
            if d not in seen:
                seen.add(d)
                stack.append(d)
    return roots, seen


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

def _under(mod, prefixes):
    return any(mod == p or mod.startswith(p + "::") for p in prefixes)


def cast_chunk(line, pos):
    """The expression text immediately feeding an ``as`` cast at
    ``pos``: walks back over one postfix chain (identifiers, field/
    method dots, one balanced paren/bracket group each step)."""
    i = pos
    start = pos
    while i > 0:
        j = i - 1
        while j >= 0 and line[j].isspace():
            j -= 1
        if j < 0:
            break
        if line[j] in ")]":
            close, open_ = line[j], "(" if line[j] == ")" else "["
            depth = 0
            while j >= 0:
                if line[j] == close:
                    depth += 1
                elif line[j] == open_:
                    depth -= 1
                    if depth == 0:
                        break
                j -= 1
            start = max(j, 0)
            i = j
        elif line[j].isalnum() or line[j] == "_":
            while j >= 0 and (line[j].isalnum() or line[j] == "_"):
                j -= 1
            start = j + 1
            i = j + 1
        elif line[j] == ".":
            start = j
            i = j
        else:
            break
    return line[start:pos]


def scan_file(mod, sf, reachable):
    """Yield (line, rule, snippet) violations for one file."""
    in_product = mod in reachable
    if not in_product:
        return
    is_main = mod == "main" or mod.startswith("main::")
    for ln, line in enumerate(sf.code_lines, 1):
        if ln in sf.tests or not line.strip():
            continue
        snippet = sf.raw_lines[ln - 1].strip() if ln <= len(sf.raw_lines) else ""

        if re.search(r"\b(HashMap|HashSet)\b", line):
            yield ln, "hash-iter", snippet
        if not _under(mod, WALLCLOCK_ALLOW) and re.search(
                r"\bInstant::now\b|\bSystemTime\b|\bUNIX_EPOCH\b", line):
            yield ln, "wallclock", snippet
        if re.search(r"\bthread::current\b|\bThreadId\b", line):
            yield ln, "thread-id", snippet
        if re.search(rf"(?:{FLOAT_LIT})\s*(?:==|!=)[^=]", line) or re.search(
                rf"(?:==|!=)\s*[-+]?(?:{FLOAT_LIT})", line):
            yield ln, "float-eq", snippet
        for m in re.finditer(rf"\bas\s+({INT_TYPES}|f32)\b", line):
            if m.group(1) == "f32":
                yield ln, "lossy-cast", snippet
                break
            chunk = cast_chunk(line, m.start())
            if FLOAT_EVIDENCE.search(chunk):
                yield ln, "lossy-cast", snippet
                break
        if not is_main and not _under(mod, TESTINFRA):
            for m in re.finditer(r"\.\s*(unwrap\s*\(\s*\)|expect\s*\()", line):
                before = line[:m.start()]
                # parser's own `self.expect(b'[')` is not Result::expect
                if m.group(1).startswith("expect") and re.search(r"\bself\s*$", before):
                    continue
                ctx = before
                if not ctx.strip() or ctx.strip() in (".",):
                    prev = ln - 2
                    while prev >= 0 and not sf.code_lines[prev].strip():
                        prev -= 1
                    if prev >= 0:
                        ctx = sf.code_lines[prev] + " " + ctx
                if UNWRAP_IDIOMS.search(ctx):
                    continue
                yield ln, "unwrap", snippet
                break


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def run(src_root, json_out=None, verbose=False):
    src_root = Path(src_root)
    if not src_root.is_dir():
        print(f"detlint: {src_root} is not a directory", file=sys.stderr)
        return 2
    modules, gated = discover_modules(src_root)
    edges = dep_edges(modules)
    roots, reachable = reachable_set(modules, edges)

    violations, suppressed, annot_errors, stale = [], [], [], []
    used_allows = set()
    for mod in sorted(modules):
        sf = modules[mod]
        for ln, rule, snippet in scan_file(mod, sf, reachable):
            allow = sf.allows.get(ln, {})
            if rule in allow:
                suppressed.append({
                    "file": sf.rel, "line": ln, "rule": rule,
                    "reason": allow[rule],
                })
                used_allows.add((mod, ln, rule))
            else:
                violations.append({
                    "file": sf.rel, "line": ln, "rule": rule,
                    "module": mod, "snippet": snippet[:160],
                })
        for ln, msg in sf.annot_errors:
            annot_errors.append({"file": sf.rel, "line": ln, "error": msg})
        for ln, rules in sf.allows.items():
            for rule in rules:
                if (mod, ln, rule) not in used_allows:
                    stale.append({"file": sf.rel, "line": ln, "rule": rule})

    report = {
        "tool": "detlint",
        "version": 1,
        "root": str(src_root),
        "rules": RULES,
        "roots": sorted(roots),
        "reachable_modules": sorted(reachable),
        "feature_gated_files": sorted(gated),
        "files_scanned": len(modules),
        "violations": violations,
        "suppressed": suppressed,
        "annotation_errors": annot_errors,
        "stale_allows": stale,
        "summary": {
            r: sum(1 for v in violations if v["rule"] == r) for r in RULES
        },
    }
    if json_out:
        Path(json_out).write_text(json.dumps(report, indent=2) + "\n")

    for v in violations:
        print(f"{v['file']}:{v['line']}: [{v['rule']}] {v['snippet']}")
        if verbose:
            print(f"    {RULES[v['rule']]}")
    for e in annot_errors:
        print(f"{e['file']}:{e['line']}: [bad-annotation] {e['error']}")
    for s in stale:
        print(f"{s['file']}:{s['line']}: warning: stale allow({s['rule']}) "
              "suppresses nothing", file=sys.stderr)
    ok = not violations and not annot_errors
    print(
        f"detlint: {len(modules)} modules ({len(reachable)} product-reachable, "
        f"{len(gated)} feature-gated file(s) skipped), "
        f"{len(violations)} violation(s), {len(suppressed)} suppressed, "
        f"{len(annot_errors)} bad annotation(s)"
        + (" — clean" if ok else ""))
    return 0 if ok else 1


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        epilog="The rule catalog, the allow-annotation grammar, and the "
               "determinism contract this tool enforces are documented in "
               "docs/DETERMINISM.md.",
    )
    ap.add_argument("src", nargs="?", help="crate source root (e.g. rust/src)")
    ap.add_argument("--json", help="write the machine-readable report here")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for r, desc in RULES.items():
            print(f"{r}: {desc}")
        return 0
    if args.src is None:
        ap.error("the following arguments are required: src")
    return run(args.src, args.json, args.verbose)


if __name__ == "__main__":
    sys.exit(main())
