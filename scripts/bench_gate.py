#!/usr/bin/env python3
"""Bench-trajectory gate for the CI `bench-smoke` job.

Merges the per-target JSON files the benches emit (util/bench.rs
`write_json_env`, driven by IPTUNE_BENCH_JSON_DIR) into one
`BENCH_<sha>.json` trajectory artifact, then gates the scheduler
epoch-cost and tuner hot-path benches against the checked-in baseline:
the job FAILS when a gated bench's median exceeds 2x its baseline
budget. Non-gated benches (simulator frame cost, trace generation) ride
along in the artifact and print warnings only — they seed the trajectory
without flaking the gate on noisy shared runners. Bench side-metrics
(e.g. `ladder_trace/light_peak_bytes`, the ladder-trace peak memory) are
lifted into the artifact's top-level "metrics" map so non-timing
regressions stay visible across commits.

Usage:
    bench_gate.py <json_dir> <baseline.json> <out.json> [--sha SHA]
                  [--trajectory FILE]
    bench_gate.py --suggest <baseline.json> <trajectory.json> [...]
                  [--factor F]

`--trajectory FILE` (the committed `ci/bench-trajectory.json`) appends
one compact entry per *passing* run — the sha, every gated bench's
median, and the side metrics — pruned to the last 50 entries, so
budget-tightening has real history instead of whatever artifacts happen
to survive retention. Runs that trip the gate leave the file untouched.

`--suggest` tightens budgets from accumulated history: it accepts both
`BENCH_<sha>.json` artifacts and compact trajectory files (detected by
their "entries" key) and, for every bench observed, prints a
baseline-shaped JSON whose budget is `F x` the worst observed median
(default F = 3, rounded up to two significant digits so re-runs over
the same inputs are reproducible). Benches already in the baseline keep
their gated/tracked bucket; new benches land in "tracked" for a human
to promote. Side metrics are no longer dropped: the output's "metrics"
key summarizes each one (min/max/latest) — informational, not a budget.
Paste the "gated"/"tracked" maps over ci/bench-baseline.json once
enough runs have accumulated.

stdlib only — runs on any CI python3.
"""
import json
import math
import pathlib
import sys

REGRESSION_FACTOR = 2.0
SUGGEST_FACTOR = 3.0
TRAJECTORY_KEEP = 50


def round_up_2sig(ns):
    """Round up to two significant digits (stable across re-runs)."""
    if ns <= 0:
        return 1
    exp = 10 ** max(int(math.floor(math.log10(ns))) - 1, 0)
    return int(math.ceil(ns / exp) * exp)


def suggest(argv):
    factor = SUGGEST_FACTOR
    args = list(argv)
    if "--factor" in args:
        i = args.index("--factor")
        try:
            factor = float(args[i + 1])
        except (IndexError, ValueError):
            print(__doc__)
            return 2
        del args[i:i + 2]
    if len(args) < 2:
        print(__doc__)
        return 2
    baseline = json.loads(pathlib.Path(args[0]).read_text())
    medians = {}
    metric_series = {}
    for p in args[1:]:
        doc = json.loads(pathlib.Path(p).read_text())
        if "entries" in doc:
            # compact trajectory history (ci/bench-trajectory.json)
            for entry in doc["entries"]:
                for name, med in entry.get("medians", {}).items():
                    medians.setdefault(name, []).append(med)
                for name, value in entry.get("metrics", {}).items():
                    metric_series.setdefault(name, []).append(value)
        else:
            # one BENCH_<sha>.json artifact
            for tdoc in doc.get("targets", {}).values():
                for r in tdoc.get("results", []):
                    medians.setdefault(r["name"], []).append(r["median_ns"])
            for name, value in doc.get("metrics", {}).items():
                metric_series.setdefault(name, []).append(value)
    if not medians:
        print("bench_gate --suggest: no bench results in the given trajectories")
        return 1
    out = {"gated": {}, "tracked": {}}
    gated_names = set(baseline.get("gated", {}))
    for name in sorted(medians):
        budget = round_up_2sig(factor * max(medians[name]))
        bucket = "gated" if name in gated_names else "tracked"
        out[bucket][name] = budget
    if metric_series:
        out["metrics"] = {
            name: {"min": min(vs), "max": max(vs), "latest": vs[-1]}
            for name, vs in sorted(metric_series.items())
        }
    print(json.dumps(out, indent=2, sort_keys=True))
    for name in sorted(gated_names - set(medians)):
        print(f"# gated bench {name} absent from the trajectories "
              "(budget left for a human)", file=sys.stderr)
    return 0


def append_trajectory(path, sha, results, metrics, gated):
    """Append this run's gated medians + side metrics to the compact
    trajectory history, pruned to the last TRAJECTORY_KEEP entries."""
    p = pathlib.Path(path)
    try:
        doc = json.loads(p.read_text())
    except FileNotFoundError:
        doc = {}
    except json.JSONDecodeError as exc:
        # Losing the committed history (and its _comment block) should be
        # loud — a corrupt file means someone's hand-edit went wrong.
        print(f"bench_gate: WARNING: {path} is not valid JSON ({exc}); "
              "starting a fresh history", file=sys.stderr)
        doc = {}
    entries = doc.get("entries", [])
    entries.append({
        "sha": sha,
        "medians": {
            name: results[name]["median_ns"] for name in sorted(gated)
            if name in results
        },
        "metrics": metrics,
    })
    doc["entries"] = entries[-TRAJECTORY_KEEP:]
    p.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(f"bench trajectory history -> {path} "
          f"({len(doc['entries'])} entries)")


def main(argv):
    if len(argv) >= 2 and argv[1] == "--suggest":
        return suggest(argv[2:])
    args = list(argv[1:])
    sha, trajectory_path = "local", None
    for flag in ("--sha", "--trajectory"):
        if flag in args:
            i = args.index(flag)
            try:
                value = args[i + 1]
            except IndexError:
                print(__doc__)
                return 2
            if flag == "--sha":
                sha = value
            else:
                trajectory_path = value
            del args[i:i + 2]
    if len(args) < 3:
        print(__doc__)
        return 2
    json_dir, baseline_path, out_path = args[0], args[1], args[2]

    baseline = json.loads(pathlib.Path(baseline_path).read_text())
    gated = baseline.get("gated", {})
    tracked = baseline.get("tracked", {})

    targets = {}
    for p in sorted(pathlib.Path(json_dir).glob("*.json")):
        doc = json.loads(p.read_text())
        targets[doc["target"]] = doc
    if not targets:
        print(f"bench_gate: no bench json files under {json_dir}")
        return 1

    results = {}
    metrics = {}
    for doc in targets.values():
        for r in doc["results"]:
            results[r["name"]] = r
        for name, value in doc.get("metrics", {}).items():
            metrics[name] = value

    # one summary row per bench: (bucket, name, median, budget, headroom,
    # status). headroom = how much slower the bench may get before the
    # 2x-budget tripwire fires (">1.00x" means within budget).
    rows = []
    failures, warnings, missing = [], [], []
    for name, budget_ns in sorted(gated.items()):
        r = results.get(name)
        if r is None:
            missing.append(name)
            rows.append(("gated", name, None, budget_ns, None, "MISSING"))
            continue
        ratio = r["median_ns"] / budget_ns
        status = "FAIL" if ratio > REGRESSION_FACTOR else "ok"
        rows.append(("gated", name, r["median_ns"], budget_ns,
                     REGRESSION_FACTOR / ratio, status))
        if ratio > REGRESSION_FACTOR:
            failures.append((name, r["median_ns"], budget_ns))
    for name, budget_ns in sorted(tracked.items()):
        r = results.get(name)
        if r is None:
            rows.append(("tracked", name, None, budget_ns, None, "absent"))
            continue
        ratio = r["median_ns"] / budget_ns
        status = "WARN" if ratio > REGRESSION_FACTOR else "ok"
        rows.append(("tracked", name, r["median_ns"], budget_ns,
                     REGRESSION_FACTOR / ratio, status))
        if ratio > REGRESSION_FACTOR:
            warnings.append(name)
    budgeted = set(gated) | set(tracked)
    for name in sorted(set(results) - budgeted):
        rows.append(("untracked", name, results[name]["median_ns"], None, None, "-"))

    name_w = max([len(r[1]) for r in rows] + [len("bench")])
    print(f"{'bench':<{name_w}}  {'bucket':<9} {'median':>12} {'budget':>12} "
          f"{'headroom':>9}  status")
    print("-" * (name_w + 52))
    for bucket, name, median_ns, budget_ns, headroom, status in rows:
        med = f"{median_ns} ns" if median_ns is not None else "-"
        bud = f"{budget_ns} ns" if budget_ns is not None else "-"
        head = f"{headroom:.2f}x" if headroom is not None else "-"
        print(f"{name:<{name_w}}  {bucket:<9} {med:>12} {bud:>12} {head:>9}  {status}")
    if metrics:
        print(f"\n{'side metric':<{name_w}}  value")
        for name in sorted(metrics):
            print(f"{name:<{name_w}}  {metrics[name]}")

    out = {
        "sha": sha,
        "regression_factor": REGRESSION_FACTOR,
        "metrics": metrics,
        "targets": targets,
        "gate": {
            "failures": [
                {"name": n, "median_ns": m, "budget_ns": b} for n, m, b in failures
            ],
            "warnings": warnings,
            "missing_gated": missing,
        },
    }
    pathlib.Path(out_path).write_text(json.dumps(out, indent=2, sort_keys=True))
    print(f"bench trajectory -> {out_path}")

    if warnings:
        # explicit, not just a WARN cell in the table: tracked benches
        # regressed past the same 2x tripwire the gate uses — recorded
        # loudly but never blocking (noisy-runner tolerance).
        print(f"bench_gate: {len(warnings)} tracked bench(es) regressed "
              f">{REGRESSION_FACTOR:g}x (warn-only): {sorted(warnings)}")
    if missing:
        print(f"bench_gate: gated benches missing from results: {missing}")
        return 1
    if failures:
        print(f"bench_gate: {len(failures)} gated bench(es) regressed >2x")
        return 1
    # Only a passing run earns a history entry — a regressed run must not
    # rewrite the committed trajectory it just failed against.
    if trajectory_path is not None:
        append_trajectory(trajectory_path, sha, results, metrics, gated)
    print("bench_gate: all gated benches within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
