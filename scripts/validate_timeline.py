#!/usr/bin/env python3
"""Schema-validate a --trace-out timeline artifact (CI obs-smoke).

Checks, with pure stdlib:
  * the versioned envelope (version/kind/source/seed/apps/frames/
    epoch_frames/events) with the right types;
  * every event's required fields per kind, and that logical clocks are
    in range (tenant < apps, frame < frames);
  * canonical sort order: the event list is non-decreasing in the
    (epoch, tenant|inf, frame|inf, seq, kind-rank) key the Rust drain
    sorts by (rust: ``obs::sort_events``).

Exit 0 on a valid artifact, 1 with a diagnostic otherwise.

Usage: validate_timeline.py TIMELINE.json
"""

import json
import sys

KIND_RANK = {
    "frame_start": 0,
    "frame": 1,
    "knobs": 2,
    "park": 3,
    "resume": 4,
    "frontier": 5,
    "admission": 6,
    "alloc": 7,
    "shard_alloc": 8,
}

# required payload fields (beyond the clock fields) and their types
KIND_FIELDS = {
    "frame_start": {"knobs": list},
    "frame": {"ms": (int, float), "stage_ms": list, "fidelity": (int, float)},
    "knobs": {"from_frame": int, "horizon": int, "knobs": list},
    "park": {},
    "resume": {"at_epoch": int},
    "frontier": {"passed": int},
    "admission": {"admitted": list, "reservations": list},
    "alloc": {"cores": list, "parked": list, "churn_cores": int},
    "shard_alloc": {"shard": int, "lo": int, "hi": int, "cores": list},
}

INF = float("inf")


def fail(msg):
    print(f"validate_timeline: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def expect(cond, msg):
    if not cond:
        fail(msg)


def check_event(i, e, apps, frames):
    expect(isinstance(e, dict), f"event {i}: not an object")
    for key in ("tenant", "epoch", "frame", "seq", "kind"):
        expect(key in e, f"event {i}: missing {key!r}")
    kind = e["kind"]
    expect(kind in KIND_RANK, f"event {i}: unknown kind {kind!r}")
    expect(
        e["tenant"] is None or (isinstance(e["tenant"], int) and 0 <= e["tenant"] < apps),
        f"event {i}: tenant {e['tenant']!r} out of range",
    )
    expect(isinstance(e["epoch"], int) and e["epoch"] >= 0, f"event {i}: bad epoch")
    expect(
        e["frame"] is None or (isinstance(e["frame"], int) and 0 <= e["frame"] < frames),
        f"event {i}: frame {e['frame']!r} out of range",
    )
    expect(isinstance(e["seq"], int) and e["seq"] >= 0, f"event {i}: bad seq")
    for field, ty in KIND_FIELDS[kind].items():
        expect(field in e, f"event {i} ({kind}): missing {field!r}")
        expect(
            isinstance(e[field], ty) and not isinstance(e[field], bool),
            f"event {i} ({kind}): {field!r} has wrong type",
        )
    if kind in ("admission", "alloc"):
        for field in KIND_FIELDS[kind]:
            if isinstance(e[field], list) and field in ("admitted", "parked", "cores"):
                expect(
                    len(e[field]) == apps,
                    f"event {i} ({kind}): {field!r} has {len(e[field])} entries, want {apps}",
                )
    if kind == "shard_alloc":
        # a shard's cores slice covers exactly its contiguous tenant range
        expect(
            0 <= e["lo"] <= e["hi"] <= apps,
            f"event {i} (shard_alloc): range [{e['lo']}, {e['hi']}) outside 0..{apps}",
        )
        expect(
            len(e["cores"]) == e["hi"] - e["lo"],
            f"event {i} (shard_alloc): {len(e['cores'])} cores for a "
            f"{e['hi'] - e['lo']}-tenant shard",
        )
        expect(e["seq"] == e["shard"], f"event {i} (shard_alloc): seq must stamp the shard id")
    return (
        e["epoch"],
        INF if e["tenant"] is None else e["tenant"],
        INF if e["frame"] is None else e["frame"],
        e["seq"],
        KIND_RANK[kind],
    )


def main():
    if len(sys.argv) != 2:
        fail("usage: validate_timeline.py TIMELINE.json")
    try:
        with open(sys.argv[1]) as f:
            tl = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot read {sys.argv[1]}: {e}")

    expect(isinstance(tl, dict), "top level is not an object")
    expect(tl.get("version") == 1, f"version {tl.get('version')!r} != 1")
    expect(tl.get("kind") == "iptune-timeline", f"kind {tl.get('kind')!r}")
    expect(tl.get("source") in ("fleet", "live"), f"source {tl.get('source')!r}")
    for key in ("seed", "apps", "frames", "epoch_frames"):
        expect(
            isinstance(tl.get(key), int) and not isinstance(tl.get(key), bool),
            f"{key!r} is not an integer",
        )
    expect(tl["apps"] > 0 and tl["frames"] > 0 and tl["epoch_frames"] > 0, "empty run shape")
    events = tl.get("events")
    expect(isinstance(events, list), "'events' is not an array")
    expect(len(events) > 0, "timeline has no events")

    prev = None
    kinds = set()
    for i, e in enumerate(events):
        key = check_event(i, e, tl["apps"], tl["frames"])
        if prev is not None:
            expect(prev <= key, f"event {i}: out of canonical order ({prev} > {key})")
        prev = key
        kinds.add(e["kind"])
    expect("frame" in kinds, "no frame events traced")
    expect("alloc" in kinds, "no allocation events traced")

    print(
        f"validate_timeline: OK: {tl['source']} run, {tl['apps']} tenants, "
        f"{len(events)} events, kinds {sorted(kinds)}"
    )


if __name__ == "__main__":
    main()
