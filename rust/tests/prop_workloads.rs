//! Property tests over procedurally generated workloads and the
//! critical-path machinery they rely on (ISSUE: ≥200 seeded DAGs).
//!
//! * critical path is monotone in stage weights
//! * critical path equals the weight sum on chain graphs and the max on
//!   pure fan-out graphs
//! * `critical_path_nodes` is a real dependency path whose weight sum
//!   equals `critical_path`
//! * every generated pipeline validates, is series-parallel consistent
//!   (structured combine == critical path), has a calibrated feasible
//!   bound, and respects the paper's knob-semantics invariants
//! * `gen-dag` pipelines (general DAGs: multi-level fan-out, diamond
//!   joins, skip connections) stay *exact* under the critical-path
//!   combine over the declared group graph, cross-checked against the
//!   brute-force path enumeration
//! * `--drift` keeps every per-frame stage cost inside the configured
//!   walk band, on both generator families

use iptune::dataflow::critical_path::{critical_path_brute, critical_path_nodes};
use iptune::dataflow::{critical_path, Graph};
use iptune::learner::GroupMap;
use iptune::simulator::{Cluster, ClusterSim};
use iptune::trace::TraceSet;
use iptune::util::prop::{check, random_dag, unit_vec};
use iptune::workloads::{self, DagConfig, WorkloadConfig};

fn graph_from(deps: &[Vec<usize>]) -> Graph {
    let stages: Vec<(String, Vec<String>)> = deps
        .iter()
        .enumerate()
        .map(|(i, d)| (format!("s{i}"), d.iter().map(|&j| format!("s{j}")).collect()))
        .collect();
    Graph::new(&stages).unwrap()
}

// ---- critical-path properties on 200+ random DAGs -----------------------

#[test]
fn prop_critical_path_monotone_in_weights() {
    check("cp-monotone", 220, |rng, _| {
        let (deps, weights) = random_dag(rng, 12);
        let g = graph_from(&deps);
        let before = critical_path(&g, &weights);
        let mut bumped = weights.clone();
        let i = rng.below(bumped.len());
        bumped[i] += rng.range_f64(0.1, 20.0);
        let after = critical_path(&g, &bumped);
        assert!(
            after >= before - 1e-12,
            "raising w[{i}] shrank the critical path: {before} -> {after}"
        );
        // and lowering a weight never raises it
        let mut cut = weights.clone();
        cut[i] *= rng.f64();
        assert!(critical_path(&g, &cut) <= before + 1e-12);
    });
}

#[test]
fn prop_chain_critical_path_is_sum() {
    check("cp-chain-sum", 200, |rng, _| {
        let n = 1 + rng.below(12);
        let deps: Vec<Vec<usize>> =
            (0..n).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect();
        let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.1, 50.0)).collect();
        let g = graph_from(&deps);
        let sum: f64 = weights.iter().sum();
        assert!((critical_path(&g, &weights) - sum).abs() < 1e-9);
    });
}

#[test]
fn prop_pure_fanout_critical_path_is_max() {
    check("cp-fanout-max", 200, |rng, _| {
        // star: one source fanning out to k leaves
        let k = 1 + rng.below(10);
        let mut deps: Vec<Vec<usize>> = vec![vec![]];
        for _ in 0..k {
            deps.push(vec![0]);
        }
        let weights: Vec<f64> = (0..=k).map(|_| rng.range_f64(0.1, 50.0)).collect();
        let g = graph_from(&deps);
        let max_leaf = weights[1..].iter().cloned().fold(f64::MIN, f64::max);
        let want = weights[0] + max_leaf;
        assert!((critical_path(&g, &weights) - want).abs() < 1e-9);

        // fully disconnected nodes: plain max
        let free: Vec<Vec<usize>> = (0..=k).map(|_| vec![]).collect();
        let g2 = graph_from(&free);
        let max_all = weights.iter().cloned().fold(f64::MIN, f64::max);
        assert!((critical_path(&g2, &weights) - max_all).abs() < 1e-9);
    });
}

#[test]
fn prop_critical_path_nodes_consistent() {
    check("cp-nodes", 220, |rng, _| {
        let (deps, weights) = random_dag(rng, 12);
        let g = graph_from(&deps);
        let path = critical_path_nodes(&g, &weights);
        assert!(!path.is_empty() && path.len() <= g.len());
        // consecutive path entries are real connectors
        for pair in path.windows(2) {
            assert!(
                g.node(pair[1]).deps.contains(&pair[0]),
                "{:?} is not an edge",
                pair
            );
        }
        // the path's weight sum is exactly the critical-path length
        let len: f64 = path.iter().map(|&i| weights[i]).sum();
        let cp = critical_path(&g, &weights);
        assert!((len - cp).abs() < 1e-9, "path sum {len} vs cp {cp}");
        // which also matches brute force
        assert!((cp - critical_path_brute(&g, &weights)).abs() < 1e-9);
    });
}

// ---- generated-pipeline properties (200 seeds) --------------------------

#[test]
fn prop_generated_pipelines_are_valid_apps() {
    let cfg = WorkloadConfig::default();
    check("gen-valid", 200, |rng, case| {
        let app = workloads::generate(case as u64, &cfg);
        app.spec.validate().expect("generated spec validates");
        assert_eq!(app.graph.sources().len(), 1);
        assert_eq!(app.graph.sinks().len(), 1);
        assert_eq!(app.graph.len(), app.spec.stages.len());
        assert!(app.spec.num_vars() >= 3 && app.spec.num_vars() <= 6);
        let bound = app.spec.latency_bounds_ms[0];
        assert!(bound.is_finite() && bound > 0.0);

        // structured combine reproduces the critical path on a random knob
        let map = GroupMap::structured(&app.spec);
        let u = unit_vec(rng, app.spec.num_vars());
        let ks = app.spec.denormalize(&u);
        let content = app.model.content(rng.below(900));
        let stage_ms = app.stage_latencies(&ks, &content);
        assert!(stage_ms.iter().all(|&t| t > 0.0 && t.is_finite()));
        let e2e = critical_path(&app.graph, &stage_ms);
        let (y, offset) = map.targets(&stage_ms, e2e);
        assert!((map.combine(&y, offset) - e2e).abs() < 1e-9);

        // fidelity is a proper reward and the defaults are its argmax
        let best = app.model.fidelity(&app.spec.defaults(), &content);
        assert!((0.0..=1.0).contains(&best));
        let f = app.model.fidelity(&ks, &content);
        assert!((0.0..=1.0).contains(&f));
        assert!(f <= best + 1e-9, "random config beat the default corner");

        // denormalized knobs are valid (discrete ones integral, in range)
        for (p, &k) in app.spec.params.iter().zip(&ks) {
            assert!(k >= p.min && k <= p.max);
            if p.is_discrete() {
                assert_eq!(k, k.round());
            }
        }
    });
}

#[test]
fn prop_gen_dag_combine_equals_critical_path() {
    // the ISSUE-5 extension of `group_combine_reproduces_critical_path`:
    // on general DAGs the structured combine (critical path over the
    // declared group graph + source/sink offset) equals the simulator's
    // weighted critical path, itself cross-checked against brute force
    let cfg = WorkloadConfig { dag: Some(DagConfig::default()), ..Default::default() };
    check("gen-dag-exact", 200, |rng, case| {
        let app = workloads::generate(case as u64, &cfg);
        app.spec.validate().expect("generated DAG spec validates");
        assert_eq!(app.graph.sources().len(), 1);
        assert_eq!(app.graph.sinks().len(), 1);
        let map = GroupMap::structured(&app.spec);
        assert!(map.group_graph.is_some(), "gen-dag must declare the group DAG");
        let u = unit_vec(rng, app.spec.num_vars());
        let ks = app.spec.denormalize(&u);
        let content = app.model.content(rng.below(900));
        let stage_ms = app.stage_latencies(&ks, &content);
        assert!(stage_ms.iter().all(|&t| t > 0.0 && t.is_finite()));
        let e2e = critical_path(&app.graph, &stage_ms);
        assert!(
            (e2e - critical_path_brute(&app.graph, &stage_ms)).abs() < 1e-9,
            "case {case}: critical path disagrees with brute force"
        );
        let (y, offset) = map.targets(&stage_ms, e2e);
        let combined = map.combine(&y, offset);
        assert!(
            (combined - e2e).abs() < 1e-9,
            "case {case}: combined {combined} vs e2e {e2e}"
        );
    });
}

#[test]
fn prop_drift_keeps_costs_within_walk_bounds() {
    // --drift B: every per-frame stage cost moves by a factor inside
    // [1-B, 1+B] relative to the drift-free twin (which is otherwise
    // byte-identical — drift draws on an independent rng stream)
    check("gen-drift-band", 30, |rng, case| {
        let seed = case as u64 + 2100;
        let dag = case % 2 == 1;
        let bound = 0.15 + 0.05 * ((case % 3) as f64);
        let plain_cfg = WorkloadConfig {
            dag: dag.then(DagConfig::default),
            ..Default::default()
        };
        let drift_cfg = WorkloadConfig { drift: Some(bound), ..plain_cfg.clone() };
        let plain = workloads::generate(seed, &plain_cfg);
        let drifting = workloads::generate(seed, &drift_cfg);
        assert_eq!(plain.spec.params.len(), drifting.spec.params.len());
        let u = unit_vec(rng, plain.spec.num_vars());
        let ks = plain.spec.denormalize(&u);
        let mut sp = ClusterSim::deterministic(Cluster::default());
        let mut sd = ClusterSim::deterministic(Cluster::default());
        // sample frames inside and beyond the precomputed walk horizon
        let f = rng.below(3000);
        let rp = sp.run_frame(&plain, &ks, f);
        let rd = sd.run_frame(&drifting, &ks, f);
        for s in 0..rp.stage_ms.len() {
            let ratio = rd.stage_ms[s] / rp.stage_ms[s];
            assert!(
                ratio >= 1.0 - bound - 1e-9 && ratio <= 1.0 + bound + 1e-9,
                "case {case} frame {f} stage {s}: ratio {ratio} outside ±{bound}"
            );
        }
        assert_eq!(rp.fidelity, rd.fidelity, "drift is cost-only");
    });
}

#[test]
fn prop_generated_bounds_keep_a_feasible_region() {
    let cfg = WorkloadConfig::default();
    check("gen-feasible", 24, |_rng, case| {
        let seed = case as u64 * 13 + 1;
        let app = workloads::generate(seed, &cfg);
        let bound = app.spec.latency_bounds_ms[0];
        let costs = workloads::probe_costs(&app, &Cluster::default(), cfg.probe_configs, seed);
        let feasible = costs.iter().filter(|&&c| c <= bound).count() as f64;
        let frac = feasible / costs.len() as f64;
        assert!(
            frac >= 0.2,
            "seed {seed}: bound {bound} leaves only {frac} of the space feasible"
        );
    });
}

#[test]
fn prop_generated_traces_have_protocol_shape() {
    let cfg = WorkloadConfig::default();
    check("gen-traces", 12, |_rng, case| {
        let app = workloads::generate(case as u64 + 500, &cfg);
        let ts = TraceSet::generate(&app, 5, 30, 9);
        assert_eq!(ts.num_configs(), 5);
        assert_eq!(ts.num_frames(), 30);
        assert_eq!(ts.stage_names.len(), app.spec.stages.len());
        for t in &ts.traces {
            for f in t.frames.iter() {
                assert!(f.end_to_end_ms > 0.0);
                assert!((0.0..=1.0).contains(&f.fidelity));
                // e2e never exceeds the stage sum (series-parallel graphs)
                let sum: f64 = f.stage_ms.iter().sum();
                assert!(f.end_to_end_ms <= sum + 1e-9);
            }
        }
    });
}

#[test]
fn prop_generated_worker_requests_respect_grant_budget() {
    let cfg = WorkloadConfig::default();
    check("gen-workers", 40, |rng, case| {
        let app = workloads::generate(case as u64 + 900, &cfg);
        let sim = ClusterSim::deterministic(Cluster {
            servers: 2,
            cores_per_server: 4,
            comm_ms_per_frame: 0.0,
        });
        let u = unit_vec(rng, app.spec.num_vars());
        let ks = app.spec.denormalize(&u);
        let requested: Vec<usize> = (0..app.graph.len())
            .map(|s| app.model.requested_workers(s, &ks))
            .collect();
        assert!(requested.iter().all(|&w| w >= 1));
        let granted = sim.grant_workers(&requested);
        assert_eq!(granted.len(), requested.len());
        assert!(granted.iter().zip(&requested).all(|(&g, &r)| g <= r.max(1)));
    });
}

#[test]
fn prop_scale_knobs_trade_latency_for_fidelity() {
    // turning any scale knob up from the default must not raise cost and
    // must not raise fidelity (the monotone trade-off the tuner exploits)
    let cfg = WorkloadConfig::default();
    check("gen-scale-tradeoff", 30, |_rng, case| {
        let app = workloads::generate(case as u64 + 1300, &cfg);
        let content = app.model.content(10);
        let base = app.spec.defaults();
        let base_fid = app.model.fidelity(&base, &content);
        let base_cost: f64 = app.stage_latencies(&base, &content).iter().sum();
        for (k, p) in app.spec.params.iter().enumerate() {
            if !p.name.starts_with("scale_") {
                continue;
            }
            let mut scaled = base.clone();
            scaled[k] = p.max;
            let fid = app.model.fidelity(&scaled, &content);
            let cost: f64 = app.stage_latencies(&scaled, &content).iter().sum();
            assert!(fid <= base_fid + 1e-9, "scaling raised fidelity");
            assert!(cost <= base_cost + 1e-9, "scaling raised total cost");
        }
    });
}
