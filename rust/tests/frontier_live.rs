//! Live-path frontier protocol guarantees (ISSUE 6):
//!
//! * **Straggler isolation** — under an injected straggler, non-straggler
//!   tenants keep completing epochs at decision cadence under the
//!   frontier, while the legacy barrier collapses their cadence (a
//!   stalled boundary gulps their banked frames in bulk). The 3x
//!   threshold is validated against the Python behavioral mirror
//!   (`python/tests/test_frontier_mirror.py`), which simulates both
//!   protocols' epoch accounting over adversarial arrival schedules.
//! * **Frontier-ordered replay** — live reports are a pure function of
//!   `(seed, apps, frames)`: byte-identical across repeated runs, across
//!   real-time pacing (which perturbs OS thread interleavings), and even
//!   across injected source delays, because record content is pinned by
//!   the frame-indexed knob schedule and folds happen in (tenant, epoch,
//!   seq) order.

use iptune::scheduler::live::{run_live, LiveConfig};
use iptune::scheduler::SchedulerConfig;
use iptune::simulator::Cluster;

/// The seed-42 fleet from the acceptance criteria: 3 tenants, 300
/// frames, 30-frame epochs, tenant 0 is the (optional) straggler.
fn straggler_cfg(barrier: bool, delay_ms: f64) -> LiveConfig {
    LiveConfig {
        apps: 3,
        frames: 300,
        seed: 42,
        candidates: 10,
        heterogeneous: true,
        realtime_scale: 0.0,
        barrier,
        straggler: if delay_ms > 0.0 { Some((0, delay_ms)) } else { None },
        scheduler: SchedulerConfig { epoch_frames: 30, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn frontier_isolates_an_injected_straggler() {
    // tenant 0 sleeps 5ms of wall-clock per source frame; tenants 1-2
    // run at channel speed and finish their 300 frames long before the
    // straggler crosses its first epoch boundary
    let frontier = run_live(&straggler_cfg(false, 5.0)).unwrap();
    let barrier = run_live(&straggler_cfg(true, 5.0)).unwrap();
    assert_eq!(frontier.protocol, "frontier");
    assert_eq!(barrier.protocol, "barrier");
    // zero lost frames in both protocols
    for r in [&frontier, &barrier] {
        for a in &r.apps {
            assert_eq!(a.frames, 300, "{} app {} lost frames", r.protocol, a.index);
        }
    }
    // the frontier folds exactly one fresh epoch batch per tenant per
    // decision, so every tenant completes one epoch per decision; the
    // barrier fires only when the straggler crosses each boundary, by
    // which time the fast tenants' whole backlog folds at once and their
    // decision-cadence count collapses to ~1
    let decisions = frontier.allocations.len() - 1;
    assert!(decisions >= 8, "expected ~9 decisions, got {decisions}");
    for a in &frontier.apps {
        assert_eq!(a.completed_epochs, decisions, "frontier app {}", a.index);
    }
    for i in [1usize, 2] {
        let f = frontier.apps[i].completed_epochs;
        let b = barrier.apps[i].completed_epochs.max(1);
        assert!(
            f >= 3 * b,
            "non-straggler tenant {i}: frontier completed {f} epochs at decision \
             cadence vs barrier {b} — expected >= 3x isolation"
        );
    }
}

#[test]
fn frontier_reports_are_byte_identical_across_runs_and_pacing() {
    // admission pressure included: 12-core pool, floor 5 x 3 tenants
    // parks someone every epoch, exercising park/resume determinism
    let cfg = LiveConfig {
        apps: 3,
        frames: 150,
        seed: 42,
        candidates: 10,
        heterogeneous: true,
        realtime_scale: 0.0,
        cluster: Cluster { servers: 1, cores_per_server: 12, comm_ms_per_frame: 0.0 },
        scheduler: SchedulerConfig {
            epoch_frames: 30,
            fairness_floor: 5,
            admission_epoch: true,
            starvation_bound: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let base = run_live(&cfg).unwrap().to_json().to_string();

    // same config, fresh threads: the report is a pure function of it
    let again = run_live(&cfg).unwrap().to_json().to_string();
    assert_eq!(base, again, "repeated run diverged");

    // real-time pacing perturbs every thread interleaving but no record
    // content: byte-identical report
    let mut paced = cfg.clone();
    paced.realtime_scale = 1e-7;
    let paced = run_live(&paced).unwrap().to_json().to_string();
    assert_eq!(base, paced, "real-time pacing changed the report bytes");

    // an injected source delay is pure timing too: the frontier replay
    // folds the same records in the same order
    let mut slow = cfg.clone();
    slow.straggler = Some((2, 1.5));
    let slow = run_live(&slow).unwrap().to_json().to_string();
    assert_eq!(base, slow, "an injected straggler changed the report bytes");
}

#[test]
fn frontier_timelines_and_percentiles_are_byte_identical_across_pacing() {
    // the ISSUE 7 extension of the byte-identity bar: with tracing on,
    // the *timeline* is a pure function of the config too, and the
    // report now carries per-tenant latency percentiles
    let mut cfg = LiveConfig {
        apps: 3,
        frames: 150,
        seed: 42,
        candidates: 10,
        heterogeneous: true,
        realtime_scale: 0.0,
        cluster: Cluster { servers: 1, cores_per_server: 12, comm_ms_per_frame: 0.0 },
        scheduler: SchedulerConfig {
            epoch_frames: 30,
            fairness_floor: 5,
            admission_epoch: true,
            starvation_bound: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.trace_events = true;
    let base = run_live(&cfg).unwrap();
    let report = base.to_json().to_string();
    assert!(report.contains("\"latency_ms\""), "{report}");
    assert!(report.contains("\"epoch_latency_ms\""), "{report}");
    for a in &base.apps {
        let h = a.latency.total();
        assert_eq!(h.count(), a.frames as u64, "app {}", a.index);
        let (p50, p95, p99) = (
            h.quantile(0.50).unwrap(),
            h.quantile(0.95).unwrap(),
            h.quantile(0.99).unwrap(),
        );
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "app {}: {p50} {p95} {p99}", a.index);
    }
    let tl = base.timeline.as_ref().expect("trace_events captures a timeline");
    assert_eq!(tl.source, "live");
    assert!(
        tl.events.iter().any(|e| e.kind.name() == "frontier"),
        "frontier advances must be traced"
    );
    let base_tl = tl.to_json().to_string();

    let mut paced = cfg.clone();
    paced.realtime_scale = 1e-7;
    let paced = run_live(&paced).unwrap();
    assert_eq!(report, paced.to_json().to_string(), "pacing changed the report bytes");
    assert_eq!(
        base_tl,
        paced.timeline.as_ref().unwrap().to_json().to_string(),
        "pacing changed the timeline bytes"
    );

    let mut slow = cfg.clone();
    slow.straggler = Some((2, 1.5));
    let slow = run_live(&slow).unwrap();
    assert_eq!(report, slow.to_json().to_string(), "a straggler changed the report bytes");
    assert_eq!(
        base_tl,
        slow.timeline.as_ref().unwrap().to_json().to_string(),
        "a straggler changed the timeline bytes"
    );
}

#[test]
fn frontier_and_barrier_agree_on_frame_accounting_without_stragglers() {
    // with no straggler and no admission pressure the two protocols see
    // the same per-tenant frame totals (content differs: the barrier
    // latches knobs by wall clock, the frontier by frame index)
    let frontier = run_live(&straggler_cfg(false, 0.0)).unwrap();
    let barrier = run_live(&straggler_cfg(true, 0.0)).unwrap();
    for (f, b) in frontier.apps.iter().zip(&barrier.apps) {
        assert_eq!(f.frames, 300);
        assert_eq!(b.frames, 300);
        assert_eq!(f.parked_epochs, 0);
        assert_eq!(b.parked_epochs, 0);
    }
    assert_eq!(frontier.allocations.len(), barrier.allocations.len());
}
