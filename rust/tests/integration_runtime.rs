//! Integration: the XLA (PJRT, AOT-compiled HLO) backend against the
//! native Rust twin — the cross-layer correctness signal for the whole
//! AOT pipeline (Pallas kernel → JAX model → HLO text → PJRT execute).
//!
//! These tests are skipped gracefully when `make artifacts` has not run.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::Variant;
use iptune::runtime::manifest::find_artifact_dir;
use iptune::runtime::native::NativeBackend;
use iptune::runtime::xla::XlaBackend;
use iptune::runtime::Backend;
use iptune::util::Rng;

fn backends(app: &str, variant: Variant) -> Option<(NativeBackend, XlaBackend)> {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = app_by_name(app, spec_dir).unwrap();
    let Ok(artifact_dir) = find_artifact_dir(None) else {
        eprintln!("skipping: artifacts not built");
        return None;
    };
    let xla = match XlaBackend::new(&app.spec, variant, artifact_dir) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping: {e}");
            return None;
        }
    };
    Some((NativeBackend::new(&app.spec, variant, 3), xla))
}

fn rand_candidates(rng: &mut Rng, n: usize) -> Vec<Vec<f64>> {
    (0..n).map(|_| (0..5).map(|_| rng.f64()).collect()).collect()
}

/// Drive both backends through an identical observation stream and check
/// predictions agree to float32 tolerance at every step.
fn parity_case(app: &str, variant: Variant, seed: u64, steps: usize) {
    let Some((mut native, mut xla)) = backends(app, variant) else { return };
    let g = native.group_map().num_groups();
    let mut rng = Rng::new(seed);
    for t in 0..steps {
        let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        // plausible per-group latency targets in ms
        let y: Vec<f64> = (0..g).map(|_| rng.range_f64(2.0, 250.0)).collect();
        native.update(&u, &y);
        xla.update(&u, &y);
        let off = rng.range_f64(2.0, 12.0);
        native.observe_offset(off);
        xla.observe_offset(off);

        if t % 7 == 0 {
            let cands = rand_candidates(&mut rng, 9);
            let pn = native.predict(&cands);
            let px = xla.predict(&cands);
            for (i, (a, b)) in pn.iter().zip(&px).enumerate() {
                assert!(
                    (a - b).abs() < 0.35 + 1e-3 * a.abs().max(b.abs()),
                    "{app}/{variant:?} step {t} cand {i}: native {a} vs xla {b}"
                );
            }
        }
    }
}

#[test]
fn parity_pose_structured() {
    parity_case("pose", Variant::Structured, 1, 60);
}

#[test]
fn parity_pose_unstructured() {
    parity_case("pose", Variant::Unstructured, 2, 60);
}

#[test]
fn parity_motion_sift_structured() {
    parity_case("motion_sift", Variant::Structured, 3, 60);
}

#[test]
fn parity_motion_sift_unstructured() {
    parity_case("motion_sift", Variant::Unstructured, 4, 60);
}

#[test]
fn solve_parity_on_trained_models() {
    let Some((mut native, mut xla)) = backends("motion_sift", Variant::Structured) else {
        return;
    };
    let g = native.group_map().num_groups();
    let mut rng = Rng::new(9);
    // train both on the same stream
    for _ in 0..120 {
        let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        let y: Vec<f64> = (0..g)
            .map(|_| 20.0 + 150.0 * u[0] + 60.0 * rng.f64())
            .collect();
        native.update(&u, &y);
        xla.update(&u, &y);
        native.observe_offset(8.0);
        xla.observe_offset(8.0);
    }
    // solve over a shared candidate set for a sweep of bounds
    let cands = rand_candidates(&mut rng, 16);
    let rewards: Vec<f64> = (0..16).map(|i| 0.2 + 0.05 * (i as f64 % 7.0)).collect();
    for bound in [40.0, 80.0, 120.0, 200.0] {
        let a = native.solve(&cands, &rewards, bound);
        let b = xla.solve(&cands, &rewards, bound);
        // ties between equal rewards can legitimately differ; compare the
        // achieved (reward, feasibility) instead of indices
        let ca = native.predict(&cands)[a];
        let cb = xla.predict(&cands)[b];
        let feas_a = ca <= bound;
        let feas_b = cb <= bound + 0.35; // float32 edge tolerance
        assert_eq!(feas_a, feas_b, "bound {bound}: {ca} vs {cb}");
        if feas_a {
            assert!(
                (rewards[a] - rewards[b]).abs() < 1e-9,
                "bound {bound}: native picked r={}, xla r={}",
                rewards[a],
                rewards[b]
            );
        }
    }
}

#[test]
fn xla_weights_stay_in_subspace() {
    let Some((native, mut xla)) = backends("motion_sift", Variant::Structured) else {
        return;
    };
    drop(native);
    let mut rng = Rng::new(11);
    for _ in 0..40 {
        let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
        xla.update(&u, &[rng.range_f64(5.0, 300.0), rng.range_f64(5.0, 300.0)]);
    }
    // group 0 = face branch over vars {0,2,4}: any monomial touching vars
    // 1 or 3 must have zero weight. Group feature layout is the shared
    // graded-lex order over all 5 vars.
    let monos = iptune::learner::features::monomials_of(&[0, 1, 2, 3, 4], 3);
    let w = xla.weights();
    for (j, mono) in monos.iter().enumerate() {
        let touches_foreign = mono.iter().any(|&v| v == 1 || v == 3);
        if touches_foreign {
            assert_eq!(w[j], 0.0, "face-branch weight leaked into monomial {mono:?}");
        }
    }
}

#[test]
fn xla_reset_clears_state() {
    let Some((_, mut xla)) = backends("pose", Variant::Unstructured) else { return };
    xla.update(&[0.5; 5], &[120.0]);
    assert!(xla.weights().iter().any(|&w| w != 0.0));
    xla.reset();
    assert!(xla.weights().iter().all(|&w| w == 0.0));
    let c = xla.predict(&[vec![0.5; 5]]);
    assert_eq!(c[0], 0.0);
}

#[test]
fn xla_rejects_oversized_batch() {
    let Some((_, mut xla)) = backends("pose", Variant::Structured) else { return };
    let mut rng = Rng::new(13);
    let cands = rand_candidates(&mut rng, 65); // candidate_pad is 64
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        xla.predict(&cands)
    }));
    assert!(result.is_err(), "oversized batch must be rejected");
}
