//! General-DAG fleet acceptance (ISSUE 5): the scheduler stack runs
//! end-to-end on `gen-dag` workloads under slow cost drift —
//!
//! * on the seed-42 heterogeneous 8-app DAG fleet with a scripted load
//!   shift at frame 250 and ±15% per-stage cost drift, dynamic
//!   marginal-utility reallocation beats the static even slice on
//!   aggregate fidelity-vs-oracle at equal SLO health (mirror-validated:
//!   static 0.8417 vs dynamic 0.8584, 8/8 apps meeting the SLO in both
//!   modes, min post-warmup bound-met 0.917);
//! * reports stay byte-identical across worker-thread counts (the DAG
//!   combine and drift walk are pure functions of the seed and frame);
//! * epoch-granular admission with the demand-confidence term runs a
//!   DAG fleet through park/re-admit rotation without losing a tenant
//!   (the CI `dag-smoke` scenario, asserted here at test scale too).
//!
//! Thresholds validated via the /tmp/mirror Python behavioral mirror
//! extended with the DAG generator and drift walk (it reproduces PR 2's
//! recorded 0.7606/0.7909 series-parallel numbers exactly).

use std::sync::OnceLock;

use iptune::fleet::{run_fleet, FleetConfig, FleetMode, FleetReport};
use iptune::workloads::DagConfig;

/// The acceptance scenario: 8 co-tenant `gen-dag` apps on the paper's
/// 120-core cluster, alternating light/heavy profiles, heavy apps' costs
/// jumping 1.9x at frame 250, every stage cost drifting inside ±15%.
fn dag_cfg(mode: FleetMode) -> FleetConfig {
    let mut cfg = FleetConfig {
        apps: 8,
        frames: 500,
        seed: 42,
        configs_per_app: 16,
        threads: 0,
        mode,
        heterogeneous: true,
        load_shift_frame: Some(250),
        ..Default::default()
    };
    cfg.workload.dag = Some(DagConfig::default());
    cfg.workload.drift = Some(0.15);
    cfg
}

fn static_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&dag_cfg(FleetMode::Static)))
}

fn dynamic_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&dag_cfg(FleetMode::Dynamic)))
}

#[test]
fn dynamic_beats_static_on_dag_fleet_under_drift() {
    let stat = static_report();
    let dynamic = dynamic_report();

    // apples-to-apples: identical DAG apps and identical even-share
    // oracle yardsticks in both modes
    for (s, d) in stat.apps.iter().zip(&dynamic.apps) {
        assert_eq!(s.name, d.name);
        assert!(s.name.starts_with("gendag"), "{} is not a DAG app", s.name);
        assert_eq!(s.bound_ms, d.bound_ms, "{}", s.name);
        assert_eq!(s.oracle_fidelity, d.oracle_fidelity, "{}", s.name);
    }

    // headline: strictly higher aggregate fidelity-vs-oracle ...
    assert!(
        dynamic.avg_fidelity_vs_oracle > stat.avg_fidelity_vs_oracle,
        "dynamic {:.4} must beat static {:.4} on the DAG fleet",
        dynamic.avg_fidelity_vs_oracle,
        stat.avg_fidelity_vs_oracle
    );
    // ... at equal-or-better SLO compliance, with every app healthy
    assert!(dynamic.apps_meeting_slo >= stat.apps_meeting_slo);
    assert!(dynamic.all_apps_meet_slo(), "min {:.3}", dynamic.min_bound_met_frac);
    assert!(stat.all_apps_meet_slo(), "min {:.3}", stat.min_bound_met_frac);

    // the win comes from actual reallocation
    let even = stat.cores_per_app;
    assert!(
        dynamic.allocations.iter().any(|a| a.cores.iter().any(|&c| c != even)),
        "dynamic mode never reallocated"
    );
    assert!(
        dynamic.apps.iter().any(|a| (a.avg_cores - even as f64).abs() > 0.5),
        "no app's average quota moved off the even share"
    );
    assert!(stat.allocations.iter().all(|a| a.cores.iter().all(|&c| c == even)));
}

#[test]
fn dag_fleet_allocations_respect_budget_and_rungs() {
    for report in [static_report(), dynamic_report()] {
        assert!(!report.allocations.is_empty());
        for alloc in &report.allocations {
            assert!(
                alloc.total_cores() <= report.total_cores,
                "epoch {} oversubscribes: {:?}",
                alloc.epoch,
                alloc.cores
            );
            assert!(alloc.cores.iter().all(|c| report.levels.contains(c)));
            assert!(alloc.cores.iter().all(|&c| c >= report.fairness_floor));
        }
        // the fleet ran real general DAGs: every tenant declares branches
        // through the group graph, not the legacy branch ids
        for a in &report.apps {
            assert!(a.stages >= 4, "{} too small", a.name);
            assert!(a.avg_fidelity.is_finite() && a.fidelity_vs_oracle.is_finite());
        }
    }
}

#[test]
fn dag_fleet_reports_identical_across_thread_counts() {
    let mut one = dag_cfg(FleetMode::Dynamic);
    one.frames = 200;
    one.configs_per_app = 8;
    one.threads = 1;
    let mut four = one.clone();
    four.threads = 4;
    let a = run_fleet(&one);
    let b = run_fleet(&four);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "DAG fleet report must be a pure function of (seed, apps, frames)"
    );
}

#[test]
fn dag_epoch_admission_with_demand_confidence_rotates_and_scores_everyone() {
    // the CI dag-smoke scenario at test scale: 6 DAG tenants demanding a
    // 30-core floor on 120 cores (floor x apps = 180 > 120) under
    // epoch-granular admission with a 3-epoch starvation bound and the
    // demand-confidence term. Mirror-validated: 2 re-admissions, parked
    // epochs rotate [0,0,2,3,2,3], every tenant runs and every scored
    // tenant clears the SLO.
    let mut cfg = FleetConfig {
        apps: 6,
        frames: 240,
        seed: 42,
        configs_per_app: 8,
        threads: 0,
        mode: FleetMode::Dynamic,
        heterogeneous: true,
        ..Default::default()
    };
    cfg.workload.dag = Some(DagConfig::default());
    cfg.workload.drift = Some(0.15);
    cfg.scheduler.fairness_floor = 30;
    cfg.scheduler.admission_epoch = true;
    cfg.scheduler.starvation_bound = 3;
    cfg.scheduler.demand_confidence = 2;
    let report = run_fleet(&cfg);
    assert_eq!(report.apps.len(), 6);
    // nobody is parked whole-run; parking rotates instead
    assert_eq!(report.parked_apps, 0, "a tenant never ran");
    assert!(report.park_transitions > 0, "admission never rotated");
    assert!(report.parked_app_epochs > 0, "admission never parked anyone");
    assert_eq!(report.scored_apps, 6);
    assert!(
        report.all_apps_meet_slo(),
        "min bound-met {:.3}",
        report.min_bound_met_frac
    );
    for alloc in &report.allocations {
        assert!(alloc.total_cores() <= report.total_cores, "epoch {}", alloc.epoch);
        for (c, &p) in alloc.cores.iter().zip(&alloc.parked) {
            if p {
                assert_eq!(*c, 0);
            } else {
                assert!(*c >= 1);
            }
        }
    }
    // rotation honors the 3-epoch starvation bound
    let mut streak = vec![0usize; 6];
    for alloc in &report.allocations {
        for i in 0..6 {
            if alloc.parked[i] {
                streak[i] += 1;
                assert!(streak[i] <= 3, "app {i} parked {} > bound 3", streak[i]);
            } else {
                streak[i] = 0;
            }
        }
    }
}
