//! Integration: the full trace → learn → tune pipeline on both apps —
//! the end-to-end controller behavior the paper's Sec. 4.4 evaluates.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::deps::analyze;
use iptune::learner::offline::{fit, mean_abs_error, samples_from_traces};
use iptune::learner::{StagePredictor, Variant};
use iptune::runtime::native::NativeBackend;
use iptune::trace::TraceSet;
use iptune::tuner::policy::{best_fixed_action, oracle_best};
use iptune::tuner::{EpsGreedyController, TunerConfig};
use iptune::util::testdir::TestDir;

fn setup(name: &str, configs: usize, frames: usize, seed: u64) -> (iptune::apps::App, TraceSet) {
    let app = app_by_name(name, find_spec_dir(None).unwrap()).unwrap();
    let traces = TraceSet::generate(&app, configs, frames, seed);
    (app, traces)
}

#[test]
fn tuner_beats_best_fixed_feasible_action_or_close() {
    // the whole point of online tuning: at the paper's eps = 1/sqrt(T) the
    // controller should be competitive with the best static configuration
    for (name, bound) in [("pose", 60.0), ("motion_sift", 120.0)] {
        let (app, traces) = setup(name, 25, 500, 21);
        let eps = TunerConfig::epsilon_for_horizon(1000);
        let backend = NativeBackend::structured(&app.spec);
        let cfg = TunerConfig { epsilon: eps, bound_ms: bound, warmup_frames: 25 };
        let mut ctl =
            EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 5);
        let out = ctl.run(1000);
        let (_, fixed) = best_fixed_action(&traces, bound);
        assert!(
            out.avg_reward > fixed.avg_reward * 0.8,
            "{name}: tuned {} vs best-fixed {}",
            out.avg_reward,
            fixed.avg_reward
        );
    }
}

#[test]
fn ninety_percent_of_oracle_at_three_percent_exploration() {
    // headline claim (C1) on the motion_sift app with its spec bound
    let (app, traces) = setup("motion_sift", 30, 1000, 7);
    let bound = app.spec.latency_bounds_ms[0];
    let eps = TunerConfig::epsilon_for_horizon(1000); // ~0.03
    let backend = NativeBackend::structured(&app.spec);
    let cfg = TunerConfig { epsilon: eps, bound_ms: bound, warmup_frames: 25 };
    let mut ctl = EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 5);
    let out = ctl.run(1000);
    let oracle = oracle_best(&traces, 1000, bound);
    let ratio = out.avg_reward / oracle.avg_reward;
    assert!(
        ratio >= 0.85,
        "reward {} is {:.1}% of oracle {}",
        out.avg_reward,
        100.0 * ratio,
        oracle.avg_reward
    );
}

#[test]
fn trace_roundtrip_preserves_controller_behavior() {
    let (app, traces) = setup("pose", 10, 120, 3);
    let dir = TestDir::new("pipeline");
    let path = dir.join("t.json");
    traces.save(&path).unwrap();
    let reloaded = TraceSet::load(&path).unwrap();

    let run = |ts: &TraceSet| {
        let backend = NativeBackend::structured(&app.spec);
        let cfg = TunerConfig { epsilon: 0.1, bound_ms: 70.0, warmup_frames: 10 };
        let mut ctl = EpsGreedyController::new(&app.spec, ts, Box::new(backend), cfg, 9);
        let out = ctl.run(120);
        (out.avg_reward, out.avg_violation_ms)
    };
    let a = run(&traces);
    let b = run(&reloaded);
    assert!((a.0 - b.0).abs() < 1e-9, "reward drifted through serialization");
    assert!((a.1 - b.1).abs() < 1e-6, "violation drifted through serialization");
}

#[test]
fn dependency_analysis_feeds_consistent_structure() {
    // end-to-end Sec. 2.3 story: analysis recovers knob associations that
    // the spec's declared groups rely on
    let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
    let a = analyze(&app, 36, 17);
    for g in &app.spec.groups {
        for &p in &g.params {
            let hit = g.stages.iter().any(|sn| {
                let s = app.spec.stage_index(sn).unwrap();
                a.correlation[s][p] >= iptune::learner::deps::CORRELATION_THRESHOLD
            });
            assert!(hit, "group {} knob {p} not recovered", g.name);
        }
    }
}

#[test]
fn offline_fit_close_to_noise_floor_on_pose() {
    let (app, traces) = setup("pose", 15, 150, 31);
    let samples = samples_from_traces(&app.spec, &traces);
    let mut pred = fit(&app.spec, Variant::Structured, 3, &samples, 25, 1);
    let err = mean_abs_error(&mut pred, &samples);
    let scale: f64 =
        samples.iter().map(|s| s.end_to_end_ms).sum::<f64>() / samples.len() as f64;
    assert!(err < scale * 0.25, "offline err {err} vs scale {scale}");
}

#[test]
fn predictor_adapts_after_scene_change() {
    // C4: error bumps at frame 600, then falls again as the model adapts
    let (app, traces) = setup("pose", 15, 900, 41);
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| app.spec.normalize(c)).collect();
    let mut pred = StagePredictor::new(&app.spec, Variant::Structured, 3);
    let mut rng = iptune::util::Rng::new(2);
    let mut errs = Vec::new();
    for t in 0..900 {
        let a = rng.below(candidates.len());
        let rec = traces.frame(a, t);
        let before = pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
        errs.push((before - rec.end_to_end_ms).abs());
    }
    let win = |lo: usize, hi: usize| errs[lo..hi].iter().sum::<f64>() / (hi - lo) as f64;
    let before = win(520, 590);
    let at_change = win(600, 650);
    let adapted = win(780, 880);
    assert!(at_change > before, "no bump at scene change: {before} -> {at_change}");
    assert!(adapted < at_change, "no re-adaptation: {at_change} -> {adapted}");
}

#[test]
fn cli_binary_spec_smoke() {
    // the `repro` binary prints the Tables 1-2 rows
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = std::process::Command::new(exe)
        .args(["spec", "pose"])
        .output()
        .expect("run repro spec");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("K1"));
    assert!(text.contains("The degree of image scaling"));
    assert!(text.contains("2147483648"));
}

#[test]
fn cli_binary_graph_dot() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = std::process::Command::new(exe)
        .args(["spec", "motion-sift", "--graph"])
        .output()
        .expect("run repro spec --graph");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("digraph"));
    assert!(text.contains("\"copy\" -> \"face_scale\""));
    assert!(text.contains("\"motion_extract\" -> \"filter_agg\""));
}
