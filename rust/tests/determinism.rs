//! Determinism guarantees: identical seeds reproduce byte-identical
//! traces and identical controller outcomes — on the hand-written apps
//! and on procedurally generated ones — and the multi-threaded fleet
//! report is a pure function of its seed.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::fleet::{run_fleet, FleetConfig};
use iptune::runtime::native::NativeBackend;
use iptune::trace::TraceSet;
use iptune::tuner::{EpsGreedyController, TunerConfig};
use iptune::util::testdir::TestDir;
use iptune::workloads::{self, WorkloadConfig};

fn apps_under_test() -> Vec<iptune::apps::App> {
    let dir = find_spec_dir(None).unwrap();
    vec![
        app_by_name("pose", &dir).unwrap(),
        app_by_name("motion_sift", &dir).unwrap(),
        workloads::generate(7, &WorkloadConfig::default()),
        workloads::generate(1234, &WorkloadConfig::default()),
    ]
}

#[test]
fn trace_sets_are_byte_identical_across_runs() {
    for app in apps_under_test() {
        let a = TraceSet::generate(&app, 6, 50, 99);
        let b = TraceSet::generate(&app, 6, 50, 99);
        assert_eq!(
            a.to_json().to_string(),
            b.to_json().to_string(),
            "{}: same seed must give byte-identical traces",
            app.spec.name
        );
        let c = TraceSet::generate(&app, 6, 50, 100);
        assert_ne!(
            a.to_json().to_string(),
            c.to_json().to_string(),
            "{}: different seed must change the traces",
            app.spec.name
        );
    }
}

#[test]
fn trace_files_are_byte_identical_on_disk() {
    let dir = TestDir::new("determinism");
    for app in apps_under_test() {
        let ts1 = TraceSet::generate(&app, 4, 30, 5);
        let ts2 = TraceSet::generate(&app, 4, 30, 5);
        let p1 = dir.join(&format!("{}_a.json", app.spec.name));
        let p2 = dir.join(&format!("{}_b.json", app.spec.name));
        ts1.save(&p1).unwrap();
        ts2.save(&p2).unwrap();
        let b1 = std::fs::read(&p1).unwrap();
        let b2 = std::fs::read(&p2).unwrap();
        assert_eq!(b1, b2, "{}: on-disk trace bytes differ", app.spec.name);
    }
}

#[test]
fn controller_outcomes_identical_across_runs() {
    for app in apps_under_test() {
        let traces = TraceSet::generate(&app, 10, 120, 3);
        let bound = app.spec.latency_bounds_ms[0];
        let run = |seed: u64| {
            let backend = NativeBackend::structured(&app.spec);
            let cfg = TunerConfig { epsilon: 0.1, bound_ms: bound, warmup_frames: 10 };
            let mut ctl =
                EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, seed);
            ctl.run(120)
        };
        let a = run(17);
        let b = run(17);
        assert_eq!(a.explore_frames, b.explore_frames, "{}", app.spec.name);
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.action, sb.action, "{}: action diverged", app.spec.name);
            assert_eq!(sa.explored, sb.explored);
            assert_eq!(sa.latency_ms, sb.latency_ms);
            assert_eq!(sa.reward, sb.reward);
            assert_eq!(sa.predicted_ms, sb.predicted_ms);
        }
        // a different controller seed must actually change the trajectory
        let c = run(18);
        assert!(
            a.steps.iter().zip(&c.steps).any(|(x, y)| x.action != y.action),
            "{}: controller seed had no effect",
            app.spec.name
        );
    }
}

#[test]
fn generated_apps_identical_across_runs() {
    // generation itself is a pure function of the seed: spec tables and
    // model outputs agree element-wise
    let cfg = WorkloadConfig::default();
    for seed in [0u64, 7, 19, 255] {
        let a = workloads::generate(seed, &cfg);
        let b = workloads::generate(seed, &cfg);
        assert_eq!(a.spec.latency_bounds_ms, b.spec.latency_bounds_ms);
        assert_eq!(a.spec.num_vars(), b.spec.num_vars());
        for (pa, pb) in a.spec.params.iter().zip(&b.spec.params) {
            assert_eq!(pa.name, pb.name);
            assert_eq!((pa.min, pa.max, pa.default, pa.log), (pb.min, pb.max, pb.default, pb.log));
        }
        for (sa, sb) in a.spec.stages.iter().zip(&b.spec.stages) {
            assert_eq!(sa.name, sb.name);
            assert_eq!(sa.deps, sb.deps);
            assert_eq!(sa.params, sb.params);
        }
        for frame in [0usize, 100, 650] {
            let ca = a.model.content(frame);
            let cb = b.model.content(frame);
            assert_eq!(ca, cb);
            let ks = a.spec.defaults();
            assert_eq!(a.stage_latencies(&ks, &ca), b.stage_latencies(&ks, &cb));
            assert_eq!(a.model.fidelity(&ks, &ca), b.model.fidelity(&ks, &cb));
        }
    }
}

#[test]
fn fleet_report_is_seed_deterministic() {
    let cfg = FleetConfig {
        apps: 2,
        frames: 80,
        seed: 11,
        configs_per_app: 8,
        threads: 2,
        ..Default::default()
    };
    let a = run_fleet(&cfg);
    let b = run_fleet(&cfg);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());

    let mut other = cfg.clone();
    other.seed = 12;
    let c = run_fleet(&other);
    assert_ne!(a.to_json().to_string(), c.to_json().to_string());
}
