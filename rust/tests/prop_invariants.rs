//! Property-based invariants (in-tree harness: `iptune::util::prop`).
//!
//! * critical path == brute force, and ≥ any single path, on random DAGs
//! * normalization round-trips for arbitrary knob values
//! * GroupMap targets + combine are consistent with the critical path
//! * the solver never picks a predicted-infeasible action when a
//!   predicted-feasible one exists
//! * convex hulls contain every input point; mixture frontier dominates
//!   pure strategies
//! * the engine loses no frames and keeps them in order under random
//!   queue capacities (routing/batching invariants)

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::dataflow::{critical_path, critical_path::critical_path_brute, Graph};
use iptune::learner::{GroupMap, Variant};
use iptune::metrics::hull::{best_mixture_reward, convex_hull, hull_contains};
use iptune::runtime::native::NativeBackend;
use iptune::runtime::Backend;
use iptune::util::prop::{check, random_dag, unit_vec};

fn graph_from(deps: &[Vec<usize>]) -> Graph {
    let stages: Vec<(String, Vec<String>)> = deps
        .iter()
        .enumerate()
        .map(|(i, d)| {
            (format!("s{i}"), d.iter().map(|&j| format!("s{j}")).collect())
        })
        .collect();
    Graph::new(&stages).unwrap()
}

#[test]
fn prop_critical_path_matches_brute_force() {
    check("critical-path-brute", 80, |rng, _| {
        let (deps, weights) = random_dag(rng, 10);
        let g = graph_from(&deps);
        let fast = critical_path(&g, &weights);
        let brute = critical_path_brute(&g, &weights);
        assert!((fast - brute).abs() < 1e-9, "{fast} vs {brute}");
    });
}

#[test]
fn prop_critical_path_dominates_random_walks() {
    check("critical-path-dominates", 40, |rng, _| {
        let (deps, weights) = random_dag(rng, 10);
        let g = graph_from(&deps);
        let cp = critical_path(&g, &weights);
        // random downstream walk from a random source
        let succ = g.successors();
        let mut node = g.sources()[rng.below(g.sources().len())];
        let mut acc = weights[node];
        while !succ[node].is_empty() {
            node = succ[node][rng.below(succ[node].len())];
            acc += weights[node];
        }
        assert!(cp >= acc - 1e-9, "cp {cp} < path {acc}");
    });
}

#[test]
fn prop_normalize_denormalize_valid() {
    let spec_dir = find_spec_dir(None).unwrap();
    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir).unwrap();
        check("normalize-roundtrip", 60, |rng, _| {
            let u = unit_vec(rng, app.spec.num_vars());
            let ks = app.spec.denormalize(&u);
            for (p, &k) in app.spec.params.iter().zip(&ks) {
                assert!(k >= p.min && k <= p.max);
                if p.is_discrete() {
                    assert_eq!(k, k.round());
                }
            }
            // re-normalizing stays in [0,1]
            for v in app.spec.normalize(&ks) {
                assert!((-1e-9..=1.0 + 1e-9).contains(&v));
            }
        });
    }
}

#[test]
fn prop_group_targets_consistent_with_critical_path() {
    // structured targets + combine must reproduce the end-to-end latency
    // when fed the exact per-stage values (up to the offset moving-average
    // semantics, which we bypass by feeding the true offset)
    let spec_dir = find_spec_dir(None).unwrap();
    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir).unwrap();
        let map = GroupMap::structured(&app.spec);
        check("targets-combine", 60, |rng, _| {
            // run the true cost model on a random config to get stage times
            let u = unit_vec(rng, app.spec.num_vars());
            let ks = app.spec.denormalize(&u);
            let content = app.model.content(rng.below(900));
            let stage_ms = app.stage_latencies(&ks, &content);
            let e2e = critical_path(&app.graph, &stage_ms);
            let (y, offset) = map.targets(&stage_ms, e2e);
            let combined = map.combine(&y, offset);
            // combine sums per-group stage latencies along the critical
            // path; for these graphs it must equal the true e2e
            assert!(
                (combined - e2e).abs() < 1e-9,
                "{name}: combined {combined} vs e2e {e2e}"
            );
        });
    }
}

#[test]
fn prop_solver_feasibility() {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = app_by_name("pose", &spec_dir).unwrap();
    check("solver-feasibility", 25, |rng, case| {
        let mut backend = NativeBackend::structured(&app.spec);
        // random training
        for _ in 0..80 {
            let u = unit_vec(rng, 5);
            let y: Vec<f64> = (0..4).map(|_| rng.range_f64(1.0, 200.0)).collect();
            backend.update(&u, &y);
        }
        let cands: Vec<Vec<f64>> = (0..12).map(|_| unit_vec(rng, 5)).collect();
        let rewards: Vec<f64> = (0..12).map(|_| rng.f64()).collect();
        let costs = backend.predict(&cands);
        let bound = costs[case % 12].max(1.0); // ensures >=1 feasible
        let pick = backend.solve(&cands, &rewards, bound);
        assert!(costs[pick] <= bound + 1e-9, "picked infeasible");
        for (i, &c) in costs.iter().enumerate() {
            if c <= bound {
                assert!(rewards[pick] >= rewards[i] - 1e-12);
            }
        }
    });
}

#[test]
fn prop_hull_contains_inputs_and_frontier_dominates() {
    check("hull", 50, |rng, _| {
        let n = 3 + rng.below(40);
        let pts: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.range_f64(0.0, 50.0), rng.f64()))
            .collect();
        let hull = convex_hull(&pts);
        for &p in &pts {
            assert!(hull_contains(&hull, p), "{p:?} escaped its hull");
        }
        // mixture frontier at x >= max violation equals the best reward
        let best = pts.iter().map(|p| p.1).fold(f64::MIN, f64::max);
        let frontier = best_mixture_reward(&pts, 60.0);
        assert!((frontier - best).abs() < 1e-9);
        // frontier is monotone in the violation budget
        let lo = best_mixture_reward(&pts, 1.0);
        let hi = best_mixture_reward(&pts, 10.0);
        assert!(hi >= lo - 1e-12);
    });
}

#[test]
fn prop_engine_no_frame_lost_any_capacity() {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = std::sync::Arc::new(app_by_name("motion_sift", &spec_dir).unwrap());
    check("engine-no-loss", 6, |rng, case| {
        let cap = 1 + rng.below(6);
        let frames = 15 + rng.below(25);
        let recs = iptune::engine::run_stream_blocking(
            std::sync::Arc::clone(&app),
            app.spec.defaults(),
            iptune::engine::EngineConfig {
                frames,
                queue_capacity: cap,
                seed: case as u64,
                ..Default::default()
            },
        );
        assert_eq!(recs.len(), frames, "cap {cap}");
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.frame, i, "out-of-order delivery");
            assert!(r.stage_ms.iter().all(|&x| x > 0.0));
        }
    });
}

#[test]
fn prop_ogd_prediction_error_bounded_after_training() {
    // after T observations of a bounded target, predictions on the
    // training distribution stay within a sane multiple of the range
    check("ogd-bounded", 20, |rng, _| {
        let mut reg = iptune::learner::OgdRegressor::new(&[0, 1, 2], 3);
        for _ in 0..300 {
            let u = unit_vec(rng, 3);
            let y = rng.range_f64(10.0, 300.0);
            reg.update(&u, y);
        }
        for _ in 0..50 {
            let u = unit_vec(rng, 3);
            let p = reg.predict(&u);
            assert!(
                (-200.0..800.0).contains(&p),
                "prediction {p} blew past the target range"
            );
        }
    });
}

#[test]
fn prop_variant_feature_counts() {
    let spec_dir = find_spec_dir(None).unwrap();
    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir).unwrap();
        let s = GroupMap::for_variant(&app.spec, Variant::Structured);
        let u = GroupMap::for_variant(&app.spec, Variant::Unstructured);
        // structured compact space is never larger than unstructured
        assert!(s.feature_count(3) <= u.feature_count(3) + 16, "{name}");
        assert_eq!(u.feature_count(3), 56);
    }
}
