//! Integration: the streaming data-flow engine + closed-loop retuning —
//! the live system of paper Sec. 2 ("changes in parameter settings are
//! then applied to the running application").

use std::sync::Arc;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::engine::{run_stream_blocking, spawn_stream, EngineConfig};
use iptune::runtime::native::NativeBackend;
use iptune::runtime::Backend;
use iptune::util::Rng;

fn app(name: &str) -> Arc<iptune::apps::App> {
    Arc::new(app_by_name(name, find_spec_dir(None).unwrap()).unwrap())
}

#[test]
fn closed_loop_tuner_brings_stream_under_bound() {
    // start at defaults (way over bound), learn online from the live
    // stream, retune every 20 frames; by the end the pipe must run under
    // the bound most of the time
    let a = app("pose");
    let bound = 60.0;
    let frames = 400;
    let handle = spawn_stream(
        Arc::clone(&a),
        a.spec.defaults(),
        EngineConfig { frames, seed: 4, ..Default::default() },
    );

    let mut backend = NativeBackend::structured(&a.spec);
    let mut rng = Rng::new(23);
    let mut candidates: Vec<Vec<f64>> = (0..48)
        .map(|_| (0..a.spec.num_vars()).map(|_| rng.f64()).collect())
        .collect();
    candidates.push(a.spec.normalize(&a.spec.defaults()));
    let content = a.model.content(0);
    let rewards: Vec<f64> = candidates
        .iter()
        .map(|u| a.model.fidelity(&a.spec.denormalize(u), &content))
        .collect();

    let mut tail_over = 0usize;
    let mut tail_n = 0usize;
    while let Ok(rec) = handle.records.recv() {
        let u = a.spec.normalize(&rec.knobs);
        let (y, off) = backend.group_map().targets(&rec.stage_ms, rec.end_to_end_ms);
        backend.update(&u, &y);
        backend.observe_offset(off);
        if rec.frame % 20 == 19 {
            let pick = backend.solve(&candidates, &rewards, bound);
            handle.set_knobs(a.spec.denormalize(&candidates[pick]));
        }
        if rec.frame >= frames - 150 {
            tail_n += 1;
            if rec.end_to_end_ms > bound {
                tail_over += 1;
            }
        }
    }
    assert!(tail_n > 0);
    let rate = tail_over as f64 / tail_n as f64;
    assert!(rate < 0.3, "tail over-bound rate {rate} (bound {bound} ms)");
}

#[test]
fn stream_fidelity_matches_model() {
    let a = app("motion_sift");
    let ks = vec![2.0, 2.0, 1.0, 8.0, 8.0];
    let recs = run_stream_blocking(
        Arc::clone(&a),
        ks.clone(),
        EngineConfig { frames: 30, ..Default::default() },
    );
    for r in &recs {
        let want = a.model.fidelity(&ks, &a.model.content(r.frame));
        assert!((r.fidelity - want).abs() < 1e-12);
    }
}

#[test]
fn branch_stages_overlap_in_stream() {
    // virtual time must reflect branch parallelism: e2e < sum of stages
    let a = app("motion_sift");
    let recs = run_stream_blocking(
        Arc::clone(&a),
        a.spec.defaults(),
        EngineConfig { frames: 15, ..Default::default() },
    );
    for r in &recs {
        let sum: f64 = r.stage_ms.iter().sum();
        assert!(r.end_to_end_ms < sum - 1.0, "no overlap: {} vs {}", r.end_to_end_ms, sum);
    }
}

#[test]
fn realtime_pacing_slows_wallclock() {
    let a = app("pose");
    let t0 = std::time::Instant::now();
    let _ = run_stream_blocking(
        Arc::clone(&a),
        vec![3.0, 1e6, 16.0, 10.0, 10.0],
        EngineConfig { frames: 20, realtime_scale: 0.0, ..Default::default() },
    );
    let fast = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = run_stream_blocking(
        Arc::clone(&a),
        vec![3.0, 1e6, 16.0, 10.0, 10.0],
        EngineConfig { frames: 20, realtime_scale: 2e-4, ..Default::default() },
    );
    let paced = t1.elapsed();
    assert!(paced > fast, "pacing must cost wall-clock: {fast:?} vs {paced:?}");
}

#[test]
fn engine_cli_demo_smoke() {
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = std::process::Command::new(exe)
        .args(["engine", "--app", "pose", "--frames", "120", "--period", "30"])
        .output()
        .expect("run repro engine");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("retune to"));
    assert!(text.contains("engine demo complete"));
}
