//! Fleet-scheduler guarantees: determinism (byte-identical reports for a
//! fixed `(seed, apps, frames)` regardless of thread count), safety
//! (allocations never oversubscribe the shared cluster; every app keeps
//! its fairness-floor cores), and the headline acceptance claims —
//!
//! * PR 2: on a heterogeneous 8-app fleet with a scripted load shift,
//!   dynamic marginal-utility reallocation beats the static even slice
//!   on aggregate fidelity-vs-oracle at equal-or-better SLO compliance;
//! * scheduler v2: on the same seed-42 fleet with thrash-inducing noisy
//!   curves, hysteresis cuts steady-state reallocation churn by ≥50%
//!   against the PR 2 greedy baseline without losing aggregate fidelity
//!   (within 1%), and an over-subscribed fleet (`floor × apps > pool`)
//!   parks its lowest-priority tenants instead of over-granting — with
//!   zero epochs whose granted cores exceed the pool.
//!
//! The full-size runs are shared across tests via `OnceLock` (the
//! reports are pure functions of the config, which is what the
//! determinism tests assert in the first place).

use std::sync::OnceLock;

use iptune::fleet::{
    run_fleet, FleetConfig, FleetMode, FleetReport, FLEET_SLO_FRAC, LOAD_DROP_MULT,
};
use iptune::simulator::Cluster;

/// The acceptance scenario: 8 co-tenant apps on the paper's 120-core
/// cluster, alternating light/heavy profiles, heavy apps' costs jumping
/// 1.9x at frame 250. Both modes run the same seeds, apps, ladder traces
/// and controllers — only the allocation policy differs.
fn hetero_cfg(mode: FleetMode) -> FleetConfig {
    FleetConfig {
        apps: 8,
        frames: 400,
        seed: 42,
        configs_per_app: 16,
        threads: 0,
        mode,
        heterogeneous: true,
        load_shift_frame: Some(250),
        ..Default::default()
    }
}

fn static_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&hetero_cfg(FleetMode::Static)))
}

fn dynamic_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&hetero_cfg(FleetMode::Dynamic)))
}

#[test]
fn dynamic_beats_static_on_heterogeneous_fleet() {
    let stat = static_report();
    let dynamic = dynamic_report();

    // the comparison is apples-to-apples: identical apps and yardsticks
    for (s, d) in stat.apps.iter().zip(&dynamic.apps) {
        assert_eq!(s.name, d.name);
        assert_eq!(s.bound_ms, d.bound_ms);
        assert_eq!(s.oracle_fidelity, d.oracle_fidelity, "{}", s.name);
    }

    // headline: strictly higher aggregate fidelity-vs-oracle ...
    assert!(
        dynamic.avg_fidelity_vs_oracle > stat.avg_fidelity_vs_oracle,
        "dynamic {:.4} must beat static {:.4}",
        dynamic.avg_fidelity_vs_oracle,
        stat.avg_fidelity_vs_oracle
    );
    // ... at equal-or-better post-warmup SLO compliance
    assert!(
        dynamic.apps_meeting_slo >= stat.apps_meeting_slo,
        "SLO compliance regressed: {} vs {}",
        dynamic.apps_meeting_slo,
        stat.apps_meeting_slo
    );
    assert!(
        dynamic.all_apps_meet_slo(),
        "dynamic mode must keep every app above {FLEET_SLO_FRAC}: min bound-met {:.3}",
        dynamic.min_bound_met_frac
    );
    assert!(stat.all_apps_meet_slo(), "static baseline must itself be healthy");

    // the win must come from actual reallocation, not noise: some epoch
    // moved cores off the even share, and some app held a different
    // average quota than the even share
    let even = stat.cores_per_app;
    assert!(
        dynamic.allocations.iter().any(|a| a.cores.iter().any(|&c| c != even)),
        "dynamic mode never reallocated"
    );
    assert!(
        dynamic.apps.iter().any(|a| (a.avg_cores - even as f64).abs() > 0.5),
        "no app's average quota moved off the even share"
    );
    // static mode, through the same machinery, never moves
    assert!(stat.allocations.iter().all(|a| a.cores.iter().all(|&c| c == even)));
}

#[test]
fn allocations_respect_budget_and_fairness_floor() {
    for report in [static_report(), dynamic_report()] {
        assert!(!report.allocations.is_empty());
        for alloc in &report.allocations {
            assert!(
                alloc.total_cores() <= report.total_cores,
                "epoch {} oversubscribes: {:?}",
                alloc.epoch,
                alloc.cores
            );
            assert!(
                alloc.cores.iter().all(|&c| c >= report.fairness_floor),
                "epoch {} starves an app below the {}-core floor: {:?}",
                alloc.epoch,
                report.fairness_floor,
                alloc.cores
            );
            assert_eq!(alloc.cores.len(), 8);
            // every quota sits on a ladder rung
            assert!(alloc.cores.iter().all(|c| report.levels.contains(c)));
        }
        // floor sanity: half the even share by default
        assert_eq!(report.fairness_floor, report.cores_per_app / 2);
    }
}

#[test]
fn fleet_report_identical_across_thread_counts() {
    // the cached report ran with threads = 0 (one per available core);
    // a single-threaded run must produce byte-identical JSON
    let mut one = hetero_cfg(FleetMode::Dynamic);
    one.threads = 1;
    let a = run_fleet(&one);
    assert_eq!(
        a.to_json().to_string(),
        dynamic_report().to_json().to_string(),
        "fleet report must be a pure function of (seed, apps, frames)"
    );
}

#[test]
fn fleet_report_seed_sensitivity() {
    let mut cfg = hetero_cfg(FleetMode::Dynamic);
    cfg.frames = 150;
    cfg.configs_per_app = 8;
    cfg.threads = 2;
    let a = run_fleet(&cfg);
    let b = run_fleet(&cfg);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    let mut other = cfg.clone();
    other.seed = 43;
    let c = run_fleet(&other);
    assert_ne!(
        a.to_json().to_string(),
        c.to_json().to_string(),
        "different seeds must change the report"
    );
}

/// The v2 acceptance scenario: the seed-42 heterogeneous 8-app fleet
/// with the adversarial thrash workload family layered on (3x content
/// wobble at 3x the frequency), so the learned utility curves are noisy
/// and the PR 2 greedy water-filler has something to thrash over.
/// `hysteresis == 0.0` IS the PR 2 greedy baseline (`allocate_v2`
/// reduces to it bit-for-bit); `hysteresis > 0.0` is v2. The 3x level
/// is deliberately moderate: bound calibration is worst-case-aware, so
/// cranking the wobble much higher *loosens* every bound until the
/// utility curves flatten and nobody reallocates at all (validated via
/// the Python behavioral mirror: steady-state churn 107 for greedy vs
/// 32 for v2 at any hysteresis in [0.06, 0.15], aggregate
/// fidelity-vs-oracle 0.843 vs 0.840).
fn thrash_cfg(hysteresis: f64) -> FleetConfig {
    let mut cfg = hetero_cfg(FleetMode::Dynamic);
    cfg.workload.thrash = Some(3.0);
    cfg.scheduler.hysteresis = hysteresis;
    cfg
}

fn greedy_thrash_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&thrash_cfg(0.0)))
}

fn v2_thrash_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&thrash_cfg(0.1)))
}

/// Steady-state reallocation churn: core movement across epochs after
/// the first post-warmup decision. The initial move off the warmup even
/// share is a *desired* reallocation every policy makes; churn is what
/// happens after, when noisy curves invite pointless migration.
fn steady_state_churn(report: &FleetReport) -> usize {
    let first_dynamic = 2; // warmup epoch 0, first decision epoch 1
    report
        .allocations
        .iter()
        .skip(first_dynamic)
        .map(|a| a.churn_cores)
        .sum()
}

#[test]
fn v2_hysteresis_cuts_churn_without_losing_fidelity() {
    let greedy = greedy_thrash_report();
    let v2 = v2_thrash_report();

    // apples-to-apples: identical apps, traces, and oracle yardsticks
    for (a, b) in greedy.apps.iter().zip(&v2.apps) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.oracle_fidelity, b.oracle_fidelity, "{}", a.name);
    }

    let churn_greedy = steady_state_churn(greedy);
    let churn_v2 = steady_state_churn(v2);
    assert!(
        churn_greedy > 0,
        "the greedy baseline must thrash under noisy curves, else the \
         scenario is not adversarial enough (churn {churn_greedy})"
    );
    // headline: >= 50% churn cut ...
    assert!(
        churn_v2 * 2 <= churn_greedy,
        "v2 churn {churn_v2} must be <= half of greedy churn {churn_greedy}"
    );
    // ... without losing aggregate fidelity-vs-oracle (within 1%)
    assert!(
        v2.avg_fidelity_vs_oracle >= greedy.avg_fidelity_vs_oracle - 0.01,
        "v2 fidelity {:.4} lost more than 1% vs greedy {:.4}",
        v2.avg_fidelity_vs_oracle,
        greedy.avg_fidelity_vs_oracle
    );
    // hysteresis must not freeze the allocator solid: the scripted load
    // shift is a real gain and still reallocates
    assert!(
        v2.allocations.iter().any(|a| a.cores.iter().any(|&c| c != v2.cores_per_app)),
        "v2 never reallocated at all"
    );
    // both runs stay inside the budget at every epoch
    for report in [greedy, v2] {
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
        }
    }
}

/// The over-subscribed fleet: 4 apps demanding a 4-core floor on a
/// 10-core pool (`floor × apps = 16 > 10`). Admission control must park
/// the two lowest-priority tenants (ties park the higher index) rather
/// than over-grant, and no epoch may exceed the pool.
fn oversubscribed_cfg(threads: usize) -> FleetConfig {
    let mut cfg = FleetConfig {
        apps: 4,
        frames: 120,
        seed: 42,
        configs_per_app: 8,
        threads,
        mode: FleetMode::Dynamic,
        cluster: Cluster { servers: 1, cores_per_server: 10, comm_ms_per_frame: 0.0 },
        ..Default::default()
    };
    cfg.scheduler.fairness_floor = 4;
    cfg.scheduler.admission = true; // implies exact accounting (workload_of)
    cfg.scheduler.priorities = vec![1.0, 1.0, 0.5, 2.0];
    cfg
}

#[test]
fn oversubscribed_fleet_parks_lowest_priority_instead_of_overgranting() {
    let report = run_fleet(&oversubscribed_cfg(2));
    assert_eq!(report.apps.len(), 4);
    assert_eq!(report.parked_apps, 2);
    // app 2 (priority 0.5) parks first; the 1.0-tie parks the higher
    // index (app 1); app 3 (priority 2.0) and app 0 run
    let parked: Vec<bool> = report.apps.iter().map(|a| a.admitted_frames == 0).collect();
    assert_eq!(parked, vec![false, true, true, false]);
    let epochs = report.allocations.len();
    for a in &report.apps {
        if a.admitted_frames == 0 {
            assert_eq!(a.dropped_frames, 120, "parked app {} must drop all frames", a.index);
            assert_eq!(a.parked_epochs, epochs, "v1 parking is whole-run");
            assert_eq!(a.scored_frames, 0);
            assert_eq!(a.avg_cores, 0.0);
            assert_eq!(a.avg_fidelity, 0.0);
        } else {
            assert_eq!(a.dropped_frames, 0);
            assert_eq!(a.parked_epochs, 0);
            assert_eq!(a.admitted_frames, 120);
            assert!(a.avg_cores >= 4.0, "admitted app {} below floor", a.index);
        }
    }
    // whole-run parking never transitions park state mid-run, and the SLO
    // denominator is the apps with scorable frames
    assert_eq!(report.park_transitions, 0);
    assert_eq!(report.scored_apps, 2);
    // zero epochs where granted cores exceed the pool, parked apps at
    // exactly zero, admitted apps at or above the requested floor
    assert!(!report.allocations.is_empty());
    for alloc in &report.allocations {
        assert!(
            alloc.total_cores() <= report.total_cores,
            "epoch {} oversubscribes: {:?}",
            alloc.epoch,
            alloc.cores
        );
        assert_eq!(alloc.parked, vec![false, true, true, false]);
        assert_eq!(alloc.cores[1], 0);
        assert_eq!(alloc.cores[2], 0);
        assert!(alloc.cores[0] >= 4 && alloc.cores[3] >= 4, "{:?}", alloc.cores);
    }
    // the SLO gate scores admitted tenants; parking is reported, not hidden
    assert!(report.apps_meeting_slo <= 2);
}

#[test]
fn v2_reports_identical_across_thread_counts() {
    // the satellite determinism check: a v2 fleet (admission + parking +
    // priorities + exact accounting) must stay byte-identical however
    // many worker threads carry it
    let a = run_fleet(&oversubscribed_cfg(1));
    let b = run_fleet(&oversubscribed_cfg(4));
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "v2 fleet report must be a pure function of (seed, apps, frames)"
    );
    // and hysteresis runs are deterministic too
    let mut h1 = thrash_cfg(0.1);
    h1.frames = 150;
    h1.configs_per_app = 8;
    h1.threads = 1;
    let mut h2 = h1.clone();
    h2.threads = 3;
    assert_eq!(
        run_fleet(&h1).to_json().to_string(),
        run_fleet(&h2).to_json().to_string()
    );
}

#[test]
fn priorities_decide_who_is_admitted() {
    // the same over-subscribed fleet with the tiers rotated: a different
    // pair of tenants survives admission — priorities are not cosmetic.
    // (The water-filling tilt itself is pinned down deterministically by
    // the allocator unit test `priority_weights_tilt_contested_cores`;
    // at fleet scale a tier only moves cores when the tiered app's curve
    // has contested marginal gains to scale.)
    let mut cfg = oversubscribed_cfg(2);
    cfg.scheduler.priorities = vec![2.0, 1.0, 1.0, 0.5];
    let report = run_fleet(&cfg);
    let parked: Vec<bool> = report.apps.iter().map(|a| a.admitted_frames == 0).collect();
    assert_eq!(parked, vec![false, false, true, true]);
    for alloc in &report.allocations {
        assert!(alloc.total_cores() <= report.total_cores);
        assert!(alloc.cores[0] >= 4 && alloc.cores[1] >= 4, "{:?}", alloc.cores);
    }
}

#[test]
fn static_and_dynamic_identical_through_warmup() {
    // during the warmup epochs both modes pin the even share, so the two
    // reports' first allocation frames agree exactly
    let stat = static_report();
    let dynamic = dynamic_report();
    assert_eq!(stat.allocations[0].cores, dynamic.allocations[0].cores);
    assert_eq!(stat.levels, dynamic.levels);
    assert_eq!(stat.cores_per_app, dynamic.cores_per_app);
}

/// The scheduler-v3 acceptance scenario: the seed-42 heterogeneous 8-app
/// fleet on the paper's 120-core cluster with a 20-core requested floor
/// (floor × apps = 160 > 120 → over-subscribed) and a scripted load
/// *drop* (heavy apps' costs fall to 0.55x at frame 200). Whole-run (v1)
/// admission parks two tenants for all 600 frames; epoch-granular
/// admission re-admits parked tenants as demands shrink and rotates
/// parking under the 3-epoch starvation bound. Thresholds validated via
/// the full-fleet Python behavioral mirror (seed 42: whole-run aggregate
/// fidelity-vs-oracle 0.6115 with 6/6 admitted meeting the SLO; epoch
/// mode 0.6921 with 8/8 scored meeting it, 5 re-admissions, max
/// consecutive parked epochs 3).
fn load_drop_cfg(epoch_granular: bool) -> FleetConfig {
    let mut cfg = FleetConfig {
        apps: 8,
        frames: 600,
        seed: 42,
        configs_per_app: 8,
        threads: 0,
        mode: FleetMode::Dynamic,
        heterogeneous: true,
        load_shift_frame: Some(200),
        load_shift_mult: LOAD_DROP_MULT,
        ..Default::default()
    };
    cfg.scheduler.fairness_floor = 20;
    if epoch_granular {
        cfg.scheduler.admission_epoch = true;
        cfg.scheduler.starvation_bound = 3;
    } else {
        cfg.scheduler.admission = true;
    }
    cfg
}

fn whole_run_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&load_drop_cfg(false)))
}

fn epoch_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&load_drop_cfg(true)))
}

#[test]
fn epoch_admission_readmits_and_beats_whole_run_parking() {
    let whole = whole_run_report();
    let epoch = epoch_report();

    // apples-to-apples: identical tenants and identical even-share
    // yardsticks for every tenant both flavors actually ran
    for (w, e) in whole.apps.iter().zip(&epoch.apps) {
        assert_eq!(w.name, e.name);
        assert_eq!(w.bound_ms, e.bound_ms, "{}", w.name);
        if w.admitted_frames > 0 {
            assert_eq!(w.oracle_fidelity, e.oracle_fidelity, "{}", w.name);
        }
    }

    // the v1 baseline parks two tenants for the whole run
    assert_eq!(whole.parked_apps, 2);
    assert_eq!(whole.park_transitions, 0);
    assert!(whole.all_apps_meet_slo(), "baseline must be healthy");

    // epoch-granular admission: nobody is parked whole-run — every tenant
    // runs (and is scored), because parked tenants are re-admitted
    assert_eq!(epoch.parked_apps, 0, "a tenant stayed parked all run");
    assert_eq!(epoch.scored_apps, 8);
    assert!(
        epoch.apps.iter().all(|a| a.admitted_frames > 0),
        "every tenant must run some frames"
    );
    // ... with at least one literal re-admission (parked at epoch e,
    // admitted at e+1) visible in the allocation record
    let readmissions: usize = epoch
        .allocations
        .windows(2)
        .map(|w| {
            w[0].parked
                .iter()
                .zip(&w[1].parked)
                .filter(|(&was, &now)| was && !now)
                .count()
        })
        .sum();
    assert!(readmissions >= 1, "no parked tenant was ever re-admitted");
    assert!(epoch.park_transitions > 0);
    assert!(epoch.parked_app_epochs > 0, "admission never parked anyone");

    // headline: higher aggregate fidelity-vs-oracle at equal SLO health
    assert!(
        epoch.avg_fidelity_vs_oracle > whole.avg_fidelity_vs_oracle,
        "epoch-granular {:.4} must beat whole-run parking {:.4}",
        epoch.avg_fidelity_vs_oracle,
        whole.avg_fidelity_vs_oracle
    );
    assert!(
        epoch.all_apps_meet_slo(),
        "every scored tenant must clear the {FLEET_SLO_FRAC} SLO: min bound-met {:.3}",
        epoch.min_bound_met_frac
    );
    assert!(epoch.apps_meeting_slo >= whole.apps_meeting_slo);

    // equal priorities: rotation keeps every tenant's consecutive parked
    // epochs within the configured starvation bound
    let mut streak = vec![0usize; 8];
    for alloc in &epoch.allocations {
        assert!(alloc.total_cores() <= epoch.total_cores, "epoch {}", alloc.epoch);
        for i in 0..8 {
            if alloc.parked[i] {
                streak[i] += 1;
                assert!(
                    streak[i] <= 3,
                    "app {i} parked {} consecutive epochs (> bound 3)",
                    streak[i]
                );
            } else {
                streak[i] = 0;
            }
        }
    }

    // per-epoch accounting adds up: dropped frames are parked epochs'
    // frames, and admitted + dropped covers the whole run
    for a in &epoch.apps {
        assert_eq!(a.admitted_frames + a.dropped_frames, 600, "app {}", a.index);
        assert_eq!(a.dropped_frames, a.parked_epochs * 50, "app {}", a.index);
    }
}

#[test]
fn epoch_admission_reports_identical_across_thread_counts() {
    // rotation + re-admission is scheduler state, not worker state: the
    // report must stay a pure function of (seed, apps, frames)
    let mut one = load_drop_cfg(true);
    one.frames = 200;
    one.configs_per_app = 6;
    one.threads = 1;
    let mut four = one.clone();
    four.threads = 4;
    let a = run_fleet(&one);
    let b = run_fleet(&four);
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "epoch-granular admission must be thread-count independent"
    );
}

#[test]
fn tier_shift_preempts_a_seat_at_the_shift_epoch() {
    // 4 tenants x 4-core floor on 10 cores (capacity 2), equal priorities;
    // at frame 60 app 2 is upgraded to a 6.0 tier. Before the shift it is
    // parked; from the first epoch at/after the shift it holds a seat.
    let mut cfg = FleetConfig {
        apps: 4,
        frames: 150,
        seed: 42,
        configs_per_app: 6,
        threads: 2,
        mode: FleetMode::Dynamic,
        heterogeneous: true,
        cluster: Cluster { servers: 1, cores_per_server: 10, comm_ms_per_frame: 0.0 },
        ..Default::default()
    };
    cfg.scheduler.epoch_frames = 30;
    cfg.scheduler.fairness_floor = 4;
    cfg.scheduler.admission_epoch = true;
    cfg.scheduler.starvation_bound = 8;
    cfg.scheduler.tier_shift = Some((60, vec![1.0, 1.0, 6.0, 1.0]));
    let report = run_fleet(&cfg);
    assert_eq!(report.apps.len(), 4);
    for alloc in &report.allocations {
        assert!(alloc.total_cores() <= report.total_cores, "epoch {}", alloc.epoch);
        if alloc.start_frame < 60 {
            assert!(alloc.parked[2], "app 2 admitted before its upgrade: {alloc:?}");
        } else {
            assert!(!alloc.parked[2], "upgraded app 2 parked after the shift: {alloc:?}");
        }
    }
    let app2 = &report.apps[2];
    assert!(app2.admitted_frames > 0 && app2.parked_epochs > 0);
    // the preemption is a real park/unpark transition on the cluster
    assert!(report.park_transitions >= 2, "{}", report.park_transitions);
}
