//! Fleet-scheduler guarantees: determinism (byte-identical reports for a
//! fixed `(seed, apps, frames)` regardless of thread count), safety
//! (allocations never oversubscribe the shared cluster; every app keeps
//! its fairness-floor cores), and the headline acceptance claim — on a
//! heterogeneous 8-app fleet with a scripted load shift, dynamic
//! marginal-utility reallocation beats the static even slice on
//! aggregate fidelity-vs-oracle at equal-or-better SLO compliance.
//!
//! The two full-size runs are shared across tests via `OnceLock` (the
//! reports are pure functions of the config, which is what the
//! determinism tests assert in the first place).

use std::sync::OnceLock;

use iptune::fleet::{run_fleet, FleetConfig, FleetMode, FleetReport, FLEET_SLO_FRAC};

/// The acceptance scenario: 8 co-tenant apps on the paper's 120-core
/// cluster, alternating light/heavy profiles, heavy apps' costs jumping
/// 1.9x at frame 250. Both modes run the same seeds, apps, ladder traces
/// and controllers — only the allocation policy differs.
fn hetero_cfg(mode: FleetMode) -> FleetConfig {
    FleetConfig {
        apps: 8,
        frames: 400,
        seed: 42,
        configs_per_app: 16,
        threads: 0,
        mode,
        heterogeneous: true,
        load_shift_frame: Some(250),
        ..Default::default()
    }
}

fn static_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&hetero_cfg(FleetMode::Static)))
}

fn dynamic_report() -> &'static FleetReport {
    static R: OnceLock<FleetReport> = OnceLock::new();
    R.get_or_init(|| run_fleet(&hetero_cfg(FleetMode::Dynamic)))
}

#[test]
fn dynamic_beats_static_on_heterogeneous_fleet() {
    let stat = static_report();
    let dynamic = dynamic_report();

    // the comparison is apples-to-apples: identical apps and yardsticks
    for (s, d) in stat.apps.iter().zip(&dynamic.apps) {
        assert_eq!(s.name, d.name);
        assert_eq!(s.bound_ms, d.bound_ms);
        assert_eq!(s.oracle_fidelity, d.oracle_fidelity, "{}", s.name);
    }

    // headline: strictly higher aggregate fidelity-vs-oracle ...
    assert!(
        dynamic.avg_fidelity_vs_oracle > stat.avg_fidelity_vs_oracle,
        "dynamic {:.4} must beat static {:.4}",
        dynamic.avg_fidelity_vs_oracle,
        stat.avg_fidelity_vs_oracle
    );
    // ... at equal-or-better post-warmup SLO compliance
    assert!(
        dynamic.apps_meeting_slo >= stat.apps_meeting_slo,
        "SLO compliance regressed: {} vs {}",
        dynamic.apps_meeting_slo,
        stat.apps_meeting_slo
    );
    assert!(
        dynamic.all_apps_meet_slo(),
        "dynamic mode must keep every app above {FLEET_SLO_FRAC}: min bound-met {:.3}",
        dynamic.min_bound_met_frac
    );
    assert!(stat.all_apps_meet_slo(), "static baseline must itself be healthy");

    // the win must come from actual reallocation, not noise: some epoch
    // moved cores off the even share, and some app held a different
    // average quota than the even share
    let even = stat.cores_per_app;
    assert!(
        dynamic.allocations.iter().any(|a| a.cores.iter().any(|&c| c != even)),
        "dynamic mode never reallocated"
    );
    assert!(
        dynamic.apps.iter().any(|a| (a.avg_cores - even as f64).abs() > 0.5),
        "no app's average quota moved off the even share"
    );
    // static mode, through the same machinery, never moves
    assert!(stat.allocations.iter().all(|a| a.cores.iter().all(|&c| c == even)));
}

#[test]
fn allocations_respect_budget_and_fairness_floor() {
    for report in [static_report(), dynamic_report()] {
        assert!(!report.allocations.is_empty());
        for alloc in &report.allocations {
            assert!(
                alloc.total_cores() <= report.total_cores,
                "epoch {} oversubscribes: {:?}",
                alloc.epoch,
                alloc.cores
            );
            assert!(
                alloc.cores.iter().all(|&c| c >= report.fairness_floor),
                "epoch {} starves an app below the {}-core floor: {:?}",
                alloc.epoch,
                report.fairness_floor,
                alloc.cores
            );
            assert_eq!(alloc.cores.len(), 8);
            // every quota sits on a ladder rung
            assert!(alloc.cores.iter().all(|c| report.levels.contains(c)));
        }
        // floor sanity: half the even share by default
        assert_eq!(report.fairness_floor, report.cores_per_app / 2);
    }
}

#[test]
fn fleet_report_identical_across_thread_counts() {
    // the cached report ran with threads = 0 (one per available core);
    // a single-threaded run must produce byte-identical JSON
    let mut one = hetero_cfg(FleetMode::Dynamic);
    one.threads = 1;
    let a = run_fleet(&one);
    assert_eq!(
        a.to_json().to_string(),
        dynamic_report().to_json().to_string(),
        "fleet report must be a pure function of (seed, apps, frames)"
    );
}

#[test]
fn fleet_report_seed_sensitivity() {
    let mut cfg = hetero_cfg(FleetMode::Dynamic);
    cfg.frames = 150;
    cfg.configs_per_app = 8;
    cfg.threads = 2;
    let a = run_fleet(&cfg);
    let b = run_fleet(&cfg);
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    let mut other = cfg.clone();
    other.seed = 43;
    let c = run_fleet(&other);
    assert_ne!(
        a.to_json().to_string(),
        c.to_json().to_string(),
        "different seeds must change the report"
    );
}

#[test]
fn static_and_dynamic_identical_through_warmup() {
    // during the warmup epochs both modes pin the even share, so the two
    // reports' first allocation frames agree exactly
    let stat = static_report();
    let dynamic = dynamic_report();
    assert_eq!(stat.allocations[0].cores, dynamic.allocations[0].cores);
    assert_eq!(stat.levels, dynamic.levels);
    assert_eq!(stat.cores_per_app, dynamic.cores_per_app);
}
