//! Golden convergence tests for the OGD learner (paper Sec. 3.2–3.3).
//!
//! * On a synthetic linear latency function the regressor's prediction
//!   error falls below a fixed threshold within a fixed update budget.
//! * Structure-aware (per-group, per-stage-target) learning converges in
//!   fewer frames than the monolithic end-to-end model — the Sec. 3.3 /
//!   Fig. 7 claim — on the two-branch MotionSIFT app and on a generated
//!   multi-branch pipeline.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::apps::App;
use iptune::learner::{OgdRegressor, StagePredictor, Variant};
use iptune::simulator::{Cluster, ClusterSim};
use iptune::util::Rng;
use iptune::workloads::{self, WorkloadConfig};

/// Fixed budget/threshold constants of the golden linear case.
const LINEAR_BUDGET: usize = 2000;
const LINEAR_MEAN_THRESHOLD_MS: f64 = 5.0;
const LINEAR_WORST_THRESHOLD_MS: f64 = 10.0;

#[test]
fn golden_linear_latency_converges_within_budget() {
    // y = 20 + 30*u0 - 10*u1 ms: realizable by the degree-1 expansion, so
    // the error must sink into the eps-insensitive zone within the budget
    let mut reg = OgdRegressor::new(&[0, 1], 1);
    let mut rng = Rng::new(0);
    let f = |u: &[f64]| 20.0 + 30.0 * u[0] - 10.0 * u[1];
    for _ in 0..LINEAR_BUDGET {
        let u = [rng.f64(), rng.f64()];
        reg.update(&u, f(&u));
    }
    let mut sum = 0.0;
    let mut worst = 0.0f64;
    let probes = 100;
    for i in 0..probes {
        for j in 0..2 {
            let u = [i as f64 / (probes - 1) as f64, j as f64];
            let e = (reg.predict(&u) - f(&u)).abs();
            sum += e;
            worst = worst.max(e);
        }
    }
    let mean = sum / (2 * probes) as f64;
    assert!(
        mean < LINEAR_MEAN_THRESHOLD_MS,
        "mean |err| {mean} ms after {LINEAR_BUDGET} updates"
    );
    assert!(
        worst < LINEAR_WORST_THRESHOLD_MS,
        "worst |err| {worst} ms after {LINEAR_BUDGET} updates"
    );
}

#[test]
fn golden_linear_error_shrinks_with_budget() {
    // the same stream probed at increasing budgets: error must decrease
    let f = |u: &[f64]| 50.0 + 60.0 * u[0];
    let err_after = |budget: usize| {
        let mut reg = OgdRegressor::new(&[0], 1);
        let mut rng = Rng::new(3);
        for _ in 0..budget {
            let u = [rng.f64()];
            reg.update(&u, f(&u));
        }
        let mut sum = 0.0;
        for i in 0..50 {
            let u = [i as f64 / 49.0];
            sum += (reg.predict(&u) - f(&u)).abs();
        }
        sum / 50.0
    };
    let early = err_after(50);
    let mid = err_after(400);
    let late = err_after(2000);
    assert!(mid < early, "400-update error {mid} vs 50-update {early}");
    assert!(late <= mid + 1e-9, "2000-update error {late} vs 400-update {mid}");
    assert!(late < 4.0, "converged error {late} ms too high");
}

/// Drive both predictor variants over the same deterministic frame
/// stream; returns per-frame absolute end-to-end prediction errors.
fn error_series(app: &App, variant: Variant, frames: usize) -> Vec<f64> {
    let mut sim = ClusterSim::deterministic(Cluster::default());
    let mut pred = StagePredictor::new(&app.spec, variant, 3);
    let mut rng = Rng::new(1234);
    let mut errs = Vec::with_capacity(frames);
    for t in 0..frames {
        let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
        let ks = app.spec.denormalize(&u);
        let r = sim.run_frame(app, &ks, t % 500);
        let before = pred.observe(&app.spec.normalize(&ks), &r.stage_ms, r.end_to_end_ms);
        errs.push((before - r.end_to_end_ms).abs());
    }
    errs
}

/// First frame at which the trailing-`window` mean of `errs` drops below
/// `threshold`; `None` if it never does.
fn frames_to_threshold(errs: &[f64], window: usize, threshold: f64) -> Option<usize> {
    if errs.len() < window {
        return None;
    }
    let mut sum: f64 = errs[..window].iter().sum();
    if sum / window as f64 <= threshold {
        return Some(window - 1);
    }
    for i in window..errs.len() {
        sum += errs[i] - errs[i - window];
        if sum / window as f64 <= threshold {
            return Some(i);
        }
    }
    None
}

fn mean_latency(app: &App, frames: usize) -> f64 {
    let mut sim = ClusterSim::deterministic(Cluster::default());
    let mut rng = Rng::new(1234);
    let mut sum = 0.0;
    for t in 0..frames {
        let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
        let ks = app.spec.denormalize(&u);
        sum += sim.run_frame(app, &ks, t % 500).end_to_end_ms;
    }
    sum / frames as f64
}

fn assert_structured_converges_faster(app: &App) {
    const FRAMES: usize = 600;
    const WINDOW: usize = 50;
    let scale = mean_latency(app, 200);
    let s = error_series(app, Variant::Structured, FRAMES);
    let u = error_series(app, Variant::Unstructured, FRAMES);

    // cumulative error after the shared cold-start: structured lower
    let cum_s: f64 = s[50..400].iter().sum();
    let cum_u: f64 = u[50..400].iter().sum();
    assert!(
        cum_s < cum_u,
        "{}: structured cumulative error {cum_s:.1} !< unstructured {cum_u:.1}",
        app.spec.name
    );

    // frames-to-threshold: structured reaches the band no later
    let threshold = 0.20 * scale;
    let conv_s = frames_to_threshold(&s, WINDOW, threshold);
    let conv_u = frames_to_threshold(&u, WINDOW, threshold);
    assert!(
        conv_s.is_some(),
        "{}: structured never reached {threshold:.1} ms trailing error",
        app.spec.name
    );
    if let (Some(fs), Some(fu)) = (conv_s, conv_u) {
        assert!(
            fs <= fu,
            "{}: structured converged at {fs}, unstructured earlier at {fu}",
            app.spec.name
        );
    }
}

#[test]
fn structured_beats_monolithic_on_motion_sift() {
    let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
    assert_structured_converges_faster(&app);
}

#[test]
fn structured_beats_monolithic_on_generated_branchy_app() {
    // first generated pipeline with >= 2 parallel branches
    let cfg = WorkloadConfig::default();
    let app = (0u64..50)
        .map(|seed| workloads::generate(seed, &cfg))
        .find(|a| a.spec.branches().len() >= 2)
        .expect("a multi-branch pipeline exists in the first 50 seeds");
    assert_structured_converges_faster(&app);
}
