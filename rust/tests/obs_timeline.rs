//! Deterministic-tracing guarantees (ISSUE 7): a captured timeline is a
//! pure function of the run config — byte-identical across worker thread
//! counts (fleet) — its events arrive in canonical logical-clock order,
//! the artifact round-trips through JSON, and the always-on per-epoch
//! histograms in the reports agree with the traced frame events.

use iptune::fleet::{run_fleet, FleetConfig, FleetMode};
use iptune::obs::{sort_events, EventKind, Timeline};
use iptune::scheduler::SchedulerConfig;
use iptune::util::Json;

fn traced_cfg(threads: usize) -> FleetConfig {
    FleetConfig {
        apps: 3,
        frames: 120,
        seed: 42,
        configs_per_app: 10,
        threads,
        mode: FleetMode::Dynamic,
        heterogeneous: true,
        scheduler: SchedulerConfig { epoch_frames: 30, ..Default::default() },
        trace_events: true,
        ..Default::default()
    }
}

#[test]
fn fleet_timeline_is_byte_identical_across_thread_counts() {
    let base = run_fleet(&traced_cfg(1));
    let report1 = base.to_json().to_string();
    let tl1 = base.timeline.as_ref().unwrap().to_json().to_string();
    for threads in [2usize, 4] {
        let r = run_fleet(&traced_cfg(threads));
        assert_eq!(
            report1,
            r.to_json().to_string(),
            "report bytes diverged at {threads} threads"
        );
        assert_eq!(
            tl1,
            r.timeline.as_ref().unwrap().to_json().to_string(),
            "timeline bytes diverged at {threads} threads"
        );
    }
}

#[test]
fn fleet_timeline_events_are_canonical_and_complete() {
    let report = run_fleet(&traced_cfg(2));
    let tl = report.timeline.as_ref().unwrap();
    assert_eq!(tl.source, "fleet");
    assert_eq!(tl.apps, 3);

    // already in canonical order: re-sorting is a no-op
    let mut resorted = tl.events.clone();
    sort_events(&mut resorted);
    assert_eq!(resorted, tl.events, "drained events were not canonically ordered");

    // every tuned frame appears exactly once per tenant
    for t in 0..tl.apps {
        let frames: Vec<usize> = tl
            .events
            .iter()
            .filter(|e| e.tenant == Some(t) && matches!(e.kind, EventKind::Frame { .. }))
            .map(|e| e.frame.unwrap())
            .collect();
        let expect: Vec<usize> = (0..tl.frames).collect();
        assert_eq!(frames, expect, "tenant {t} frame events");
    }
    // the dynamic scheduler traced its decisions
    assert!(tl.events.iter().any(|e| matches!(e.kind, EventKind::Alloc { .. })));
    assert!(tl.events.iter().any(|e| matches!(e.kind, EventKind::Admission { .. })));
}

#[test]
fn fleet_histograms_match_the_traced_frame_events() {
    let report = run_fleet(&traced_cfg(2));
    let tl = report.timeline.as_ref().unwrap();
    for (t, app) in report.apps.iter().enumerate() {
        let traced: Vec<f64> = tl
            .events
            .iter()
            .filter(|e| e.tenant == Some(t))
            .filter_map(|e| match &e.kind {
                EventKind::Frame { ms, .. } => Some(*ms),
                _ => None,
            })
            .collect();
        let mut mirror = iptune::obs::Histogram::new();
        for ms in &traced {
            mirror.record(*ms);
        }
        let total = app.latency.total();
        assert_eq!(total.count(), traced.len() as u64, "app {t} count");
        assert_eq!(total.bucket_counts(), mirror.bucket_counts(), "app {t} buckets");
        assert_eq!(total.quantile(0.95), mirror.quantile(0.95), "app {t} p95");
    }
}

#[test]
fn timeline_artifact_round_trips_through_disk() {
    let report = run_fleet(&traced_cfg(1));
    let tl = report.timeline.as_ref().unwrap();
    let dir = iptune::util::testdir::TestDir::new("obs_timeline_roundtrip");
    let path = dir.path().join("timeline.json");
    tl.save(&path).unwrap();
    let back = Timeline::load(&path).unwrap();
    assert_eq!(&back, tl);
    // the artifact is schema-versioned
    let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(j.req("version").unwrap().as_u64().unwrap(), iptune::obs::TIMELINE_VERSION);
    assert_eq!(j.req("kind").unwrap().as_str().unwrap(), "iptune-timeline");
}

#[test]
fn tracing_off_leaves_no_timeline_but_keeps_histograms() {
    let mut cfg = traced_cfg(1);
    cfg.trace_events = false;
    let report = run_fleet(&cfg);
    assert!(report.timeline.is_none());
    for app in &report.apps {
        assert_eq!(app.latency.total().count(), cfg.frames as u64);
    }
}
