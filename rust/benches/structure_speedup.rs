//! Paper claim C3 (Sec. 4.3): "it takes 30 and 56 features to describe
//! the structured and unstructured spaces ... updating of the structured
//! predictor should be twice as fast in practice."
//!
//! Measures online-update and predict throughput of both variants on both
//! apps, plus the degree sweep (linear/quadratic/cubic cost).
//!
//! Run: `cargo bench --bench structure_speedup`

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::{StagePredictor, Variant};
use iptune::util::bench::{black_box, Bencher};
use iptune::util::Rng;

fn main() {
    let spec_dir = find_spec_dir(None).unwrap();
    let mut b = Bencher::default();

    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir).unwrap();
        let mut rng = Rng::new(2);
        let n_stages = app.graph.len();
        let stage_ms: Vec<f64> = (0..n_stages).map(|_| rng.range_f64(1.0, 80.0)).collect();
        let e2e: f64 = stage_ms.iter().sum();
        let us: Vec<Vec<f64>> =
            (0..64).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();

        for variant in [Variant::Unstructured, Variant::Structured] {
            let mut pred = StagePredictor::new(&app.spec, variant, 3);
            let feats = pred.num_features();
            let mut i = 0usize;
            b.bench(&format!("{name}/{}/update ({feats}f)", variant.as_str()), || {
                let u = &us[i % us.len()];
                black_box(pred.observe(u, &stage_ms, e2e));
                i += 1;
            });
            b.bench(&format!("{name}/{}/predict ({feats}f)", variant.as_str()), || {
                let u = &us[i % us.len()];
                black_box(pred.predict(u));
                i += 1;
            });
        }
        for degree in [1usize, 2, 3] {
            let mut pred = StagePredictor::new(&app.spec, Variant::Unstructured, degree);
            let mut i = 0usize;
            b.bench(&format!("{name}/unstructured/deg{degree}/update"), || {
                let u = &us[i % us.len()];
                black_box(pred.observe(u, &stage_ms, e2e));
                i += 1;
            });
        }
    }

    // headline ratio
    let un = b
        .results
        .iter()
        .find(|r| r.name.starts_with("motion_sift/unstructured/update"))
        .unwrap()
        .per_iter_ns();
    let st = b
        .results
        .iter()
        .find(|r| r.name.starts_with("motion_sift/structured/update"))
        .unwrap()
        .per_iter_ns();
    println!(
        "\nC3: MotionSIFT structured update speedup = {:.2}x (paper: ~2x from 30 vs 56 features)",
        un / st
    );
}
