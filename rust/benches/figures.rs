//! Per-figure regeneration benchmarks: one bench per paper table/figure
//! (DESIGN.md §Experiment index), timing the full regeneration path on a
//! reduced protocol so `cargo bench` stays fast. The full-protocol run is
//! `repro figures --all`.
//!
//! Run: `cargo bench --bench figures`

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::experiments::{fig6, fig7, fig8};
use iptune::learner::Variant;
use iptune::metrics::convex_hull;
use iptune::trace::TraceSet;
use iptune::tuner::policy::pure_payoffs;
use iptune::util::bench::{black_box, Bencher};

fn main() {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = app_by_name("pose", &spec_dir).unwrap();
    let ms = app_by_name("motion_sift", &spec_dir).unwrap();
    let traces_pose = TraceSet::generate(&app, 15, 200, 7);
    let traces_ms = TraceSet::generate(&ms, 15, 200, 7);
    let mut b = Bencher::quick();

    // Fig. 5: payoff cloud + hull
    b.bench("fig5/payoffs+hull", || {
        let payoffs = traces_pose.payoffs();
        black_box(convex_hull(&payoffs));
    });

    // Fig. 6: three online predictors, 400 frames
    b.bench("fig6/3_degrees_x_400_frames", || {
        black_box(fig6::compute(&app.spec, &traces_pose, Variant::Unstructured, 400, 5));
    });

    // Fig. 7: structured vs unstructured, 400 frames
    b.bench("fig7/2_variants_x_400_frames", || {
        black_box(fig7::compute(&ms.spec, &traces_ms, 400, 5));
    });

    // Fig. 8: one policy run (the sweep is EPSILONS.len() x this)
    b.bench("fig8/one_policy_400_frames", || {
        black_box(fig8::run_policy(&ms.spec, &traces_ms, 0.03, 120.0, 400, 5));
    });

    // Fig. 8 payoff region
    b.bench("fig8/pure_payoffs+hull", || {
        let p = pure_payoffs(&traces_ms, 120.0);
        black_box(convex_hull(&p));
    });

    println!("\nfull-protocol regeneration: `./target/release/repro figures --all`");
}
