//! Ablations over the learner/controller design choices documented in
//! DESIGN.md §8 and EXPERIMENTS.md §Perf:
//!
//! * PA-step damping (0.25 / 0.5 / 1.0) × η₀ — final cumulative expected
//!   error of the cubic predictor (the Fig. 6 metric);
//! * controller warm-up length — reward + violation at the paper's
//!   ε = 1/√T (how much forced early exploration the solver needs);
//! * ε-insensitive zone width — error vs update-rate tradeoff.
//!
//! These are quality ablations (they report metrics, not wall-clock);
//! run with `cargo bench --bench ablations`.

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::{StagePredictor, Variant};
use iptune::metrics::ErrorTracker;
use iptune::runtime::native::NativeBackend;
use iptune::trace::TraceSet;
use iptune::tuner::{EpsGreedyController, TunerConfig};
use iptune::util::Rng;

fn online_error(
    spec: &iptune::apps::spec::AppSpec,
    traces: &TraceSet,
    eta0: f64,
    frames: usize,
) -> (f64, f64) {
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| spec.normalize(c)).collect();
    let mut pred = StagePredictor::new(spec, Variant::Structured, 3).with_eta0(eta0);
    let mut tracker = ErrorTracker::new();
    let mut rng = Rng::new(5);
    for t in 0..frames {
        let a = rng.below(candidates.len());
        let rec = traces.frame(a, t % traces.num_frames());
        let before = pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
        tracker.observe((before - rec.end_to_end_ms).abs());
    }
    (tracker.expected(), tracker.max_norm())
}

fn main() {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = app_by_name("motion_sift", &spec_dir).unwrap();
    let traces = TraceSet::generate(&app, 30, 500, 7);

    // NOTE: PA damping is a compile-time constant (shared with the AOT
    // artifacts); this ablation sweeps the η₀ ceiling, which bounds the
    // effective step the same way at the schedule's start, and reports
    // the shipped damping=0.5 column from the main harness.
    println!("== eta0 ceiling ablation (structured cubic, motion_sift, T=500) ==");
    println!("{:>8} {:>14} {:>12}", "eta0", "expected(ms)", "maxnorm(ms)");
    for eta0 in [0.1, 0.25, 0.5, 1.0, 2.0, 4.0] {
        let (e, m) = online_error(&app.spec, &traces, eta0, 500);
        println!("{eta0:>8} {e:>14.2} {m:>12.1}");
    }

    println!("\n== controller warm-up ablation (eps = 1/sqrt(T), L = 100 ms) ==");
    println!("{:>8} {:>10} {:>16} {:>16}", "warmup", "reward", "avg viol (ms)", "max viol (ms)");
    for warmup in [0usize, 5, 10, 20, 40, 80] {
        let backend = NativeBackend::structured(&app.spec);
        let cfg = TunerConfig {
            epsilon: TunerConfig::epsilon_for_horizon(1000),
            bound_ms: 100.0,
            warmup_frames: warmup,
        };
        let mut ctl =
            EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 11);
        let out = ctl.run(1000);
        println!(
            "{warmup:>8} {:>10.3} {:>16.2} {:>16.1}",
            out.avg_reward, out.avg_violation_ms, out.max_violation_ms
        );
    }

    println!("\n== eps-insensitive zone ablation (native learner, ms) ==");
    println!("{:>8} {:>14} {:>12}", "eps_ins", "expected(ms)", "maxnorm(ms)");
    for eps in [0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let candidates: Vec<Vec<f64>> =
            traces.configs().iter().map(|c| app.spec.normalize(c)).collect();
        let mut pred =
            StagePredictor::new(&app.spec, Variant::Structured, 3).with_eps(eps);
        let mut tracker = ErrorTracker::new();
        let mut rng = Rng::new(5);
        for t in 0..500 {
            let a = rng.below(candidates.len());
            let rec = traces.frame(a, t % traces.num_frames());
            let before = pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
            tracker.observe((before - rec.end_to_end_ms).abs());
        }
        println!("{eps:>8} {:>14.2} {:>12.1}", tracker.expected(), tracker.max_norm());
    }
    println!("(the AOT artifacts bake the shipped 1 ms zone; this sweep is native-only)");
}
