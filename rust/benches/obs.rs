//! Observability overhead benchmarks (ISSUE 7).
//!
//! `obs/on_frame_overhead` is the gated one: the per-frame cost the
//! always-on instrumentation adds to a hot loop — one histogram record
//! plus one disabled-sink `record_with` branch. Capture off is the
//! default production configuration, so this is the number that must
//! stay within budget.
//!
//! `obs/capture_flush_1k` tracks the enabled-capture path end to end:
//! record 1k events through a sink (one buffer-swap flush), close, and
//! drain into canonical order. Per-iteration collector keeps memory
//! bounded.
//!
//! Run: `cargo bench --bench obs`

use iptune::obs::{Event, EventKind, Histogram, TraceCollector};
use iptune::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::from_env();

    // ---- gated: the disabled-capture per-frame cost ---------------------
    let collector = TraceCollector::new(false);
    let mut sink = collector.sink();
    let mut hist = Histogram::new();
    let mut tick = 0usize;
    b.bench("obs/on_frame_overhead", || {
        let ms = 5.0 + (tick % 97) as f64 * 0.37;
        hist.record(black_box(ms));
        sink.record_with(|| Event {
            tenant: Some(tick % 8),
            epoch: tick / 30,
            frame: Some(tick),
            seq: 0,
            kind: EventKind::Frame {
                ms,
                stage_ms: Vec::new(),
                fidelity: 0.9,
            },
        });
        tick += 1;
    });
    b.metric("obs/hist_count", hist.count() as f64);

    // ---- tracked: enabled capture, flush, and canonical drain -----------
    b.bench("obs/capture_flush_1k", || {
        let collector = TraceCollector::new(true);
        let mut sink = collector.sink();
        for f in 0..1000usize {
            sink.record_with(|| Event {
                tenant: Some(f % 8),
                epoch: f / 30,
                frame: Some(f),
                seq: 0,
                kind: EventKind::Frame {
                    ms: 4.2,
                    stage_ms: Vec::new(),
                    fidelity: 0.9,
                },
            });
        }
        sink.close();
        black_box(collector.drain().len());
    });

    // ---- tracked: histogram quantile extraction -------------------------
    let mut full = Histogram::new();
    for i in 0..4096u64 {
        full.record(0.1 + (i % 613) as f64 * 0.21);
    }
    b.bench("obs/hist_quantiles", || {
        black_box(full.quantile(black_box(0.5)));
        black_box(full.quantile(black_box(0.95)));
        black_box(full.quantile(black_box(0.99)));
    });

    iptune::log_info!("\n{} benchmarks complete", b.results.len());
    b.write_json_env("obs");
}
