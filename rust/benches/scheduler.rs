//! Scheduler hot-path benchmarks: the reallocation-epoch cost (utility
//! curves + water-filling) and the per-frame overhead the budgeted
//! controller adds on top of the plain ε-greedy step. The scheduler runs
//! once per epoch (~50 frames) and the controller once per frame, so
//! both must stay far below the 33 ms frame budget.
//!
//! Run: `cargo bench --bench scheduler`

use iptune::learner::GroupMap;
use iptune::runtime::native::NativeBackend;
use iptune::scheduler::frontier::ProgressFrontier;
use iptune::scheduler::{allocate, allocate_v2, core_levels};
use iptune::simulator::Cluster;
use iptune::trace::{LadderTraceSet, TraceSet};
use iptune::tuner::{BudgetedController, EpsGreedyController, TunerConfig};
use iptune::util::bench::{black_box, Bencher};
use iptune::util::Rng;
use iptune::workloads::{self, AppProfile, DagConfig, WorkloadConfig};

fn main() {
    let mut b = Bencher::from_env();

    // ---- water-filling allocator over synthetic utility curves ---------
    let levels = core_levels(120, 8, 7, 6, 3.0);
    let mut rng = Rng::new(3);
    let curves8: Vec<Vec<f64>> = (0..8)
        .map(|_| {
            let mut u: Vec<f64> = (0..levels.len()).map(|_| rng.f64()).collect();
            u.sort_by(|a, b| a.partial_cmp(b).unwrap());
            u
        })
        .collect();
    b.bench("allocate/8apps_6rungs", || {
        black_box(allocate(black_box(&curves8), &levels, 120));
    });

    // v2: priority-weighted + incumbent hysteresis (the stateful path
    // every dynamic fleet epoch actually takes)
    let weights: Vec<f64> = (0..8).map(|i| 1.0 + (i % 3) as f64).collect();
    let prev = allocate(&curves8, &levels, 120);
    b.bench("allocate_v2/8apps_6rungs_hysteresis", || {
        black_box(allocate_v2(
            black_box(&curves8),
            &levels,
            120,
            &weights,
            Some(&prev),
            0.1,
        ));
    });

    let big_levels = core_levels(4096, 64, 32, 8, 3.0);
    let curves64: Vec<Vec<f64>> = (0..64)
        .map(|_| {
            let mut u: Vec<f64> = (0..big_levels.len()).map(|_| rng.f64()).collect();
            u.sort_by(|a, b| a.partial_cmp(b).unwrap());
            u
        })
        .collect();
    b.bench("allocate/64apps_8rungs", || {
        black_box(allocate(black_box(&curves64), &big_levels, 4096));
    });

    // ---- per-app machinery on a generated heavy workload ---------------
    let wcfg = WorkloadConfig { profile: AppProfile::Heavy, ..Default::default() };
    let cluster = Cluster::default();
    let app = workloads::generate_on(11, &wcfg, &cluster);
    let bound = app.spec.latency_bounds_ms[0];
    let ladder = LadderTraceSet::generate_on(&app, &cluster, &levels, 24, 200, 5);
    let cfg = TunerConfig { epsilon: 0.05, bound_ms: bound * 0.9, warmup_frames: 20 };

    // utility-curve query: the scheduler's whole per-app epoch cost
    let mut ctl = BudgetedController::new(
        &app,
        &ladder,
        Box::new(NativeBackend::structured(&app.spec)),
        cfg.clone(),
        9,
    )
    .with_empirical_blend(8.0);
    for f in 0..200 {
        ctl.step(f);
    }
    b.bench("scheduler/utility_curve_6rungs_24cand", || {
        black_box(ctl.utility_curve());
    });

    // budgeted step vs the plain fixed-cluster step (same action space)
    let mut frame = 200usize;
    b.bench("scheduler/budgeted_step", || {
        black_box(ctl.step(black_box(frame % ladder.num_frames())));
        frame += 1;
    });

    let traces = TraceSet::generate_on(&app, &cluster, 24, 200, 5);
    let mut plain = EpsGreedyController::new(
        &app.spec,
        &traces,
        Box::new(NativeBackend::structured(&app.spec)),
        cfg,
        9,
    )
    .with_empirical_blend(8.0);
    for f in 0..200 {
        plain.step(f);
    }
    let mut pframe = 200usize;
    b.bench("scheduler/plain_step_baseline", || {
        black_box(plain.step(black_box(pframe % traces.num_frames())));
        pframe += 1;
    });

    // ---- ladder tracing (fleet construction cost, per app) -------------
    b.bench("scheduler/ladder_traces_6x8cfg_100f", || {
        black_box(LadderTraceSet::generate_on(&app, &cluster, &levels, 8, 100, 5));
    });

    // ---- ladder-trace peak memory (rung sharing) ------------------------
    // A light (core-insensitive) app's grant is identical at every rung:
    // the ladder must share one frame buffer per config instead of
    // replicating levels-fold. The assertion gates the fix; the metrics
    // put the byte counts on the bench trajectory (BENCH_<sha>.json).
    let light_cfg = WorkloadConfig { profile: AppProfile::Light, ..Default::default() };
    let light = workloads::generate_on(42, &light_cfg, &cluster);
    let light_ladder = LadderTraceSet::generate_on(&light, &cluster, &levels, 16, 200, 7);
    let (unique, logical) =
        (light_ladder.unique_trace_bytes(), light_ladder.logical_trace_bytes());
    assert!(
        unique * 4 <= logical,
        "light-app ladder peak trace bytes must be >= 4x below the \
         unshared footprint: {unique} vs {logical}"
    );
    b.metric("ladder_trace/light_peak_bytes", unique as f64);
    b.metric("ladder_trace/light_logical_bytes", logical as f64);
    b.metric("ladder_trace/light_sharing_ratio", light_ladder.sharing_ratio());
    let heavy_ladder = LadderTraceSet::generate_on(&app, &cluster, &levels, 8, 100, 5);
    b.metric("ladder_trace/heavy_peak_bytes", heavy_ladder.unique_trace_bytes() as f64);
    b.metric("ladder_trace/heavy_sharing_ratio", heavy_ladder.sharing_ratio());

    // ---- PR 5: general-DAG generation + critical-path combine -----------
    // full gen-dag construction (topology draw + knob assignment + drift
    // walk tables + bound-calibration probes) — the per-tenant cost a DAG
    // fleet pays at startup
    let dag_cfg = WorkloadConfig {
        dag: Some(DagConfig::default()),
        drift: Some(0.15),
        ..Default::default()
    };
    b.bench("workloads/gen_dag_drift", || {
        black_box(workloads::generate_on(black_box(11), &dag_cfg, &cluster));
    });

    // the structured combine over the group DAG — called once per
    // candidate per predict, i.e. the hottest new code on the tuner path
    let dag_app = workloads::generate_on(11, &dag_cfg, &cluster);
    let map = GroupMap::structured(&dag_app.spec);
    assert!(map.group_graph.is_some());
    let preds: Vec<f64> = (0..map.num_groups()).map(|g| 5.0 + g as f64).collect();
    b.metric("workloads/gen_dag_groups", map.num_groups() as f64);
    b.bench("learner/combine_dag", || {
        black_box(map.combine(black_box(&preds), 2.5));
    });

    // ---- PR 6: progress-frontier bookkeeping ----------------------------
    // the per-frame cost the live recv loop pays: one clock advance plus
    // an envelope scan per arrival (16 tenants, worst case all admitted)
    let mut frontier = ProgressFrontier::new(16, 30, &[true; 16]);
    let mut ftick = 0usize;
    b.bench("scheduler/frontier_on_frame_envelope_16t", || {
        let i = ftick % 16;
        ftick += 1;
        black_box(frontier.on_frame(black_box(i)));
        black_box(frontier.passed(black_box(ftick / (16 * 30))));
    });

    // ---- PR 8: the 100k-tenant reallocation epoch ------------------------
    // One heap water-fill epoch at fleet scale: floor-1 ladders so
    // `tenants * levels[0] <= pool` holds at every size, sorted-random
    // curves with manufactured exact ties, tiered weights, and incumbent
    // hysteresis (the stateful path every production epoch takes). The
    // legacy full-scan allocator was O(moves x tenants x rungs) — at 100k
    // tenants a single epoch took minutes, which is why no bench existed
    // above 64 apps. The per-tenant side metrics feed the trajectory: the
    // 100k/1k ratio proves the epoch cost grows sub-linearly (the Python
    // mirror asserts the op-count version of the same bound <= 1.5x).
    let mut per_tenant_ns = Vec::new();
    for &(n, label) in &[
        (1_000usize, "allocate_v2/1k_tenants"),
        (10_000, "allocate_v2/10k_tenants"),
        (100_000, "allocate_v2/100k_tenants"),
    ] {
        let pool = 3 * n;
        let lv = core_levels(pool, n, 1, 8, 3.0);
        let mut trng = Rng::new(0x8EA1 + n as u64);
        let tcurves: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let mut u: Vec<f64> = (0..lv.len())
                    .map(|_| (trng.f64() * 64.0).floor() / 64.0)
                    .collect();
                u.sort_by(|a, b| a.partial_cmp(b).unwrap());
                u
            })
            .collect();
        let tweights: Vec<f64> = (0..n).map(|i| 1.0 + (i % 3) as f64).collect();
        let tprev: Vec<usize> = (0..n).map(|i| i % lv.len()).collect();
        let med = b
            .bench(label, || {
                black_box(allocate_v2(
                    black_box(&tcurves),
                    &lv,
                    pool,
                    &tweights,
                    Some(&tprev),
                    0.05,
                ));
            })
            .per_iter_ns();
        per_tenant_ns.push(med / n as f64);
    }
    b.metric("allocate_v2/ns_per_tenant_1k", per_tenant_ns[0]);
    b.metric("allocate_v2/ns_per_tenant_10k", per_tenant_ns[1]);
    b.metric("allocate_v2/ns_per_tenant_100k", per_tenant_ns[2]);
    b.metric(
        "allocate_v2/per_tenant_ratio_100k_over_1k",
        per_tenant_ns[2] / per_tenant_ns[0],
    );

    // ---- PR 10: the sharded coordinator epoch ----------------------------
    // One full 10k-tenant reallocation epoch through the hierarchical
    // coordinator over 4 mpsc worker shards — synthesis, token-protocol
    // admission, both water-fill phases, the reservation top-up, and the
    // statistics fold. Tracked (not gated): the budget anchors the cost
    // of the cross-shard protocol against the single-pool
    // allocate_v2/10k_tenants point so a chatty-protocol regression
    // (e.g. per-tenant messages sneaking into a summary) shows up in the
    // trajectory.
    let shard_cfg = iptune::fleet::scale::ScaleConfig {
        tenants: 10_000,
        epochs: 1,
        shards: 4,
        ..Default::default()
    };
    b.bench("scheduler/coordinator_epoch_4shards", || {
        black_box(
            iptune::fleet::scale::run(black_box(&shard_cfg)).expect("sharded epoch runs"),
        );
    });

    println!("\n{} benchmarks complete", b.results.len());
    b.write_json_env("scheduler");
}
