//! Substrate benchmarks: the cluster simulator's frame throughput (it
//! generates 30×1000-frame trace sets for every experiment), the trace
//! JSON codec, the critical-path kernel, and the streaming engine's
//! end-to-end frame rate.
//!
//! Run: `cargo bench --bench simulator`

use std::sync::Arc;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::dataflow::critical_path;
use iptune::engine::{run_stream_blocking, EngineConfig};
use iptune::simulator::{Cluster, ClusterSim, NoiseModel};
use iptune::trace::TraceSet;
use iptune::util::bench::{black_box, Bencher};
use iptune::util::Rng;

fn main() {
    let spec_dir = find_spec_dir(None).unwrap();
    let mut b = Bencher::from_env();

    for name in ["pose", "motion_sift"] {
        let app = app_by_name(name, &spec_dir).unwrap();
        let ks = app.spec.defaults();
        let mut sim = ClusterSim::new(Cluster::default(), NoiseModel::default(), 1);
        let mut f = 0usize;
        b.bench(&format!("simulator/{name}/run_frame"), || {
            black_box(sim.run_frame(&app, &ks, f % 1000));
            f += 1;
        });

        let g = &app.graph;
        let mut rng = Rng::new(3);
        let w: Vec<f64> = (0..g.len()).map(|_| rng.range_f64(0.5, 50.0)).collect();
        b.bench(&format!("dataflow/{name}/critical_path"), || {
            black_box(critical_path(g, black_box(&w)));
        });
    }

    // trace generation + serialization round-trip
    let app = app_by_name("pose", &spec_dir).unwrap();
    b.bench("trace/generate_5cfg_x_100f", || {
        black_box(TraceSet::generate(&app, 5, 100, 7));
    });
    let ts = TraceSet::generate(&app, 10, 200, 7);
    b.bench("trace/json_encode", || {
        black_box(ts.to_json().to_string());
    });
    let text = ts.to_json().to_string();
    println!("trace json size: {} KiB (10 cfg x 200 frames)", text.len() / 1024);
    b.bench("trace/json_decode", || {
        let v = iptune::util::Json::parse(black_box(&text)).unwrap();
        black_box(TraceSet::from_json(&v).unwrap());
    });

    // streaming engine throughput (no pacing)
    let app = Arc::new(app_by_name("motion_sift", &spec_dir).unwrap());
    b.bench("engine/stream_100_frames", || {
        black_box(run_stream_blocking(
            Arc::clone(&app),
            app.spec.defaults(),
            EngineConfig { frames: 100, ..Default::default() },
        ));
    });
    if let Some(r) = b.result("engine/stream_100_frames") {
        println!(
            "\nengine throughput ~ {:.0} frames/s (unpaced, 10-stage graph)",
            100.0 / (r.per_iter_ns() / 1e9)
        );
    }

    b.write_json_env("simulator");
}
