//! Hot-path benchmarks: the per-frame operations of the controller —
//! predict over the candidate batch, the OGD update, the constrained
//! solve, and a full tuner step — on both backends. The controller must
//! stay far below the 33 ms frame budget (and below the 50 ms pose
//! bound), otherwise the tuner itself would be the bottleneck.
//!
//! Run: `cargo bench --bench tuner_hot_path`

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::learner::Variant;
use iptune::runtime::native::NativeBackend;
use iptune::runtime::xla::XlaBackend;
use iptune::runtime::Backend;
use iptune::trace::TraceSet;
use iptune::tuner::{EpsGreedyController, TunerConfig};
use iptune::util::bench::{black_box, Bencher};
use iptune::util::Rng;

fn main() {
    let spec_dir = find_spec_dir(None).unwrap();
    let app = app_by_name("motion_sift", &spec_dir).unwrap();
    let mut rng = Rng::new(1);
    let candidates: Vec<Vec<f64>> =
        (0..30).map(|_| (0..5).map(|_| rng.f64()).collect()).collect();
    let rewards: Vec<f64> = (0..30).map(|_| rng.f64()).collect();
    let y = vec![60.0, 90.0];
    let u = vec![0.4, 0.6, 0.5, 0.3, 0.7];

    let mut b = Bencher::from_env();

    // --- native backend -------------------------------------------------
    let mut native = NativeBackend::structured(&app.spec);
    for _ in 0..200 {
        native.update(&u, &y);
    }
    b.bench("native/predict_30cand", || {
        black_box(native.predict(black_box(&candidates)));
    });
    b.bench("native/update", || {
        native.update(black_box(&u), black_box(&y));
    });
    b.bench("native/solve_30cand", || {
        black_box(native.solve(black_box(&candidates), &rewards, 100.0));
    });

    // --- XLA backend (skipped without artifacts) ------------------------
    match XlaBackend::from_default_artifacts(&app.spec, Variant::Structured) {
        Ok(mut xla) => {
            for _ in 0..50 {
                xla.update(&u, &y);
            }
            b.bench("xla/predict_30cand", || {
                black_box(xla.predict(black_box(&candidates)));
            });
            b.bench("xla/update", || {
                xla.update(black_box(&u), black_box(&y));
            });
            b.bench("xla/solve_30cand", || {
                black_box(xla.solve(black_box(&candidates), &rewards, 100.0));
            });
        }
        Err(e) => iptune::log_warn!("skipping xla benches: {e}"),
    }

    // --- full controller step -------------------------------------------
    let traces = TraceSet::generate(&app, 30, 300, 7);
    let backend = NativeBackend::structured(&app.spec);
    let cfg = TunerConfig { epsilon: 0.03, bound_ms: 100.0, warmup_frames: 20 };
    let mut ctl = EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 3);
    let mut frame = 0usize;
    b.bench("controller/full_step", || {
        black_box(ctl.step(frame));
        frame += 1;
    });

    // frame-budget report
    if let Some(step) = b.result("controller/full_step") {
        let budget_ms = 33.0;
        let step_ms = step.per_iter_ns() / 1e6;
        println!(
            "\ncontroller step = {:.3} ms ({:.2}% of the 33 ms frame budget)",
            step_ms,
            100.0 * step_ms / budget_ms
        );
    }

    b.write_json_env("tuner_hot_path");
}
