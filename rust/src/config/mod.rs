//! JSON run-configuration system for the CLI and the examples.
//!
//! Everything has sensible defaults; a config file only overrides what it
//! names. Example:
//!
//! ```json
//! {
//!   "cluster": { "servers": 4 },
//!   "tuner":   { "epsilon": 0.1, "backend": "native" }
//! }
//! ```

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// Which predictor backend executes the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// AOT-compiled HLO artifacts on the PJRT CPU client (production).
    #[default]
    Xla,
    /// Pure-Rust twin (compact features; no artifacts needed).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "xla" => Ok(BackendKind::Xla),
            "native" => Ok(BackendKind::Native),
            other => bail!("unknown backend '{other}' (expected xla|native)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Xla => "xla",
            BackendKind::Native => "native",
        }
    }
}

/// Which predictor architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum VariantKind {
    #[default]
    Structured,
    Unstructured,
}

impl VariantKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "structured" => Ok(VariantKind::Structured),
            "unstructured" => Ok(VariantKind::Unstructured),
            other => bail!("unknown variant '{other}' (expected structured|unstructured)"),
        }
    }
}

impl From<VariantKind> for crate::learner::Variant {
    fn from(v: VariantKind) -> Self {
        match v {
            VariantKind::Structured => crate::learner::Variant::Structured,
            VariantKind::Unstructured => crate::learner::Variant::Unstructured,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub servers: usize,
    pub cores_per_server: usize,
    /// Per-connector communication latency (ms) at full resolution; 0
    /// reproduces the paper (network modeling is its named future work).
    pub comm_ms_per_frame: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            servers: crate::simulator::DEFAULT_SERVERS,
            cores_per_server: crate::simulator::DEFAULT_CORES_PER_SERVER,
            comm_ms_per_frame: 0.0,
        }
    }
}

impl From<&ClusterConfig> for crate::simulator::Cluster {
    fn from(c: &ClusterConfig) -> Self {
        crate::simulator::Cluster {
            servers: c.servers,
            cores_per_server: c.cores_per_server,
            comm_ms_per_frame: c.comm_ms_per_frame,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Random configurations in the action space (paper: 30).
    pub configs: usize,
    /// Frames per configuration (paper: 1000).
    pub frames: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { configs: 30, frames: 1000, seed: 7 }
    }
}

#[derive(Debug, Clone)]
pub struct TunerSection {
    /// Exploration rate; `None` → the paper's 1/√T rule.
    pub epsilon: Option<f64>,
    /// Latency bound L (ms); `None` → the spec's first bound.
    pub bound_ms: Option<f64>,
    pub warmup_frames: usize,
    pub backend: BackendKind,
    pub variant: VariantKind,
    /// Polynomial degree of the native predictor (XLA artifacts are cubic).
    pub degree: usize,
    pub seed: u64,
}

impl Default for TunerSection {
    fn default() -> Self {
        TunerSection {
            epsilon: None,
            bound_ms: None,
            warmup_frames: 20,
            backend: BackendKind::Xla,
            variant: VariantKind::Structured,
            degree: 3,
            seed: 11,
        }
    }
}

/// Top-level run configuration.
#[derive(Debug, Clone, Default)]
pub struct RunConfig {
    pub cluster: ClusterConfig,
    pub trace: TraceConfig,
    pub tuner: TunerSection,
}

impl RunConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = RunConfig::default();
        if let Some(c) = v.get("cluster") {
            if let Some(x) = c.get("servers") {
                cfg.cluster.servers = x.as_usize()?;
            }
            if let Some(x) = c.get("cores_per_server") {
                cfg.cluster.cores_per_server = x.as_usize()?;
            }
            if let Some(x) = c.get("comm_ms_per_frame") {
                cfg.cluster.comm_ms_per_frame = x.as_f64()?;
            }
        }
        if let Some(t) = v.get("trace") {
            if let Some(x) = t.get("configs") {
                cfg.trace.configs = x.as_usize()?;
            }
            if let Some(x) = t.get("frames") {
                cfg.trace.frames = x.as_usize()?;
            }
            if let Some(x) = t.get("seed") {
                cfg.trace.seed = x.as_u64()?;
            }
        }
        if let Some(t) = v.get("tuner") {
            if let Some(x) = t.get("epsilon") {
                cfg.tuner.epsilon = Some(x.as_f64()?);
            }
            if let Some(x) = t.get("bound_ms") {
                cfg.tuner.bound_ms = Some(x.as_f64()?);
            }
            if let Some(x) = t.get("warmup_frames") {
                cfg.tuner.warmup_frames = x.as_usize()?;
            }
            if let Some(x) = t.get("backend") {
                cfg.tuner.backend = BackendKind::parse(x.as_str()?)?;
            }
            if let Some(x) = t.get("variant") {
                cfg.tuner.variant = VariantKind::parse(x.as_str()?)?;
            }
            if let Some(x) = t.get("degree") {
                cfg.tuner.degree = x.as_usize()?;
            }
            if let Some(x) = t.get("seed") {
                cfg.tuner.seed = x.as_u64()?;
            }
        }
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut tuner = Json::obj()
            .put("warmup_frames", self.tuner.warmup_frames)
            .put("backend", self.tuner.backend.as_str())
            .put(
                "variant",
                match self.tuner.variant {
                    VariantKind::Structured => "structured",
                    VariantKind::Unstructured => "unstructured",
                },
            )
            .put("degree", self.tuner.degree)
            .put("seed", self.tuner.seed);
        if let Some(e) = self.tuner.epsilon {
            tuner = tuner.put("epsilon", e);
        }
        if let Some(b) = self.tuner.bound_ms {
            tuner = tuner.put("bound_ms", b);
        }
        Json::obj()
            .put(
                "cluster",
                Json::obj()
                    .put("servers", self.cluster.servers)
                    .put("cores_per_server", self.cluster.cores_per_server)
                    .put("comm_ms_per_frame", self.cluster.comm_ms_per_frame),
            )
            .put(
                "trace",
                Json::obj()
                    .put("configs", self.trace.configs)
                    .put("frames", self.trace.frames)
                    .put("seed", self.trace.seed),
            )
            .put("tuner", tuner)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(
            &Json::parse(&text)
                .with_context(|| format!("parsing config {}", path.display()))?,
        )
    }

    pub fn load_or_default(path: Option<&Path>) -> Result<Self> {
        match path {
            Some(p) => Self::load(p),
            None => Ok(Self::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_protocol() {
        let c = RunConfig::default();
        assert_eq!(c.trace.configs, 30);
        assert_eq!(c.trace.frames, 1000);
        assert_eq!(c.cluster.servers, 15);
        assert_eq!(c.cluster.cores_per_server, 8);
        assert_eq!(c.tuner.backend, BackendKind::Xla);
    }

    #[test]
    fn partial_json_overrides() {
        let v = Json::parse(
            r#"{"tuner": {"epsilon": 0.1, "backend": "native", "variant": "unstructured"},
                "cluster": {"servers": 4}}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&v).unwrap();
        assert_eq!(cfg.tuner.epsilon, Some(0.1));
        assert_eq!(cfg.tuner.backend, BackendKind::Native);
        assert_eq!(cfg.tuner.variant, VariantKind::Unstructured);
        assert_eq!(cfg.cluster.servers, 4);
        assert_eq!(cfg.cluster.cores_per_server, 8); // default retained
        assert_eq!(cfg.trace.frames, 1000);
    }

    #[test]
    fn roundtrip_serialization() {
        let mut c = RunConfig::default();
        c.tuner.epsilon = Some(0.25);
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.trace.seed, c.trace.seed);
        assert_eq!(back.tuner.epsilon, Some(0.25));
    }

    #[test]
    fn bad_backend_rejected() {
        let v = Json::parse(r#"{"tuner": {"backend": "gpu"}}"#).unwrap();
        assert!(RunConfig::from_json(&v).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::testdir::TestDir::new("config");
        let path = dir.join("run.json");
        std::fs::write(&path, RunConfig::default().to_json().to_string()).unwrap();
        let cfg = RunConfig::load(&path).unwrap();
        assert_eq!(cfg.trace.configs, 30);
    }
}
