//! Budget-aware ε-greedy controller — the per-app tuner the fleet
//! scheduler drives.
//!
//! Differences from the fixed-cluster [`EpsGreedyController`]:
//!
//! * The action space is a [`LadderTraceSet`]: the same configurations
//!   traced at a ladder of core budgets on the shared cluster. The
//!   scheduler moves the app between rungs ([`set_level`]) at
//!   reallocation epochs; frames are replayed from the active rung.
//! * Candidates fed to the learned latency model are *effective* knob
//!   vectors: each action's parallelism knobs are clamped to what the
//!   current budget would actually grant
//!   ([`grant_under`](crate::simulator::grant_under)), so the model's
//!   input always describes the execution that produced the observation.
//!   Because the input encodes granted workers rather than requested
//!   ones, the weights learned at one budget transfer to every other —
//!   which is what lets the scheduler ask "what would this app's latency
//!   be at k cores?" ([`utility_at`]) without re-exploring.
//! * The per-action empirical cost blend is tracked per `(level, action)`
//!   pair: an action's observed latency at 7 cores says little about the
//!   same action at 45.
//!
//! [`set_level`]: BudgetedController::set_level
//! [`utility_at`]: BudgetedController::utility_at

use crate::apps::App;
use crate::runtime::{constrained_argmax, Backend};
use crate::simulator::{grant_under, time_multiplex_factor};
use crate::trace::LadderTraceSet;
use crate::tuner::{StepOutcome, TunerConfig};
use crate::util::Rng;

/// Normalized effective knob vectors of every action at every ladder
/// level: parallel knobs are replaced by the workers the level's budget
/// would grant. Exposed for the live scheduler path, which clamps the
/// knobs it installs on running engine streams the same way.
pub fn effective_candidates(
    app: &App,
    configs: &[Vec<f64>],
    levels: &[usize],
) -> Vec<Vec<Vec<f64>>> {
    let n_stages = app.graph.len();
    levels
        .iter()
        .map(|&budget| {
            configs
                .iter()
                .map(|ks| {
                    let requested: Vec<usize> = (0..n_stages)
                        .map(|s| app.model.requested_workers(s, ks))
                        .collect();
                    let granted = grant_under(&requested, budget);
                    let mut eff = ks.clone();
                    for s in 0..n_stages {
                        if let Some(k) = app.model.par_knob(s) {
                            eff[k] = granted[s] as f64;
                        }
                    }
                    app.spec.normalize(&eff)
                })
                .collect()
        })
        .collect()
}

/// Per-`(level, action)` time-multiplexing latency factors
/// ([`time_multiplex_factor`]): what exact fairness-floor accounting
/// charges when a rung's budget holds fewer cores than the grant's
/// worker total. All 1.0 at budgets at or above the app's stage count.
pub fn time_multiplex_factors(
    app: &App,
    configs: &[Vec<f64>],
    levels: &[usize],
) -> Vec<Vec<f64>> {
    let n_stages = app.graph.len();
    levels
        .iter()
        .map(|&budget| {
            configs
                .iter()
                .map(|ks| {
                    let requested: Vec<usize> = (0..n_stages)
                        .map(|s| app.model.requested_workers(s, ks))
                        .collect();
                    let granted = grant_under(&requested, budget);
                    time_multiplex_factor(granted.iter().sum(), budget)
                })
                .collect()
        })
        .collect()
}

/// ε-greedy controller over a ladder trace set (see module docs).
pub struct BudgetedController<'a> {
    ladder: &'a LadderTraceSet,
    backend: Box<dyn Backend>,
    cfg: TunerConfig,
    rng: Rng,
    level: usize,
    /// `candidates_at[level][action]`: normalized effective knobs.
    candidates_at: Vec<Vec<Vec<f64>>>,
    /// The same candidates flattened across rungs
    /// (`candidates_flat[level * num_configs + action]`), precomputed so
    /// [`utility_curve`](Self::utility_curve) runs **one** batched
    /// backend prediction for the whole ladder instead of one per rung —
    /// the vectorized demand-summary path the epoch scheduler hits for
    /// every tenant every reallocation epoch.
    candidates_flat: Vec<Vec<f64>>,
    /// Known per-action expected fidelity — identical across levels
    /// (parallelism is fidelity-neutral), taken from the floor rung.
    rewards: Vec<f64>,
    blend_k: f64,
    ema_alpha: f64,
    /// Per-`(level, action)` observation state, indexed
    /// `level * num_actions + action`.
    obs_count: Vec<u64>,
    obs_ema_ms: Vec<f64>,
    /// Exact accounting: multiply model predictions by the
    /// per-`(level, action)` time-multiplexing factor so the model and
    /// the simulator agree about sub-stage-count budgets. Off by
    /// default (historical behavior).
    time_multiplex: bool,
    /// `tm_at[level][action]` — see [`time_multiplex_factors`].
    tm_at: Vec<Vec<f64>>,
}

impl<'a> BudgetedController<'a> {
    pub fn new(
        app: &App,
        ladder: &'a LadderTraceSet,
        backend: Box<dyn Backend>,
        cfg: TunerConfig,
        seed: u64,
    ) -> Self {
        assert!(ladder.num_configs() > 0, "empty action space");
        assert!((0.0..=1.0).contains(&cfg.epsilon));
        let candidates_at = effective_candidates(app, &ladder.configs(), &ladder.levels);
        let tm_at = time_multiplex_factors(app, &ladder.configs(), &ladder.levels);
        let rewards: Vec<f64> =
            ladder.set(0).traces.iter().map(|t| t.avg_fidelity()).collect();
        let slots = ladder.num_levels() * ladder.num_configs();
        let candidates_flat = candidates_at.concat();
        BudgetedController {
            ladder,
            backend,
            cfg,
            rng: Rng::new(seed),
            level: 0,
            candidates_at,
            candidates_flat,
            rewards,
            blend_k: 0.0,
            ema_alpha: 0.2,
            obs_count: vec![0; slots],
            obs_ema_ms: vec![0.0; slots],
            time_multiplex: false,
            tm_at,
        }
    }

    /// Enable the per-`(level, action)` empirical cost blend (same
    /// semantics as [`EpsGreedyController::with_empirical_blend`]).
    ///
    /// [`EpsGreedyController::with_empirical_blend`]:
    ///     crate::tuner::EpsGreedyController::with_empirical_blend
    pub fn with_empirical_blend(mut self, k: f64) -> Self {
        assert!(k >= 0.0);
        self.blend_k = k;
        self
    }

    /// Exact accounting: scale every model prediction by the rung's
    /// time-multiplexing factor, matching a simulator (and ladder traces)
    /// running with [`ClusterSim::set_time_multiplex`] on. The fleet
    /// enables this together with admission control.
    ///
    /// [`ClusterSim::set_time_multiplex`]:
    ///     crate::simulator::ClusterSim::set_time_multiplex
    pub fn with_time_multiplex(mut self, on: bool) -> Self {
        self.time_multiplex = on;
        self
    }

    /// Move the app to ladder rung `level` (scheduler epochs call this).
    pub fn set_level(&mut self, level: usize) {
        assert!(level < self.ladder.num_levels(), "level {level} off the ladder");
        self.level = level;
    }

    pub fn level(&self) -> usize {
        self.level
    }

    /// Core budget of the active rung.
    pub fn cores(&self) -> usize {
        self.ladder.levels[self.level]
    }

    pub fn action_rewards(&self) -> &[f64] {
        &self.rewards
    }

    /// Observations this controller holds per ladder rung (summed over
    /// actions) — the evidence counts behind the scheduler's
    /// demand-confidence term
    /// ([`demand_cores_confident`](crate::scheduler::demand_cores_confident)).
    pub fn rung_observations(&self) -> Vec<u64> {
        let n = self.ladder.num_configs();
        (0..self.ladder.num_levels())
            .map(|l| self.obs_count[l * n..(l + 1) * n].iter().sum())
            .collect()
    }

    /// Blended cost estimates for every candidate at ladder rung `level`
    /// (no cross-rung transfer; see [`estimates_at`](Self::estimates_at)).
    fn blended_costs_at(&mut self, level: usize) -> Vec<f64> {
        let costs = self.backend.predict(&self.candidates_at[level]);
        self.blend_raw(level, &costs)
    }

    /// Apply exact accounting and the empirical blend to `raw` model
    /// costs for rung `level`. One implementation shared by the per-rung
    /// path ([`blended_costs_at`](Self::blended_costs_at)) and the
    /// vectorized whole-curve path
    /// ([`utility_curve`](Self::utility_curve)), so the two can't drift.
    fn blend_raw(&self, level: usize, raw: &[f64]) -> Vec<f64> {
        let n = self.ladder.num_configs();
        raw.iter()
            .enumerate()
            .map(|(i, &raw_c)| {
                // exact accounting first: the observations being blended
                // in already carry the time-multiplexing charge
                let c =
                    if self.time_multiplex { raw_c * self.tm_at[level][i] } else { raw_c };
                if self.blend_k <= 0.0 {
                    return c;
                }
                let o = level * n + i;
                let cnt = self.obs_count[o] as f64;
                (self.blend_k * c + cnt * self.obs_ema_ms[o]) / (self.blend_k + cnt)
            })
            .collect()
    }

    /// Cost estimates at rung `level` under the *monotone resource
    /// prior*: granted workers only grow with the budget, so an action
    /// observed at a lower rung is expected to be at most as slow at
    /// `level`. Two guards keep the prior honest:
    ///
    /// * only **observed** lower-rung estimates transfer — model-only
    ///   predictions don't (the model is already queryable at `level`
    ///   directly, and a spuriously-low extrapolation at an unexplored
    ///   rung must not masquerade as evidence);
    /// * the prior only fills `(rung, action)` pairs not yet observed at
    ///   `level` itself — own evidence always trumps, so a stale
    ///   fast-at-few-cores reading can't permanently hide an action that
    ///   turned out slow at many cores (per-worker dispatch overhead
    ///   makes over-granting genuinely costly — the Amdahl U-shape).
    fn estimates_at(&mut self, level: usize) -> Vec<f64> {
        let mut est = self.blended_costs_at(level);
        let n = self.ladder.num_configs();
        for l in 0..level {
            // a rung with no observations at all can transfer nothing —
            // skip its full-grid prediction (the common case: rungs the
            // scheduler never assigned; keeps the exploit path at one
            // batched predict per visited rung instead of one per rung)
            if self.obs_count[l * n..(l + 1) * n].iter().all(|&c| c == 0) {
                continue;
            }
            let b = self.blended_costs_at(l);
            for a in 0..n {
                if self.obs_count[level * n + a] == 0
                    && self.obs_count[l * n + a] > 0
                    && b[a] < est[a]
                {
                    est[a] = b[a];
                }
            }
        }
        est
    }

    /// The scheduler's query: the fidelity this app's learned model
    /// predicts it could hold at ladder rung `level` while meeting the
    /// latency bound — 0 when nothing is predicted feasible (a strong
    /// "needs more cores" signal, since becoming feasible at a higher
    /// rung is then worth the full best-action fidelity).
    pub fn utility_at(&mut self, level: usize) -> f64 {
        let est = self.estimates_at(level);
        self.utility_of(&est)
    }

    fn utility_of(&self, est: &[f64]) -> f64 {
        let a = constrained_argmax(est, &self.rewards, self.cfg.bound_ms);
        if est[a] <= self.cfg.bound_ms {
            self.rewards[a]
        } else {
            0.0
        }
    }

    /// [`utility_at`](Self::utility_at) for every rung — the app's
    /// marginal-utility curve the water-filling allocator consumes.
    /// Vectorized over rungs (PR 8): **one** batched backend prediction
    /// covers the whole ladder (`candidates_flat`), then one ascending
    /// sweep applies blending and carries the observation-anchored
    /// minimum upward. [`Backend::predict`] is defined per-candidate
    /// (row `i`'s cost depends only on row `i`), so the flat batch's
    /// per-rung slices are bit-identical to the per-rung calls — the
    /// demand summary every tenant hands the epoch allocator is computed
    /// in one pass.
    ///
    /// [`Backend::predict`]: crate::runtime::Backend::predict
    pub fn utility_curve(&mut self) -> Vec<f64> {
        let n = self.ladder.num_configs();
        let flat = self.backend.predict(&self.candidates_flat);
        let mut out = Vec::with_capacity(self.ladder.num_levels());
        let mut obs_min = vec![f64::INFINITY; n];
        for l in 0..self.ladder.num_levels() {
            let b = self.blend_raw(l, &flat[l * n..(l + 1) * n]);
            let est: Vec<f64> = b
                .iter()
                .enumerate()
                .map(|(a, &x)| {
                    if self.obs_count[l * n + a] > 0 {
                        x
                    } else {
                        x.min(obs_min[a])
                    }
                })
                .collect();
            out.push(self.utility_of(&est));
            for a in 0..n {
                if self.obs_count[l * n + a] > 0 && b[a] < obs_min[a] {
                    obs_min[a] = b[a];
                }
            }
        }
        out
    }

    /// Run one frame at the active rung: choose an action, observe that
    /// rung's trace outcome, learn.
    pub fn step(&mut self, frame: usize) -> StepOutcome {
        let level = self.level;
        let n = self.ladder.num_configs();
        let explore =
            frame < self.cfg.warmup_frames || self.rng.f64() < self.cfg.epsilon;
        let (action, predicted_ms) = if explore {
            let a = self.rng.below(n);
            let mut p = self
                .backend
                .predict(std::slice::from_ref(&self.candidates_at[level][a]))[0];
            if self.time_multiplex {
                p *= self.tm_at[level][a];
            }
            (a, p)
        } else if self.blend_k > 0.0 || self.time_multiplex {
            // exploit under the monotone resource prior: estimates from
            // observed lower rungs carry over (see estimates_at)
            let est = self.estimates_at(level);
            let a = constrained_argmax(&est, &self.rewards, self.cfg.bound_ms);
            (a, est[a])
        } else {
            // paper-exact pure-model exploit (no blend, no prior)
            let (a, costs) = self.backend.solve_with_costs(
                &self.candidates_at[level],
                &self.rewards,
                self.cfg.bound_ms,
            );
            (a, costs[a])
        };

        let rec = self.ladder.set(level).frame(action, frame % self.ladder.num_frames());
        let u = self.candidates_at[level][action].clone();
        // Rung-conditioned observation charge: the feature map cannot see
        // the time-multiplex multiplier (the effective knobs encode granted
        // workers, and a sub-stage-count budget grants the same workers at
        // every such rung), so exact-accounting observations are
        // de-multiplexed before the model update. The model then learns
        // budget-invariant latencies and the prediction side re-charges the
        // analytic factor (`blended_costs_at`), which lets it generalize
        // sub-stage-count quotas instead of relying on the per-(rung,
        // action) empirical blend to correct a tm-confounded fit.
        let tm = self.tm_at[level][action];
        let (y, offset_obs) = if self.time_multiplex && tm > 1.0 {
            let adj: Vec<f64> = rec.stage_ms.iter().map(|&v| v / tm).collect();
            self.backend.group_map().targets(&adj, rec.end_to_end_ms / tm)
        } else {
            self.backend.group_map().targets(&rec.stage_ms, rec.end_to_end_ms)
        };
        self.backend.update(&u, &y);
        self.backend.observe_offset(offset_obs);

        let o = level * n + action;
        if self.obs_count[o] == 0 {
            self.obs_ema_ms[o] = rec.end_to_end_ms;
        } else {
            self.obs_ema_ms[o] +=
                self.ema_alpha * (rec.end_to_end_ms - self.obs_ema_ms[o]);
        }
        self.obs_count[o] += 1;

        StepOutcome {
            frame,
            action,
            explored: explore,
            predicted_ms,
            latency_ms: rec.end_to_end_ms,
            reward: rec.fidelity,
            violation_ms: (rec.end_to_end_ms - self.cfg.bound_ms).max(0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeBackend;
    use crate::simulator::Cluster;
    use crate::workloads::{self, WorkloadConfig};

    fn setup(seed: u64) -> (crate::apps::App, LadderTraceSet) {
        let app = workloads::generate(seed, &WorkloadConfig::default());
        let ladder = LadderTraceSet::generate_on(
            &app,
            &Cluster::default(),
            &[7, 15, 45],
            8,
            80,
            seed ^ 0x7A3E_5EED,
        );
        (app, ladder)
    }

    #[test]
    fn effective_candidates_clamp_only_parallel_knobs() {
        let (app, ladder) = setup(9);
        let cands = effective_candidates(&app, &ladder.configs(), &ladder.levels);
        let par_knobs: Vec<usize> =
            (0..app.graph.len()).filter_map(|s| app.model.par_knob(s)).collect();
        for l in 0..ladder.num_levels() {
            for (a, ks) in ladder.configs().iter().enumerate() {
                let u0 = app.spec.normalize(ks);
                for k in 0..app.spec.num_vars() {
                    if par_knobs.contains(&k) {
                        // clamped grants can only shrink the request
                        assert!(
                            cands[l][a][k] <= u0[k] + 1e-12,
                            "level {l} action {a} knob {k}"
                        );
                    } else {
                        assert_eq!(cands[l][a][k], u0[k], "non-par knob moved");
                    }
                }
            }
        }
        // at a generous top budget nothing is squeezed
        let top = ladder.num_levels() - 1;
        if ladder.levels[top] >= 120 {
            for (a, ks) in ladder.configs().iter().enumerate() {
                assert_eq!(cands[top][a], app.spec.normalize(ks), "action {a}");
            }
        }
    }

    #[test]
    fn step_replays_active_level() {
        let (app, ladder) = setup(3);
        let bound = app.spec.latency_bounds_ms[0];
        let cfg = TunerConfig { epsilon: 0.3, bound_ms: bound, warmup_frames: 4 };
        let backend = NativeBackend::structured(&app.spec);
        let mut ctl = BudgetedController::new(&app, &ladder, Box::new(backend), cfg, 5)
            .with_empirical_blend(8.0);
        ctl.set_level(1);
        for f in 0..30 {
            let s = ctl.step(f);
            let rec = ladder.set(1).frame(s.action, f % ladder.num_frames());
            assert_eq!(s.latency_ms, rec.end_to_end_ms);
            assert_eq!(s.reward, rec.fidelity);
        }
        assert_eq!(ctl.level(), 1);
        assert_eq!(ctl.cores(), 15);
    }

    #[test]
    fn rung_observations_count_steps_per_level() {
        let (app, ladder) = setup(3);
        let bound = app.spec.latency_bounds_ms[0];
        let cfg = TunerConfig { epsilon: 0.3, bound_ms: bound, warmup_frames: 4 };
        let backend = NativeBackend::structured(&app.spec);
        let mut ctl = BudgetedController::new(&app, &ladder, Box::new(backend), cfg, 5)
            .with_empirical_blend(8.0);
        assert_eq!(ctl.rung_observations(), vec![0, 0, 0]);
        ctl.set_level(1);
        for f in 0..10 {
            ctl.step(f);
        }
        ctl.set_level(0);
        for f in 10..15 {
            ctl.step(f);
        }
        assert_eq!(ctl.rung_observations(), vec![5, 10, 0]);
    }

    #[test]
    fn utility_curve_has_one_entry_per_level() {
        let (app, ladder) = setup(11);
        let bound = app.spec.latency_bounds_ms[0];
        let cfg = TunerConfig { epsilon: 0.2, bound_ms: bound * 0.9, warmup_frames: 10 };
        let backend = NativeBackend::structured(&app.spec);
        let mut ctl = BudgetedController::new(&app, &ladder, Box::new(backend), cfg, 7)
            .with_empirical_blend(8.0);
        for f in 0..60 {
            ctl.step(f);
        }
        let curve = ctl.utility_curve();
        assert_eq!(curve.len(), 3);
        for (l, u) in curve.iter().enumerate() {
            assert!((0.0..=1.0).contains(u), "level {l}: utility {u}");
        }
    }

    #[test]
    fn utility_query_does_not_change_trajectory() {
        // the scheduler may interrogate the model at any rung without
        // perturbing what the controller subsequently does
        let (app, ladder) = setup(21);
        let bound = app.spec.latency_bounds_ms[0];
        let run = |query: bool| {
            let cfg =
                TunerConfig { epsilon: 0.2, bound_ms: bound * 0.9, warmup_frames: 5 };
            let backend = NativeBackend::structured(&app.spec);
            let mut ctl =
                BudgetedController::new(&app, &ladder, Box::new(backend), cfg, 13)
                    .with_empirical_blend(8.0);
            let mut actions = Vec::new();
            for f in 0..80 {
                if query && f % 10 == 0 {
                    let _ = ctl.utility_curve();
                }
                actions.push(ctl.step(f).action);
            }
            actions
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn time_multiplex_factors_match_exact_ladder() {
        // the controller's predicted charge and the exact-accounting
        // simulator must agree about sub-stage-count budgets
        let (app, ladder) = setup(7);
        let tm = time_multiplex_factors(&app, &ladder.configs(), &ladder.levels);
        assert_eq!(tm.len(), ladder.num_levels());
        let n_stages = app.graph.len();
        for (l, row) in tm.iter().enumerate() {
            assert_eq!(row.len(), ladder.num_configs());
            for &f in row {
                assert!(f >= 1.0, "level {l}: factor {f}");
                if ladder.levels[l] >= 32 * n_stages {
                    assert_eq!(f, 1.0, "generous budgets never multiplex");
                }
            }
        }
        // a 3-core budget on a >=4-stage pipeline must charge something
        let tiny = time_multiplex_factors(&app, &ladder.configs(), &[3]);
        if n_stages > 3 {
            assert!(tiny[0].iter().all(|&f| f >= n_stages as f64 / 3.0));
        }
    }

    #[test]
    fn exact_accounting_scales_controller_predictions() {
        let (app, ladder) = setup(13);
        let bound = app.spec.latency_bounds_ms[0];
        let cfg = TunerConfig { epsilon: 0.0, bound_ms: bound, warmup_frames: 0 };
        let mk = |tm: bool| {
            BudgetedController::new(
                &app,
                &ladder,
                Box::new(NativeBackend::structured(&app.spec)),
                cfg.clone(),
                3,
            )
            .with_time_multiplex(tm)
        };
        let mut plain = mk(false);
        let mut exact = mk(true);
        // no observations yet: blended costs are pure model x factor
        let a = plain.estimates_at(0);
        let b = exact.estimates_at(0);
        let tm = time_multiplex_factors(&app, &ladder.configs(), &ladder.levels);
        for i in 0..a.len() {
            assert!((b[i] - a[i] * tm[0][i]).abs() < 1e-9, "action {i}");
        }
    }

    #[test]
    fn demultiplexed_observations_generalize_across_rungs() {
        // A light (core-insensitive) app under exact accounting: effective
        // candidates are identical at every rung, so the only cross-rung
        // difference the model could express is the tm charge. Train ONLY
        // at a sub-stage-count rung (tm = stages/4 > 1); the model must
        // still predict the un-multiplexed top-rung latency — which the
        // pre-fix controller (trained on charged targets) over-predicts by
        // the full tm factor (~2.5x here; mirror-validated at ≤11% error
        // for the fix vs ≥120% without it).
        let wcfg = crate::workloads::WorkloadConfig {
            profile: crate::workloads::AppProfile::Light,
            ..Default::default()
        };
        let app = workloads::generate(42, &wcfg);
        let levels = vec![4, 15, 120];
        let ladder = LadderTraceSet::generate_with(
            &app,
            &Cluster::default(),
            &levels,
            6,
            80,
            11,
            true,
        );
        let bound = app.spec.latency_bounds_ms[0];
        // warmup > frames: every step explores, blend off: predictions are
        // pure model x analytic tm
        let cfg = TunerConfig { epsilon: 0.0, bound_ms: bound * 0.9, warmup_frames: 200 };
        let mut ctl = BudgetedController::new(
            &app,
            &ladder,
            Box::new(NativeBackend::structured(&app.spec)),
            cfg,
            5,
        )
        .with_time_multiplex(true);
        ctl.set_level(0);
        for f in 0..80 {
            ctl.step(f);
        }
        let tiny = ctl.blended_costs_at(0);
        let top = ctl.blended_costs_at(2);
        let tm0 = time_multiplex_factors(&app, &ladder.configs(), &levels);
        for a in 0..6 {
            assert!(tm0[0][a] > 1.5, "scenario must actually multiplex");
            // prediction side re-charges the analytic factor exactly
            assert!(
                (tiny[a] / top[a] - tm0[0][a]).abs() < 1e-9,
                "action {a}: {} / {} vs tm {}",
                tiny[a],
                top[a],
                tm0[0][a]
            );
            // and the top-rung prediction tracks the un-multiplexed truth
            let truth = ladder.set(2).traces[a].avg_cost_ms();
            let rel = (top[a] - truth).abs() / truth;
            assert!(
                rel < 0.5,
                "action {a}: top-rung prediction {} vs truth {truth} \
                 (rel {rel:.2}; a tm-confounded model sits at ~1.2-1.7)",
                top[a]
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (app, ladder) = setup(2);
        let bound = app.spec.latency_bounds_ms[0];
        let run = |seed: u64| {
            let cfg =
                TunerConfig { epsilon: 0.25, bound_ms: bound * 0.9, warmup_frames: 5 };
            let backend = NativeBackend::structured(&app.spec);
            let mut ctl =
                BudgetedController::new(&app, &ladder, Box::new(backend), cfg, seed)
                    .with_empirical_blend(8.0);
            (0..60)
                .map(|f| {
                    if f == 30 {
                        ctl.set_level(2);
                    }
                    let s = ctl.step(f);
                    (s.action, s.latency_ms)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(4), run(4));
        assert_ne!(run(4), run(5), "controller seed must matter");
    }
}
