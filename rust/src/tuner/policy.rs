//! Reference policies the controller is scored against (paper Sec. 4.4):
//! the clairvoyant optimum of Eq. 2 and randomized strategies over the
//! action space (the gray payoff regions of Fig. 5/8).

use crate::metrics::PolicyStats;
use crate::trace::TraceSet;

/// Outcome of a reference policy over a trace set.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub avg_reward: f64,
    pub avg_violation_ms: f64,
    pub max_violation_ms: f64,
}

/// The clairvoyant per-frame optimum (Eq. 2 with the *true* cost): for
/// every frame play the action maximizing fidelity among those whose true
/// latency satisfies the bound. This is the "optimal offline solution"
/// the paper's 90%-of-optimum claim is measured against.
pub fn oracle_best(traces: &TraceSet, frames: usize, bound_ms: f64) -> PolicyOutcome {
    let mut stats = PolicyStats::new();
    let n_frames = traces.num_frames();
    for f in 0..frames {
        let ff = f % n_frames;
        let mut best: Option<(f64, f64)> = None; // (reward, latency)
        let mut fallback: Option<(f64, f64)> = None;
        for c in 0..traces.num_configs() {
            let rec = traces.frame(c, ff);
            if rec.end_to_end_ms <= bound_ms {
                if best.map_or(true, |(r, _)| rec.fidelity > r) {
                    best = Some((rec.fidelity, rec.end_to_end_ms));
                }
            }
            if fallback.map_or(true, |(_, l)| rec.end_to_end_ms < l) {
                fallback = Some((rec.fidelity, rec.end_to_end_ms));
            }
        }
        // detlint: allow(unwrap) — candidate grids are non-empty: TraceSet construction asserts configs >= 1
        let (r, l) = best.or(fallback).expect("non-empty action space");
        stats.observe(r, l, bound_ms);
    }
    PolicyOutcome {
        avg_reward: stats.avg_reward(),
        avg_violation_ms: stats.avg_violation_ms(),
        max_violation_ms: stats.max_violation_ms(),
    }
}

/// The *best fixed action* under the bound (average-case): the pure
/// strategy a static configuration would give you.
pub fn best_fixed_action(traces: &TraceSet, bound_ms: f64) -> (usize, PolicyOutcome) {
    let mut best: Option<(usize, f64)> = None;
    for c in 0..traces.num_configs() {
        let avg_cost = traces.traces[c].avg_cost_ms();
        let avg_rew = traces.traces[c].avg_fidelity();
        if avg_cost <= bound_ms && best.map_or(true, |(_, r)| avg_rew > r) {
            best = Some((c, avg_rew));
        }
    }
    let c = best.map(|(c, _)| c).unwrap_or_else(|| {
        // nothing feasible on average: least-violating action
        (0..traces.num_configs())
            .min_by(|&a, &b| {
                traces.traces[a]
                    .avg_cost_ms()
                    .partial_cmp(&traces.traces[b].avg_cost_ms())
                    .unwrap()
            })
            // detlint: allow(unwrap) — min_by over 0..num_configs, non-empty by the same construction assert
            .unwrap()
    });
    (c, fixed_action(traces, c, bound_ms))
}

/// Outcome of always playing action `c`.
pub fn fixed_action(traces: &TraceSet, c: usize, bound_ms: f64) -> PolicyOutcome {
    let mut stats = PolicyStats::new();
    for rec in traces.traces[c].frames.iter() {
        stats.observe(rec.fidelity, rec.end_to_end_ms, bound_ms);
    }
    PolicyOutcome {
        avg_reward: stats.avg_reward(),
        avg_violation_ms: stats.avg_violation_ms(),
        max_violation_ms: stats.max_violation_ms(),
    }
}

/// (violation, reward) payoff of every pure strategy — the points whose
/// convex hull is the Fig. 8 gray region.
pub fn pure_payoffs(traces: &TraceSet, bound_ms: f64) -> Vec<(f64, f64)> {
    (0..traces.num_configs())
        .map(|c| {
            let o = fixed_action(traces, c, bound_ms);
            (o.avg_violation_ms, o.avg_reward)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    fn traces() -> TraceSet {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        TraceSet::generate(&app, 15, 200, 11)
    }

    #[test]
    fn oracle_dominates_fixed_actions() {
        let ts = traces();
        let bound = 80.0;
        let oracle = oracle_best(&ts, 200, bound);
        let (_, fixed) = best_fixed_action(&ts, bound);
        assert!(oracle.avg_reward >= fixed.avg_reward - 1e-9);
    }

    #[test]
    fn oracle_violation_zero_when_feasible_exists() {
        let ts = traces();
        // generous bound: every frame has some feasible action
        let oracle = oracle_best(&ts, 200, 500.0);
        assert_eq!(oracle.avg_violation_ms, 0.0);
    }

    #[test]
    fn tight_bound_forces_violations() {
        let ts = traces();
        let oracle = oracle_best(&ts, 200, 1.0); // impossible bound
        assert!(oracle.avg_violation_ms > 0.0);
    }

    #[test]
    fn pure_payoffs_shape() {
        let ts = traces();
        let p = pure_payoffs(&ts, 80.0);
        assert_eq!(p.len(), 15);
        assert!(p.iter().all(|&(v, r)| v >= 0.0 && (0.0..=1.0).contains(&r)));
    }

    #[test]
    fn fixed_action_consistency() {
        let ts = traces();
        let o = fixed_action(&ts, 3, 60.0);
        let manual: f64 = ts.traces[3]
            .frames
            .iter()
            .map(|f| (f.end_to_end_ms - 60.0).max(0.0))
            .sum::<f64>()
            / ts.traces[3].frames.len() as f64;
        assert!((o.avg_violation_ms - manual).abs() < 1e-9);
    }
}
