//! The ε-greedy constrained controller (paper Sec. 3.1 / 4.4).
//!
//! At every frame the controller either *explores* (probability ε: play a
//! uniformly random action, so the latency model keeps learning off-policy
//! regions) or *exploits* (solve Eq. 2: the feasible-fidelity argmax under
//! the current latency model). Either way the observation of the played
//! action updates the model. ε = 1/√T is the paper's recommended setting
//! (≈ 0.03 for T = 1000 — "90% of the optimal fidelity by exploring the
//! parameter space only 3% of the time").

pub mod budgeted;
pub mod policy;

pub use budgeted::BudgetedController;

use crate::apps::spec::AppSpec;
use crate::metrics::PolicyStats;
use crate::runtime::Backend;
use crate::trace::TraceSet;
use crate::util::Rng;

/// Controller configuration.
#[derive(Debug, Clone)]
pub struct TunerConfig {
    /// Exploration rate ε ∈ [0, 1].
    pub epsilon: f64,
    /// Latency bound L (ms).
    pub bound_ms: f64,
    /// Warm-up frames of forced exploration before the first exploit
    /// (the model starts at zero; a handful of samples keeps the first
    /// exploit from being arbitrary).
    pub warmup_frames: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig { epsilon: 0.03, bound_ms: 100.0, warmup_frames: 20 }
    }
}

impl TunerConfig {
    /// The paper's ε = 1/√T rule.
    pub fn epsilon_for_horizon(t: usize) -> f64 {
        1.0 / (t as f64).sqrt()
    }
}

/// One frame's controller decision + observed outcome.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub frame: usize,
    /// Index into the trace-set's action space.
    pub action: usize,
    pub explored: bool,
    /// Model's latency prediction for the played action (ms).
    pub predicted_ms: f64,
    /// Observed end-to-end latency (ms).
    pub latency_ms: f64,
    /// Observed fidelity.
    pub reward: f64,
    /// max(latency − L, 0) (ms).
    pub violation_ms: f64,
}

/// Aggregate outcome of a controller run.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub avg_reward: f64,
    pub avg_violation_ms: f64,
    pub max_violation_ms: f64,
    pub violation_rate: f64,
    pub explore_frames: usize,
    pub steps: Vec<StepOutcome>,
}

impl RunOutcome {
    /// Fraction of frames at index `>= warmup` whose observed latency met
    /// `bound_ms` — the fleet acceptance metric ("post-warmup frames under
    /// the bound"). Returns 1.0 when no frames remain past the warmup.
    pub fn bound_met_frac_after(&self, warmup: usize, bound_ms: f64) -> f64 {
        let (mut tail, mut met) = (0usize, 0usize);
        for s in self.steps.iter().filter(|s| s.frame >= warmup) {
            tail += 1;
            if s.latency_ms <= bound_ms {
                met += 1;
            }
        }
        if tail == 0 {
            return 1.0;
        }
        met as f64 / tail as f64
    }

    /// First frame index at which the trailing-`window` mean reward
    /// reaches `target` (and the window is full) — the convergence-frame
    /// measure aggregated in fleet reports. `None` if never reached.
    pub fn convergence_frame(&self, window: usize, target: f64) -> Option<usize> {
        if self.steps.len() < window || window == 0 {
            return None;
        }
        let mut sum: f64 = self.steps[..window].iter().map(|s| s.reward).sum();
        if sum / window as f64 >= target {
            return Some(self.steps[window - 1].frame);
        }
        for i in window..self.steps.len() {
            sum += self.steps[i].reward - self.steps[i - window].reward;
            if sum / window as f64 >= target {
                return Some(self.steps[i].frame);
            }
        }
        None
    }
}

/// ε-greedy controller over a trace-based action space (the paper's
/// "predefined alternative futures" methodology, Sec. 4.1).
pub struct EpsGreedyController<'a> {
    traces: &'a TraceSet,
    backend: Box<dyn Backend>,
    cfg: TunerConfig,
    rng: Rng,
    /// Normalized knob vectors of the candidate actions.
    candidates: Vec<Vec<f64>>,
    /// Known per-action expected fidelity (the paper assumes r is known;
    /// these are the Fig. 5 rewards).
    rewards: Vec<f64>,
    /// Shrinkage count of the per-action empirical cost blend; 0 disables
    /// it and reproduces the paper's pure-model exploit exactly.
    blend_k: f64,
    /// EMA rate of the per-action observed-cost tracker.
    ema_alpha: f64,
    obs_count: Vec<u64>,
    obs_ema_ms: Vec<f64>,
}

impl<'a> EpsGreedyController<'a> {
    pub fn new(
        spec: &AppSpec,
        traces: &'a TraceSet,
        backend: Box<dyn Backend>,
        cfg: TunerConfig,
        seed: u64,
    ) -> Self {
        assert!(traces.num_configs() > 0, "empty action space");
        assert!((0.0..=1.0).contains(&cfg.epsilon));
        let candidates: Vec<Vec<f64>> = traces
            .configs()
            .iter()
            .map(|c| spec.normalize(c))
            .collect();
        let rewards = traces.traces.iter().map(|t| t.avg_fidelity()).collect();
        let n = candidates.len();
        EpsGreedyController {
            traces,
            backend,
            cfg,
            rng: Rng::new(seed),
            candidates,
            rewards,
            blend_k: 0.0,
            ema_alpha: 0.2,
            obs_count: vec![0; n],
            obs_ema_ms: vec![0.0; n],
        }
    }

    /// Enable per-action empirical cost blending in the exploit path:
    /// feasibility is judged on `(k·model + n_a·ema_a) / (k + n_a)`
    /// instead of the model alone. The polynomial model generalizes
    /// across actions but can carry a persistent bias at specific corners
    /// of the knob space; after an action has been played a few times its
    /// own observed latency dominates, so a systematically under-predicted
    /// infeasible action cannot be exploited forever. With `k = 0` (the
    /// default) behavior is exactly the paper's Eq. 2 exploit.
    pub fn with_empirical_blend(mut self, k: f64) -> Self {
        assert!(k >= 0.0);
        self.blend_k = k;
        self
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    pub fn action_rewards(&self) -> &[f64] {
        &self.rewards
    }

    /// Blended cost estimates for every candidate (exploit path of the
    /// empirical-blend mode).
    fn blended_costs(&mut self) -> Vec<f64> {
        let costs = self.backend.predict(&self.candidates);
        costs
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let n = self.obs_count[i] as f64;
                (self.blend_k * c + n * self.obs_ema_ms[i]) / (self.blend_k + n)
            })
            .collect()
    }

    /// Run one frame: choose an action, observe its trace outcome, learn.
    pub fn step(&mut self, frame: usize) -> StepOutcome {
        let explore =
            frame < self.cfg.warmup_frames || self.rng.f64() < self.cfg.epsilon;
        let (action, predicted_ms) = if explore {
            let a = self.rng.below(self.candidates.len());
            let p = self.backend.predict(std::slice::from_ref(&self.candidates[a]))[0];
            (a, p)
        } else if self.blend_k > 0.0 {
            // constrained argmax over the blended estimates, through the
            // same routine the backend solve uses (identical tie-breaking)
            let est = self.blended_costs();
            let a = crate::runtime::constrained_argmax(&est, &self.rewards, self.cfg.bound_ms);
            (a, est[a])
        } else {
            // the solve artifact computes every candidate's predicted
            // latency anyway — reuse it instead of a second dispatch
            let (a, costs) =
                self.backend
                    .solve_with_costs(&self.candidates, &self.rewards, self.cfg.bound_ms);
            (a, costs[a])
        };
        let u = self.candidates[action].clone();

        // "switch futures": observe the pre-recorded frame of that action
        let rec = self.traces.frame(action, frame % self.traces.num_frames());
        let (y, offset_obs) = self
            .backend
            .group_map()
            .targets(&rec.stage_ms, rec.end_to_end_ms);
        self.backend.update(&u, &y);
        self.backend.observe_offset(offset_obs);

        // per-action observed-cost tracker (drives the empirical blend;
        // updated unconditionally — with blend_k == 0 it is inert)
        if self.obs_count[action] == 0 {
            self.obs_ema_ms[action] = rec.end_to_end_ms;
        } else {
            self.obs_ema_ms[action] +=
                self.ema_alpha * (rec.end_to_end_ms - self.obs_ema_ms[action]);
        }
        self.obs_count[action] += 1;

        StepOutcome {
            frame,
            action,
            explored: explore,
            predicted_ms,
            latency_ms: rec.end_to_end_ms,
            reward: rec.fidelity,
            violation_ms: (rec.end_to_end_ms - self.cfg.bound_ms).max(0.0),
        }
    }

    /// Run `frames` frames and aggregate.
    pub fn run(&mut self, frames: usize) -> RunOutcome {
        let mut stats = PolicyStats::new();
        let mut steps = Vec::with_capacity(frames);
        let mut explore_frames = 0;
        for f in 0..frames {
            let s = self.step(f);
            stats.observe(s.reward, s.latency_ms, self.cfg.bound_ms);
            if s.explored {
                explore_frames += 1;
            }
            steps.push(s);
        }
        RunOutcome {
            avg_reward: stats.avg_reward(),
            avg_violation_ms: stats.avg_violation_ms(),
            max_violation_ms: stats.max_violation_ms(),
            violation_rate: stats.violation_rate(),
            explore_frames,
            steps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::learner::Variant;
    use crate::runtime::native::NativeBackend;

    fn setup(name: &str) -> (crate::apps::App, TraceSet) {
        let app = app_by_name(name, find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 20, 300, 9);
        (app, traces)
    }

    #[test]
    fn epsilon_rule() {
        assert!((TunerConfig::epsilon_for_horizon(1000) - 0.0316).abs() < 0.01);
    }

    #[test]
    fn explores_at_configured_rate() {
        let (app, traces) = setup("pose");
        let backend = NativeBackend::new(&app.spec, Variant::Structured, 3);
        let cfg = TunerConfig { epsilon: 0.5, bound_ms: 60.0, warmup_frames: 0 };
        let mut ctl =
            EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 1);
        let out = ctl.run(300);
        let rate = out.explore_frames as f64 / 300.0;
        assert!((0.38..0.62).contains(&rate), "explore rate {rate}");
    }

    #[test]
    fn pure_exploit_after_warmup_converges_to_feasible() {
        let (app, traces) = setup("pose");
        let backend = NativeBackend::new(&app.spec, Variant::Structured, 3);
        let cfg = TunerConfig { epsilon: 0.05, bound_ms: 80.0, warmup_frames: 30 };
        let mut ctl =
            EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 2);
        let out = ctl.run(300);
        // tail of the run should mostly satisfy the bound
        let tail: Vec<&StepOutcome> =
            out.steps.iter().filter(|s| s.frame >= 150 && !s.explored).collect();
        assert!(!tail.is_empty());
        let viol_rate = tail.iter().filter(|s| s.violation_ms > 0.0).count() as f64
            / tail.len() as f64;
        assert!(viol_rate < 0.5, "late exploit violation rate {viol_rate}");
    }

    #[test]
    fn higher_epsilon_means_lower_reward() {
        // the right arm of the paper's U-shape: mostly-exploring policies
        // sacrifice fidelity
        let (app, traces) = setup("motion_sift");
        let run_with = |eps: f64| {
            let backend = NativeBackend::new(&app.spec, Variant::Structured, 3);
            let cfg = TunerConfig { epsilon: eps, bound_ms: 150.0, warmup_frames: 20 };
            let mut ctl = EpsGreedyController::new(
                &app.spec,
                &traces,
                Box::new(backend),
                cfg,
                3,
            );
            ctl.run(300).avg_reward
        };
        let greedy = run_with(0.05);
        let random = run_with(1.0);
        assert!(
            greedy > random - 0.02,
            "greedy {greedy} should beat mostly-random {random}"
        );
    }

    #[test]
    fn zero_blend_is_identity() {
        // with_empirical_blend(0) must reproduce the default trajectory
        let (app, traces) = setup("pose");
        let run = |blend: Option<f64>| {
            let backend = NativeBackend::structured(&app.spec);
            let cfg = TunerConfig { epsilon: 0.2, bound_ms: 70.0, warmup_frames: 5 };
            let mut ctl =
                EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 4);
            if let Some(k) = blend {
                ctl = ctl.with_empirical_blend(k);
            }
            ctl.run(100)
        };
        let a = run(None);
        let b = run(Some(0.0));
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.action, sb.action);
            assert_eq!(sa.explored, sb.explored);
            assert_eq!(sa.predicted_ms, sb.predicted_ms);
        }
    }

    #[test]
    fn empirical_blend_steers_off_underpredicted_actions() {
        // two-action synthetic space: the high-fidelity action costs 100.5
        // ms against a 50 ms bound; with blending the controller must park
        // on the feasible action once it has observed the slow one
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let spec = &app.spec;
        let mk_frames = |stage_ms: Vec<f64>, fid: f64| {
            let e2e: f64 = stage_ms.iter().sum();
            let mut block = crate::trace::FrameBlock::new(stage_ms.len());
            for _ in 0..60 {
                block.push(&stage_ms, e2e, fid);
            }
            std::sync::Arc::new(block)
        };
        let slow = crate::trace::Trace {
            config: spec.defaults(),
            frames: mk_frames(vec![1.0, 2.0, 30.0, 30.0, 20.0, 16.0, 1.0], 0.9),
        };
        let fast = crate::trace::Trace {
            config: spec.denormalize(&[0.9; 5]),
            frames: mk_frames(vec![0.5, 0.5, 2.0, 3.0, 2.0, 1.5, 0.5], 0.5),
        };
        let traces = TraceSet {
            app: "pose".into(),
            seed: 0,
            traces: vec![slow, fast],
            stage_names: spec.stages.iter().map(|s| s.name.clone()).collect(),
        };
        let backend = NativeBackend::structured(spec);
        let cfg = TunerConfig { epsilon: 0.0, bound_ms: 50.0, warmup_frames: 2 };
        let mut ctl =
            EpsGreedyController::new(spec, &traces, Box::new(backend), cfg, 99)
                .with_empirical_blend(8.0);
        let out = ctl.run(60);
        for s in &out.steps[40..] {
            assert_eq!(s.action, 1, "frame {} drifted back to the slow action", s.frame);
        }
        let violations = out.steps.iter().filter(|s| s.violation_ms > 0.0).count();
        assert!(violations <= 6, "{violations} violations");
    }

    #[test]
    fn bound_met_and_convergence_helpers() {
        let mk = |frame: usize, latency_ms: f64, reward: f64| StepOutcome {
            frame,
            action: 0,
            explored: false,
            predicted_ms: latency_ms,
            latency_ms,
            reward,
            violation_ms: (latency_ms - 50.0).max(0.0),
        };
        let steps = vec![
            mk(0, 80.0, 0.1),
            mk(1, 80.0, 0.1),
            mk(2, 40.0, 0.9),
            mk(3, 60.0, 0.9),
            mk(4, 40.0, 0.9),
            mk(5, 40.0, 0.9),
        ];
        let out = RunOutcome {
            avg_reward: 0.0,
            avg_violation_ms: 0.0,
            max_violation_ms: 0.0,
            violation_rate: 0.0,
            explore_frames: 0,
            steps,
        };
        // frames 2..=5: latencies 40,60,40,40 -> 3/4 under the 50ms bound
        assert!((out.bound_met_frac_after(2, 50.0) - 0.75).abs() < 1e-12);
        // past the end: vacuously met
        assert_eq!(out.bound_met_frac_after(10, 50.0), 1.0);
        // trailing-2 mean reward first reaches 0.9 at frame 3
        assert_eq!(out.convergence_frame(2, 0.9), Some(3));
        assert_eq!(out.convergence_frame(2, 0.95), None);
    }

    #[test]
    fn steps_record_consistent_violation() {
        let (app, traces) = setup("pose");
        let backend = NativeBackend::new(&app.spec, Variant::Unstructured, 3);
        let cfg = TunerConfig { epsilon: 0.2, bound_ms: 70.0, warmup_frames: 5 };
        let mut ctl =
            EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 4);
        for s in ctl.run(100).steps {
            assert!((s.violation_ms - (s.latency_ms - 70.0).max(0.0)).abs() < 1e-9);
            assert!(s.action < 20);
        }
    }
}
