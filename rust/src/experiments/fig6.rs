//! Figure 6: quality of online latency predictors vs their complexity —
//! linear, quadratic, and cubic kernels, learned online by randomly
//! sampling an action each frame, compared by the cumulative average of
//! their expected and max-norm errors up to each frame; dashed lines are
//! the corresponding offline (batch) predictors.

use anyhow::Result;

use crate::util::Rng;

use super::{app_tag, f, ExperimentCtx};
use crate::apps::spec::AppSpec;
use crate::learner::offline::{self, samples_from_traces};
use crate::learner::{StagePredictor, Variant};
use crate::metrics::ErrorTracker;
use crate::trace::TraceSet;

pub const DEGREES: [usize; 3] = [1, 2, 3];

/// Error series of one online predictor.
pub struct Series {
    pub degree: usize,
    /// (cumulative expected error, cumulative max-norm error) per frame.
    pub per_frame: Vec<(f64, f64)>,
    /// Offline baseline: (expected, max-norm) over the full trace.
    pub offline: (f64, f64),
}

/// Run the Fig. 6 protocol for one app: random action every frame, online
/// update, cumulative errors.
pub fn compute(
    spec: &AppSpec,
    traces: &TraceSet,
    variant: Variant,
    frames: usize,
    seed: u64,
) -> Vec<Series> {
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| spec.normalize(c)).collect();
    DEGREES
        .iter()
        .map(|&degree| {
            let mut pred = StagePredictor::new(spec, variant, degree);
            let mut tracker = ErrorTracker::new();
            let mut rng = Rng::new(seed);
            let mut per_frame = Vec::with_capacity(frames);
            for t in 0..frames {
                let a = rng.below(candidates.len());
                let rec = traces.frame(a, t % traces.num_frames());
                let before =
                    pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
                per_frame.push(tracker.observe((before - rec.end_to_end_ms).abs()));
            }
            // offline baseline (dashed): batch fit on the whole trace set
            let samples = samples_from_traces(spec, traces);
            let mut off = offline::fit(spec, variant, degree, &samples, 15, seed);
            let offline = (
                offline::mean_abs_error(&mut off, &samples),
                offline::max_abs_error(&mut off, &samples),
            );
            Series { degree, per_frame, offline }
        })
        .collect()
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    for app in &ctx.experiment_apps() {
        let (app_obj, traces) = ctx.app_traces(app)?;
        let series =
            compute(&app_obj.spec, &traces, Variant::Unstructured, ctx.frames, ctx.seed);
        let mut csv = ctx.csv(
            &format!("fig6_{}", app_tag(app)),
            "frame,linear_expected,linear_maxnorm,quadratic_expected,quadratic_maxnorm,cubic_expected,cubic_maxnorm",
        )?;
        for t in 0..ctx.frames {
            let mut row = vec![t.to_string()];
            for s in &series {
                row.push(f(s.per_frame[t].0));
                row.push(f(s.per_frame[t].1));
            }
            csv.row(&row)?;
        }
        // offline dashed lines as sentinel rows (frame = -1)
        let mut off_row = vec!["-1".to_string()];
        for s in &series {
            off_row.push(f(s.offline.0));
            off_row.push(f(s.offline.1));
        }
        csv.row(&off_row)?;
        let path = csv.finish()?;
        let finals: Vec<String> = series
            .iter()
            .map(|s| {
                format!(
                    "deg{}: exp {:.2} (off {:.2}) max {:.1}",
                    s.degree,
                    // detlint: allow(unwrap) — per_frame is non-empty: the harness rejects zero-frame runs
                    s.per_frame.last().unwrap().0,
                    s.offline.0,
                    // detlint: allow(unwrap) — per_frame is non-empty: the harness rejects zero-frame runs
                    s.per_frame.last().unwrap().1
                )
            })
            .collect();
        crate::log_info!("fig6[{app}]: {} -> {}", finals.join(" | "), path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn cubic_beats_linear_and_errors_shrink() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 12, 250, 3);
        let series = compute(&app.spec, &traces, Variant::Unstructured, 1000, 5);
        let lin = &series[0];
        let cub = &series[2];
        // errors decrease over time (paper: "errors ... tend to decrease")
        let early = cub.per_frame[60].0;
        let late = cub.per_frame.last().unwrap().0;
        assert!(late < early, "cubic expected err should fall: {early} -> {late}");
        // cubic < linear in final expected error
        assert!(
            cub.per_frame.last().unwrap().0 < lin.per_frame.last().unwrap().0,
            "cubic {} vs linear {}",
            cub.per_frame.last().unwrap().0,
            lin.per_frame.last().unwrap().0
        );
    }

    #[test]
    fn online_approaches_offline() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 12, 250, 4);
        let series = compute(&app.spec, &traces, Variant::Unstructured, 1500, 6);
        for s in &series {
            let online_final = s.per_frame.last().unwrap().0;
            // "all predictors are almost as good as their offline
            // counterparts" — allow a generous online/offline gap
            assert!(
                online_final < s.offline.0 * 4.0 + 10.0,
                "deg {}: online {} offline {}",
                s.degree,
                online_final,
                s.offline.0
            );
        }
    }
}
