//! Figure 7: structured vs unstructured cubic latency predictors, learned
//! online with random action sampling (same protocol as Fig. 6), compared
//! by cumulative expected and max-norm error — plus the Sec. 4.3 feature
//! economics (30 vs 56 features on MotionSIFT, ~2× cheaper updates).

use anyhow::Result;

use crate::util::Rng;

use super::{app_tag, f, ExperimentCtx};
use crate::apps::spec::AppSpec;
use crate::learner::{StagePredictor, Variant};
use crate::metrics::ErrorTracker;
use crate::trace::TraceSet;

pub struct Fig7 {
    /// Per frame: (unstructured expected, unstructured max-norm,
    /// structured expected, structured max-norm).
    pub per_frame: Vec<(f64, f64, f64, f64)>,
    pub unstructured_features: usize,
    pub structured_features: usize,
}

pub fn compute(spec: &AppSpec, traces: &TraceSet, frames: usize, seed: u64) -> Fig7 {
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| spec.normalize(c)).collect();
    let mut un = StagePredictor::new(spec, Variant::Unstructured, 3);
    let mut st = StagePredictor::new(spec, Variant::Structured, 3);
    let mut t_un = ErrorTracker::new();
    let mut t_st = ErrorTracker::new();
    // identical action sequence for both predictors
    let mut rng = Rng::new(seed);
    let mut per_frame = Vec::with_capacity(frames);
    for t in 0..frames {
        let a = rng.below(candidates.len());
        let rec = traces.frame(a, t % traces.num_frames());
        let u = &candidates[a];
        let p_un = un.observe(u, &rec.stage_ms, rec.end_to_end_ms);
        let p_st = st.observe(u, &rec.stage_ms, rec.end_to_end_ms);
        let (ue, um) = t_un.observe((p_un - rec.end_to_end_ms).abs());
        let (se, sm) = t_st.observe((p_st - rec.end_to_end_ms).abs());
        per_frame.push((ue, um, se, sm));
    }
    Fig7 {
        per_frame,
        unstructured_features: un.num_features(),
        structured_features: st.num_features(),
    }
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    for app in &ctx.experiment_apps() {
        let (app_obj, traces) = ctx.app_traces(app)?;
        let r = compute(&app_obj.spec, &traces, ctx.frames, ctx.seed);
        let mut csv = ctx.csv(
            &format!("fig7_{}", app_tag(app)),
            "frame,unstructured_expected,unstructured_maxnorm,structured_expected,structured_maxnorm",
        )?;
        for (t, &(ue, um, se, sm)) in r.per_frame.iter().enumerate() {
            csv.row(&[t.to_string(), f(ue), f(um), f(se), f(sm)])?;
        }
        let path = csv.finish()?;
        // detlint: allow(unwrap) — per_frame is non-empty: the harness rejects zero-frame runs
        let last = r.per_frame.last().unwrap();
        crate::log_info!(
            "fig7[{app}]: features {} vs {} | final expected {:.2} vs {:.2} | max-norm {:.1} vs {:.1} (unstructured vs structured) -> {}",
            r.unstructured_features,
            r.structured_features,
            last.0,
            last.2,
            last.1,
            last.3,
            path.display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn motion_sift_structured_smaller_and_comparable() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 12, 250, 8);
        let r = compute(&app.spec, &traces, 1500, 9);
        // Sec. 4.3: 30 vs 56 features
        assert_eq!(r.structured_features, 30);
        assert_eq!(r.unstructured_features, 56);
        let last = r.per_frame.last().unwrap();
        // "expected errors of unstructured and structured latency
        // predictors are almost identical" — same order of magnitude
        assert!(
            last.2 < last.0 * 2.5 + 5.0,
            "structured expected {} vs unstructured {}",
            last.2,
            last.0
        );
    }

    #[test]
    fn structured_maxnorm_competitive() {
        // "max-norm errors of structured latency predictors can be
        // significantly smaller" — require at least not-much-worse
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 12, 250, 10);
        let r = compute(&app.spec, &traces, 1500, 11);
        let last = r.per_frame.last().unwrap();
        assert!(
            last.3 <= last.1 * 1.5,
            "structured max-norm {} vs unstructured {}",
            last.3,
            last.1
        );
    }
}
