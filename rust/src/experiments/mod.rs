//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (Sec. 4) as CSV series + printed summary rows.
//!
//! | module    | paper artifact |
//! |-----------|----------------|
//! | [`fig5`]  | Fig. 5 — payoff clouds + randomized-strategy convex hull |
//! | [`fig6`]  | Fig. 6 — linear/quadratic/cubic online predictors vs offline |
//! | [`fig7`]  | Fig. 7 — structured vs unstructured predictors |
//! | [`fig8`]  | Fig. 8 — reward & constraint violation vs ε, payoff regions |
//! | [`claims`]| headline claims: 90%-of-optimum @ 3% exploration, violation |
//!
//! Absolute numbers come from the simulated testbed, not the authors'
//! cluster; the *shapes* (orderings, crossovers, U-curves) are the
//! reproduction targets — see EXPERIMENTS.md.

pub mod claims;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;

use anyhow::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::apps::registry::app_by_name;
use crate::apps::App;
use crate::trace::TraceSet;

/// Shared context: where specs/traces/results live.
pub struct ExperimentCtx {
    pub spec_dir: PathBuf,
    pub trace_dir: PathBuf,
    pub out_dir: PathBuf,
    pub seed: u64,
    /// Frames per experiment run (paper: 1000).
    pub frames: usize,
    /// Generated workloads (`gen:SEED` registry names) each experiment
    /// additionally runs for a scenario-diversity variant beside the two
    /// paper apps. Empty disables the variants.
    pub generated: Vec<String>,
}

impl ExperimentCtx {
    pub fn new(
        spec_dir: impl Into<PathBuf>,
        trace_dir: impl Into<PathBuf>,
        out_dir: impl Into<PathBuf>,
    ) -> Self {
        ExperimentCtx {
            spec_dir: spec_dir.into(),
            trace_dir: trace_dir.into(),
            out_dir: out_dir.into(),
            seed: 7,
            frames: 1000,
            generated: vec!["gen:11".into()],
        }
    }

    /// The apps every experiment covers: the two paper case studies plus
    /// the configured generated workloads.
    pub fn experiment_apps(&self) -> Vec<String> {
        let mut names = vec!["pose".to_string(), "motion_sift".to_string()];
        names.extend(self.generated.iter().cloned());
        names
    }

    /// Load (or generate + cache) an app and its 30×1000 trace set.
    pub fn app_traces(&self, name: &str) -> Result<(App, TraceSet)> {
        let app = app_by_name(name, &self.spec_dir)?;
        let traces = TraceSet::load_or_generate(&app, &self.trace_dir, self.seed)?;
        Ok((app, traces))
    }

    /// Open `results/<name>.csv` with a header row.
    pub fn csv(&self, name: &str, header: &str) -> Result<CsvWriter> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = self.out_dir.join(format!("{name}.csv"));
        let mut file = std::io::BufWriter::new(
            std::fs::File::create(&path)
                .with_context(|| format!("creating {}", path.display()))?,
        );
        writeln!(file, "{header}")?;
        Ok(CsvWriter { file, path })
    }
}

/// Minimal CSV emitter.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    pub path: PathBuf,
}

impl CsvWriter {
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, fields: std::fmt::Arguments<'_>) -> Result<()> {
        writeln!(self.file, "{fields}")?;
        Ok(())
    }

    pub fn finish(mut self) -> Result<PathBuf> {
        self.file.flush()?;
        Ok(self.path)
    }
}

/// Format a float compactly for CSV.
pub fn f(x: f64) -> String {
    format!("{x:.6}")
}

/// Filesystem-safe tag for an app name (`gen:11` → `gen11`).
pub fn app_tag(name: &str) -> String {
    name.replace(':', "")
}

/// Run every experiment (the `repro figures --all` entry point).
pub fn run_all(ctx: &ExperimentCtx) -> Result<()> {
    fig5::run(ctx)?;
    fig6::run(ctx)?;
    fig7::run(ctx)?;
    fig8::run(ctx)?;
    claims::run(ctx)?;
    Ok(())
}

/// Resolve default context directories relative to the repo root.
pub fn default_ctx(out_dir: Option<&Path>) -> Result<ExperimentCtx> {
    let spec_dir = crate::apps::spec::find_spec_dir(None)?;
    // detlint: allow(unwrap) — find_spec_dir returns a specs/ directory, which always has a parent
    let root = spec_dir.parent().unwrap().to_path_buf();
    Ok(ExperimentCtx::new(
        spec_dir,
        root.join("traces"),
        out_dir.map(|p| p.to_path_buf()).unwrap_or_else(|| root.join("results")),
    ))
}
