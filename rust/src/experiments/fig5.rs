//! Figure 5: average reward (fidelity) vs average cost (latency) of the
//! 30 random action configurations, plus the convex hull — the payoffs
//! feasible by randomized strategies over the action space.

use anyhow::Result;

use super::{app_tag, f, ExperimentCtx};
use crate::metrics::convex_hull;

/// Per-app result (exposed for tests and the claims module).
pub struct Fig5 {
    pub app: String,
    /// (avg cost ms, avg reward) per configuration — the gray crosses.
    pub payoffs: Vec<(f64, f64)>,
    /// CCW hull of the payoffs.
    pub hull: Vec<(f64, f64)>,
}

pub fn compute(ctx: &ExperimentCtx, app_name: &str) -> Result<Fig5> {
    let (_, traces) = ctx.app_traces(app_name)?;
    let payoffs = traces.payoffs();
    let hull = convex_hull(&payoffs);
    Ok(Fig5 { app: app_name.to_string(), payoffs, hull })
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    for app in &ctx.experiment_apps() {
        let r = compute(ctx, app)?;
        let mut csv = ctx.csv(&format!("fig5_{}", app_tag(app)), "kind,cost_ms,reward")?;
        for &(c, rew) in &r.payoffs {
            csv.row(&["point".into(), f(c), f(rew)])?;
        }
        for &(c, rew) in &r.hull {
            csv.row(&["hull".into(), f(c), f(rew)])?;
        }
        let path = csv.finish()?;
        let (cmin, cmax) = r
            .payoffs
            .iter()
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), &(c, _)| (lo.min(c), hi.max(c)));
        crate::log_info!(
            "fig5[{app}]: {} configs, cost {:.1}..{:.1} ms, hull {} vertices -> {}",
            r.payoffs.len(),
            cmin,
            cmax,
            r.hull.len(),
            path.display()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::hull::hull_contains;

    #[test]
    fn payoff_cloud_and_hull() {
        let dir = crate::apps::spec::find_spec_dir(None).unwrap();
        let mut app = crate::apps::registry::app_by_name("pose", &dir).unwrap();
        app.spec.trace_configs = 8;
        app.spec.trace_frames = 30;
        let traces = crate::trace::TraceSet::generate_default(&app, 1);
        let payoffs = traces.payoffs();
        let hull = convex_hull(&payoffs);
        for &p in &payoffs {
            assert!(hull_contains(&hull, p));
        }
        // fidelity/cost trade-off visible: the cheapest config should not
        // also be the most accurate
        let cheapest = payoffs
            .iter()
            .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
            .unwrap();
        let best = payoffs
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert!(best.0 > cheapest.0, "best-fidelity config must cost more");
    }
}
