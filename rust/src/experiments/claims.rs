//! Headline claims of the paper, re-measured on the simulated testbed:
//!
//! * C1 — "operating points can be found that achieve 90% of the optimal
//!   fidelity by exploring the parameter space only 3% of the time"
//!   (abstract; Sec. 4.4: rewards "always within 90 percent of the
//!   optimum" for the (1/√T)-greedy policies).
//! * C2 — "the average constraint violation in all experiments is about
//!   0.03 second and never exceeds 0.1 second. When measured relatively
//!   to the latency bound L, the average and worst-case constraint
//!   violations are 23 and 50 percent."
//! * C3 — Sec. 4.3: "it takes 30 and 56 features to describe the
//!   structured and unstructured spaces ... updating of the structured
//!   predictor should be twice as fast."
//! * C4 — Sec. 4.2: the frame-600 scene change bumps prediction error,
//!   then the online learner adapts.

use anyhow::Result;

use super::{f, ExperimentCtx};
use crate::learner::{GroupMap, StagePredictor, Variant};
use crate::tuner::policy::oracle_best;
use crate::tuner::TunerConfig;

pub struct ClaimRow {
    pub id: &'static str,
    pub app: String,
    pub detail: String,
    pub paper: String,
    pub measured: String,
    pub pass: bool,
}

pub fn compute(ctx: &ExperimentCtx) -> Result<Vec<ClaimRow>> {
    let mut rows = Vec::new();
    let eps_star = TunerConfig::epsilon_for_horizon(ctx.frames);

    for app_name in ["pose", "motion_sift"] {
        let (app, traces) = ctx.app_traces(app_name)?;
        for &bound in &app.spec.latency_bounds_ms {
            let (reward, violation, max_violation) = super::fig8::run_policy(
                &app.spec,
                &traces,
                eps_star,
                bound,
                ctx.frames,
                ctx.seed,
            );
            let oracle = oracle_best(&traces, ctx.frames, bound);
            let ratio = reward / oracle.avg_reward.max(1e-9);
            rows.push(ClaimRow {
                id: "C1",
                app: app_name.into(),
                detail: format!("L={bound}ms, eps=1/sqrt(T)={eps_star:.3}"),
                paper: ">= 0.90 x optimal fidelity".into(),
                measured: format!(
                    "{:.1}% of optimal ({:.3} vs {:.3})",
                    100.0 * ratio,
                    reward,
                    oracle.avg_reward
                ),
                pass: ratio >= 0.90,
            });
            rows.push(ClaimRow {
                id: "C2",
                app: app_name.into(),
                detail: format!("L={bound}ms"),
                paper: "avg violation ~0.03 s, worst <= 0.1 s; 23%/50% of L".into(),
                measured: format!(
                    "avg {:.1} ms ({:.0}% of L), worst {:.1} ms ({:.0}% of L)",
                    violation,
                    100.0 * violation / bound,
                    max_violation,
                    100.0 * max_violation / bound
                ),
                // graded on the average (the paper's 23%-of-L figure);
                // the worst case is dominated by exploration frames that
                // deliberately sample expensive actions, and our action
                // spaces include configs several bounds above L
                pass: violation / bound <= 0.35,
            });
        }

        // C3: feature-space economics + update-speed ratio
        let st = GroupMap::structured(&app.spec).feature_count(3);
        let un = GroupMap::unstructured(&app.spec).feature_count(3);
        let speedup = update_speed_ratio(&app.spec, &traces, 2000);
        let paper = if app_name == "motion_sift" {
            "30 vs 56 features; ~2x faster updates".to_string()
        } else {
            "structured decomposition per Sec 2.3".to_string()
        };
        rows.push(ClaimRow {
            id: "C3",
            app: app_name.into(),
            detail: "cubic feature spaces".into(),
            paper,
            measured: format!("{st} vs {un} features; update speedup {speedup:.2}x"),
            pass: app_name != "motion_sift" || (st == 30 && un == 56),
        });
    }

    // C4: pose scene change at frame 600 bumps the per-frame error
    let (app, traces) = ctx.app_traces("pose")?;
    let bump = scene_change_bump(&app.spec, &traces, ctx.frames.min(900), ctx.seed);
    rows.push(ClaimRow {
        id: "C4",
        app: "pose".into(),
        detail: "frame-600 scene change (notebook appears)".into(),
        paper: "error increases at frame 600, then adapts".into(),
        measured: format!(
            "per-frame |err| around change: before {:.1} ms, at change {:.1} ms, after re-adapt {:.1} ms",
            bump.0, bump.1, bump.2
        ),
        pass: bump.1 > bump.0,
    });
    Ok(rows)
}

/// Measured wall-clock ratio of unstructured/structured online updates.
pub fn update_speed_ratio(
    spec: &crate::apps::spec::AppSpec,
    traces: &crate::trace::TraceSet,
    iters: usize,
) -> f64 {
    use std::time::Instant;
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| spec.normalize(c)).collect();
    let time_variant = |variant: Variant| {
        let mut pred = StagePredictor::new(spec, variant, 3);
        // detlint: allow(wallclock) — measured wall-clock speedup IS this experiment's product; never feeds a report comparison
        let start = Instant::now();
        for t in 0..iters {
            let a = t % candidates.len();
            let rec = traces.frame(a, t % traces.num_frames());
            pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
        }
        start.elapsed().as_secs_f64()
    };
    // warm up, then measure
    let _ = time_variant(Variant::Structured);
    let t_st = time_variant(Variant::Structured);
    let t_un = time_variant(Variant::Unstructured);
    t_un / t_st
}

/// (mean |err| in frames 540..590, 600..640, 750..800) of an online cubic
/// structured predictor trained with random actions.
pub fn scene_change_bump(
    spec: &crate::apps::spec::AppSpec,
    traces: &crate::trace::TraceSet,
    frames: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let candidates: Vec<Vec<f64>> =
        traces.configs().iter().map(|c| spec.normalize(c)).collect();
    let mut pred = StagePredictor::new(spec, Variant::Structured, 3);
    let mut rng = crate::util::Rng::new(seed);
    let mut errs = Vec::with_capacity(frames);
    for t in 0..frames {
        let a = rng.below(candidates.len());
        let rec = traces.frame(a, t % traces.num_frames());
        let before = pred.observe(&candidates[a], &rec.stage_ms, rec.end_to_end_ms);
        errs.push((before - rec.end_to_end_ms).abs());
    }
    let mean = |lo: usize, hi: usize| {
        let hi = hi.min(errs.len());
        let lo = lo.min(hi);
        if hi == lo {
            return 0.0;
        }
        errs[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
    };
    (mean(540, 590), mean(600, 640), mean(750, 800))
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    let rows = compute(ctx)?;
    let mut csv = ctx.csv("claims", "id,app,detail,paper,measured,pass")?;
    println!("--- headline claims ---");
    for r in &rows {
        csv.row(&[
            r.id.into(),
            r.app.clone(),
            format!("\"{}\"", r.detail),
            format!("\"{}\"", r.paper),
            format!("\"{}\"", r.measured),
            r.pass.to_string(),
        ])?;
        println!(
            "[{}] {} {} — paper: {} | measured: {} | {}",
            if r.pass { "ok" } else { "!!" },
            r.id,
            r.app,
            r.paper,
            r.measured,
            r.detail
        );
    }
    let path = csv.finish()?;
    crate::log_info!("claims -> {}", path.display());
    let _ = f(0.0); // keep helper linked
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::trace::TraceSet;

    #[test]
    fn scene_change_bump_visible() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 12, 900, 15);
        let (before, at, after) = scene_change_bump(&app.spec, &traces, 900, 1);
        assert!(at > before, "error should bump at the scene change: {before} -> {at}");
        let _ = after;
    }

    #[test]
    fn structured_updates_not_slower() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 8, 100, 16);
        let ratio = update_speed_ratio(&app.spec, &traces, 3000);
        assert!(ratio > 0.8, "structured updates should not be slower: {ratio}");
    }
}
