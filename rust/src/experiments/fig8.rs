//! Figure 8: online learning with constraints — average reward and
//! average constraint violation of ε-greedy policies for a sweep of
//! exploration rates ε and latency bounds L, against the payoff region of
//! randomized strategies over the action space. Diamonds mark ε = 1/√T.

use anyhow::Result;

use super::{app_tag, f, ExperimentCtx};
use crate::apps::spec::AppSpec;
use crate::learner::Variant;
use crate::metrics::convex_hull;
use crate::runtime::native::NativeBackend;
use crate::trace::TraceSet;
use crate::tuner::policy::pure_payoffs;
use crate::tuner::{EpsGreedyController, TunerConfig};

/// The ε sweep of the figure.
pub const EPSILONS: [f64; 10] =
    [0.01, 0.02, 0.03, 0.05, 0.08, 0.13, 0.2, 0.35, 0.6, 1.0];

pub struct Fig8Panel {
    pub app: String,
    pub bound_ms: f64,
    /// (ε, avg reward, avg violation ms, max violation ms) per policy.
    pub policies: Vec<(f64, f64, f64, f64)>,
    /// ε = 1/√T operating point (the diamond).
    pub diamond: (f64, f64, f64),
    /// (violation ms, reward) payoffs of pure strategies + their hull.
    pub pure: Vec<(f64, f64)>,
    pub hull: Vec<(f64, f64)>,
}

/// Run one ε-greedy policy (structured cubic, native backend) and return
/// (avg reward, avg violation ms, max violation ms).
pub fn run_policy(
    spec: &AppSpec,
    traces: &TraceSet,
    epsilon: f64,
    bound_ms: f64,
    frames: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let backend = NativeBackend::new(spec, Variant::Structured, 3);
    let cfg = TunerConfig { epsilon, bound_ms, warmup_frames: 20 };
    let mut ctl = EpsGreedyController::new(spec, traces, Box::new(backend), cfg, seed);
    let out = ctl.run(frames);
    (out.avg_reward, out.avg_violation_ms, out.max_violation_ms)
}

pub fn compute(
    spec: &AppSpec,
    traces: &TraceSet,
    bound_ms: f64,
    frames: usize,
    seed: u64,
) -> Fig8Panel {
    let policies: Vec<(f64, f64, f64, f64)> = EPSILONS
        .iter()
        .map(|&eps| {
            let (r, v, m) = run_policy(spec, traces, eps, bound_ms, frames, seed);
            (eps, r, v, m)
        })
        .collect();
    let eps_star = TunerConfig::epsilon_for_horizon(frames);
    let diamond = run_policy(spec, traces, eps_star, bound_ms, frames, seed);
    let pure = pure_payoffs(traces, bound_ms);
    let hull = convex_hull(&pure);
    Fig8Panel {
        app: spec.name.clone(),
        bound_ms,
        policies,
        diamond,
        pure,
        hull,
    }
}

pub fn run(ctx: &ExperimentCtx) -> Result<()> {
    for app in &ctx.experiment_apps() {
        let (app_obj, traces) = ctx.app_traces(app)?;
        // generated workloads carry three calibrated bounds; one panel
        // (the tight bound) is the scenario-diversity variant
        let bounds: Vec<f64> = if app.starts_with("gen") {
            vec![app_obj.spec.latency_bounds_ms[0]]
        } else {
            app_obj.spec.latency_bounds_ms.clone()
        };
        for &bound in &bounds {
            let panel = compute(&app_obj.spec, &traces, bound, ctx.frames, ctx.seed);
            let tag = format!("fig8_{}_L{}", app_tag(app), bound as i64);
            let mut csv = ctx.csv(
                &tag,
                "kind,epsilon,reward,violation_ms,max_violation_ms",
            )?;
            for &(eps, r, v, m) in &panel.policies {
                csv.row(&["policy".into(), f(eps), f(r), f(v), f(m)])?;
            }
            let (dr, dv, dm) = panel.diamond;
            csv.row(&[
                "diamond".into(),
                f(TunerConfig::epsilon_for_horizon(ctx.frames)),
                f(dr),
                f(dv),
                f(dm),
            ])?;
            for &(v, r) in &panel.pure {
                csv.row(&["pure".into(), String::new(), f(r), f(v), String::new()])?;
            }
            for &(v, r) in &panel.hull {
                csv.row(&["hull".into(), String::new(), f(r), f(v), String::new()])?;
            }
            let path = csv.finish()?;
            crate::log_info!(
                "fig8[{app}, L={bound}ms]: diamond eps={:.3} reward {:.3} violation {:.1} ms -> {}",
                TunerConfig::epsilon_for_horizon(ctx.frames),
                dr,
                dv,
                path.display()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn u_shape_endpoints() {
        // tiny-ε policies violate more (uncertain model); ε≈1 policies
        // earn less reward (mostly exploring) — the U-shape's two arms
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 15, 300, 13);
        let frames = 600;
        // bound at the 40th percentile of the action costs: feasible and
        // infeasible actions both guaranteed to exist
        let mut costs: Vec<f64> = traces.payoffs().iter().map(|p| p.0).collect();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let bound = costs[costs.len() * 2 / 5];
        let (r_mid, v_mid, _) =
            run_policy(&app.spec, &traces, 0.1, bound, frames, 1);
        let (_r_big, v_big, _) = run_policy(&app.spec, &traces, 1.0, bound, frames, 1);
        // fully-random exploration must violate substantially
        assert!(v_big > 1.0, "random policy violation {v_big}");
        // a mostly-exploiting policy violates far less than random ...
        assert!(v_mid < v_big * 0.6, "violations: exploit {v_mid} vs random {v_big}");
        // ... and earns a solid fraction of the constrained optimum
        let oracle = crate::tuner::policy::oracle_best(&traces, frames, bound);
        assert!(
            r_mid > oracle.avg_reward * 0.5,
            "reward {r_mid} vs oracle {}",
            oracle.avg_reward
        );
    }

    #[test]
    fn panel_is_complete() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 10, 120, 14);
        let p = compute(&app.spec, &traces, 150.0, 200, 2);
        assert_eq!(p.policies.len(), EPSILONS.len());
        assert_eq!(p.pure.len(), 10);
        assert!(!p.hull.is_empty());
        assert!(p.policies.iter().all(|&(_, r, v, m)| {
            (0.0..=1.0).contains(&r) && v >= 0.0 && m >= v
        }));
    }
}
