//! Hierarchical coordinator for the sharded reallocation epoch.
//!
//! Tenants are partitioned contiguously across S shards
//! ([`shard_bounds`]); each shard runs the existing admission and
//! water-fill machinery over its own tenant slice, and a global
//! coordinator drives the cross-shard sequencing with a token-passing
//! protocol that is **exact** — not approximate — by construction:
//!
//! * every global tie-break in the single-pool algorithms
//!   ([`EpochAdmission::decide`], [`allocate_v2`], [`reserve_top_up`])
//!   ends on "index ascending"; a contiguous partition turns global
//!   index order into (shard asc, local index asc), so any
//!   globally-ordered scan is a concatenation of per-shard segments;
//! * the admission scan is segmented by rank bucket ([`BucketKey`]:
//!   weight desc, class, streak): shards report bucket keys + member
//!   counts + demand totals (the per-priority-tier demand histogram of
//!   [`ShardSummary`]), the coordinator walks buckets in rank order and
//!   passes the running `used` token through the owning shards — the
//!   per-tenant demand vectors never leave the shard;
//! * both water-fill phases keep one priority heap per shard; the
//!   coordinator repeatedly hands the fill token to the shard holding
//!   the globally-best top along with a *boundary* (the best rival
//!   top), and the shard drains its heap while its top still beats the
//!   boundary — the single lazy heap of [`allocate_v2`], partitioned
//!   across shards, stale tops and all;
//! * the reservation top-up is segmented by (weight desc, shard asc)
//!   with the same `used` token, and report statistics (float utility
//!   sum, chained FNV quota fingerprint) fold in shard-major order —
//!   exactly the single-pool accumulation order, so reports are
//!   **byte-identical across shard counts**.
//!
//! The shard↔coordinator exchange goes through the [`ShardChannel`]
//! trait: [`InlineChannel`] runs the shard server in-process with no
//! threads (the S=1 and fleet tiers), `fleet::shard::MpscShardChannel`
//! runs it on a worker thread over `std::sync::mpsc` (the scale tier).
//! The trait is the seam for a multi-process tier later, in the spirit
//! of timely-dataflow's thread/process allocator stack.
//!
//! The protocol is mirror-validated: `python/tests/test_shard_mirror.py`
//! proves (pure stdlib, same token protocol) that the sharded run
//! reproduces the single-pool report dict exactly — float utility and
//! fingerprints included — across S ∈ {1..4}, and the unit tests below
//! re-prove it against the Rust single-pool implementations. See
//! `docs/DETERMINISM.md` for the contract this module is held to.
//!
//! [`EpochAdmission::decide`]: super::EpochAdmission::decide
//! [`allocate_v2`]: super::allocate_v2
//! [`reserve_top_up`]: super::reserve_top_up

use std::cmp::{Ordering, Reverse};
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

use super::Jump;

/// Contiguous balanced partition: shard `s` owns `[s*n/S, (s+1)*n/S)`.
/// The shard count is clamped to `[1, n]` (an empty fleet keeps one
/// empty shard), so callers can pass `--shards` values larger than the
/// tenant count without creating degenerate empty shards.
pub fn shard_bounds(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let s = shards.clamp(1, n.max(1));
    (0..s).map(|sid| (sid * n / s, (sid + 1) * n / s)).collect()
}

/// Admission rank-bucket key, ordered exactly like
/// [`EpochAdmission::decide`]'s global sort: weight descending, then
/// class (0 = overdue, 1 = admitted, 2 = parked), then the class-local
/// streak key (admitted streak ascending for class 1, parked streak
/// *descending* for the others, encoded as its negation so one
/// ascending `i64` covers both).
///
/// `Ord` uses `f64::total_cmp` on the weight, which agrees with the
/// single-pool `partial_cmp(..).unwrap()` for the finite weights the
/// schedulers produce, and gives buckets a total order so they can key
/// a `BTreeMap` without violating the determinism contract's hash-iter
/// rule.
///
/// [`EpochAdmission::decide`]: super::EpochAdmission::decide
#[derive(Clone, Copy, Debug)]
pub struct BucketKey {
    /// Priority weight of every member of the bucket.
    pub weight: f64,
    /// 0 = overdue (parked one epoch short of the starvation bound),
    /// 1 = currently admitted, 2 = parked.
    pub class: u8,
    /// Class-local streak tie-break (see type docs for the encoding).
    pub streak: i64,
}

impl PartialEq for BucketKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for BucketKey {}
impl PartialOrd for BucketKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for BucketKey {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .weight
            .total_cmp(&self.weight)
            .then(self.class.cmp(&other.class))
            .then(self.streak.cmp(&other.streak))
    }
}

/// One shard's compact per-epoch admission summary: for each rank
/// bucket present on the shard, the member count and the demand total —
/// a per-priority-tier demand histogram. Sorted by [`BucketKey`]. This
/// is everything that crosses the shard boundary at admission time;
/// per-tenant curves and demands stay local.
#[derive(Clone, Debug)]
pub struct ShardSummary {
    /// `(bucket, member count, summed demand)` in bucket rank order.
    pub buckets: Vec<(BucketKey, usize, usize)>,
}

/// Coordinator → shard messages. The protocol sequence for one epoch is
/// driven by [`decide_sharded`], [`waterfill_sharded`] and
/// [`top_up_sharded`]; every directive elicits exactly one [`Reply`].
#[derive(Clone, Debug)]
pub enum Directive {
    /// Transport-layer epoch kickoff: the channel owner synthesizes or
    /// gathers the shard's tenant slice and calls
    /// [`TenantShard::load_epoch`] itself. Never reaches
    /// [`TenantShard::handle`].
    Begin { epoch: usize },
    /// Install this epoch's per-tenant inputs. `curves` may be empty
    /// for admission-only use (the fleet tier partitions the fill
    /// separately).
    LoadEpoch { curves: Vec<Vec<f64>>, demands: Vec<usize>, weights: Vec<f64> },
    /// Bucket local tenants by rank and report the [`ShardSummary`].
    Summarize,
    /// Scan this shard's members of one rank bucket in local index
    /// order, applying the packing rule with the global `used` token.
    AdmitSegment { key: BucketKey, used: usize, total: usize },
    /// Fallback when nothing fit anywhere: admit the bucket's first
    /// local member (the global `order[0]`).
    ForceFirst { key: BucketKey },
    /// Stagger parked streaks over the global fresh cohort: this
    /// shard's members of `key` occupy `[offset, offset+count)` of the
    /// `m`-tenant cohort, with `gpe` cohort members per epoch.
    AssignFresh { key: BucketKey, offset: usize, m: usize, gpe: usize },
    /// Commit the pending decision and tick streaks.
    FinalizeAdmission,
    /// Re-apply the previous decision, ticking streaks (warmup epochs).
    Hold,
    /// Would any parked local tenant exceed the starvation bound if
    /// parked once more?
    OverduePending,
    /// Build the fill sub-instance from this shard's *admitted* tenants
    /// (curves/weights/demands loaded via [`Directive::LoadEpoch`];
    /// parked tenants restart at the floor rung).
    InstallFillLocal { levels: Vec<usize>, hysteresis: f64 },
    /// Install an explicit fill sub-instance (the fleet tier, where the
    /// admitted set is partitioned independently of tenant ownership).
    InstallFillWith {
        curves: Vec<Vec<f64>>,
        weights: Vec<f64>,
        prev: Option<Vec<usize>>,
        reservations: Vec<usize>,
        levels: Vec<usize>,
        hysteresis: f64,
    },
    /// Build the phase-1 jump heap at the global floor state.
    FillInit { used: usize, total: usize },
    /// Drain the phase-1 heap while its top beats `boundary`
    /// (`(gain, shard)`, gain descending then shard ascending).
    Fill { used: usize, total: usize, boundary: Option<(f64, usize)> },
    /// Build the phase-2 even-share raise heap.
    RaiseInit { even: usize },
    /// Drain the phase-2 heap while its top beats `boundary`
    /// (`(cores, shard)`, cores ascending then shard ascending).
    Raise { used: usize, total: usize, boundary: Option<(usize, usize)> },
    /// Run one (weight tier × shard) segment of the reservation top-up.
    TopUpSegment { weight: f64, even: usize, total: usize, used: usize },
    /// Return the fill sub-instance's final rungs.
    TakeRungs,
    /// Fold this shard's epoch statistics onto the running totals
    /// (chained FNV fingerprint, shard-major float utility sum) and
    /// roll per-tenant previous-rung state forward.
    Stats { fp: u64, util: f64 },
    /// Tear down the channel; the shard server replies [`Reply::Done`]
    /// and a threaded transport exits its worker loop.
    Shutdown,
}

/// Shard → coordinator replies, one per [`Directive`].
#[derive(Clone, Debug)]
pub enum Reply {
    Loaded,
    Summary(ShardSummary),
    /// `used` token after the segment, members admitted, members left
    /// in the segment's fresh cohort.
    Admitted { used: usize, admitted: usize, fresh: usize },
    /// Whether the force-admitted tenant was removed from a fresh list.
    Forced { was_fresh: bool },
    FreshAssigned,
    /// The shard's committed admission flags, local index order.
    Finalized { flags: Vec<bool> },
    Held { flags: Vec<bool> },
    Overdue { pending: bool },
    FillInstalled,
    /// Best local phase-1 gain after heap construction.
    FillTop { top: Option<f64> },
    /// `used` token and new best local gain after a drain run.
    Filled { used: usize, top: Option<f64> },
    /// Lowest eligible core count after phase-2 heap construction.
    RaiseTop { top: Option<usize> },
    Raised { used: usize, top: Option<usize> },
    ToppedUp { used: usize },
    Rungs { rungs: Vec<usize> },
    /// Folded running totals plus this shard's own per-epoch counts.
    Stats { admitted: usize, used: usize, top_up: usize, moved: usize, util: f64, fp: u64 },
    Done,
}

/// The shard↔coordinator transport seam. [`InlineChannel`] is the
/// zero-thread in-process tier; `fleet::shard::MpscShardChannel` is the
/// `mpsc` worker-thread tier; a multi-process tier would serialize
/// [`Directive`]/[`Reply`] over a socket — the protocol already never
/// moves per-tenant state, so only this trait needs a new impl.
///
/// Drivers broadcast a directive to every shard before collecting
/// replies, so threaded transports overlap shard work; `send` must
/// therefore queue exactly one reply per directive for `recv` to
/// retrieve in order.
pub trait ShardChannel {
    fn send(&mut self, d: Directive);
    fn recv(&mut self) -> Reply;
}

/// In-process [`ShardChannel`]: owns the [`TenantShard`] and handles
/// each directive synchronously at `send`, queueing the reply.
pub struct InlineChannel {
    shard: TenantShard,
    pending: VecDeque<Reply>,
}

impl InlineChannel {
    pub fn new(shard: TenantShard) -> Self {
        InlineChannel { shard, pending: VecDeque::new() }
    }

    /// The owned shard server (tests and diagnostics).
    pub fn shard(&self) -> &TenantShard {
        &self.shard
    }
}

impl ShardChannel for InlineChannel {
    fn send(&mut self, d: Directive) {
        let r = self.shard.handle(d);
        self.pending.push_back(r);
    }

    fn recv(&mut self) -> Reply {
        self.pending
            .pop_front()
            // detlint: allow(unwrap) — every send queues exactly one reply; recv without send is a protocol bug
            .expect("InlineChannel::recv with no pending reply")
    }
}

/// One shard's server state: the admission machinery of
/// [`EpochAdmission`] over the local tenant slice `[lo, hi)`, plus the
/// per-epoch fill sub-instance. Pure protocol state — it never spawns
/// threads or reads clocks; transports own the concurrency.
///
/// [`EpochAdmission`]: super::EpochAdmission
pub struct TenantShard {
    sid: usize,
    lo: usize,
    hi: usize,
    bound: usize,
    hysteresis: usize,
    admitted: Vec<bool>,
    parked_streak: Vec<usize>,
    admitted_streak: Vec<usize>,
    decided: bool,
    prev_rung: Vec<usize>,
    prev_admitted: Vec<bool>,
    curves: Vec<Vec<f64>>,
    demands: Vec<usize>,
    weights: Vec<f64>,
    buckets: BTreeMap<BucketKey, Vec<usize>>,
    next: Vec<bool>,
    fresh: BTreeMap<BucketKey, Vec<usize>>,
    fill: Option<FillState>,
}

impl TenantShard {
    /// A shard owning tenants `[lo, hi)` with the same `bound` /
    /// `hysteresis` admission knobs as [`EpochAdmission::new`] +
    /// [`with_hysteresis`] — every shard of a fleet must share them.
    ///
    /// [`EpochAdmission::new`]: super::EpochAdmission::new
    /// [`with_hysteresis`]: super::EpochAdmission::with_hysteresis
    pub fn new(sid: usize, lo: usize, hi: usize, bound: usize, hysteresis: usize) -> Self {
        assert!(lo <= hi, "shard {sid}: inverted tenant range {lo}..{hi}");
        let n = hi - lo;
        TenantShard {
            sid,
            lo,
            hi,
            bound: bound.max(1),
            hysteresis,
            admitted: vec![true; n],
            parked_streak: vec![0; n],
            admitted_streak: vec![0; n],
            decided: false,
            prev_rung: vec![0; n],
            prev_admitted: vec![false; n],
            curves: Vec::new(),
            demands: Vec::new(),
            weights: Vec::new(),
            buckets: BTreeMap::new(),
            next: Vec::new(),
            fresh: BTreeMap::new(),
            fill: None,
        }
    }

    /// First owned global tenant index.
    pub fn lo(&self) -> usize {
        self.lo
    }

    /// One past the last owned global tenant index.
    pub fn hi(&self) -> usize {
        self.hi
    }

    /// Install this epoch's per-tenant inputs. `curves` may be empty
    /// when the shard only arbitrates admission (the fleet tier).
    pub fn load_epoch(&mut self, curves: Vec<Vec<f64>>, demands: Vec<usize>, weights: Vec<f64>) {
        let n = self.hi - self.lo;
        assert!(demands.len() == n && weights.len() == n, "shard {}: epoch shape", self.sid);
        assert!(curves.is_empty() || curves.len() == n, "shard {}: curve shape", self.sid);
        self.curves = curves;
        self.demands = demands;
        self.weights = weights;
    }

    /// Dispatch one protocol directive. Panics on [`Directive::Begin`]
    /// (transport-layer) and on protocol-order violations — a shard
    /// fed out-of-order directives is a coordinator bug, not a
    /// recoverable condition.
    pub fn handle(&mut self, d: Directive) -> Reply {
        match d {
            Directive::Begin { .. } => {
                panic!("Begin is transport-layer: the channel owner loads the epoch")
            }
            Directive::LoadEpoch { curves, demands, weights } => {
                self.load_epoch(curves, demands, weights);
                Reply::Loaded
            }
            Directive::Summarize => Reply::Summary(self.summarize()),
            Directive::AdmitSegment { key, used, total } => {
                let (used, admitted, fresh) = self.admit_segment(key, used, total);
                Reply::Admitted { used, admitted, fresh }
            }
            Directive::ForceFirst { key } => Reply::Forced { was_fresh: self.force_first(key) },
            Directive::AssignFresh { key, offset, m, gpe } => {
                self.assign_fresh(key, offset, m, gpe);
                Reply::FreshAssigned
            }
            Directive::FinalizeAdmission => Reply::Finalized { flags: self.finalize_admission() },
            Directive::Hold => Reply::Held { flags: self.hold() },
            Directive::OverduePending => Reply::Overdue { pending: self.overdue_pending() },
            Directive::InstallFillLocal { levels, hysteresis } => {
                self.install_fill_local(levels, hysteresis);
                Reply::FillInstalled
            }
            Directive::InstallFillWith {
                curves,
                weights,
                prev,
                reservations,
                levels,
                hysteresis,
            } => {
                self.fill =
                    Some(FillState::new(curves, weights, prev, reservations, levels, hysteresis));
                Reply::FillInstalled
            }
            Directive::FillInit { used, total } => {
                let f = self.fill_mut();
                f.heap_init(used, total);
                Reply::FillTop { top: f.top() }
            }
            Directive::Fill { used, total, boundary } => {
                let sid = self.sid;
                let f = self.fill_mut();
                let used = f.fill(sid, used, total, boundary);
                Reply::Filled { used, top: f.top() }
            }
            Directive::RaiseInit { even } => {
                let f = self.fill_mut();
                f.raise_init(even);
                Reply::RaiseTop { top: f.top2() }
            }
            Directive::Raise { used, total, boundary } => {
                let sid = self.sid;
                let f = self.fill_mut();
                let used = f.raise(sid, used, total, boundary);
                Reply::Raised { used, top: f.top2() }
            }
            Directive::TopUpSegment { weight, even, total, used } => {
                let used = self.fill_mut().top_up_segment(weight, even, total, used);
                Reply::ToppedUp { used }
            }
            Directive::TakeRungs => Reply::Rungs { rungs: self.fill_ref().lvl.clone() },
            Directive::Stats { fp, util } => self.stats(fp, util),
            Directive::Shutdown => Reply::Done,
        }
    }

    fn fill_mut(&mut self) -> &mut FillState {
        self.fill
            .as_mut()
            // detlint: allow(unwrap) — protocol order: InstallFill* precedes every fill directive
            .expect("shard fill state missing: InstallFill must precede fill directives")
    }

    fn fill_ref(&self) -> &FillState {
        self.fill
            .as_ref()
            // detlint: allow(unwrap) — protocol order: InstallFill* precedes every fill directive
            .expect("shard fill state missing: InstallFill must precede fill directives")
    }

    /// Bucket local tenants by rank key and emit the compact summary,
    /// resetting the pending-decision scratch ([`EpochAdmission::rank`]
    /// segmented: the bucket order is the global sort order restricted
    /// to this shard, members kept in local = global index order).
    ///
    /// [`EpochAdmission::rank`]: super::EpochAdmission
    fn summarize(&mut self) -> ShardSummary {
        let n = self.hi - self.lo;
        self.buckets.clear();
        for k in 0..n {
            let over =
                self.decided && !self.admitted[k] && self.parked_streak[k] + 1 >= self.bound;
            let class = if over {
                0u8
            } else if self.admitted[k] {
                1
            } else {
                2
            };
            let streak = if class == 1 {
                self.admitted_streak[k] as i64
            } else {
                -(self.parked_streak[k] as i64)
            };
            self.buckets
                .entry(BucketKey { weight: self.weights[k], class, streak })
                .or_default()
                .push(k);
        }
        self.next = vec![false; n];
        self.fresh.clear();
        let buckets = self
            .buckets
            .iter()
            .map(|(k, v)| (*k, v.len(), v.iter().map(|&i| self.demands[i]).sum::<usize>()))
            .collect();
        ShardSummary { buckets }
    }

    /// One bucket segment of the global admission scan, local index
    /// order, with the exact packing rule of [`EpochAdmission::decide`]:
    /// reservation clamped to `[1, total]`, the hysteresis slack charged
    /// only to steady-state parked tenants. Members that neither fit nor
    /// stay parked join the segment's fresh cohort.
    ///
    /// [`EpochAdmission::decide`]: super::EpochAdmission::decide
    fn admit_segment(
        &mut self,
        key: BucketKey,
        mut used: usize,
        total: usize,
    ) -> (usize, usize, usize) {
        let mut admitted = 0usize;
        let mut fresh = Vec::new();
        if let Some(members) = self.buckets.get(&key) {
            for &k in members {
                let r = self.demands[k].clamp(1, total.max(1));
                let slack = if self.decided && key.class == 2 { self.hysteresis } else { 0 };
                if used + r + slack <= total {
                    self.next[k] = true;
                    used += r;
                    admitted += 1;
                } else if self.admitted[k] || !self.decided {
                    fresh.push(k);
                }
            }
        }
        let nf = fresh.len();
        self.fresh.insert(key, fresh);
        (used, admitted, nf)
    }

    /// Coordinator fallback when nothing fit anywhere: admit this
    /// bucket's first local member (the global `order[0]`). Returns
    /// whether the member had joined the fresh cohort (the coordinator
    /// shrinks its count — a forced tenant is admitted, not fresh).
    fn force_first(&mut self, key: BucketKey) -> bool {
        let k0 = self
            .buckets
            .get(&key)
            .and_then(|v| v.first().copied())
            // detlint: allow(unwrap) — the coordinator only forces a bucket its summary reported non-empty
            .expect("force_first on an empty bucket");
        self.next[k0] = true;
        match self.fresh.get_mut(&key) {
            Some(f) if f.first() == Some(&k0) => {
                f.remove(0);
                true
            }
            _ => false,
        }
    }

    /// Stagger `parked_streak` over the global fresh cohort exactly as
    /// the single-pool decide does: member `offset + j` of the
    /// `m`-tenant cohort gets `(m - 1 - (offset + j)) / gpe`.
    fn assign_fresh(&mut self, key: BucketKey, offset: usize, m: usize, gpe: usize) {
        if let Some(f) = self.fresh.get(&key) {
            for (j, &k) in f.iter().enumerate() {
                self.parked_streak[k] = (m - 1 - (offset + j)) / gpe;
                self.admitted_streak[k] = 0;
            }
        }
    }

    fn finalize_admission(&mut self) -> Vec<bool> {
        let n = self.hi - self.lo;
        let mut is_fresh = vec![false; n];
        for f in self.fresh.values() {
            for &k in f {
                is_fresh[k] = true;
            }
        }
        for k in 0..n {
            if self.next[k] {
                self.parked_streak[k] = 0;
                self.admitted_streak[k] += 1;
            } else if !is_fresh[k] {
                self.parked_streak[k] += 1;
                self.admitted_streak[k] = 0;
            }
        }
        self.admitted = self.next.clone();
        self.decided = true;
        self.admitted.clone()
    }

    fn hold(&mut self) -> Vec<bool> {
        for k in 0..self.admitted.len() {
            if self.admitted[k] {
                self.admitted_streak[k] += 1;
            } else {
                self.parked_streak[k] += 1;
            }
        }
        self.admitted.clone()
    }

    fn overdue_pending(&self) -> bool {
        (0..self.admitted.len())
            .any(|k| self.decided && !self.admitted[k] && self.parked_streak[k] + 1 >= self.bound)
    }

    /// Fill sub-instance from this shard's admitted tenants: parked
    /// tenants restart at the floor rung, reservations are the loaded
    /// demands (the scale tier's `sub_*` vectors, shard-local).
    fn install_fill_local(&mut self, levels: Vec<usize>, hysteresis: f64) {
        let n = self.hi - self.lo;
        assert!(self.curves.len() == n, "shard {}: InstallFillLocal needs loaded curves", self.sid);
        let idx: Vec<usize> = (0..n).filter(|&k| self.admitted[k]).collect();
        let curves: Vec<Vec<f64>> = idx.iter().map(|&k| self.curves[k].clone()).collect();
        let weights: Vec<f64> = idx.iter().map(|&k| self.weights[k]).collect();
        let prev: Vec<usize> = idx
            .iter()
            .map(|&k| if self.prev_admitted[k] { self.prev_rung[k] } else { 0 })
            .collect();
        let reservations: Vec<usize> = idx.iter().map(|&k| self.demands[k]).collect();
        let mut st = FillState::new(curves, weights, Some(prev), reservations, levels, hysteresis);
        st.idx = idx;
        self.fill = Some(st);
    }

    /// Fold this shard's epoch statistics onto the running `(fp, util)`
    /// totals in local = global index order, mirroring the single-pool
    /// report loop exactly: per-admitted-tenant quota, weighted utility
    /// (asserted finite), moved count against the previous epoch, and
    /// the top-up core delta; then roll `prev_rung`/`prev_admitted`
    /// forward. The FNV-1a constants must stay in sync with
    /// `fleet::scale`'s fingerprint (asserted byte-identical by the
    /// cross-shard report tests).
    fn stats(&mut self, fp: u64, util0: f64) -> Reply {
        let f = self
            .fill
            .take()
            // detlint: allow(unwrap) — protocol order: the fill runs before Stats every epoch
            .expect("shard fill state missing: Stats follows the fill phases");
        let n = self.hi - self.lo;
        let mut quota = vec![0usize; n];
        let mut util = util0;
        let mut moved = 0usize;
        for (s, &k) in f.idx.iter().enumerate() {
            quota[k] = f.levels[f.lvl[s]];
            let u = f.curves[s][f.lvl[s]];
            assert!(u.is_finite(), "tenant {}: non-finite utility {u}", self.lo + k);
            util += self.weights[k] * u;
            if self.prev_admitted[k] && f.lvl[s] != self.prev_rung[k] {
                moved += 1;
            }
            self.prev_rung[k] = f.lvl[s];
        }
        let pre = if f.pre.is_empty() { f.lvl.clone() } else { f.pre.clone() };
        let mut top_up = 0usize;
        for (&g, &p) in f.lvl.iter().zip(pre.iter()) {
            assert!(g >= p, "top-up reduced a rung: {p} -> {g}");
            top_up += f.levels[g] - f.levels[p];
        }
        self.prev_admitted = self.admitted.clone();
        let used: usize = quota.iter().sum();
        let mut h = fp;
        for &q in &quota {
            for b in (q as u64).to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        }
        Reply::Stats { admitted: f.idx.len(), used, top_up, moved, util, fp: h }
    }
}

/// The per-shard slice of the admitted fill sub-instance, with local
/// heaps for both [`allocate_v2`] phases and the rung cursor the
/// segmented top-up advances.
///
/// [`allocate_v2`]: super::allocate_v2
struct FillState {
    /// Local tenant index per sub-instance slot (identity for explicit
    /// installs; the admitted subset for [`Directive::InstallFillLocal`]).
    idx: Vec<usize>,
    curves: Vec<Vec<f64>>,
    weights: Vec<f64>,
    prev: Option<Vec<usize>>,
    reservations: Vec<usize>,
    levels: Vec<usize>,
    hysteresis: f64,
    lvl: Vec<usize>,
    /// Rung snapshot taken at the first top-up segment (the phase-2
    /// fixed point), so [`TenantShard::stats`] can report the top-up
    /// core delta.
    pre: Vec<usize>,
    even: usize,
    heap: BinaryHeap<Jump>,
    heap2: BinaryHeap<Reverse<(usize, usize)>>,
}

impl FillState {
    fn new(
        curves: Vec<Vec<f64>>,
        weights: Vec<f64>,
        prev: Option<Vec<usize>>,
        reservations: Vec<usize>,
        levels: Vec<usize>,
        hysteresis: f64,
    ) -> FillState {
        let n = curves.len();
        assert!(!levels.is_empty(), "sharded fill needs a non-empty ladder");
        assert!(
            levels.windows(2).all(|w| w[0] < w[1]),
            "sharded fill requires a strictly increasing ladder (the heap protocol's precondition)"
        );
        assert!(weights.len() == n && reservations.len() == n, "fill sub-instance shape");
        assert!(curves.iter().all(|c| c.len() == levels.len()), "curve/ladder shape");
        if let Some(p) = &prev {
            assert!(p.len() == n, "prev shape");
        }
        assert!(hysteresis >= 0.0, "negative hysteresis");
        FillState {
            idx: (0..n).collect(),
            curves,
            weights,
            prev,
            reservations,
            levels,
            hysteresis,
            lvl: vec![0; n],
            pre: Vec::new(),
            even: 0,
            heap: BinaryHeap::new(),
            heap2: BinaryHeap::new(),
        }
    }

    /// Hysteresis-adjusted utility of slot `a` at rung `l` — identical
    /// to [`allocate_v2`]'s internal adjustment.
    ///
    /// [`allocate_v2`]: super::allocate_v2
    fn adj(&self, a: usize, l: usize) -> f64 {
        let mut u = self.weights[a] * self.curves[a][l];
        if self.hysteresis > 0.0 {
            if let Some(p) = &self.prev {
                if p[a] == l {
                    u += self.hysteresis;
                }
            }
        }
        u
    }

    /// Best feasible upward jump for slot `a` at the current `used`
    /// token — the same scan as [`allocate_v2`]'s, strict `>` keeping
    /// the lowest target rung on gain ties.
    ///
    /// [`allocate_v2`]: super::allocate_v2
    fn best_jump(&self, a: usize, used: usize, total: usize) -> Option<Jump> {
        let cur = self.levels[self.lvl[a]];
        let mut best: Option<Jump> = None;
        for j in self.lvl[a] + 1..self.levels.len() {
            if used - cur + self.levels[j] > total {
                continue;
            }
            let du = self.adj(a, j) - self.adj(a, self.lvl[a]);
            if du <= 1e-12 {
                continue;
            }
            let g = du / (self.levels[j] - cur) as f64;
            let better = match &best {
                None => true,
                Some(b) => g > b.gain,
            };
            if better {
                best = Some(Jump { gain: g, app: a, rung: j });
            }
        }
        best
    }

    fn heap_init(&mut self, used: usize, total: usize) {
        self.heap.clear();
        for a in 0..self.curves.len() {
            if let Some(j) = self.best_jump(a, used, total) {
                self.heap.push(j);
            }
        }
    }

    fn top(&self) -> Option<f64> {
        self.heap.peek().map(|j| j.gain)
    }

    /// Drain the phase-1 heap while the local top beats the boundary
    /// (gain desc, shard asc): the pop run this produces is exactly the
    /// run of global pops the single heap would take. Stale entries are
    /// recomputed and re-pushed, as in [`allocate_v2`].
    ///
    /// [`allocate_v2`]: super::allocate_v2
    fn fill(
        &mut self,
        sid: usize,
        mut used: usize,
        total: usize,
        boundary: Option<(f64, usize)>,
    ) -> usize {
        loop {
            let Some(top) = self.heap.peek().copied() else { break };
            let beat = match boundary {
                None => true,
                Some((bg, bsid)) => match top.gain.total_cmp(&bg) {
                    Ordering::Greater => true,
                    Ordering::Equal => sid < bsid,
                    Ordering::Less => false,
                },
            };
            if !beat {
                break;
            }
            self.heap.pop();
            let a = top.app;
            let cur = self.levels[self.lvl[a]];
            if used - cur + self.levels[top.rung] > total {
                if let Some(j) = self.best_jump(a, used, total) {
                    self.heap.push(j);
                }
                continue;
            }
            used = used - cur + self.levels[top.rung];
            self.lvl[a] = top.rung;
            if let Some(j) = self.best_jump(a, used, total) {
                self.heap.push(j);
            }
        }
        used
    }

    fn eligible(&self, a: usize) -> bool {
        let j = self.lvl[a] + 1;
        j < self.levels.len() && self.levels[j] <= self.even
    }

    fn raise_init(&mut self, even: usize) {
        self.even = even;
        self.heap2.clear();
        for a in 0..self.curves.len() {
            if self.eligible(a) {
                self.heap2.push(Reverse((self.levels[self.lvl[a]], a)));
            }
        }
    }

    fn top2(&self) -> Option<usize> {
        self.heap2.peek().map(|&Reverse((c, _))| c)
    }

    /// Drain the phase-2 even-share raise heap while the local minimum
    /// beats the boundary (cores asc, shard asc). Infeasible pops are
    /// dropped for good — `used` only grows, matching [`allocate_v2`].
    ///
    /// [`allocate_v2`]: super::allocate_v2
    fn raise(
        &mut self,
        sid: usize,
        mut used: usize,
        total: usize,
        boundary: Option<(usize, usize)>,
    ) -> usize {
        while let Some(&Reverse((cores, a))) = self.heap2.peek() {
            let beat = match boundary {
                None => true,
                Some((bc, bsid)) => cores < bc || (cores == bc && sid < bsid),
            };
            if !beat {
                break;
            }
            self.heap2.pop();
            let j = self.lvl[a] + 1;
            if used - self.levels[self.lvl[a]] + self.levels[j] > total {
                continue;
            }
            used = used - self.levels[self.lvl[a]] + self.levels[j];
            self.lvl[a] = j;
            if self.eligible(a) {
                self.heap2.push(Reverse((self.levels[self.lvl[a]], a)));
            }
        }
        used
    }

    /// This shard's members of one weight tier, local index order —
    /// one segment of [`reserve_top_up`]'s (weight desc, index asc)
    /// scan. The first segment snapshots the pre-top-up rungs.
    ///
    /// [`reserve_top_up`]: super::reserve_top_up
    fn top_up_segment(&mut self, weight: f64, even: usize, total: usize, mut used: usize) -> usize {
        if self.pre.is_empty() {
            self.pre = self.lvl.clone();
        }
        for a in 0..self.lvl.len() {
            if self.weights[a].total_cmp(&weight) != Ordering::Equal {
                continue;
            }
            let want = self.reservations[a].min(even);
            while self.lvl[a] + 1 < self.levels.len()
                && self.levels[self.lvl[a]] < want
                && self.levels[self.lvl[a] + 1] <= want
                && used - self.levels[self.lvl[a]] + self.levels[self.lvl[a] + 1] <= total
            {
                used = used - self.levels[self.lvl[a]] + self.levels[self.lvl[a] + 1];
                self.lvl[a] += 1;
            }
        }
        used
    }
}

// -------------------------------------------------------------------------
// coordinator drivers
// -------------------------------------------------------------------------

fn protocol_panic(expected: &str, got: &Reply) -> ! {
    panic!("shard protocol violation: expected {expected} reply, got {got:?}")
}

/// Broadcast a directive to every shard, then collect the replies in
/// shard order — threaded transports overlap the shard work.
fn broadcast<C: ShardChannel>(channels: &mut [C], make: impl Fn(usize) -> Directive) -> Vec<Reply> {
    for (i, c) in channels.iter_mut().enumerate() {
        c.send(make(i));
    }
    channels.iter_mut().map(|c| c.recv()).collect()
}

fn ask<C: ShardChannel>(ch: &mut C, d: Directive) -> Reply {
    ch.send(d);
    ch.recv()
}

/// Outcome of one sharded admission decision.
pub struct ShardedDecision {
    /// Global admission flags (shard-major concatenation = global
    /// tenant index order).
    pub flags: Vec<bool>,
    /// Distinct priority weights present this epoch, descending — the
    /// segment order [`top_up_sharded`] walks. Derived from the bucket
    /// summaries, so the coordinator stays summary-driven.
    pub tiers: Vec<f64>,
}

/// One global admission decision over the shards — the two-level
/// [`EpochAdmission::decide`]: summaries up, then the `used` token
/// walks (bucket rank, shard asc) segments, then the force-first
/// fallback, fresh-cohort staggering, and commit.
///
/// `bound` must equal the shards' starvation bound (it sizes the fresh
/// cohort's stagger groups).
///
/// [`EpochAdmission::decide`]: super::EpochAdmission::decide
pub fn decide_sharded<C: ShardChannel>(
    channels: &mut [C],
    total: usize,
    bound: usize,
) -> ShardedDecision {
    let bound = bound.max(1);
    let summaries: Vec<ShardSummary> = broadcast(channels, |_| Directive::Summarize)
        .into_iter()
        .map(|r| match r {
            Reply::Summary(s) => s,
            other => protocol_panic("Summary", &other),
        })
        .collect();
    let mut keys: Vec<BucketKey> =
        summaries.iter().flat_map(|s| s.buckets.iter().map(|&(k, _, _)| k)).collect();
    keys.sort();
    keys.dedup();
    let has_bucket = |sid: usize, key: BucketKey| {
        summaries[sid].buckets.binary_search_by(|&(k, _, _)| k.cmp(&key)).is_ok()
    };
    let mut used = 0usize;
    let mut n_admitted = 0usize;
    // (shard, bucket, fresh count) in global scan order — the fresh
    // cohort's global layout.
    let mut segments: Vec<(usize, BucketKey, usize)> = Vec::new();
    for &key in &keys {
        for sid in 0..channels.len() {
            if !has_bucket(sid, key) {
                continue;
            }
            match ask(&mut channels[sid], Directive::AdmitSegment { key, used, total }) {
                Reply::Admitted { used: u, admitted, fresh } => {
                    used = u;
                    n_admitted += admitted;
                    segments.push((sid, key, fresh));
                }
                other => protocol_panic("Admitted", &other),
            }
        }
    }
    if n_admitted == 0 {
        'force: for &key in &keys {
            for sid in 0..channels.len() {
                if !has_bucket(sid, key) {
                    continue;
                }
                match ask(&mut channels[sid], Directive::ForceFirst { key }) {
                    Reply::Forced { was_fresh } => {
                        if was_fresh {
                            // detlint: allow(float-eq) — BucketKey equality is its total_cmp Ord, exact by design
                            let hit = segments.iter_mut().find(|s| s.0 == sid && s.1 == key);
                            if let Some(seg) = hit {
                                seg.2 -= 1;
                            }
                        }
                        break 'force;
                    }
                    other => protocol_panic("Forced", &other),
                }
            }
        }
    }
    let m: usize = segments.iter().map(|s| s.2).sum();
    let gpe = ((m + bound - 1) / bound).max(1);
    let mut off = 0usize;
    for &(sid, key, fresh) in &segments {
        if fresh == 0 {
            continue;
        }
        match ask(&mut channels[sid], Directive::AssignFresh { key, offset: off, m, gpe }) {
            Reply::FreshAssigned => {}
            other => protocol_panic("FreshAssigned", &other),
        }
        off += fresh;
    }
    let mut flags = Vec::new();
    for r in broadcast(channels, |_| Directive::FinalizeAdmission) {
        match r {
            Reply::Finalized { flags: f } => flags.extend(f),
            other => protocol_panic("Finalized", &other),
        }
    }
    let mut tiers: Vec<f64> = Vec::new();
    for k in &keys {
        let fresh_tier = match tiers.last() {
            None => true,
            Some(t) => t.total_cmp(&k.weight) != Ordering::Equal,
        };
        if fresh_tier {
            tiers.push(k.weight);
        }
    }
    ShardedDecision { flags, tiers }
}

/// Sharded [`EpochAdmission::hold`]: tick streaks everywhere, return
/// the concatenated standing flags.
///
/// [`EpochAdmission::hold`]: super::EpochAdmission::hold
pub fn hold_sharded<C: ShardChannel>(channels: &mut [C]) -> Vec<bool> {
    let mut flags = Vec::new();
    for r in broadcast(channels, |_| Directive::Hold) {
        match r {
            Reply::Held { flags: f } => flags.extend(f),
            other => protocol_panic("Held", &other),
        }
    }
    flags
}

/// Sharded [`EpochAdmission::overdue_pending`].
///
/// [`EpochAdmission::overdue_pending`]: super::EpochAdmission::overdue_pending
pub fn overdue_sharded<C: ShardChannel>(channels: &mut [C]) -> bool {
    broadcast(channels, |_| Directive::OverduePending).into_iter().any(|r| match r {
        Reply::Overdue { pending } => pending,
        other => protocol_panic("Overdue", &other),
    })
}

/// Both [`allocate_v2`] phases over installed shard fill states: the
/// coordinator repeatedly hands the `used` token to the shard with the
/// globally-best heap top, passing the best rival top as the drain
/// boundary. Returns the final `used`. `even` is phase 2's even-share
/// cap (`total / napps` in the single-pool fill).
///
/// [`allocate_v2`]: super::allocate_v2
pub fn waterfill_sharded<C: ShardChannel>(
    channels: &mut [C],
    mut used: usize,
    total: usize,
    even: usize,
) -> usize {
    let mut tops: Vec<Option<f64>> = broadcast(channels, |_| Directive::FillInit { used, total })
        .into_iter()
        .map(|r| match r {
            Reply::FillTop { top } => top,
            other => protocol_panic("FillTop", &other),
        })
        .collect();
    loop {
        // argmax (gain desc, shard asc): strict Greater keeps the
        // lowest shard on exact gain ties, like the global heap's
        // app-index tie-break under a contiguous partition.
        let mut best: Option<(f64, usize)> = None;
        for (sid, t) in tops.iter().enumerate() {
            if let Some(g) = *t {
                let better = match best {
                    None => true,
                    Some((bg, _)) => g.total_cmp(&bg) == Ordering::Greater,
                };
                if better {
                    best = Some((g, sid));
                }
            }
        }
        let Some((_, sid)) = best else { break };
        let mut boundary: Option<(f64, usize)> = None;
        for (osid, t) in tops.iter().enumerate() {
            if osid == sid {
                continue;
            }
            if let Some(g) = *t {
                let better = match boundary {
                    None => true,
                    Some((bg, _)) => g.total_cmp(&bg) == Ordering::Greater,
                };
                if better {
                    boundary = Some((g, osid));
                }
            }
        }
        match ask(&mut channels[sid], Directive::Fill { used, total, boundary }) {
            Reply::Filled { used: u, top } => {
                used = u;
                tops[sid] = top;
            }
            other => protocol_panic("Filled", &other),
        }
    }
    let mut tops2: Vec<Option<usize>> = broadcast(channels, |_| Directive::RaiseInit { even })
        .into_iter()
        .map(|r| match r {
            Reply::RaiseTop { top } => top,
            other => protocol_panic("RaiseTop", &other),
        })
        .collect();
    loop {
        let mut best: Option<(usize, usize)> = None;
        for (sid, t) in tops2.iter().enumerate() {
            if let Some(c) = *t {
                let better = match best {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    best = Some((c, sid));
                }
            }
        }
        let Some((_, sid)) = best else { break };
        let mut boundary: Option<(usize, usize)> = None;
        for (osid, t) in tops2.iter().enumerate() {
            if osid == sid {
                continue;
            }
            if let Some(c) = *t {
                let better = match boundary {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    boundary = Some((c, osid));
                }
            }
        }
        match ask(&mut channels[sid], Directive::Raise { used, total, boundary }) {
            Reply::Raised { used: u, top } => {
                used = u;
                tops2[sid] = top;
            }
            other => protocol_panic("Raised", &other),
        }
    }
    used
}

/// Segmented [`reserve_top_up`]: walk `(weight tier desc, shard asc)`
/// segments with the `used` token against the full pool. `tiers` is
/// typically [`ShardedDecision::tiers`]; segments for tiers absent on a
/// shard are no-ops, so a fixed global tier list is safe.
///
/// [`reserve_top_up`]: super::reserve_top_up
pub fn top_up_sharded<C: ShardChannel>(
    channels: &mut [C],
    tiers: &[f64],
    even: usize,
    total: usize,
    mut used: usize,
) -> usize {
    for &w in tiers {
        for ch in channels.iter_mut() {
            match ask(ch, Directive::TopUpSegment { weight: w, even, total, used }) {
                Reply::ToppedUp { used: u } => used = u,
                other => protocol_panic("ToppedUp", &other),
            }
        }
    }
    used
}

/// Drop-in sharded [`allocate_v2`]: partition the sub-instance
/// contiguously across `shards` in-process shards and run the token
/// protocol. Bit-identical to the single-pool fill for any shard count
/// (the unit tests below and the Python mirror prove it), so the fleet
/// tier can swap it in under `--shards` without moving the report.
///
/// [`allocate_v2`]: super::allocate_v2
pub fn allocate_v2_sharded(
    shards: usize,
    curves: &[Vec<f64>],
    levels: &[usize],
    total: usize,
    weights: &[f64],
    prev: Option<&[usize]>,
    hysteresis: f64,
) -> Vec<usize> {
    let napps = curves.len();
    assert!(napps > 0, "allocate_v2_sharded needs at least one app");
    let bounds = shard_bounds(napps, shards);
    let mut channels: Vec<InlineChannel> = bounds
        .iter()
        .enumerate()
        .map(|(sid, &(lo, hi))| InlineChannel::new(TenantShard::new(sid, lo, hi, 1, 0)))
        .collect();
    for (ch, &(lo, hi)) in channels.iter_mut().zip(bounds.iter()) {
        match ask(
            ch,
            Directive::InstallFillWith {
                curves: curves[lo..hi].to_vec(),
                weights: weights[lo..hi].to_vec(),
                prev: prev.map(|p| p[lo..hi].to_vec()),
                reservations: vec![0; hi - lo],
                levels: levels.to_vec(),
                hysteresis,
            },
        ) {
            Reply::FillInstalled => {}
            other => protocol_panic("FillInstalled", &other),
        }
    }
    let used = napps * levels[0];
    assert!(used <= total, "floor rung oversubscribes the cluster");
    waterfill_sharded(&mut channels, used, total, total / napps);
    let mut out = Vec::with_capacity(napps);
    for ch in &mut channels {
        match ask(ch, Directive::TakeRungs) {
            Reply::Rungs { rungs } => out.extend(rungs),
            other => protocol_panic("Rungs", &other),
        }
    }
    out
}

/// The fleet scheduler's admission front: the single-pool
/// [`EpochAdmission`] when `shards <= 1` (bit-identical to the
/// pre-shard path by construction — it *is* that path), or the sharded
/// coordinator protocol over [`InlineChannel`]s. Both arms expose the
/// same `decide`/`hold`/`overdue_pending` shape, so call sites don't
/// branch.
///
/// [`EpochAdmission`]: super::EpochAdmission
pub enum AdmissionTier {
    Single(super::EpochAdmission),
    Sharded { channels: Vec<InlineChannel>, bounds: Vec<(usize, usize)>, bound: usize },
}

impl AdmissionTier {
    /// `shards <= 1` builds the legacy single-pool controller; larger
    /// values partition `apps` contiguously (clamped to `apps` shards).
    /// `bound`/`hysteresis` as in [`EpochAdmission::new`] /
    /// [`with_hysteresis`].
    ///
    /// [`EpochAdmission::new`]: super::EpochAdmission::new
    /// [`with_hysteresis`]: super::EpochAdmission::with_hysteresis
    pub fn new(apps: usize, shards: usize, bound: usize, hysteresis: usize) -> Self {
        if shards <= 1 {
            AdmissionTier::Single(
                super::EpochAdmission::new(apps, bound).with_hysteresis(hysteresis),
            )
        } else {
            let bounds = shard_bounds(apps, shards);
            let channels = bounds
                .iter()
                .enumerate()
                .map(|(sid, &(lo, hi))| {
                    InlineChannel::new(TenantShard::new(sid, lo, hi, bound, hysteresis))
                })
                .collect();
            AdmissionTier::Sharded { channels, bounds, bound: bound.max(1) }
        }
    }

    /// See [`EpochAdmission::decide`].
    ///
    /// [`EpochAdmission::decide`]: super::EpochAdmission::decide
    pub fn decide(&mut self, total: usize, weights: &[f64], reservations: &[usize]) -> Vec<bool> {
        match self {
            AdmissionTier::Single(a) => a.decide(total, weights, reservations),
            AdmissionTier::Sharded { channels, bounds, bound } => {
                for (ch, &(lo, hi)) in channels.iter_mut().zip(bounds.iter()) {
                    match ask(
                        ch,
                        Directive::LoadEpoch {
                            curves: Vec::new(),
                            demands: reservations[lo..hi].to_vec(),
                            weights: weights[lo..hi].to_vec(),
                        },
                    ) {
                        Reply::Loaded => {}
                        other => protocol_panic("Loaded", &other),
                    }
                }
                decide_sharded(channels, total, *bound).flags
            }
        }
    }

    /// See [`EpochAdmission::hold`].
    ///
    /// [`EpochAdmission::hold`]: super::EpochAdmission::hold
    pub fn hold(&mut self) -> Vec<bool> {
        match self {
            AdmissionTier::Single(a) => a.hold(),
            AdmissionTier::Sharded { channels, .. } => hold_sharded(channels),
        }
    }

    /// See [`EpochAdmission::overdue_pending`]. Takes `&mut self`
    /// because the sharded arm queries through its channels.
    ///
    /// [`EpochAdmission::overdue_pending`]: super::EpochAdmission::overdue_pending
    pub fn overdue_pending(&mut self) -> bool {
        match self {
            AdmissionTier::Single(a) => a.overdue_pending(),
            AdmissionTier::Sharded { channels, .. } => overdue_sharded(channels),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{allocate_v2, reserve_top_up, EpochAdmission};
    use super::*;
    use crate::util::Rng;

    /// Random monotone-ladder instance with quantized curves (exact
    /// gain ties) and optional hysteresis — the adversarial family the
    /// heap-vs-scan mirror uses.
    fn rand_instance(
        rng: &mut Rng,
    ) -> (Vec<Vec<f64>>, Vec<usize>, usize, Vec<f64>, Option<Vec<usize>>, f64) {
        let napps = 1 + rng.below(24);
        let nlv = 2 + rng.below(7);
        let mut levels = vec![1 + rng.below(3)];
        for _ in 1..nlv {
            let last = *levels.last().unwrap();
            levels.push(last + 1 + rng.below(6));
        }
        let floor_need = napps * levels[0];
        let ceil = napps * levels[nlv - 1];
        let total = floor_need + rng.below(ceil - floor_need + 1);
        let mut curves = Vec::with_capacity(napps);
        for _ in 0..napps {
            let sat = 1 + rng.below(nlv);
            let mut acc = 0.0;
            let mut c = Vec::with_capacity(nlv);
            for l in 0..nlv {
                if l < sat {
                    acc += 0.05 + rng.f64();
                }
                c.push((acc * 32.0).round() / 32.0);
            }
            curves.push(c);
        }
        let weights: Vec<f64> = (0..napps).map(|_| (1 + rng.below(4)) as f64).collect();
        let prev = if rng.below(2) == 1 {
            Some((0..napps).map(|_| rng.below(nlv)).collect())
        } else {
            None
        };
        let hysteresis = [0.0, 0.02, 0.1][rng.below(3)];
        (curves, levels, total, weights, prev, hysteresis)
    }

    #[test]
    fn shard_bounds_partition_covers_and_balances() {
        for n in [0usize, 1, 7, 100, 101] {
            for s in [1usize, 2, 3, 4, 7, 200] {
                let b = shard_bounds(n, s);
                assert_eq!(b[0].0, 0);
                assert_eq!(b[b.len() - 1].1, n);
                for w in b.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap in partition");
                }
                let sizes: Vec<usize> = b.iter().map(|&(lo, hi)| hi - lo).collect();
                let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(mx - mn <= 1, "unbalanced partition {sizes:?}");
            }
        }
    }

    #[test]
    fn sharded_fill_matches_allocate_v2() {
        // Mirror-validated (python/tests/test_shard_mirror.py): the
        // token protocol is the single lazy heap partitioned, so the
        // rung vectors agree exactly — ties, hysteresis and all.
        let mut rng = Rng::new(0x51A2D);
        for case in 0..200 {
            let (curves, levels, total, weights, prev, hyst) = rand_instance(&mut rng);
            let want = allocate_v2(&curves, &levels, total, &weights, prev.as_deref(), hyst);
            for s in [1usize, 2, 3, 4] {
                let got = allocate_v2_sharded(
                    s,
                    &curves,
                    &levels,
                    total,
                    &weights,
                    prev.as_deref(),
                    hyst,
                );
                assert_eq!(got, want, "case {case} shards {s}");
            }
        }
    }

    #[test]
    fn sharded_top_up_matches_reserve_top_up() {
        // Run the fill at a reduced budget (the fairness holdback),
        // then the segmented top-up at the full pool, against the
        // single-pool reserve_top_up on the same start state.
        let mut rng = Rng::new(0x701A);
        for case in 0..120 {
            let (curves, levels, total, weights, prev, hyst) = rand_instance(&mut rng);
            let napps = curves.len();
            let reservations: Vec<usize> =
                (0..napps).map(|_| 1 + rng.below(levels[levels.len() - 1] + 1)).collect();
            let even = (total / napps).max(1);
            let full = total + total / 10 + 1;
            let mut want = allocate_v2(&curves, &levels, total, &weights, prev.as_deref(), hyst);
            reserve_top_up(
                &mut want,
                &levels,
                full,
                &vec![true; napps],
                &reservations,
                even,
                &weights,
            );
            let s = 1 + rng.below(4);
            let bounds = shard_bounds(napps, s);
            let mut channels: Vec<InlineChannel> = bounds
                .iter()
                .enumerate()
                .map(|(sid, &(lo, hi))| InlineChannel::new(TenantShard::new(sid, lo, hi, 1, 0)))
                .collect();
            for (ch, &(lo, hi)) in channels.iter_mut().zip(bounds.iter()) {
                ch.send(Directive::InstallFillWith {
                    curves: curves[lo..hi].to_vec(),
                    weights: weights[lo..hi].to_vec(),
                    prev: prev.as_ref().map(|p| p[lo..hi].to_vec()),
                    reservations: reservations[lo..hi].to_vec(),
                    levels: levels.clone(),
                    hysteresis: hyst,
                });
                ch.recv();
            }
            let used = waterfill_sharded(&mut channels, napps * levels[0], total, total / napps);
            let mut tiers: Vec<f64> = weights.clone();
            tiers.sort_by(|a, b| b.total_cmp(a));
            tiers.dedup_by(|a, b| a.total_cmp(b) == Ordering::Equal);
            top_up_sharded(&mut channels, &tiers, even, full, used);
            let mut got = Vec::new();
            for ch in &mut channels {
                match ask(ch, Directive::TakeRungs) {
                    Reply::Rungs { rungs } => got.extend(rungs),
                    other => protocol_panic("Rungs", &other),
                }
            }
            assert_eq!(got, want, "case {case} shards {s}");
        }
    }

    #[test]
    fn sharded_admission_matches_epoch_admission() {
        // Multi-epoch equivalence under parking churn: flags AND both
        // streak arrays, via the AdmissionTier facade the fleet uses.
        let mut rng = Rng::new(0xAD31);
        for trial in 0..30 {
            let n = 5 + rng.below(40);
            let bound = 2 + rng.below(4);
            let hyst = rng.below(3);
            let total = (n / 2).max(1) * 2;
            let weights: Vec<f64> = (0..n).map(|_| (1 + rng.below(4)) as f64).collect();
            let mut single = EpochAdmission::new(n, bound).with_hysteresis(hyst);
            let shards = 2 + rng.below(3);
            let mut tier = AdmissionTier::new(n, shards, bound, hyst);
            for epoch in 0..6 {
                let demands: Vec<usize> = (0..n).map(|_| 1 + rng.below(4)).collect();
                let want = single.decide(total, &weights, &demands);
                let got = tier.decide(total, &weights, &demands);
                assert_eq!(got, want, "trial {trial} epoch {epoch} shards {shards}");
                assert_eq!(tier.overdue_pending(), single.overdue_pending());
            }
        }
    }

    #[test]
    fn sharded_admission_hold_ticks_match() {
        let n = 12;
        let weights: Vec<f64> = (0..n).map(|i| (1 + i % 3) as f64).collect();
        let demands = vec![3usize; n];
        let mut single = EpochAdmission::new(n, 3).with_hysteresis(1);
        let mut tier = AdmissionTier::new(n, 3, 3, 1);
        for round in 0..4 {
            let want = single.decide(n, &weights, &demands);
            let got = tier.decide(n, &weights, &demands);
            assert_eq!(got, want, "decide round {round}");
            let want_h = single.hold();
            let got_h = tier.hold();
            assert_eq!(got_h, want_h, "hold round {round}");
            assert_eq!(tier.overdue_pending(), single.overdue_pending(), "round {round}");
        }
    }

    #[test]
    fn sharded_force_first_matches() {
        // total = 0: nothing fits, the coordinator must force the same
        // global order[0] the single scan picks.
        let n = 7;
        let weights = vec![1.0, 4.0, 2.0, 4.0, 1.0, 2.0, 4.0];
        let demands = vec![50usize; n];
        for s in [2usize, 3] {
            let mut single = EpochAdmission::new(n, 3);
            let mut tier = AdmissionTier::new(n, s, 3, 0);
            let w1 = single.decide(10, &weights, &demands);
            let g1 = tier.decide(10, &weights, &demands);
            assert_eq!(g1, w1);
            let w2 = single.decide(0, &weights, &demands);
            let g2 = tier.decide(0, &weights, &demands);
            assert_eq!(g2, w2, "shards {s}");
            assert_eq!(g2.iter().filter(|&&a| a).count(), 1);
        }
    }

    #[test]
    fn single_tier_is_the_legacy_controller() {
        // S=1 must be the pre-shard path itself, not an equivalent.
        let tier = AdmissionTier::new(8, 1, 4, 2);
        assert!(matches!(tier, AdmissionTier::Single(_)));
    }

    #[test]
    fn decision_reports_weight_tiers_descending() {
        let n = 10;
        let weights: Vec<f64> =
            (0..n).map(|i| if i % 5 == 0 { 4.0 } else if i % 5 <= 2 { 2.0 } else { 1.0 }).collect();
        let demands = vec![2usize; n];
        let bounds = shard_bounds(n, 3);
        let mut channels: Vec<InlineChannel> = bounds
            .iter()
            .enumerate()
            .map(|(sid, &(lo, hi))| InlineChannel::new(TenantShard::new(sid, lo, hi, 4, 0)))
            .collect();
        for (ch, &(lo, hi)) in channels.iter_mut().zip(bounds.iter()) {
            ch.send(Directive::LoadEpoch {
                curves: Vec::new(),
                demands: demands[lo..hi].to_vec(),
                weights: weights[lo..hi].to_vec(),
            });
            ch.recv();
        }
        let d = decide_sharded(&mut channels, 3 * n, 4);
        assert_eq!(d.tiers, vec![4.0, 2.0, 1.0]);
        assert_eq!(d.flags.len(), n);
    }
}
