//! Fleet-level resource scheduler: one shared, contended cluster and
//! dynamic cross-app core reallocation.
//!
//! The paper tunes a single perception application against a dedicated
//! cluster; a production fleet co-tenants many pipelines on the same
//! cores. This module owns that global decision: every *reallocation
//! epoch* it asks each app's tuner "what fidelity could you hold at k
//! cores?" ([`BudgetedController::utility_at`] — answered from the
//! learned latency model under the monotone resource prior, not by
//! re-exploring) and then runs a **marginal-utility water-filling** pass:
//! starting from a fairness floor, the next core chunk always goes to the
//! app that buys the most fidelity with it, subject to every app's
//! latency bound. Related systems learn the same joint decision end to
//! end (Chanakya, arXiv 2106.05665) or reallocate cores across concurrent
//! perception pipelines by marginal utility (arXiv 2207.13280); here the
//! utility curves fall out of the paper's own latency models, so the
//! scheduler needs no training of its own.
//!
//! Determinism: [`allocate`] is a pure function of the utility curves,
//! and curves are pure functions of per-app tuner state, so fleet runs
//! are reproducible regardless of worker-thread count (asserted by
//! `rust/tests/scheduler_fleet.rs`).
//!
//! [`BudgetedController::utility_at`]:
//!     crate::tuner::BudgetedController::utility_at

pub mod live;

use crate::util::json::Json;

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Frames between reallocations.
    pub epoch_frames: usize,
    /// Epochs pinned at the even share before the first reallocation
    /// (the latency models start empty; scheduling on noise helps no one).
    pub warmup_epochs: usize,
    /// Minimum cores every app keeps; 0 → half the even share.
    pub fairness_floor: usize,
    /// Ladder rungs generated between the floor and the cap.
    pub ladder_rungs: usize,
    /// Cap on any single app's allocation, as a multiple of the even
    /// share (bounded by what the floor leaves available).
    pub max_boost: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            epoch_frames: 50,
            warmup_epochs: 1,
            fairness_floor: 0,
            ladder_rungs: 6,
            max_boost: 3.0,
        }
    }
}

impl SchedulerConfig {
    /// The effective fairness floor for a fleet of `apps` on `total`
    /// cores: the configured floor, defaulted to half the even share and
    /// never above it.
    pub fn floor_cores(&self, total: usize, apps: usize) -> usize {
        let even = (total / apps.max(1)).max(1);
        let floor = if self.fairness_floor > 0 {
            self.fairness_floor
        } else {
            (even / 2).max(1)
        };
        floor.min(even).max(1)
    }
}

/// The shared core ladder for a fleet of `apps` on `total` cores: rungs
/// from the fairness floor up to the boost cap, geometrically spaced,
/// always containing the even share exactly (so the static baseline sits
/// on a rung).
pub fn core_levels(total: usize, apps: usize, floor: usize, rungs: usize, boost: f64) -> Vec<usize> {
    let even = (total / apps.max(1)).max(1);
    let floor = floor.clamp(1, even);
    let cap = ((even as f64 * boost).ceil() as usize)
        .min(total.saturating_sub((apps.saturating_sub(1)) * floor))
        .max(even);
    let mut levels = std::collections::BTreeSet::new();
    levels.insert(floor);
    levels.insert(even);
    levels.insert(cap);
    if rungs > 1 && cap > floor {
        let ratio = cap as f64 / floor as f64;
        for i in 0..rungs {
            let lvl = (floor as f64 * ratio.powf(i as f64 / (rungs - 1) as f64)).round()
                as usize;
            levels.insert(lvl.clamp(floor, cap));
        }
    }
    levels.into_iter().collect()
}

/// Marginal-utility water-filling over a shared rung ladder.
///
/// `curves[a][l]` is app `a`'s predicted feasible fidelity at rung `l`
/// (from its learned latency model). Every app starts at the floor rung;
/// the best affordable jump — the one with the highest fidelity gain per
/// core — is applied repeatedly until no strictly positive gain fits the
/// budget. Ties break deterministically toward the lower app index and
/// the lower target rung. A final top-up pass raises the lowest-allocated
/// apps back toward the even share while cores sit idle, so uninformative
/// curves degrade to the static baseline instead of starving the fleet.
///
/// Returns one rung index per app. Invariants (tested): allocated cores
/// never exceed `total`, and every app keeps at least the floor rung.
pub fn allocate(curves: &[Vec<f64>], levels: &[usize], total: usize) -> Vec<usize> {
    let napps = curves.len();
    assert!(napps > 0, "allocate needs at least one app");
    assert!(!levels.is_empty(), "allocate needs a rung ladder");
    for c in curves {
        assert_eq!(c.len(), levels.len(), "curve shape mismatch");
    }
    let mut lvl = vec![0usize; napps];
    let mut used = napps * levels[0];
    assert!(used <= total, "floor rung oversubscribes the cluster");

    loop {
        let mut best: Option<(f64, usize, usize)> = None; // (gain/core, app, rung)
        for a in 0..napps {
            for j in (lvl[a] + 1)..levels.len() {
                if used - levels[lvl[a]] + levels[j] > total {
                    continue;
                }
                let du = curves[a][j] - curves[a][lvl[a]];
                if du <= 1e-12 {
                    continue;
                }
                let g = du / (levels[j] - levels[lvl[a]]) as f64;
                if best.map_or(true, |(bg, _, _)| g > bg) {
                    best = Some((g, a, j));
                }
            }
        }
        match best {
            None => break,
            Some((_, a, j)) => {
                used = used - levels[lvl[a]] + levels[j];
                lvl[a] = j;
            }
        }
    }

    // top-up: while cores sit idle, raise the lowest-allocated app back
    // toward the even share (uninformative curves degrade to ~static)
    let even = total / napps;
    loop {
        let mut cand: Option<(usize, usize, usize)> = None; // (cores, app, rung)
        for a in 0..napps {
            let j = lvl[a] + 1;
            if j >= levels.len() || levels[j] > even {
                continue;
            }
            if used - levels[lvl[a]] + levels[j] > total {
                continue;
            }
            if cand.map_or(true, |(c, _, _)| levels[lvl[a]] < c) {
                cand = Some((levels[lvl[a]], a, j));
            }
        }
        match cand {
            None => break,
            Some((_, a, j)) => {
                used = used - levels[lvl[a]] + levels[j];
                lvl[a] = j;
            }
        }
    }
    lvl
}

/// One epoch's allocation decision, recorded in the fleet report.
#[derive(Debug, Clone)]
pub struct AllocationFrame {
    pub epoch: usize,
    /// First frame the allocation governs.
    pub start_frame: usize,
    /// Ladder rung index per app.
    pub levels: Vec<usize>,
    /// Core quota per app (the rung budgets).
    pub cores: Vec<usize>,
    /// Utility the scheduler predicted for each app at its rung (NaN-free;
    /// warmup epochs record zeros).
    pub predicted_utility: Vec<f64>,
}

impl AllocationFrame {
    pub fn total_cores(&self) -> usize {
        self.cores.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .put("epoch", self.epoch)
            .put("start_frame", self.start_frame)
            .put(
                "levels",
                Json::Arr(self.levels.iter().map(|&l| Json::from(l)).collect()),
            )
            .put(
                "cores",
                Json::Arr(self.cores.iter().map(|&c| Json::from(c)).collect()),
            )
            .put("predicted_utility", Json::from_f64_slice(&self.predicted_utility))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_contains_floor_even_and_cap() {
        let levels = core_levels(120, 8, 7, 6, 3.0);
        assert_eq!(levels.first(), Some(&7));
        assert!(levels.contains(&15), "{levels:?}");
        assert_eq!(levels.last(), Some(&45));
        assert!(levels.windows(2).all(|w| w[0] < w[1]), "{levels:?}");
        // cap is bounded by what the floor leaves for everyone else
        let tight = core_levels(32, 4, 8, 5, 4.0);
        assert_eq!(tight, vec![8]); // floor == even == cap
    }

    #[test]
    fn floor_cores_defaults_to_half_even_share() {
        let cfg = SchedulerConfig::default();
        assert_eq!(cfg.floor_cores(120, 8), 7);
        assert_eq!(cfg.floor_cores(120, 4), 15);
        let explicit = SchedulerConfig { fairness_floor: 4, ..Default::default() };
        assert_eq!(explicit.floor_cores(120, 8), 4);
        // never above the even share
        assert_eq!(explicit.floor_cores(8, 4), 2);
    }

    #[test]
    fn allocate_respects_budget_and_floor() {
        let levels = vec![7, 10, 15, 21, 31, 45];
        // two greedy apps, two flat ones
        let steep = vec![0.0, 0.2, 0.4, 0.6, 0.8, 0.9];
        let flat = vec![0.5, 0.5, 0.5, 0.5, 0.5, 0.5];
        let curves = vec![steep.clone(), flat.clone(), steep, flat];
        let lvl = allocate(&curves, &levels, 60);
        let cores: Vec<usize> = lvl.iter().map(|&l| levels[l]).collect();
        assert!(cores.iter().sum::<usize>() <= 60, "{cores:?}");
        assert!(cores.iter().all(|&c| c >= 7), "{cores:?}");
        // the steep apps got the spare cores
        assert!(cores[0] > cores[1], "{cores:?}");
        assert!(cores[2] > cores[3], "{cores:?}");
    }

    #[test]
    fn allocate_flat_curves_degrade_to_even_share() {
        let levels = vec![7, 10, 15, 21, 31, 45];
        let curves = vec![vec![0.7; 6]; 8];
        let lvl = allocate(&curves, &levels, 120);
        let cores: Vec<usize> = lvl.iter().map(|&l| levels[l]).collect();
        // top-up parks everyone on the even share
        assert_eq!(cores, vec![15; 8], "{cores:?}");
    }

    #[test]
    fn allocate_handles_nonconcave_curves() {
        // utility jumps only at the top rung: the greedy pass must see the
        // multi-rung jump, not stall at the flat middle
        let levels = vec![4, 8, 16, 32];
        let late = vec![0.1, 0.1, 0.1, 0.9];
        let flat = vec![0.6, 0.6, 0.6, 0.6];
        let lvl = allocate(&[late.clone(), flat.clone(), flat], &levels, 44);
        assert_eq!(levels[lvl[0]], 32, "{lvl:?}");
    }

    #[test]
    fn allocate_tie_breaks_toward_lower_index() {
        let levels = vec![4, 8];
        let want = vec![0.1, 0.9];
        // only one app can be raised
        let lvl = allocate(&[want.clone(), want.clone(), want], &levels, 16);
        assert_eq!(lvl, vec![1, 0, 0]);
    }

    #[test]
    fn allocation_frame_json_roundtrips() {
        let f = AllocationFrame {
            epoch: 3,
            start_frame: 150,
            levels: vec![0, 2, 1],
            cores: vec![7, 15, 10],
            predicted_utility: vec![0.5, 0.25, 0.75],
        };
        assert_eq!(f.total_cores(), 32);
        let j = Json::parse(&f.to_json().to_string()).unwrap();
        assert_eq!(j.req("epoch").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("cores").unwrap().as_f64_vec().unwrap(), vec![7.0, 15.0, 10.0]);
    }
}
