//! Fleet-level resource scheduler: one shared, contended cluster and
//! dynamic cross-app core reallocation.
//!
//! The paper tunes a single perception application against a dedicated
//! cluster; a production fleet co-tenants many pipelines on the same
//! cores. This module owns that global decision: every *reallocation
//! epoch* it asks each app's tuner "what fidelity could you hold at k
//! cores?" ([`BudgetedController::utility_at`] — answered from the
//! learned latency model under the monotone resource prior, not by
//! re-exploring) and then runs a **marginal-utility water-filling** pass:
//! starting from a fairness floor, the next core chunk always goes to the
//! app that buys the most fidelity with it, subject to every app's
//! latency bound. Related systems learn the same joint decision end to
//! end (Chanakya, arXiv 2106.05665) or reallocate cores across concurrent
//! perception pipelines by marginal utility (arXiv 2207.13280); here the
//! utility curves fall out of the paper's own latency models, so the
//! scheduler needs no training of its own.
//!
//! **v2** makes the allocator stateful and production-shaped, following
//! the switching-cost and admission lessons of those same systems:
//!
//! * **Hysteresis** ([`allocate_v2`], [`SchedulerConfig::hysteresis`]) —
//!   each app's incumbent rung carries a utility bonus equal to the
//!   migration penalty, so a grant only moves when the predicted
//!   marginal-utility gain exceeds it. Noisy learned curves stop
//!   thrashing allocations; real load shifts still reallocate.
//! * **Priority weights** ([`SchedulerConfig::priorities`]) — tenant
//!   tiers scale each app's curve in the water-filling pass, so a paying
//!   tenant's fidelity point buys proportionally more cores.
//! * **Admission control** ([`admit`], [`SchedulerConfig::admission`]) —
//!   when `floor × apps` exceeds the pool, the lowest-priority apps are
//!   parked (zero cores, frames dropped and counted) instead of silently
//!   over-granting, and sub-stage-count quotas charge the
//!   time-multiplexing latency multiplier so fairness-floor accounting
//!   is exact.
//!
//! **v3** makes admission *epoch-granular* ([`EpochAdmission`],
//! [`SchedulerConfig::admission_epoch`]): parking stops being a run-level
//! precomputation and becomes allocator state. Every reallocation epoch
//! the admission controller re-decides who runs from the tenants' *demands*
//! (the cores at which each learned utility curve tops out,
//! [`demand_cores`]) — so a tenant parked under load pressure is re-admitted
//! as soon as the pool frees up — and rotates parking among equal-priority
//! tenants so no tenant is parked more than `starvation_bound` consecutive
//! epochs (Sense-React-style bounded re-admission, arXiv 2207.13280).
//! Scripted mid-run tier changes ([`SchedulerConfig::tier_shift`]) feed the
//! same machinery: an upgraded tenant preempts a seat at the next epoch
//! instead of waiting for the next run.
//!
//! Determinism: [`allocate`] is a pure function of the utility curves,
//! and curves are pure functions of per-app tuner state, so fleet runs
//! are reproducible regardless of worker-thread count (asserted by
//! `rust/tests/scheduler_fleet.rs`).
//!
//! [`BudgetedController::utility_at`]:
//!     crate::tuner::BudgetedController::utility_at

pub mod coordinator;
pub mod frontier;
pub mod live;

use crate::util::json::Json;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One candidate rung jump in the heap water-fill: app `app` moving from
/// its current rung to `rung` at `gain` marginal utility per core. Heap
/// order reproduces the legacy scan's strict-`>` tie-breaks exactly:
/// highest gain first, then the lower app index, then the lower target
/// rung. Gains are finite and positive (the `du <= 1e-12` filter runs
/// before an entry is built), so `total_cmp` agrees with the scan's
/// partial-order comparisons.
#[derive(Clone, Copy, Debug)]
struct Jump {
    gain: f64,
    app: usize,
    rung: usize,
}

impl PartialEq for Jump {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Jump {}
impl PartialOrd for Jump {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Jump {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain
            .total_cmp(&other.gain)
            .then_with(|| other.app.cmp(&self.app))
            .then_with(|| other.rung.cmp(&self.rung))
    }
}

/// Scheduler policy knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Frames between reallocations.
    pub epoch_frames: usize,
    /// Epochs pinned at the even share before the first reallocation
    /// (the latency models start empty; scheduling on noise helps no one).
    pub warmup_epochs: usize,
    /// Minimum cores every app keeps; 0 → half the even share.
    pub fairness_floor: usize,
    /// Ladder rungs generated between the floor and the cap.
    pub ladder_rungs: usize,
    /// Cap on any single app's allocation, as a multiple of the even
    /// share (bounded by what the floor leaves available).
    pub max_boost: f64,
    /// Switching-cost term (utility units): an app's grant only moves
    /// off its incumbent rung when the priority-weighted marginal-utility
    /// gain exceeds this migration penalty. 0 (the default) reproduces
    /// the PR 2 stateless greedy water-filler exactly; positive values
    /// kill allocation thrash under noisy learned curves.
    pub hysteresis: f64,
    /// Per-app priority weights (paying-tenant tiers) scaling each app's
    /// utility curve in the water-filling pass. Empty → every tenant at
    /// weight 1.0; shorter vectors are padded with 1.0. Must be finite
    /// and > 0.
    pub priorities: Vec<f64>,
    /// Admission control: when `floor × apps` exceeds the shared pool,
    /// park the lowest-priority apps (zero cores, frames dropped and
    /// counted) instead of silently over-granting, and charge
    /// sub-stage-count time-multiplexing as a latency multiplier
    /// ([`time_multiplex_factor`]) so fairness-floor accounting is exact.
    ///
    /// [`time_multiplex_factor`]: crate::simulator::time_multiplex_factor
    pub admission: bool,
    /// Epoch-granular admission ([`EpochAdmission`]): the park/run decision
    /// is re-made every reallocation epoch from the tenants' learned demands
    /// instead of once per run from static priorities. Implies exact
    /// fairness-floor accounting (like [`admission`](Self::admission)) and
    /// requires the dynamic fleet mode (the decision consumes utility
    /// curves). Re-admitted tenants resume with a *warm* model: their
    /// controllers and learned curves survive parking.
    pub admission_epoch: bool,
    /// Starvation bound `k` for epoch-granular admission: with equal
    /// priorities, parking rotates so no tenant is parked more than `k`
    /// consecutive epochs (0 → [`DEFAULT_STARVATION_BOUND`]). The bound is
    /// honored whenever capacity permits — overdue tenants outrank every
    /// equal-priority incumbent; a strictly higher tier still wins.
    pub starvation_bound: usize,
    /// Scripted mid-run tier change: from the first epoch whose start frame
    /// reaches `.0`, the priority vector becomes `.1` (tier
    /// upgrades/downgrades land at the next epoch boundary, flowing into
    /// both the water-filling pass and the admission decision).
    pub tier_shift: Option<(usize, Vec<f64>)>,
    /// Demand-confidence term for epoch-granular admission: a ladder
    /// rung's utility only counts toward a tenant's demand
    /// ([`demand_cores_confident`]) once the tenant has at least this
    /// many observations at that rung. An immature model whose curve
    /// optimistically tops out at a tiny untried rung then reserves the
    /// calibration share instead of under-reserving — the post-warmup
    /// over-admission that squeezed heavies below SLO on some seeds
    /// (PR 4 ROADMAP note). 0 (the default) reproduces the historical
    /// optimistic demand bit-for-bit.
    pub demand_confidence: usize,
    /// Re-admission hysteresis for epoch-granular admission (cores): a
    /// *parked, non-overdue* tenant is only re-admitted when the pool
    /// holds this many idle cores beyond its reservation. Without it, a
    /// load blip that frees exactly one tenant's floor re-admits then
    /// immediately re-parks — each transition costing a pause/drain
    /// cycle. Set it to roughly one rotation period's churn (the floor
    /// of the tenants being rotated). Overdue tenants bypass the gate —
    /// the starvation bound stays honored. 0 (the default) reproduces
    /// the historical decision bit-for-bit.
    pub admission_hysteresis: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            epoch_frames: 50,
            warmup_epochs: 1,
            fairness_floor: 0,
            ladder_rungs: 6,
            max_boost: 3.0,
            hysteresis: 0.0,
            priorities: Vec::new(),
            admission: false,
            admission_epoch: false,
            starvation_bound: 0,
            tier_shift: None,
            demand_confidence: 0,
            admission_hysteresis: 0,
        }
    }
}

/// Default starvation bound (consecutive parked epochs) for epoch-granular
/// admission — four epochs keeps rotation churn of the same order as the
/// hysteresis cooldown horizon while still time-bounding every tenant's
/// wait.
pub const DEFAULT_STARVATION_BOUND: usize = 4;

impl SchedulerConfig {
    /// The effective fairness floor for a fleet of `apps` on `total`
    /// cores: the configured floor, defaulted to half the even share and
    /// never above it.
    pub fn floor_cores(&self, total: usize, apps: usize) -> usize {
        let even = (total / apps.max(1)).max(1);
        let floor = if self.fairness_floor > 0 {
            self.fairness_floor
        } else {
            (even / 2).max(1)
        };
        floor.min(even).max(1)
    }

    /// The fairness floor *requested* (no even-share clamp): what
    /// admission control accounts against. Without admission the floor
    /// is silently clamped to the even share (the historical behavior);
    /// with it, a floor the pool cannot honor parks tenants instead.
    pub fn requested_floor(&self, total: usize, apps: usize) -> usize {
        if self.fairness_floor > 0 {
            self.fairness_floor.min(total.max(1))
        } else {
            self.floor_cores(total, apps)
        }
    }

    /// Priority weight of app `index` (missing entries default to 1.0).
    pub fn priority_of(&self, index: usize) -> f64 {
        self.priorities.get(index).copied().unwrap_or(1.0)
    }

    /// The full per-app weight vector for a fleet of `apps`, validated.
    pub fn weights(&self, apps: usize) -> Vec<f64> {
        pad_weights(&self.priorities, apps)
    }

    /// The weight vector in force at `frame`: the base priorities, or the
    /// scripted [`tier_shift`](Self::tier_shift) vector once its frame has
    /// been reached. Reduces to [`weights`](Self::weights) without a shift.
    pub fn weights_at(&self, apps: usize, frame: usize) -> Vec<f64> {
        match &self.tier_shift {
            Some((f, ws)) if frame >= *f => pad_weights(ws, apps),
            _ => self.weights(apps),
        }
    }

    /// Either admission flavor is on (both switch the run to exact
    /// fairness-floor accounting).
    pub fn admission_any(&self) -> bool {
        self.admission || self.admission_epoch
    }

    /// The configured starvation bound, defaulted.
    pub fn starvation_bound_or_default(&self) -> usize {
        if self.starvation_bound == 0 {
            DEFAULT_STARVATION_BOUND
        } else {
            self.starvation_bound
        }
    }
}

/// Pad a priority list to `apps` entries (missing → 1.0) and validate.
fn pad_weights(priorities: &[f64], apps: usize) -> Vec<f64> {
    let w: Vec<f64> =
        (0..apps).map(|i| priorities.get(i).copied().unwrap_or(1.0)).collect();
    assert!(
        w.iter().all(|p| p.is_finite() && *p > 0.0),
        "priority weights must be finite and > 0: {w:?}"
    );
    w
}

/// Admission decision: which apps run when `floor × apps` exceeds the
/// pool. Keeps the `total / floor` highest-priority apps and parks the
/// rest (ties park the higher index first, so the decision is
/// deterministic). Returns one `admitted` flag per app; every app is
/// admitted when the floor fits.
pub fn admit(total: usize, floor: usize, weights: &[f64]) -> Vec<bool> {
    let apps = weights.len();
    let floor = floor.max(1);
    if floor * apps <= total {
        return vec![true; apps];
    }
    let capacity = (total / floor).clamp(1, apps);
    // sort by (priority desc, index asc); keep the first `capacity`
    let mut order: Vec<usize> = (0..apps).collect();
    order.sort_by(|&a, &b| {
        weights[b].partial_cmp(&weights[a]).unwrap().then(a.cmp(&b))
    });
    let mut admitted = vec![false; apps];
    for &i in order.iter().take(capacity) {
        admitted[i] = true;
    }
    admitted
}

/// A tenant's *demand*: the smallest ladder budget (cores) at which its
/// learned utility curve reaches its maximum — the point past which more
/// cores buy no predicted fidelity. A flat-zero curve (nothing predicted
/// feasible anywhere) returns `fallback` instead of the floor rung: a
/// starved model must be read as "needs the calibration share", not as
/// contentment, or parking becomes a death spiral (no cores → infeasible
/// observations → no demand → no cores).
pub fn demand_cores(curve: &[f64], levels: &[usize], fallback: usize) -> usize {
    assert_eq!(curve.len(), levels.len(), "curve/ladder shape");
    let mx = curve.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    if !(mx > 0.0) {
        return fallback;
    }
    for (l, &u) in curve.iter().enumerate() {
        if u >= mx - 1e-12 {
            return levels[l];
        }
    }
    levels[levels.len() - 1]
}

/// [`demand_cores`] with a *demand-confidence* term: a rung's utility
/// only counts toward the demand once the tenant holds at least
/// `min_obs` observations at that rung (`obs[l]`, from
/// [`BudgetedController::rung_observations`] in the trace-replaying fleet
/// or rung-residency frame counts on the live path). Unconfident rungs
/// are masked to zero, so
///
/// * an immature model whose curve optimistically tops out at a tiny
///   *untried* rung reserves a confident rung (or, with no confident
///   rung at all, the `fallback` calibration share) instead of
///   under-reserving — the over-admission fix of the PR 4 ROADMAP note;
/// * `min_obs == 0` masks nothing and reproduces [`demand_cores`]
///   bit-for-bit (the historical optimistic behavior every recorded
///   threshold depends on).
///
/// [`BudgetedController::rung_observations`]:
///     crate::tuner::BudgetedController::rung_observations
pub fn demand_cores_confident(
    curve: &[f64],
    levels: &[usize],
    fallback: usize,
    obs: &[u64],
    min_obs: usize,
) -> usize {
    if min_obs == 0 {
        return demand_cores(curve, levels, fallback);
    }
    assert_eq!(curve.len(), obs.len(), "curve/observation shape");
    let masked: Vec<f64> = curve
        .iter()
        .zip(obs)
        .map(|(&u, &c)| if c >= min_obs as u64 { u } else { 0.0 })
        .collect();
    demand_cores(&masked, levels, fallback)
}

/// Epoch-granular admission state: who ran last epoch, how long each parked
/// tenant has waited, and how long each incumbent has held its seat.
///
/// Every epoch [`decide`](Self::decide) re-ranks the tenants —
///
/// 1. priority weight (descending): a strictly higher tier always outranks;
/// 2. within a tier, *overdue* parked tenants first (parked for
///    `bound` − 1 epochs already: parking them again would break the
///    starvation bound), longest-parked first;
/// 3. then incumbents, shortest-tenured first (so rotation displaces the
///    tenant that has held a seat longest);
/// 4. then the remaining parked tenants, longest-parked first (so freed
///    pool capacity re-admits the tenant that has waited longest);
///
/// — and admits greedily in rank order while the tenants' core
/// *reservations* (their demands, floored at one core) fit the pool.
/// Freshly parked cohorts get staggered virtual streaks so their overdue
/// deadlines spread over the bound window instead of piling up on one
/// epoch: with equal priorities and adequate capacity no tenant is ever
/// parked more than `bound` consecutive epochs (property-tested across
/// seeds in `rust/tests/scheduler_fleet.rs`).
#[derive(Debug, Clone)]
pub struct EpochAdmission {
    bound: usize,
    admitted: Vec<bool>,
    parked_streak: Vec<usize>,
    admitted_streak: Vec<usize>,
    decided: bool,
    /// Re-admission slack gate (cores); see
    /// [`SchedulerConfig::admission_hysteresis`].
    hysteresis: usize,
}

impl EpochAdmission {
    /// Admission state for `apps` tenants under a starvation `bound`
    /// (consecutive parked epochs; clamped to at least 1). Starts
    /// all-admitted and undecided — the first [`decide`](Self::decide)
    /// ranks every tenant as an incumbent.
    pub fn new(apps: usize, bound: usize) -> Self {
        assert!(apps > 0, "admission needs at least one tenant");
        EpochAdmission {
            bound: bound.max(1),
            admitted: vec![true; apps],
            parked_streak: vec![0; apps],
            admitted_streak: vec![0; apps],
            decided: false,
            hysteresis: 0,
        }
    }

    /// Enable the re-admission slack gate: a parked, non-overdue tenant
    /// is only re-admitted when `slack` idle cores remain beyond its
    /// reservation ([`SchedulerConfig::admission_hysteresis`]).
    pub fn with_hysteresis(mut self, slack: usize) -> Self {
        self.hysteresis = slack;
        self
    }

    /// The starvation bound in force.
    pub fn bound(&self) -> usize {
        self.bound
    }

    /// Last decision (all-admitted before the first [`decide`](Self::decide)).
    pub fn admitted(&self) -> &[bool] {
        &self.admitted
    }

    /// Per-tenant overdue flags: parked tenants whose next parked epoch
    /// would break the starvation bound.
    fn overdue_flags(&self) -> Vec<bool> {
        (0..self.admitted.len())
            .map(|i| {
                self.decided
                    && !self.admitted[i]
                    && self.parked_streak[i] + 1 >= self.bound
            })
            .collect()
    }

    /// Tenants ranked for admission (see the type docs for the order).
    fn rank(&self, weights: &[f64]) -> Vec<usize> {
        let n = weights.len();
        let overdue = self.overdue_flags();
        debug_assert_eq!(overdue.len(), n);
        let class = |i: usize| -> u8 {
            if overdue[i] {
                0
            } else if self.admitted[i] {
                1
            } else {
                2
            }
        };
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            weights[b]
                .partial_cmp(&weights[a])
                .unwrap()
                .then(class(a).cmp(&class(b)))
                .then_with(|| {
                    if class(a) == 1 {
                        self.admitted_streak[a].cmp(&self.admitted_streak[b])
                    } else {
                        self.parked_streak[b].cmp(&self.parked_streak[a])
                    }
                })
                .then(a.cmp(&b))
        });
        order
    }

    /// One epoch's admission decision. `reservations[i]` is tenant `i`'s
    /// core demand (clamped to at least one core and at most the pool);
    /// tenants are admitted greedily in rank order while the reservations
    /// fit `total`. The top-ranked tenant is always admitted.
    pub fn decide(
        &mut self,
        total: usize,
        weights: &[f64],
        reservations: &[usize],
    ) -> Vec<bool> {
        let n = self.admitted.len();
        assert_eq!(weights.len(), n, "weight vector shape");
        assert_eq!(reservations.len(), n, "reservation vector shape");
        let order = self.rank(weights);
        let overdue = self.overdue_flags();
        let mut next = vec![false; n];
        let mut used = 0usize;
        for &i in &order {
            let r = reservations[i].clamp(1, total.max(1));
            // re-admission hysteresis: a parked, non-overdue tenant only
            // re-enters when `hysteresis` idle cores remain beyond its
            // reservation, so a one-epoch load blip cannot flap it
            // through a re-admit/re-park pause/drain cycle. Overdue
            // tenants bypass the gate (the starvation bound wins).
            let slack =
                if self.decided && !self.admitted[i] && !overdue[i] { self.hysteresis } else { 0 };
            if used + r + slack <= total {
                next[i] = true;
                used += r;
            }
        }
        if !next.iter().any(|&a| a) {
            next[order[0]] = true;
        }
        // stagger freshly parked cohorts: the j-th freshly parked tenant
        // (rank order) starts with virtual streak (m-1-j)/gpe, spreading
        // the cohort's overdue deadlines over the bound window so at most
        // ceil(m/bound) re-admissions fall due per epoch
        let fresh: Vec<usize> = order
            .iter()
            .copied()
            .filter(|&i| !next[i] && (self.admitted[i] || !self.decided))
            .collect();
        let m = fresh.len();
        let gpe = ((m + self.bound - 1) / self.bound).max(1);
        let mut is_fresh = vec![false; n];
        for (j, &i) in fresh.iter().enumerate() {
            self.parked_streak[i] = (m - 1 - j) / gpe;
            self.admitted_streak[i] = 0;
            is_fresh[i] = true;
        }
        for i in 0..n {
            if next[i] {
                self.parked_streak[i] = 0;
                self.admitted_streak[i] += 1;
            } else if !is_fresh[i] {
                self.parked_streak[i] += 1;
                self.admitted_streak[i] = 0;
            }
        }
        self.admitted = next.clone();
        self.decided = true;
        next
    }

    /// Re-apply the previous decision for one epoch without re-deciding
    /// (warmup epochs hold the initial decision), ticking the streaks so
    /// held epochs still count against the starvation bound.
    pub fn hold(&mut self) -> Vec<bool> {
        for i in 0..self.admitted.len() {
            if self.admitted[i] {
                self.admitted_streak[i] += 1;
            } else {
                self.parked_streak[i] += 1;
            }
        }
        self.admitted.clone()
    }

    /// A parked tenant would exceed the starvation bound if parked for one
    /// more epoch. Warmup holds consult this so a tight bound (smaller
    /// than the warmup span) forces an early decision instead of silently
    /// overshooting the guarantee.
    pub fn overdue_pending(&self) -> bool {
        self.overdue_flags().into_iter().any(|o| o)
    }
}

/// Raise admitted tenants from *idle* cores toward their reservation
/// (capped at the even share), in priority order (weight descending, ties
/// to the lower index). The water-filler leaves a tenant whose model
/// predicts nothing feasible at the floor rung; without this top-up a
/// freshly (re-)admitted tenant would be left at scraps, learn nothing
/// feasible, and stay starved — the guarantee admitted tenants used to
/// get from the fairness floor, restored under the sub-floor ladder that
/// epoch admission packs against. Only idle cores are spent: no tenant's
/// water-filled grant is ever reduced.
pub fn reserve_top_up(
    rungs: &mut [usize],
    levels: &[usize],
    total: usize,
    admitted: &[bool],
    reservations: &[usize],
    even: usize,
    weights: &[f64],
) {
    let mut order: Vec<usize> = (0..rungs.len()).collect();
    order.sort_by(|&a, &b| {
        weights[b].partial_cmp(&weights[a]).unwrap().then(a.cmp(&b))
    });
    let mut used: usize = (0..rungs.len())
        .filter(|&i| admitted[i])
        .map(|i| levels[rungs[i]])
        .sum();
    for &i in &order {
        if !admitted[i] {
            continue;
        }
        let want = reservations[i].min(even);
        while rungs[i] + 1 < levels.len()
            && levels[rungs[i]] < want
            && levels[rungs[i] + 1] <= want
            && used - levels[rungs[i]] + levels[rungs[i] + 1] <= total
        {
            used = used - levels[rungs[i]] + levels[rungs[i] + 1];
            rungs[i] += 1;
        }
    }
}

/// The shared core ladder for a fleet of `apps` on `total` cores: rungs
/// from the fairness floor up to the boost cap, geometrically spaced,
/// always containing the even share exactly (so the static baseline sits
/// on a rung).
pub fn core_levels(
    total: usize,
    apps: usize,
    floor: usize,
    rungs: usize,
    boost: f64,
) -> Vec<usize> {
    let even = (total / apps.max(1)).max(1);
    let floor = floor.clamp(1, even);
    // detlint: allow(lossy-cast) — core-count cap: ceil of a small positive product, exact below 2^53
    let cap = ((even as f64 * boost).ceil() as usize)
        .min(total.saturating_sub((apps.saturating_sub(1)) * floor))
        .max(even);
    let mut levels = std::collections::BTreeSet::new();
    levels.insert(floor);
    levels.insert(even);
    levels.insert(cap);
    if rungs > 1 && cap > floor {
        let ratio = cap as f64 / floor as f64;
        for i in 0..rungs {
            let lvl = (floor as f64 * ratio.powf(i as f64 / (rungs - 1) as f64)).round()
                as usize;
            levels.insert(lvl.clamp(floor, cap));
        }
    }
    levels.into_iter().collect()
}

/// Marginal-utility water-filling over a shared rung ladder.
///
/// `curves[a][l]` is app `a`'s predicted feasible fidelity at rung `l`
/// (from its learned latency model). Every app starts at the floor rung;
/// the best affordable jump — the one with the highest fidelity gain per
/// core — is applied repeatedly until no strictly positive gain fits the
/// budget. Ties break deterministically toward the lower app index and
/// the lower target rung. A final top-up pass raises the lowest-allocated
/// apps back toward the even share while cores sit idle, so uninformative
/// curves degrade to the static baseline instead of starving the fleet.
///
/// Returns one rung index per app. Invariants (tested): allocated cores
/// never exceed `total`, and every app keeps at least the floor rung.
pub fn allocate(curves: &[Vec<f64>], levels: &[usize], total: usize) -> Vec<usize> {
    let uniform = vec![1.0; curves.len()];
    allocate_v2(curves, levels, total, &uniform, None, 0.0)
}

/// The v2 stateful water-filler: [`allocate`] plus per-app priority
/// weights and a hysteresis/switching-cost term.
///
/// Each app's curve is scaled by its `weights` entry before gains are
/// compared, so a paying tenant's fidelity point buys proportionally
/// more cores. `prev` is the rung vector the previous epoch installed;
/// with `hysteresis > 0` each app's *incumbent* rung gets a utility
/// bonus of `hysteresis`, which makes the greedy fill (a) route through
/// the incumbent on the way up and (b) refuse to move past (or stop
/// short of) it unless the weighted marginal-utility gain over the
/// incumbent exceeds the migration penalty. With uniform weights and
/// `hysteresis == 0` this reduces to the PR 2 stateless greedy
/// water-filler bit-for-bit (`1.0 * u + 0.0` is exact in IEEE 754).
///
/// **Implementation (PR 8):** the greedy fill runs as a priority-heap
/// water-fill — one live heap entry per app holding its best affordable
/// jump, refreshed lazily when feasibility shrinks — turning the
/// per-move full scan into O(n·rungs·log n) for a whole epoch, which is
/// what keeps a 100k-tenant reallocation epoch under the bench gate
/// (`allocate_v2/100k_tenants` in `ci/bench-baseline.json`). The heap
/// order reproduces the scan's tie-breaks exactly (gain desc, app asc,
/// rung asc), so results are bit-identical to the legacy scan on every
/// input with a strictly increasing ladder; other ladders take the
/// retained scan path. Equivalence is regression-tested against a
/// verbatim copy of the scan on random instances
/// (`heap_waterfill_matches_legacy_scan_*`) and mirrored in
/// `python/tests/test_heap_waterfill_mirror.py`.
pub fn allocate_v2(
    curves: &[Vec<f64>],
    levels: &[usize],
    total: usize,
    weights: &[f64],
    prev: Option<&[usize]>,
    hysteresis: f64,
) -> Vec<usize> {
    let napps = curves.len();
    assert!(napps > 0, "allocate needs at least one app");
    assert!(!levels.is_empty(), "allocate needs a rung ladder");
    assert_eq!(weights.len(), napps, "weight vector shape");
    assert!(hysteresis >= 0.0, "hysteresis must be non-negative");
    for c in curves {
        assert_eq!(c.len(), levels.len(), "curve shape mismatch");
    }
    if let Some(p) = prev {
        assert_eq!(p.len(), napps, "prev rung vector shape");
    }
    // weighted utility with the incumbent-rung hysteresis bonus
    let adj = |a: usize, l: usize| -> f64 {
        let mut u = weights[a] * curves[a][l];
        if hysteresis > 0.0 {
            if let Some(p) = prev {
                if p[a] == l {
                    u += hysteresis;
                }
            }
        }
        u
    };
    let mut lvl = vec![0usize; napps];
    let mut used = napps * levels[0];
    assert!(used <= total, "floor rung oversubscribes the cluster");

    // Every real ladder is strictly increasing (`core_levels` collects a
    // sorted set), which is what makes the heap water-fill exact: every
    // applied jump then strictly grows `used`, so feasibility only ever
    // shrinks. A pathological hand-built ladder that is not strictly
    // increasing falls back to the legacy O(moves·n·rungs) full-scan
    // loops, keeping historical behavior bit-for-bit on any input.
    let monotone = levels.windows(2).all(|w| w[0] < w[1]);

    // App `a`'s best affordable jump from its current rung: highest gain
    // per core, ties toward the lower target rung (ascending scan with
    // strict `>`, exactly the legacy inner loop).
    let best_jump = |a: usize, lvl: &[usize], used: usize| -> Option<Jump> {
        let mut best: Option<(f64, usize)> = None;
        for j in (lvl[a] + 1)..levels.len() {
            if used - levels[lvl[a]] + levels[j] > total {
                continue;
            }
            let du = adj(a, j) - adj(a, lvl[a]);
            if du <= 1e-12 {
                continue;
            }
            let g = du / (levels[j] - levels[lvl[a]]) as f64;
            if best.map_or(true, |(bg, _)| g > bg) {
                best = Some((g, j));
            }
        }
        best.map(|(gain, rung)| Jump { gain, app: a, rung })
    };

    if monotone {
        // Heap water-fill, O(n·rungs·log n): one live entry per app — its
        // best affordable jump as of the last time the app was touched.
        // `used` only grows, so a stored entry's candidate set can only
        // have shrunk: a popped entry that still fits is still its app's
        // best jump (a maximum over a superset, still present, is the
        // maximum of the subset, and no equal-gain lower rung can appear),
        // while every other app's stored gain upper-bounds its current
        // best — so the heap top that validates is exactly the jump the
        // full scan would have picked, tie-breaks included ([`Jump`]'s
        // order). A popped entry that no longer fits is recomputed at the
        // current `used` and re-pushed.
        let mut heap: BinaryHeap<Jump> =
            (0..napps).filter_map(|a| best_jump(a, &lvl, used)).collect();
        while let Some(e) = heap.pop() {
            let a = e.app;
            if used - levels[lvl[a]] + levels[e.rung] > total {
                if let Some(next) = best_jump(a, &lvl, used) {
                    heap.push(next);
                }
                continue;
            }
            used = used - levels[lvl[a]] + levels[e.rung];
            lvl[a] = e.rung;
            if let Some(next) = best_jump(a, &lvl, used) {
                heap.push(next);
            }
        }
    } else {
        loop {
            let mut best: Option<(f64, usize, usize)> = None; // (gain/core, app, rung)
            for a in 0..napps {
                for j in (lvl[a] + 1)..levels.len() {
                    if used - levels[lvl[a]] + levels[j] > total {
                        continue;
                    }
                    let du = adj(a, j) - adj(a, lvl[a]);
                    if du <= 1e-12 {
                        continue;
                    }
                    let g = du / (levels[j] - levels[lvl[a]]) as f64;
                    if best.map_or(true, |(bg, _, _)| g > bg) {
                        best = Some((g, a, j));
                    }
                }
            }
            match best {
                None => break,
                Some((_, a, j)) => {
                    used = used - levels[lvl[a]] + levels[j];
                    lvl[a] = j;
                }
            }
        }
    }

    // top-up: while cores sit idle, raise the lowest-allocated app back
    // toward the even share (uninformative curves degrade to ~static)
    let even = total / napps;
    if monotone {
        // Min-heap on (cores, app), matching the scan's strict-`<` pick
        // of the lowest-allocated app with ties toward the lower index.
        // Entries stay exact because an app's rung only changes when its
        // own entry is popped; and since `used` only grows, an entry that
        // fails the feasibility check on pop can never fit again, so the
        // app drops out for good — exactly when the scan stops picking it.
        let eligible = |a: usize, lvl: &[usize]| -> bool {
            let j = lvl[a] + 1;
            j < levels.len() && levels[j] <= even
        };
        let mut heap: BinaryHeap<Reverse<(usize, usize)>> = (0..napps)
            .filter(|&a| eligible(a, &lvl))
            .map(|a| Reverse((levels[lvl[a]], a)))
            .collect();
        while let Some(Reverse((_, a))) = heap.pop() {
            let j = lvl[a] + 1;
            if used - levels[lvl[a]] + levels[j] > total {
                continue;
            }
            used = used - levels[lvl[a]] + levels[j];
            lvl[a] = j;
            if eligible(a, &lvl) {
                heap.push(Reverse((levels[lvl[a]], a)));
            }
        }
    } else {
        loop {
            let mut cand: Option<(usize, usize, usize)> = None; // (cores, app, rung)
            for a in 0..napps {
                let j = lvl[a] + 1;
                if j >= levels.len() || levels[j] > even {
                    continue;
                }
                if used - levels[lvl[a]] + levels[j] > total {
                    continue;
                }
                if cand.map_or(true, |(c, _, _)| levels[lvl[a]] < c) {
                    cand = Some((levels[lvl[a]], a, j));
                }
            }
            match cand {
                None => break,
                Some((_, a, j)) => {
                    used = used - levels[lvl[a]] + levels[j];
                    lvl[a] = j;
                }
            }
        }
    }
    lvl
}

/// One epoch's allocation decision, recorded in the fleet report.
#[derive(Debug, Clone)]
pub struct AllocationFrame {
    pub epoch: usize,
    /// First frame the allocation governs.
    pub start_frame: usize,
    /// Ladder rung index per app.
    pub levels: Vec<usize>,
    /// Core quota per app (the rung budgets; 0 for parked apps).
    pub cores: Vec<usize>,
    /// Utility the scheduler predicted for each app at its rung (NaN-free;
    /// warmup epochs record zeros).
    pub predicted_utility: Vec<f64>,
    /// Apps parked by admission control this epoch (zero cores, frames
    /// dropped). Empty-of-true outside admission mode.
    pub parked: Vec<bool>,
    /// Cores moved relative to the previous epoch: Σ |cores − prev|.
    /// 0 at epoch 0.
    pub churn_cores: usize,
}

impl AllocationFrame {
    pub fn total_cores(&self) -> usize {
        self.cores.iter().sum()
    }

    /// Apps whose quota changed relative to the previous epoch's.
    pub fn moved_apps(&self, prev: &AllocationFrame) -> usize {
        self.cores
            .iter()
            .zip(&prev.cores)
            .filter(|(a, b)| a != b)
            .count()
    }

    /// Cores moved relative to `prev`: Σ |cores − prev.cores| — the
    /// value recorded in [`churn_cores`](Self::churn_cores). One
    /// definition shared by the fleet and live paths so the per-epoch
    /// frames and the aggregated totals can never drift apart.
    pub fn churn_vs(cores: &[usize], prev: &AllocationFrame) -> usize {
        cores.iter().zip(&prev.cores).map(|(&a, &b)| a.abs_diff(b)).sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .put("epoch", self.epoch)
            .put("start_frame", self.start_frame)
            .put(
                "levels",
                Json::Arr(self.levels.iter().map(|&l| Json::from(l)).collect()),
            )
            .put(
                "cores",
                Json::Arr(self.cores.iter().map(|&c| Json::from(c)).collect()),
            )
            .put("predicted_utility", Json::from_f64_slice(&self.predicted_utility))
            .put(
                "parked",
                Json::Arr(self.parked.iter().map(|&p| Json::from(p)).collect()),
            )
            .put("churn_cores", self.churn_cores)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_contains_floor_even_and_cap() {
        let levels = core_levels(120, 8, 7, 6, 3.0);
        assert_eq!(levels.first(), Some(&7));
        assert!(levels.contains(&15), "{levels:?}");
        assert_eq!(levels.last(), Some(&45));
        assert!(levels.windows(2).all(|w| w[0] < w[1]), "{levels:?}");
        // cap is bounded by what the floor leaves for everyone else
        let tight = core_levels(32, 4, 8, 5, 4.0);
        assert_eq!(tight, vec![8]); // floor == even == cap
    }

    #[test]
    fn floor_cores_defaults_to_half_even_share() {
        let cfg = SchedulerConfig::default();
        assert_eq!(cfg.floor_cores(120, 8), 7);
        assert_eq!(cfg.floor_cores(120, 4), 15);
        let explicit = SchedulerConfig { fairness_floor: 4, ..Default::default() };
        assert_eq!(explicit.floor_cores(120, 8), 4);
        // never above the even share
        assert_eq!(explicit.floor_cores(8, 4), 2);
    }

    #[test]
    fn allocate_respects_budget_and_floor() {
        let levels = vec![7, 10, 15, 21, 31, 45];
        // two greedy apps, two flat ones
        let steep = vec![0.0, 0.2, 0.4, 0.6, 0.8, 0.9];
        let flat = vec![0.5, 0.5, 0.5, 0.5, 0.5, 0.5];
        let curves = vec![steep.clone(), flat.clone(), steep, flat];
        let lvl = allocate(&curves, &levels, 60);
        let cores: Vec<usize> = lvl.iter().map(|&l| levels[l]).collect();
        assert!(cores.iter().sum::<usize>() <= 60, "{cores:?}");
        assert!(cores.iter().all(|&c| c >= 7), "{cores:?}");
        // the steep apps got the spare cores
        assert!(cores[0] > cores[1], "{cores:?}");
        assert!(cores[2] > cores[3], "{cores:?}");
    }

    #[test]
    fn allocate_flat_curves_degrade_to_even_share() {
        let levels = vec![7, 10, 15, 21, 31, 45];
        let curves = vec![vec![0.7; 6]; 8];
        let lvl = allocate(&curves, &levels, 120);
        let cores: Vec<usize> = lvl.iter().map(|&l| levels[l]).collect();
        // top-up parks everyone on the even share
        assert_eq!(cores, vec![15; 8], "{cores:?}");
    }

    #[test]
    fn allocate_handles_nonconcave_curves() {
        // utility jumps only at the top rung: the greedy pass must see the
        // multi-rung jump, not stall at the flat middle
        let levels = vec![4, 8, 16, 32];
        let late = vec![0.1, 0.1, 0.1, 0.9];
        let flat = vec![0.6, 0.6, 0.6, 0.6];
        let lvl = allocate(&[late.clone(), flat.clone(), flat], &levels, 44);
        assert_eq!(levels[lvl[0]], 32, "{lvl:?}");
    }

    #[test]
    fn allocate_tie_breaks_toward_lower_index() {
        let levels = vec![4, 8];
        let want = vec![0.1, 0.9];
        // only one app can be raised
        let lvl = allocate(&[want.clone(), want.clone(), want], &levels, 16);
        assert_eq!(lvl, vec![1, 0, 0]);
    }

    #[test]
    fn allocation_frame_json_roundtrips() {
        let f = AllocationFrame {
            epoch: 3,
            start_frame: 150,
            levels: vec![0, 2, 1],
            cores: vec![7, 15, 10],
            predicted_utility: vec![0.5, 0.25, 0.75],
            parked: vec![false, false, true],
            churn_cores: 13,
        };
        assert_eq!(f.total_cores(), 32);
        let j = Json::parse(&f.to_json().to_string()).unwrap();
        assert_eq!(j.req("epoch").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("cores").unwrap().as_f64_vec().unwrap(), vec![7.0, 15.0, 10.0]);
        assert_eq!(j.req("churn_cores").unwrap().as_usize().unwrap(), 13);
        assert!(j.req("parked").unwrap().as_arr().unwrap()[2].as_bool().unwrap());
        let prev = AllocationFrame { cores: vec![7, 10, 15], ..f.clone() };
        assert_eq!(f.moved_apps(&prev), 2);
    }

    #[test]
    fn allocate_v2_defaults_reproduce_v1() {
        // uniform weights, no incumbents, zero hysteresis == PR 2 greedy
        let levels = vec![7, 10, 15, 21, 31, 45];
        let mut rng = crate::util::Rng::new(11);
        for _ in 0..20 {
            let curves: Vec<Vec<f64>> = (0..6)
                .map(|_| {
                    let mut u: Vec<f64> = (0..levels.len()).map(|_| rng.f64()).collect();
                    u.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    u
                })
                .collect();
            let v1 = allocate(&curves, &levels, 90);
            let v2 = allocate_v2(&curves, &levels, 90, &[1.0; 6], None, 0.0);
            assert_eq!(v1, v2);
            // a zero-hysteresis incumbent changes nothing either
            let v2p = allocate_v2(&curves, &levels, 90, &[1.0; 6], Some(&v1), 0.0);
            assert_eq!(v1, v2p);
        }
    }

    /// The pre-PR 8 `allocate_v2` body, inlined **verbatim** (both full
    /// scans), so the heap water-fill is regression-tested against the
    /// exact code it replaced rather than against a re-derivation that
    /// could share a bug with it.
    fn legacy_scan_allocate_v2(
        curves: &[Vec<f64>],
        levels: &[usize],
        total: usize,
        weights: &[f64],
        prev: Option<&[usize]>,
        hysteresis: f64,
    ) -> Vec<usize> {
        let napps = curves.len();
        let adj = |a: usize, l: usize| -> f64 {
            let mut u = weights[a] * curves[a][l];
            if hysteresis > 0.0 {
                if let Some(p) = prev {
                    if p[a] == l {
                        u += hysteresis;
                    }
                }
            }
            u
        };
        let mut lvl = vec![0usize; napps];
        let mut used = napps * levels[0];
        assert!(used <= total, "floor rung oversubscribes the cluster");
        loop {
            let mut best: Option<(f64, usize, usize)> = None; // (gain/core, app, rung)
            for a in 0..napps {
                for j in (lvl[a] + 1)..levels.len() {
                    if used - levels[lvl[a]] + levels[j] > total {
                        continue;
                    }
                    let du = adj(a, j) - adj(a, lvl[a]);
                    if du <= 1e-12 {
                        continue;
                    }
                    let g = du / (levels[j] - levels[lvl[a]]) as f64;
                    if best.map_or(true, |(bg, _, _)| g > bg) {
                        best = Some((g, a, j));
                    }
                }
            }
            match best {
                None => break,
                Some((_, a, j)) => {
                    used = used - levels[lvl[a]] + levels[j];
                    lvl[a] = j;
                }
            }
        }
        let even = total / napps;
        loop {
            let mut cand: Option<(usize, usize, usize)> = None; // (cores, app, rung)
            for a in 0..napps {
                let j = lvl[a] + 1;
                if j >= levels.len() || levels[j] > even {
                    continue;
                }
                if used - levels[lvl[a]] + levels[j] > total {
                    continue;
                }
                if cand.map_or(true, |(c, _, _)| levels[lvl[a]] < c) {
                    cand = Some((levels[lvl[a]], a, j));
                }
            }
            match cand {
                None => break,
                Some((_, a, j)) => {
                    used = used - levels[lvl[a]] + levels[j];
                    lvl[a] = j;
                }
            }
        }
        lvl
    }

    #[test]
    fn heap_waterfill_matches_legacy_scan_random_instances() {
        // 300 random fleets spanning tight/loose budgets, weights,
        // hysteresis, flat curve segments (du <= 1e-12 filter), and
        // deliberate exact utility ties — the heap must reproduce the
        // scan's answer bit-for-bit, tie-breaks included.
        let mut rng = crate::util::Rng::new(0x8EA9);
        for case in 0..300 {
            let napps = 1 + rng.below(24);
            let nlevels = 2 + rng.below(7);
            let floor = 1 + rng.below(4);
            let mut levels = vec![floor];
            for _ in 1..nlevels {
                levels.push(levels.last().unwrap() + 1 + rng.below(9));
            }
            // budget from "floor only fits" up to "everything fits"
            let max = napps * levels[nlevels - 1];
            let total = napps * floor + rng.below(max - napps * floor + 1);
            let quantize = rng.bool_with(0.5); // force exact gain ties
            let curves: Vec<Vec<f64>> = (0..napps)
                .map(|_| {
                    let mut u: Vec<f64> = (0..nlevels)
                        .map(|_| {
                            if quantize {
                                (rng.f64() * 8.0).floor() / 8.0
                            } else {
                                rng.f64()
                            }
                        })
                        .collect();
                    u.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    if rng.bool_with(0.3) && nlevels > 2 {
                        u[nlevels - 1] = u[nlevels - 2]; // flat top: du == 0
                    }
                    u
                })
                .collect();
            let weights: Vec<f64> = (0..napps)
                .map(|_| if rng.bool_with(0.5) { 1.0 } else { 1.0 + rng.below(4) as f64 })
                .collect();
            let prev: Option<Vec<usize>> = if rng.bool_with(0.5) {
                Some((0..napps).map(|_| rng.below(nlevels)).collect())
            } else {
                None
            };
            let hysteresis = if rng.bool_with(0.5) { 0.0 } else { rng.f64() * 0.2 };
            let want = legacy_scan_allocate_v2(
                &curves,
                &levels,
                total,
                &weights,
                prev.as_deref(),
                hysteresis,
            );
            let got =
                allocate_v2(&curves, &levels, total, &weights, prev.as_deref(), hysteresis);
            assert_eq!(
                got, want,
                "case {case}: napps={napps} levels={levels:?} total={total} \
                 weights={weights:?} prev={prev:?} h={hysteresis}"
            );
        }
    }

    #[test]
    fn non_monotone_ladder_takes_scan_path_unchanged() {
        // a hand-built ladder that is not strictly increasing must keep
        // the historical scan behavior (the heap requires monotonicity)
        let levels = vec![4, 8, 6, 12];
        let curves = vec![vec![0.1, 0.5, 0.4, 0.9], vec![0.2, 0.3, 0.7, 0.8]];
        let want = legacy_scan_allocate_v2(&curves, &levels, 20, &[1.0; 2], None, 0.0);
        let got = allocate_v2(&curves, &levels, 20, &[1.0; 2], None, 0.0);
        assert_eq!(got, want);
    }

    #[test]
    fn hysteresis_pins_sub_penalty_wobble_but_follows_real_shifts() {
        let levels = vec![4, 8, 16];
        // two apps contending for one boost slot; app 0 clearly ahead
        let a = vec![0.10, 0.50, 0.70];
        let b = vec![0.10, 0.46, 0.64];
        let prev = allocate_v2(&[a.clone(), b.clone()], &levels, 24, &[1.0; 2], None, 0.0);
        assert_eq!(prev, vec![2, 1]); // app 0 holds the 16-core rung
        // noise swaps the two curves — v1 migrates, v2 (h=0.1) holds
        let a2 = b.clone();
        let b2 = a;
        let v1 = allocate_v2(&[a2.clone(), b2.clone()], &levels, 24, &[1.0; 2], None, 0.0);
        assert_eq!(v1, vec![1, 2], "greedy chases the wobble");
        let v2 = allocate_v2(&[a2.clone(), b2.clone()], &levels, 24, &[1.0; 2], Some(&prev), 0.1);
        assert_eq!(v2, prev, "hysteresis keeps the incumbent");
        // a real shift (gain above the penalty) still migrates
        let b3 = vec![0.10, 0.50, 0.95];
        let v2s =
            allocate_v2(&[a2.clone(), b3.clone()], &levels, 24, &[1.0; 2], Some(&prev), 0.1);
        assert_eq!(v2s, vec![1, 2], "gains above the penalty must move");
    }

    #[test]
    fn priority_weights_tilt_contested_cores() {
        let levels = vec![4, 8];
        let want = vec![0.1, 0.9];
        // same curves, but app 2 pays for a 3x tier: it wins the one slot
        // that uniform weights hand to app 0
        let curves = vec![want.clone(), want.clone(), want.clone()];
        let uniform = allocate_v2(&curves, &levels, 16, &[1.0; 3], None, 0.0);
        assert_eq!(uniform, vec![1, 0, 0]);
        let tiered = allocate_v2(&curves, &levels, 16, &[1.0, 1.0, 3.0], None, 0.0);
        assert_eq!(tiered, vec![0, 0, 1]);
    }

    #[test]
    fn admit_parks_lowest_priority_when_floor_oversubscribes() {
        // floor fits: everyone runs
        assert_eq!(admit(120, 15, &[1.0; 8]), vec![true; 8]);
        // 4 apps x 4-core floor on 10 cores: capacity 2; lowest priority
        // parks first, ties park the higher index
        let admitted = admit(10, 4, &[1.0, 1.0, 0.5, 2.0]);
        assert_eq!(admitted, vec![true, false, false, true]);
        // uniform priorities: highest indexes park
        assert_eq!(admit(10, 4, &[1.0; 4]), vec![true, true, false, false]);
        // floor larger than the pool: exactly one app survives
        assert_eq!(admit(8, 64, &[1.0, 2.0]), vec![false, true]);
    }

    #[test]
    fn weights_and_floor_helpers() {
        let cfg = SchedulerConfig {
            priorities: vec![2.0, 0.5],
            fairness_floor: 20,
            admission: true,
            ..Default::default()
        };
        assert_eq!(cfg.weights(4), vec![2.0, 0.5, 1.0, 1.0]);
        assert_eq!(cfg.priority_of(0), 2.0);
        assert_eq!(cfg.priority_of(9), 1.0);
        // requested floor is NOT clamped to the even share (admission
        // accounts against what was asked for), but floor_cores still is
        assert_eq!(cfg.requested_floor(120, 8), 20);
        assert_eq!(cfg.floor_cores(120, 8), 15);
        let default = SchedulerConfig::default();
        assert_eq!(default.requested_floor(120, 8), 7);
    }

    #[test]
    #[should_panic(expected = "priority weights must be finite")]
    fn non_positive_priorities_rejected() {
        let cfg = SchedulerConfig { priorities: vec![1.0, 0.0], ..Default::default() };
        cfg.weights(2);
    }

    #[test]
    fn tier_shift_swaps_weights_at_frame() {
        let cfg = SchedulerConfig {
            priorities: vec![2.0],
            tier_shift: Some((100, vec![1.0, 1.0, 5.0])),
            ..Default::default()
        };
        assert_eq!(cfg.weights_at(4, 0), vec![2.0, 1.0, 1.0, 1.0]);
        assert_eq!(cfg.weights_at(4, 99), vec![2.0, 1.0, 1.0, 1.0]);
        assert_eq!(cfg.weights_at(4, 100), vec![1.0, 1.0, 5.0, 1.0]);
        assert_eq!(cfg.weights_at(4, 500), vec![1.0, 1.0, 5.0, 1.0]);
        // no shift: weights_at is weights
        let plain = SchedulerConfig::default();
        assert_eq!(plain.weights_at(3, 1000), vec![1.0; 3]);
    }

    #[test]
    #[should_panic(expected = "priority weights must be finite")]
    fn tier_shift_weights_validated_too() {
        let cfg = SchedulerConfig {
            tier_shift: Some((0, vec![-1.0])),
            ..Default::default()
        };
        cfg.weights_at(2, 10);
    }

    #[test]
    fn demand_is_smallest_rung_at_curve_max() {
        let levels = vec![1, 5, 12, 20, 60];
        assert_eq!(demand_cores(&[0.0, 0.2, 0.8, 0.8, 0.8], &levels, 20), 12);
        assert_eq!(demand_cores(&[0.9, 0.9, 0.9, 0.9, 0.9], &levels, 20), 1);
        assert_eq!(demand_cores(&[0.0, 0.0, 0.0, 0.0, 0.9], &levels, 20), 60);
        // flat-zero curve: the starved-model fallback, not the floor rung
        assert_eq!(demand_cores(&[0.0; 5], &levels, 20), 20);
    }

    #[test]
    fn demand_confidence_masks_unobserved_rungs() {
        let levels = vec![1, 5, 12, 20, 60];
        let curve = vec![0.9, 0.9, 0.9, 0.9, 0.9];
        // optimistic: a flat curve demands the smallest rung ...
        assert_eq!(demand_cores_confident(&curve, &levels, 20, &[0; 5], 0), 1);
        // ... but with confidence required, an untried tiny rung cannot
        // carry the demand: the smallest *confident* max rung wins
        assert_eq!(
            demand_cores_confident(&curve, &levels, 20, &[0, 0, 3, 9, 0], 3),
            12
        );
        // no confident rung at all -> the calibration-share fallback
        assert_eq!(demand_cores_confident(&curve, &levels, 20, &[1; 5], 3), 20);
        // min_obs == 0 is bit-for-bit the legacy optimistic demand
        let noisy = vec![0.0, 0.2, 0.8, 0.8, 0.8];
        assert_eq!(
            demand_cores_confident(&noisy, &levels, 20, &[0; 5], 0),
            demand_cores(&noisy, &levels, 20)
        );
        // confident rungs below the masked max still lose to it
        assert_eq!(
            demand_cores_confident(&noisy, &levels, 20, &[9, 9, 0, 9, 9], 3),
            20,
            "the 0.8 max must come from a confident rung"
        );
    }

    #[test]
    fn epoch_admission_reproduces_v1_capacity_on_floor_reservations() {
        // 4 tenants x 4-core floor on 10 cores: greedy fit admits exactly
        // total/floor = 2, same ranking as the run-level admit()
        let mut adm = EpochAdmission::new(4, 3);
        let got = adm.decide(10, &[1.0, 1.0, 0.5, 2.0], &[4; 4]);
        assert_eq!(got, admit(10, 4, &[1.0, 1.0, 0.5, 2.0]));
        let mut uniform = EpochAdmission::new(4, 3);
        assert_eq!(uniform.decide(10, &[1.0; 4], &[4; 4]), admit(10, 4, &[1.0; 4]));
    }

    #[test]
    fn epoch_admission_readmits_when_demands_shrink() {
        // load pressure parks tenant 3; when demands drop the pool frees
        // up and the parked tenant is re-admitted before its deadline
        let mut adm = EpochAdmission::new(4, 8);
        let heavy = vec![2, 5, 2, 5];
        assert_eq!(adm.decide(10, &[1.0; 4], &heavy), vec![true, true, true, false]);
        let light = vec![2, 3, 2, 3];
        assert_eq!(adm.decide(10, &[1.0; 4], &light), vec![true; 4]);
    }

    #[test]
    fn epoch_admission_rotation_meets_starvation_bound() {
        // equal priorities, fixed floor reservations, random feasible
        // (apps, capacity, bound) tuples: no tenant is ever parked more
        // than `bound` consecutive epochs, and every tenant runs
        let mut rng = crate::util::Rng::new(0xA11);
        for _case in 0..40 {
            let n = 2 + rng.below(5);
            let floor = 2 + rng.below(4);
            let cap = 1 + rng.below(n);
            let total = floor * cap + rng.below(floor);
            let parked = n - cap;
            if parked == 0 {
                continue;
            }
            let kmin = (parked + cap - 1) / cap;
            let k = kmin + rng.below(4);
            let mut adm = EpochAdmission::new(n, k);
            let mut streak = vec![0usize; n];
            let mut ran = vec![false; n];
            for _e in 0..120 {
                let a = adm.decide(total, &vec![1.0; n], &vec![floor; n]);
                for i in 0..n {
                    if a[i] {
                        streak[i] = 0;
                        ran[i] = true;
                    } else {
                        streak[i] += 1;
                        assert!(
                            streak[i] <= k,
                            "tenant {i} parked {} > bound {k} (n {n} cap {cap})",
                            streak[i]
                        );
                    }
                }
            }
            assert!(ran.iter().all(|&r| r), "a tenant never ran: {ran:?}");
        }
    }

    #[test]
    fn admission_hysteresis_blocks_marginal_readmission_until_overdue() {
        // pool 10; tenants 0,1 reserve 4 each, tenant 2 gets parked.
        // With slack 3, the 2 idle cores left by tenant 2's shrunken
        // demand are not enough headroom to re-admit it ...
        let mut adm = EpochAdmission::new(3, 2).with_hysteresis(3);
        assert_eq!(adm.decide(10, &[1.0; 3], &[4, 4, 4]), vec![true, true, false]);
        let next = adm.decide(10, &[1.0; 3], &[4, 4, 2]);
        assert!(!next[2], "marginal slack must not flap the tenant back in: {next:?}");
        // ... but the starvation bound still wins: once overdue, the
        // tenant bypasses the slack gate entirely
        let next = adm.decide(10, &[1.0; 3], &[4, 4, 2]);
        assert!(next[2], "overdue tenant must bypass the hysteresis gate: {next:?}");
        // and ample slack re-admits immediately (no gate once it fits)
        let mut adm = EpochAdmission::new(3, 8).with_hysteresis(3);
        assert_eq!(adm.decide(10, &[1.0; 3], &[4, 4, 4]), vec![true, true, false]);
        let next = adm.decide(10, &[1.0; 3], &[2, 2, 2]);
        assert!(next[2], "slack 10-6=4 >= reservation 2 + gate 3: {next:?}");
    }

    #[test]
    fn admission_hysteresis_zero_is_bit_identical_to_legacy() {
        let mut rng = crate::util::Rng::new(0xB22);
        for _case in 0..20 {
            let n = 2 + rng.below(5);
            let total = 6 + rng.below(10);
            let mut legacy = EpochAdmission::new(n, 3);
            let mut gated = EpochAdmission::new(n, 3).with_hysteresis(0);
            for _e in 0..30 {
                let res: Vec<usize> = (0..n).map(|_| 1 + rng.below(5)).collect();
                assert_eq!(
                    legacy.decide(total, &vec![1.0; n], &res),
                    gated.decide(total, &vec![1.0; n], &res)
                );
            }
        }
    }

    #[test]
    fn epoch_admission_hold_ticks_streaks() {
        let mut adm = EpochAdmission::new(3, 2);
        assert_eq!(adm.decide(4, &[1.0; 3], &[2; 3]), vec![true, true, false]);
        assert!(!adm.overdue_pending());
        // a held (warmup) epoch counts against the bound: tenant 2 has now
        // waited 2 of its 2 epochs and must be admitted at the next decide
        assert_eq!(adm.hold(), vec![true, true, false]);
        assert!(adm.overdue_pending(), "the bound is due: a further hold would break it");
        let next = adm.decide(4, &[1.0; 3], &[2; 3]);
        assert!(next[2], "overdue tenant not re-admitted: {next:?}");
        assert!(!adm.overdue_pending());
    }

    #[test]
    fn tier_upgrade_preempts_a_seat_next_decide() {
        let mut adm = EpochAdmission::new(4, 8);
        assert_eq!(
            adm.decide(10, &[1.0; 4], &[4; 4]),
            vec![true, true, false, false]
        );
        let next = adm.decide(10, &[1.0, 1.0, 5.0, 1.0], &[4; 4]);
        assert!(next[2], "upgraded tenant must be admitted: {next:?}");
        assert_eq!(next.iter().filter(|&&a| a).count(), 2);
    }

    #[test]
    fn reserve_top_up_spends_idle_cores_only() {
        let levels = vec![1, 2, 5, 12, 20, 60];
        // three admitted tenants at the floor, one parked; 120-core pool
        let admitted = vec![true, true, true, false];
        let mut rungs = vec![0, 4, 0, 0]; // used = 1 + 20 + 1 = 22
        reserve_top_up(&mut rungs, &levels, 120, &admitted, &[20, 20, 12, 60], 20, &[1.0; 4]);
        assert_eq!(levels[rungs[0]], 20, "{rungs:?}");
        assert_eq!(levels[rungs[1]], 20, "incumbent grant untouched");
        assert_eq!(levels[rungs[2]], 12, "capped at its own reservation");
        assert_eq!(rungs[3], 0, "parked tenants get nothing");
        // a tight pool raises only as far as idle cores allow
        let mut tight = vec![0, 0];
        reserve_top_up(&mut tight, &levels, 7, &[true, true], &[20, 20], 20, &[1.0; 2]);
        assert_eq!(levels[tight[0]], 5, "{tight:?}");
        assert_eq!(levels[tight[1]], 2, "{tight:?}");
        assert!(levels[tight[0]] + levels[tight[1]] <= 7);
    }
}
