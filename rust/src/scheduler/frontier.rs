//! Progress-frontier protocol for the live path (ISSUE 6).
//!
//! Replaces the frame-count barrier epoch of `scheduler/live` with
//! per-tenant epoch clocks in the spirit of timely dataflow's progress
//! tracking (`timely/src/progress/{broadcast,subgraph}.rs`): each tenant
//! advances its own clock as *its* frames complete, the frontier is the
//! lower envelope of the participating clocks, and the allocator fires
//! whenever the envelope advances — acting on whatever observations each
//! tenant has banked, instead of waiting for the slowest stream.
//!
//! Three properties the live loop builds on:
//!
//! * **Per-tenant progress.** A tenant seals epoch `e` after delivering
//!   its `epoch_frames`-th frame of that epoch. Sealing is a pure
//!   function of the tenant's own frame count — no cross-tenant wait.
//! * **Straggler isolation.** Parked tenants leave the participation
//!   set, so they never hold the envelope back. On re-admission a
//!   tenant's clock *fast-forwards* to the current decision epoch (the
//!   skipped epochs are what `parked_epochs` counts): its next
//!   `epoch_frames` frames seal the *current* epoch, not a backlog of
//!   stale ones, so a re-admitted straggler delays only its own
//!   updates, never the fleet's.
//! * **Deterministic replay.** Decision `e` folds, per tenant, exactly
//!   the records from that tenant's sealed epochs `<= e` — even if more
//!   frames have already arrived (the surplus folds at later
//!   decisions). Per-tenant record arrival is frame-ordered and the
//!   per-tenant models are independent, so the decision sequence is a
//!   pure function of `(seed, apps, frames)` and live reports are
//!   byte-identical across thread counts.

/// Per-tenant epoch clocks plus the lower envelope over the admitted
/// participation set. The live loop owns one of these; the engine
/// threads never see it (they just stamp frames with their epoch).
#[derive(Debug, Clone)]
pub struct ProgressFrontier {
    /// Frames per epoch — the sealing cadence every clock shares.
    epoch_frames: usize,
    /// Next epoch each tenant will seal (clock `c` means epochs
    /// `0..c` are sealed for that tenant).
    clock: Vec<usize>,
    /// Frames banked toward each tenant's next seal.
    pending: Vec<usize>,
    /// Whether the tenant participates in the envelope (admitted and
    /// not yet finished). Parked and finished tenants are excluded.
    participating: Vec<bool>,
    /// Tenants that delivered every frame (their clock stops but they
    /// must not freeze the envelope).
    finished: Vec<bool>,
}

impl ProgressFrontier {
    /// A frontier over `n` tenants sealing every `epoch_frames` frames;
    /// `participating[i]` is the initial admission set.
    pub fn new(n: usize, epoch_frames: usize, participating: &[bool]) -> Self {
        assert!(epoch_frames >= 1, "epoch_frames must be >= 1");
        assert_eq!(participating.len(), n);
        ProgressFrontier {
            epoch_frames,
            clock: vec![0; n],
            pending: vec![0; n],
            participating: participating.to_vec(),
            finished: vec![false; n],
        }
    }

    /// Record one completed frame for tenant `i`; returns the epoch the
    /// tenant sealed by this frame, if any.
    pub fn on_frame(&mut self, i: usize) -> Option<usize> {
        self.pending[i] += 1;
        if self.pending[i] >= self.epoch_frames {
            self.pending[i] = 0;
            let sealed = self.clock[i];
            self.clock[i] += 1;
            Some(sealed)
        } else {
            None
        }
    }

    /// Tenant `i` delivered all its frames: it stops participating in
    /// the envelope (a finished stream must not freeze the frontier).
    pub fn finish(&mut self, i: usize) {
        self.finished[i] = true;
        self.participating[i] = false;
    }

    /// Park tenant `i`: it leaves the envelope and its partial epoch is
    /// discarded (those frames were already folded as observations; the
    /// epoch they belonged to will be re-sealed after fast-forward).
    pub fn park(&mut self, i: usize) {
        if !self.finished[i] {
            self.participating[i] = false;
            self.pending[i] = 0;
        }
    }

    /// Re-admit tenant `i`, fast-forwarding its clock to `epoch`: the
    /// epochs it sat out are *skipped*, not replayed, so its next
    /// `epoch_frames` frames seal the current epoch rather than a
    /// backlog — the structural fix for the straggler stall.
    pub fn resume_at(&mut self, i: usize, epoch: usize) {
        if !self.finished[i] {
            self.participating[i] = true;
            self.pending[i] = 0;
            if self.clock[i] < epoch {
                self.clock[i] = epoch;
            }
        }
    }

    /// The lower envelope: the smallest clock among participating
    /// tenants, i.e. the highest epoch `e` such that every participant
    /// has sealed all epochs `< e`. With no participants the envelope
    /// is unbounded (`None`) — every banked decision may fire.
    pub fn envelope(&self) -> Option<usize> {
        self.clock
            .iter()
            .zip(&self.participating)
            .filter(|&(_, &p)| p)
            .map(|(&c, _)| c)
            .min()
    }

    /// Has the envelope passed `epoch`, i.e. may decision `epoch` fire?
    /// (True when every participant sealed `epoch`, or nobody
    /// participates any more.)
    pub fn passed(&self, epoch: usize) -> bool {
        self.envelope().map(|e| e > epoch).unwrap_or(true)
    }

    /// Tenant `i`'s clock: the number of epochs it has sealed.
    pub fn sealed(&self, i: usize) -> usize {
        self.clock[i]
    }

    /// Whether tenant `i` currently participates in the envelope.
    pub fn participating(&self, i: usize) -> bool {
        self.participating[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clocks_advance_independently_and_envelope_is_min() {
        let mut f = ProgressFrontier::new(3, 2, &[true, true, true]);
        assert_eq!(f.envelope(), Some(0));
        // tenant 0 seals epoch 0 after 2 frames; others lag
        assert_eq!(f.on_frame(0), None);
        assert_eq!(f.on_frame(0), Some(0));
        assert_eq!(f.sealed(0), 1);
        assert_eq!(f.envelope(), Some(0), "envelope waits for the slowest");
        assert!(!f.passed(0));
        // the rest catch up; envelope advances, decision 0 may fire
        for i in 1..3 {
            f.on_frame(i);
            f.on_frame(i);
        }
        assert_eq!(f.envelope(), Some(1));
        assert!(f.passed(0));
        assert!(!f.passed(1));
    }

    #[test]
    fn parked_tenants_leave_the_envelope() {
        let mut f = ProgressFrontier::new(3, 1, &[true, true, false]);
        assert_eq!(f.envelope(), Some(0), "parked tenant 2 is excluded");
        f.on_frame(0);
        f.on_frame(1);
        assert_eq!(f.envelope(), Some(1), "tenant 2's zero clock never gates");
        f.park(1);
        f.on_frame(0);
        assert_eq!(f.envelope(), Some(2), "only tenant 0 participates now");
    }

    #[test]
    fn resume_fast_forwards_instead_of_replaying_backlog() {
        let mut f = ProgressFrontier::new(2, 2, &[true, false]);
        for _ in 0..10 {
            f.on_frame(0);
        }
        assert_eq!(f.sealed(0), 5);
        assert_eq!(f.envelope(), Some(5));
        // re-admit tenant 1 at the current decision epoch: its clock
        // jumps to 5 — it owes one epoch of frames, not five
        f.resume_at(1, 5);
        assert_eq!(f.sealed(1), 5);
        assert_eq!(f.envelope(), Some(5));
        f.on_frame(1);
        f.on_frame(1);
        assert_eq!(f.sealed(1), 6, "first post-resume seal is the current epoch");
    }

    #[test]
    fn resume_never_rewinds_a_clock() {
        let mut f = ProgressFrontier::new(1, 1, &[true]);
        for _ in 0..4 {
            f.on_frame(0);
        }
        f.park(0);
        f.resume_at(0, 2);
        assert_eq!(f.sealed(0), 4, "fast-forward is monotone");
    }

    #[test]
    fn park_discards_the_partial_epoch() {
        let mut f = ProgressFrontier::new(1, 3, &[true]);
        f.on_frame(0);
        f.on_frame(0);
        f.park(0);
        f.resume_at(0, 1);
        // the two banked frames were discarded with the park: a full
        // epoch_frames batch is owed after resume
        assert_eq!(f.on_frame(0), None);
        assert_eq!(f.on_frame(0), None);
        assert_eq!(f.on_frame(0), Some(1));
    }

    #[test]
    fn finished_tenants_do_not_freeze_the_frontier() {
        let mut f = ProgressFrontier::new(2, 1, &[true, true]);
        f.on_frame(0);
        f.finish(0);
        for _ in 0..3 {
            f.on_frame(1);
        }
        assert_eq!(f.envelope(), Some(3), "finished tenant 0 is excluded");
        f.finish(1);
        assert_eq!(f.envelope(), None);
        assert!(f.passed(100), "empty participation unblocks everything");
    }
}
