//! Live multi-app streaming under the fleet scheduler — the `schedule`
//! CLI subcommand.
//!
//! Where the simulated fleet ([`fleet`](crate::fleet)) replays ladder
//! traces, this path runs every co-tenant app through the *threaded
//! streaming engine* ([`engine`](crate::engine)) concurrently: each app's
//! stages execute as real OS threads with bounded connectors, a
//! per-app forwarder thread multiplexes the finished frames into one
//! channel, and the scheduler thread learns each app's latency model
//! online from the live records. Every reallocation epoch it rebuilds
//! the utility curves, water-fills the shared core pool, and *applies*
//! each app's new quota by retuning the running pipeline: the chosen
//! configuration's parallelism knobs are clamped to what the quota would
//! grant ([`effective_candidates`]) and installed via the stream's
//! detached [`KnobHandle`] — the engine never pauses.
//!
//! Unlike the trace-based fleet, live runs are **not** bit-deterministic:
//! frames already inside the bounded connectors when a retune lands run
//! under the old knobs, and how many there are depends on OS scheduling.
//! The structural invariants (quota sums, fairness floors, frame counts)
//! hold regardless and are what the tests assert.
//!
//! The v2 scheduler features carry over: per-app priority weights scale
//! the utility curves and the hysteresis term pins each stream to its
//! incumbent quota unless the predicted gain clears the migration
//! penalty — retuning a *running* pipeline is exactly where switching
//! cost is real (in-flight frames execute under stale knobs).
//!
//! **Parking a live tenant pauses its source.** A live stream cannot drop
//! frames retroactively the way the trace-replaying fleet does, so
//! run-level (v1) admission stays rejected up front; epoch-granular
//! admission ([`SchedulerConfig::admission_epoch`]) instead closes the
//! parked tenant's source gate ([`PauseHandle`]) — no new frame enters the
//! pipeline, frames already inside the bounded connectors drain normally,
//! and re-admission reopens the gate with the tenant's learned model
//! intact. Parked tenants finish their remaining frames after the
//! scheduled window (the final drain), so no frame is ever lost. Tier
//! shifts ([`SchedulerConfig::tier_shift`]) land at epoch boundaries like
//! the fleet's.
//!
//! Known limitation: epoch boundaries are frame-count barriers over the
//! admitted set, so after a mid-run re-admission the next boundary waits
//! for the returning tenant to stream through its parked backlog — under
//! real-time pacing that defers further scheduling decisions for roughly
//! as long as the tenant was parked (with `realtime_scale == 0`, the
//! default demo mode, catch-up is immediate). Per-tenant epoch clocks are
//! the recorded follow-on (see ROADMAP).

use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::Result;

use crate::apps::App;
use crate::engine::{spawn_stream, EngineConfig, FrameRecord, KnobHandle, PauseHandle};
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::scheduler::{
    self, demand_cores_confident, reserve_top_up, AllocationFrame, EpochAdmission,
    SchedulerConfig,
};
use crate::simulator::{Cluster, SharedCluster};
use crate::tuner::budgeted::effective_candidates;
use crate::util::Rng;
use crate::workloads::{AppProfile, WorkloadConfig};

/// Live run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub apps: usize,
    /// Frames each app streams.
    pub frames: usize,
    pub seed: u64,
    /// Random candidate configurations per app (plus the defaults).
    pub candidates: usize,
    /// Alternate Light/Heavy profiles instead of Balanced ones.
    pub heterogeneous: bool,
    /// Wall-clock seconds per simulated millisecond (0 = as fast as the
    /// channels allow; small values keep execution genuinely concurrent).
    pub realtime_scale: f64,
    /// The controller solves against `bound × headroom`.
    pub bound_headroom: f64,
    pub cluster: Cluster,
    pub scheduler: SchedulerConfig,
    pub workload: WorkloadConfig,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            apps: 4,
            frames: 300,
            seed: 7,
            candidates: 48,
            heterogeneous: true,
            realtime_scale: 0.0,
            bound_headroom: 0.90,
            cluster: Cluster::default(),
            scheduler: SchedulerConfig::default(),
            workload: WorkloadConfig::default(),
        }
    }
}

/// Per-app outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveAppSummary {
    pub index: usize,
    pub name: String,
    pub profile: &'static str,
    pub bound_ms: f64,
    pub frames: usize,
    pub avg_latency_ms: f64,
    pub avg_fidelity: f64,
    pub bound_met_frac: f64,
    /// Core quota at the final epoch.
    pub final_cores: usize,
    /// Scheduled epochs this tenant spent parked (source paused).
    pub parked_epochs: usize,
}

/// Outcome of a live scheduled run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    pub apps: Vec<LiveAppSummary>,
    pub allocations: Vec<AllocationFrame>,
    pub levels: Vec<usize>,
    pub total_cores: usize,
    pub fairness_floor: usize,
}

/// Stream `cfg.apps` generated pipelines through the threaded engine
/// concurrently, learning each latency model online and reallocating the
/// shared cores every `scheduler.epoch_frames` frames. With
/// `scheduler.admission_epoch`, an over-subscribed floor parks tenants by
/// pausing their sources; parking is re-decided every epoch from learned
/// demands with starvation-bounded rotation, and parked tenants drain
/// their remaining frames after the scheduled window.
pub fn run_live(cfg: &LiveConfig) -> Result<LiveReport> {
    assert!(cfg.apps > 0 && cfg.frames > 0);
    let total = cfg.cluster.total_cores();
    assert!(cfg.apps <= total, "one core per app minimum");
    let epoch_mode = cfg.scheduler.admission_epoch;
    let weights0 = cfg.scheduler.weights_at(cfg.apps, 0);
    let floor_req = cfg.scheduler.requested_floor(total, cfg.apps);
    // run-level parking cannot work on live streams (frames cannot be
    // dropped retroactively): an over-subscribed floor is rejected unless
    // epoch-granular admission is on, which parks by pausing sources
    anyhow::ensure!(
        epoch_mode || floor_req * cfg.apps <= total,
        "fairness floor x apps exceeds the {total}-core pool; whole-run \
         admission parking is fleet-only (a live stream cannot drop frames) \
         — lower --floor, or pass --admission-epoch to park live tenants by \
         pausing their sources"
    );
    let mut adm_state =
        EpochAdmission::new(cfg.apps, cfg.scheduler.starvation_bound_or_default());
    let mut admitted: Vec<bool> = if epoch_mode {
        adm_state.decide(
            total,
            &weights0,
            &vec![floor_req.clamp(1, total.max(1)); cfg.apps],
        )
    } else {
        vec![true; cfg.apps]
    };
    let capacity0 = admitted.iter().filter(|&&a| a).count();
    let even = (total / capacity0).max(1);
    let floor = if epoch_mode { 1 } else { cfg.scheduler.floor_cores(total, cfg.apps) };
    let levels = scheduler::core_levels(
        total,
        capacity0,
        floor,
        cfg.scheduler.ladder_rungs,
        cfg.scheduler.max_boost,
    );
    let even_rung = levels
        .iter()
        .position(|&l| l == even)
        .expect("core_levels always contains the even share");
    let epoch_frames = cfg.scheduler.epoch_frames.max(1);

    // ---- spawn every app through the engine + one forwarder each -------
    let (rec_tx, rec_rx) = channel::<(usize, FrameRecord)>();
    let mut apps: Vec<Arc<App>> = Vec::with_capacity(cfg.apps);
    let mut knob_handles: Vec<KnobHandle> = Vec::with_capacity(cfg.apps);
    let mut pause_handles: Vec<PauseHandle> = Vec::with_capacity(cfg.apps);
    let mut profiles: Vec<AppProfile> = Vec::with_capacity(cfg.apps);
    for i in 0..cfg.apps {
        let profile = AppProfile::for_fleet_member(cfg.heterogeneous, i, cfg.workload.profile);
        let mut wcfg = cfg.workload.clone();
        wcfg.profile = profile;
        let slice = Cluster {
            servers: 1,
            cores_per_server: even,
            comm_ms_per_frame: cfg.cluster.comm_ms_per_frame,
        };
        let app = Arc::new(crate::workloads::generate_on(
            cfg.seed.wrapping_add(i as u64),
            &wcfg,
            &slice,
        ));
        let handle = spawn_stream(
            Arc::clone(&app),
            app.spec.defaults(),
            EngineConfig {
                frames: cfg.frames,
                realtime_scale: cfg.realtime_scale,
                queue_capacity: 8,
                seed: cfg.seed.wrapping_add(0x11CE ^ i as u64),
                // parked tenants spawn with the source gate closed: not a
                // single frame enters the pipe until re-admission
                start_paused: !admitted[i],
            },
        );
        knob_handles.push(handle.knob_handle());
        pause_handles.push(handle.pause_handle());
        let tx = rec_tx.clone();
        std::thread::Builder::new()
            .name(format!("forward-{}", app.spec.name))
            .spawn(move || {
                while let Ok(rec) = handle.records.recv() {
                    if tx.send((i, rec)).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn forwarder thread");
        apps.push(app);
        profiles.push(profile);
    }
    drop(rec_tx);

    // ---- per-app scheduler state: model, candidate grid, rewards -------
    let mut backends: Vec<NativeBackend> =
        apps.iter().map(|a| NativeBackend::structured(&a.spec)).collect();
    // effective (budget-clamped) candidates per app per rung
    let mut cand_at: Vec<Vec<Vec<Vec<f64>>>> = Vec::with_capacity(cfg.apps);
    let mut rewards: Vec<Vec<f64>> = Vec::with_capacity(cfg.apps);
    for (i, app) in apps.iter().enumerate() {
        let mut rng = Rng::new(cfg.seed.wrapping_add(0xCAFE).wrapping_add(i as u64));
        let mut grid: Vec<Vec<f64>> = (0..cfg.candidates)
            .map(|_| {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                app.spec.denormalize(&u)
            })
            .collect();
        grid.push(app.spec.defaults());
        let content = app.model.content(0);
        rewards.push(grid.iter().map(|ks| app.model.fidelity(ks, &content)).collect());
        cand_at.push(effective_candidates(app, &grid, &levels));
    }

    let bounds: Vec<f64> = apps.iter().map(|a| a.spec.latency_bounds_ms[0]).collect();
    let mut shared = SharedCluster::parked_even(cfg.cluster.clone(), &admitted);
    let mut rungs = vec![even_rung; cfg.apps];
    let mut parked_epochs = vec![0usize; cfg.apps];
    for (i, &a) in admitted.iter().enumerate() {
        if !a {
            parked_epochs[i] += 1;
        }
    }
    let mut allocations: Vec<AllocationFrame> = vec![AllocationFrame {
        epoch: 0,
        start_frame: 0,
        levels: rungs.clone(),
        cores: shared.quotas().to_vec(),
        predicted_utility: vec![0.0; cfg.apps],
        parked: admitted.iter().map(|&a| !a).collect(),
        churn_cores: 0,
    }];

    // ---- consume live records, learn, reallocate at epoch boundaries ---
    let mut frames_seen = vec![0usize; cfg.apps];
    let mut lat_sum = vec![0.0f64; cfg.apps];
    let mut fid_sum = vec![0.0f64; cfg.apps];
    let mut met = vec![0usize; cfg.apps];
    // rung-residency frame counts: the live path's demand-confidence
    // evidence (the model is learned from live records, so "observations
    // at a rung" = frames streamed while holding that rung)
    let mut rung_frames: Vec<Vec<u64>> = vec![vec![0; levels.len()]; cfg.apps];
    let mut last_seen = vec![0usize; cfg.apps];
    let mut boundary = epoch_frames;
    let mut draining = false;
    while let Ok((i, rec)) = rec_rx.recv() {
        let u = apps[i].spec.normalize(&rec.knobs);
        let (y, off) = backends[i].group_map().targets(&rec.stage_ms, rec.end_to_end_ms);
        backends[i].update(&u, &y);
        backends[i].observe_offset(off);
        frames_seen[i] += 1;
        lat_sum[i] += rec.end_to_end_ms;
        fid_sum[i] += rec.fidelity;
        if rec.end_to_end_ms <= bounds[i] {
            met[i] += 1;
        }

        // an epoch completes when every *admitted* app has streamed past
        // the boundary (parked sources are gated and cannot advance)
        let all_past = (0..cfg.apps)
            .filter(|&a| admitted[a])
            .all(|a| frames_seen[a] >= boundary.min(cfg.frames));
        if all_past && boundary < cfg.frames {
            // one batched prediction per (app, rung): the curve point and
            // the best action it came from are recorded together so the
            // retune below never re-predicts the grid
            let mut curves: Vec<Vec<f64>> = Vec::with_capacity(cfg.apps);
            let mut best_at: Vec<Vec<usize>> = Vec::with_capacity(cfg.apps);
            for a in 0..cfg.apps {
                let target = bounds[a] * cfg.bound_headroom;
                let mut curve = Vec::with_capacity(levels.len());
                let mut bests = Vec::with_capacity(levels.len());
                for l in 0..levels.len() {
                    let costs = backends[a].predict(&cand_at[a][l]);
                    let best =
                        crate::runtime::constrained_argmax(&costs, &rewards[a], target);
                    curve.push(if costs[best] <= target { rewards[a][best] } else { 0.0 });
                    bests.push(best);
                }
                curves.push(curve);
                best_at.push(bests);
            }
            let epoch_idx = allocations.len();
            let w = cfg.scheduler.weights_at(cfg.apps, boundary);
            // charge the closing epoch's frames to the rung each stream
            // held (rungs[] is still the closing epoch's assignment here)
            for a in 0..cfg.apps {
                rung_frames[a][rungs[a]] += (frames_seen[a] - last_seen[a]) as u64;
                last_seen[a] = frames_seen[a];
            }
            let reservations: Vec<usize> = (0..cfg.apps)
                .map(|a| {
                    if frames_seen[a] > 0 {
                        demand_cores_confident(
                            &curves[a],
                            &levels,
                            even,
                            &rung_frames[a],
                            cfg.scheduler.demand_confidence,
                        )
                        .clamp(1, even)
                    } else {
                        floor_req.clamp(1, even)
                    }
                })
                .collect();
            let review_due = epoch_idx > cfg.scheduler.warmup_epochs
                || adm_state.overdue_pending();
            if epoch_mode && !draining && review_due {
                let next = adm_state.decide(total, &w, &reservations);
                for a in 0..cfg.apps {
                    if next[a] && !admitted[a] {
                        // re-admitted: reopen the source gate (the warm
                        // model learned so far is still in `backends`)
                        pause_handles[a].resume();
                    } else if !next[a] && admitted[a] {
                        pause_handles[a].pause();
                    }
                }
                admitted = next;
            } else if epoch_mode && !draining {
                admitted = adm_state.hold();
            }
            for (a, &adm) in admitted.iter().enumerate() {
                if !adm {
                    parked_epochs[a] += 1;
                }
            }
            let active: Vec<usize> = (0..cfg.apps).filter(|&a| admitted[a]).collect();
            let sub_curves: Vec<Vec<f64>> =
                active.iter().map(|&a| curves[a].clone()).collect();
            let sub_w: Vec<f64> = active.iter().map(|&a| w[a]).collect();
            let sub_prev: Vec<usize> = active.iter().map(|&a| rungs[a]).collect();
            let sub = scheduler::allocate_v2(
                &sub_curves,
                &levels,
                total,
                &sub_w,
                Some(&sub_prev),
                cfg.scheduler.hysteresis,
            );
            for (k, &a) in active.iter().enumerate() {
                rungs[a] = sub[k];
            }
            if epoch_mode {
                reserve_top_up(
                    &mut rungs,
                    &levels,
                    total,
                    &admitted,
                    &reservations,
                    even,
                    &w,
                );
            }
            let cores: Vec<usize> = (0..cfg.apps)
                .map(|a| if admitted[a] { levels[rungs[a]] } else { 0 })
                .collect();
            let parked: Vec<bool> = admitted.iter().map(|&a| !a).collect();
            shared.set_quotas_parked(&cores, &parked);
            // retune every running pipeline to the best predicted-feasible
            // config at its new quota, parallelism clamped to the grant
            for &a in &active {
                let pick = best_at[a][rungs[a]];
                let ks = apps[a].spec.denormalize(&cand_at[a][rungs[a]][pick]);
                knob_handles[a].set(ks);
            }
            let churn_cores = allocations
                .last()
                .map(|prev| AllocationFrame::churn_vs(shared.quotas(), prev))
                .unwrap_or(0);
            allocations.push(AllocationFrame {
                epoch: epoch_idx,
                start_frame: boundary,
                levels: rungs.clone(),
                // read back from the shared cluster: the bookkeeper that
                // enforced the budget is the one the report quotes
                cores: shared.quotas().to_vec(),
                predicted_utility: (0..cfg.apps)
                    .map(|a| if admitted[a] { curves[a][rungs[a]] } else { 0.0 })
                    .collect(),
                parked,
                churn_cores,
            });
            boundary += epoch_frames;
        }

        // final drain: once every admitted tenant has delivered all its
        // frames, reopen the parked tenants' gates so they finish too (a
        // live stream never loses frames to parking — they are deferred)
        if !draining
            && admitted.iter().any(|&a| !a)
            && (0..cfg.apps).filter(|&a| admitted[a]).all(|a| frames_seen[a] >= cfg.frames)
        {
            draining = true;
            for a in 0..cfg.apps {
                if !admitted[a] {
                    pause_handles[a].resume();
                    admitted[a] = true;
                }
            }
        }
    }

    // the closing quota is what the last epoch actually installed (a
    // tenant parked at the final decide closes at zero cores, not at its
    // stale pre-park rung)
    let final_cores = allocations.last().expect("epoch 0 recorded").cores.clone();
    let summaries: Vec<LiveAppSummary> = (0..cfg.apps)
        .map(|i| {
            let n = frames_seen[i].max(1) as f64;
            LiveAppSummary {
                index: i,
                name: apps[i].spec.name.clone(),
                profile: profiles[i].name(),
                bound_ms: bounds[i],
                frames: frames_seen[i],
                avg_latency_ms: lat_sum[i] / n,
                avg_fidelity: fid_sum[i] / n,
                bound_met_frac: met[i] as f64 / n,
                final_cores: final_cores[i],
                parked_epochs: parked_epochs[i],
            }
        })
        .collect();
    Ok(LiveReport {
        apps: summaries,
        allocations,
        levels,
        total_cores: total,
        fairness_floor: floor,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_fleet_streams_and_reallocates() {
        let cfg = LiveConfig {
            apps: 3,
            frames: 90,
            seed: 5,
            candidates: 12,
            heterogeneous: true,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig { epoch_frames: 30, ..Default::default() },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 90, "app {} lost frames", a.index);
            assert!(a.avg_latency_ms > 0.0);
            assert!((0.0..=1.0).contains(&a.avg_fidelity));
            assert!(a.final_cores >= report.fairness_floor);
        }
        assert!(!report.allocations.is_empty());
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            assert!(alloc.cores.iter().all(|&c| c >= report.fairness_floor));
        }
        // profiles alternate
        assert_eq!(report.apps[0].profile, "light");
        assert_eq!(report.apps[1].profile, "heavy");
    }

    #[test]
    fn live_v2_priorities_and_hysteresis_keep_invariants() {
        let cfg = LiveConfig {
            apps: 3,
            frames: 60,
            seed: 11,
            candidates: 10,
            heterogeneous: true,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig {
                epoch_frames: 20,
                hysteresis: 0.05,
                priorities: vec![3.0, 1.0],
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 60, "app {} lost frames", a.index);
        }
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            assert!(alloc.cores.iter().all(|&c| c >= report.fairness_floor));
            assert!(alloc.parked.iter().all(|&p| !p), "live never parks");
        }
    }

    #[test]
    fn live_rejects_infeasible_floor_without_epoch_admission() {
        // a floor the pool cannot honor errors out instead of being
        // silently clamped (whole-run parking is fleet-only; the error
        // names the epoch-admission escape hatch)
        let cfg = LiveConfig {
            scheduler: SchedulerConfig { fairness_floor: 40, ..Default::default() },
            ..Default::default()
        };
        let err = run_live(&cfg).unwrap_err().to_string();
        assert!(err.contains("fleet-only"), "{err}");
        assert!(err.contains("--admission-epoch"), "{err}");
    }

    #[test]
    fn live_epoch_admission_parks_by_pausing_and_loses_no_frames() {
        // 3 tenants demanding a 5-core floor on a 12-core pool: one is
        // parked (source paused) per epoch; every tenant still delivers
        // all its frames (parked tenants drain after the window)
        let cfg = LiveConfig {
            apps: 3,
            frames: 120,
            seed: 9,
            candidates: 10,
            heterogeneous: true,
            realtime_scale: 0.0,
            cluster: Cluster { servers: 1, cores_per_server: 12, comm_ms_per_frame: 0.0 },
            scheduler: SchedulerConfig {
                epoch_frames: 30,
                fairness_floor: 5,
                admission_epoch: true,
                starvation_bound: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 120, "app {} lost frames to parking", a.index);
            assert!(a.avg_latency_ms > 0.0);
        }
        // the initial decision parks exactly one tenant (floor 5 x 3 > 12)
        let first = &report.allocations[0];
        assert_eq!(first.parked.iter().filter(|&&p| p).count(), 1, "{first:?}");
        assert!(
            report.apps.iter().any(|a| a.parked_epochs > 0),
            "nobody was ever parked"
        );
        // budget safety at every epoch; parked tenants hold zero cores
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            for (c, &p) in alloc.cores.iter().zip(&alloc.parked) {
                if p {
                    assert_eq!(*c, 0);
                } else {
                    assert!(*c >= 1);
                }
            }
        }
    }

    #[test]
    fn live_tier_shift_changes_weights_mid_run() {
        // structural: a scripted tier shift mid-run keeps every invariant
        // (frame counts, budget) while the scheduler consumes the new
        // weights at epoch boundaries
        let cfg = LiveConfig {
            apps: 3,
            frames: 90,
            seed: 4,
            candidates: 10,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig {
                epoch_frames: 30,
                tier_shift: Some((45, vec![1.0, 1.0, 4.0])),
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        for a in &report.apps {
            assert_eq!(a.frames, 90, "app {} lost frames", a.index);
            assert_eq!(a.parked_epochs, 0, "no admission: nobody parks");
        }
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
        }
    }
}
