//! Live multi-app streaming under the fleet scheduler — the `schedule`
//! CLI subcommand.
//!
//! Where the simulated fleet ([`fleet`](crate::fleet)) replays ladder
//! traces, this path runs every co-tenant app through the *threaded
//! streaming engine* ([`engine`](crate::engine)) concurrently: each app's
//! stages execute as real OS threads with bounded connectors, a
//! per-app forwarder thread multiplexes the finished frames into one
//! channel, and the scheduler thread learns each app's latency model
//! online from the live records. Every reallocation epoch it rebuilds
//! the utility curves, water-fills the shared core pool, and *applies*
//! each app's new quota by retuning the running pipeline: the chosen
//! configuration's parallelism knobs are clamped to what the quota would
//! grant ([`effective_candidates`]) and installed for the frames the
//! decision governs — the engine never pauses.
//!
//! # The progress-frontier protocol (default)
//!
//! Epochs are **per-tenant clocks**, not a global barrier: a tenant
//! seals epoch `e` after delivering its `epoch_frames`-th frame of that
//! epoch, and the allocator fires decision `e` as soon as the
//! [`ProgressFrontier`]'s lower envelope passes `e` — acting on the
//! observations each tenant banked, never waiting for a re-admitted
//! straggler to replay a parked backlog (on re-admission a tenant's
//! clock *fast-forwards* to the current decision epoch, so it owes one
//! epoch of frames, not the epochs it sat out). A tenant's new
//! quota/knob decision applies as *its own* frontier passes the epoch:
//! the engine's frame-indexed knob schedule pins decision `e`'s knobs to
//! the tenant's frames `e*epoch_frames..(e+1)*epoch_frames`, and the
//! source blocks at the first *undecided* frame — one epoch of lookahead
//! beyond the envelope, timely-dataflow style.
//!
//! That bounded lookahead is what buys **byte-identical replay**: which
//! knobs a frame ran under is a pure function of the decision sequence
//! (never of retune/emission races), each stage's noise stream is drawn
//! in frame order, and every decision folds records in (tenant, epoch,
//! seq) order up to a deterministic per-tenant prefix — surplus frames
//! wait in a per-tenant buffer for the decision that owns them. Live
//! reports are therefore byte-identical across thread counts and
//! real-time pacing, the same determinism bar the trace-based fleet
//! meets. ([`FrameRecord::epoch`] stamps are advisory; the fold trusts
//! only its own counts.)
//!
//! **Parking a live tenant freezes its schedule.** Run-level (v1)
//! admission stays rejected up front (a live stream cannot drop frames
//! retroactively); epoch-granular admission
//! ([`SchedulerConfig::admission_epoch`]) parks a tenant by *not
//! extending* its knob schedule — the source blocks at the frozen
//! horizon with every emitted frame already folded, so parking is exact
//! and deterministic, and the parked tenant leaves the frontier's
//! participation set (it cannot stall anyone else's decisions). After
//! the final decision every remaining frame is scheduled under the last
//! decided knobs, so parked tenants drain their deferred tails and no
//! frame is ever lost. Tier shifts ([`SchedulerConfig::tier_shift`])
//! land at epoch boundaries like the fleet's.
//!
//! The v2 scheduler features carry over: per-app priority weights scale
//! the utility curves and the hysteresis term pins each stream to its
//! incumbent quota unless the predicted gain clears the migration
//! penalty. [`SchedulerConfig::admission_hysteresis`] additionally keeps
//! a parked tenant out until the pool has real slack, so a load blip
//! cannot thrash park/resume cycles.
//!
//! The pre-frontier **barrier protocol** (a frame-count barrier over the
//! admitted set, eager folding, wall-clock knob latching) is retained
//! behind [`LiveConfig::barrier`] as the A/B baseline for the straggler
//! regression tests; it keeps its historical caveat that reports are not
//! bit-deterministic and that a re-admitted straggler's backlog stalls
//! every tenant's next decision.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::apps::App;
use crate::engine::{
    spawn_stream, EngineConfig, FrameRecord, KnobHandle, PauseHandle, ScheduleHandle,
};
use crate::obs::{self, EpochLatencies, Event, EventKind, EventSink, TraceCollector};
use crate::runtime::native::NativeBackend;
use crate::runtime::Backend;
use crate::scheduler::frontier::ProgressFrontier;
use crate::scheduler::{
    self, demand_cores_confident, reserve_top_up, AllocationFrame, EpochAdmission,
    SchedulerConfig,
};
use crate::simulator::{Cluster, SharedCluster};
use crate::tuner::budgeted::effective_candidates;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{AppProfile, WorkloadConfig};

/// Live run configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub apps: usize,
    /// Frames each app streams.
    pub frames: usize,
    pub seed: u64,
    /// Random candidate configurations per app (plus the defaults).
    pub candidates: usize,
    /// Alternate Light/Heavy profiles instead of Balanced ones.
    pub heterogeneous: bool,
    /// Wall-clock seconds per simulated millisecond (0 = as fast as the
    /// channels allow; small values keep execution genuinely concurrent).
    pub realtime_scale: f64,
    /// The controller solves against `bound × headroom`.
    pub bound_headroom: f64,
    /// Inject a straggler: `(tenant, delay_ms)` adds that much raw
    /// wall-clock delay per source frame (independent of
    /// `realtime_scale`) — the regression hook for the frontier's
    /// straggler-isolation tests and the CI `live-smoke` job.
    pub straggler: Option<(usize, f64)>,
    /// Run the legacy frame-count barrier protocol instead of the
    /// progress frontier (A/B baseline; see the module docs).
    pub barrier: bool,
    pub cluster: Cluster,
    pub scheduler: SchedulerConfig,
    pub workload: WorkloadConfig,
    /// Capture the full event trace into [`LiveReport::timeline`]
    /// (`--trace-out`). Off, only the always-on histograms/counters run.
    pub trace_events: bool,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            apps: 4,
            frames: 300,
            seed: 7,
            candidates: 48,
            heterogeneous: true,
            realtime_scale: 0.0,
            bound_headroom: 0.90,
            straggler: None,
            barrier: false,
            cluster: Cluster::default(),
            scheduler: SchedulerConfig::default(),
            workload: WorkloadConfig::default(),
            trace_events: false,
        }
    }
}

/// Per-app outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveAppSummary {
    pub index: usize,
    pub name: String,
    pub profile: &'static str,
    pub bound_ms: f64,
    pub frames: usize,
    pub avg_latency_ms: f64,
    pub avg_fidelity: f64,
    pub bound_met_frac: f64,
    /// Core quota at the final epoch.
    pub final_cores: usize,
    /// Scheduled epochs this tenant spent parked (source frozen).
    pub parked_epochs: usize,
    /// Epochs this tenant completed *at decision cadence*: reallocation
    /// decisions that consumed a full fresh `epoch_frames` batch of its
    /// frames. Under the frontier every admitted tenant completes one
    /// epoch per decision; under the barrier a stalled boundary gulps a
    /// fast tenant's banked frames in bulk and this count collapses —
    /// the divergence the straggler regression test measures.
    pub completed_epochs: usize,
    /// Streaming end-to-end latency histograms, bucketed per epoch
    /// (always on; independent of [`LiveConfig::trace_events`]).
    pub latency: EpochLatencies,
}

impl LiveAppSummary {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .put("index", self.index)
            .put("name", self.name.as_str())
            .put("profile", self.profile)
            .put("bound_ms", self.bound_ms)
            .put("frames", self.frames)
            .put("avg_latency_ms", self.avg_latency_ms)
            .put("avg_fidelity", self.avg_fidelity)
            .put("bound_met_frac", self.bound_met_frac)
            .put("final_cores", self.final_cores)
            .put("parked_epochs", self.parked_epochs)
            .put("completed_epochs", self.completed_epochs)
            .put("latency_ms", self.latency.total().summary_json())
            .put("epoch_latency_ms", self.latency.to_json())
    }
}

/// Outcome of a live scheduled run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// `"frontier"` or `"barrier"` (see [`LiveConfig::barrier`]).
    pub protocol: &'static str,
    pub apps: Vec<LiveAppSummary>,
    pub allocations: Vec<AllocationFrame>,
    pub levels: Vec<usize>,
    pub total_cores: usize,
    pub fairness_floor: usize,
    /// Full event timeline, populated only under
    /// [`LiveConfig::trace_events`]. Deliberately *not* serialized into
    /// [`to_json`](Self::to_json): the report stays byte-comparable and
    /// the timeline is saved separately (`--trace-out`).
    pub timeline: Option<obs::Timeline>,
}

impl LiveReport {
    pub fn to_json(&self) -> Json {
        let apps: Vec<Json> = self.apps.iter().map(|a| a.to_json()).collect();
        let allocs: Vec<Json> = self.allocations.iter().map(|a| a.to_json()).collect();
        Json::obj()
            .put("protocol", self.protocol)
            .put("total_cores", self.total_cores)
            .put("fairness_floor", self.fairness_floor)
            .put(
                "levels",
                Json::Arr(self.levels.iter().map(|&l| Json::from(l)).collect()),
            )
            .put("apps", Json::Arr(apps))
            .put("allocations", Json::Arr(allocs))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing live report {}", path.display()))?;
        Ok(())
    }
}

/// All mutable state of a live run. Both protocols share
/// [`fire_decision`](LiveRun::fire_decision); they differ in *when* it
/// fires and *how* records reach the learners (deterministic frontier
/// folds vs. the barrier's eager arrival-order folds).
struct LiveRun<'a> {
    cfg: &'a LiveConfig,
    epoch_mode: bool,
    epoch_frames: usize,
    total: usize,
    even: usize,
    floor_req: usize,
    levels: Vec<usize>,
    apps: Vec<Arc<App>>,
    knob_handles: Vec<KnobHandle>,
    pause_handles: Vec<PauseHandle>,
    sched_handles: Vec<Option<ScheduleHandle>>,
    backends: Vec<NativeBackend>,
    /// Effective (budget-clamped) candidates per app per rung.
    cand_at: Vec<Vec<Vec<Vec<f64>>>>,
    rewards: Vec<Vec<f64>>,
    bounds: Vec<f64>,
    shared: SharedCluster,
    adm_state: EpochAdmission,
    admitted: Vec<bool>,
    rungs: Vec<usize>,
    allocations: Vec<AllocationFrame>,
    parked_epochs: Vec<usize>,
    completed_epochs: Vec<usize>,
    /// Frames folded into each tenant's learner/stats (= arrivals under
    /// the barrier; = the deterministic fold prefix under the frontier).
    frames_seen: Vec<usize>,
    lat_sum: Vec<f64>,
    fid_sum: Vec<f64>,
    met: Vec<usize>,
    /// Rung-residency frame counts: the live path's demand-confidence
    /// evidence (the model is learned from live records, so "observations
    /// at a rung" = frames folded while holding that rung).
    rung_frames: Vec<Vec<u64>>,
    last_seen: Vec<usize>,
    // ---- frontier bookkeeping (idle under the barrier) ---------------
    frontier: ProgressFrontier,
    /// Per-tenant fold prefix for the next decision == the tenant's knob
    /// horizon: every emitted frame is decided, every decided frame is
    /// folded before the next decision reads the models.
    target: Vec<usize>,
    /// Arrived-but-unfolded records, per tenant, in frame order.
    buf: Vec<VecDeque<FrameRecord>>,
    delivered: Vec<usize>,
    /// Last knobs scheduled per tenant (the drain extends these over any
    /// post-window tail).
    current_ks: Vec<Vec<f64>>,
    /// Per-tenant per-epoch latency histograms (always on).
    lat: Vec<EpochLatencies>,
    /// Event sink for the fold thread (no-op unless `trace_events`).
    sink: EventSink,
}

impl LiveRun<'_> {
    /// Fold one record into tenant `i`'s learner and summary stats.
    fn fold(&mut self, i: usize, rec: &FrameRecord) {
        let u = self.apps[i].spec.normalize(&rec.knobs);
        let (y, off) = self.backends[i].group_map().targets(&rec.stage_ms, rec.end_to_end_ms);
        self.backends[i].update(&u, &y);
        self.backends[i].observe_offset(off);
        // the tenant's epoch is its own fold count, not wall time — the
        // same frame lands in the same bucket under any pacing
        let epoch = self.frames_seen[i] / self.epoch_frames;
        self.lat[i].record(epoch, rec.end_to_end_ms);
        self.sink.record_with(|| Event {
            tenant: Some(i),
            epoch,
            frame: Some(rec.frame),
            seq: 0,
            kind: EventKind::FrameStart { knobs: rec.knobs.clone() },
        });
        self.sink.record_with(|| Event {
            tenant: Some(i),
            epoch,
            frame: Some(rec.frame),
            seq: 1,
            kind: EventKind::Frame {
                ms: rec.end_to_end_ms,
                stage_ms: rec.stage_ms.clone(),
                fidelity: rec.fidelity,
            },
        });
        self.frames_seen[i] += 1;
        self.lat_sum[i] += rec.end_to_end_ms;
        self.fid_sum[i] += rec.fidelity;
        if rec.end_to_end_ms <= self.bounds[i] {
            self.met[i] += 1;
        }
    }

    /// Frontier-ordered replay: fold tenant `i`'s buffered records up to
    /// its deterministic prefix for the firing decision.
    fn fold_to_target(&mut self, i: usize) {
        while self.frames_seen[i] < self.target[i] {
            let rec = self.buf[i]
                .pop_front()
                // detlint: allow(unwrap) — frontier protocol invariant: a decision fires only after its fold prefix arrived
                .expect("frontier fired before its fold prefix arrived");
            self.fold(i, &rec);
        }
    }

    /// The scheduled window is over: decide every remaining frame under
    /// the last decided knobs so parked tenants drain their deferred
    /// tails — a live stream never loses frames to parking.
    fn drain_schedules(&mut self) {
        // drain extensions are stamped past every decision epoch so they
        // sort after all in-window knob events
        let drain_epoch = (self.cfg.frames + self.epoch_frames - 1) / self.epoch_frames;
        let frames = self.cfg.frames;
        for i in 0..self.cfg.apps {
            if self.target[i] < frames {
                let from = self.target[i];
                let ks = self.current_ks[i].clone();
                self.sched_handles[i]
                    .as_ref()
                    // detlint: allow(unwrap) — every scheduled tenant owns a stream entry by construction
                    .expect("frontier streams are scheduled")
                    .extend(from, ks.clone(), frames);
                self.sink.record_with(|| Event {
                    tenant: Some(i),
                    epoch: drain_epoch,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Knobs { from_frame: from, horizon: frames, knobs: ks },
                });
                self.target[i] = frames;
            }
        }
    }

    /// One reallocation decision: rebuild utility curves from the folded
    /// models, re-decide admission, water-fill the pool, install quotas
    /// and knobs, and record the allocation frame.
    fn fire_decision(&mut self, epoch_idx: usize, draining: bool) {
        let n = self.cfg.apps;
        if !self.cfg.barrier {
            // fold each tenant's deterministic prefix, in (tenant, epoch,
            // seq) order, before anything reads the models
            for a in 0..n {
                self.fold_to_target(a);
            }
        }
        // one batched prediction per (app, rung): the curve point and the
        // best action it came from are recorded together so the retune
        // below never re-predicts the grid
        let mut curves: Vec<Vec<f64>> = Vec::with_capacity(n);
        let mut best_at: Vec<Vec<usize>> = Vec::with_capacity(n);
        for a in 0..n {
            let target = self.bounds[a] * self.cfg.bound_headroom;
            let mut curve = Vec::with_capacity(self.levels.len());
            let mut bests = Vec::with_capacity(self.levels.len());
            for l in 0..self.levels.len() {
                let costs = self.backends[a].predict(&self.cand_at[a][l]);
                let best = crate::runtime::constrained_argmax(&costs, &self.rewards[a], target);
                curve.push(if costs[best] <= target { self.rewards[a][best] } else { 0.0 });
                bests.push(best);
            }
            curves.push(curve);
            best_at.push(bests);
        }
        let w = self.cfg.scheduler.weights_at(n, epoch_idx * self.epoch_frames);
        // charge the closing epoch's folded frames to the rung each
        // stream held (rungs[] is still the closing epoch's assignment);
        // a decision that consumed a full fresh batch completes an epoch
        // for that tenant at decision cadence
        for a in 0..n {
            let fresh = self.frames_seen[a] - self.last_seen[a];
            self.rung_frames[a][self.rungs[a]] += fresh as u64;
            if fresh >= self.epoch_frames {
                self.completed_epochs[a] += 1;
            }
            self.last_seen[a] = self.frames_seen[a];
        }
        let reservations: Vec<usize> = (0..n)
            .map(|a| {
                if self.frames_seen[a] > 0 {
                    demand_cores_confident(
                        &curves[a],
                        &self.levels,
                        self.even,
                        &self.rung_frames[a],
                        self.cfg.scheduler.demand_confidence,
                    )
                    .clamp(1, self.even)
                } else {
                    self.floor_req.clamp(1, self.even)
                }
            })
            .collect();
        let review_due = epoch_idx > self.cfg.scheduler.warmup_epochs
            || self.adm_state.overdue_pending();
        if self.epoch_mode && !draining && review_due {
            let next = self.adm_state.decide(self.total, &w, &reservations);
            for a in 0..n {
                if next[a] && !self.admitted[a] {
                    self.sink.record_with(|| Event {
                        tenant: Some(a),
                        epoch: epoch_idx,
                        frame: None,
                        seq: 0,
                        kind: EventKind::Resume { at_epoch: epoch_idx },
                    });
                    if self.cfg.barrier {
                        // re-admitted: reopen the source gate (the warm
                        // model learned so far is still in `backends`)
                        self.pause_handles[a].resume();
                    } else {
                        // fast-forward: the re-admitted tenant owes one
                        // epoch of frames for the *current* decision, not
                        // the backlog it sat out — the straggler-stall fix
                        self.frontier.resume_at(a, epoch_idx);
                        self.pause_handles[a].resume_at(epoch_idx);
                    }
                } else if !next[a] && self.admitted[a] {
                    self.sink.record_with(|| Event {
                        tenant: Some(a),
                        epoch: epoch_idx,
                        frame: None,
                        seq: 0,
                        kind: EventKind::Park,
                    });
                    if self.cfg.barrier {
                        self.pause_handles[a].pause();
                    } else {
                        // parking = not extending the knob schedule: the
                        // source blocks at the frozen horizon with every
                        // emitted frame already folded, and the tenant
                        // leaves the frontier's participation set
                        self.frontier.park(a);
                    }
                }
            }
            self.admitted = next;
        } else if self.epoch_mode && !draining {
            self.admitted = self.adm_state.hold();
        }
        if self.sink.enabled() {
            let ev = Event {
                tenant: None,
                epoch: epoch_idx,
                frame: None,
                seq: 0,
                kind: EventKind::Admission {
                    admitted: self.admitted.clone(),
                    reservations: reservations.clone(),
                },
            };
            self.sink.record_with(|| ev);
        }
        for (a, &adm) in self.admitted.iter().enumerate() {
            if !adm {
                self.parked_epochs[a] += 1;
            }
        }
        let active: Vec<usize> = (0..n).filter(|&a| self.admitted[a]).collect();
        let sub_curves: Vec<Vec<f64>> = active.iter().map(|&a| curves[a].clone()).collect();
        let sub_w: Vec<f64> = active.iter().map(|&a| w[a]).collect();
        let sub_prev: Vec<usize> = active.iter().map(|&a| self.rungs[a]).collect();
        // 2% fairness holdback (epoch mode only): at the full pool the
        // reserve_top_up below is provably a no-op — the water-filler's
        // even-share raise strictly dominates the top-up condition — so
        // withhold 2% from the fill and let the top-up spend it seating
        // under-served admitted tenants. Floor-guarded so every
        // admitted tenant still seats its floor rung on tight pools.
        // Mirror-validated: python/tests/test_shard_mirror.py.
        let fill_budget = if self.epoch_mode {
            let hold = (self.total / 50)
                .min(self.total.saturating_sub(active.len() * self.levels[0]));
            self.total - hold
        } else {
            self.total
        };
        let sub = scheduler::allocate_v2(
            &sub_curves,
            &self.levels,
            fill_budget,
            &sub_w,
            Some(&sub_prev),
            self.cfg.scheduler.hysteresis,
        );
        for (k, &a) in active.iter().enumerate() {
            self.rungs[a] = sub[k];
        }
        if self.epoch_mode {
            reserve_top_up(
                &mut self.rungs,
                &self.levels,
                self.total,
                &self.admitted,
                &reservations,
                self.even,
                &w,
            );
        }
        let cores: Vec<usize> = (0..n)
            .map(|a| if self.admitted[a] { self.levels[self.rungs[a]] } else { 0 })
            .collect();
        let parked: Vec<bool> = self.admitted.iter().map(|&a| !a).collect();
        self.shared.set_quotas_parked(&cores, &parked);
        // retune every running pipeline to the best predicted-feasible
        // config at its new quota, parallelism clamped to the grant: the
        // barrier latches "from now", the frontier pins the knobs to the
        // exact frames the decision governs
        for &a in &active {
            let pick = best_at[a][self.rungs[a]];
            let ks = self.apps[a].spec.denormalize(&self.cand_at[a][self.rungs[a]][pick]);
            if self.cfg.barrier {
                self.knob_handles[a].set(ks);
            } else if self.target[a] < self.cfg.frames {
                let from = self.target[a];
                let to = (from + self.epoch_frames).min(self.cfg.frames);
                self.sched_handles[a]
                    .as_ref()
                    // detlint: allow(unwrap) — every scheduled tenant owns a stream entry by construction
                    .expect("frontier streams are scheduled")
                    .extend(from, ks.clone(), to);
                self.sink.record_with(|| Event {
                    tenant: Some(a),
                    epoch: epoch_idx,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Knobs { from_frame: from, horizon: to, knobs: ks.clone() },
                });
                self.current_ks[a] = ks;
                self.target[a] = to;
            }
        }
        let churn_cores = self
            .allocations
            .last()
            .map(|prev| AllocationFrame::churn_vs(self.shared.quotas(), prev))
            .unwrap_or(0);
        if self.sink.enabled() {
            let ev = Event {
                tenant: None,
                epoch: epoch_idx,
                frame: None,
                seq: 0,
                kind: EventKind::Alloc {
                    cores: self.shared.quotas().to_vec(),
                    parked: parked.clone(),
                    churn_cores,
                },
            };
            self.sink.record_with(|| ev);
        }
        let predicted_utility: Vec<f64> = (0..n)
            .map(|a| if self.admitted[a] { curves[a][self.rungs[a]] } else { 0.0 })
            .collect();
        self.allocations.push(AllocationFrame {
            epoch: epoch_idx,
            start_frame: epoch_idx * self.epoch_frames,
            levels: self.rungs.clone(),
            // read back from the shared cluster: the bookkeeper that
            // enforced the budget is the one the report quotes
            cores: self.shared.quotas().to_vec(),
            predicted_utility,
            parked,
            churn_cores,
        });
    }

    /// Frontier protocol: decisions fire as the envelope advances, each
    /// folding a deterministic per-tenant prefix.
    fn frontier_loop(&mut self, rx: &Receiver<(usize, FrameRecord)>) {
        let mut next_decision = 1usize;
        if next_decision * self.epoch_frames >= self.cfg.frames {
            // zero-decision run: the whole stream is decided up front
            self.drain_schedules();
        }
        while let Ok((i, rec)) = rx.recv() {
            self.delivered[i] += 1;
            if self.admitted[i] {
                self.frontier.on_frame(i);
            }
            if self.delivered[i] == self.cfg.frames {
                self.frontier.finish(i);
            }
            self.buf[i].push_back(rec);
            while next_decision * self.epoch_frames < self.cfg.frames
                && self.frontier.passed(next_decision - 1)
            {
                // stamp the *decided* epoch, not the racy envelope state:
                // the trace is identical under any arrival interleaving
                self.sink.record_with(|| Event {
                    tenant: None,
                    epoch: next_decision,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Frontier { passed: next_decision - 1 },
                });
                self.fire_decision(next_decision, false);
                next_decision += 1;
                if next_decision * self.epoch_frames >= self.cfg.frames {
                    self.drain_schedules();
                }
            }
        }
        // fold every banked tail record (post-window epochs feed the
        // summary stats, not decisions), still in per-tenant frame order
        for i in 0..self.cfg.apps {
            while let Some(rec) = self.buf[i].pop_front() {
                self.fold(i, &rec);
            }
        }
    }

    /// Legacy barrier protocol: eager folds, frame-count boundaries over
    /// the admitted set, wall-clock knob latching.
    fn barrier_loop(&mut self, rx: &Receiver<(usize, FrameRecord)>) {
        let mut boundary = self.epoch_frames;
        let mut draining = false;
        while let Ok((i, rec)) = rx.recv() {
            self.fold(i, &rec);
            // an epoch completes when every *admitted* app has streamed
            // past the boundary (paused sources cannot advance)
            let all_past = (0..self.cfg.apps)
                .filter(|&a| self.admitted[a])
                .all(|a| self.frames_seen[a] >= boundary.min(self.cfg.frames));
            if all_past && boundary < self.cfg.frames {
                let epoch_idx = self.allocations.len();
                self.fire_decision(epoch_idx, draining);
                boundary += self.epoch_frames;
            }
            // final drain: once every admitted tenant has delivered all
            // its frames, reopen the parked tenants' gates so they finish
            // too (frames are deferred by parking, never lost)
            if !draining
                && self.admitted.iter().any(|&a| !a)
                && (0..self.cfg.apps)
                    .filter(|&a| self.admitted[a])
                    .all(|a| self.frames_seen[a] >= self.cfg.frames)
            {
                draining = true;
                for a in 0..self.cfg.apps {
                    if !self.admitted[a] {
                        self.pause_handles[a].resume();
                        self.admitted[a] = true;
                    }
                }
            }
        }
    }
}

/// Stream `cfg.apps` generated pipelines through the threaded engine
/// concurrently, learning each latency model online and reallocating the
/// shared cores every `scheduler.epoch_frames` frames of per-tenant
/// progress (see the module docs for the frontier protocol). With
/// `scheduler.admission_epoch`, an over-subscribed floor parks tenants by
/// freezing their schedules; parking is re-decided every epoch from
/// learned demands with starvation-bounded rotation, and parked tenants
/// drain their remaining frames after the scheduled window.
pub fn run_live(cfg: &LiveConfig) -> Result<LiveReport> {
    assert!(cfg.apps > 0 && cfg.frames > 0);
    let total = cfg.cluster.total_cores();
    assert!(cfg.apps <= total, "one core per app minimum");
    if let Some((s, delay)) = cfg.straggler {
        anyhow::ensure!(
            s < cfg.apps,
            "straggler tenant {s} out of range (run has {} apps)",
            cfg.apps
        );
        anyhow::ensure!(delay >= 0.0, "straggler delay must be >= 0 ms");
    }
    let epoch_mode = cfg.scheduler.admission_epoch;
    let weights0 = cfg.scheduler.weights_at(cfg.apps, 0);
    let floor_req = cfg.scheduler.requested_floor(total, cfg.apps);
    // run-level parking cannot work on live streams (frames cannot be
    // dropped retroactively): an over-subscribed floor is rejected unless
    // epoch-granular admission is on, which parks by freezing schedules
    anyhow::ensure!(
        epoch_mode || floor_req * cfg.apps <= total,
        "fairness floor x apps exceeds the {total}-core pool; whole-run \
         admission parking is fleet-only (a live stream cannot drop frames) \
         — lower --floor, or pass --admission-epoch to park live tenants by \
         freezing their sources"
    );
    let mut adm_state = EpochAdmission::new(cfg.apps, cfg.scheduler.starvation_bound_or_default())
        .with_hysteresis(cfg.scheduler.admission_hysteresis);
    let admitted: Vec<bool> = if epoch_mode {
        adm_state.decide(
            total,
            &weights0,
            &vec![floor_req.clamp(1, total.max(1)); cfg.apps],
        )
    } else {
        vec![true; cfg.apps]
    };
    let capacity0 = admitted.iter().filter(|&&a| a).count();
    let even = (total / capacity0).max(1);
    let floor = if epoch_mode { 1 } else { cfg.scheduler.floor_cores(total, cfg.apps) };
    let levels = scheduler::core_levels(
        total,
        capacity0,
        floor,
        cfg.scheduler.ladder_rungs,
        cfg.scheduler.max_boost,
    );
    let even_rung = levels
        .iter()
        .position(|&l| l == even)
        // detlint: allow(unwrap) — core_levels inserts the even share unconditionally
        .expect("core_levels always contains the even share");
    let epoch_frames = cfg.scheduler.epoch_frames.max(1);

    // ---- spawn every app through the engine + one forwarder each -------
    let (rec_tx, rec_rx) = channel::<(usize, FrameRecord)>();
    let mut apps: Vec<Arc<App>> = Vec::with_capacity(cfg.apps);
    let mut knob_handles: Vec<KnobHandle> = Vec::with_capacity(cfg.apps);
    let mut pause_handles: Vec<PauseHandle> = Vec::with_capacity(cfg.apps);
    let mut sched_handles: Vec<Option<ScheduleHandle>> = Vec::with_capacity(cfg.apps);
    let mut profiles: Vec<AppProfile> = Vec::with_capacity(cfg.apps);
    for i in 0..cfg.apps {
        let profile = AppProfile::for_fleet_member(cfg.heterogeneous, i, cfg.workload.profile);
        let mut wcfg = cfg.workload.clone();
        wcfg.profile = profile;
        let slice = Cluster {
            servers: 1,
            cores_per_server: even,
            comm_ms_per_frame: cfg.cluster.comm_ms_per_frame,
        };
        let app = Arc::new(crate::workloads::generate_on(
            cfg.seed.wrapping_add(i as u64),
            &wcfg,
            &slice,
        ));
        let source_delay_ms = match cfg.straggler {
            Some((s, d)) if s == i => d,
            _ => 0.0,
        };
        let handle = spawn_stream(
            Arc::clone(&app),
            app.spec.defaults(),
            EngineConfig {
                frames: cfg.frames,
                realtime_scale: cfg.realtime_scale,
                queue_capacity: 8,
                seed: cfg.seed.wrapping_add(0x11CE ^ i as u64),
                // the barrier parks by closing the source gate; the
                // frontier parks by freezing the knob-schedule horizon
                // (an initially-parked tenant simply starts with an
                // empty schedule), so its gate stays open
                start_paused: cfg.barrier && !admitted[i],
                epoch_frames: if cfg.barrier { 0 } else { epoch_frames },
                source_delay_ms,
                knob_horizon: if cfg.barrier {
                    None
                } else {
                    Some(if admitted[i] { epoch_frames.min(cfg.frames) } else { 0 })
                },
            },
        );
        knob_handles.push(handle.knob_handle());
        pause_handles.push(handle.pause_handle());
        sched_handles.push(handle.schedule_handle());
        let tx = rec_tx.clone();
        std::thread::Builder::new()
            .name(format!("forward-{}", app.spec.name))
            .spawn(move || {
                while let Ok(rec) = handle.records.recv() {
                    if tx.send((i, rec)).is_err() {
                        return;
                    }
                }
            })
            // detlint: allow(unwrap) — OS thread-spawn failure is resource exhaustion — fatal by design
            .expect("spawn forwarder thread");
        apps.push(app);
        profiles.push(profile);
    }
    drop(rec_tx);

    // ---- per-app scheduler state: model, candidate grid, rewards -------
    let backends: Vec<NativeBackend> =
        apps.iter().map(|a| NativeBackend::structured(&a.spec)).collect();
    let mut cand_at: Vec<Vec<Vec<Vec<f64>>>> = Vec::with_capacity(cfg.apps);
    let mut rewards: Vec<Vec<f64>> = Vec::with_capacity(cfg.apps);
    for (i, app) in apps.iter().enumerate() {
        let mut rng = Rng::new(cfg.seed.wrapping_add(0xCAFE).wrapping_add(i as u64));
        let mut grid: Vec<Vec<f64>> = (0..cfg.candidates)
            .map(|_| {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                app.spec.denormalize(&u)
            })
            .collect();
        grid.push(app.spec.defaults());
        let content = app.model.content(0);
        rewards.push(grid.iter().map(|ks| app.model.fidelity(ks, &content)).collect());
        cand_at.push(effective_candidates(app, &grid, &levels));
    }

    let bounds: Vec<f64> = apps.iter().map(|a| a.spec.latency_bounds_ms[0]).collect();
    let shared = SharedCluster::parked_even(cfg.cluster.clone(), &admitted);
    let rungs = vec![even_rung; cfg.apps];
    let mut parked_epochs = vec![0usize; cfg.apps];
    for (i, &a) in admitted.iter().enumerate() {
        if !a {
            parked_epochs[i] += 1;
        }
    }
    let allocations: Vec<AllocationFrame> = vec![AllocationFrame {
        epoch: 0,
        start_frame: 0,
        levels: rungs.clone(),
        cores: shared.quotas().to_vec(),
        predicted_utility: vec![0.0; cfg.apps],
        parked: admitted.iter().map(|&a| !a).collect(),
        churn_cores: 0,
    }];

    let frontier = ProgressFrontier::new(cfg.apps, epoch_frames, &admitted);
    let target: Vec<usize> = admitted
        .iter()
        .map(|&a| if a { epoch_frames.min(cfg.frames) } else { 0 })
        .collect();
    let current_ks: Vec<Vec<f64>> = apps.iter().map(|a| a.spec.defaults()).collect();
    let n_levels = levels.len();
    let trace = TraceCollector::new(cfg.trace_events);
    let total_epochs = (cfg.frames + epoch_frames - 1) / epoch_frames;
    let mut run = LiveRun {
        cfg,
        epoch_mode,
        epoch_frames,
        total,
        even,
        floor_req,
        levels,
        apps,
        knob_handles,
        pause_handles,
        sched_handles,
        backends,
        cand_at,
        rewards,
        bounds,
        shared,
        adm_state,
        admitted,
        rungs,
        allocations,
        parked_epochs,
        completed_epochs: vec![0; cfg.apps],
        frames_seen: vec![0; cfg.apps],
        lat_sum: vec![0.0; cfg.apps],
        fid_sum: vec![0.0; cfg.apps],
        met: vec![0; cfg.apps],
        rung_frames: vec![vec![0; n_levels]; cfg.apps],
        last_seen: vec![0; cfg.apps],
        frontier,
        target,
        buf: (0..cfg.apps).map(|_| VecDeque::new()).collect(),
        delivered: vec![0; cfg.apps],
        current_ks,
        lat: (0..cfg.apps).map(|_| EpochLatencies::with_epochs(total_epochs)).collect(),
        sink: trace.sink(),
    };
    if run.sink.enabled() {
        // the epoch-0 decision happens before any frame streams: record
        // its admission verdict, initial schedules, and even-share grants
        for i in 0..cfg.apps {
            if !run.admitted[i] {
                let ev = Event {
                    tenant: Some(i),
                    epoch: 0,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Park,
                };
                run.sink.record_with(|| ev);
            } else if !cfg.barrier {
                let ev = Event {
                    tenant: Some(i),
                    epoch: 0,
                    frame: None,
                    seq: 0,
                    kind: EventKind::Knobs {
                        from_frame: 0,
                        horizon: run.target[i],
                        knobs: run.current_ks[i].clone(),
                    },
                };
                run.sink.record_with(|| ev);
            }
        }
        let ev = Event {
            tenant: None,
            epoch: 0,
            frame: None,
            seq: 0,
            kind: EventKind::Admission {
                admitted: run.admitted.clone(),
                reservations: if epoch_mode {
                    vec![floor_req.clamp(1, total.max(1)); cfg.apps]
                } else {
                    Vec::new()
                },
            },
        };
        run.sink.record_with(|| ev);
        let ev = Event {
            tenant: None,
            epoch: 0,
            frame: None,
            seq: 0,
            kind: EventKind::Alloc {
                cores: run.shared.quotas().to_vec(),
                parked: run.admitted.iter().map(|&a| !a).collect(),
                churn_cores: 0,
            },
        };
        run.sink.record_with(|| ev);
    }
    if cfg.barrier {
        run.barrier_loop(&rec_rx);
    } else {
        run.frontier_loop(&rec_rx);
    }

    // the closing quota is what the last epoch actually installed (a
    // tenant parked at the final decide closes at zero cores, not at its
    // stale pre-park rung)
    // detlint: allow(unwrap) — warmup records the epoch-0 allocation before any decision fires
    let final_cores = run.allocations.last().expect("epoch 0 recorded").cores.clone();
    // release the fold thread's sender before draining: the collector's
    // receiver only hangs up once every sink has flushed and closed
    run.sink.close();
    let mut lat = std::mem::take(&mut run.lat);
    let summaries: Vec<LiveAppSummary> = (0..cfg.apps)
        .map(|i| {
            let n = run.frames_seen[i].max(1) as f64;
            LiveAppSummary {
                index: i,
                name: run.apps[i].spec.name.clone(),
                profile: profiles[i].name(),
                bound_ms: run.bounds[i],
                frames: run.frames_seen[i],
                avg_latency_ms: run.lat_sum[i] / n,
                avg_fidelity: run.fid_sum[i] / n,
                bound_met_frac: run.met[i] as f64 / n,
                final_cores: final_cores[i],
                parked_epochs: run.parked_epochs[i],
                completed_epochs: run.completed_epochs[i],
                latency: std::mem::take(&mut lat[i]),
            }
        })
        .collect();
    let timeline = cfg.trace_events.then(|| obs::Timeline {
        source: "live".to_string(),
        seed: cfg.seed,
        apps: cfg.apps,
        frames: cfg.frames,
        epoch_frames,
        events: trace.drain(),
    });
    Ok(LiveReport {
        protocol: if cfg.barrier { "barrier" } else { "frontier" },
        apps: summaries,
        allocations: run.allocations,
        levels: run.levels,
        total_cores: total,
        fairness_floor: floor,
        timeline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_fleet_streams_and_reallocates() {
        let cfg = LiveConfig {
            apps: 3,
            frames: 90,
            seed: 5,
            candidates: 12,
            heterogeneous: true,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig { epoch_frames: 30, ..Default::default() },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.protocol, "frontier");
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 90, "app {} lost frames", a.index);
            assert!(a.avg_latency_ms > 0.0);
            assert!((0.0..=1.0).contains(&a.avg_fidelity));
            assert!(a.final_cores >= report.fairness_floor);
        }
        assert!(!report.allocations.is_empty());
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            assert!(alloc.cores.iter().all(|&c| c >= report.fairness_floor));
        }
        // profiles alternate
        assert_eq!(report.apps[0].profile, "light");
        assert_eq!(report.apps[1].profile, "heavy");
        // without stragglers or parking every tenant completes one epoch
        // per decision, at decision cadence
        let decisions = report.allocations.len() - 1;
        for a in &report.apps {
            assert_eq!(a.completed_epochs, decisions, "app {}", a.index);
        }
    }

    #[test]
    fn live_v2_priorities_and_hysteresis_keep_invariants() {
        let cfg = LiveConfig {
            apps: 3,
            frames: 60,
            seed: 11,
            candidates: 10,
            heterogeneous: true,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig {
                epoch_frames: 20,
                hysteresis: 0.05,
                priorities: vec![3.0, 1.0],
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 60, "app {} lost frames", a.index);
        }
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            assert!(alloc.cores.iter().all(|&c| c >= report.fairness_floor));
            assert!(alloc.parked.iter().all(|&p| !p), "live never parks");
        }
    }

    #[test]
    fn live_rejects_infeasible_floor_without_epoch_admission() {
        // a floor the pool cannot honor errors out instead of being
        // silently clamped (whole-run parking is fleet-only; the error
        // names the epoch-admission escape hatch)
        let cfg = LiveConfig {
            scheduler: SchedulerConfig { fairness_floor: 40, ..Default::default() },
            ..Default::default()
        };
        let err = run_live(&cfg).unwrap_err().to_string();
        assert!(err.contains("fleet-only"), "{err}");
        assert!(err.contains("--admission-epoch"), "{err}");
    }

    #[test]
    fn live_epoch_admission_parks_and_loses_no_frames() {
        // 3 tenants demanding a 5-core floor on a 12-core pool: one is
        // parked (schedule frozen) per epoch; every tenant still delivers
        // all its frames (parked tenants drain after the window)
        let cfg = LiveConfig {
            apps: 3,
            frames: 120,
            seed: 9,
            candidates: 10,
            heterogeneous: true,
            realtime_scale: 0.0,
            cluster: Cluster { servers: 1, cores_per_server: 12, comm_ms_per_frame: 0.0 },
            scheduler: SchedulerConfig {
                epoch_frames: 30,
                fairness_floor: 5,
                admission_epoch: true,
                starvation_bound: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.apps.len(), 3);
        for a in &report.apps {
            assert_eq!(a.frames, 120, "app {} lost frames to parking", a.index);
            assert!(a.avg_latency_ms > 0.0);
        }
        // the initial decision parks exactly one tenant (floor 5 x 3 > 12)
        let first = &report.allocations[0];
        assert_eq!(first.parked.iter().filter(|&&p| p).count(), 1, "{first:?}");
        assert!(
            report.apps.iter().any(|a| a.parked_epochs > 0),
            "nobody was ever parked"
        );
        // a parked tenant skips epochs instead of replaying them, so its
        // decision-cadence epoch count falls behind the admitted tenants'
        let max_completed = report.apps.iter().map(|a| a.completed_epochs).max().unwrap();
        let parked_most =
            report.apps.iter().max_by_key(|a| a.parked_epochs).unwrap();
        assert!(
            parked_most.completed_epochs < max_completed
                || report.apps.iter().all(|a| a.parked_epochs == 0),
            "parked tenant completed as many epochs as the admitted ones: {report:?}"
        );
        // budget safety at every epoch; parked tenants hold zero cores
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
            for (c, &p) in alloc.cores.iter().zip(&alloc.parked) {
                if p {
                    assert_eq!(*c, 0);
                } else {
                    assert!(*c >= 1);
                }
            }
        }
    }

    #[test]
    fn live_tier_shift_changes_weights_mid_run() {
        // structural: a scripted tier shift mid-run keeps every invariant
        // (frame counts, budget) while the scheduler consumes the new
        // weights at epoch boundaries
        let cfg = LiveConfig {
            apps: 3,
            frames: 90,
            seed: 4,
            candidates: 10,
            realtime_scale: 0.0,
            scheduler: SchedulerConfig {
                epoch_frames: 30,
                tier_shift: Some((45, vec![1.0, 1.0, 4.0])),
                ..Default::default()
            },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        for a in &report.apps {
            assert_eq!(a.frames, 90, "app {} lost frames", a.index);
            assert_eq!(a.parked_epochs, 0, "no admission: nobody parks");
        }
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
        }
    }

    #[test]
    fn barrier_protocol_remains_available_for_ab_comparison() {
        let cfg = LiveConfig {
            apps: 2,
            frames: 60,
            seed: 3,
            candidates: 8,
            realtime_scale: 0.0,
            barrier: true,
            scheduler: SchedulerConfig { epoch_frames: 20, ..Default::default() },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        assert_eq!(report.protocol, "barrier");
        for a in &report.apps {
            assert_eq!(a.frames, 60, "app {} lost frames", a.index);
        }
        for alloc in &report.allocations {
            assert!(alloc.total_cores() <= report.total_cores);
        }
    }

    #[test]
    fn live_report_serializes_per_tenant_epoch_counts() {
        let cfg = LiveConfig {
            apps: 2,
            frames: 40,
            seed: 6,
            candidates: 6,
            scheduler: SchedulerConfig { epoch_frames: 20, ..Default::default() },
            ..Default::default()
        };
        let report = run_live(&cfg).unwrap();
        let json = report.to_json().to_string();
        assert!(json.contains("\"protocol\""), "{json}");
        assert!(json.contains("\"completed_epochs\""), "{json}");
        assert!(json.contains("\"parked_epochs\""), "{json}");
        assert!(json.contains("\"allocations\""), "{json}");
    }

    #[test]
    fn live_rejects_out_of_range_straggler() {
        let cfg = LiveConfig {
            apps: 2,
            straggler: Some((5, 10.0)),
            ..Default::default()
        };
        let err = run_live(&cfg).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }
}
