//! # iptune — Automatic Tuning of Interactive Perception Applications
//!
//! A production-shaped reproduction of *Automatic Tuning of Interactive
//! Perception Applications* (Zhu, Kveton, Mummert, Pillai; 2012): an
//! online auto-tuner for parallel perception pipelines structured as
//! data-flow graphs. The tuner learns per-stage latency models online
//! (online gradient descent on the ε-insensitive SVR loss over polynomial
//! feature expansions), composes them along the graph's critical path
//! (sum for sequential stages, max for parallel branches — paper Eq. 9),
//! and drives an ε-greedy controller that maximizes fidelity subject to a
//! latency bound (paper Eq. 1–2).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordinator: the data-flow substrate
//!   ([`dataflow`], [`engine`]), the cluster simulator standing in for
//!   the paper's 15-node testbed ([`simulator`]), the two case-study
//!   application models ([`apps`]), trace collection ([`trace`]), the
//!   learner and controller ([`learner`], [`tuner`]), metrics
//!   ([`metrics`]) and the experiment harness ([`experiments`]).
//! * **L2/L1 (build-time Python)** — the predictor compute graph and its
//!   Pallas kernels, AOT-lowered to HLO text artifacts that the
//!   [`runtime`] module loads and executes through the PJRT CPU client.
//!   Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use iptune::apps::registry::app_by_name;
//! use iptune::trace::TraceSet;
//! use iptune::tuner::{EpsGreedyController, TunerConfig};
//! use iptune::runtime::native::NativeBackend;
//!
//! let app = app_by_name("motion_sift", "specs").unwrap();
//! let traces = TraceSet::generate(&app, 30, 1000, 7);
//! let backend = NativeBackend::structured(&app.spec);
//! let cfg = TunerConfig { epsilon: 0.03, bound_ms: 100.0, ..Default::default() };
//! let mut ctl = EpsGreedyController::new(&app.spec, &traces, Box::new(backend), cfg, 11);
//! let outcome = ctl.run(1000);
//! println!("avg fidelity {:.3}, avg violation {:.1} ms",
//!          outcome.avg_reward, outcome.avg_violation_ms);
//! ```

// Determinism and memory safety are load-bearing here: every report must
// be byte-identical across thread counts, and nothing in the tree needs
// raw pointers. Forbid (not just deny) so no module can opt back in.
#![forbid(unsafe_code)]

pub mod apps;
pub mod config;
pub mod dataflow;
pub mod engine;
pub mod experiments;
pub mod fleet;
pub mod learner;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod trace;
pub mod tuner;
pub mod util;
pub mod workloads;

/// Milliseconds, the time unit used throughout the crate.
pub type Ms = f64;
