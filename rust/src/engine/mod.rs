//! Streaming data-flow engine — the runtime-system substrate (paper
//! Sec. 2: "an application-independent runtime system to distribute and
//! execute applications in parallel", SLIPStream-like).
//!
//! Stages run as concurrent OS threads connected by *bounded* channels
//! (connectors with backpressure). Each frame token carries a virtual
//! timestamp: a stage joins its input connectors (max of dependency
//! timestamps — the critical-path semantics), "computes" for its modeled
//! latency (an optional scaled real sleep keeps execution genuinely
//! concurrent), advances the timestamp, and forwards. The engine exports
//! exactly the interface the paper's tuner needs: per-stage latency
//! probes and dynamically settable knobs that take effect on the next
//! frame entering the pipe.

use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;

use crate::apps::App;
use crate::simulator::NoiseModel;
use crate::util::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Wall-clock seconds per simulated millisecond (e.g. 1e-5 → a 100 ms
    /// frame sleeps 1 ms of real time). 0 disables sleeping entirely.
    pub realtime_scale: f64,
    /// Connector (channel) capacity — the backpressure bound.
    pub queue_capacity: usize,
    /// Frames emitted by the source; the source paces itself by the app's
    /// `frame_interval_ms` when `realtime_scale > 0`.
    pub frames: usize,
    pub seed: u64,
    /// Spawn with the source gate already closed: no frame enters the
    /// pipeline until [`PauseHandle::resume`] — how the live scheduler
    /// parks a tenant from frame zero without dropping anything.
    pub start_paused: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            realtime_scale: 0.0,
            queue_capacity: 8,
            frames: 100,
            seed: 0,
            start_paused: false,
        }
    }
}

/// A frame token flowing through the connectors.
#[derive(Debug, Clone)]
struct Token {
    id: usize,
    /// Virtual time (ms) at which this frame's data became available on
    /// this path.
    vt: f64,
    /// The knob vector latched when the frame entered the pipeline.
    knobs: Arc<Vec<f64>>,
}

/// One completed frame at the sink.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    pub frame: usize,
    /// End-to-end virtual latency (ms): critical path through the stages.
    pub end_to_end_ms: f64,
    /// Per-stage virtual latencies (ms).
    pub stage_ms: Vec<f64>,
    pub fidelity: f64,
    /// The knob vector this frame ran under.
    pub knobs: Vec<f64>,
}

enum Evt {
    StageLat { frame: usize, stage: usize, lat: f64 },
    Done { frame: usize, vt: f64, knobs: Arc<Vec<f64>> },
}

/// Handle to a running stream: consume [`FrameRecord`]s, retune knobs.
pub struct StreamHandle {
    pub records: Receiver<FrameRecord>,
    knobs: Arc<RwLock<Arc<Vec<f64>>>>,
    pause: Arc<(Mutex<bool>, Condvar)>,
}

impl StreamHandle {
    /// Set the knob vector for subsequently emitted frames (the paper's
    /// "changes in parameter settings are then applied to the running
    /// application").
    pub fn set_knobs(&self, ks: Vec<f64>) {
        *self.knobs.write().unwrap() = Arc::new(ks);
    }

    pub fn current_knobs(&self) -> Vec<f64> {
        self.knobs.read().unwrap().as_ref().clone()
    }

    /// A cloneable retuning handle that can live on a different thread
    /// than the record receiver. The live fleet scheduler forwards each
    /// stream's records from a dedicated thread while the scheduler
    /// thread keeps the knob handles and retunes every epoch.
    pub fn knob_handle(&self) -> KnobHandle {
        KnobHandle(Arc::clone(&self.knobs))
    }

    /// A cloneable source-gate handle: pausing closes the gate *before*
    /// the next frame enters the pipeline (frames already inside the
    /// bounded connectors drain normally — a live stream never drops or
    /// retro-drops frames), resuming reopens it. The live scheduler parks
    /// a tenant by pausing its source instead of zeroing its quota.
    pub fn pause_handle(&self) -> PauseHandle {
        PauseHandle(Arc::clone(&self.pause))
    }
}

/// Cloneable, thread-safe source gate detached from a [`StreamHandle`]
/// (see [`StreamHandle::pause_handle`]).
#[derive(Clone)]
pub struct PauseHandle(Arc<(Mutex<bool>, Condvar)>);

impl PauseHandle {
    /// Close the gate: the source blocks before emitting its next frame.
    pub fn pause(&self) {
        let (m, _) = &*self.0;
        *m.lock().unwrap() = true;
    }

    /// Reopen the gate and wake the source.
    pub fn resume(&self) {
        let (m, cv) = &*self.0;
        *m.lock().unwrap() = false;
        cv.notify_all();
    }

    pub fn paused(&self) -> bool {
        let (m, _) = &*self.0;
        *m.lock().unwrap()
    }
}

/// Cloneable, thread-safe knob setter detached from a [`StreamHandle`]
/// (see [`StreamHandle::knob_handle`]).
#[derive(Clone)]
pub struct KnobHandle(Arc<RwLock<Arc<Vec<f64>>>>);

impl KnobHandle {
    pub fn set(&self, ks: Vec<f64>) {
        *self.0.write().unwrap() = Arc::new(ks);
    }

    pub fn get(&self) -> Vec<f64> {
        self.0.read().unwrap().as_ref().clone()
    }
}

fn sleep_scaled(ms: f64, scale: f64) {
    if scale > 0.0 {
        thread::sleep(std::time::Duration::from_secs_f64(ms * scale));
    }
}

/// Spawn the full data-flow of `app` as threads and return a
/// [`StreamHandle`]. The pipeline finishes after `cfg.frames` frames; the
/// record channel then closes and all threads exit.
pub fn spawn_stream(app: Arc<App>, initial_knobs: Vec<f64>, cfg: EngineConfig) -> StreamHandle {
    let n_stages = app.graph.len();
    let knobs = Arc::new(RwLock::new(Arc::new(initial_knobs)));
    let pause = Arc::new((Mutex::new(cfg.start_paused), Condvar::new()));
    let (rec_tx, rec_rx) = channel::<FrameRecord>();
    let (evt_tx, evt_rx) = channel::<Evt>();

    // connectors: one bounded channel per graph edge
    let succ = app.graph.successors();
    let mut stage_inputs: Vec<Vec<Receiver<Token>>> =
        (0..n_stages).map(|_| Vec::new()).collect();
    let mut stage_outputs: Vec<Vec<SyncSender<Token>>> =
        (0..n_stages).map(|_| Vec::new()).collect();
    for (src, dsts) in succ.iter().enumerate() {
        for &dst in dsts {
            let (tx, rx) = sync_channel::<Token>(cfg.queue_capacity);
            stage_outputs[src].push(tx);
            stage_inputs[dst].push(rx);
        }
    }

    let sources = app.graph.sources();
    let sinks = app.graph.sinks();
    assert_eq!(sinks.len(), 1, "engine expects a single sink stage");
    let sink_id = sinks[0];

    for stage in 0..n_stages {
        let inputs = std::mem::take(&mut stage_inputs[stage]);
        let outputs = std::mem::take(&mut stage_outputs[stage]);
        let app = Arc::clone(&app);
        let evt_tx = evt_tx.clone();
        let knobs_cell = Arc::clone(&knobs);
        let cfg2 = cfg.clone();
        let pause_gate = Arc::clone(&pause);
        let is_source = sources.contains(&stage);
        let is_sink = stage == sink_id;
        thread::Builder::new()
            .name(format!("stage-{}", app.graph.node(stage).name))
            .spawn(move || {
                let mut rng = Rng::new(cfg2.seed.wrapping_add(stage as u64 * 7919));
                let noise = NoiseModel::default();
                let interval_ms = app.spec.frame_interval_ms;
                for frame in 0..cfg2.frames {
                    // join all input connectors (critical-path max)
                    let token = if is_source {
                        // parked tenants hold here: no frame enters the
                        // pipe until the scheduler reopens the gate
                        {
                            let (m, cv) = &*pause_gate;
                            let mut paused = m.lock().unwrap();
                            while *paused {
                                paused = cv.wait(paused).unwrap();
                            }
                        }
                        sleep_scaled(interval_ms, cfg2.realtime_scale); // camera pace
                        let ks = knobs_cell.read().unwrap().clone();
                        Token { id: frame, vt: 0.0, knobs: ks }
                    } else {
                        let mut joined: Option<Token> = None;
                        for rx in &inputs {
                            match rx.recv() {
                                Ok(t) => {
                                    joined = Some(match joined {
                                        None => t,
                                        Some(prev) => Token {
                                            id: prev.id,
                                            vt: prev.vt.max(t.vt),
                                            knobs: prev.knobs,
                                        },
                                    });
                                }
                                Err(_) => return, // upstream closed
                            }
                        }
                        match joined {
                            Some(t) => t,
                            None => return,
                        }
                    };
                    debug_assert_eq!(token.id, frame);

                    // compute: modeled latency (+drift +noise), optionally
                    // slept — the same cost_drift charge the simulator
                    // applies, so live streams see the drifting-cost
                    // scenario families too
                    let content = app.model.content(frame);
                    let workers = app.model.requested_workers(stage, &token.knobs);
                    let base = app.model.stage_latency(stage, &token.knobs, &content, workers)
                        * app.model.cost_drift(stage, frame);
                    let lat = noise.apply(base, &mut rng);
                    sleep_scaled(lat, cfg2.realtime_scale);
                    let _ = evt_tx.send(Evt::StageLat { frame, stage, lat });
                    let out = Token { id: token.id, vt: token.vt + lat, knobs: token.knobs };

                    if is_sink {
                        let _ = evt_tx.send(Evt::Done {
                            frame,
                            vt: out.vt,
                            knobs: Arc::clone(&out.knobs),
                        });
                    }
                    for tx in &outputs {
                        if tx.send(out.clone()).is_err() {
                            return;
                        }
                    }
                }
            })
            .expect("spawn stage thread");
    }
    drop(evt_tx);

    // assembler: joins per-stage latencies + sink completions into records
    let app2 = Arc::clone(&app);
    let frames = cfg.frames;
    thread::Builder::new()
        .name("assembler".into())
        .spawn(move || {
            use std::collections::HashMap;
            let n_stages = app2.graph.len();
            let mut lat_acc: HashMap<usize, Vec<f64>> = HashMap::new();
            let mut lat_count: HashMap<usize, usize> = HashMap::new();
            let mut done: HashMap<usize, (f64, Arc<Vec<f64>>)> = HashMap::new();
            let mut emitted = 0usize;
            while let Ok(evt) = evt_rx.recv() {
                match evt {
                    Evt::StageLat { frame, stage, lat } => {
                        lat_acc.entry(frame).or_insert_with(|| vec![0.0; n_stages])[stage] =
                            lat;
                        *lat_count.entry(frame).or_insert(0) += 1;
                    }
                    Evt::Done { frame, vt, knobs } => {
                        done.insert(frame, (vt, knobs));
                    }
                }
                // emit in frame order once complete
                while let (Some(&count), Some((vt, ks))) =
                    (lat_count.get(&emitted), done.get(&emitted))
                {
                    if count < n_stages {
                        break;
                    }
                    let stage_ms = lat_acc.remove(&emitted).unwrap();
                    let content = app2.model.content(emitted);
                    let fidelity = app2.model.fidelity(ks, &content);
                    let rec = FrameRecord {
                        frame: emitted,
                        end_to_end_ms: *vt,
                        stage_ms,
                        fidelity,
                        knobs: ks.as_ref().clone(),
                    };
                    lat_count.remove(&emitted);
                    done.remove(&emitted);
                    if rec_tx.send(rec).is_err() {
                        return;
                    }
                    emitted += 1;
                    if emitted == frames {
                        return;
                    }
                }
            }
        })
        .expect("spawn assembler");

    StreamHandle { records: rec_rx, knobs, pause }
}

/// Run a stream to completion, collecting all records (convenience for
/// tests and non-interactive use).
pub fn run_stream_blocking(app: Arc<App>, knobs: Vec<f64>, cfg: EngineConfig) -> Vec<FrameRecord> {
    let frames = cfg.frames;
    let handle = spawn_stream(app, knobs, cfg);
    let mut out = Vec::with_capacity(frames);
    while let Ok(rec) = handle.records.recv() {
        out.push(rec);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::dataflow::critical_path;

    fn app(name: &str) -> Arc<App> {
        Arc::new(app_by_name(name, find_spec_dir(None).unwrap()).unwrap())
    }

    #[test]
    fn stream_delivers_all_frames_in_order() {
        let a = app("pose");
        let ks = a.spec.defaults();
        let recs = run_stream_blocking(
            Arc::clone(&a),
            ks,
            EngineConfig { frames: 50, ..Default::default() },
        );
        assert_eq!(recs.len(), 50);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.frame, i);
            assert_eq!(r.stage_ms.len(), a.graph.len());
            assert!(r.end_to_end_ms > 0.0);
        }
    }

    #[test]
    fn virtual_time_is_critical_path() {
        let a = app("motion_sift");
        let ks = a.spec.defaults();
        let recs = run_stream_blocking(
            Arc::clone(&a),
            ks,
            EngineConfig { frames: 20, ..Default::default() },
        );
        for r in &recs {
            let cp = critical_path(&a.graph, &r.stage_ms);
            assert!(
                (r.end_to_end_ms - cp).abs() < 1e-6,
                "vt {} != critical path {cp}",
                r.end_to_end_ms
            );
        }
    }

    #[test]
    fn retuning_applies_to_later_frames() {
        let a = app("pose");
        let cfg = EngineConfig { frames: 60, realtime_scale: 1e-6, ..Default::default() };
        let handle = spawn_stream(Arc::clone(&a), a.spec.defaults(), cfg);
        let fast = vec![3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let mut records = Vec::new();
        let mut switched = false;
        while let Ok(rec) = handle.records.recv() {
            if rec.frame == 10 && !switched {
                handle.set_knobs(fast.clone());
                switched = true;
            }
            records.push(rec);
        }
        assert_eq!(records.len(), 60);
        // some later frame must run under the fast knobs
        assert!(records.iter().any(|r| r.knobs == fast));
        let early: f64 =
            records[..10].iter().map(|r| r.end_to_end_ms).sum::<f64>() / 10.0;
        let late: f64 =
            records[50..].iter().map(|r| r.end_to_end_ms).sum::<f64>() / 10.0;
        assert!(late < early * 0.5, "retune must speed the pipe: {early} -> {late}");
    }

    #[test]
    fn knob_handle_retunes_from_another_thread() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 40, realtime_scale: 1e-6, ..Default::default() },
        );
        let knobs = handle.knob_handle();
        assert_eq!(knobs.get(), a.spec.defaults());
        let fast = vec![3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let setter = {
            let knobs = knobs.clone();
            let fast = fast.clone();
            std::thread::spawn(move || knobs.set(fast))
        };
        setter.join().unwrap();
        assert_eq!(knobs.get(), fast);
        assert_eq!(handle.current_knobs(), fast);
        let mut n = 0;
        while handle.records.recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 40);
    }

    #[test]
    fn pause_gates_the_source_and_resume_loses_nothing() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 30, start_paused: true, ..Default::default() },
        );
        let pause = handle.pause_handle();
        assert!(pause.paused());
        // the closed gate lets no frame enter the pipeline at all
        assert!(
            handle.records.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "a frame leaked through a closed source gate"
        );
        pause.resume();
        assert!(!pause.paused());
        let mut n = 0;
        while handle.records.recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 30, "deferred frames must all arrive after resume");
    }

    #[test]
    fn no_frame_lost_under_tiny_queues() {
        let a = app("motion_sift");
        let recs = run_stream_blocking(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 40, queue_capacity: 1, ..Default::default() },
        );
        assert_eq!(recs.len(), 40);
    }

    #[test]
    fn knob_latch_is_per_frame_consistent() {
        // every record's knob vector must be one of the two configs set,
        // never a mix
        let a = app("motion_sift");
        let slow = a.spec.defaults();
        let fast = vec![4.0, 4.0, 1.0, 8.0, 8.0];
        let handle = spawn_stream(
            Arc::clone(&a),
            slow.clone(),
            EngineConfig { frames: 40, realtime_scale: 1e-6, ..Default::default() },
        );
        let mut recs = Vec::new();
        while let Ok(rec) = handle.records.recv() {
            if rec.frame == 5 {
                handle.set_knobs(fast.clone());
            }
            recs.push(rec);
        }
        for r in &recs {
            assert!(r.knobs == slow || r.knobs == fast, "mixed knobs {:?}", r.knobs);
        }
    }
}
