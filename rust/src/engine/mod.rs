//! Streaming data-flow engine — the runtime-system substrate (paper
//! Sec. 2: "an application-independent runtime system to distribute and
//! execute applications in parallel", SLIPStream-like).
//!
//! Stages run as concurrent OS threads connected by *bounded* channels
//! (connectors with backpressure). Each frame token carries a virtual
//! timestamp: a stage joins its input connectors (max of dependency
//! timestamps — the critical-path semantics), "computes" for its modeled
//! latency (an optional scaled real sleep keeps execution genuinely
//! concurrent), advances the timestamp, and forwards. The engine exports
//! exactly the interface the paper's tuner needs: per-stage latency
//! probes and dynamically settable knobs that take effect on the next
//! frame entering the pipe.

use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread;

use crate::apps::App;
use crate::obs::Histogram;
use crate::simulator::NoiseModel;
use crate::util::Rng;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Wall-clock seconds per simulated millisecond (e.g. 1e-5 → a 100 ms
    /// frame sleeps 1 ms of real time). 0 disables sleeping entirely.
    pub realtime_scale: f64,
    /// Connector (channel) capacity — the backpressure bound.
    pub queue_capacity: usize,
    /// Frames emitted by the source; the source paces itself by the app's
    /// `frame_interval_ms` when `realtime_scale > 0`.
    pub frames: usize,
    pub seed: u64,
    /// Spawn with the source gate already closed: no frame enters the
    /// pipeline until [`PauseHandle::resume`] — how the live scheduler
    /// parks a tenant from frame zero without dropping anything.
    pub start_paused: bool,
    /// Source-side epoch cadence: every `epoch_frames` emitted frames the
    /// source advances its epoch stamp ([`FrameRecord::epoch`]); a
    /// [`PauseHandle::resume_at`] fast-forwards the stamp. 0 disables
    /// stamping (every record carries epoch 0).
    pub epoch_frames: usize,
    /// Extra *wall-clock* delay (ms of real time, independent of
    /// `realtime_scale`) the source sleeps before each frame — the
    /// injected-straggler hook for the live-path frontier tests and the
    /// CI `live-smoke` job. 0 disables it.
    pub source_delay_ms: f64,
    /// `Some(h)`: knobs come from a *frame-indexed schedule* instead of
    /// the free-running [`KnobHandle`] — frames `0..h` run under the
    /// initial knobs, and the source **blocks** at the first frame past
    /// the scheduled horizon until [`ScheduleHandle::extend`] decides it.
    /// This pins "which knobs did frame `f` run under" to a pure function
    /// of the schedule, independent of OS thread timing — the property
    /// the live path's frontier-ordered replay is built on. `None` keeps
    /// the legacy free-running latch (retunes apply to whatever frame the
    /// source emits next).
    pub knob_horizon: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            realtime_scale: 0.0,
            queue_capacity: 8,
            frames: 100,
            seed: 0,
            start_paused: false,
            epoch_frames: 0,
            source_delay_ms: 0.0,
            knob_horizon: None,
        }
    }
}

/// A frame token flowing through the connectors.
#[derive(Debug, Clone)]
struct Token {
    id: usize,
    /// Virtual time (ms) at which this frame's data became available on
    /// this path.
    vt: f64,
    /// The knob vector latched when the frame entered the pipeline.
    knobs: Arc<Vec<f64>>,
    /// Source epoch stamp latched when the frame entered the pipeline.
    epoch: usize,
}

/// One completed frame at the sink.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    pub frame: usize,
    /// End-to-end virtual latency (ms): critical path through the stages.
    pub end_to_end_ms: f64,
    /// Per-stage virtual latencies (ms).
    pub stage_ms: Vec<f64>,
    pub fidelity: f64,
    /// The knob vector this frame ran under.
    pub knobs: Vec<f64>,
    /// The source's epoch stamp when this frame entered the pipeline
    /// (see [`EngineConfig::epoch_frames`]; 0 when stamping is off).
    /// Advisory for frames emitted inside a park/resume window — the
    /// live scheduler folds by its own deterministic per-tenant counts,
    /// not by this stamp.
    pub epoch: usize,
}

enum Evt {
    StageLat { frame: usize, stage: usize, lat: f64 },
    Done { frame: usize, vt: f64, knobs: Arc<Vec<f64>>, epoch: usize },
}

/// Always-on per-stream statistics built by the assembler thread as it
/// emits records (no locks on the stage hot path — the assembler owns
/// the accumulator) and delivered once when the stream finishes.
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// Frames emitted at the sink.
    pub frames: usize,
    /// End-to-end virtual latency distribution across those frames.
    pub latency: Histogram,
}

/// Source-gate state shared between the source thread and its
/// [`PauseHandle`]s: the pause flag plus the epoch-stamp counter the
/// source latches into each frame.
#[derive(Debug)]
struct SourceGate {
    paused: bool,
    /// Epoch stamped into the next emitted frame.
    epoch: usize,
    /// Frames already stamped with the current epoch.
    into_epoch: usize,
}

/// Frame-indexed knob plan (see [`EngineConfig::knob_horizon`]): the
/// entries map each frame to the knob vector decided for it, and the
/// horizon is the first *undecided* frame — the source blocks there
/// until the scheduler extends the plan.
#[derive(Debug)]
struct KnobPlan {
    /// `(from_frame, knobs)` in ascending `from_frame` order; frame `f`
    /// latches the last entry with `from_frame <= f`.
    entries: Vec<(usize, Arc<Vec<f64>>)>,
    /// Frames `0..horizon` are decided.
    horizon: usize,
}

impl KnobPlan {
    fn knobs_for(&self, frame: usize) -> Arc<Vec<f64>> {
        self.entries
            .iter()
            .rev()
            .find(|(from, _)| *from <= frame)
            .map(|(_, ks)| Arc::clone(ks))
            // detlint: allow(unwrap) — KnobPlan::new installs the frame-0 entry; extend never removes it
            .expect("knob plan always holds a frame-0 entry")
    }
}

/// Handle to a running stream: consume [`FrameRecord`]s, retune knobs.
pub struct StreamHandle {
    pub records: Receiver<FrameRecord>,
    knobs: Arc<RwLock<Arc<Vec<f64>>>>,
    pause: Arc<(Mutex<SourceGate>, Condvar)>,
    plan: Option<Arc<(Mutex<KnobPlan>, Condvar)>>,
    stats_rx: Receiver<EngineStats>,
}

impl StreamHandle {
    /// Set the knob vector for subsequently emitted frames (the paper's
    /// "changes in parameter settings are then applied to the running
    /// application").
    pub fn set_knobs(&self, ks: Vec<f64>) {
        *self.knobs.write().unwrap() = Arc::new(ks);
    }

    pub fn current_knobs(&self) -> Vec<f64> {
        self.knobs.read().unwrap().as_ref().clone()
    }

    /// A cloneable retuning handle that can live on a different thread
    /// than the record receiver. The live fleet scheduler forwards each
    /// stream's records from a dedicated thread while the scheduler
    /// thread keeps the knob handles and retunes every epoch.
    pub fn knob_handle(&self) -> KnobHandle {
        KnobHandle(Arc::clone(&self.knobs))
    }

    /// A cloneable source-gate handle: pausing closes the gate *before*
    /// the next frame enters the pipeline (frames already inside the
    /// bounded connectors drain normally — a live stream never drops or
    /// retro-drops frames), resuming reopens it. The live scheduler parks
    /// a tenant by pausing its source instead of zeroing its quota.
    pub fn pause_handle(&self) -> PauseHandle {
        PauseHandle(Arc::clone(&self.pause))
    }

    /// A cloneable extender for the frame-indexed knob schedule; `None`
    /// unless the stream was spawned with [`EngineConfig::knob_horizon`].
    pub fn schedule_handle(&self) -> Option<ScheduleHandle> {
        self.plan.as_ref().map(|p| ScheduleHandle(Arc::clone(p)))
    }

    /// Block until the stream's assembler finishes, then return its
    /// always-on stats (frame count + end-to-end latency histogram).
    /// `None` if the assembler died without reporting.
    pub fn stats(&self) -> Option<EngineStats> {
        self.stats_rx.recv().ok()
    }
}

/// Cloneable, thread-safe extender for a scheduled stream's knob plan
/// (see [`EngineConfig::knob_horizon`]).
#[derive(Clone)]
pub struct ScheduleHandle(Arc<(Mutex<KnobPlan>, Condvar)>);

impl ScheduleHandle {
    /// Decide frames `from_frame..horizon`: they run under `knobs`
    /// (frames before `from_frame` keep their already-decided entries).
    /// Wakes a source blocked at the old horizon. `from_frame` must not
    /// precede an existing entry — the plan is append-only, so a frame's
    /// knobs can never be rewritten after the fact.
    pub fn extend(&self, from_frame: usize, knobs: Vec<f64>, horizon: usize) {
        let (m, cv) = &*self.0;
        let mut plan = m.lock().unwrap();
        debug_assert!(
            plan.entries.last().map(|(f, _)| *f <= from_frame).unwrap_or(true),
            "knob plan extended backwards"
        );
        plan.entries.push((from_frame, Arc::new(knobs)));
        if horizon > plan.horizon {
            plan.horizon = horizon;
        }
        cv.notify_all();
    }

    /// The first undecided frame.
    pub fn horizon(&self) -> usize {
        let (m, _) = &*self.0;
        m.lock().unwrap().horizon
    }
}

/// Cloneable, thread-safe source gate detached from a [`StreamHandle`]
/// (see [`StreamHandle::pause_handle`]).
#[derive(Clone)]
pub struct PauseHandle(Arc<(Mutex<SourceGate>, Condvar)>);

impl PauseHandle {
    /// Close the gate: the source blocks before emitting its next frame.
    pub fn pause(&self) {
        let (m, _) = &*self.0;
        m.lock().unwrap().paused = true;
    }

    /// Reopen the gate and wake the source.
    pub fn resume(&self) {
        let (m, cv) = &*self.0;
        m.lock().unwrap().paused = false;
        cv.notify_all();
    }

    /// Reopen the gate and *fast-forward* the source's epoch stamp to
    /// `epoch` (monotone — a stamp already past `epoch` is kept): the
    /// frontier protocol's re-admission. The partial epoch in progress
    /// is abandoned; the next emitted frame starts a fresh
    /// `epoch_frames` batch stamped `epoch`, so a re-admitted tenant
    /// owes one epoch of frames for the *current* decision, not a
    /// backlog of stale ones.
    pub fn resume_at(&self, epoch: usize) {
        let (m, cv) = &*self.0;
        {
            let mut g = m.lock().unwrap();
            g.paused = false;
            if g.epoch < epoch {
                g.epoch = epoch;
            }
            g.into_epoch = 0;
        }
        cv.notify_all();
    }

    pub fn paused(&self) -> bool {
        let (m, _) = &*self.0;
        m.lock().unwrap().paused
    }
}

/// Cloneable, thread-safe knob setter detached from a [`StreamHandle`]
/// (see [`StreamHandle::knob_handle`]).
#[derive(Clone)]
pub struct KnobHandle(Arc<RwLock<Arc<Vec<f64>>>>);

impl KnobHandle {
    pub fn set(&self, ks: Vec<f64>) {
        *self.0.write().unwrap() = Arc::new(ks);
    }

    pub fn get(&self) -> Vec<f64> {
        self.0.read().unwrap().as_ref().clone()
    }
}

fn sleep_scaled(ms: f64, scale: f64) {
    if scale > 0.0 {
        thread::sleep(std::time::Duration::from_secs_f64(ms * scale));
    }
}

/// Spawn the full data-flow of `app` as threads and return a
/// [`StreamHandle`]. The pipeline finishes after `cfg.frames` frames; the
/// record channel then closes and all threads exit.
pub fn spawn_stream(app: Arc<App>, initial_knobs: Vec<f64>, cfg: EngineConfig) -> StreamHandle {
    let n_stages = app.graph.len();
    let knobs = Arc::new(RwLock::new(Arc::new(initial_knobs.clone())));
    let plan = cfg.knob_horizon.map(|h| {
        Arc::new((
            Mutex::new(KnobPlan {
                entries: vec![(0, Arc::new(initial_knobs))],
                horizon: h,
            }),
            Condvar::new(),
        ))
    });
    let pause = Arc::new((
        Mutex::new(SourceGate { paused: cfg.start_paused, epoch: 0, into_epoch: 0 }),
        Condvar::new(),
    ));
    let (rec_tx, rec_rx) = channel::<FrameRecord>();
    let (evt_tx, evt_rx) = channel::<Evt>();

    // connectors: one bounded channel per graph edge
    let succ = app.graph.successors();
    let mut stage_inputs: Vec<Vec<Receiver<Token>>> =
        (0..n_stages).map(|_| Vec::new()).collect();
    let mut stage_outputs: Vec<Vec<SyncSender<Token>>> =
        (0..n_stages).map(|_| Vec::new()).collect();
    for (src, dsts) in succ.iter().enumerate() {
        for &dst in dsts {
            let (tx, rx) = sync_channel::<Token>(cfg.queue_capacity);
            stage_outputs[src].push(tx);
            stage_inputs[dst].push(rx);
        }
    }

    let sources = app.graph.sources();
    let sinks = app.graph.sinks();
    assert_eq!(sinks.len(), 1, "engine expects a single sink stage");
    let sink_id = sinks[0];

    for stage in 0..n_stages {
        let inputs = std::mem::take(&mut stage_inputs[stage]);
        let outputs = std::mem::take(&mut stage_outputs[stage]);
        let app = Arc::clone(&app);
        let evt_tx = evt_tx.clone();
        let knobs_cell = Arc::clone(&knobs);
        let plan2 = plan.clone();
        let cfg2 = cfg.clone();
        let pause_gate = Arc::clone(&pause);
        let is_source = sources.contains(&stage);
        let is_sink = stage == sink_id;
        thread::Builder::new()
            .name(format!("stage-{}", app.graph.node(stage).name))
            .spawn(move || {
                let mut rng = Rng::new(cfg2.seed.wrapping_add(stage as u64 * 7919));
                let noise = NoiseModel::default();
                let interval_ms = app.spec.frame_interval_ms;
                for frame in 0..cfg2.frames {
                    // join all input connectors (critical-path max)
                    let token = if is_source {
                        // parked tenants hold here: no frame enters the
                        // pipe until the scheduler reopens the gate
                        let epoch = {
                            let (m, cv) = &*pause_gate;
                            let mut gate = m.lock().unwrap();
                            while gate.paused {
                                gate = cv.wait(gate).unwrap();
                            }
                            // stamp-then-advance under the same lock, so
                            // a resume_at fast-forward never splits a
                            // stamped batch
                            let epoch = gate.epoch;
                            if cfg2.epoch_frames > 0 {
                                gate.into_epoch += 1;
                                if gate.into_epoch >= cfg2.epoch_frames {
                                    gate.epoch += 1;
                                    gate.into_epoch = 0;
                                }
                            }
                            epoch
                        };
                        sleep_scaled(interval_ms, cfg2.realtime_scale); // camera pace
                        if cfg2.source_delay_ms > 0.0 {
                            // injected straggler: real wall-clock lag
                            thread::sleep(std::time::Duration::from_secs_f64(
                                cfg2.source_delay_ms * 1e-3,
                            ));
                        }
                        let ks = match &plan2 {
                            // scheduled mode: block until the plan decides
                            // this frame, then latch its decided knobs —
                            // content is a pure function of the schedule
                            Some(p) => {
                                let (m, cv) = &**p;
                                let mut plan = m.lock().unwrap();
                                while frame >= plan.horizon {
                                    plan = cv.wait(plan).unwrap();
                                }
                                plan.knobs_for(frame)
                            }
                            None => knobs_cell.read().unwrap().clone(),
                        };
                        Token { id: frame, vt: 0.0, knobs: ks, epoch }
                    } else {
                        let mut joined: Option<Token> = None;
                        for rx in &inputs {
                            match rx.recv() {
                                Ok(t) => {
                                    joined = Some(match joined {
                                        None => t,
                                        Some(prev) => Token {
                                            id: prev.id,
                                            vt: prev.vt.max(t.vt),
                                            knobs: prev.knobs,
                                            epoch: prev.epoch,
                                        },
                                    });
                                }
                                Err(_) => return, // upstream closed
                            }
                        }
                        match joined {
                            Some(t) => t,
                            None => return,
                        }
                    };
                    debug_assert_eq!(token.id, frame);

                    // compute: modeled latency (+drift +noise), optionally
                    // slept — the same cost_drift charge the simulator
                    // applies, so live streams see the drifting-cost
                    // scenario families too
                    let content = app.model.content(frame);
                    let workers = app.model.requested_workers(stage, &token.knobs);
                    let base = app.model.stage_latency(stage, &token.knobs, &content, workers)
                        * app.model.cost_drift(stage, frame);
                    let lat = noise.apply(base, &mut rng);
                    sleep_scaled(lat, cfg2.realtime_scale);
                    let _ = evt_tx.send(Evt::StageLat { frame, stage, lat });
                    let out = Token {
                        id: token.id,
                        vt: token.vt + lat,
                        knobs: token.knobs,
                        epoch: token.epoch,
                    };

                    if is_sink {
                        let _ = evt_tx.send(Evt::Done {
                            frame,
                            vt: out.vt,
                            knobs: Arc::clone(&out.knobs),
                            epoch: out.epoch,
                        });
                    }
                    for tx in &outputs {
                        if tx.send(out.clone()).is_err() {
                            return;
                        }
                    }
                }
            })
            // detlint: allow(unwrap) — OS thread-spawn failure is resource exhaustion — fatal by design
            .expect("spawn stage thread");
    }
    drop(evt_tx);

    // assembler: joins per-stage latencies + sink completions into records
    let app2 = Arc::clone(&app);
    let frames = cfg.frames;
    let (stats_tx, stats_rx) = channel::<EngineStats>();
    thread::Builder::new()
        .name("assembler".into())
        .spawn(move || {
            use std::collections::BTreeMap;
            let n_stages = app2.graph.len();
            let mut lat_acc: BTreeMap<usize, Vec<f64>> = BTreeMap::new();
            let mut lat_count: BTreeMap<usize, usize> = BTreeMap::new();
            let mut done: BTreeMap<usize, (f64, Arc<Vec<f64>>, usize)> = BTreeMap::new();
            let mut emitted = 0usize;
            let mut stats = EngineStats { frames: 0, latency: Histogram::new() };
            'pump: while let Ok(evt) = evt_rx.recv() {
                match evt {
                    Evt::StageLat { frame, stage, lat } => {
                        lat_acc.entry(frame).or_insert_with(|| vec![0.0; n_stages])[stage] =
                            lat;
                        *lat_count.entry(frame).or_insert(0) += 1;
                    }
                    Evt::Done { frame, vt, knobs, epoch } => {
                        done.insert(frame, (vt, knobs, epoch));
                    }
                }
                // emit in frame order once complete
                while let (Some(&count), Some((vt, ks, epoch))) =
                    (lat_count.get(&emitted), done.get(&emitted))
                {
                    if count < n_stages {
                        break;
                    }
                    // detlint: allow(unwrap) — entry exists: the stage-count check above only passes after every stage inserted
                    let stage_ms = lat_acc.remove(&emitted).unwrap();
                    let content = app2.model.content(emitted);
                    let fidelity = app2.model.fidelity(ks, &content);
                    let rec = FrameRecord {
                        frame: emitted,
                        end_to_end_ms: *vt,
                        stage_ms,
                        fidelity,
                        knobs: ks.as_ref().clone(),
                        epoch: *epoch,
                    };
                    lat_count.remove(&emitted);
                    done.remove(&emitted);
                    stats.frames += 1;
                    stats.latency.record(rec.end_to_end_ms);
                    if rec_tx.send(rec).is_err() {
                        break 'pump;
                    }
                    emitted += 1;
                    if emitted == frames {
                        break 'pump;
                    }
                }
            }
            let _ = stats_tx.send(stats);
        })
        // detlint: allow(unwrap) — OS thread-spawn failure is resource exhaustion — fatal by design
        .expect("spawn assembler");

    StreamHandle { records: rec_rx, knobs, pause, plan, stats_rx }
}

/// Run a stream to completion, collecting all records (convenience for
/// tests and non-interactive use).
pub fn run_stream_blocking(app: Arc<App>, knobs: Vec<f64>, cfg: EngineConfig) -> Vec<FrameRecord> {
    let frames = cfg.frames;
    let handle = spawn_stream(app, knobs, cfg);
    let mut out = Vec::with_capacity(frames);
    while let Ok(rec) = handle.records.recv() {
        out.push(rec);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::dataflow::critical_path;

    fn app(name: &str) -> Arc<App> {
        Arc::new(app_by_name(name, find_spec_dir(None).unwrap()).unwrap())
    }

    #[test]
    fn stream_delivers_all_frames_in_order() {
        let a = app("pose");
        let ks = a.spec.defaults();
        let recs = run_stream_blocking(
            Arc::clone(&a),
            ks,
            EngineConfig { frames: 50, ..Default::default() },
        );
        assert_eq!(recs.len(), 50);
        for (i, r) in recs.iter().enumerate() {
            assert_eq!(r.frame, i);
            assert_eq!(r.stage_ms.len(), a.graph.len());
            assert!(r.end_to_end_ms > 0.0);
            assert_eq!(r.epoch, 0, "stamping off must stamp epoch 0");
        }
    }

    #[test]
    fn scheduled_knobs_switch_at_exact_frame_indices() {
        // with a knob plan, "which knobs did frame f run under" is a pure
        // function of the schedule — no retune/emission race
        let a = app("pose");
        let slow = a.spec.defaults();
        let fast = vec![3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let handle = spawn_stream(
            Arc::clone(&a),
            slow.clone(),
            EngineConfig { frames: 30, knob_horizon: Some(10), ..Default::default() },
        );
        let sched = handle.schedule_handle().expect("scheduled stream");
        assert_eq!(sched.horizon(), 10);
        sched.extend(10, fast.clone(), 30);
        let mut recs = Vec::new();
        while let Ok(r) = handle.records.recv() {
            recs.push(r);
        }
        assert_eq!(recs.len(), 30);
        for r in &recs {
            let want = if r.frame < 10 { &slow } else { &fast };
            assert_eq!(&r.knobs, want, "frame {}", r.frame);
        }
    }

    #[test]
    fn scheduled_source_blocks_at_the_horizon_until_extended() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 12, knob_horizon: Some(4), ..Default::default() },
        );
        let sched = handle.schedule_handle().unwrap();
        for want in 0..4 {
            let r = handle.records.recv().unwrap();
            assert_eq!(r.frame, want);
        }
        // frames past the horizon are undecided: nothing may arrive
        assert!(
            handle.records.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "a frame ran past the undecided horizon"
        );
        sched.extend(4, a.spec.defaults(), 12);
        let rest: Vec<_> = handle.records.iter().collect();
        assert_eq!(rest.len(), 8, "extension must release the source");
    }

    #[test]
    fn unscheduled_streams_have_no_schedule_handle() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 1, ..Default::default() },
        );
        assert!(handle.schedule_handle().is_none());
        let _ = handle.records.iter().count();
    }

    #[test]
    fn epoch_stamps_advance_by_count_and_fast_forward_on_resume_at() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig {
                frames: 20,
                epoch_frames: 5,
                start_paused: true,
                ..Default::default()
            },
        );
        let pause = handle.pause_handle();
        // fast-forward the clock before any frame is emitted: the
        // re-admission path — stamps start at the handed epoch, then
        // advance every epoch_frames frames
        pause.resume_at(3);
        let mut recs = Vec::new();
        while let Ok(r) = handle.records.recv() {
            recs.push(r);
        }
        assert_eq!(recs.len(), 20);
        for r in &recs {
            assert_eq!(r.epoch, 3 + r.frame / 5, "frame {}", r.frame);
        }
    }

    #[test]
    fn virtual_time_is_critical_path() {
        let a = app("motion_sift");
        let ks = a.spec.defaults();
        let recs = run_stream_blocking(
            Arc::clone(&a),
            ks,
            EngineConfig { frames: 20, ..Default::default() },
        );
        for r in &recs {
            let cp = critical_path(&a.graph, &r.stage_ms);
            assert!(
                (r.end_to_end_ms - cp).abs() < 1e-6,
                "vt {} != critical path {cp}",
                r.end_to_end_ms
            );
        }
    }

    #[test]
    fn retuning_applies_to_later_frames() {
        let a = app("pose");
        let cfg = EngineConfig { frames: 60, realtime_scale: 1e-6, ..Default::default() };
        let handle = spawn_stream(Arc::clone(&a), a.spec.defaults(), cfg);
        let fast = vec![3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let mut records = Vec::new();
        let mut switched = false;
        while let Ok(rec) = handle.records.recv() {
            if rec.frame == 10 && !switched {
                handle.set_knobs(fast.clone());
                switched = true;
            }
            records.push(rec);
        }
        assert_eq!(records.len(), 60);
        // some later frame must run under the fast knobs
        assert!(records.iter().any(|r| r.knobs == fast));
        let early: f64 =
            records[..10].iter().map(|r| r.end_to_end_ms).sum::<f64>() / 10.0;
        let late: f64 =
            records[50..].iter().map(|r| r.end_to_end_ms).sum::<f64>() / 10.0;
        assert!(late < early * 0.5, "retune must speed the pipe: {early} -> {late}");
    }

    #[test]
    fn knob_handle_retunes_from_another_thread() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 40, realtime_scale: 1e-6, ..Default::default() },
        );
        let knobs = handle.knob_handle();
        assert_eq!(knobs.get(), a.spec.defaults());
        let fast = vec![3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let setter = {
            let knobs = knobs.clone();
            let fast = fast.clone();
            std::thread::spawn(move || knobs.set(fast))
        };
        setter.join().unwrap();
        assert_eq!(knobs.get(), fast);
        assert_eq!(handle.current_knobs(), fast);
        let mut n = 0;
        while handle.records.recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 40);
    }

    #[test]
    fn pause_gates_the_source_and_resume_loses_nothing() {
        let a = app("pose");
        let handle = spawn_stream(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 30, start_paused: true, ..Default::default() },
        );
        let pause = handle.pause_handle();
        assert!(pause.paused());
        // the closed gate lets no frame enter the pipeline at all
        assert!(
            handle.records.recv_timeout(std::time::Duration::from_millis(100)).is_err(),
            "a frame leaked through a closed source gate"
        );
        pause.resume();
        assert!(!pause.paused());
        let mut n = 0;
        while handle.records.recv().is_ok() {
            n += 1;
        }
        assert_eq!(n, 30, "deferred frames must all arrive after resume");
    }

    #[test]
    fn no_frame_lost_under_tiny_queues() {
        let a = app("motion_sift");
        let recs = run_stream_blocking(
            Arc::clone(&a),
            a.spec.defaults(),
            EngineConfig { frames: 40, queue_capacity: 1, ..Default::default() },
        );
        assert_eq!(recs.len(), 40);
    }

    #[test]
    fn knob_latch_is_per_frame_consistent() {
        // every record's knob vector must be one of the two configs set,
        // never a mix
        let a = app("motion_sift");
        let slow = a.spec.defaults();
        let fast = vec![4.0, 4.0, 1.0, 8.0, 8.0];
        let handle = spawn_stream(
            Arc::clone(&a),
            slow.clone(),
            EngineConfig { frames: 40, realtime_scale: 1e-6, ..Default::default() },
        );
        let mut recs = Vec::new();
        while let Ok(rec) = handle.records.recv() {
            if rec.frame == 5 {
                handle.set_knobs(fast.clone());
            }
            recs.push(rec);
        }
        for r in &recs {
            assert!(r.knobs == slow || r.knobs == fast, "mixed knobs {:?}", r.knobs);
        }
    }

    #[test]
    fn stream_stats_track_every_emitted_frame() {
        let a = app("pose");
        let ks = a.spec.defaults();
        let handle = spawn_stream(
            Arc::clone(&a),
            ks,
            EngineConfig { frames: 25, ..Default::default() },
        );
        let mut max_e2e: f64 = 0.0;
        while let Ok(rec) = handle.records.recv() {
            max_e2e = max_e2e.max(rec.end_to_end_ms);
        }
        let stats = handle.stats().expect("assembler reports stats");
        assert_eq!(stats.frames, 25);
        assert_eq!(stats.latency.count(), 25);
        assert_eq!(stats.latency.max_ms(), Some(max_e2e));
        let p50 = stats.latency.quantile(0.5).unwrap();
        let p99 = stats.latency.quantile(0.99).unwrap();
        assert!(p50 > 0.0 && p50 <= p99 && p99 <= max_e2e);
    }
}
