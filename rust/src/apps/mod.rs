//! The paper's two case-study applications (Sec. 2.1), rebuilt as
//! analytic cost + fidelity models over the same data-flow graphs and
//! tunable-parameter tables.
//!
//! The original evaluation ran real vision code (SIFT + RANSAC pose
//! registration; MotionSIFT + SVM gesture recognition) on a 15-node
//! cluster. Neither the applications nor the testbed are available, so —
//! per the substitution ledger in DESIGN.md §1 — each stage's latency is
//! modeled as a smooth nonlinear function of the knobs and the scene
//! content, with Amdahl-style data-parallel speedup and per-worker
//! dispatch overhead. The *learning problem* the tuner faces (predict
//! stage latencies from knob settings, online, under drift) is preserved.

pub mod content;
pub mod motion_sift;
pub mod pose;
pub mod registry;
pub mod spec;

pub use content::Content;
pub use spec::AppSpec;

use crate::dataflow::Graph;

/// Per-stage cost + fidelity model of one application.
pub trait CostModel: Send + Sync {
    /// Deterministic content stream (scene script) — frame index to scene
    /// content. Drives data-dependent costs (paper Sec. 2.2) and the
    /// Fig. 6 non-stationarity.
    fn content(&self, frame: usize) -> Content;

    /// Noiseless latency (ms) of one execution of `stage` under raw knob
    /// vector `ks`, given `workers` granted data-parallel workers.
    fn stage_latency(&self, stage: usize, ks: &[f64], content: &Content, workers: usize) -> f64;

    /// Data-parallel workers *requested* by `stage` under `ks` (1 for
    /// sequential stages).
    fn requested_workers(&self, stage: usize, ks: &[f64]) -> usize;

    /// Index of the knob that sets `stage`'s data-parallel worker count,
    /// if any. The scheduler uses this to clamp a candidate's parallelism
    /// to what a hypothetical core quota would actually grant, so the
    /// learned latency model can be queried at k cores without
    /// re-exploring (models without parallel knobs are budget-insensitive
    /// and may keep the default).
    fn par_knob(&self, _stage: usize) -> Option<usize> {
        None
    }

    /// Slow cost-coefficient drift multiplier for `stage` at `frame` —
    /// the `--drift` scenario family (a bounded per-stage random walk in
    /// generated workloads). The simulator and the streaming engine
    /// multiply every stage latency by this; the default of exactly 1.0
    /// leaves every historical model and trace byte-identical. Drift is
    /// cost-only: fidelity never reads it (parallel to paper Sec. 2.2's
    /// latency/fidelity separation).
    fn cost_drift(&self, _stage: usize, _frame: usize) -> f64 {
        1.0
    }

    /// Noiseless fidelity r(x, k) ∈ [0, 1] (paper Eq. 10 / Eq. 11).
    fn fidelity(&self, ks: &[f64], content: &Content) -> f64;
}

/// An application: spec + graph + cost model.
pub struct App {
    pub spec: AppSpec,
    pub graph: Graph,
    pub model: Box<dyn CostModel>,
}

impl App {
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Noiseless per-stage latencies for one frame (the simulator adds
    /// noise and core contention on top).
    pub fn stage_latencies(&self, ks: &[f64], content: &Content) -> Vec<f64> {
        (0..self.graph.len())
            .map(|s| {
                let w = self.model.requested_workers(s, ks);
                self.model.stage_latency(s, ks, content, w)
            })
            .collect()
    }
}

/// Amdahl-style data-parallel execution time: a serial fraction, a
/// perfectly dividable fraction, and a per-worker dispatch overhead that
/// makes over-parallelization *hurt* (the U-shape the tuner must learn).
pub fn amdahl(t: f64, workers: usize, serial_frac: f64, per_worker_ov: f64) -> f64 {
    let p = workers.max(1) as f64;
    t * (serial_frac + (1.0 - serial_frac) / p) + per_worker_ov * (p - 1.0)
}

/// Pixel fraction remaining after proportional down-scaling by factor `s`
/// (s = 1 keeps the full frame; s = 10 keeps 1% of the pixels).
pub fn pixel_fraction(s: f64) -> f64 {
    1.0 / (s * s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amdahl_monotone_then_overhead() {
        let t1 = amdahl(100.0, 1, 0.1, 0.1);
        let t8 = amdahl(100.0, 8, 0.1, 0.1);
        let t96 = amdahl(100.0, 96, 0.1, 0.5);
        assert!((t1 - 100.0).abs() < 1e-9);
        assert!(t8 < t1);
        // with enough per-worker overhead, 96 workers is worse than 8
        assert!(t96 > amdahl(100.0, 8, 0.1, 0.5));
    }

    #[test]
    fn amdahl_serial_floor() {
        // even with unbounded parallelism the serial fraction remains
        let t = amdahl(100.0, 10_000, 0.25, 0.0);
        assert!(t >= 25.0);
    }

    #[test]
    fn pixel_fraction_bounds() {
        assert_eq!(pixel_fraction(1.0), 1.0);
        assert!((pixel_fraction(10.0) - 0.01).abs() < 1e-12);
    }
}
