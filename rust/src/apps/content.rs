//! Synthetic scene content streams — the stand-in for the paper's
//! annotated video sequences (Sec. 4.1).
//!
//! Content drives the data-dependent part of stage costs (paper Sec. 2.2:
//! "application performance may be data-dependent, and for this reason
//! may change over time"). The pose stream reproduces the documented
//! non-stationarity of Fig. 6: "the increase in the pose detection
//! dataset at frame 600 corresponds to a change in the scene, in which a
//! notebook appeared", which "increased the number of SIFT features".

/// Scene content for one frame (fields unused by an app stay at their
/// defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct Content {
    /// Number of SIFT(-like) interest points in the full-resolution frame.
    pub features: f64,
    /// Objects of interest in the scene (pose app).
    pub objects: usize,
    /// Faces visible (MotionSIFT app).
    pub faces: usize,
    /// Is a control gesture being performed this frame (MotionSIFT app)?
    pub gesture: bool,
    /// Monotone scene-segment id (bumps at scripted scene changes).
    pub scene_id: usize,
}

impl Default for Content {
    fn default() -> Self {
        Content { features: 500.0, objects: 1, faces: 1, gesture: false, scene_id: 0 }
    }
}

/// The pose-detection scene script: one object, slow feature-count
/// oscillation from object motion, and a notebook entering at frame 600
/// (+~75% SIFT features, second object).
pub fn pose_content(frame: usize) -> Content {
    let t = frame as f64;
    let wobble = 40.0 * (t / 37.0).sin() + 25.0 * (t / 11.0).cos();
    let (base, objects, scene_id) = if frame >= 600 {
        (1000.0, 2, 1) // notebook appeared (paper Fig. 2 / Sec. 4.2)
    } else {
        (570.0, 1, 0)
    };
    Content {
        features: (base + wobble).max(50.0),
        objects,
        faces: 0,
        gesture: false,
        scene_id,
    }
}

/// The TV-control scene script: a single viewer (paper Fig. 3), gestures
/// performed in bursts (~20-frame gestures every ~90 frames), moderate
/// motion-energy wobble.
pub fn motion_sift_content(frame: usize) -> Content {
    let t = frame as f64;
    let gesture = (frame % 90) < 20;
    let motion_boost = if gesture { 140.0 } else { 0.0 };
    let wobble = 30.0 * (t / 23.0).sin();
    Content {
        features: (430.0 + motion_boost + wobble).max(50.0),
        objects: 0,
        faces: 1,
        gesture,
        scene_id: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pose_scene_change_at_600() {
        let before = pose_content(599);
        let after = pose_content(600);
        assert_eq!(before.scene_id, 0);
        assert_eq!(after.scene_id, 1);
        assert!(after.features > before.features * 1.4);
        assert_eq!(after.objects, 2);
    }

    #[test]
    fn pose_content_deterministic() {
        assert_eq!(pose_content(123), pose_content(123));
    }

    #[test]
    fn pose_features_positive_and_bounded() {
        for f in 0..1000 {
            let c = pose_content(f);
            assert!(c.features > 0.0 && c.features < 1200.0);
        }
    }

    #[test]
    fn motion_sift_gesture_schedule() {
        assert!(motion_sift_content(5).gesture);
        assert!(!motion_sift_content(45).gesture);
        // gestures raise motion feature count
        assert!(motion_sift_content(5).features > motion_sift_content(45).features);
    }

    #[test]
    fn gesture_duty_cycle_reasonable() {
        let on = (0..900).filter(|&f| motion_sift_content(f).gesture).count();
        // ~22% of frames contain a gesture
        assert!(on > 150 && on < 300, "{on}");
    }
}
