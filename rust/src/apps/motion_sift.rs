//! Gesture-based TV control application model (paper Fig. 4, Table 2;
//! Chen et al. 2010): source → copy → { face detection ‖ motion-SIFT
//! extraction } → feature filter/aggregate → SVM classify.
//!
//! Calibration targets: the default configuration costs ~260 ms
//! end-to-end (vs the 100 ms responsive-UI bound); the critical path is
//! max(face branch, motion branch), so the tuner must learn *which*
//! branch dominates where in knob space — the structured predictor's
//! Eq. 9 case study.

use super::content::{motion_sift_content, Content};
use super::{amdahl, pixel_fraction, CostModel};

/// Stage indices (topological, matching `specs/motion_sift.json`).
pub const SOURCE: usize = 0;
pub const COPY: usize = 1;
pub const FACE_SCALE: usize = 2;
pub const FACE_DETECT: usize = 3;
pub const MOTION_SCALE: usize = 4;
pub const PAIR_ACCUM: usize = 5;
pub const MOTION_EXTRACT: usize = 6;
pub const FILTER_AGG: usize = 7;
pub const CLASSIFY: usize = 8;
pub const SINK: usize = 9;

/// Knob indices (Table 2).
pub const K_SCALE_FACE: usize = 0;
pub const K_SCALE_MOTION: usize = 1;
pub const K_FACE_QUALITY: usize = 2;
pub const K_PAR_EXTRACT: usize = 3;
pub const K_PAR_FACE: usize = 4;

/// Global cost scale calibrating the simulated testbed so the 100 ms
/// responsive-UI bound splits the random action space (paper Fig. 5
/// right: costs ~0.1–0.75 s with the bound near the fast edge).
const COST_SCALE: f64 = 2.5;

pub struct MotionSiftModel;

impl MotionSiftModel {
    /// Motion-SIFT features extracted at motion-branch scale `s`.
    fn motion_features(content: &Content, s: f64) -> f64 {
        content.features / s.powf(1.3)
    }

    /// K3 semantics: 0 = highest quality (default, slower), 1 = fast/low.
    fn high_quality(ks: &[f64]) -> bool {
        ks[K_FACE_QUALITY].round() < 0.5
    }
}

impl CostModel for MotionSiftModel {
    fn content(&self, frame: usize) -> Content {
        motion_sift_content(frame)
    }

    fn requested_workers(&self, stage: usize, ks: &[f64]) -> usize {
        match stage {
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            FACE_DETECT => ks[K_PAR_FACE].round().max(1.0) as usize,
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            MOTION_EXTRACT => ks[K_PAR_EXTRACT].round().max(1.0) as usize,
            _ => 1,
        }
    }

    fn par_knob(&self, stage: usize) -> Option<usize> {
        match stage {
            FACE_DETECT => Some(K_PAR_FACE),
            MOTION_EXTRACT => Some(K_PAR_EXTRACT),
            _ => None,
        }
    }

    fn stage_latency(&self, stage: usize, ks: &[f64], content: &Content, workers: usize) -> f64 {
        let s_face = ks[K_SCALE_FACE].max(1.0);
        let s_motion = ks[K_SCALE_MOTION].max(1.0);
        COST_SCALE * match stage {
            SOURCE => 0.6,
            COPY => 1.0,
            FACE_SCALE => 0.8 + 0.6 * pixel_fraction(s_face),
            // cascade detector: cost ∝ pixels, higher quality = more
            // cascade stages + finer sliding-window stride
            FACE_DETECT => {
                let quality = if Self::high_quality(ks) { 2.0 } else { 1.0 };
                let base = 4.0 + 125.0 * pixel_fraction(s_face) * quality
                    + 1.5 * content.faces as f64;
                amdahl(base, workers, 0.07, 0.16)
            }
            MOTION_SCALE => 0.8 + 0.6 * pixel_fraction(s_motion),
            PAIR_ACCUM => 1.2,
            // optical-flow SIFT over frame pairs: pixel term + per-feature
            // descriptor term, data-parallel over tiles
            MOTION_EXTRACT => {
                let base = 6.0
                    + 145.0 * pixel_fraction(s_motion)
                    + 0.055 * Self::motion_features(content, s_motion);
                amdahl(base, workers, 0.06, 0.16)
            }
            FILTER_AGG => 2.5,
            CLASSIFY => 4.0, // fixed SVM bank over the histogram
            SINK => 0.5,
            _ => panic!("motion_sift: unknown stage {stage}"),
        }
    }

    /// Paper Eq. 11: r = F1 = 2PR/(P+R). Precision suffers from low-
    /// quality face gating (false positives leak through); recall suffers
    /// from scaling either branch (missed gestures / missed faces).
    fn fidelity(&self, ks: &[f64], _content: &Content) -> f64 {
        let s_face = ks[K_SCALE_FACE].max(1.0);
        let s_motion = ks[K_SCALE_MOTION].max(1.0);
        let hq = Self::high_quality(ks);
        let precision = 0.95
            * if hq { 1.0 } else { 0.86 }
            * (-0.022 * (s_face - 1.0)).exp();
        let recall = 0.93
            * (-0.055 * (s_motion - 1.0)).exp()
            * (-0.020 * (s_face - 1.0)).exp()
            * if hq { 1.0 } else { 0.97 };
        2.0 * precision * recall / (precision + recall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::spec::{find_spec_dir, AppSpec};
    use crate::dataflow::{critical_path, Graph};

    fn spec() -> AppSpec {
        AppSpec::load_named("motion_sift", find_spec_dir(None).unwrap()).unwrap()
    }

    fn e2e(ks: &[f64], frame: usize) -> f64 {
        let m = MotionSiftModel;
        let c = m.content(frame);
        let g = Graph::from_spec(&spec());
        let w: Vec<f64> = (0..g.len())
            .map(|st| m.stage_latency(st, ks, &c, m.requested_workers(st, ks)))
            .collect();
        critical_path(&g, &w)
    }

    #[test]
    fn default_config_exceeds_100ms() {
        let lat = e2e(&spec().defaults(), 100);
        assert!(lat > 150.0, "default latency {lat}");
    }

    #[test]
    fn tuned_config_meets_100ms() {
        let ks = [2.0, 2.5, 1.0, 8.0, 8.0];
        let lat = e2e(&ks, 100);
        assert!(lat < 100.0, "tuned latency {lat}");
        let m = MotionSiftModel;
        assert!(m.fidelity(&ks, &m.content(100)) > 0.6);
    }

    #[test]
    fn default_fidelity_high() {
        let m = MotionSiftModel;
        let f = m.fidelity(&spec().defaults(), &m.content(0));
        assert!(f > 0.9, "default F1 {f}");
    }

    #[test]
    fn critical_path_is_max_of_branches() {
        // cripple only the motion branch: e2e should track it
        let fast_face = e2e(&[10.0, 1.0, 1.0, 1.0, 96.0], 100);
        let fast_motion = e2e(&[1.0, 10.0, 0.0, 96.0, 1.0], 100);
        let both_fast = e2e(&[10.0, 10.0, 1.0, 8.0, 8.0], 100);
        assert!(both_fast < fast_face.min(fast_motion));
    }

    #[test]
    fn quality_knob_trades_cost_for_precision() {
        let m = MotionSiftModel;
        let c = m.content(0);
        let hq = [1.0, 1.0, 0.0, 1.0, 1.0];
        let lq = [1.0, 1.0, 1.0, 1.0, 1.0];
        let t_hq = m.stage_latency(FACE_DETECT, &hq, &c, 1);
        let t_lq = m.stage_latency(FACE_DETECT, &lq, &c, 1);
        assert!(t_hq > t_lq * 1.3);
        assert!(m.fidelity(&hq, &c) > m.fidelity(&lq, &c));
    }

    #[test]
    fn branch_scaling_only_hits_its_branch() {
        let m = MotionSiftModel;
        let c = m.content(0);
        let base = [1.0, 1.0, 0.0, 1.0, 1.0];
        let scaled_motion = [1.0, 8.0, 0.0, 1.0, 1.0];
        assert_eq!(
            m.stage_latency(FACE_DETECT, &base, &c, 1),
            m.stage_latency(FACE_DETECT, &scaled_motion, &c, 1)
        );
        assert!(
            m.stage_latency(MOTION_EXTRACT, &scaled_motion, &c, 1)
                < m.stage_latency(MOTION_EXTRACT, &base, &c, 1) * 0.3
        );
    }

    #[test]
    fn gesture_frames_cost_more_motion_extraction() {
        let m = MotionSiftModel;
        let ks = spec().defaults();
        let on = m.stage_latency(MOTION_EXTRACT, &ks, &m.content(5), 1);
        let off = m.stage_latency(MOTION_EXTRACT, &ks, &m.content(45), 1);
        assert!(on > off);
    }

    #[test]
    fn fidelity_in_unit_interval_across_grid() {
        let m = MotionSiftModel;
        let c = m.content(0);
        for sf in [1.0, 5.0, 10.0] {
            for sm in [1.0, 5.0, 10.0] {
                for q in [0.0, 1.0] {
                    let f = m.fidelity(&[sf, sm, q, 4.0, 4.0], &c);
                    assert!((0.0..=1.0).contains(&f));
                }
            }
        }
    }
}
