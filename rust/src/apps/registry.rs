//! Application registry: name → [`App`] construction.

use anyhow::{bail, Result};
use std::path::Path;

use super::motion_sift::MotionSiftModel;
use super::pose::PoseModel;
use super::spec::AppSpec;
use super::App;
use crate::dataflow::Graph;

/// Canonical application names.
pub const APP_NAMES: [&str; 2] = ["pose", "motion_sift"];

/// Parse a procedural-workload name: `gen:SEED` (or `gen_SEED`, so the
/// CLI-friendly `gen-SEED` also works after hyphen canonicalization).
fn parse_generated(canonical: &str) -> Option<u64> {
    let rest = canonical
        .strip_prefix("gen:")
        .or_else(|| canonical.strip_prefix("gen_"))?;
    rest.parse::<u64>().ok()
}

/// Parse a general-DAG workload name: `gen-dag:SEED` (canonicalized to
/// `gen_dag:SEED`; `gen_dag_SEED` also accepted).
fn parse_generated_dag(canonical: &str) -> Option<u64> {
    let rest = canonical
        .strip_prefix("gen_dag:")
        .or_else(|| canonical.strip_prefix("gen_dag_"))?;
    rest.parse::<u64>().ok()
}

/// Construct an application by name, loading its spec from `spec_dir`:
/// `pose` / `motion_sift` (hyphens are accepted for CLI friendliness), or
/// `gen:SEED` / `gen-dag:SEED` for a procedurally generated pipeline
/// (`workloads` module; no spec file involved — the spec is synthesized
/// from the seed; the `-dag` family emits general DAGs with multi-level
/// fan-out and skip connections).
pub fn app_by_name(name: &str, spec_dir: impl AsRef<Path>) -> Result<App> {
    let canonical = name.replace('-', "_");
    if let Some(seed) = parse_generated_dag(&canonical) {
        let cfg = crate::workloads::WorkloadConfig {
            dag: Some(crate::workloads::DagConfig::default()),
            ..Default::default()
        };
        return Ok(crate::workloads::generate(seed, &cfg));
    }
    if let Some(seed) = parse_generated(&canonical) {
        return Ok(crate::workloads::generate(
            seed,
            &crate::workloads::WorkloadConfig::default(),
        ));
    }
    let spec = AppSpec::load_named(&canonical, spec_dir)?;
    let graph = Graph::from_spec(&spec);
    let model: Box<dyn super::CostModel> = match canonical.as_str() {
        "pose" => Box::new(PoseModel),
        "motion_sift" => Box::new(MotionSiftModel),
        _ => bail!("unknown app {name} (expected one of {APP_NAMES:?} or gen:SEED)"),
    };
    Ok(App { spec, graph, model })
}

/// All registered applications.
pub fn all_apps(spec_dir: impl AsRef<Path>) -> Result<Vec<App>> {
    APP_NAMES
        .iter()
        .map(|n| app_by_name(n, spec_dir.as_ref()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn both_apps_construct() {
        let dir = find_spec_dir(None).unwrap();
        for name in APP_NAMES {
            let app = app_by_name(name, &dir).unwrap();
            assert_eq!(app.graph.len(), app.spec.stages.len());
        }
    }

    #[test]
    fn hyphenated_name_accepted() {
        let dir = find_spec_dir(None).unwrap();
        assert!(app_by_name("motion-sift", &dir).is_ok());
    }

    #[test]
    fn unknown_name_rejected() {
        let dir = find_spec_dir(None).unwrap();
        assert!(app_by_name("nope", &dir).is_err());
        // malformed generated names fall through to the spec path and fail
        assert!(app_by_name("gen:abc", &dir).is_err());
    }

    #[test]
    fn generated_names_resolve() {
        let dir = find_spec_dir(None).unwrap();
        for name in ["gen:5", "gen_5", "gen-5"] {
            let app = app_by_name(name, &dir).unwrap();
            assert_eq!(app.spec.name, "gen5");
            assert_eq!(app.graph.len(), app.spec.stages.len());
        }
        // different seeds give different pipelines under the same scheme
        let a = app_by_name("gen:1", &dir).unwrap();
        let b = app_by_name("gen:2", &dir).unwrap();
        assert_eq!(a.spec.name, "gen1");
        assert_eq!(b.spec.name, "gen2");
    }

    #[test]
    fn generated_dag_names_resolve() {
        let dir = find_spec_dir(None).unwrap();
        for name in ["gen-dag:5", "gen_dag:5", "gen_dag_5"] {
            let app = app_by_name(name, &dir).unwrap();
            assert_eq!(app.spec.name, "gendag5");
            assert_eq!(app.graph.len(), app.spec.stages.len());
            assert!(
                app.spec.groups.iter().all(|g| g.deps.is_some()),
                "gen-dag specs must declare the group DAG"
            );
        }
        // a distinct family from the series-parallel generator
        let sp = app_by_name("gen:5", &dir).unwrap();
        assert_eq!(sp.spec.name, "gen5");
        assert!(sp.spec.groups.iter().all(|g| g.deps.is_none()));
        // malformed seeds still fall through to the spec path and fail
        assert!(app_by_name("gen-dag:abc", &dir).is_err());
    }

    #[test]
    fn stage_latencies_align_with_graph() {
        let dir = find_spec_dir(None).unwrap();
        for app in all_apps(&dir).unwrap() {
            let ks = app.spec.defaults();
            let content = app.model.content(0);
            let lats = app.stage_latencies(&ks, &content);
            assert_eq!(lats.len(), app.graph.len());
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }
}
