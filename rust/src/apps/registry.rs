//! Application registry: name → [`App`] construction.

use anyhow::{bail, Result};
use std::path::Path;

use super::motion_sift::MotionSiftModel;
use super::pose::PoseModel;
use super::spec::AppSpec;
use super::App;
use crate::dataflow::Graph;

/// Canonical application names.
pub const APP_NAMES: [&str; 2] = ["pose", "motion_sift"];

/// Construct an application by name (`pose` / `motion_sift`; hyphens are
/// accepted for CLI friendliness), loading its spec from `spec_dir`.
pub fn app_by_name(name: &str, spec_dir: impl AsRef<Path>) -> Result<App> {
    let canonical = name.replace('-', "_");
    let spec = AppSpec::load_named(&canonical, spec_dir)?;
    let graph = Graph::from_spec(&spec);
    let model: Box<dyn super::CostModel> = match canonical.as_str() {
        "pose" => Box::new(PoseModel),
        "motion_sift" => Box::new(MotionSiftModel),
        _ => bail!("unknown app {name} (expected one of {APP_NAMES:?})"),
    };
    Ok(App { spec, graph, model })
}

/// All registered applications.
pub fn all_apps(spec_dir: impl AsRef<Path>) -> Result<Vec<App>> {
    APP_NAMES
        .iter()
        .map(|n| app_by_name(n, spec_dir.as_ref()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn both_apps_construct() {
        let dir = find_spec_dir(None).unwrap();
        for name in APP_NAMES {
            let app = app_by_name(name, &dir).unwrap();
            assert_eq!(app.graph.len(), app.spec.stages.len());
        }
    }

    #[test]
    fn hyphenated_name_accepted() {
        let dir = find_spec_dir(None).unwrap();
        assert!(app_by_name("motion-sift", &dir).is_ok());
    }

    #[test]
    fn unknown_name_rejected() {
        let dir = find_spec_dir(None).unwrap();
        assert!(app_by_name("nope", &dir).is_err());
    }

    #[test]
    fn stage_latencies_align_with_graph() {
        let dir = find_spec_dir(None).unwrap();
        for app in all_apps(&dir).unwrap() {
            let ks = app.spec.defaults();
            let content = app.model.content(0);
            let lats = app.stage_latencies(&ks, &content);
            assert_eq!(lats.len(), app.graph.len());
            assert!(lats.iter().all(|&l| l > 0.0));
        }
    }
}
