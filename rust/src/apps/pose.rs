//! Pose-detection application model (paper Fig. 1, Table 1; Collet et
//! al. 2009): scaler → SIFT → model matching → clustering → RANSAC pose.
//!
//! Calibration targets (derived from the paper's setting): the default
//! configuration (no scaling, unbounded features, no parallelism)
//! maximizes fidelity and costs ~350 ms end-to-end on the simulated
//! testbed — far above the 50 ms visual-servoing bound — while aggressive
//! scaling + parallelism reaches ~25 ms at reduced fidelity, so the 50 ms
//! constraint is *feasible but tight*, as in the paper's Fig. 5 (left).

use super::content::{pose_content, Content};
use super::{amdahl, pixel_fraction, CostModel};

/// Stage indices (topological, matching `specs/pose.json`).
pub const SOURCE: usize = 0;
pub const SCALER: usize = 1;
pub const SIFT: usize = 2;
pub const MATCH: usize = 3;
pub const CLUSTER: usize = 4;
pub const RANSAC: usize = 5;
pub const SINK: usize = 6;

/// Knob indices (Table 1).
pub const K_SCALE: usize = 0;
pub const K_THRESHOLD: usize = 1;
pub const K_PAR_SIFT: usize = 2;
pub const K_PAR_MATCH: usize = 3;
pub const K_PAR_CLUSTER: usize = 4;

/// Number of 3D object models matched against (paper: "a set of
/// previously constructed 3D models").
const NUM_MODELS: f64 = 6.0;

/// Global cost scale calibrating the simulated testbed so the 50 ms
/// visual-servoing bound splits the random action space (paper Fig. 5
/// left: costs ~0.05–0.75 s with the bound at the fast edge).
const COST_SCALE: f64 = 1.5;

pub struct PoseModel;

impl PoseModel {
    /// SIFT features surviving the down-scaler at scale factor `s`.
    fn extracted(content: &Content, s: f64) -> f64 {
        // interest points die off a bit slower than pixel count
        content.features / s.powf(1.4)
    }

    /// Features surviving the K2 threshold.
    fn used(content: &Content, ks: &[f64]) -> f64 {
        Self::extracted(content, ks[K_SCALE]).min(ks[K_THRESHOLD])
    }
}

impl CostModel for PoseModel {
    fn content(&self, frame: usize) -> Content {
        pose_content(frame)
    }

    fn requested_workers(&self, stage: usize, ks: &[f64]) -> usize {
        match stage {
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            SIFT => ks[K_PAR_SIFT].round().max(1.0) as usize,
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            MATCH => ks[K_PAR_MATCH].round().max(1.0) as usize,
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            CLUSTER => ks[K_PAR_CLUSTER].round().max(1.0) as usize,
            _ => 1,
        }
    }

    fn par_knob(&self, stage: usize) -> Option<usize> {
        match stage {
            SIFT => Some(K_PAR_SIFT),
            MATCH => Some(K_PAR_MATCH),
            CLUSTER => Some(K_PAR_CLUSTER),
            _ => None,
        }
    }

    fn stage_latency(&self, stage: usize, ks: &[f64], content: &Content, workers: usize) -> f64 {
        let s = ks[K_SCALE].max(1.0);
        let px = pixel_fraction(s);
        let n_ext = Self::extracted(content, s);
        let n_used = Self::used(content, ks);
        COST_SCALE * match stage {
            SOURCE => 0.8,
            // proportional down-scaler reads the full frame
            SCALER => 1.0 + 0.9 * (0.35 + 0.65 * px),
            // dense interest-point detection + descriptors: pixel term +
            // per-feature descriptor term, data-parallel over tiles
            SIFT => amdahl(6.0 + 150.0 * px + 0.10 * n_ext, workers, 0.08, 0.18),
            // descriptor matching against NUM_MODELS model databases,
            // data-parallel over models/features
            MATCH => amdahl(4.0 + 0.028 * n_used * NUM_MODELS, workers, 0.10, 0.09),
            // position clustering of matched features
            CLUSTER => amdahl(2.0 + 0.065 * n_used, workers, 0.12, 0.12),
            // RANSAC + nonlinear 6D pose refinement per instance
            RANSAC => 3.0 + 1.6 * content.objects as f64 + 0.008 * n_used,
            SINK => 0.5,
            _ => panic!("pose: unknown stage {stage}"),
        }
    }

    /// Paper Eq. 10: r = (1/n) Σ_i R_i exp(-(wτ·τ_i + wθ·θ_i)) with
    /// wτ = 0.7, wθ = 0.3. Recognition probability and pose errors are
    /// analytic functions of feature budget and scaling.
    fn fidelity(&self, ks: &[f64], content: &Content) -> f64 {
        let s = ks[K_SCALE].max(1.0);
        let n_used = Self::used(content, ks);
        // fraction of the feature budget the matcher needs for reliable
        // recognition (~35% of the scene's native features)
        let feat_quality = (n_used / (0.35 * content.features)).min(1.0);
        let scale_penalty = (-0.06 * (s - 1.0)).exp();
        let p_rec = (0.98 * feat_quality.powf(0.7) * scale_penalty).clamp(0.0, 1.0);
        // translation/rotation errors grow as resolution and features drop
        let tau = 0.10 + 0.35 * (s - 1.0) / 9.0 + 0.30 * (1.0 - feat_quality);
        let theta = 0.08 + 0.30 * (s - 1.0) / 9.0 + 0.22 * (1.0 - feat_quality);
        p_rec * (-(0.7 * tau + 0.3 * theta)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::spec::{find_spec_dir, AppSpec};

    fn spec() -> AppSpec {
        AppSpec::load_named("pose", find_spec_dir(None).unwrap()).unwrap()
    }

    fn e2e(ks: &[f64], frame: usize) -> f64 {
        let m = PoseModel;
        let c = m.content(frame);
        (0..=SINK)
            .map(|st| m.stage_latency(st, ks, &c, m.requested_workers(st, ks)))
            .sum()
    }

    #[test]
    fn default_config_is_slow_and_high_fidelity() {
        let s = spec();
        let ks = s.defaults();
        let m = PoseModel;
        let c = m.content(100);
        let lat = e2e(&ks, 100);
        assert!(lat > 250.0, "default latency {lat} should dwarf the 50 ms bound");
        assert!(m.fidelity(&ks, &c) > 0.85);
    }

    #[test]
    fn tuned_config_meets_50ms() {
        // scaling 3x + parallelism: the kind of operating point the
        // controller should find under L = 50 ms
        let ks = [3.0, 2.0_f64.powi(31), 16.0, 10.0, 10.0];
        let lat = e2e(&ks, 100);
        assert!(lat < 50.0, "tuned latency {lat}");
        let m = PoseModel;
        let f = m.fidelity(&ks, &m.content(100));
        assert!(f > 0.4, "tuned fidelity {f} should stay useful");
    }

    #[test]
    fn fidelity_monotone_in_scale() {
        let m = PoseModel;
        let c = m.content(0);
        let mut prev = f64::INFINITY;
        for s in [1.0, 2.0, 4.0, 8.0, 10.0] {
            let f = m.fidelity(&[s, 1e9, 1.0, 1.0, 1.0], &c);
            assert!(f < prev);
            prev = f;
        }
    }

    #[test]
    fn fidelity_degrades_with_tight_threshold() {
        let m = PoseModel;
        let c = m.content(0);
        let loose = m.fidelity(&[1.0, 1e9, 1.0, 1.0, 1.0], &c);
        let tight = m.fidelity(&[1.0, 50.0, 1.0, 1.0, 1.0], &c);
        assert!(tight < loose * 0.75, "tight {tight} loose {loose}");
    }

    #[test]
    fn parallelism_does_not_affect_fidelity() {
        // paper Sec. 2.2: "the degree of parallelism ... generally does
        // not affect fidelity"
        let m = PoseModel;
        let c = m.content(0);
        let f1 = m.fidelity(&[2.0, 500.0, 1.0, 1.0, 1.0], &c);
        let f2 = m.fidelity(&[2.0, 500.0, 96.0, 10.0, 10.0], &c);
        assert_eq!(f1, f2);
    }

    #[test]
    fn sift_parallelism_u_shape() {
        let m = PoseModel;
        let c = m.content(0);
        let ks = |p: f64| [1.0, 1e9, p, 1.0, 1.0];
        let t1 = m.stage_latency(SIFT, &ks(1.0), &c, 1);
        let t16 = m.stage_latency(SIFT, &ks(16.0), &c, 16);
        let t96 = m.stage_latency(SIFT, &ks(96.0), &c, 96);
        assert!(t16 < t1 * 0.3);
        assert!(t96 > t16, "over-parallelization must cost: {t96} vs {t16}");
    }

    #[test]
    fn scene_change_increases_sift_cost() {
        let m = PoseModel;
        let ks = spec().defaults();
        let before = m.stage_latency(SIFT, &ks, &m.content(599), 1);
        let after = m.stage_latency(SIFT, &ks, &m.content(600), 1);
        assert!(after > before * 1.15, "frame-600 jump: {before} -> {after}");
    }

    #[test]
    fn threshold_caps_match_cost() {
        let m = PoseModel;
        let c = m.content(0);
        let uncapped = m.stage_latency(MATCH, &[1.0, 1e9, 1.0, 1.0, 1.0], &c, 1);
        let capped = m.stage_latency(MATCH, &[1.0, 100.0, 1.0, 1.0, 1.0], &c, 1);
        assert!(capped < uncapped * 0.5);
    }
}
