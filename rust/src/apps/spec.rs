//! Application specs: the tunable-parameter tables (paper Tables 1–2),
//! the data-flow graphs (paper Figures 1 and 4) and the structured-learner
//! group decomposition (paper Sec. 2.3), parsed from the shared
//! `specs/*.json` files that the Python AOT pipeline reads too.

use anyhow::{bail, Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// One tunable knob (a row of paper Table 1 or 2).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    /// Paper symbol, e.g. `K3`.
    pub symbol: String,
    /// `"continuous"` or `"discrete"`.
    pub kind: String,
    pub min: f64,
    pub max: f64,
    pub default: f64,
    /// Normalize on a log scale (wide ranges: feature threshold, 1–96
    /// parallelism).
    pub log: bool,
    pub description: String,
}

impl ParamSpec {
    pub fn is_discrete(&self) -> bool {
        self.kind == "discrete"
    }

    /// Map a raw knob value into `[0, 1]` (log scale where flagged).
    pub fn normalize(&self, k: f64) -> f64 {
        if self.log {
            let (lo, hi) = (self.min.ln(), self.max.ln());
            (k.max(self.min).ln() - lo) / (hi - lo)
        } else {
            (k - self.min) / (self.max - self.min)
        }
    }

    /// Inverse of [`normalize`](Self::normalize); discrete knobs round to
    /// the nearest integer and every result is clamped to the range.
    pub fn denormalize(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        let raw = if self.log {
            let (lo, hi) = (self.min.ln(), self.max.ln());
            (lo + u * (hi - lo)).exp()
        } else {
            self.min + u * (self.max - self.min)
        };
        let raw = raw.clamp(self.min, self.max);
        if self.is_discrete() {
            raw.round().clamp(self.min, self.max)
        } else {
            raw
        }
    }
}

/// A vertex of the data-flow graph.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    /// Names of upstream stages (connectors point dep -> this stage).
    pub deps: Vec<String>,
    /// Does this stage contribute enough latency to get its own learned
    /// model (paper Sec. 2.3)? Non-critical stages use a moving average.
    pub critical: bool,
    /// Indices (into `params`) of the knobs that affect this stage.
    pub params: Vec<usize>,
}

/// A structured-learner group: a critical stage-set plus the knob subset
/// that the dependency analysis associates with it (paper Sec. 2.3/3.3).
#[derive(Debug, Clone)]
pub struct GroupSpec {
    pub name: String,
    pub stages: Vec<String>,
    pub params: Vec<usize>,
    /// `None` for sequential groups (their predictions are summed);
    /// `Some(b)` assigns the group to parallel branch `b` (branch sums
    /// are combined with `max` — paper Eq. 9).
    pub branch: Option<usize>,
    /// Group-level topology for general-DAG specs: names of the upstream
    /// groups whose outputs this group consumes. `None` (legacy specs and
    /// the series-parallel generator) keeps the historical sum/max
    /// combine rule; `Some` — on *every* group of the spec — switches the
    /// structured predictor to a weighted critical path over the group
    /// DAG (entry groups carry `Some(vec![])`). In the JSON schema this
    /// is the optional `"deps"` array.
    pub deps: Option<Vec<String>>,
}

/// A full application spec (the tuple (G, K, L) of paper Sec. 3).
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub name: String,
    pub title: String,
    pub description: String,
    /// Latency bounds L evaluated in the paper's Fig. 8 (ms).
    pub latency_bounds_ms: Vec<f64>,
    pub frame_interval_ms: f64,
    pub trace_frames: usize,
    pub trace_configs: usize,
    pub params: Vec<ParamSpec>,
    pub stages: Vec<StageSpec>,
    pub groups: Vec<GroupSpec>,
    /// Polynomial degree of the cubic predictor (3 in the paper).
    pub degree: usize,
    /// Padded candidate-batch size of the AOT artifacts.
    pub candidate_pad: usize,
    /// Padded monomial-feature size of the AOT artifacts.
    pub feature_pad: usize,
}

impl AppSpec {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading spec {}", path.display()))?;
        let json = Json::parse(&text)
            .with_context(|| format!("parsing spec {}", path.display()))?;
        let spec = Self::from_json(&json)
            .with_context(|| format!("decoding spec {}", path.display()))?;
        spec.validate()?;
        Ok(spec)
    }

    /// Decode from the shared JSON schema (`specs/*.json`).
    pub fn from_json(v: &Json) -> Result<Self> {
        let params = v
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str()?.to_string(),
                    symbol: p.req("symbol")?.as_str()?.to_string(),
                    kind: p.req("kind")?.as_str()?.to_string(),
                    min: p.req("min")?.as_f64()?,
                    max: p.req("max")?.as_f64()?,
                    default: p.req("default")?.as_f64()?,
                    log: p.req("log")?.as_bool()?,
                    description: p.req("description")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let stages = v
            .req("stages")?
            .as_arr()?
            .iter()
            .map(|s| {
                Ok(StageSpec {
                    name: s.req("name")?.as_str()?.to_string(),
                    deps: s.req("deps")?.as_str_vec()?,
                    critical: s.req("critical")?.as_bool()?,
                    params: s.req("params")?.as_usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let groups = v
            .req("groups")?
            .as_arr()?
            .iter()
            .map(|g| {
                let branch = match g.req("branch")? {
                    Json::Null => None,
                    b => Some(b.as_usize()?),
                };
                let deps = match g.get("deps") {
                    None | Some(Json::Null) => None,
                    Some(d) => Some(d.as_str_vec()?),
                };
                Ok(GroupSpec {
                    name: g.req("name")?.as_str()?.to_string(),
                    stages: g.req("stages")?.as_str_vec()?,
                    params: g.req("params")?.as_usize_vec()?,
                    branch,
                    deps,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(AppSpec {
            name: v.req("name")?.as_str()?.to_string(),
            title: v.req("title")?.as_str()?.to_string(),
            description: v.req("description")?.as_str()?.to_string(),
            latency_bounds_ms: v.req("latency_bounds_ms")?.as_f64_vec()?,
            frame_interval_ms: v.req("frame_interval_ms")?.as_f64()?,
            trace_frames: v.req("trace_frames")?.as_usize()?,
            trace_configs: v.req("trace_configs")?.as_usize()?,
            params,
            stages,
            groups,
            degree: v.req("degree")?.as_usize()?,
            candidate_pad: v.req("candidate_pad")?.as_usize()?,
            feature_pad: v.req("feature_pad")?.as_usize()?,
        })
    }

    /// Load `specs/{name}.json` under the given directory.
    pub fn load_named(name: &str, spec_dir: impl AsRef<Path>) -> Result<Self> {
        Self::load(spec_dir.as_ref().join(format!("{name}.json")))
    }

    pub fn num_vars(&self) -> usize {
        self.params.len()
    }

    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Sorted distinct branch ids among the groups (may be empty).
    pub fn branches(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.groups.iter().filter_map(|g| g.branch).collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    pub fn stage_index(&self, name: &str) -> Option<usize> {
        self.stages.iter().position(|s| s.name == name)
    }

    /// Normalize a raw knob vector into `[0,1]^m`.
    pub fn normalize(&self, ks: &[f64]) -> Vec<f64> {
        assert_eq!(ks.len(), self.params.len());
        self.params
            .iter()
            .zip(ks)
            .map(|(p, &k)| p.normalize(k))
            .collect()
    }

    /// Denormalize `[0,1]^m` into a valid raw knob vector.
    pub fn denormalize(&self, us: &[f64]) -> Vec<f64> {
        assert_eq!(us.len(), self.params.len());
        self.params
            .iter()
            .zip(us)
            .map(|(p, &u)| p.denormalize(u))
            .collect()
    }

    /// The paper's default configuration (maximizes fidelity, ignores
    /// latency).
    pub fn defaults(&self) -> Vec<f64> {
        self.params.iter().map(|p| p.default).collect()
    }

    /// Structural validation: non-empty tables, sane ranges, topological
    /// stages, resolvable group references, and full knob coverage by the
    /// groups. Public so generated specs (`workloads`) can be checked with
    /// the exact same rules as the JSON-loaded ones.
    pub fn validate(&self) -> Result<()> {
        if self.params.is_empty() || self.stages.is_empty() {
            bail!("spec {}: empty params or stages", self.name);
        }
        for p in &self.params {
            if !(p.min < p.max) || p.default < p.min || p.default > p.max {
                bail!("spec {}: bad range for {}", self.name, p.symbol);
            }
            if p.log && p.min <= 0.0 {
                bail!("spec {}: log scale needs positive min ({})", self.name, p.symbol);
            }
        }
        // stages listed in topological order, deps resolve, DAG by construction
        let mut seen = std::collections::BTreeSet::new();
        for s in &self.stages {
            for d in &s.deps {
                if !seen.contains(d.as_str()) {
                    bail!("spec {}: stage {} dep {} not defined earlier", self.name, s.name, d);
                }
            }
            if !seen.insert(s.name.as_str()) {
                bail!("spec {}: duplicate stage {}", self.name, s.name);
            }
            for &pi in &s.params {
                if pi >= self.params.len() {
                    bail!("spec {}: stage {} param index {} out of range", self.name, s.name, pi);
                }
            }
        }
        for g in &self.groups {
            for st in &g.stages {
                if self.stage_index(st).is_none() {
                    bail!("spec {}: group {} references unknown stage {}", self.name, g.name, st);
                }
            }
            for &pi in &g.params {
                if pi >= self.params.len() {
                    bail!("spec {}: group {} param index {} out of range", self.name, g.name, pi);
                }
            }
        }
        // group-level DAG topology: all-or-nothing, deps resolve to groups
        // declared earlier (topological order, so the graph is acyclic by
        // construction — same rule as the stage table)
        let dag_groups = self.groups.iter().filter(|g| g.deps.is_some()).count();
        if dag_groups > 0 {
            if dag_groups != self.groups.len() {
                bail!(
                    "spec {}: {} of {} groups declare DAG deps — the group \
                     topology must be all-or-nothing",
                    self.name,
                    dag_groups,
                    self.groups.len()
                );
            }
            let mut seen_groups = std::collections::BTreeSet::new();
            for g in &self.groups {
                for d in g.deps.as_deref().unwrap_or(&[]) {
                    if !seen_groups.contains(d.as_str()) {
                        bail!(
                            "spec {}: group {} dep {} not defined earlier",
                            self.name,
                            g.name,
                            d
                        );
                    }
                }
                if !seen_groups.insert(g.name.as_str()) {
                    bail!("spec {}: duplicate group {}", self.name, g.name);
                }
            }
        }
        // every knob owned by some group, else the structured solver is blind to it
        let owned: std::collections::BTreeSet<usize> =
            self.groups.iter().flat_map(|g| g.params.iter().copied()).collect();
        if owned.len() != self.params.len() {
            bail!("spec {}: some knobs not covered by any group", self.name);
        }
        Ok(())
    }
}

/// Locate the repo's `specs/` directory: explicit arg, `$IPTUNE_SPECS`, or
/// walking up from the current dir / executable (so tests, examples and
/// installed binaries all find it).
pub fn find_spec_dir(explicit: Option<&Path>) -> Result<std::path::PathBuf> {
    if let Some(p) = explicit {
        if p.is_dir() {
            return Ok(p.to_path_buf());
        }
        bail!("spec dir {} not found", p.display());
    }
    if let Ok(env) = std::env::var("IPTUNE_SPECS") {
        let p = std::path::PathBuf::from(env);
        if p.is_dir() {
            return Ok(p);
        }
    }
    let mut candidates: Vec<std::path::PathBuf> = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        candidates.push(cwd);
    }
    if let Ok(exe) = std::env::current_exe() {
        candidates.push(exe);
    }
    candidates.push(std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    for start in candidates {
        let mut cur: Option<&Path> = Some(start.as_path());
        while let Some(dir) = cur {
            let specs = dir.join("specs");
            if specs.join("pose.json").is_file() {
                return Ok(specs);
            }
            cur = dir.parent();
        }
    }
    bail!("could not locate specs/ (set IPTUNE_SPECS)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_dir() -> std::path::PathBuf {
        find_spec_dir(None).unwrap()
    }

    #[test]
    fn both_specs_load_and_validate() {
        for name in ["pose", "motion_sift"] {
            let s = AppSpec::load_named(name, spec_dir()).unwrap();
            assert_eq!(s.num_vars(), 5);
            assert_eq!(s.degree, 3);
        }
    }

    #[test]
    fn table1_pose_rows() {
        let s = AppSpec::load_named("pose", spec_dir()).unwrap();
        let syms: Vec<&str> = s.params.iter().map(|p| p.symbol.as_str()).collect();
        assert_eq!(syms, ["K1", "K2", "K3", "K4", "K5"]);
        assert_eq!(s.params[0].kind, "continuous");
        assert_eq!((s.params[0].min, s.params[0].max), (1.0, 10.0));
        assert_eq!(s.params[1].max, 2147483648.0);
        assert_eq!(s.params[1].default, 2147483648.0);
        assert_eq!((s.params[2].min, s.params[2].max), (1.0, 96.0));
        assert_eq!((s.params[3].min, s.params[3].max), (1.0, 10.0));
        assert_eq!((s.params[4].min, s.params[4].max), (1.0, 10.0));
    }

    #[test]
    fn table2_motion_sift_rows() {
        let s = AppSpec::load_named("motion_sift", spec_dir()).unwrap();
        assert_eq!(s.params[2].kind, "discrete");
        assert_eq!((s.params[2].min, s.params[2].max), (0.0, 1.0));
        for i in [3usize, 4] {
            assert_eq!((s.params[i].min, s.params[i].max), (1.0, 96.0));
            assert_eq!(s.params[i].default, 1.0);
        }
    }

    #[test]
    fn normalize_roundtrip() {
        for name in ["pose", "motion_sift"] {
            let s = AppSpec::load_named(name, spec_dir()).unwrap();
            for p in &s.params {
                assert!((p.normalize(p.min) - 0.0).abs() < 1e-12);
                assert!((p.normalize(p.max) - 1.0).abs() < 1e-12);
                for u in [0.0, 0.25, 0.5, 0.75, 1.0] {
                    let k = p.denormalize(u);
                    assert!(k >= p.min && k <= p.max);
                    if !p.is_discrete() {
                        assert!((p.normalize(k) - u).abs() < 1e-9, "{} u={}", p.symbol, u);
                    }
                }
            }
        }
    }

    #[test]
    fn discrete_denormalize_rounds() {
        let s = AppSpec::load_named("pose", spec_dir()).unwrap();
        let k3 = &s.params[2];
        let k = k3.denormalize(0.5);
        assert_eq!(k, k.round());
        assert!(k >= 1.0 && k <= 96.0);
    }

    #[test]
    fn branches_detected() {
        let s = AppSpec::load_named("motion_sift", spec_dir()).unwrap();
        assert_eq!(s.branches(), vec![0, 1]);
        let p = AppSpec::load_named("pose", spec_dir()).unwrap();
        assert!(p.branches().is_empty());
    }

    #[test]
    fn defaults_are_fidelity_maximizing_corner() {
        // Paper: default values maximize fidelity (no scaling, no feature
        // cap, no parallelism-induced reordering).
        let s = AppSpec::load_named("pose", spec_dir()).unwrap();
        assert_eq!(s.defaults()[0], 1.0);
        assert_eq!(s.defaults()[1], 2147483648.0);
    }

    #[test]
    fn bad_spec_rejected() {
        let mut s = AppSpec::load_named("pose", spec_dir()).unwrap();
        s.params[0].min = 100.0; // min > max
        assert!(s.validate().is_err());
    }

    #[test]
    fn group_dag_deps_validated() {
        let mut s = AppSpec::load_named("pose", spec_dir()).unwrap();
        // JSON specs without "deps" stay legacy
        assert!(s.groups.iter().all(|g| g.deps.is_none()));
        // the group topology is all-or-nothing
        s.groups[0].deps = Some(vec![]);
        assert!(s.validate().is_err(), "mixed deps must be rejected");
        for g in &mut s.groups {
            g.deps = Some(vec![]);
        }
        s.validate().unwrap();
        // a chain over the declared order is fine
        for i in 1..s.groups.len() {
            let prev = s.groups[i - 1].name.clone();
            s.groups[i].deps = Some(vec![prev]);
        }
        s.validate().unwrap();
        // forward references are rejected (topological order required)
        let last = s.groups.last().unwrap().name.clone();
        s.groups[0].deps = Some(vec![last]);
        assert!(s.validate().is_err(), "forward dep must be rejected");
    }

    #[test]
    fn group_coverage_enforced() {
        let mut s = AppSpec::load_named("pose", spec_dir()).unwrap();
        s.groups.pop();
        // dropping the ransac group still leaves all knobs covered? K2 is
        // shared; removing a group must only fail if coverage breaks.
        let owned: std::collections::BTreeSet<usize> =
            s.groups.iter().flat_map(|g| g.params.iter().copied()).collect();
        assert_eq!(s.validate().is_ok(), owned.len() == s.params.len());
    }
}
