//! The analytic cost + fidelity model backing a generated pipeline.
//!
//! Mirrors the hand-written [`pose`](crate::apps::pose) /
//! [`motion_sift`](crate::apps::motion_sift) models, but every coefficient
//! is drawn (seeded) at generation time: per-stage polynomial costs in the
//! scene content and the knob-derived quantities (pixel fraction, capped
//! feature count), Amdahl-style data-parallel speedup with per-worker
//! dispatch overhead, and a fidelity model composed of one multiplicative
//! factor per knob (parallelism knobs contribute none — paper Sec. 2.2).

use crate::apps::content::Content;
use crate::apps::{amdahl, pixel_fraction, CostModel};

/// How a generated knob enters the cost and fidelity models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    /// Frame down-scaling for one segment (continuous 1–10, default 1).
    Scale,
    /// Cap on the features a segment forwards (continuous, log, default
    /// = max, i.e. effectively uncapped).
    Threshold,
    /// Data-parallel worker count for one stage (discrete 1–32, log).
    Parallel,
    /// Quality toggle for one stage: 0 = high quality (default, slower),
    /// 1 = fast low-quality mode.
    Quality,
}

/// One knob's role: which segment (and, for stage-targeted kinds, which
/// stage) it acts on, plus its fidelity-model coefficients.
#[derive(Debug, Clone)]
pub struct KnobRole {
    pub kind: KnobKind,
    /// Segment the knob acts on (0 = prefix, 1..=B branches, B+1 suffix).
    pub segment: usize,
    /// Target stage (global index) for `Parallel` / `Quality` knobs.
    pub stage: Option<usize>,
    /// Scale: decay rate a in exp(-a(s-1)). Threshold: exponent p of the
    /// feature-quality factor. Quality: the fast-mode fidelity penalty
    /// multiplier. Parallel: unused.
    pub fidelity_coef: f64,
    /// Threshold only: fraction of the scene's native features the
    /// downstream consumer needs for full quality.
    pub need_frac: f64,
}

/// Knob lookup for one segment of the generated graph.
#[derive(Debug, Clone, Default)]
pub struct SegmentKnobs {
    pub scale: Option<usize>,
    pub threshold: Option<usize>,
}

/// Per-stage polynomial cost coefficients.
#[derive(Debug, Clone)]
pub struct StageCost {
    pub segment: usize,
    /// Constant term (ms).
    pub base: f64,
    /// Weight on the segment's pixel fraction.
    pub px: f64,
    /// Weight on features_used / 100.
    pub feat: f64,
    /// Weight on (features_used / 100)^2 — the nonlinearity the cubic
    /// predictor must pick up.
    pub feat2: f64,
    /// Knob index granting data-parallel workers, if any.
    pub par_knob: Option<usize>,
    /// Knob index toggling the quality mode, if any.
    pub quality_knob: Option<usize>,
    /// Cost multiplier while in high-quality mode (> 1).
    pub quality_mult: f64,
    pub serial_frac: f64,
    pub per_worker_ov: f64,
}

/// Deterministic scene script of a generated app: baseline feature count,
/// two wobble harmonics, and one scripted scene change (the Fig. 6-style
/// non-stationarity every generated workload carries).
#[derive(Debug, Clone)]
pub struct ContentScript {
    pub base_features: f64,
    pub amp1: f64,
    pub per1: f64,
    pub amp2: f64,
    pub per2: f64,
    pub change_frame: usize,
    pub change_mult: f64,
}

impl ContentScript {
    pub fn content(&self, frame: usize) -> Content {
        let t = frame as f64;
        let (mult, objects, scene_id) = if frame >= self.change_frame {
            (self.change_mult, 2, 1)
        } else {
            (1.0, 1, 0)
        };
        let wobble = self.amp1 * (t / self.per1).sin() + self.amp2 * (t / self.per2).cos();
        Content {
            features: (self.base_features * mult + wobble).max(50.0),
            objects,
            faces: 0,
            gesture: false,
            scene_id,
        }
    }
}

/// Feature-survival exponent under down-scaling (interest points die off
/// a little slower than pixel count — same shape as the two case studies).
pub const FEATURE_DECAY: f64 = 1.35;

/// Slow per-stage cost-coefficient drift: one bounded random walk per
/// stage, *streamed incrementally* — the model stays a pure
/// (deterministic, `Send + Sync`) function of `(seed, stage, frame)`
/// without precomputing `max(trace_frames, 2048)` frames per stage
/// (ISSUE 6: memory used to scale with fleet size × drift horizon, and
/// long live runs silently hit a frozen tail).
///
/// Walk dynamics: `w[0] = 1`, `w[t+1] = clamp(w[t] + U(-step, step),
/// 1 − bound, 1 + bound)` — the coefficient wanders slowly inside the
/// band instead of jumping the way the scripted scene change does. The
/// two compose: a scene cut moves the *content*, the walk moves the
/// *cost model* (On-line Application Autotuning Exploiting Ensemble
/// Models, PAPERS.md).
///
/// Implementation: the historical generator consumed ONE sequential rng
/// stream, stage by stage, `horizon` draws each. Construction now only
/// *checkpoints* the rng state at each stage's draw offset (O(stages)
/// memory, O(stages × horizon) cheap xoshiro advances); each stage's
/// value table then grows lazily, in chunks, as frames are queried —
/// values below the legacy horizon are byte-identical to the historical
/// precomputed tables. Past the horizon the walk *keeps walking* on a
/// per-stage continuation stream (seeded from `(seed, stage)`, stepping
/// continuously from the legacy end state) instead of freezing at its
/// last value — the frozen-tail fix.
#[derive(Debug)]
pub struct DriftWalk {
    /// Walk amplitude B: every multiplier stays within `[1 − B, 1 + B]`.
    pub bound: f64,
    /// Per-frame step amplitude.
    step: f64,
    /// Legacy horizon: draws below it come from the historical shared
    /// stream (byte-compat); draws past it from the continuation stream.
    horizon: usize,
    /// Lazily grown per-stage walks (interior mutability: `at` is called
    /// through `&self` from concurrent engine/simulator threads).
    stages: Vec<std::sync::RwLock<StageWalk>>,
}

/// One stage's walk state: the values materialized so far plus the rng
/// cursors positioned at the next draw.
#[derive(Debug, Clone)]
struct StageWalk {
    /// Values materialized so far (`vals[frame]`), grown in chunks.
    vals: Vec<f64>,
    /// Next walk value (the one `vals[vals.len()]` would hold).
    w: f64,
    /// Historical shared stream, checkpointed at this stage's offset.
    legacy: crate::util::Rng,
    /// Continuation stream for draws past the legacy horizon.
    cont: crate::util::Rng,
}

/// Chunk granularity of lazy walk growth: big enough to amortize the
/// write-lock, small enough that short live runs stop well before the
/// historical 2048-frame precompute.
const DRIFT_CHUNK: usize = 256;

impl DriftWalk {
    /// Set up `stages` independent walks from `seed` with a legacy
    /// horizon of `frames` (one shared rng stream below the horizon,
    /// stages in order — byte-identical to the historical precomputed
    /// tables; per-stage continuation streams past it). No table is
    /// materialized here.
    pub fn generate(seed: u64, stages: usize, bound: f64, frames: usize, step: f64) -> Self {
        assert!(bound > 0.0 && bound < 1.0, "drift bound must be in (0, 1): {bound}");
        assert!(step > 0.0 && frames >= 1);
        let mut rng = crate::util::Rng::new(seed);
        // continuation streams fork off a separate salted master so the
        // legacy stream's draw positions stay untouched
        let mut cont_master = crate::util::Rng::new(seed ^ 0xC0_17_1A7E_57AB_1E55);
        let stage_walks = (0..stages)
            .map(|_| {
                let legacy = rng.clone();
                for _ in 0..frames {
                    rng.range_f64(-step, step); // advance to the next stage's offset
                }
                let cont = cont_master.fork(0xD21F);
                std::sync::RwLock::new(StageWalk { vals: Vec::new(), w: 1.0, legacy, cont })
            })
            .collect();
        DriftWalk { bound, step, horizon: frames, stages: stage_walks }
    }

    /// The multiplier for `stage` at `frame` — a pure function of
    /// `(seed, stage, frame)` regardless of query order or thread count.
    pub fn at(&self, stage: usize, frame: usize) -> f64 {
        {
            let sw = self.stages[stage].read().unwrap();
            if frame < sw.vals.len() {
                return sw.vals[frame];
            }
        }
        let mut sw = self.stages[stage].write().unwrap();
        let target = (frame / DRIFT_CHUNK + 1) * DRIFT_CHUNK;
        while sw.vals.len() < target {
            let cur = sw.w;
            sw.vals.push(cur);
            // the draw *after* value i comes from the legacy stream for
            // i < horizon (the historical generator consumed exactly
            // `horizon` draws per stage) and the continuation past it
            let i = sw.vals.len() - 1;
            let d = if i < self.horizon {
                sw.legacy.range_f64(-self.step, self.step)
            } else {
                sw.cont.range_f64(-self.step, self.step)
            };
            sw.w = (cur + d).clamp(1.0 - self.bound, 1.0 + self.bound);
        }
        sw.vals[frame]
    }

    /// The legacy horizon (frames drawn from the historical shared
    /// stream before the continuation stream takes over).
    pub fn horizon(&self) -> usize {
        self.horizon
    }
}

impl Clone for DriftWalk {
    fn clone(&self) -> Self {
        DriftWalk {
            bound: self.bound,
            step: self.step,
            horizon: self.horizon,
            stages: self
                .stages
                .iter()
                .map(|s| std::sync::RwLock::new(s.read().unwrap().clone()))
                .collect(),
        }
    }
}

/// The generated cost model: pure data, deterministic, `Send + Sync`.
pub struct GeneratedModel {
    pub script: ContentScript,
    pub roles: Vec<KnobRole>,
    pub segments: Vec<SegmentKnobs>,
    pub stages: Vec<StageCost>,
    pub cost_scale: f64,
    pub base_fidelity: f64,
    /// Optional per-stage cost drift (the `--drift` scenario family).
    pub drift: Option<DriftWalk>,
}

impl GeneratedModel {
    /// Features a segment's consumers see under raw knobs `ks`: the scene
    /// features decayed by the segment's scale, capped by its threshold.
    fn features_used(&self, segment: usize, ks: &[f64], content: &Content) -> f64 {
        let seg = &self.segments[segment];
        let s = seg.scale.map(|k| ks[k].max(1.0)).unwrap_or(1.0);
        let raw = content.features / s.powf(FEATURE_DECAY);
        match seg.threshold {
            Some(k) => raw.min(ks[k]),
            None => raw,
        }
    }
}

impl CostModel for GeneratedModel {
    fn content(&self, frame: usize) -> Content {
        self.script.content(frame)
    }

    fn requested_workers(&self, stage: usize, ks: &[f64]) -> usize {
        match self.stages[stage].par_knob {
            // detlint: allow(lossy-cast) — worker-count knob: round() precedes and the spec bounds it to a small exact integer
            Some(k) => ks[k].round().max(1.0) as usize,
            None => 1,
        }
    }

    fn par_knob(&self, stage: usize) -> Option<usize> {
        self.stages[stage].par_knob
    }

    fn cost_drift(&self, stage: usize, frame: usize) -> f64 {
        match &self.drift {
            Some(d) => d.at(stage, frame),
            None => 1.0,
        }
    }

    fn stage_latency(&self, stage: usize, ks: &[f64], content: &Content, workers: usize) -> f64 {
        let sc = &self.stages[stage];
        let seg = &self.segments[sc.segment];
        let s = seg.scale.map(|k| ks[k].max(1.0)).unwrap_or(1.0);
        let fu = self.features_used(sc.segment, ks, content) / 100.0;
        let mut t = sc.base + sc.px * pixel_fraction(s) + sc.feat * fu + sc.feat2 * fu * fu;
        if let Some(qk) = sc.quality_knob {
            if ks[qk].round() < 0.5 {
                t *= sc.quality_mult;
            }
        }
        if sc.par_knob.is_some() {
            t = amdahl(t, workers, sc.serial_frac, sc.per_worker_ov);
        }
        self.cost_scale * t
    }

    fn fidelity(&self, ks: &[f64], content: &Content) -> f64 {
        let mut r = self.base_fidelity;
        for (k, role) in self.roles.iter().enumerate() {
            match role.kind {
                KnobKind::Scale => {
                    r *= (-role.fidelity_coef * (ks[k].max(1.0) - 1.0)).exp();
                }
                KnobKind::Threshold => {
                    let used = self.features_used(role.segment, ks, content);
                    let q = (used / (role.need_frac * content.features)).min(1.0);
                    r *= q.powf(role.fidelity_coef);
                }
                KnobKind::Parallel => {}
                KnobKind::Quality => {
                    if ks[k].round() >= 0.5 {
                        r *= role.fidelity_coef;
                    }
                }
            }
        }
        r.clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn script() -> ContentScript {
        ContentScript {
            base_features: 500.0,
            amp1: 30.0,
            per1: 17.0,
            amp2: 20.0,
            per2: 41.0,
            change_frame: 400,
            change_mult: 1.5,
        }
    }

    #[test]
    fn content_scene_change() {
        let s = script();
        let before = s.content(399);
        let after = s.content(400);
        assert_eq!(before.scene_id, 0);
        assert_eq!(after.scene_id, 1);
        assert!(after.features > before.features * 1.2);
    }

    #[test]
    fn content_deterministic_and_positive() {
        let s = script();
        for f in 0..1000 {
            let a = s.content(f);
            let b = s.content(f);
            assert_eq!(a, b);
            assert!(a.features >= 50.0);
        }
    }

    #[test]
    fn drift_walk_stays_inside_band_and_moves_slowly() {
        let d = DriftWalk::generate(7, 4, 0.25, 1000, 0.0125);
        assert_eq!(d.horizon(), 1000);
        for s in 0..4 {
            for f in 0..1200 {
                let w = d.at(s, f);
                assert!((0.75..=1.25).contains(&w), "stage {s} frame {f}: {w}");
                if f > 0 {
                    // the per-frame step bound holds across the
                    // legacy/continuation seam at the horizon too
                    let step = (w - d.at(s, f - 1)).abs();
                    assert!(step <= 0.0125 + 1e-12, "stage {s} frame {f} jumped {step}");
                }
            }
            // past the horizon the walk keeps walking (frozen-tail fix)
            // but stays inside the band
            assert!((0.75..=1.25).contains(&d.at(s, 5000)), "stage {s} left the band");
        }
        // deterministic given the seed — below and past the horizon,
        // regardless of query order; stages walk independently
        let e = DriftWalk::generate(7, 4, 0.25, 1000, 0.0125);
        assert_eq!(d.at(2, 5000), e.at(2, 5000));
        assert_eq!(d.at(2, 500), e.at(2, 500));
        assert_ne!(d.at(0, 500), d.at(1, 500));
        // the walk actually goes somewhere (not stuck at 1.0)
        let spread: f64 = (0..4)
            .map(|s| (0..1000).map(|f| (d.at(s, f) - 1.0).abs()).fold(0.0, f64::max))
            .fold(0.0, f64::max);
        assert!(spread > 0.05, "walk never left 1.0: {spread}");
    }

    #[test]
    fn streamed_drift_walk_is_byte_identical_to_the_precomputed_prefix() {
        // the historical generator: ONE sequential rng stream, stages in
        // order, `frames` draws each, tables precomputed eagerly. The
        // streamed walk must reproduce it bit-for-bit below the horizon
        // (recorded fleet thresholds depend on these values).
        let (seed, stages, bound, frames, step) = (99u64, 3usize, 0.2f64, 64usize, 0.01f64);
        let d = DriftWalk::generate(seed, stages, bound, frames, step);
        // query out of order first: laziness must not change values
        let probe = d.at(2, 40);
        let mut rng = crate::util::Rng::new(seed);
        for s in 0..stages {
            let mut w = 1.0f64;
            for f in 0..frames {
                assert_eq!(d.at(s, f), w, "stage {s} frame {f} diverged");
                w = (w + rng.range_f64(-step, step)).clamp(1.0 - bound, 1.0 + bound);
            }
        }
        assert_eq!(probe, d.at(2, 40));
    }

    #[test]
    fn drift_walk_clones_and_shares_across_threads() {
        let d = std::sync::Arc::new(DriftWalk::generate(11, 2, 0.25, 100, 0.01));
        let c = DriftWalk::clone(&d); // deep clone, before any growth
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let d = std::sync::Arc::clone(&d);
                std::thread::spawn(move || {
                    (0..400).map(|f| d.at(t % 2, f)).collect::<Vec<f64>>()
                })
            })
            .collect();
        let got: Vec<Vec<f64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // concurrent growth returns the same pure function of
        // (seed, stage, frame) every thread, matching a fresh clone
        for (t, vals) in got.iter().enumerate() {
            for (f, v) in vals.iter().enumerate() {
                assert_eq!(*v, c.at(t % 2, f), "thread {t} frame {f}");
            }
        }
    }
}
