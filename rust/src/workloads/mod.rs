//! Procedural workload generation — the scenario space behind the repo's
//! scale-out story.
//!
//! The paper demonstrates the tuner on two hand-modeled applications; the
//! north star demands "as many scenarios as you can imagine". This module
//! emits randomized-but-valid perception pipelines from a seed: a
//! series-parallel data-flow graph (sequential prefix → fan-out into 1–3
//! parallel branches → join → sequential suffix), per-stage polynomial
//! cost models, data-parallel stages with Amdahl speedup, knob sets with
//! frame-scaling / window-threshold / parallelism / quality semantics,
//! and a composable fidelity model — packaged as a regular
//! [`App`](crate::apps::App) whose [`AppSpec`] passes the exact same
//! validation as the JSON-loaded case studies. Every existing layer
//! (simulator, traces, engine, learner, tuner, experiments) runs on
//! generated apps unmodified; the registry resolves `gen:SEED` names to
//! this generator.
//!
//! The series-parallel shape was the historical ceiling: it is the
//! largest graph family for which the *legacy* sum/max combination rule
//! (paper Eq. 9) reproduces the weighted critical path exactly. Since the
//! structured predictor learned to combine along an arbitrary group-level
//! DAG (`GroupSpec::deps` + `GroupMap::group_graph`), the generator also
//! emits **general DAGs** — `gen-dag:SEED` names and
//! [`WorkloadConfig::dag`]: layered segment graphs with multi-level
//! fan-out, diamond joins and skip connections (the 3D-vision pipeline
//! shapes of HyperMapper, PAPERS.md) — whose segment decomposition is
//! still exact under the critical-path combine. A second new scenario
//! axis, [`WorkloadConfig::drift`], layers slow per-stage
//! cost-coefficient drift (a bounded random walk, [`DriftWalk`]) on any
//! generated app, composable with the scripted scene-change / thrash /
//! load-drop families.
//!
//! Latency bounds are calibrated per app: a deterministic probe of random
//! configurations on the target cluster picks the bound so that roughly a
//! quarter of the action space is robustly feasible — tight enough that
//! tuning matters, loose enough that an oracle exists (the regime of the
//! paper's Fig. 5).

pub mod model;

pub use model::{
    ContentScript, DriftWalk, GeneratedModel, KnobKind, KnobRole, SegmentKnobs, StageCost,
};

use crate::apps::spec::{AppSpec, GroupSpec, ParamSpec, StageSpec};
use crate::apps::App;
use crate::dataflow::Graph;
use crate::simulator::{Cluster, ClusterSim};
use crate::util::Rng;

/// Resource-appetite profile of a generated app — the lever behind
/// heterogeneous fleets. `Balanced` is byte-identical to the PR-1
/// generator (all multipliers are exactly 1 and no extra rng draws are
/// made); `Light` and `Heavy` skew the same draw stream:
///
/// * `Light` — cheap, **core-insensitive** pipelines: parallelism knobs
///   are never assigned, so latency does not depend on the core quota at
///   all. The scheduler can safely park these at the fairness floor.
/// * `Heavy` — core-hungry pipelines: at least two parallelism knobs are
///   guaranteed, the parallelizable (per-pixel) cost term is inflated,
///   and the Amdahl serial fraction / per-worker overhead are shrunk so
///   the work actually scales. Squeezed at an even cluster share, these
///   apps' best configurations go infeasible — exactly what dynamic
///   reallocation exists to fix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AppProfile {
    #[default]
    Balanced,
    Light,
    Heavy,
}

impl AppProfile {
    fn allows_parallel(self) -> bool {
        !matches!(self, AppProfile::Light)
    }

    fn min_par_knobs(self) -> usize {
        match self {
            AppProfile::Heavy => 2,
            _ => 0,
        }
    }

    fn px_mult(self) -> f64 {
        match self {
            AppProfile::Heavy => 2.5,
            _ => 1.0,
        }
    }

    fn serial_mult(self) -> f64 {
        match self {
            AppProfile::Heavy => 0.35,
            _ => 1.0,
        }
    }

    fn overhead_mult(self) -> f64 {
        match self {
            AppProfile::Heavy => 0.4,
            _ => 1.0,
        }
    }

    fn cost_mult(self) -> f64 {
        match self {
            AppProfile::Light => 0.5,
            AppProfile::Heavy => 1.6,
            AppProfile::Balanced => 1.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AppProfile::Balanced => "balanced",
            AppProfile::Light => "light",
            AppProfile::Heavy => "heavy",
        }
    }

    /// Profile of fleet member `index`: alternating Light/Heavy when the
    /// fleet is heterogeneous, else `base`. The single source of truth
    /// shared by the simulated fleet and the live `schedule` path so the
    /// two can never drift apart on what a scenario means.
    pub fn for_fleet_member(heterogeneous: bool, index: usize, base: AppProfile) -> AppProfile {
        if heterogeneous {
            if index % 2 == 0 {
                AppProfile::Light
            } else {
                AppProfile::Heavy
            }
        } else {
            base
        }
    }
}

/// Generation envelope: topology and knob-count ranges, trace protocol,
/// and bound-calibration policy.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Max parallel branches between fan-out and join (min 1).
    pub max_branches: usize,
    /// Max stages in the sequential prefix (min 1).
    pub max_prefix: usize,
    /// Max stages per branch (min 1).
    pub max_branch_len: usize,
    /// Max stages in the sequential suffix (0 allowed).
    pub max_suffix: usize,
    /// Knob-count range (every branch always gets a scale knob, so the
    /// effective minimum is `max(min_knobs, branches)`).
    pub min_knobs: usize,
    pub max_knobs: usize,
    /// Random configurations probed for bound calibration.
    pub probe_configs: usize,
    /// Quantile of per-config worst-case cost the bound sits at.
    pub feasible_quantile: f64,
    /// Multiplicative slack on top of the quantile cost.
    pub bound_margin: f64,
    /// Trace protocol baked into the generated spec.
    pub trace_configs: usize,
    pub trace_frames: usize,
    /// Resource-appetite profile (heterogeneous fleets mix these).
    pub profile: AppProfile,
    /// Scripted load shift: overrides the content script's scene change
    /// with `(frame, multiplier)` — the fleet uses this to synchronize a
    /// mid-run cost jump across its heavy apps so reallocation has
    /// something to chase. Applied after all draws (rng-neutral).
    pub load_shift: Option<(usize, f64)>,
    /// Adversarial thrash scenario: multiply the content script's wobble
    /// amplitudes by this and shrink its periods by the same factor, so
    /// per-epoch cost samples wobble hard and the learned utility curves
    /// get noisy — the scenario family the scheduler's hysteresis term
    /// is measured against. `None` leaves the drawn script untouched.
    /// Applied after all draws (rng-neutral).
    pub thrash: Option<f64>,
    /// Exact fairness-floor accounting: calibrate latency bounds with the
    /// time-multiplexing multiplier charged on sub-stage-count budgets
    /// ([`crate::simulator::time_multiplex_factor`]), matching what an
    /// admission-controlled fleet replays. Rng-neutral.
    pub exact_accounting: bool,
    /// General-DAG topology (the `gen-dag:SEED` scenario family): `Some`
    /// switches generation from the series-parallel shape to a layered
    /// DAG of stage segments with multi-level fan-out, skip connections
    /// and diamond joins, whose spec declares the group-level graph
    /// (`GroupSpec::deps`) the structured critical-path combine consumes.
    /// `None` (the default) is the historical generator, byte-identical.
    pub dag: Option<DagConfig>,
    /// Slow per-stage cost-coefficient drift (the `--drift` scenario
    /// family): the walk amplitude B — every stage's cost is multiplied
    /// by a per-stage bounded random walk confined to `[1 − B, 1 + B]`
    /// ([`DriftWalk`]; step `B ×` [`DRIFT_STEP_FRAC`] per frame). Drawn
    /// on an rng stream independent of app generation, so enabling drift
    /// never disturbs the topology/knob/script draws (rng-neutral), and
    /// composable with the scripted scene change, thrash and load-drop
    /// families. Bound calibration probes run *with* drift, so bounds
    /// stay honest under it.
    pub drift: Option<f64>,
}

/// Topology envelope of the `gen-dag` family: a layered DAG of stage
/// segments. Each level holds 1..=`max_width` segments of
/// 1..=`max_seg_len` chained stages; every segment past level 0 draws
/// 1–2 parents from the previous level (diamond joins), and with
/// probability `skip_prob` one extra parent from a level at least two
/// below (skip connections). A global source stage feeds level 0 and a
/// global sink joins every childless tail, so the graph keeps one source
/// and one sink like every other app.
#[derive(Debug, Clone)]
pub struct DagConfig {
    /// Max segment levels (min 2, so fan-out always exists).
    pub max_depth: usize,
    /// Max segments per level (min 1).
    pub max_width: usize,
    /// Max stages per segment (min 1).
    pub max_seg_len: usize,
    /// Probability of an extra skip-level parent per eligible segment.
    pub skip_prob: f64,
}

impl Default for DagConfig {
    fn default() -> Self {
        DagConfig { max_depth: 4, max_width: 3, max_seg_len: 2, skip_prob: 0.35 }
    }
}

/// Per-frame step of the drift walk, as a fraction of the walk bound:
/// slow enough that the tuner sees a moving target rather than noise
/// (σ after F frames ≈ `bound × 0.05 × sqrt(F / 3)` until the band
/// clamps engage).
pub const DRIFT_STEP_FRAC: f64 = 0.05;

/// Minimum *legacy* drift-walk horizon (frames): below
/// `max(trace_frames, this)` the streamed walk reproduces the historical
/// precomputed tables byte-for-byte; past it the walk keeps walking on a
/// per-stage continuation stream (ISSUE 6 frozen-tail fix) instead of
/// holding its last value. Nothing is precomputed any more — values
/// materialize lazily as frames are queried ([`DriftWalk`]).
pub const DRIFT_TABLE_FRAMES: usize = 2048;

/// The drift walk for a generated app, on its own seed stream (never
/// perturbs the generation draws).
fn drift_walk_for(seed: u64, n_stages: usize, cfg: &WorkloadConfig) -> Option<DriftWalk> {
    cfg.drift.map(|bound| {
        DriftWalk::generate(
            seed ^ 0xD21F_7A11_0B0B_5EED,
            n_stages,
            bound,
            cfg.trace_frames.max(DRIFT_TABLE_FRAMES),
            bound * DRIFT_STEP_FRAC,
        )
    })
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            max_branches: 3,
            max_prefix: 3,
            max_branch_len: 3,
            max_suffix: 2,
            min_knobs: 3,
            max_knobs: 6,
            probe_configs: 48,
            feasible_quantile: 0.25,
            bound_margin: 1.10,
            trace_configs: 24,
            trace_frames: 500,
            profile: AppProfile::Balanced,
            load_shift: None,
            thrash: None,
            exact_accounting: false,
            dag: None,
            drift: None,
        }
    }
}

/// Segment ids: 0 is the prefix, `1..=branches` the branches,
/// `branches + 1` the suffix.
fn segment_label(segment: usize, branches: usize) -> String {
    if segment == 0 {
        "pre".to_string()
    } else if segment <= branches {
        format!("b{}", segment - 1)
    } else {
        "post".to_string()
    }
}

/// Scripted load-*drop* multiplier — the inverse of the fleet's classic
/// 1.9x load jump: heavy apps' scene content roughly halves at the shift
/// frame. The scenario family epoch-granular admission is measured on
/// (tenants parked under load pressure must be re-admitted once the pool
/// frees up).
pub const LOAD_DROP_MULT: f64 = 0.55;

/// Scenario helper: the scripted load-drop `(frame, multiplier)` pair for
/// [`WorkloadConfig::load_shift`].
pub fn load_drop(frame: usize) -> (usize, f64) {
    (frame, LOAD_DROP_MULT)
}

/// Scenario family: a deterministic mid-run tier shift for a fleet of
/// `apps` — one tenant upgrades to a paying tier (weight 4.0), a different
/// tenant downgrades (0.5), everyone else stays at 1.0. Derived from
/// `seed` alone, on an rng stream independent of app generation, so the
/// same fleet can be replayed with or without the shift.
pub fn tier_shift_weights(seed: u64, apps: usize) -> Vec<f64> {
    assert!(apps >= 2, "a tier shift needs at least two tenants");
    let mut rng = Rng::new(seed ^ 0x7151_5EED);
    let up = rng.below(apps);
    let mut down = rng.below(apps - 1);
    if down >= up {
        down += 1;
    }
    let mut w = vec![1.0; apps];
    w[up] = 4.0;
    w[down] = 0.5;
    w
}

/// Generate a pipeline, calibrating its latency bounds on the default
/// (paper) cluster. Same seed → byte-identical app.
pub fn generate(seed: u64, cfg: &WorkloadConfig) -> App {
    generate_on(seed, cfg, &Cluster::default())
}

/// Generate a pipeline with bounds calibrated for `cluster` — the fleet
/// runner passes each app's slice of the shared cluster here so bounds
/// stay achievable under contention. [`WorkloadConfig::dag`] switches to
/// the general-DAG family ([`generate_dag_on`]).
pub fn generate_on(seed: u64, cfg: &WorkloadConfig, cluster: &Cluster) -> App {
    if cfg.dag.is_some() {
        return generate_dag_on(seed, cfg, cluster);
    }
    assert!(cfg.max_branches >= 1 && cfg.max_prefix >= 1 && cfg.max_branch_len >= 1);
    assert!(cfg.min_knobs >= 1 && cfg.max_knobs >= cfg.min_knobs);
    let mut rng = Rng::new(seed);

    // ---- topology -------------------------------------------------------
    let branches = 1 + rng.below(cfg.max_branches);
    let prefix_len = 1 + rng.below(cfg.max_prefix);
    let branch_lens: Vec<usize> =
        (0..branches).map(|_| 1 + rng.below(cfg.max_branch_len)).collect();
    let suffix_len = rng.below(cfg.max_suffix + 1);
    let n_segments = branches + 2;
    let suffix_seg = branches + 1;

    struct StageDraft {
        names: Vec<String>,
        deps: Vec<Vec<String>>,
        seg_of: Vec<usize>,
        is_heavy: Vec<bool>,
    }
    impl StageDraft {
        fn push(&mut self, name: String, dep: Vec<String>, seg: usize, heavy: bool) {
            self.names.push(name);
            self.deps.push(dep);
            self.seg_of.push(seg);
            self.is_heavy.push(heavy);
        }
        fn last_name(&self) -> String {
            // detlint: allow(unwrap) — names is seeded with the source stage before any accessor runs
            self.names.last().unwrap().clone()
        }
    }
    let mut draft = StageDraft {
        names: Vec::new(),
        deps: Vec::new(),
        seg_of: Vec::new(),
        is_heavy: Vec::new(),
    };

    draft.push("source".into(), vec![], 0, false);
    for i in 0..prefix_len {
        let dep = draft.last_name();
        draft.push(format!("pre{i}"), vec![dep], 0, true);
    }
    let prefix_tail = draft.last_name();
    let mut branch_tails: Vec<String> = Vec::new();
    for (b, &len) in branch_lens.iter().enumerate() {
        for j in 0..len {
            let dep = if j == 0 { prefix_tail.clone() } else { draft.last_name() };
            draft.push(format!("br{b}_{j}"), vec![dep], 1 + b, true);
        }
        branch_tails.push(draft.last_name());
    }
    draft.push("join".into(), branch_tails, suffix_seg, false);
    for i in 0..suffix_len {
        let dep = draft.last_name();
        draft.push(format!("post{i}"), vec![dep], suffix_seg, true);
    }
    let dep = draft.last_name();
    draft.push("sink".into(), vec![dep], suffix_seg, false);
    let StageDraft { names, deps, seg_of, is_heavy } = draft;
    let n_stages = names.len();

    // heavy stages per segment (knob targets)
    let mut seg_heavy: Vec<Vec<usize>> = vec![Vec::new(); n_segments];
    for i in 0..n_stages {
        if is_heavy[i] {
            seg_heavy[seg_of[i]].push(i);
        }
    }

    // ---- knob roles -----------------------------------------------------
    let min_k = cfg.min_knobs.max(branches);
    let max_k = cfg.max_knobs.max(min_k);
    let target_knobs = min_k + rng.below(max_k - min_k + 1);

    let mut roles: Vec<KnobRole> = Vec::new();
    let mut seg_scale: Vec<Option<usize>> = vec![None; n_segments];
    let mut seg_thresh: Vec<Option<usize>> = vec![None; n_segments];
    let mut seg_quality: Vec<Option<usize>> = vec![None; n_segments];
    let mut stage_par: Vec<Option<usize>> = vec![None; n_stages];
    let mut quality_stage: Vec<Option<usize>> = vec![None; n_stages];

    // every branch is scale-tunable — the fidelity/latency trade-off the
    // tuner exists for
    for b in 0..branches {
        let k = roles.len();
        seg_scale[1 + b] = Some(k);
        roles.push(KnobRole {
            kind: KnobKind::Scale,
            segment: 1 + b,
            stage: None,
            fidelity_coef: rng.range_f64(0.03, 0.08),
            need_frac: 0.0,
        });
    }
    // remaining knobs cycle through threshold / parallel / quality kinds,
    // landing on a random segment that still has room for that kind
    let extra_kinds = [KnobKind::Threshold, KnobKind::Parallel, KnobKind::Quality];
    let mut attempt = 0usize;
    while roles.len() < target_knobs && attempt < 24 {
        let kind = extra_kinds[attempt % extra_kinds.len()];
        attempt += 1;
        let eligible: Vec<usize> = (0..n_segments)
            .filter(|&s| !seg_heavy[s].is_empty())
            .filter(|&s| match kind {
                KnobKind::Threshold => seg_thresh[s].is_none(),
                KnobKind::Quality => seg_quality[s].is_none(),
                KnobKind::Parallel => {
                    cfg.profile.allows_parallel()
                        && seg_heavy[s].iter().any(|&st| stage_par[st].is_none())
                }
                KnobKind::Scale => false,
            })
            .collect();
        if eligible.is_empty() {
            continue;
        }
        let s = eligible[rng.below(eligible.len())];
        let k = roles.len();
        match kind {
            KnobKind::Threshold => {
                seg_thresh[s] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: None,
                    fidelity_coef: rng.range_f64(0.4, 0.8),
                    need_frac: rng.range_f64(0.25, 0.45),
                });
            }
            KnobKind::Parallel => {
                let free: Vec<usize> = seg_heavy[s]
                    .iter()
                    .copied()
                    .filter(|&st| stage_par[st].is_none())
                    .collect();
                let st = free[rng.below(free.len())];
                stage_par[st] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: Some(st),
                    fidelity_coef: 0.0,
                    need_frac: 0.0,
                });
            }
            KnobKind::Quality => {
                let st = seg_heavy[s][rng.below(seg_heavy[s].len())];
                seg_quality[s] = Some(k);
                quality_stage[st] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: Some(st),
                    fidelity_coef: rng.range_f64(0.85, 0.95),
                    need_frac: 0.0,
                });
            }
            KnobKind::Scale => unreachable!(),
        }
    }
    // heavy profile: guarantee core-hungry pipelines by force-assigning
    // parallel knobs to heavy stages until the minimum is met (rng-free,
    // deterministic stage order, so earlier draws are untouched)
    let mut par_count = roles.iter().filter(|r| r.kind == KnobKind::Parallel).count();
    if par_count < cfg.profile.min_par_knobs() {
        'outer: for s in 0..n_segments {
            for &st in &seg_heavy[s] {
                if par_count >= cfg.profile.min_par_knobs() {
                    break 'outer;
                }
                if stage_par[st].is_none() {
                    let k = roles.len();
                    stage_par[st] = Some(k);
                    roles.push(KnobRole {
                        kind: KnobKind::Parallel,
                        segment: s,
                        stage: Some(st),
                        fidelity_coef: 0.0,
                        need_frac: 0.0,
                    });
                    par_count += 1;
                }
            }
        }
    }
    let num_knobs = roles.len();

    // ---- per-stage polynomial cost coefficients -------------------------
    let mut stage_costs: Vec<StageCost> = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let (base, px, feat, feat2) = if is_heavy[i] {
            (
                rng.range_f64(0.5, 2.0),
                rng.range_f64(15.0, 80.0) * cfg.profile.px_mult(),
                rng.range_f64(1.0, 6.0),
                rng.range_f64(0.0, 1.2),
            )
        } else {
            (rng.range_f64(0.3, 1.2), 0.0, 0.0, 0.0)
        };
        // drawn unconditionally so the rng stream does not depend on the
        // knob assignment above (profile multipliers are rng-neutral)
        let quality_mult = rng.range_f64(1.5, 2.2);
        let serial_frac = rng.range_f64(0.05, 0.15) * cfg.profile.serial_mult();
        let per_worker_ov = rng.range_f64(0.04, 0.18) * cfg.profile.overhead_mult();
        stage_costs.push(StageCost {
            segment: seg_of[i],
            base,
            px,
            feat,
            feat2,
            par_knob: stage_par[i],
            quality_knob: quality_stage[i],
            quality_mult,
            serial_frac,
            per_worker_ov,
        });
    }

    // ---- content script + global scales ---------------------------------
    let mut script = ContentScript {
        base_features: rng.range_f64(350.0, 750.0),
        amp1: rng.range_f64(20.0, 60.0),
        per1: rng.range_f64(9.0, 45.0),
        amp2: rng.range_f64(10.0, 40.0),
        per2: rng.range_f64(9.0, 45.0),
        change_frame: 300 + rng.below(400),
        change_mult: rng.range_f64(1.2, 1.8),
    };
    let cost_scale = rng.range_f64(0.8, 1.6) * cfg.profile.cost_mult();
    let base_fidelity = rng.range_f64(0.90, 0.98);
    if let Some((frame, mult)) = cfg.load_shift {
        script.change_frame = frame;
        script.change_mult = mult;
    }
    if let Some(t) = cfg.thrash {
        assert!(t >= 1.0, "thrash multiplier must be >= 1");
        script.amp1 *= t;
        script.amp2 *= t;
        script.per1 = (script.per1 / t).max(2.0);
        script.per2 = (script.per2 / t).max(2.0);
    }

    // ---- spec tables ----------------------------------------------------
    let params: Vec<ParamSpec> = roles
        .iter()
        .enumerate()
        .map(|(k, role)| {
            let label = segment_label(role.segment, branches);
            match role.kind {
                KnobKind::Scale => ParamSpec {
                    name: format!("scale_{label}"),
                    symbol: format!("K{}", k + 1),
                    kind: "continuous".into(),
                    min: 1.0,
                    max: 10.0,
                    default: 1.0,
                    log: false,
                    description: format!(
                        "The degree of image scaling on segment {label} (1 = full resolution)"
                    ),
                },
                KnobKind::Threshold => ParamSpec {
                    name: format!("threshold_{label}"),
                    symbol: format!("K{}", k + 1),
                    kind: "continuous".into(),
                    min: 1.0,
                    max: 65536.0,
                    default: 65536.0,
                    log: true,
                    description: format!(
                        "Cap on the features segment {label} forwards downstream"
                    ),
                },
                KnobKind::Parallel => ParamSpec {
                    // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                    name: format!("par_{}", names[role.stage.unwrap()]),
                    symbol: format!("K{}", k + 1),
                    kind: "discrete".into(),
                    min: 1.0,
                    max: 32.0,
                    default: 1.0,
                    log: true,
                    description: format!(
                        "Data-parallel workers for stage {}",
                        // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                        names[role.stage.unwrap()]
                    ),
                },
                KnobKind::Quality => ParamSpec {
                    // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                    name: format!("quality_{}", names[role.stage.unwrap()]),
                    symbol: format!("K{}", k + 1),
                    kind: "discrete".into(),
                    min: 0.0,
                    max: 1.0,
                    default: 0.0,
                    log: false,
                    description: format!(
                        "Quality mode of stage {}: 0 = high (default), 1 = fast",
                        // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                        names[role.stage.unwrap()]
                    ),
                },
            }
        })
        .collect();

    let stages: Vec<StageSpec> = (0..n_stages)
        .map(|i| {
            let s = seg_of[i];
            let mut ps: Vec<usize> = Vec::new();
            if is_heavy[i] {
                if let Some(k) = seg_scale[s] {
                    ps.push(k);
                }
                if let Some(k) = seg_thresh[s] {
                    ps.push(k);
                }
            }
            if let Some(k) = stage_par[i] {
                ps.push(k);
            }
            if let Some(k) = quality_stage[i] {
                ps.push(k);
            }
            ps.sort_unstable();
            StageSpec {
                name: names[i].clone(),
                deps: deps[i].clone(),
                critical: is_heavy[i],
                params: ps,
            }
        })
        .collect();

    let seg_params = |s: usize| -> Vec<usize> {
        roles
            .iter()
            .enumerate()
            .filter(|(_, r)| r.segment == s)
            .map(|(k, _)| k)
            .collect()
    };
    let seg_stage_names = |s: usize| -> Vec<String> {
        seg_heavy[s].iter().map(|&i| names[i].clone()).collect()
    };
    let mut groups: Vec<GroupSpec> = Vec::new();
    if !seg_params(0).is_empty() {
        groups.push(GroupSpec {
            name: "pre".into(),
            stages: seg_stage_names(0),
            params: seg_params(0),
            branch: None,
            deps: None,
        });
    }
    for b in 0..branches {
        groups.push(GroupSpec {
            name: format!("branch{b}"),
            stages: seg_stage_names(1 + b),
            params: seg_params(1 + b),
            branch: Some(b),
            deps: None,
        });
    }
    if !seg_params(suffix_seg).is_empty() {
        groups.push(GroupSpec {
            name: "post".into(),
            stages: seg_stage_names(suffix_seg),
            params: seg_params(suffix_seg),
            branch: None,
            deps: None,
        });
    }

    let spec = AppSpec {
        name: format!("gen{seed}"),
        title: format!(
            "generated perception pipeline #{seed} ({branches}-branch, {n_stages} stages)"
        ),
        description: format!(
            "Procedurally generated workload (seed {seed}): {n_stages}-stage \
             series-parallel pipeline with {branches} parallel branch(es) and \
             {num_knobs} tunable knobs."
        ),
        latency_bounds_ms: vec![100.0], // placeholder until calibration below
        frame_interval_ms: 33.3,
        trace_frames: cfg.trace_frames,
        trace_configs: cfg.trace_configs,
        params,
        stages,
        groups,
        degree: 3,
        candidate_pad: 64,
        feature_pad: 64,
    };
    // detlint: allow(unwrap) — an invalid generated spec is a generator bug: fail loudly at the source
    spec.validate().expect("generated spec must validate");

    let graph = Graph::from_spec(&spec);
    let model = GeneratedModel {
        script,
        roles,
        segments: (0..n_segments)
            .map(|s| SegmentKnobs { scale: seg_scale[s], threshold: seg_thresh[s] })
            .collect(),
        stages: stage_costs,
        cost_scale,
        base_fidelity,
        drift: drift_walk_for(seed, n_stages, cfg),
    };
    let mut app = App { spec, graph, model: Box::new(model) };

    // ---- bound calibration ----------------------------------------------
    let costs =
        probe_costs_with(&app, cluster, cfg.probe_configs, seed, cfg.exact_accounting);
    let bound = calibrated_bound(&costs, cfg.feasible_quantile, cfg.bound_margin);
    app.spec.latency_bounds_ms = vec![bound, bound * 1.5, bound * 2.0];
    app
}

/// Generate a **general-DAG** pipeline (the `gen-dag:SEED` family):
/// a layered segment graph with multi-level fan-out, diamond joins and
/// skip connections (see [`DagConfig`]). Each segment is a chain of
/// heavy stages; a global source feeds level 0 and a global sink joins
/// every childless tail. The spec declares the segment graph as the
/// group-level DAG (`GroupSpec::deps`), so the structured predictor's
/// critical-path combine stays *exact*: every source→sink path is
/// source → segment chain → sink, hence
/// `e2e = offset(source + sink) + critical_path(segment graph, segment sums)`.
///
/// Shares the knob semantics, cost/fidelity model, content scripts,
/// profiles, scenario hooks (load shift / thrash / drift) and bound
/// calibration of the series-parallel generator. Same seed →
/// byte-identical app.
pub fn generate_dag_on(seed: u64, cfg: &WorkloadConfig, cluster: &Cluster) -> App {
    let dag = cfg.dag.clone().unwrap_or_default();
    assert!(dag.max_depth >= 2, "gen-dag needs at least two levels");
    assert!(dag.max_width >= 1 && dag.max_seg_len >= 1);
    assert!((0.0..=1.0).contains(&dag.skip_prob), "skip_prob is a probability");
    assert!(cfg.min_knobs >= 1 && cfg.max_knobs >= cfg.min_knobs);
    let mut rng = Rng::new(seed);

    // ---- segment topology -----------------------------------------------
    let depth = 2 + rng.below(dag.max_depth - 1);
    let widths: Vec<usize> = (0..depth).map(|_| 1 + rng.below(dag.max_width)).collect();
    let mut seg_level: Vec<usize> = Vec::new();
    let mut level_segs: Vec<Vec<usize>> = vec![Vec::new(); depth];
    for (l, &w) in widths.iter().enumerate() {
        for _ in 0..w {
            level_segs[l].push(seg_level.len());
            seg_level.push(l);
        }
    }
    let n_segments = seg_level.len();
    // parents: 1–2 from the previous level (diamond joins when two
    // siblings share one), plus an occasional skip-level parent
    let mut seg_parents: Vec<Vec<usize>> = vec![Vec::new(); n_segments];
    for l in 1..depth {
        for si in 0..level_segs[l].len() {
            let s = level_segs[l][si];
            let prev = &level_segs[l - 1];
            let k = 1 + rng.below(prev.len().min(2));
            let mut pool = prev.clone();
            for _ in 0..k {
                let i = rng.below(pool.len());
                seg_parents[s].push(pool.swap_remove(i));
            }
            if l >= 2 && rng.bool_with(dag.skip_prob) {
                let el = rng.below(l - 1); // a level at least two below
                let cand = &level_segs[el];
                seg_parents[s].push(cand[rng.below(cand.len())]);
            }
            seg_parents[s].sort_unstable();
            seg_parents[s].dedup();
        }
    }
    let seg_len: Vec<usize> =
        (0..n_segments).map(|_| 1 + rng.below(dag.max_seg_len)).collect();

    // ---- stage tables (source, segment chains in level order, sink) ----
    let conn_seg = n_segments; // knob-less segment id for source/sink
    let mut names: Vec<String> = Vec::new();
    let mut deps: Vec<Vec<String>> = Vec::new();
    let mut seg_of: Vec<usize> = Vec::new();
    let mut is_heavy: Vec<bool> = Vec::new();
    names.push("source".into());
    deps.push(vec![]);
    seg_of.push(conn_seg);
    is_heavy.push(false);
    let mut seg_tail: Vec<String> = vec![String::new(); n_segments];
    for s in 0..n_segments {
        for j in 0..seg_len[s] {
            let dep: Vec<String> = if j > 0 {
                // detlint: allow(unwrap) — names holds the source stage before sink wiring
                vec![names.last().unwrap().clone()]
            } else if seg_parents[s].is_empty() {
                vec!["source".into()]
            } else {
                seg_parents[s].iter().map(|&p| seg_tail[p].clone()).collect()
            };
            names.push(format!("g{s}_{j}"));
            deps.push(dep);
            seg_of.push(s);
            is_heavy.push(true);
        }
        // detlint: allow(unwrap) — names holds the source stage before sink wiring
        seg_tail[s] = names.last().unwrap().clone();
    }
    let mut has_child = vec![false; n_segments];
    for s in 0..n_segments {
        for &p in &seg_parents[s] {
            has_child[p] = true;
        }
    }
    let sink_deps: Vec<String> = (0..n_segments)
        .filter(|&s| !has_child[s])
        .map(|s| seg_tail[s].clone())
        .collect();
    names.push("sink".into());
    deps.push(sink_deps);
    seg_of.push(conn_seg);
    is_heavy.push(false);
    let n_stages = names.len();

    let mut seg_heavy: Vec<Vec<usize>> = vec![Vec::new(); n_segments];
    for i in 0..n_stages {
        if is_heavy[i] {
            seg_heavy[seg_of[i]].push(i);
        }
    }

    // ---- knob roles (same kinds and draw scheme as the SP generator) ---
    let n_scale = n_segments.min(2);
    let min_k = cfg.min_knobs.max(n_scale);
    let max_k = cfg.max_knobs.max(min_k);
    let target_knobs = min_k + rng.below(max_k - min_k + 1);

    let mut roles: Vec<KnobRole> = Vec::new();
    let mut seg_scale: Vec<Option<usize>> = vec![None; n_segments + 1];
    let mut seg_thresh: Vec<Option<usize>> = vec![None; n_segments + 1];
    let mut seg_quality: Vec<Option<usize>> = vec![None; n_segments + 1];
    let mut stage_par: Vec<Option<usize>> = vec![None; n_stages];
    let mut quality_stage: Vec<Option<usize>> = vec![None; n_stages];

    // scale knobs land on distinct random segments — the
    // fidelity/latency trade-off anchors the tuner exists for
    {
        let mut pool: Vec<usize> = (0..n_segments).collect();
        for _ in 0..n_scale {
            let i = rng.below(pool.len());
            let s = pool.swap_remove(i);
            let k = roles.len();
            seg_scale[s] = Some(k);
            roles.push(KnobRole {
                kind: KnobKind::Scale,
                segment: s,
                stage: None,
                fidelity_coef: rng.range_f64(0.03, 0.08),
                need_frac: 0.0,
            });
        }
    }
    let extra_kinds = [KnobKind::Threshold, KnobKind::Parallel, KnobKind::Quality];
    let mut attempt = 0usize;
    while roles.len() < target_knobs && attempt < 24 {
        let kind = extra_kinds[attempt % extra_kinds.len()];
        attempt += 1;
        let eligible: Vec<usize> = (0..n_segments)
            .filter(|&s| !seg_heavy[s].is_empty())
            .filter(|&s| match kind {
                KnobKind::Threshold => seg_thresh[s].is_none(),
                KnobKind::Quality => seg_quality[s].is_none(),
                KnobKind::Parallel => {
                    cfg.profile.allows_parallel()
                        && seg_heavy[s].iter().any(|&st| stage_par[st].is_none())
                }
                KnobKind::Scale => false,
            })
            .collect();
        if eligible.is_empty() {
            continue;
        }
        let s = eligible[rng.below(eligible.len())];
        let k = roles.len();
        match kind {
            KnobKind::Threshold => {
                seg_thresh[s] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: None,
                    fidelity_coef: rng.range_f64(0.4, 0.8),
                    need_frac: rng.range_f64(0.25, 0.45),
                });
            }
            KnobKind::Parallel => {
                let free: Vec<usize> = seg_heavy[s]
                    .iter()
                    .copied()
                    .filter(|&st| stage_par[st].is_none())
                    .collect();
                let st = free[rng.below(free.len())];
                stage_par[st] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: Some(st),
                    fidelity_coef: 0.0,
                    need_frac: 0.0,
                });
            }
            KnobKind::Quality => {
                let st = seg_heavy[s][rng.below(seg_heavy[s].len())];
                seg_quality[s] = Some(k);
                quality_stage[st] = Some(k);
                roles.push(KnobRole {
                    kind,
                    segment: s,
                    stage: Some(st),
                    fidelity_coef: rng.range_f64(0.85, 0.95),
                    need_frac: 0.0,
                });
            }
            KnobKind::Scale => unreachable!(),
        }
    }
    // heavy profile: guarantee core-hungry pipelines (rng-free)
    let mut par_count = roles.iter().filter(|r| r.kind == KnobKind::Parallel).count();
    if par_count < cfg.profile.min_par_knobs() {
        'outer: for s in 0..n_segments {
            for &st in &seg_heavy[s] {
                if par_count >= cfg.profile.min_par_knobs() {
                    break 'outer;
                }
                if stage_par[st].is_none() {
                    let k = roles.len();
                    stage_par[st] = Some(k);
                    roles.push(KnobRole {
                        kind: KnobKind::Parallel,
                        segment: s,
                        stage: Some(st),
                        fidelity_coef: 0.0,
                        need_frac: 0.0,
                    });
                    par_count += 1;
                }
            }
        }
    }
    let num_knobs = roles.len();

    // ---- per-stage costs, content script, global scales -----------------
    let mut stage_costs: Vec<StageCost> = Vec::with_capacity(n_stages);
    for i in 0..n_stages {
        let (base, px, feat, feat2) = if is_heavy[i] {
            (
                rng.range_f64(0.5, 2.0),
                rng.range_f64(15.0, 80.0) * cfg.profile.px_mult(),
                rng.range_f64(1.0, 6.0),
                rng.range_f64(0.0, 1.2),
            )
        } else {
            (rng.range_f64(0.3, 1.2), 0.0, 0.0, 0.0)
        };
        let quality_mult = rng.range_f64(1.5, 2.2);
        let serial_frac = rng.range_f64(0.05, 0.15) * cfg.profile.serial_mult();
        let per_worker_ov = rng.range_f64(0.04, 0.18) * cfg.profile.overhead_mult();
        stage_costs.push(StageCost {
            segment: seg_of[i],
            base,
            px,
            feat,
            feat2,
            par_knob: stage_par[i],
            quality_knob: quality_stage[i],
            quality_mult,
            serial_frac,
            per_worker_ov,
        });
    }
    let mut script = ContentScript {
        base_features: rng.range_f64(350.0, 750.0),
        amp1: rng.range_f64(20.0, 60.0),
        per1: rng.range_f64(9.0, 45.0),
        amp2: rng.range_f64(10.0, 40.0),
        per2: rng.range_f64(9.0, 45.0),
        change_frame: 300 + rng.below(400),
        change_mult: rng.range_f64(1.2, 1.8),
    };
    let cost_scale = rng.range_f64(0.8, 1.6) * cfg.profile.cost_mult();
    let base_fidelity = rng.range_f64(0.90, 0.98);
    if let Some((frame, mult)) = cfg.load_shift {
        script.change_frame = frame;
        script.change_mult = mult;
    }
    if let Some(t) = cfg.thrash {
        assert!(t >= 1.0, "thrash multiplier must be >= 1");
        script.amp1 *= t;
        script.amp2 *= t;
        script.per1 = (script.per1 / t).max(2.0);
        script.per2 = (script.per2 / t).max(2.0);
    }

    // ---- spec tables ----------------------------------------------------
    let params: Vec<ParamSpec> = roles
        .iter()
        .enumerate()
        .map(|(k, role)| {
            let label = format!("g{}", role.segment);
            match role.kind {
                KnobKind::Scale => ParamSpec {
                    name: format!("scale_{label}"),
                    symbol: format!("K{}", k + 1),
                    kind: "continuous".into(),
                    min: 1.0,
                    max: 10.0,
                    default: 1.0,
                    log: false,
                    description: format!(
                        "The degree of image scaling on segment {label} (1 = full resolution)"
                    ),
                },
                KnobKind::Threshold => ParamSpec {
                    name: format!("threshold_{label}"),
                    symbol: format!("K{}", k + 1),
                    kind: "continuous".into(),
                    min: 1.0,
                    max: 65536.0,
                    default: 65536.0,
                    log: true,
                    description: format!(
                        "Cap on the features segment {label} forwards downstream"
                    ),
                },
                KnobKind::Parallel => ParamSpec {
                    // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                    name: format!("par_{}", names[role.stage.unwrap()]),
                    symbol: format!("K{}", k + 1),
                    kind: "discrete".into(),
                    min: 1.0,
                    max: 32.0,
                    default: 1.0,
                    log: true,
                    description: format!(
                        "Data-parallel workers for stage {}",
                        // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                        names[role.stage.unwrap()]
                    ),
                },
                KnobKind::Quality => ParamSpec {
                    // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                    name: format!("quality_{}", names[role.stage.unwrap()]),
                    symbol: format!("K{}", k + 1),
                    kind: "discrete".into(),
                    min: 0.0,
                    max: 1.0,
                    default: 0.0,
                    log: false,
                    description: format!(
                        "Quality mode of stage {}: 0 = high (default), 1 = fast",
                        // detlint: allow(unwrap) — par_/quality_ roles always carry Some(stage) — set two lines up
                        names[role.stage.unwrap()]
                    ),
                },
            }
        })
        .collect();

    let stages: Vec<StageSpec> = (0..n_stages)
        .map(|i| {
            let s = seg_of[i];
            let mut ps: Vec<usize> = Vec::new();
            if is_heavy[i] {
                if let Some(k) = seg_scale[s] {
                    ps.push(k);
                }
                if let Some(k) = seg_thresh[s] {
                    ps.push(k);
                }
            }
            if let Some(k) = stage_par[i] {
                ps.push(k);
            }
            if let Some(k) = quality_stage[i] {
                ps.push(k);
            }
            ps.sort_unstable();
            StageSpec {
                name: names[i].clone(),
                deps: deps[i].clone(),
                critical: is_heavy[i],
                params: ps,
            }
        })
        .collect();

    // one group per segment — param-less segments still get one (the
    // group DAG must tile the graph for the combine to stay exact; their
    // regressor degenerates to a learned constant)
    let groups: Vec<GroupSpec> = (0..n_segments)
        .map(|s| GroupSpec {
            name: format!("seg{s}"),
            stages: seg_heavy[s].iter().map(|&i| names[i].clone()).collect(),
            params: roles
                .iter()
                .enumerate()
                .filter(|(_, r)| r.segment == s)
                .map(|(k, _)| k)
                .collect(),
            branch: None,
            deps: Some(seg_parents[s].iter().map(|&p| format!("seg{p}")).collect()),
        })
        .collect();

    let spec = AppSpec {
        name: format!("gendag{seed}"),
        title: format!(
            "generated DAG perception pipeline #{seed} \
             ({depth}-level, {n_segments} segments, {n_stages} stages)"
        ),
        description: format!(
            "Procedurally generated general-DAG workload (seed {seed}): \
             {n_stages}-stage pipeline over {n_segments} segments in {depth} \
             levels with {num_knobs} tunable knobs, multi-level fan-out and \
             skip connections."
        ),
        latency_bounds_ms: vec![100.0], // placeholder until calibration below
        frame_interval_ms: 33.3,
        trace_frames: cfg.trace_frames,
        trace_configs: cfg.trace_configs,
        params,
        stages,
        groups,
        degree: 3,
        candidate_pad: 64,
        feature_pad: 64,
    };
    // detlint: allow(unwrap) — an invalid generated DAG spec is a generator bug: fail loudly at the source
    spec.validate().expect("generated DAG spec must validate");

    let graph = Graph::from_spec(&spec);
    let model = GeneratedModel {
        script,
        roles,
        segments: (0..=n_segments)
            .map(|s| SegmentKnobs { scale: seg_scale[s], threshold: seg_thresh[s] })
            .collect(),
        stages: stage_costs,
        cost_scale,
        base_fidelity,
        drift: drift_walk_for(seed, n_stages, cfg),
    };
    let mut app = App { spec, graph, model: Box::new(model) };

    // ---- bound calibration (same policy as the SP generator) ------------
    let costs =
        probe_costs_with(&app, cluster, cfg.probe_configs, seed, cfg.exact_accounting);
    let bound = calibrated_bound(&costs, cfg.feasible_quantile, cfg.bound_margin);
    app.spec.latency_bounds_ms = vec![bound, bound * 1.5, bound * 2.0];
    app
}

/// Worst-case (over a deterministic frame spread spanning the scene
/// change) end-to-end cost of `n` random configurations on `cluster` —
/// the calibration sample the generated bounds are derived from.
pub fn probe_costs(app: &App, cluster: &Cluster, n: usize, seed: u64) -> Vec<f64> {
    probe_costs_with(app, cluster, n, seed, false)
}

/// [`probe_costs`] with optional exact fairness-floor accounting: the
/// probe simulator charges the sub-stage-count time-multiplexing
/// multiplier, so bounds calibrated for a tiny quota are honest about
/// what that quota can actually run.
pub fn probe_costs_with(
    app: &App,
    cluster: &Cluster,
    n: usize,
    seed: u64,
    exact_accounting: bool,
) -> Vec<f64> {
    const PROBE_FRAMES: [usize; 9] = [0, 61, 137, 253, 389, 491, 645, 811, 953];
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    (0..n)
        .map(|_| {
            let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
            let ks = app.spec.denormalize(&u);
            let mut sim = ClusterSim::deterministic(cluster.clone())
                .with_time_multiplex(exact_accounting);
            PROBE_FRAMES
                .iter()
                .map(|&f| sim.run_frame(app, &ks, f).end_to_end_ms)
                .fold(0.0f64, f64::max)
        })
        .collect()
}

/// The bound sitting at `quantile` of the sorted worst-case costs, padded
/// by `margin`: configs below it stay feasible even under the simulator's
/// measurement noise.
pub fn calibrated_bound(costs: &[f64], quantile: f64, margin: f64) -> f64 {
    assert!(!costs.is_empty());
    let mut sorted = costs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // detlint: allow(lossy-cast) — quantile index: rounded product of bounded counts, exact below 2^53
    let idx = ((sorted.len() - 1) as f64 * quantile.clamp(0.0, 1.0)).round() as usize;
    sorted[idx] * margin
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::critical_path;
    use crate::learner::GroupMap;

    #[test]
    fn generated_specs_validate_across_seeds() {
        let cfg = WorkloadConfig::default();
        for seed in 0..25 {
            let app = generate(seed, &cfg);
            app.spec.validate().unwrap();
            assert_eq!(app.graph.len(), app.spec.stages.len());
            assert_eq!(app.graph.sources().len(), 1, "seed {seed}");
            assert_eq!(app.graph.sinks().len(), 1, "seed {seed}");
            assert!(app.spec.num_vars() >= 3, "seed {seed}");
        }
    }

    #[test]
    fn same_seed_same_app() {
        let cfg = WorkloadConfig::default();
        let a = generate(123, &cfg);
        let b = generate(123, &cfg);
        assert_eq!(a.spec.name, b.spec.name);
        assert_eq!(a.spec.latency_bounds_ms, b.spec.latency_bounds_ms);
        let names_a: Vec<&str> = a.spec.stages.iter().map(|s| s.name.as_str()).collect();
        let names_b: Vec<&str> = b.spec.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names_a, names_b);
        let ks = a.spec.defaults();
        let ca = a.model.content(7);
        let cb = b.model.content(7);
        assert_eq!(ca, cb);
        assert_eq!(a.stage_latencies(&ks, &ca), b.stage_latencies(&ks, &cb));
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = WorkloadConfig::default();
        let a = generate(1, &cfg);
        let b = generate(2, &cfg);
        let differs = a.spec.stages.len() != b.spec.stages.len()
            || a.spec.num_vars() != b.spec.num_vars()
            || a.spec.latency_bounds_ms != b.spec.latency_bounds_ms;
        assert!(differs, "seeds 1 and 2 generated identical apps");
    }

    #[test]
    fn defaults_are_fidelity_max_corner() {
        let cfg = WorkloadConfig::default();
        for seed in [3u64, 11, 42] {
            let app = generate(seed, &cfg);
            let content = app.model.content(0);
            let best = app.model.fidelity(&app.spec.defaults(), &content);
            let mut rng = Rng::new(seed + 1000);
            for _ in 0..30 {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                let ks = app.spec.denormalize(&u);
                assert!(app.model.fidelity(&ks, &content) <= best + 1e-9, "seed {seed}");
            }
        }
    }

    #[test]
    fn group_combine_reproduces_critical_path() {
        let cfg = WorkloadConfig::default();
        for seed in 0..15 {
            let app = generate(seed, &cfg);
            let map = GroupMap::structured(&app.spec);
            let mut rng = Rng::new(seed ^ 0xABCD);
            for _ in 0..10 {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                let ks = app.spec.denormalize(&u);
                let content = app.model.content(rng.below(900));
                let stage_ms = app.stage_latencies(&ks, &content);
                let e2e = critical_path(&app.graph, &stage_ms);
                let (y, offset) = map.targets(&stage_ms, e2e);
                let combined = map.combine(&y, offset);
                assert!(
                    (combined - e2e).abs() < 1e-9,
                    "seed {seed}: combined {combined} vs e2e {e2e}"
                );
            }
        }
    }

    #[test]
    fn series_parallel_combine_is_bit_identical_to_legacy_rule() {
        // the pre-DAG sum/max rule, inlined verbatim: on series-parallel
        // specs (no group graph) the new combine must reproduce it
        // BIT-FOR-BIT — every recorded trace and mirror threshold
        // depends on that arithmetic
        fn legacy(map: &GroupMap, group_pred: &[f64], offset: f64) -> f64 {
            let mut total = offset;
            let mut branch_sums: std::collections::BTreeMap<usize, f64> =
                std::collections::BTreeMap::new();
            for (g, &p) in group_pred.iter().enumerate() {
                match map.branch[g] {
                    None => total += p,
                    Some(b) => *branch_sums.entry(b).or_insert(0.0) += p,
                }
            }
            if !branch_sums.is_empty() {
                total += branch_sums.values().cloned().fold(f64::MIN, f64::max);
            }
            total
        }
        let cfg = WorkloadConfig::default();
        for seed in 0..20 {
            let app = generate(seed, &cfg);
            let map = GroupMap::structured(&app.spec);
            assert!(map.group_graph.is_none(), "gen:SEED specs stay legacy");
            let mut rng = Rng::new(seed ^ 0xB17);
            for _ in 0..20 {
                let preds: Vec<f64> = (0..map.num_groups())
                    .map(|_| rng.range_f64(0.0, 120.0))
                    .collect();
                let offset = rng.range_f64(0.0, 10.0);
                let (a, b) = (map.combine(&preds, offset), legacy(&map, &preds, offset));
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn gen_dag_specs_validate_and_declare_group_dags() {
        let cfg = WorkloadConfig { dag: Some(DagConfig::default()), ..Default::default() };
        let mut saw_multi_parent = false;
        let mut saw_skip = false;
        for seed in 0..25u64 {
            let app = generate(seed, &cfg);
            app.spec.validate().unwrap();
            assert_eq!(app.spec.name, format!("gendag{seed}"));
            assert_eq!(app.graph.len(), app.spec.stages.len());
            assert_eq!(app.graph.sources().len(), 1, "seed {seed}");
            assert_eq!(app.graph.sinks().len(), 1, "seed {seed}");
            assert!(app.spec.num_vars() >= 2, "seed {seed}");
            assert!(app.spec.groups.len() >= 2, "seed {seed}");
            assert!(
                app.spec.groups.iter().all(|g| g.deps.is_some()),
                "seed {seed}: group DAG must be declared"
            );
            // witness the non-series-parallel shapes across the seed set:
            // diamond joins (>= 2 parents) and skip connections (a parent
            // whose longest-path depth sits >= 2 below the child's — in a
            // strictly layered graph every parent is exactly one level up)
            let mut gdepth: std::collections::BTreeMap<&str, usize> =
                std::collections::BTreeMap::new();
            for g in &app.spec.groups {
                let deps = g.deps.as_deref().unwrap();
                if deps.len() >= 2 {
                    saw_multi_parent = true;
                }
                let d = deps
                    .iter()
                    .map(|p| gdepth[p.as_str()] + 1)
                    .max()
                    .unwrap_or(0);
                if deps.iter().any(|p| gdepth[p.as_str()] + 2 <= d) {
                    saw_skip = true;
                }
                gdepth.insert(g.name.as_str(), d);
            }
        }
        assert!(saw_multi_parent, "no seed produced a diamond join");
        assert!(saw_skip, "no seed produced a skip connection");
    }

    #[test]
    fn gen_dag_same_seed_same_app() {
        let cfg = WorkloadConfig { dag: Some(DagConfig::default()), ..Default::default() };
        let a = generate(123, &cfg);
        let b = generate(123, &cfg);
        assert_eq!(a.spec.name, b.spec.name);
        assert_eq!(a.spec.latency_bounds_ms, b.spec.latency_bounds_ms);
        let names_a: Vec<&str> = a.spec.stages.iter().map(|s| s.name.as_str()).collect();
        let names_b: Vec<&str> = b.spec.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names_a, names_b);
        let c = generate(124, &cfg);
        assert!(
            a.spec.stages.len() != c.spec.stages.len()
                || a.spec.latency_bounds_ms != c.spec.latency_bounds_ms,
            "seeds 123 and 124 generated identical DAG apps"
        );
    }

    #[test]
    fn gen_dag_group_combine_reproduces_critical_path() {
        // the tentpole exactness claim: on general DAGs the structured
        // critical-path combine equals the simulator's weighted critical
        // path (offset = source + sink, group weights = segment sums)
        let cfg = WorkloadConfig { dag: Some(DagConfig::default()), ..Default::default() };
        for seed in 0..15 {
            let app = generate(seed, &cfg);
            let map = GroupMap::structured(&app.spec);
            assert!(map.group_graph.is_some(), "seed {seed}");
            let mut rng = Rng::new(seed ^ 0xABCD);
            for _ in 0..10 {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                let ks = app.spec.denormalize(&u);
                let content = app.model.content(rng.below(900));
                let stage_ms = app.stage_latencies(&ks, &content);
                let e2e = critical_path(&app.graph, &stage_ms);
                let (y, offset) = map.targets(&stage_ms, e2e);
                let combined = map.combine(&y, offset);
                assert!(
                    (combined - e2e).abs() < 1e-9,
                    "seed {seed}: combined {combined} vs e2e {e2e}"
                );
            }
        }
    }

    #[test]
    fn gen_dag_profiles_mirror_series_parallel_semantics() {
        let light = WorkloadConfig {
            dag: Some(DagConfig::default()),
            profile: AppProfile::Light,
            ..Default::default()
        };
        let heavy = WorkloadConfig {
            dag: Some(DagConfig::default()),
            profile: AppProfile::Heavy,
            ..Default::default()
        };
        for seed in [1u64, 8, 33] {
            let l = generate(seed, &light);
            assert!(
                l.spec.params.iter().all(|p| !p.name.starts_with("par_")),
                "seed {seed}: light DAG app grew a parallel knob"
            );
            let h = generate(seed, &heavy);
            let par = h.spec.params.iter().filter(|p| p.name.starts_with("par_")).count();
            assert!(par >= 2, "seed {seed}: only {par} parallel knobs");
        }
    }

    #[test]
    fn gen_dag_bounds_leave_a_feasible_region() {
        let cfg = WorkloadConfig { dag: Some(DagConfig::default()), ..Default::default() };
        for seed in [0u64, 7, 19] {
            let app = generate(seed, &cfg);
            let bound = app.spec.latency_bounds_ms[0];
            let costs = probe_costs(&app, &Cluster::default(), cfg.probe_configs, seed);
            let feasible = costs.iter().filter(|&&c| c <= bound).count();
            let frac = feasible as f64 / costs.len() as f64;
            assert!(frac >= 0.2, "seed {seed}: only {frac} of probes feasible");
            assert!(frac <= 0.9, "seed {seed}: bound too loose ({frac} feasible)");
        }
    }

    #[test]
    fn drift_is_rng_neutral_and_stays_inside_the_walk_band() {
        for dag in [false, true] {
            let plain_cfg = WorkloadConfig {
                dag: dag.then(DagConfig::default),
                ..Default::default()
            };
            let drift_cfg = WorkloadConfig { drift: Some(0.25), ..plain_cfg.clone() };
            for seed in [3u64, 11, 42] {
                let plain = generate(seed, &plain_cfg);
                let drifting = generate(seed, &drift_cfg);
                // rng-neutral: identical topology and knob table
                assert_eq!(plain.spec.stages.len(), drifting.spec.stages.len());
                for (a, b) in plain.spec.params.iter().zip(&drifting.spec.params) {
                    assert_eq!(a.name, b.name, "drift must not disturb the draw stream");
                }
                // per-frame stage costs stay within the configured band
                let ks = plain.spec.defaults();
                let mut sp = ClusterSim::deterministic(Cluster::default());
                let mut sd = ClusterSim::deterministic(Cluster::default());
                let mut moved = false;
                for f in (0..900).step_by(37) {
                    let rp = sp.run_frame(&plain, &ks, f);
                    let rd = sd.run_frame(&drifting, &ks, f);
                    for s in 0..rp.stage_ms.len() {
                        let ratio = rd.stage_ms[s] / rp.stage_ms[s];
                        assert!(
                            (0.75 - 1e-9..=1.25 + 1e-9).contains(&ratio),
                            "dag {dag} seed {seed} frame {f} stage {s}: ratio {ratio}"
                        );
                        if (ratio - 1.0).abs() > 0.02 {
                            moved = true;
                        }
                    }
                    // drift is cost-only
                    assert_eq!(rp.fidelity, rd.fidelity);
                }
                assert!(moved, "dag {dag} seed {seed}: drift never moved a cost");
                // drifted bounds are calibrated under drift (not copied)
                assert!(drifting.spec.latency_bounds_ms[0] > 0.0);
            }
        }
    }

    #[test]
    fn drift_composes_with_scripted_load_drop() {
        let cfg = WorkloadConfig {
            drift: Some(0.2),
            load_shift: Some(load_drop(150)),
            ..Default::default()
        };
        let app = generate(9, &cfg);
        // the scene cut still fires under drift ...
        let before = app.model.content(149);
        let after = app.model.content(150);
        assert_eq!(before.scene_id, 0);
        assert_eq!(after.scene_id, 1);
        assert!(after.features < before.features * 0.75);
        // ... and the walk still applies frame-to-frame
        assert!(app.model.cost_drift(1, 500) != 1.0 || app.model.cost_drift(2, 500) != 1.0);
    }

    #[test]
    fn bound_leaves_a_feasible_quarter() {
        let cfg = WorkloadConfig::default();
        for seed in [0u64, 7, 19] {
            let app = generate(seed, &cfg);
            let bound = app.spec.latency_bounds_ms[0];
            let costs = probe_costs(&app, &Cluster::default(), cfg.probe_configs, seed);
            let feasible = costs.iter().filter(|&&c| c <= bound).count();
            let frac = feasible as f64 / costs.len() as f64;
            assert!(frac >= 0.2, "seed {seed}: only {frac} of probes feasible");
            assert!(frac <= 0.9, "seed {seed}: bound too loose ({frac} feasible)");
        }
    }

    #[test]
    fn parallel_knobs_do_not_move_fidelity() {
        // paper Sec. 2.2: parallelism trades latency, not fidelity
        let cfg = WorkloadConfig::default();
        for seed in 0..10 {
            let app = generate(seed, &cfg);
            let content = app.model.content(5);
            let mut lo = app.spec.defaults();
            let mut hi = app.spec.defaults();
            for (k, p) in app.spec.params.iter().enumerate() {
                if p.name.starts_with("par_") {
                    lo[k] = p.min;
                    hi[k] = p.max;
                }
            }
            assert_eq!(
                app.model.fidelity(&lo, &content),
                app.model.fidelity(&hi, &content),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn light_profile_is_core_insensitive() {
        let cfg = WorkloadConfig { profile: AppProfile::Light, ..Default::default() };
        for seed in [1u64, 8, 33, 77] {
            let app = generate(seed, &cfg);
            assert!(
                app.spec.params.iter().all(|p| !p.name.starts_with("par_")),
                "seed {seed}: light app grew a parallel knob"
            );
            // therefore every stage requests exactly one worker and the
            // core budget cannot change its latency
            let ks = app.spec.defaults();
            for s in 0..app.graph.len() {
                assert_eq!(app.model.requested_workers(s, &ks), 1, "seed {seed}");
                assert_eq!(app.model.par_knob(s), None, "seed {seed}");
            }
        }
    }

    #[test]
    fn heavy_profile_guarantees_parallel_knobs() {
        let cfg = WorkloadConfig { profile: AppProfile::Heavy, ..Default::default() };
        for seed in [1u64, 8, 33, 77] {
            let app = generate(seed, &cfg);
            let par = app
                .spec
                .params
                .iter()
                .filter(|p| p.name.starts_with("par_"))
                .count();
            assert!(par >= 2, "seed {seed}: only {par} parallel knobs");
            app.spec.validate().unwrap();
        }
    }

    #[test]
    fn load_shift_overrides_content_script() {
        let cfg = WorkloadConfig {
            load_shift: Some((123, 1.9)),
            ..Default::default()
        };
        let app = generate(5, &cfg);
        let before = app.model.content(122);
        let after = app.model.content(123);
        assert_eq!(before.scene_id, 0);
        assert_eq!(after.scene_id, 1);
        assert!(after.features > before.features * 1.5, "shift not applied");
        // rng-neutral: everything else matches the unshifted app
        let plain = generate(5, &WorkloadConfig::default());
        assert_eq!(plain.spec.latency_bounds_ms.len(), app.spec.latency_bounds_ms.len());
        assert_eq!(
            plain.spec.params.len(),
            app.spec.params.len(),
            "load shift must not disturb the draw stream"
        );
    }

    #[test]
    fn thrash_scenario_is_rng_neutral_and_turbulent() {
        let plain = generate(5, &WorkloadConfig::default());
        let cfg = WorkloadConfig { thrash: Some(6.0), ..Default::default() };
        let thrashed = generate(5, &cfg);
        // rng-neutral: same topology and knob table as the plain draw
        assert_eq!(plain.spec.stages.len(), thrashed.spec.stages.len());
        assert_eq!(plain.spec.params.len(), thrashed.spec.params.len());
        for (a, b) in plain.spec.params.iter().zip(&thrashed.spec.params) {
            assert_eq!(a.name, b.name, "thrash must not disturb the draw stream");
        }
        // turbulent: the content wobble swings much harder and faster
        let swing = |app: &crate::apps::App| {
            let fs: Vec<f64> = (0..50).map(|f| app.model.content(f).features).collect();
            fs.iter().copied().fold(0.0f64, f64::max)
                - fs.iter().copied().fold(f64::INFINITY, f64::min)
        };
        assert!(
            swing(&thrashed) > 3.0 * swing(&plain),
            "thrash swing {} vs plain {}",
            swing(&thrashed),
            swing(&plain)
        );
    }

    #[test]
    fn exact_accounting_calibrates_honest_bounds_on_tiny_clusters() {
        // a 3-core cluster always runs >= 5 stages, so every probe pays
        // the time-multiplexing charge and the bound must grow with it
        let tiny = Cluster { servers: 1, cores_per_server: 3, comm_ms_per_frame: 0.0 };
        let plain_cfg = WorkloadConfig::default();
        let exact_cfg = WorkloadConfig { exact_accounting: true, ..Default::default() };
        for seed in [2u64, 9, 21] {
            let plain = generate_on(seed, &plain_cfg, &tiny);
            let exact = generate_on(seed, &exact_cfg, &tiny);
            assert!(
                exact.spec.latency_bounds_ms[0] > plain.spec.latency_bounds_ms[0],
                "seed {seed}: {} !> {}",
                exact.spec.latency_bounds_ms[0],
                plain.spec.latency_bounds_ms[0]
            );
            // rng-neutral: identical topology either way
            assert_eq!(plain.spec.stages.len(), exact.spec.stages.len());
        }
        // on the paper's 120-core cluster the charge only applies to
        // configurations whose grants exceed the pool — bounds never shrink
        let big = Cluster::default();
        for seed in [2u64, 9, 21] {
            let plain = generate_on(seed, &plain_cfg, &big);
            let exact = generate_on(seed, &exact_cfg, &big);
            assert!(
                exact.spec.latency_bounds_ms[0] >= plain.spec.latency_bounds_ms[0],
                "seed {seed}"
            );
        }
    }

    #[test]
    fn load_drop_scenario_halves_post_shift_content() {
        let cfg = WorkloadConfig { load_shift: Some(load_drop(150)), ..Default::default() };
        let app = generate(9, &cfg);
        let before = app.model.content(149);
        let after = app.model.content(150);
        assert_eq!(before.scene_id, 0);
        assert_eq!(after.scene_id, 1);
        assert!(
            after.features < before.features * 0.75,
            "load must drop: {} -> {}",
            before.features,
            after.features
        );
        // rng-neutral like every scripted scenario
        let plain = generate(9, &WorkloadConfig::default());
        assert_eq!(plain.spec.params.len(), app.spec.params.len());
    }

    #[test]
    fn tier_shift_weights_upgrade_and_downgrade_distinct_tenants() {
        for seed in 0..20u64 {
            for apps in 2..6 {
                let w = tier_shift_weights(seed, apps);
                assert_eq!(w.len(), apps);
                assert_eq!(w.iter().filter(|&&x| x == 4.0).count(), 1, "{w:?}");
                assert_eq!(w.iter().filter(|&&x| x == 0.5).count(), 1, "{w:?}");
                assert!(w.iter().all(|&x| x == 1.0 || x == 4.0 || x == 0.5));
            }
            // deterministic
            assert_eq!(tier_shift_weights(seed, 4), tier_shift_weights(seed, 4));
        }
    }

    #[test]
    fn calibrated_bound_is_quantile_times_margin() {
        let costs = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        let b = calibrated_bound(&costs, 0.25, 1.1);
        assert!((b - 22.0).abs() < 1e-9);
        let b0 = calibrated_bound(&costs, 0.0, 1.0);
        assert!((b0 - 10.0).abs() < 1e-9);
    }
}
