//! Execution traces — the paper's experimental methodology (Sec. 4.1).
//!
//! "For each application, we created 30 configurations by selecting
//! random valid values for the tunable parameters. We ran each of these
//! static configurations on a sequence of 1000 frames, collected
//! performance logs from the runtime, and extracted latency measures for
//! each frame. We use the set of configurations as a point-based
//! approximation of the total space, and use the traces as predefined
//! alternative futures between which the simulated system switches."
//!
//! [`TraceSet::generate`] reproduces exactly that protocol on the
//! simulated cluster; [`TraceSet::save`]/[`TraceSet::load`] persist the
//! result so experiments are replayable.

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::apps::App;
use crate::simulator::{grant_under, time_multiplex_factor, Cluster, ClusterSim, NoiseModel};
use crate::util::json::Json;
use crate::util::Rng;

/// One frame's measurements under a fixed configuration — a borrowed
/// view into a [`FrameBlock`] row (the arena owns the data).
#[derive(Debug, Clone, Copy)]
pub struct FrameRef<'a> {
    /// Per-stage latencies (ms), indexed like the app graph.
    pub stage_ms: &'a [f64],
    /// End-to-end latency (ms): critical path.
    pub end_to_end_ms: f64,
    /// Frame fidelity r.
    pub fidelity: f64,
}

/// Columnar arena holding every frame of one trace buffer (PR 8).
///
/// The per-frame `Vec<f64>` of the old `TraceFrame` rows was the
/// dominant allocation churn of ladder-trace generation — `levels ×
/// configs × frames` little vectors built and chased per tenant per
/// epoch. The arena stores the whole buffer as three flat columns (the
/// per-stage latency matrix row-major, plus the two per-frame scalars),
/// so generation allocates O(1) vectors per buffer, readers walk
/// contiguous memory, and the JSON codec (which was already columnar on
/// disk) moves columns instead of re-slicing rows. Modeled on
/// timely-dataflow's slab idiom of batching many small payloads into one
/// backing buffer.
#[derive(Debug, Clone, Default)]
pub struct FrameBlock {
    n_stages: usize,
    /// `frames × n_stages`, row-major: frame `f`'s stage latencies are
    /// `stage_ms[f * n_stages..(f + 1) * n_stages]`.
    stage_ms: Vec<f64>,
    end_to_end_ms: Vec<f64>,
    fidelity: Vec<f64>,
}

impl FrameBlock {
    pub fn new(n_stages: usize) -> Self {
        FrameBlock { n_stages, ..Default::default() }
    }

    pub fn with_capacity(n_stages: usize, n_frames: usize) -> Self {
        FrameBlock {
            n_stages,
            stage_ms: Vec::with_capacity(n_frames * n_stages),
            end_to_end_ms: Vec::with_capacity(n_frames),
            fidelity: Vec::with_capacity(n_frames),
        }
    }

    /// Rebuild from the on-disk columns (`stage_ms_flat` et al.).
    pub fn from_columns(
        n_stages: usize,
        stage_ms: Vec<f64>,
        end_to_end_ms: Vec<f64>,
        fidelity: Vec<f64>,
    ) -> Result<Self> {
        anyhow::ensure!(stage_ms.len() == end_to_end_ms.len() * n_stages, "ragged trace");
        anyhow::ensure!(fidelity.len() == end_to_end_ms.len(), "ragged fidelity");
        Ok(FrameBlock { n_stages, stage_ms, end_to_end_ms, fidelity })
    }

    /// Append one complete frame (test/bench convenience; generation
    /// writes stages through [`stage_buf`](Self::stage_buf) instead).
    pub fn push(&mut self, stage_ms: &[f64], end_to_end_ms: f64, fidelity: f64) {
        assert_eq!(stage_ms.len(), self.n_stages, "stage count mismatch");
        self.stage_ms.extend_from_slice(stage_ms);
        self.end_to_end_ms.push(end_to_end_ms);
        self.fidelity.push(fidelity);
    }

    /// The raw stage column, for writers that stream latencies in place
    /// (`ClusterSim::run_frame_cols` appends `n_stages` values here).
    /// Every append of one frame's stages must be balanced by a
    /// [`commit_frame`](Self::commit_frame).
    pub fn stage_buf(&mut self) -> &mut Vec<f64> {
        &mut self.stage_ms
    }

    /// Seal the frame whose stages were just appended via
    /// [`stage_buf`](Self::stage_buf).
    pub fn commit_frame(&mut self, end_to_end_ms: f64, fidelity: f64) {
        assert_eq!(
            self.stage_ms.len(),
            (self.end_to_end_ms.len() + 1) * self.n_stages,
            "commit_frame without exactly n_stages appended stages"
        );
        self.end_to_end_ms.push(end_to_end_ms);
        self.fidelity.push(fidelity);
    }

    pub fn len(&self) -> usize {
        self.end_to_end_ms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.end_to_end_ms.is_empty()
    }

    pub fn n_stages(&self) -> usize {
        self.n_stages
    }

    /// Frame `i` as a borrowed row view.
    pub fn get(&self, i: usize) -> FrameRef<'_> {
        FrameRef {
            stage_ms: &self.stage_ms[i * self.n_stages..(i + 1) * self.n_stages],
            end_to_end_ms: self.end_to_end_ms[i],
            fidelity: self.fidelity[i],
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = FrameRef<'_>> + '_ {
        (0..self.len()).map(move |i| self.get(i))
    }

    /// The flat `frames × n_stages` latency matrix (serialization).
    pub fn stage_flat(&self) -> &[f64] {
        &self.stage_ms
    }

    /// The end-to-end latency column.
    pub fn end_to_end(&self) -> &[f64] {
        &self.end_to_end_ms
    }

    /// The fidelity column.
    pub fn fidelities(&self) -> &[f64] {
        &self.fidelity
    }

    /// Heap bytes this arena holds (`n_stages + 2` f64 columns per
    /// frame) — the unit behind the `ladder_trace/*_peak_bytes` bench
    /// metrics.
    pub fn heap_bytes(&self) -> usize {
        (self.stage_ms.len() + self.end_to_end_ms.len() + self.fidelity.len())
            * std::mem::size_of::<f64>()
    }
}

/// A 1000-frame run of one static configuration.
///
/// Frames live in one [`FrameBlock`] arena behind an [`Arc`] so ladder
/// traces can share one frame buffer across every rung whose worker grant
/// (and time-multiplex charge) is identical — the quota only changes
/// execution through the grant, so equal grants produce byte-identical
/// frames (see [`LadderTraceSet::generate_with`]).
#[derive(Debug, Clone)]
pub struct Trace {
    /// Raw knob vector.
    pub config: Vec<f64>,
    pub frames: Arc<FrameBlock>,
}

impl Trace {
    pub fn avg_cost_ms(&self) -> f64 {
        self.frames.end_to_end().iter().sum::<f64>() / self.frames.len() as f64
    }

    pub fn avg_fidelity(&self) -> f64 {
        self.frames.fidelities().iter().sum::<f64>() / self.frames.len() as f64
    }

    /// Fraction of frames whose end-to-end latency satisfies `bound_ms`
    /// (the fleet's `robust_feasible_actions` count is built from this).
    pub fn frac_under(&self, bound_ms: f64) -> f64 {
        if self.frames.is_empty() {
            return 0.0;
        }
        let ok = self.frames.end_to_end().iter().filter(|&&e| e <= bound_ms).count();
        ok as f64 / self.frames.len() as f64
    }
}

/// The full point-based approximation of the action space for one app.
#[derive(Debug, Clone)]
pub struct TraceSet {
    pub app: String,
    pub seed: u64,
    pub traces: Vec<Trace>,
    /// Stage names (graph order) for self-describing trace files.
    pub stage_names: Vec<String>,
}

impl TraceSet {
    /// Sample `n_configs` random valid configurations (uniform in the
    /// normalized knob space, so log-scaled knobs are log-uniform) and
    /// run each for `n_frames` frames on the default simulated cluster.
    pub fn generate(app: &App, n_configs: usize, n_frames: usize, seed: u64) -> Self {
        Self::generate_on(app, &Cluster::default(), n_configs, n_frames, seed)
    }

    /// [`generate`](Self::generate) against an explicit cluster — the
    /// fleet runner traces each app on its slice of the shared cluster.
    pub fn generate_on(
        app: &App,
        cluster: &Cluster,
        n_configs: usize,
        n_frames: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut traces = Vec::with_capacity(n_configs);
        let n_stages = app.graph.len();
        for ci in 0..n_configs {
            let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
            let config = app.spec.denormalize(&u);
            let mut sim = ClusterSim::new(
                cluster.clone(),
                NoiseModel::default(),
                seed.wrapping_mul(1_000_003).wrapping_add(ci as u64),
            );
            // the grant plan is a pure function of the knobs, so hoist it
            // out of the frame loop and stream frames into the arena
            let (granted, tm) = sim.plan_grant(app, &config);
            let mut block = FrameBlock::with_capacity(n_stages, n_frames);
            for f in 0..n_frames {
                let (e2e, fid) =
                    sim.run_frame_cols(app, &config, f, &granted, tm, block.stage_buf());
                block.commit_frame(e2e, fid);
            }
            traces.push(Trace { config, frames: Arc::new(block) });
        }
        TraceSet {
            app: app.spec.name.clone(),
            seed,
            traces,
            stage_names: app.spec.stages.iter().map(|s| s.name.clone()).collect(),
        }
    }

    /// Generate with the spec's own trace protocol (30 × 1000).
    pub fn generate_default(app: &App, seed: u64) -> Self {
        Self::generate(app, app.spec.trace_configs, app.spec.trace_frames, seed)
    }

    pub fn num_configs(&self) -> usize {
        self.traces.len()
    }

    pub fn num_frames(&self) -> usize {
        self.traces.first().map(|t| t.frames.len()).unwrap_or(0)
    }

    /// Raw knob vectors of all configurations (the candidate action set).
    pub fn configs(&self) -> Vec<Vec<f64>> {
        self.traces.iter().map(|t| t.config.clone()).collect()
    }

    /// Average (cost, reward) per configuration — the gray crosses of the
    /// paper's Fig. 5.
    pub fn payoffs(&self) -> Vec<(f64, f64)> {
        self.traces.iter().map(|t| (t.avg_cost_ms(), t.avg_fidelity())).collect()
    }

    /// The frame record for playing action `config_idx` at time `frame`
    /// (the paper's "predefined alternative futures").
    pub fn frame(&self, config_idx: usize, frame: usize) -> FrameRef<'_> {
        self.traces[config_idx].frames.get(frame)
    }

    // ---- (de)serialization via the in-tree JSON codec -------------------

    pub fn to_json(&self) -> Json {
        let traces: Vec<Json> = self
            .traces
            .iter()
            .map(|t| {
                // the on-disk layout matches the in-memory arena: flat
                // stage matrix + the per-frame scalar columns, so
                // serialization is a straight column copy
                Json::obj()
                    .put("config", Json::from_f64_slice(&t.config))
                    .put("stage_ms_flat", Json::from_f64_slice(t.frames.stage_flat()))
                    .put("end_to_end_ms", Json::from_f64_slice(t.frames.end_to_end()))
                    .put("fidelity", Json::from_f64_slice(t.frames.fidelities()))
            })
            .collect();
        Json::obj()
            .put("app", self.app.as_str())
            .put("seed", self.seed)
            .put(
                "stage_names",
                Json::Arr(self.stage_names.iter().map(|s| Json::from(s.as_str())).collect()),
            )
            .put("traces", Json::Arr(traces))
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let stage_names = v.req("stage_names")?.as_str_vec()?;
        let n_stages = stage_names.len();
        let traces = v
            .req("traces")?
            .as_arr()?
            .iter()
            .map(|t| {
                let config = t.req("config")?.as_f64_vec()?;
                let flat = t.req("stage_ms_flat")?.as_f64_vec()?;
                let e2e = t.req("end_to_end_ms")?.as_f64_vec()?;
                let fid = t.req("fidelity")?.as_f64_vec()?;
                // columns move straight into the arena — no per-frame
                // re-slicing on load
                let block = FrameBlock::from_columns(n_stages, flat, e2e, fid)?;
                Ok(Trace { config, frames: Arc::new(block) })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TraceSet {
            app: v.req("app")?.as_str()?.to_string(),
            seed: v.req("seed")?.as_u64()?,
            traces,
            stage_names,
        })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Conventional trace filename for an app under `dir`.
    pub fn default_path(dir: impl AsRef<Path>, app: &str) -> std::path::PathBuf {
        dir.as_ref().join(format!("{app}_traces.json"))
    }

    /// Load if present, else generate and save (used by experiments).
    pub fn load_or_generate(app: &App, dir: impl AsRef<Path>, seed: u64) -> Result<Self> {
        let path = Self::default_path(&dir, &app.spec.name);
        if path.is_file() {
            let ts = Self::load(&path)?;
            if ts.num_configs() > 0 {
                return Ok(ts);
            }
        }
        let ts = Self::generate_default(app, seed);
        ts.save(&path)?;
        Ok(ts)
    }
}

/// Traces of one action set at a *ladder* of core budgets on a shared
/// cluster — the scheduler's "alternative futures in two dimensions":
/// which action is played, and how many cores the app holds when it runs.
///
/// All levels share the same sampled configurations *and* the same
/// per-config noise streams (the simulator draws the same jitter sequence
/// regardless of the budget), so the core quota is the only thing that
/// differs between `sets[l]` and `sets[l + 1]`. In particular every
/// action's fidelity sequence is identical across levels — parallelism
/// trades latency, never fidelity (paper Sec. 2.2) — which the fleet
/// relies on to score rewards independently of the current allocation.
#[derive(Debug, Clone)]
pub struct LadderTraceSet {
    /// Core budgets, strictly ascending.
    pub levels: Vec<usize>,
    /// `sets[l]` holds the traces at budget `levels[l]`.
    pub sets: Vec<TraceSet>,
}

impl LadderTraceSet {
    /// Trace `n_configs` random configurations for `n_frames` frames at
    /// every budget in `levels`. The config-sampling protocol and the
    /// per-config simulator seeding match [`TraceSet::generate_on`]
    /// exactly, so a one-level ladder at the full budget reproduces the
    /// plain trace set byte-for-byte.
    pub fn generate_on(
        app: &App,
        cluster: &Cluster,
        levels: &[usize],
        n_configs: usize,
        n_frames: usize,
        seed: u64,
    ) -> Self {
        Self::generate_with(app, cluster, levels, n_configs, n_frames, seed, false)
    }

    /// [`generate_on`](Self::generate_on) with exact fairness-floor
    /// accounting: when `time_multiplex` is set, rungs below an action's
    /// granted worker total charge the time-multiplexing latency
    /// multiplier ([`crate::simulator::time_multiplex_factor`]) — the
    /// admission-controlled fleet traces its ladders this way so a
    /// 7-core rung on a 12-stage pipeline is priced honestly.
    ///
    /// **Frame sharing:** the budget reaches an action's execution only
    /// through the worker grant and (in exact mode) the time-multiplex
    /// charge; the per-config noise stream is seeded identically at every
    /// rung. So two rungs whose `(granted workers, tm factor)` signature
    /// matches produce byte-identical frames, and this generator stores
    /// one shared buffer instead of `levels × frames` copies. Per-stage
    /// cost *drift* (`CostModel::cost_drift`, the `--drift` family) is a
    /// pure function of the frame index — rung-invariant — so drifting
    /// apps share exactly as much as their drift-free twins. For a
    /// core-insensitive (light-profile) app every rung shares one buffer —
    /// the dynamic fleet used to replicate those frames `levels`-fold
    /// (~6x wasted peak memory; see [`unique_trace_bytes`] vs
    /// [`logical_trace_bytes`]).
    ///
    /// [`unique_trace_bytes`]: Self::unique_trace_bytes
    /// [`logical_trace_bytes`]: Self::logical_trace_bytes
    pub fn generate_with(
        app: &App,
        cluster: &Cluster,
        levels: &[usize],
        n_configs: usize,
        n_frames: usize,
        seed: u64,
        time_multiplex: bool,
    ) -> Self {
        assert!(!levels.is_empty(), "ladder needs at least one level");
        assert!(
            levels.windows(2).all(|w| w[0] < w[1]),
            "ladder levels must be strictly ascending: {levels:?}"
        );
        let mut rng = Rng::new(seed);
        let configs: Vec<Vec<f64>> = (0..n_configs)
            .map(|_| {
                let u: Vec<f64> = (0..app.spec.num_vars()).map(|_| rng.f64()).collect();
                app.spec.denormalize(&u)
            })
            .collect();
        let stage_names: Vec<String> =
            app.spec.stages.iter().map(|s| s.name.clone()).collect();
        let n_stages = app.graph.len();
        // one cache per config: (granted workers, tm bits) -> shared arena
        type FrameCache = BTreeMap<(Vec<usize>, u64), Arc<FrameBlock>>;
        let mut shared: Vec<FrameCache> = vec![BTreeMap::new(); n_configs];
        let sets = levels
            .iter()
            .map(|&budget| {
                let traces = configs
                    .iter()
                    .enumerate()
                    .map(|(ci, config)| {
                        // the signature mirrors ClusterSim::plan_grant: the
                        // grant is made against the effective budget, and
                        // the tm charge (when on) against the same
                        let eff = budget.min(cluster.total_cores());
                        let requested: Vec<usize> = (0..n_stages)
                            .map(|s| app.model.requested_workers(s, config))
                            .collect();
                        let granted = grant_under(&requested, eff);
                        let tm = if time_multiplex {
                            time_multiplex_factor(granted.iter().sum(), eff)
                        } else {
                            1.0
                        };
                        let key = (granted.clone(), tm.to_bits());
                        let frames = match shared[ci].entry(key) {
                            std::collections::hash_map::Entry::Occupied(e) => {
                                e.get().clone()
                            }
                            std::collections::hash_map::Entry::Vacant(e) => {
                                let mut sim = ClusterSim::new(
                                    cluster.clone(),
                                    NoiseModel::default(),
                                    seed.wrapping_mul(1_000_003).wrapping_add(ci as u64),
                                )
                                .with_core_budget(budget)
                                .with_time_multiplex(time_multiplex);
                                // stream every frame into one columnar
                                // arena; the grant plan (already the cache
                                // key) is reused instead of recomputed
                                // per frame
                                let mut block =
                                    FrameBlock::with_capacity(n_stages, n_frames);
                                for f in 0..n_frames {
                                    let (e2e, fid) = sim.run_frame_cols(
                                        app,
                                        config,
                                        f,
                                        &granted,
                                        tm,
                                        block.stage_buf(),
                                    );
                                    block.commit_frame(e2e, fid);
                                }
                                e.insert(Arc::new(block)).clone()
                            }
                        };
                        Trace { config: config.clone(), frames }
                    })
                    .collect();
                TraceSet {
                    app: app.spec.name.clone(),
                    seed,
                    traces,
                    stage_names: stage_names.clone(),
                }
            })
            .collect();
        LadderTraceSet { levels: levels.to_vec(), sets }
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn num_configs(&self) -> usize {
        self.sets[0].num_configs()
    }

    pub fn num_frames(&self) -> usize {
        self.sets[0].num_frames()
    }

    /// The trace set at ladder index `level`.
    pub fn set(&self, level: usize) -> &TraceSet {
        &self.sets[level]
    }

    /// Raw knob vectors of the shared action set.
    pub fn configs(&self) -> Vec<Vec<f64>> {
        self.sets[0].configs()
    }

    /// Index of the largest level whose budget is `<= cores` (0 when even
    /// the lowest rung exceeds `cores` — the fairness floor).
    pub fn level_for(&self, cores: usize) -> usize {
        let mut best = 0;
        for (i, &l) in self.levels.iter().enumerate() {
            if l <= cores {
                best = i;
            }
        }
        best
    }

    /// Heap bytes one frame occupies in the columnar arena: `n_stages`
    /// latency cells plus the two per-frame scalar cells. (The pre-arena
    /// layout also paid a 40-byte `TraceFrame` struct per frame — vector
    /// header plus scalars — so the same ladder now holds strictly fewer
    /// bytes; the `ladder_trace/*_peak_bytes` trajectory metrics stepped
    /// down accordingly at PR 8.)
    fn frame_bytes(&self) -> usize {
        let n_stages = self.sets[0].stage_names.len();
        (n_stages + 2) * std::mem::size_of::<f64>()
    }

    /// Trace bytes a share-less ladder would hold:
    /// `levels × configs × frames × frame_bytes`.
    pub fn logical_trace_bytes(&self) -> usize {
        self.num_levels() * self.num_configs() * self.num_frames() * self.frame_bytes()
    }

    /// Trace bytes actually held: frames are counted once per *unique*
    /// shared buffer, not once per rung. This is the peak-memory number
    /// the bench trajectory records (`ladder_trace` metrics in
    /// `BENCH_<sha>.json`).
    pub fn unique_trace_bytes(&self) -> usize {
        let mut seen = std::collections::BTreeSet::new();
        let mut frames = 0usize;
        for set in &self.sets {
            for t in &set.traces {
                if seen.insert(Arc::as_ptr(&t.frames)) {
                    frames += t.frames.len();
                }
            }
        }
        frames * self.frame_bytes()
    }

    /// `logical / unique` — 1.0 when nothing is shared; ~`levels` for a
    /// core-insensitive app whose grant never varies with the budget.
    pub fn sharing_ratio(&self) -> f64 {
        self.logical_trace_bytes() as f64 / self.unique_trace_bytes().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    fn small(app_name: &str) -> (App, TraceSet) {
        let app = app_by_name(app_name, find_spec_dir(None).unwrap()).unwrap();
        let ts = TraceSet::generate(&app, 6, 40, 42);
        (app, ts)
    }

    #[test]
    fn protocol_shape() {
        let (_, ts) = small("pose");
        assert_eq!(ts.num_configs(), 6);
        assert_eq!(ts.num_frames(), 40);
        assert_eq!(ts.stage_names.len(), 7);
    }

    #[test]
    fn frac_under_counts_frames() {
        let mut block = FrameBlock::new(1);
        for e in [40.0, 60.0, 50.0, 45.0] {
            block.push(&[e], e, 0.5);
        }
        let t = Trace { config: vec![1.0], frames: Arc::new(block) };
        assert!((t.frac_under(50.0) - 0.75).abs() < 1e-12);
        assert_eq!(t.frac_under(10.0), 0.0);
        assert_eq!(t.frac_under(100.0), 1.0);
        let empty = Trace { config: vec![], frames: Arc::new(FrameBlock::new(1)) };
        assert_eq!(empty.frac_under(1.0), 0.0);
    }

    #[test]
    fn frame_block_arena_discipline() {
        // push / stage_buf+commit_frame must be interchangeable and the
        // columnar views must line up with the per-frame refs.
        let mut a = FrameBlock::with_capacity(3, 2);
        a.push(&[1.0, 2.0, 3.0], 6.0, 0.5);
        let mut b = FrameBlock::new(3);
        b.stage_buf().extend_from_slice(&[1.0, 2.0, 3.0]);
        b.commit_frame(6.0, 0.5);
        assert_eq!(a.stage_flat(), b.stage_flat());
        assert_eq!(a.end_to_end(), b.end_to_end());
        assert_eq!(a.fidelities(), b.fidelities());

        a.push(&[4.0, 5.0, 6.0], 15.0, 0.75);
        assert_eq!(a.len(), 2);
        assert!(!a.is_empty());
        assert_eq!(a.n_stages(), 3);
        let f1 = a.get(1);
        assert_eq!(f1.stage_ms, &[4.0, 5.0, 6.0]);
        assert_eq!(f1.end_to_end_ms, 15.0);
        assert_eq!(f1.fidelity, 0.75);
        let e2e: Vec<f64> = a.iter().map(|f| f.end_to_end_ms).collect();
        assert_eq!(e2e, vec![6.0, 15.0]);
        assert_eq!(a.stage_flat(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // one flat stage matrix + two scalar columns, no per-frame Vecs
        assert_eq!(
            a.heap_bytes(),
            (6 + 2 + 2) * std::mem::size_of::<f64>()
        );

        let c = FrameBlock::from_columns(
            3,
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![6.0, 15.0],
            vec![0.5, 0.75],
        )
        .unwrap();
        assert_eq!(c.stage_flat(), a.stage_flat());
        assert!(
            FrameBlock::from_columns(3, vec![1.0; 5], vec![6.0], vec![0.5]).is_err(),
            "ragged stage column must be rejected"
        );
    }

    #[test]
    fn configs_are_valid() {
        let (app, ts) = small("pose");
        for cfg in ts.configs() {
            for (p, &k) in app.spec.params.iter().zip(&cfg) {
                assert!(k >= p.min && k <= p.max, "{} = {k}", p.symbol);
                if p.is_discrete() {
                    assert_eq!(k, k.round());
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let a = TraceSet::generate(&app, 3, 10, 7);
        let b = TraceSet::generate(&app, 3, 10, 7);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        let c = TraceSet::generate(&app, 3, 10, 8);
        assert_ne!(a.to_json().to_string(), c.to_json().to_string());
    }

    #[test]
    fn payoffs_spread_over_cost_space() {
        let (_, ts) = small("motion_sift");
        let payoffs = ts.payoffs();
        let min = payoffs.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let max = payoffs.iter().map(|p| p.0).fold(0.0, f64::max);
        assert!(max > min * 1.5, "configs should differ: {min}..{max}");
        assert!(payoffs.iter().all(|p| (0.0..=1.0).contains(&p.1)));
    }

    #[test]
    fn save_load_roundtrip() {
        let (_, ts) = small("pose");
        let dir = crate::util::testdir::TestDir::new("trace");
        let path = dir.join("t.json");
        ts.save(&path).unwrap();
        let back = TraceSet::load(&path).unwrap();
        assert_eq!(back.num_configs(), ts.num_configs());
        assert_eq!(
            back.traces[0].frames.get(3).end_to_end_ms,
            ts.traces[0].frames.get(3).end_to_end_ms
        );
    }

    #[test]
    fn load_or_generate_idempotent() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let dir = crate::util::testdir::TestDir::new("trace-gen");
        // override the protocol to keep the test fast
        let mut small_app = app;
        small_app.spec.trace_configs = 3;
        small_app.spec.trace_frames = 5;
        let a = TraceSet::load_or_generate(&small_app, dir.path(), 1).unwrap();
        let b = TraceSet::load_or_generate(&small_app, dir.path(), 999).unwrap();
        assert_eq!(a.seed, b.seed, "second call must hit the cache");
    }

    #[test]
    fn ladder_full_budget_level_matches_plain_traces() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let cluster = Cluster::default();
        let full = cluster.total_cores();
        let ladder =
            LadderTraceSet::generate_on(&app, &cluster, &[8, full], 5, 30, 77);
        let plain = TraceSet::generate_on(&app, &cluster, 5, 30, 77);
        assert_eq!(
            ladder.set(1).to_json().to_string(),
            plain.to_json().to_string(),
            "full-budget ladder level must reproduce the plain trace set"
        );
    }

    #[test]
    fn ladder_levels_share_configs_and_fidelity() {
        let app = crate::workloads::generate(5, &crate::workloads::WorkloadConfig::default());
        let ladder = LadderTraceSet::generate_on(
            &app,
            &Cluster::default(),
            &[6, 15, 45],
            6,
            40,
            3,
        );
        assert_eq!(ladder.num_levels(), 3);
        for l in 1..3 {
            for c in 0..ladder.num_configs() {
                assert_eq!(
                    ladder.set(l).traces[c].config,
                    ladder.set(0).traces[c].config,
                    "configs must be shared across levels"
                );
                for f in 0..ladder.num_frames() {
                    // the budget changes latency, never fidelity: same
                    // noise stream, and parallelism is fidelity-neutral
                    assert_eq!(
                        ladder.set(l).frame(c, f).fidelity,
                        ladder.set(0).frame(c, f).fidelity,
                        "level {l} config {c} frame {f}"
                    );
                }
            }
        }
    }

    #[test]
    fn exact_accounting_ladder_prices_tiny_rungs_honestly() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap(); // 7 stages
        let cluster = Cluster::default();
        let plain = LadderTraceSet::generate_on(&app, &cluster, &[4, 120], 4, 20, 9);
        let exact = LadderTraceSet::generate_with(&app, &cluster, &[4, 120], 4, 20, 9, true);
        // the 4-core rung is strictly slower under exact accounting ...
        for c in 0..4 {
            for f in 0..20 {
                assert!(
                    exact.set(0).frame(c, f).end_to_end_ms
                        > plain.set(0).frame(c, f).end_to_end_ms,
                    "config {c} frame {f}"
                );
                // ... and fidelity is untouched (latency-only charge)
                assert_eq!(
                    exact.set(0).frame(c, f).fidelity,
                    plain.set(0).frame(c, f).fidelity
                );
            }
        }
        // budgets the grants never exceed (pose requests at most 120
        // workers) are byte-identical — no silent repricing
        assert_eq!(
            exact.set(1).to_json().to_string(),
            plain.set(1).to_json().to_string()
        );
    }

    #[test]
    fn ladder_level_for_picks_largest_fitting() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let ladder = LadderTraceSet::generate_on(
            &app,
            &Cluster::default(),
            &[7, 15, 31],
            2,
            5,
            1,
        );
        assert_eq!(ladder.level_for(6), 0); // below the floor: floor rung
        assert_eq!(ladder.level_for(7), 0);
        assert_eq!(ladder.level_for(16), 1);
        assert_eq!(ladder.level_for(500), 2);
    }

    #[test]
    fn ladder_shares_frames_across_equal_grant_rungs() {
        // a light-profile app never requests more than one worker per
        // stage: every rung's grant is identical, so the whole ladder
        // shares one frame buffer per config (the ~6x dynamic-fleet
        // memory fix) while staying value-identical to plain generation
        let cfg = crate::workloads::WorkloadConfig {
            profile: crate::workloads::AppProfile::Light,
            ..Default::default()
        };
        let app = crate::workloads::generate(42, &cfg);
        let levels = vec![7, 10, 15, 21, 31, 45];
        let ladder =
            LadderTraceSet::generate_on(&app, &Cluster::default(), &levels, 5, 30, 9);
        assert_eq!(
            ladder.unique_trace_bytes() * levels.len(),
            ladder.logical_trace_bytes(),
            "every rung must share the light app's frames"
        );
        assert!(ladder.sharing_ratio() >= 4.0, "{}", ladder.sharing_ratio());
        for l in 1..ladder.num_levels() {
            for c in 0..ladder.num_configs() {
                assert!(
                    Arc::ptr_eq(&ladder.set(l).traces[c].frames, &ladder.set(0).traces[c].frames),
                    "level {l} config {c} not shared"
                );
            }
        }
    }

    #[test]
    fn ladder_sharing_key_separates_exact_accounting_rungs() {
        // under exact accounting a light app's tm factor differs at every
        // sub-stage-count budget, so tiny rungs must NOT share with the
        // full-budget rung — but rungs at or above the stage count (tm 1,
        // same grant) still do
        let cfg = crate::workloads::WorkloadConfig {
            profile: crate::workloads::AppProfile::Light,
            ..Default::default()
        };
        let app = crate::workloads::generate(42, &cfg);
        let n_stages = app.graph.len();
        let levels = vec![2, 3, n_stages + 1, n_stages + 9];
        let exact = LadderTraceSet::generate_with(
            &app,
            &Cluster::default(),
            &levels,
            4,
            20,
            9,
            true,
        );
        for c in 0..exact.num_configs() {
            assert!(
                !Arc::ptr_eq(&exact.set(0).traces[c].frames, &exact.set(1).traces[c].frames),
                "distinct tm factors must not share (config {c})"
            );
            assert!(
                Arc::ptr_eq(&exact.set(2).traces[c].frames, &exact.set(3).traces[c].frames),
                "tm-free rungs with equal grants must share (config {c})"
            );
            assert!(
                exact.set(0).frame(c, 3).end_to_end_ms > exact.set(2).frame(c, 3).end_to_end_ms,
                "tiny rung must stay priced honestly (config {c})"
            );
        }
        assert!(exact.sharing_ratio() > 1.0);
    }

    #[test]
    fn drifting_ladder_shares_frames_like_its_driftfree_twin() {
        // drift multiplies stage costs as a pure function of the frame
        // index — identical at every rung — so the rung-sharing memory
        // win survives, while the frames themselves move with the walk
        let plain_cfg = crate::workloads::WorkloadConfig {
            profile: crate::workloads::AppProfile::Light,
            ..Default::default()
        };
        let drift_cfg = crate::workloads::WorkloadConfig {
            drift: Some(0.25),
            ..plain_cfg.clone()
        };
        let plain = crate::workloads::generate(42, &plain_cfg);
        let drifting = crate::workloads::generate(42, &drift_cfg);
        let levels = vec![7, 15, 45];
        let lp = LadderTraceSet::generate_on(&plain, &Cluster::default(), &levels, 4, 30, 9);
        let ld =
            LadderTraceSet::generate_on(&drifting, &Cluster::default(), &levels, 4, 30, 9);
        assert_eq!(
            ld.sharing_ratio(),
            lp.sharing_ratio(),
            "drift must not break rung sharing"
        );
        // same action set (drift is rng-neutral), different frame costs
        for c in 0..4 {
            assert_eq!(ld.set(0).traces[c].config, lp.set(0).traces[c].config);
        }
        let moved = (0..4).any(|c| {
            (0..30).any(|f| {
                ld.set(0).frame(c, f).end_to_end_ms != lp.set(0).frame(c, f).end_to_end_ms
            })
        });
        assert!(moved, "drift never changed a single frame cost");
        // and fidelity is untouched (drift is cost-only)
        for c in 0..4 {
            for f in 0..30 {
                assert_eq!(ld.set(0).frame(c, f).fidelity, lp.set(0).frame(c, f).fidelity);
            }
        }
    }

    #[test]
    fn heavy_app_rungs_stay_distinct() {
        // a heavy app's grants differ per budget below its request total:
        // sharing must not conflate rungs that execute differently
        let cfg = crate::workloads::WorkloadConfig {
            profile: crate::workloads::AppProfile::Heavy,
            ..Default::default()
        };
        let app = crate::workloads::generate(43, &cfg);
        let ladder = LadderTraceSet::generate_on(
            &app,
            &Cluster::default(),
            &[7, 15, 45],
            4,
            20,
            11,
        );
        // the ladder keeps per-rung latencies monotone-ish: squeezed rungs
        // are never faster than the top rung on requested-parallel configs
        assert!(ladder.unique_trace_bytes() <= ladder.logical_trace_bytes());
        assert_eq!(ladder.num_levels(), 3);
    }

    #[test]
    fn scene_change_visible_in_pose_traces() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let ts = TraceSet::generate(&app, 2, 700, 3);
        let t = &ts.traces[0];
        let before: f64 =
            (550..600).map(|f| t.frames.get(f).end_to_end_ms).sum::<f64>() / 50.0;
        let after: f64 =
            (600..650).map(|f| t.frames.get(f).end_to_end_ms).sum::<f64>() / 50.0;
        assert!(after > before * 1.1, "frame-600 jump: {before} -> {after}");
    }
}
