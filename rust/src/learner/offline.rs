//! Offline (batch) baselines — the dashed lines of the paper's Fig. 6:
//! "the errors of the corresponding offline predictors".
//!
//! The offline predictor sees the whole trace up front and makes multiple
//! shuffled passes of the same ε-insensitive OGD update until the
//! held-in error plateaus — the batch optimum the online learner is
//! compared against.

use crate::util::Rng;

use super::{StagePredictor, Variant};
use crate::apps::spec::AppSpec;

/// One training sample: normalized knobs + frame measurements.
#[derive(Debug, Clone)]
pub struct Sample {
    pub u: Vec<f64>,
    pub stage_ms: Vec<f64>,
    pub end_to_end_ms: f64,
}

/// Batch-fit a predictor on `samples`.
///
/// Runs up to `max_epochs` shuffled passes, stopping early when the mean
/// absolute end-to-end error improves by < 1% between epochs.
pub fn fit(
    spec: &AppSpec,
    variant: Variant,
    degree: usize,
    samples: &[Sample],
    max_epochs: usize,
    seed: u64,
) -> StagePredictor {
    let mut pred = StagePredictor::new(spec, variant, degree);
    let mut order: Vec<usize> = (0..samples.len()).collect();
    let mut rng = Rng::new(seed);
    let mut prev = f64::INFINITY;
    for _epoch in 0..max_epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let s = &samples[i];
            pred.observe(&s.u, &s.stage_ms, s.end_to_end_ms);
        }
        let err = mean_abs_error(&mut pred, samples);
        if prev.is_finite() && (prev - err) < 0.01 * prev {
            break;
        }
        prev = err;
    }
    pred
}

/// Mean absolute end-to-end error of `pred` over `samples`.
pub fn mean_abs_error(pred: &mut StagePredictor, samples: &[Sample]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples
        .iter()
        .map(|s| (pred.predict(&s.u) - s.end_to_end_ms).abs())
        .sum::<f64>()
        / samples.len() as f64
}

/// Max-norm end-to-end error of `pred` over `samples`.
pub fn max_abs_error(pred: &mut StagePredictor, samples: &[Sample]) -> f64 {
    samples
        .iter()
        .map(|s| (pred.predict(&s.u) - s.end_to_end_ms).abs())
        .fold(0.0, f64::max)
}

/// Build offline training samples from a trace set (every config × frame).
pub fn samples_from_traces(
    spec: &AppSpec,
    traces: &crate::trace::TraceSet,
) -> Vec<Sample> {
    let mut out = Vec::new();
    for t in &traces.traces {
        let u = spec.normalize(&t.config);
        for f in t.frames.iter() {
            out.push(Sample {
                u: u.clone(),
                stage_ms: f.stage_ms.to_vec(),
                end_to_end_ms: f.end_to_end_ms,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::trace::TraceSet;

    #[test]
    fn offline_beats_or_matches_online() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 10, 60, 2);
        let samples = samples_from_traces(&app.spec, &traces);

        // online: single *shuffled* pass (the Fig. 6 protocol visits
        // random actions, not config-by-config order)
        let mut order: Vec<usize> = (0..samples.len()).collect();
        crate::util::Rng::new(5).shuffle(&mut order);
        let mut online = StagePredictor::new(&app.spec, Variant::Structured, 3);
        let mut online_err = 0.0;
        for &i in &order {
            let s = &samples[i];
            online_err += (online.observe(&s.u, &s.stage_ms, s.end_to_end_ms)
                - s.end_to_end_ms)
                .abs();
        }
        online_err /= samples.len() as f64;

        let mut offline = fit(&app.spec, Variant::Structured, 3, &samples, 20, 0);
        let offline_err = mean_abs_error(&mut offline, &samples);
        assert!(
            offline_err <= online_err * 1.1,
            "offline {offline_err} should not lose to online progressive {online_err}"
        );
    }

    #[test]
    fn fit_converges_on_small_set() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let traces = TraceSet::generate(&app, 6, 30, 3);
        let samples = samples_from_traces(&app.spec, &traces);
        let mut pred = fit(&app.spec, Variant::Unstructured, 3, &samples, 30, 1);
        let err = mean_abs_error(&mut pred, &samples);
        let scale: f64 = samples.iter().map(|s| s.end_to_end_ms).sum::<f64>()
            / samples.len() as f64;
        assert!(err < scale * 0.5, "err {err} vs scale {scale}");
    }

    #[test]
    fn empty_samples_safe() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let mut pred = StagePredictor::new(&app.spec, Variant::Structured, 3);
        assert_eq!(mean_abs_error(&mut pred, &[]), 0.0);
    }
}
