//! Online gradient descent on the ε-insensitive SVR loss (paper
//! Sec. 3.2–3.3, Eq. 3–8): the Zinkevich online-convex-programming update
//!
//!   f_{t+1} = P(f_t − η_t ∇ℓ_t(f_t)),
//!   ℓ_t(f)  = max(|f(z_t) − c_t| − ε, 0) + γ‖f‖²
//!
//! over an explicit polynomial feature expansion (linear SVR in the
//! expanded space).
//!
//! One practical refinement: the step size is *clipped by the
//! passive-aggressive step* τ* = max(|err|−ε, 0)/‖φ‖² (Crammer et al.'s
//! PA-I), so a single update never overshoots the current sample. The
//! effective schedule η_t' = min(η₀/√t, τ*) is pointwise ≤ the Zinkevich
//! schedule, preserving the O(√T) regret bound while giving the fast
//! initial fit online SVR needs with 56-dimensional cubic expansions.
//!
//! This is the *native* Rust twin of the Pallas `ogd_update` kernel; the
//! two are cross-checked in the runtime integration tests.

use super::features::FeatureMap;

/// Paper: "In all of our experiments, γ = 0.01".
pub const GAMMA: f64 = 0.01;
/// ε-insensitivity zone, in ms (matches `python/compile/spec.py`).
pub const EPS_INSENSITIVE_MS: f64 = 1.0;
/// Default learning-rate scale; η_t = η₀/√t (in normalized target units).
pub const DEFAULT_ETA0: f64 = 1.0;
/// Damping of the passive-aggressive step: a full step (1.0) fits each
/// sample exactly but chases measurement noise in high-dimensional cubic
/// expansions; a half step averages noise while still converging fast.
pub const PA_DAMPING: f64 = 0.5;
/// Latency normalization: targets are divided by this before the SVR
/// update (standard ε-SVR practice — with raw-millisecond targets the
/// γ‖f‖² shrinkage would bias the bounded ±1 subgradient steps). The
/// paper's γ = 0.01 applies in this normalized space; the AOT artifacts
/// use the same convention (see python/compile/spec.py).
pub const LATENCY_SCALE_MS: f64 = 100.0;

/// A single online SVR regressor over a compact monomial expansion.
#[derive(Debug, Clone)]
pub struct OgdRegressor {
    features: FeatureMap,
    /// Weights in *normalized* target space (ms / [`LATENCY_SCALE_MS`]).
    w: Vec<f64>,
    /// Update counter (drives the η_t = η₀/√t schedule).
    t: u64,
    pub eta0: f64,
    pub gamma: f64,
    /// ε-insensitivity zone in ms.
    pub eps: f64,
    /// Target normalization (ms per weight unit).
    pub scale: f64,
    /// Scratch buffer for φ(u) — kept to avoid hot-loop allocation.
    phi: Vec<f64>,
}

impl OgdRegressor {
    /// Regressor over monomials of degree ≤ `degree` of the knob subset
    /// `vars` (global indices into the normalized knob vector).
    pub fn new(vars: &[usize], degree: usize) -> Self {
        let features = FeatureMap::new(vars, degree);
        let n = features.len();
        OgdRegressor {
            features,
            w: vec![0.0; n],
            t: 0,
            eta0: DEFAULT_ETA0,
            gamma: GAMMA,
            eps: EPS_INSENSITIVE_MS,
            scale: LATENCY_SCALE_MS,
            phi: vec![0.0; n],
        }
    }

    pub fn with_eta0(mut self, eta0: f64) -> Self {
        self.eta0 = eta0;
        self
    }

    pub fn num_features(&self) -> usize {
        self.w.len()
    }

    pub fn weights(&self) -> &[f64] {
        &self.w
    }

    pub fn feature_map(&self) -> &FeatureMap {
        &self.features
    }

    /// f(u) = scale · ⟨w, φ(u)⟩ in ms. `&mut self` only to reuse the φ
    /// scratch buffer.
    pub fn predict(&mut self, u: &[f64]) -> f64 {
        let phi = std::mem::take(&mut self.phi);
        let mut phi = phi;
        self.features.expand_into(u, &mut phi);
        let y: f64 = self.w.iter().zip(&phi).map(|(w, p)| w * p).sum();
        self.phi = phi;
        y * self.scale
    }

    /// Allocation-free prediction with caller-provided φ scratch.
    pub fn predict_with(&self, u: &[f64], phi: &mut [f64]) -> f64 {
        self.features.expand_into(u, phi);
        let y: f64 = self.w.iter().zip(phi.iter()).map(|(w, p)| w * p).sum();
        y * self.scale
    }

    /// One OGD step on observation (u, y) with η_t = η₀/√t.
    /// Returns the pre-update prediction (handy for error tracking).
    pub fn update(&mut self, u: &[f64], y: f64) -> f64 {
        self.t += 1;
        let eta = self.eta0 / (self.t as f64).sqrt();
        self.update_with_eta(u, y, eta)
    }

    /// One OGD step with an explicit learning rate. `y` is in ms; the
    /// update happens in normalized space. Returns the pre-update
    /// prediction in ms.
    pub fn update_with_eta(&mut self, u: &[f64], y: f64, eta: f64) -> f64 {
        let mut phi = std::mem::take(&mut self.phi);
        self.features.expand_into(u, &mut phi);
        let pred: f64 = self.w.iter().zip(&phi).map(|(w, p)| w * p).sum();
        let err = pred - y / self.scale;
        let eps_s = self.eps / self.scale;
        let loss = (err.abs() - eps_s).max(0.0);
        if loss > 0.0 {
            // PA-clipped OGD step (see module docs): never overshoot the
            // current sample
            let phi_norm2: f64 = phi.iter().map(|p| p * p).sum::<f64>().max(1e-12);
            let tau = eta.min(PA_DAMPING * loss / phi_norm2);
            let g = err.signum();
            for (w, p) in self.w.iter_mut().zip(&phi) {
                *w -= tau * g * p + eta * 2.0 * self.gamma * *w;
            }
        } else {
            // inside the insensitive zone: regularization shrink only
            for w in self.w.iter_mut() {
                *w -= eta * 2.0 * self.gamma * *w;
            }
        }
        self.phi = phi;
        pred * self.scale
    }

    /// Reset weights and schedule (fresh learner).
    pub fn reset(&mut self) {
        self.w.iter_mut().for_each(|w| *w = 0.0);
        self.t = 0;
    }
}

/// Moving average for non-critical stages (paper Sec. 2.3: "some stages
/// contribute little to total latency ... and may be modeled very simply
/// (e.g., with an average)").
#[derive(Debug, Clone)]
pub struct MovingAverage {
    window: usize,
    buf: std::collections::VecDeque<f64>,
    sum: f64,
}

impl MovingAverage {
    pub fn new(window: usize) -> Self {
        assert!(window > 0);
        MovingAverage { window, buf: std::collections::VecDeque::with_capacity(window), sum: 0.0 }
    }

    pub fn observe(&mut self, x: f64) {
        if self.buf.len() == self.window {
            // detlint: allow(unwrap) — pop only runs when len() == window and window > 0 (asserted in new)
            self.sum -= self.buf.pop_front().unwrap();
        }
        self.buf.push_back(x);
        self.sum += x;
    }

    pub fn value(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.sum / self.buf.len() as f64
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn learns_linear_target() {
        let mut r = OgdRegressor::new(&[0, 1], 1);
        let mut rng = Rng::new(0);
        for _ in 0..2000 {
            let u = [rng.f64(), rng.f64()];
            let y = 20.0 + 30.0 * u[0] - 10.0 * u[1];
            r.update(&u, y);
        }
        let mut worst: f64 = 0.0;
        for _ in 0..100 {
            let u = [rng.f64(), rng.f64()];
            let y = 20.0 + 30.0 * u[0] - 10.0 * u[1];
            worst = worst.max((r.predict(&u) - y).abs());
        }
        assert!(worst < 6.0, "worst {worst}");
    }

    #[test]
    fn learns_cubic_target_with_cubic_features() {
        let mut r = OgdRegressor::new(&[0], 3);
        let mut rng = Rng::new(1);
        let f = |x: f64| 10.0 + 40.0 * x * x * x;
        for _ in 0..6000 {
            let x = rng.f64();
            r.update(&[x], f(x));
        }
        let mut sum = 0.0;
        for i in 0..100 {
            let x = i as f64 / 99.0;
            sum += (r.predict(&[x]) - f(x)).abs();
        }
        assert!(sum / 100.0 < 4.0, "avg err {}", sum / 100.0);
    }

    #[test]
    fn linear_features_cannot_fit_cubic_as_well() {
        let fit = |degree: usize| {
            let mut r = OgdRegressor::new(&[0], degree);
            let mut rng = Rng::new(2);
            let f = |x: f64| 5.0 + 60.0 * (x - 0.5).powi(3) + 30.0 * x * x;
            for _ in 0..6000 {
                let x = rng.f64();
                r.update(&[x], f(x));
            }
            let mut sum = 0.0;
            for i in 0..200 {
                let x = i as f64 / 199.0;
                sum += (r.predict(&[x]) - f(x)).abs();
            }
            sum / 200.0
        };
        let (lin, cub) = (fit(1), fit(3));
        assert!(cub < lin, "cubic {cub} should beat linear {lin}");
    }

    #[test]
    fn no_update_inside_insensitive_zone() {
        let mut r = OgdRegressor::new(&[0], 1);
        r.update(&[0.5], 0.5); // |0 - 0.5ms| < eps=1ms -> only shrinkage of 0 weights
        assert!(r.weights().iter().all(|&w| w == 0.0));
    }

    #[test]
    fn predictions_track_millisecond_scale() {
        // weights live in normalized space but the API is ms-in, ms-out
        let mut r = OgdRegressor::new(&[0], 1);
        for t in 0..2000 {
            let x = (t % 100) as f64 / 99.0;
            r.update(&[x], 200.0 + 100.0 * x);
        }
        let p = r.predict(&[0.5]);
        assert!((p - 250.0).abs() < 20.0, "{p}");
        assert!(r.weights().iter().all(|&w| w.abs() < 10.0), "normalized weights");
    }

    #[test]
    fn predict_with_matches_predict() {
        let mut r = OgdRegressor::new(&[0, 1, 2], 3);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let u = [rng.f64(), rng.f64(), rng.f64()];
            r.update(&u, 100.0 * u[0]);
        }
        let u = [0.3, 0.6, 0.9];
        let mut phi = vec![0.0; r.num_features()];
        assert_eq!(r.predict(&u), r.predict_with(&u, &mut phi));
    }

    #[test]
    fn reset_clears_state() {
        let mut r = OgdRegressor::new(&[0], 2);
        r.update(&[0.9], 50.0);
        assert!(r.weights().iter().any(|&w| w != 0.0));
        r.reset();
        assert!(r.weights().iter().all(|&w| w == 0.0));
    }

    #[test]
    fn regret_sublinear_sanity() {
        // average per-step loss falls to (near) the eps floor: regret stays
        // sublinear on a realizable target
        let mut r = OgdRegressor::new(&[0, 1], 2);
        let mut rng = Rng::new(4);
        let mut early = 0.0;
        let mut late = 0.0;
        for t in 0..4000 {
            let u = [rng.f64(), rng.f64()];
            let y = 15.0 + 25.0 * u[0] * u[1];
            let pred = r.update(&u, y);
            let loss = (pred - y).abs();
            if t < 200 {
                early += loss;
            } else if t >= 3000 {
                late += loss;
            }
        }
        early /= 200.0;
        late /= 1000.0;
        assert!(late < early * 0.5, "late {late} vs early {early}");
        assert!(late < 2.0, "late per-step error {late} ms should sit near eps");
    }

    #[test]
    fn moving_average_window() {
        let mut ma = MovingAverage::new(3);
        assert_eq!(ma.value(), 0.0);
        ma.observe(1.0);
        ma.observe(2.0);
        ma.observe(3.0);
        assert!((ma.value() - 2.0).abs() < 1e-12);
        ma.observe(10.0); // evicts 1.0
        assert!((ma.value() - 5.0).abs() < 1e-12);
        assert_eq!(ma.len(), 3);
    }
}
