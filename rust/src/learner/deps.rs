//! Dependency analysis (paper Sec. 2.3): identify *critical stages* from
//! a few observations of stage latencies, then associate with each
//! critical stage the knobs whose value correlates with the stage's
//! latency above a threshold (0.9 in the paper).
//!
//! The probes vary one knob at a time over its normalized range while the
//! others sit at a mid-range operating point — the "additional periodic
//! observations" the paper describes — so correlations are not diluted by
//! simultaneous variation of other knobs. The dependence measure is the
//! *correlation ratio* η (between-bucket over total standard deviation):
//! unlike Pearson/Spearman it detects the U-shaped responses that
//! data-parallelism knobs produce (speedup first, dispatch overhead
//! later), while staying in [0, 1] with the paper's 0.9 threshold
//! semantics.

use crate::apps::App;
use crate::simulator::{Cluster, ClusterSim, NoiseModel};

/// Paper's association threshold.
pub const CORRELATION_THRESHOLD: f64 = 0.9;
/// A stage is critical if its mean latency exceeds this fraction of the
/// mean end-to-end latency.
pub const CRITICAL_FRACTION: f64 = 0.05;

/// Result of the analysis.
#[derive(Debug, Clone)]
pub struct DependencyAnalysis {
    /// Stage ids deemed critical.
    pub critical_stages: Vec<usize>,
    /// For every stage (critical or not): knob indices with η ≥ 0.9.
    pub associated_params: Vec<Vec<usize>>,
    /// Correlation-ratio matrix η, `[stage][param]`.
    pub correlation: Vec<Vec<f64>>,
}

/// Dependence measure: max of the correlation ratio on raw values and on
/// rank-transformed values. Ranks make smooth monotone *and* U-shaped
/// responses score near 1 regardless of curvature; raw values catch
/// regime effects (e.g. a feature-count cap binding only at one end of
/// the sweep) whose rank signal is diluted. Independent noise stays well
/// below the 0.9 threshold for our probe counts.
pub fn dependence(xs: &[f64], ys: &[f64], buckets: usize) -> f64 {
    let raw = correlation_ratio(xs, ys, buckets);
    let ranked = correlation_ratio(xs, &rank_transform(ys), buckets);
    raw.max(ranked)
}

fn rank_transform(ys: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..ys.len()).collect();
    idx.sort_by(|&a, &b| ys[a].partial_cmp(&ys[b]).unwrap());
    let mut r = vec![0.0; ys.len()];
    for (rank, &i) in idx.iter().enumerate() {
        r[i] = rank as f64;
    }
    r
}

/// Correlation ratio η of `ys` grouped by the (sorted-x) bucket index:
/// sqrt(between-bucket variance / total variance) ∈ [0, 1]. `xs` must be
/// the swept knob values; buckets partition its range evenly.
pub fn correlation_ratio(xs: &[f64], ys: &[f64], buckets: usize) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(buckets >= 2);
    let (lo, hi) = xs
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(l, h), &x| (l.min(x), h.max(x)));
    if hi <= lo {
        return 0.0;
    }
    let mut sums = vec![0.0; buckets];
    let mut counts = vec![0usize; buckets];
    for (&x, &y) in xs.iter().zip(ys) {
        // detlint: allow(lossy-cast) — bucket index: min() clamps to [0, buckets-1]; truncation is the binning rule
        let b = (((x - lo) / (hi - lo)) * buckets as f64).min(buckets as f64 - 1.0) as usize;
        sums[b] += y;
        counts[b] += 1;
    }
    let n = ys.len() as f64;
    let grand = ys.iter().sum::<f64>() / n;
    let mut ss_between = 0.0;
    for b in 0..buckets {
        if counts[b] > 0 {
            let m = sums[b] / counts[b] as f64;
            ss_between += counts[b] as f64 * (m - grand).powi(2);
        }
    }
    let ss_total: f64 = ys.iter().map(|&y| (y - grand).powi(2)).sum();
    if ss_total <= 0.0 {
        0.0
    } else {
        (ss_between / ss_total).sqrt()
    }
}

/// Run the probe schedule and compute the analysis.
///
/// `probes_per_param` observations are taken per knob, sweeping it over
/// its normalized range at a *fixed* frame (content held constant, so
/// within-sweep variance is pure measurement noise). The mid-range base
/// point keeps every stage exercised so effects are visible.
pub fn analyze(app: &App, probes_per_param: usize, seed: u64) -> DependencyAnalysis {
    let m = app.spec.num_vars();
    let n_stages = app.graph.len();
    let mut sim = ClusterSim::new(Cluster::default(), NoiseModel::default(), seed);
    let base_u = vec![0.5; m];

    let mut correlation = vec![vec![0.0; m]; n_stages];
    let mut stage_means = vec![0.0; n_stages];
    let mut e2e_mean = 0.0;
    let mut total_obs = 0usize;

    for p in 0..m {
        let mut knob_vals: Vec<f64> = Vec::with_capacity(probes_per_param);
        let mut stage_obs: Vec<Vec<f64>> = vec![Vec::with_capacity(probes_per_param); n_stages];
        for i in 0..probes_per_param {
            let mut u = base_u.clone();
            u[p] = i as f64 / (probes_per_param.max(2) - 1) as f64;
            let ks = app.spec.denormalize(&u);
            // median of 3 repetitions at a fixed frame: content constant
            // within a sweep and load spikes cannot masquerade as knob
            // effects
            let mut reps: Vec<crate::simulator::FrameResult> = (0..3)
                .map(|_| sim.run_frame(app, &ks, (p * 37) % 500))
                .collect();
            knob_vals.push(u[p]);
            for s in 0..n_stages {
                let mut vals: Vec<f64> = reps.iter().map(|r| r.stage_ms[s]).collect();
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let med = vals[1];
                stage_obs[s].push(med);
                stage_means[s] += med;
            }
            reps.sort_by(|a, b| a.end_to_end_ms.partial_cmp(&b.end_to_end_ms).unwrap());
            e2e_mean += reps[1].end_to_end_ms;
            total_obs += 1;
        }
        for s in 0..n_stages {
            correlation[s][p] = dependence(&knob_vals, &stage_obs[s], 9);
        }
    }
    for s in 0..n_stages {
        stage_means[s] /= total_obs as f64;
    }
    e2e_mean /= total_obs as f64;

    let critical_stages: Vec<usize> = (0..n_stages)
        .filter(|&s| stage_means[s] >= CRITICAL_FRACTION * e2e_mean)
        .collect();
    let associated_params: Vec<Vec<usize>> = (0..n_stages)
        .map(|s| {
            (0..m)
                .filter(|&p| correlation[s][p] >= CORRELATION_THRESHOLD)
                .collect()
        })
        .collect();

    DependencyAnalysis { critical_stages, associated_params, correlation }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    #[test]
    fn eta_monotone_dependence_high() {
        let xs: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| x * x).collect();
        assert!(correlation_ratio(&xs, &ys, 4) > 0.9);
    }

    #[test]
    fn eta_u_shaped_dependence_high() {
        // the data-parallelism response shape Pearson/Spearman would miss
        let xs: Vec<f64> = (0..36).map(|i| i as f64 / 35.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| (x - 0.5).powi(2) * 100.0).collect();
        assert!(dependence(&xs, &ys, 6) > 0.9, "{}", dependence(&xs, &ys, 6));
    }

    #[test]
    fn sharp_regime_switch_detected() {
        // cap binding only at the low end of the sweep (rank-diluted)
        let xs: Vec<f64> = (0..36).map(|i| i as f64 / 35.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| if x < 0.2 { x * 50.0 } else { 10.0 }).collect();
        assert!(dependence(&xs, &ys, 6) > 0.9, "{}", dependence(&xs, &ys, 6));
    }

    #[test]
    fn eta_independent_low() {
        let xs: Vec<f64> = (0..200).map(|i| (i as f64 * 17.0) % 13.0).collect();
        let ys: Vec<f64> = (0..200).map(|i| ((i + 31) as f64 * 7.0) % 11.0).collect();
        assert!(dependence(&xs, &ys, 6) < 0.4);
    }

    #[test]
    fn eta_constant_is_zero() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(correlation_ratio(&xs, &[5.0; 10], 4), 0.0);
    }

    #[test]
    fn pose_analysis_recovers_structure() {
        let app = app_by_name("pose", find_spec_dir(None).unwrap()).unwrap();
        let a = analyze(&app, 36, 1);
        // SIFT (stage 2) must be critical and owned by K1 (scale) + K3 (par)
        assert!(a.critical_stages.contains(&2), "critical: {:?}", a.critical_stages);
        assert!(a.associated_params[2].contains(&0), "sift<-K1: {:?}", a.correlation[2]);
        assert!(a.associated_params[2].contains(&2), "sift<-K3: {:?}", a.correlation[2]);
        // ... and NOT by the feature threshold (sift emits before capping)
        assert!(!a.associated_params[2].contains(&1), "sift!<-K2: {:?}", a.correlation[2]);
        // match (stage 3) responds to K4 and to the K2 cap
        assert!(a.associated_params[3].contains(&3), "match<-K4: {:?}", a.correlation[3]);
        assert!(a.associated_params[3].contains(&1), "match<-K2: {:?}", a.correlation[3]);
        // source (stage 0) is constant: no associations, not critical
        assert!(a.associated_params[0].is_empty(), "{:?}", a.correlation[0]);
        assert!(!a.critical_stages.contains(&0));
    }

    #[test]
    fn motion_sift_branch_separation() {
        let app = app_by_name("motion_sift", find_spec_dir(None).unwrap()).unwrap();
        let a = analyze(&app, 36, 2);
        let fd = 3; // face_detect
        let me = 6; // motion_extract
        assert!(a.critical_stages.contains(&fd));
        assert!(a.critical_stages.contains(&me));
        // face branch knobs attach to face_detect, not motion_extract
        assert!(a.associated_params[fd].contains(&0), "{:?}", a.correlation[fd]);
        assert!(a.associated_params[fd].contains(&4), "{:?}", a.correlation[fd]);
        assert!(!a.associated_params[me].contains(&0), "{:?}", a.correlation[me]);
        // motion branch knobs attach to motion_extract only
        assert!(a.associated_params[me].contains(&1), "{:?}", a.correlation[me]);
        assert!(a.associated_params[me].contains(&3), "{:?}", a.correlation[me]);
        assert!(!a.associated_params[fd].contains(&1), "{:?}", a.correlation[fd]);
    }

    #[test]
    fn analysis_matches_spec_groups() {
        // the declared group structure must be recoverable: every declared
        // (group param -> group stage) association has high correlation
        for name in ["pose", "motion_sift"] {
            let app = app_by_name(name, find_spec_dir(None).unwrap()).unwrap();
            let a = analyze(&app, 36, 3);
            for g in &app.spec.groups {
                // at least one stage of the group must show |rho| >= 0.9
                // for each of the group's knobs that drive latency
                for &p in &g.params {
                    // skip knobs that only affect fidelity (none today)
                    let hit = g.stages.iter().any(|sn| {
                        let s = app.spec.stage_index(sn).unwrap();
                        a.correlation[s][p] >= CORRELATION_THRESHOLD
                    });
                    assert!(hit, "{name}: group {} knob {p} unrecovered", g.name);
                }
            }
        }
    }
}
