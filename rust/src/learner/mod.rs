//! Online latency learning (paper Sec. 3).
//!
//! * [`features`] — explicit polynomial feature expansion (linear /
//!   quadratic / cubic kernels, Sec. 3.3).
//! * [`ogd`] — the ε-insensitive online-gradient regressor (Eq. 6–8) and
//!   the moving average used for non-critical stages.
//! * [`GroupMap`] / [`StagePredictor`] — the structured and unstructured
//!   end-to-end latency predictors of Sec. 2.3/3.3: per-group regressors
//!   combined along the critical path (Eq. 9) plus a moving-average
//!   offset. Series-parallel specs keep the paper's sum/max evaluation
//!   bit-for-bit; specs that declare a group-level DAG
//!   (`GroupSpec::deps` — the `gen-dag` workload family) combine via a
//!   weighted critical path over the group graph, which reduces to a sum
//!   on chains and a max on pure fan-out.
//! * [`offline`] — batch-trained baselines (the dashed lines of Fig. 6).
//! * [`deps`] — the correlation-based dependency analysis of Sec. 2.3.

pub mod deps;
pub mod features;
pub mod offline;
pub mod ogd;

pub use features::FeatureMap;
pub use ogd::{MovingAverage, OgdRegressor};

use crate::apps::spec::AppSpec;
use crate::dataflow::{critical_path, Graph};

/// Which predictor architecture (paper Fig. 7 compares the two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// One regressor of all knobs against end-to-end latency.
    Unstructured,
    /// Per-group regressors over knob subsets, combined by critical path.
    Structured,
}

impl Variant {
    pub fn as_str(&self) -> &'static str {
        match self {
            Variant::Unstructured => "unstructured",
            Variant::Structured => "structured",
        }
    }
}

/// Loud-failure threshold for negative group predictions in the DAG
/// [`combine`](GroupMap::combine): OGD's signed corrections legitimately
/// undershoot zero by a few ms early in learning (the critical-path
/// clamp absorbs those), but a prediction this far below zero means a
/// diverged regressor whose clamp would silently bias every combined
/// latency. Generous on purpose — the full debug test suite trains from
/// empty models and must never trip it.
pub const COMBINE_NEG_TOLERANCE_MS: f64 = 1000.0;

/// How per-frame observations map onto learning targets for each group,
/// and how group predictions combine into an end-to-end latency.
#[derive(Debug, Clone)]
pub struct GroupMap {
    /// Per group: stage ids whose summed latency is the group's target.
    pub group_stages: Vec<Vec<usize>>,
    /// Per group: knob subset (global indices) its regressor sees.
    pub group_vars: Vec<Vec<usize>>,
    /// Per group: `None` = sequential (summed), `Some(b)` = parallel
    /// branch b (branch totals combined with max; paper Eq. 9).
    pub branch: Vec<Option<usize>>,
    /// Stages outside all groups; their summed latency is tracked with a
    /// moving average (the offset term).
    pub offset_stages: Vec<usize>,
    /// Group-level DAG for general-graph specs ([`GroupSpec::deps`]):
    /// when present, [`combine`](Self::combine) runs a weighted critical
    /// path over this graph (one vertex per group, weights = group
    /// predictions) instead of the legacy series-parallel sum/max rule.
    /// `None` keeps the historical arithmetic bit-for-bit — every
    /// JSON-loaded spec and every `gen:SEED` pipeline takes that path.
    ///
    /// [`GroupSpec::deps`]: crate::apps::spec::GroupSpec::deps
    pub group_graph: Option<Graph>,
}

impl GroupMap {
    /// The structured decomposition declared in the spec (Sec. 2.3 —
    /// recovered online by [`deps::analyze`], validated in tests). Specs
    /// that declare a group-level DAG ([`GroupSpec::deps`]) get a
    /// critical-path combine over that graph; everything else keeps the
    /// legacy series-parallel rule.
    ///
    /// [`GroupSpec::deps`]: crate::apps::spec::GroupSpec::deps
    pub fn structured(spec: &AppSpec) -> Self {
        let in_group: std::collections::BTreeSet<usize> = spec
            .groups
            .iter()
            // detlint: allow(unwrap) — group stage names resolve: AppSpec::validate() checked them at load
            .flat_map(|g| g.stages.iter().map(|s| spec.stage_index(s).unwrap()))
            .collect();
        let group_graph = if spec.groups.iter().any(|g| g.deps.is_some()) {
            let nodes: Vec<(String, Vec<String>)> = spec
                .groups
                .iter()
                .map(|g| (g.name.clone(), g.deps.clone().unwrap_or_default()))
                .collect();
            // detlint: allow(unwrap) — group deps are topologically validated by AppSpec::validate() at load
            Some(Graph::new(&nodes).expect("group deps are validated at load"))
        } else {
            None
        };
        GroupMap {
            group_stages: spec
                .groups
                .iter()
                // detlint: allow(unwrap) — group stage names resolve: AppSpec::validate() checked them at load
                .map(|g| g.stages.iter().map(|s| spec.stage_index(s).unwrap()).collect())
                .collect(),
            group_vars: spec.groups.iter().map(|g| g.params.clone()).collect(),
            branch: spec.groups.iter().map(|g| g.branch).collect(),
            offset_stages: (0..spec.stages.len()).filter(|i| !in_group.contains(i)).collect(),
            group_graph,
        }
    }

    /// The flat decomposition: one pseudo-group targeting the end-to-end
    /// latency directly, seeing every knob.
    pub fn unstructured(spec: &AppSpec) -> Self {
        GroupMap {
            group_stages: vec![(0..spec.stages.len()).collect()],
            group_vars: vec![(0..spec.num_vars()).collect()],
            branch: vec![None],
            offset_stages: vec![],
            group_graph: None,
        }
    }

    pub fn for_variant(spec: &AppSpec, variant: Variant) -> Self {
        match variant {
            Variant::Structured => Self::structured(spec),
            Variant::Unstructured => Self::unstructured(spec),
        }
    }

    pub fn num_groups(&self) -> usize {
        self.group_stages.len()
    }

    /// Is this the single-group end-to-end mapping?
    pub fn is_unstructured(&self) -> bool {
        self.num_groups() == 1 && self.offset_stages.is_empty()
    }

    /// Learning targets from one frame's measurements:
    /// (per-group target latencies, offset observation).
    ///
    /// Unstructured maps the end-to-end latency to its single group;
    /// structured sums each group's stage latencies (the runtime exposes
    /// stage-level latency probes — paper Sec. 2) and the leftover stages
    /// feed the moving-average offset.
    pub fn targets(&self, stage_ms: &[f64], end_to_end_ms: f64) -> (Vec<f64>, f64) {
        if self.is_unstructured() {
            return (vec![end_to_end_ms], 0.0);
        }
        let y = self
            .group_stages
            .iter()
            .map(|stages| stages.iter().map(|&s| stage_ms[s]).sum())
            .collect();
        let offset = self.offset_stages.iter().map(|&s| stage_ms[s]).sum();
        (y, offset)
    }

    /// Combine per-group predictions + offset into an end-to-end estimate:
    /// a weighted critical path over the group-level graph (paper Eq. 9
    /// generalized to arbitrary DAGs).
    ///
    /// Specs that declare a group DAG ([`group_graph`]) take the general
    /// rule — the longest weighted group-path, reusing
    /// [`critical_path`](crate::dataflow::critical_path) — which reduces
    /// to a sum on chain graphs and a max on pure fan-out. Legacy
    /// series-parallel specs keep the historical arithmetic (Σ sequential
    /// groups + max over branch sums) *bit-for-bit*: the old rule is the
    /// critical path of the pre → branches → post shape, evaluated in the
    /// exact floating-point order every recorded trace and mirror
    /// threshold depends on.
    ///
    /// [`group_graph`]: Self::group_graph
    pub fn combine(&self, group_pred: &[f64], offset: f64) -> f64 {
        debug_assert_eq!(group_pred.len(), self.num_groups());
        if let Some(g) = &self.group_graph {
            // The critical-path recursion anchors every join at zero
            // (`fold(0.0, max)` over parent distances), so a *negative*
            // partial path sum — a signed group-regressor correction
            // overshooting below zero — is clamped back to the join's
            // own weight rather than propagated (ISSUE 6; PR 5 note).
            // That clamp is the intended semantics for the small
            // transient undershoots OGD produces early in learning, but
            // it would also silently mask a diverged regressor biasing
            // every prediction upward — so fail loudly (debug builds)
            // when a prediction is materially negative.
            debug_assert!(
                group_pred.iter().all(|&p| p >= -COMBINE_NEG_TOLERANCE_MS),
                "group prediction below -{COMBINE_NEG_TOLERANCE_MS} ms — \
                 a diverged signed group regressor, not an OGD transient: \
                 {group_pred:?}"
            );
            return offset + critical_path(g, group_pred);
        }
        let mut total = offset;
        let mut branch_sums: std::collections::BTreeMap<usize, f64> =
            std::collections::BTreeMap::new();
        for (g, &p) in group_pred.iter().enumerate() {
            match self.branch[g] {
                None => total += p,
                Some(b) => *branch_sums.entry(b).or_insert(0.0) += p,
            }
        }
        if !branch_sums.is_empty() {
            total += branch_sums.values().cloned().fold(f64::MIN, f64::max);
        }
        total
    }

    /// Total compact feature count across groups for a given degree
    /// (30 for MotionSIFT structured cubic — paper Sec. 4.3).
    pub fn feature_count(&self, degree: usize) -> usize {
        self.group_vars
            .iter()
            .map(|v| features::monomial_count(v.len(), degree))
            .sum()
    }
}

/// End-to-end latency predictor: per-group OGD regressors + moving-average
/// offset, combined along the critical path.
pub struct StagePredictor {
    pub map: GroupMap,
    regs: Vec<OgdRegressor>,
    offset: MovingAverage,
    /// Scratch for group predictions (avoids hot-loop allocation).
    scratch: Vec<f64>,
    pub degree: usize,
}

impl StagePredictor {
    pub fn new(spec: &AppSpec, variant: Variant, degree: usize) -> Self {
        let map = GroupMap::for_variant(spec, variant);
        let regs = map
            .group_vars
            .iter()
            .map(|vars| OgdRegressor::new(vars, degree))
            .collect();
        StagePredictor {
            scratch: vec![0.0; map.num_groups()],
            map,
            regs,
            offset: MovingAverage::new(50),
            degree,
        }
    }

    pub fn with_eta0(mut self, eta0: f64) -> Self {
        for r in &mut self.regs {
            r.eta0 = eta0;
        }
        self
    }

    /// Override the ε-insensitive zone (ms) of every group regressor
    /// (ablation hook; the AOT artifacts bake the shipped 1 ms value).
    pub fn with_eps(mut self, eps_ms: f64) -> Self {
        for r in &mut self.regs {
            r.eps = eps_ms;
        }
        self
    }

    /// Predicted end-to-end latency (ms) for normalized knobs `u`.
    pub fn predict(&mut self, u: &[f64]) -> f64 {
        for g in 0..self.regs.len() {
            self.scratch[g] = self.regs[g].predict(u);
        }
        self.map.combine(&self.scratch, self.offset.value())
    }

    /// Learn from one frame: returns the pre-update end-to-end prediction
    /// (for error tracking à la Fig. 6).
    pub fn observe(&mut self, u: &[f64], stage_ms: &[f64], end_to_end_ms: f64) -> f64 {
        let pred = self.predict(u);
        let (targets, offset_obs) = self.map.targets(stage_ms, end_to_end_ms);
        for (g, &y) in targets.iter().enumerate() {
            self.regs[g].update(u, y);
        }
        if !self.map.offset_stages.is_empty() {
            self.offset.observe(offset_obs);
        }
        pred
    }

    pub fn num_features(&self) -> usize {
        self.regs.iter().map(|r| r.num_features()).sum()
    }

    pub fn regressors(&self) -> &[OgdRegressor] {
        &self.regs
    }

    /// Drive one group's regressor directly (used by backends that split
    /// targets themselves).
    pub fn regressor_update(&mut self, group: usize, u: &[f64], y: f64) {
        self.regs[group].update(u, y);
    }

    /// Feed one observation of the non-critical-stage offset.
    pub fn observe_offset(&mut self, offset_ms: f64) {
        if !self.map.offset_stages.is_empty() {
            self.offset.observe(offset_ms);
        }
    }

    pub fn offset_ms(&self) -> f64 {
        self.offset.value()
    }

    /// Forget all learned state.
    pub fn reset(&mut self) {
        for r in &mut self.regs {
            r.reset();
        }
        self.offset = MovingAverage::new(50);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;
    use crate::simulator::{Cluster, ClusterSim, NoiseModel};
    use crate::util::Rng;

    fn app(name: &str) -> crate::apps::App {
        app_by_name(name, find_spec_dir(None).unwrap()).unwrap()
    }

    #[test]
    fn feature_counts_paper() {
        let ms = app("motion_sift");
        let s = GroupMap::structured(&ms.spec);
        let u = GroupMap::unstructured(&ms.spec);
        assert_eq!(s.feature_count(3), 30);
        assert_eq!(u.feature_count(3), 56);
    }

    #[test]
    fn unstructured_targets_e2e() {
        let p = app("pose");
        let m = GroupMap::unstructured(&p.spec);
        let (y, off) = m.targets(&[1.0; 7], 42.0);
        assert_eq!(y, vec![42.0]);
        assert_eq!(off, 0.0);
    }

    #[test]
    fn structured_targets_sum_group_stages() {
        let p = app("pose");
        let m = GroupMap::structured(&p.spec);
        let stage_ms = [1.0, 2.0, 30.0, 20.0, 10.0, 5.0, 0.5];
        let (y, off) = m.targets(&stage_ms, 68.5);
        assert_eq!(y, vec![30.0, 20.0, 10.0, 5.0]);
        assert!((off - 3.5).abs() < 1e-12); // source + scaler + sink
    }

    #[test]
    fn combine_chain_is_sum() {
        let p = app("pose");
        let m = GroupMap::structured(&p.spec);
        let total = m.combine(&[10.0, 20.0, 5.0, 2.0], 3.0);
        assert!((total - 40.0).abs() < 1e-12);
    }

    #[test]
    fn combine_branches_take_max() {
        let ms = app("motion_sift");
        let m = GroupMap::structured(&ms.spec);
        assert_eq!(m.num_groups(), 2);
        let total = m.combine(&[50.0, 80.0], 10.0);
        assert!((total - 90.0).abs() < 1e-12);
        let total2 = m.combine(&[90.0, 80.0], 10.0);
        assert!((total2 - 100.0).abs() < 1e-12);
    }

    /// A hand-built DAG-mode map over `n` groups with the given edges.
    fn dag_map(n: usize, edges: &[(usize, usize)]) -> GroupMap {
        let nodes: Vec<(String, Vec<String>)> = (0..n)
            .map(|i| {
                let deps = edges
                    .iter()
                    .filter(|&&(_, dst)| dst == i)
                    .map(|&(src, _)| format!("g{src}"))
                    .collect();
                (format!("g{i}"), deps)
            })
            .collect();
        GroupMap {
            group_stages: (0..n).map(|i| vec![i]).collect(),
            group_vars: (0..n).map(|i| vec![i]).collect(),
            branch: vec![None; n],
            offset_stages: vec![],
            group_graph: Some(Graph::new(&nodes).unwrap()),
        }
    }

    #[test]
    fn dag_combine_chain_reduces_to_sum_bitwise() {
        // a 4-group chain must reproduce the legacy sequential sum exactly
        let dag = dag_map(4, &[(0, 1), (1, 2), (2, 3)]);
        let legacy = GroupMap {
            group_stages: (0..4).map(|i| vec![i]).collect(),
            group_vars: (0..4).map(|i| vec![i]).collect(),
            branch: vec![None; 4],
            offset_stages: vec![],
            group_graph: None,
        };
        let preds = [10.3, 20.7, 5.1, 2.9];
        // bit-identical at zero offset: 0.0 + x is exact and both paths
        // accumulate the same left-to-right sum
        assert_eq!(dag.combine(&preds, 0.0), legacy.combine(&preds, 0.0));
        // a nonzero offset associates differently (offset-first vs
        // offset-last) — equal to rounding, not bitwise
        let (d, l) = (dag.combine(&preds, 3.25), legacy.combine(&preds, 3.25));
        assert!((d - l).abs() < 1e-9, "{d} vs {l}");
    }

    #[test]
    fn dag_combine_fanout_reduces_to_max_bitwise() {
        // two independent single-group branches: legacy takes the branch
        // max, the DAG rule takes the longest (single-vertex) path
        let dag = dag_map(2, &[]);
        let legacy = GroupMap {
            group_stages: vec![vec![0], vec![1]],
            group_vars: vec![vec![0], vec![1]],
            branch: vec![Some(0), Some(1)],
            offset_stages: vec![],
            group_graph: None,
        };
        for preds in [[50.0, 80.0], [90.0, 80.0], [7.5, 7.5]] {
            assert_eq!(dag.combine(&preds, 10.0), legacy.combine(&preds, 10.0));
        }
    }

    #[test]
    fn dag_combine_takes_longest_group_path() {
        // diamond with a skip edge: g0 -> {g1, g2} -> g3, plus g0 -> g3
        let dag = dag_map(4, &[(0, 1), (0, 2), (1, 3), (2, 3), (0, 3)]);
        let total = dag.combine(&[1.0, 5.0, 2.0, 1.0], 0.5);
        assert!((total - 7.5).abs() < 1e-12, "{total}");
        // with non-negative weights a through-path dominates the skip
        // edge; the skip matters for connectivity, not for the max
        let skip = dag.combine(&[10.0, 0.1, 0.2, 1.0], 0.0);
        assert!((skip - 11.2).abs() < 1e-12, "{skip}");
    }

    #[test]
    fn dag_combine_clamps_small_negative_partials_at_the_join() {
        // chain g0 -> g1 -> g2 with a transiently negative middle
        // prediction: the join anchors at zero, so the negative partial
        // (g0 + g1 = -2) is clamped and g2 starts from 0, not -2 — the
        // documented (now explicit) semantics for OGD undershoot
        let dag = dag_map(3, &[(0, 1), (1, 2)]);
        let total = dag.combine(&[3.0, -5.0, 4.0], 0.0);
        assert!((total - 4.0).abs() < 1e-12, "{total}");
        // while the partial stays positive a small undershoot propagates
        // exactly (3 - 1 + 2): the clamp engages only at negative joins
        let signed = dag.combine(&[3.0, -1.0, 2.0], 0.0);
        assert!((signed - 4.0).abs() < 1e-12, "{signed}");
    }

    #[test]
    #[should_panic(expected = "diverged signed group regressor")]
    #[cfg(debug_assertions)]
    fn dag_combine_fails_loudly_on_materially_negative_predictions() {
        let dag = dag_map(2, &[(0, 1)]);
        dag.combine(&[5.0, -2.0 * COMBINE_NEG_TOLERANCE_MS], 0.0);
    }

    #[test]
    fn structured_predictor_learns_cluster_frames() {
        // end-to-end sanity: train on simulated frames with random knobs,
        // probe held-out knobs; error should be far below signal scale
        for name in ["pose", "motion_sift"] {
            let a = app(name);
            let mut sim = ClusterSim::new(Cluster::default(), NoiseModel::default(), 3);
            let mut pred = StagePredictor::new(&a.spec, Variant::Structured, 3);
            let mut rng = Rng::new(5);
            for f in 0..3000 {
                let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
                let ks = a.spec.denormalize(&u);
                let r = sim.run_frame(&a, &ks, f % 500);
                pred.observe(&a.spec.normalize(&ks), &r.stage_ms, r.end_to_end_ms);
            }
            let mut err_sum = 0.0;
            let mut scale_sum = 0.0;
            for f in 0..200 {
                let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
                let ks = a.spec.denormalize(&u);
                let r = sim.run_frame(&a, &ks, f % 500);
                err_sum += (pred.predict(&a.spec.normalize(&ks)) - r.end_to_end_ms).abs();
                scale_sum += r.end_to_end_ms;
            }
            let rel = err_sum / scale_sum;
            assert!(rel < 0.35, "{name}: relative err {rel}");
        }
    }

    #[test]
    fn structured_and_unstructured_agree_on_scale() {
        let a = app("motion_sift");
        let mut sim = ClusterSim::new(Cluster::default(), NoiseModel::default(), 4);
        let mut s = StagePredictor::new(&a.spec, Variant::Structured, 3);
        let mut un = StagePredictor::new(&a.spec, Variant::Unstructured, 3);
        let mut rng = Rng::new(6);
        for f in 0..2000 {
            let u: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
            let ks = a.spec.denormalize(&u);
            let r = sim.run_frame(&a, &ks, f % 500);
            let un_norm = a.spec.normalize(&ks);
            s.observe(&un_norm, &r.stage_ms, r.end_to_end_ms);
            un.observe(&un_norm, &r.stage_ms, r.end_to_end_ms);
        }
        let u = vec![0.5; 5];
        let (ps, pu) = (s.predict(&u), un.predict(&u));
        assert!(ps > 0.0 && pu > 0.0);
        assert!((ps - pu).abs() / ps.max(pu) < 0.5, "{ps} vs {pu}");
    }

    #[test]
    fn variant_str() {
        assert_eq!(Variant::Structured.as_str(), "structured");
        assert_eq!(Variant::Unstructured.as_str(), "unstructured");
    }
}
