//! Polynomial (monomial) feature expansion — the kernel trick by explicit
//! feature-space expansion (paper Sec. 3.3: "expand the original feature
//! space by non-linear features and learn a linear regressor in the new
//! space ... suitable for quadratic and cubic kernels").
//!
//! The enumeration order (graded, then lexicographic over non-decreasing
//! variable tuples) is shared with `python/compile/spec.py::monomials`
//! and golden-tested in `rust/tests/golden_features.rs`.

/// All monomials of total degree ≤ `degree` over variables `vars`
/// (global variable indices). Each monomial is the non-decreasing list of
/// its factors' variable indices; `vec![]` is the constant term.
pub fn monomials_of(vars: &[usize], degree: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = vec![vec![]];
    for d in 1..=degree {
        // combinations with replacement of `vars`, lexicographic
        let mut stack: Vec<(usize, Vec<usize>)> = vec![(0, Vec::new())];
        let mut level: Vec<Vec<usize>> = Vec::new();
        while let Some((start, cur)) = stack.pop() {
            if cur.len() == d {
                level.push(cur);
                continue;
            }
            // push in reverse so pop order is lexicographic
            for i in (start..vars.len()).rev() {
                let mut next = cur.clone();
                next.push(vars[i]);
                stack.push((i, next));
            }
        }
        out.extend(level);
    }
    out
}

/// `C(v + d, d)` — the number of monomials of degree ≤ d over v variables
/// (56 for the paper's 5-knob cubic predictors).
pub fn monomial_count(num_vars: usize, degree: usize) -> usize {
    // binomial(v + d, d) without overflow for our tiny sizes
    let (v, d) = (num_vars as u64, degree as u64);
    let mut num = 1u64;
    let mut den = 1u64;
    for i in 1..=d {
        num *= v + i;
        den *= i;
    }
    (num / den) as usize
}

/// A compact feature map for one regressor: monomials over a variable
/// subset, evaluated against the *full* normalized knob vector.
#[derive(Debug, Clone)]
pub struct FeatureMap {
    monos: Vec<Vec<usize>>,
}

impl FeatureMap {
    /// Expansion over a subset of the knobs (structured groups own only
    /// their own knob subsets — the 10+20 = 30 vs 56 economics of
    /// paper Sec. 4.3).
    pub fn new(vars: &[usize], degree: usize) -> Self {
        FeatureMap { monos: monomials_of(vars, degree) }
    }

    pub fn len(&self) -> usize {
        self.monos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.monos.is_empty()
    }

    pub fn monomials(&self) -> &[Vec<usize>] {
        &self.monos
    }

    /// Evaluate φ(u) into `out` (len must equal `self.len()`).
    /// Allocation-free: callers reuse the buffer on the hot path.
    pub fn expand_into(&self, u: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.monos.len());
        for (slot, mono) in out.iter_mut().zip(&self.monos) {
            let mut v = 1.0;
            for &var in mono {
                v *= u[var];
            }
            *slot = v;
        }
    }

    /// Convenience allocating variant.
    pub fn expand(&self, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.monos.len()];
        self.expand_into(u, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_binomial() {
        for v in 1..=6 {
            for d in 1..=4 {
                let vars: Vec<usize> = (0..v).collect();
                assert_eq!(monomials_of(&vars, d).len(), monomial_count(v, d));
            }
        }
    }

    #[test]
    fn paper_counts() {
        assert_eq!(monomial_count(5, 3), 56);
        assert_eq!(monomial_count(2, 3), 10);
        assert_eq!(monomial_count(3, 3), 20);
    }

    #[test]
    fn golden_order_2v2d() {
        let m = monomials_of(&[0, 1], 2);
        let want: Vec<Vec<usize>> =
            vec![vec![], vec![0], vec![1], vec![0, 0], vec![0, 1], vec![1, 1]];
        assert_eq!(m, want);
    }

    #[test]
    fn golden_order_3v3d_prefix() {
        let m = monomials_of(&[0, 1, 2], 3);
        assert_eq!(
            &m[..10],
            &[
                vec![],
                vec![0],
                vec![1],
                vec![2],
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 1],
                vec![1, 2],
                vec![2, 2],
            ]
        );
        assert_eq!(m[10], vec![0, 0, 0]);
        assert_eq!(*m.last().unwrap(), vec![2, 2, 2]);
    }

    #[test]
    fn subset_vars_used_globally() {
        let fm = FeatureMap::new(&[2, 4], 2);
        // u has 5 entries; only u[2], u[4] matter
        let phi = fm.expand(&[9.0, 9.0, 2.0, 9.0, 3.0]);
        assert_eq!(phi, vec![1.0, 2.0, 3.0, 4.0, 6.0, 9.0]);
    }

    #[test]
    fn expand_constant_term_first() {
        let fm = FeatureMap::new(&[0], 3);
        let phi = fm.expand(&[0.5]);
        assert_eq!(phi, vec![1.0, 0.5, 0.25, 0.125]);
    }

    #[test]
    fn expand_into_no_alloc_matches() {
        let fm = FeatureMap::new(&[0, 1, 2], 3);
        let u = [0.3, 0.7, 0.9];
        let mut buf = vec![0.0; fm.len()];
        fm.expand_into(&u, &mut buf);
        assert_eq!(buf, fm.expand(&u));
    }

    #[test]
    fn graded_degree_order() {
        let m = monomials_of(&[0, 1, 2, 3, 4], 3);
        let degs: Vec<usize> = m.iter().map(|t| t.len()).collect();
        let mut sorted = degs.clone();
        sorted.sort_unstable();
        assert_eq!(degs, sorted);
        // uniqueness
        let set: std::collections::BTreeSet<_> = m.iter().collect();
        assert_eq!(set.len(), m.len());
    }
}
