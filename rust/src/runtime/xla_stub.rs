//! Stub twin of the PJRT/XLA backend, compiled when the `pjrt` feature is
//! off (the default — the external `xla` crate is only vendored in
//! artifact-building environments).
//!
//! The public surface matches `xla.rs` exactly so call sites type-check
//! unchanged; both constructors return an error, and every caller in the
//! tree (CLI, quickstart, benches, parity tests) already treats that as
//! "artifacts unavailable" and falls back to the native backend or skips.

use anyhow::{bail, Result};
use std::path::Path;

use super::Backend;
use crate::apps::spec::AppSpec;
use crate::learner::{GroupMap, Variant};

/// Placeholder for `xla.rs`'s PJRT-backed predictor backend.
pub struct XlaBackend {
    map: GroupMap,
    weights: Vec<f32>,
    pub eta0: f64,
}

impl XlaBackend {
    /// Always fails: this build carries no PJRT runtime.
    pub fn new(
        _spec: &AppSpec,
        _variant: Variant,
        _artifact_dir: impl AsRef<Path>,
    ) -> Result<Self> {
        bail!(
            "this build has no PJRT runtime (compiled without the `pjrt` \
             feature); use the native backend"
        )
    }

    /// Always fails: this build carries no PJRT runtime.
    pub fn from_default_artifacts(spec: &AppSpec, variant: Variant) -> Result<Self> {
        Self::new(spec, variant, "artifacts")
    }

    pub fn with_eta0(mut self, eta0: f64) -> Self {
        self.eta0 = eta0;
        self
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-stub"
    }

    fn group_map(&self) -> &GroupMap {
        &self.map
    }

    fn predict(&mut self, u_batch: &[Vec<f64>]) -> Vec<f64> {
        vec![0.0; u_batch.len()]
    }

    fn update(&mut self, _u: &[f64], _y_groups: &[f64]) {}

    fn observe_offset(&mut self, _offset_ms: f64) {}

    fn solve_with_costs(
        &mut self,
        u_batch: &[Vec<f64>],
        _rewards: &[f64],
        _bound_ms: f64,
    ) -> (usize, Vec<f64>) {
        (0, vec![0.0; u_batch.len()])
    }

    fn reset(&mut self) {}
}
