//! PJRT execution of the AOT-compiled predictor artifacts.
//!
//! Loads `artifacts/{app}_{variant}_{predict,update,solve}.hlo.txt` (HLO
//! *text* — see DESIGN.md and /opt/xla-example/README.md for why text,
//! not serialized protos), compiles each once on the PJRT CPU client, and
//! serves the [`Backend`] operations from the compiled executables. The
//! per-group weight matrix lives host-side as `Vec<f32>` and rides along
//! on every call (shapes are tiny: G×64 f32).

use anyhow::{bail, Context, Result};
use std::path::Path;

use super::manifest::Manifest;
use super::Backend;
use crate::apps::spec::AppSpec;
use crate::learner::ogd::{DEFAULT_ETA0, LATENCY_SCALE_MS};
use crate::learner::{GroupMap, MovingAverage, Variant};

/// PJRT-backed predictor backend.
pub struct XlaBackend {
    map: GroupMap,
    predict_exe: ::xla::PjRtLoadedExecutable,
    update_exe: ::xla::PjRtLoadedExecutable,
    solve_exe: ::xla::PjRtLoadedExecutable,
    /// Host copy of the per-group weights, row-major [G, F].
    weights: Vec<f32>,
    num_groups: usize,
    feature_pad: usize,
    candidate_pad: usize,
    num_vars: usize,
    offset: MovingAverage,
    t: u64,
    pub eta0: f64,
}

impl XlaBackend {
    /// Load + compile the three artifacts for (app spec, variant).
    pub fn new(spec: &AppSpec, variant: Variant, artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = ::xla::PjRtClient::cpu().context("creating PJRT CPU client")?;

        let mut exes = Vec::with_capacity(3);
        let mut meta = None;
        for op in ["predict", "update", "solve"] {
            let (entry, path) = manifest.entry(dir, &spec.name, variant.as_str(), op)?;
            if entry.num_vars != spec.num_vars() {
                bail!(
                    "artifact {} built for {} vars, spec has {} — rerun `make artifacts`",
                    path.display(),
                    entry.num_vars,
                    spec.num_vars()
                );
            }
            let proto = ::xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = ::xla::XlaComputation::from_proto(&proto);
            exes.push(client.compile(&comp).with_context(|| format!("compiling {op}"))?);
            meta = Some((
                entry.num_groups,
                entry.feature_pad,
                entry.candidate_pad,
                entry.num_vars,
            ));
        }
        let (num_groups, feature_pad, candidate_pad, num_vars) = meta.unwrap();
        let solve_exe = exes.pop().unwrap();
        let update_exe = exes.pop().unwrap();
        let predict_exe = exes.pop().unwrap();

        Ok(XlaBackend {
            map: GroupMap::for_variant(spec, variant),
            predict_exe,
            update_exe,
            solve_exe,
            weights: vec![0.0; num_groups * feature_pad],
            num_groups,
            feature_pad,
            candidate_pad,
            num_vars,
            offset: MovingAverage::new(50),
            t: 0,
            eta0: DEFAULT_ETA0,
        })
    }

    /// Convenience: locate artifacts automatically.
    pub fn from_default_artifacts(spec: &AppSpec, variant: Variant) -> Result<Self> {
        let dir = super::manifest::find_artifact_dir(None)?;
        Self::new(spec, variant, dir)
    }

    pub fn with_eta0(mut self, eta0: f64) -> Self {
        self.eta0 = eta0;
        self
    }

    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// Build the padded `[N, V+1]` candidate literal (+ validity mask).
    fn candidate_literal(&self, u_batch: &[Vec<f64>]) -> Result<(::xla::Literal, Vec<f32>)> {
        let n = self.candidate_pad;
        let vp = self.num_vars + 1;
        if u_batch.len() > n {
            bail!("candidate batch {} exceeds artifact pad {}", u_batch.len(), n);
        }
        let mut data = vec![0.0f32; n * vp];
        let mut valid = vec![0.0f32; n];
        for (i, u) in u_batch.iter().enumerate() {
            debug_assert_eq!(u.len(), self.num_vars);
            for (j, &x) in u.iter().enumerate() {
                data[i * vp + j] = x as f32;
            }
            data[i * vp + self.num_vars] = 1.0; // trailing constant slot
            valid[i] = 1.0;
        }
        // padded rows keep the trailing 1.0 too (harmless; masked out)
        for i in u_batch.len()..n {
            data[i * vp + self.num_vars] = 1.0;
        }
        let lit = ::xla::Literal::vec1(&data).reshape(&[n as i64, vp as i64])?;
        Ok((lit, valid))
    }

    fn weights_literal(&self) -> Result<::xla::Literal> {
        ::xla::Literal::vec1(&self.weights)
            .reshape(&[self.num_groups as i64, self.feature_pad as i64])
            .map_err(Into::into)
    }

    fn scalar1(x: f64) -> ::xla::Literal {
        ::xla::Literal::vec1(&[x as f32])
    }

    fn exec(
        exe: &::xla::PjRtLoadedExecutable,
        args: &[::xla::Literal],
    ) -> Result<::xla::Literal> {
        let result = exe.execute::<::xla::Literal>(args)?;
        Ok(result[0][0].to_literal_sync()?)
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn group_map(&self) -> &GroupMap {
        &self.map
    }

    fn predict(&mut self, u_batch: &[Vec<f64>]) -> Vec<f64> {
        let n_real = u_batch.len();
        let (u_lit, _) = self.candidate_literal(u_batch).expect("candidate literal");
        let w = self.weights_literal().expect("weights literal");
        // the artifacts compute in normalized latency units (1 = 100 ms)
        let off = Self::scalar1(self.offset.value() / LATENCY_SCALE_MS);
        let out = Self::exec(&self.predict_exe, &[u_lit, w, off])
            .expect("predict exec")
            .to_tuple1()
            .expect("predict tuple");
        let c: Vec<f32> = out.to_vec().expect("predict read");
        c[..n_real].iter().map(|&x| x as f64 * LATENCY_SCALE_MS).collect()
    }

    fn update(&mut self, u: &[f64], y_groups: &[f64]) {
        debug_assert_eq!(y_groups.len(), self.num_groups);
        self.t += 1;
        let eta = self.eta0 / (self.t as f64).sqrt();
        let vp = self.num_vars + 1;
        let mut u_aug = vec![0.0f32; vp];
        for (j, &x) in u.iter().enumerate() {
            u_aug[j] = x as f32;
        }
        u_aug[self.num_vars] = 1.0;
        let y: Vec<f32> = y_groups
            .iter()
            .map(|&x| (x / LATENCY_SCALE_MS) as f32)
            .collect();
        let w = self.weights_literal().expect("weights literal");
        let out = Self::exec(
            &self.update_exe,
            &[
                w,
                ::xla::Literal::vec1(&u_aug),
                ::xla::Literal::vec1(&y),
                Self::scalar1(eta),
            ],
        )
        .expect("update exec")
        .to_tuple1()
        .expect("update tuple");
        self.weights = out.to_vec().expect("update read");
    }

    fn observe_offset(&mut self, offset_ms: f64) {
        if !self.map.offset_stages.is_empty() {
            self.offset.observe(offset_ms);
        }
    }

    fn solve_with_costs(
        &mut self,
        u_batch: &[Vec<f64>],
        rewards: &[f64],
        bound_ms: f64,
    ) -> (usize, Vec<f64>) {
        let n = self.candidate_pad;
        let (u_lit, valid) = self.candidate_literal(u_batch).expect("candidate literal");
        let mut r = vec![0.0f32; n];
        for (i, &x) in rewards.iter().enumerate() {
            r[i] = x as f32;
        }
        let w = self.weights_literal().expect("weights literal");
        let off = Self::scalar1(self.offset.value() / LATENCY_SCALE_MS);
        let out = Self::exec(
            &self.solve_exe,
            &[
                u_lit,
                w,
                off,
                ::xla::Literal::vec1(&r),
                ::xla::Literal::vec1(&valid),
                Self::scalar1(bound_ms / LATENCY_SCALE_MS),
            ],
        )
        .expect("solve exec");
        let (idx, costs) = out.to_tuple2().expect("solve tuple");
        let idx: Vec<i32> = idx.to_vec().expect("solve idx");
        let costs: Vec<f32> = costs.to_vec().expect("solve costs");
        let costs_ms: Vec<f64> = costs[..u_batch.len()]
            .iter()
            .map(|&c| c as f64 * LATENCY_SCALE_MS)
            .collect();
        ((idx[0] as usize).min(u_batch.len().saturating_sub(1)), costs_ms)
    }

    fn reset(&mut self) {
        self.weights.iter_mut().for_each(|w| *w = 0.0);
        self.offset = MovingAverage::new(50);
        self.t = 0;
    }
}
