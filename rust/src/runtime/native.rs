//! Pure-Rust predictor backend with compact per-group feature spaces.

use super::Backend;
use crate::apps::spec::AppSpec;
use crate::learner::{GroupMap, StagePredictor, Variant};

/// Native backend: wraps [`StagePredictor`] (compact monomial expansions
/// — 30 structured features vs 56 unstructured on MotionSIFT).
pub struct NativeBackend {
    pred: StagePredictor,
}

impl NativeBackend {
    pub fn new(spec: &AppSpec, variant: Variant, degree: usize) -> Self {
        NativeBackend { pred: StagePredictor::new(spec, variant, degree) }
    }

    /// Cubic structured predictor (the paper's headline configuration).
    pub fn structured(spec: &AppSpec) -> Self {
        Self::new(spec, Variant::Structured, 3)
    }

    /// Cubic unstructured predictor.
    pub fn unstructured(spec: &AppSpec) -> Self {
        Self::new(spec, Variant::Unstructured, 3)
    }

    pub fn with_eta0(mut self, eta0: f64) -> Self {
        self.pred = self.pred.with_eta0(eta0);
        self
    }

    pub fn predictor(&self) -> &StagePredictor {
        &self.pred
    }

    /// Total compact feature count (Sec. 4.3 economics).
    pub fn num_features(&self) -> usize {
        self.pred.num_features()
    }

    /// Single-candidate prediction without batching overhead.
    pub fn predict_one(&mut self, u: &[f64]) -> f64 {
        self.pred.predict(u)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn group_map(&self) -> &GroupMap {
        &self.pred.map
    }

    fn predict(&mut self, u_batch: &[Vec<f64>]) -> Vec<f64> {
        u_batch.iter().map(|u| self.pred.predict(u)).collect()
    }

    fn update(&mut self, u: &[f64], y_groups: &[f64]) {
        debug_assert_eq!(y_groups.len(), self.pred.map.num_groups());
        // StagePredictor::observe recomputes targets; here targets are
        // already split, so drive the regressors directly.
        for (g, &y) in y_groups.iter().enumerate() {
            self.pred.regressor_update(g, u, y);
        }
    }

    fn observe_offset(&mut self, offset_ms: f64) {
        self.pred.observe_offset(offset_ms);
    }

    fn solve_with_costs(
        &mut self,
        u_batch: &[Vec<f64>],
        rewards: &[f64],
        bound_ms: f64,
    ) -> (usize, Vec<f64>) {
        super::solve_by_predict(self, u_batch, rewards, bound_ms)
    }

    fn reset(&mut self) {
        self.pred.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::registry::app_by_name;
    use crate::apps::spec::find_spec_dir;

    fn spec(name: &str) -> AppSpec {
        app_by_name(name, find_spec_dir(None).unwrap()).unwrap().spec
    }

    #[test]
    fn feature_counts() {
        let s = spec("motion_sift");
        assert_eq!(NativeBackend::structured(&s).num_features(), 30);
        assert_eq!(NativeBackend::unstructured(&s).num_features(), 56);
    }

    #[test]
    fn update_then_predict_moves_toward_target() {
        let s = spec("pose");
        let mut b = NativeBackend::structured(&s);
        let u = vec![0.5; 5];
        let y = vec![40.0, 30.0, 10.0, 5.0];
        let before = b.predict(&[u.clone()])[0];
        for _ in 0..200 {
            b.update(&u, &y);
            b.observe_offset(3.0);
        }
        let after = b.predict(&[u.clone()])[0];
        let target = 85.0 + 3.0;
        assert!((after - target).abs() < (before - target).abs());
        assert!((after - target).abs() < 10.0, "after {after}");
    }

    #[test]
    fn solve_picks_feasible_max_reward() {
        let s = spec("pose");
        let mut b = NativeBackend::unstructured(&s);
        // train: latency = 200*u0
        for i in 0..3000 {
            let x = (i % 100) as f64 / 99.0;
            b.update(&[x, 0.5, 0.5, 0.5, 0.5], &[200.0 * x]);
        }
        let cands: Vec<Vec<f64>> = (0..10)
            .map(|i| vec![i as f64 / 9.0, 0.5, 0.5, 0.5, 0.5])
            .collect();
        // reward increases with u0 (slower = better fidelity here)
        let rewards: Vec<f64> = (0..10).map(|i| i as f64 / 9.0).collect();
        let pick = b.solve(&cands, &rewards, 100.0);
        let costs = b.predict(&cands);
        assert!(costs[pick] <= 100.0, "picked infeasible {}", costs[pick]);
        // it should be the largest feasible u0
        for (i, &c) in costs.iter().enumerate() {
            if c <= 100.0 {
                assert!(rewards[pick] >= rewards[i]);
            }
        }
    }

    #[test]
    fn solve_fallback_min_cost() {
        let s = spec("pose");
        let mut b = NativeBackend::unstructured(&s);
        for i in 0..2000 {
            let x = (i % 100) as f64 / 99.0;
            b.update(&[x, 0.5, 0.5, 0.5, 0.5], &[100.0 + 200.0 * x]);
        }
        let cands: Vec<Vec<f64>> =
            (0..5).map(|i| vec![i as f64 / 4.0, 0.5, 0.5, 0.5, 0.5]).collect();
        let pick = b.solve(&cands, &[0.0; 5], 1.0); // nothing feasible
        let costs = b.predict(&cands);
        let min_i = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(pick, min_i);
    }

    #[test]
    fn reset_forgets() {
        let s = spec("pose");
        let mut b = NativeBackend::unstructured(&s);
        b.update(&[0.5; 5], &[100.0]);
        assert_ne!(b.predict(&[vec![0.5; 5]])[0], 0.0);
        b.reset();
        assert_eq!(b.predict(&[vec![0.5; 5]])[0], 0.0);
    }
}
