//! `artifacts/manifest.json` — the inventory `python/compile/aot.py`
//! writes next to the HLO artifacts. The runtime validates what it loads
//! against this before compiling anything.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ShapeSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ShapeSig {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(ShapeSig {
            shape: v.req("shape")?.as_usize_vec()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub app: String,
    pub variant: String,
    pub op: String,
    pub inputs: Vec<ShapeSig>,
    pub outputs: Vec<ShapeSig>,
    pub num_groups: usize,
    pub feature_pad: usize,
    pub candidate_pad: usize,
    pub num_vars: usize,
}

#[derive(Debug, Clone)]
pub struct AppEntry {
    pub num_vars: usize,
    pub num_groups: usize,
    pub feature_pad: usize,
    pub candidate_pad: usize,
    pub structured_features: usize,
    pub unstructured_features: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub apps: BTreeMap<String, AppEntry>,
}

impl Manifest {
    pub fn load(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let path = artifact_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text)?;
        let mut artifacts = BTreeMap::new();
        for (name, e) in v.req("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    file: e.req("file")?.as_str()?.to_string(),
                    app: e.req("app")?.as_str()?.to_string(),
                    variant: e.req("variant")?.as_str()?.to_string(),
                    op: e.req("op")?.as_str()?.to_string(),
                    inputs: e
                        .req("inputs")?
                        .as_arr()?
                        .iter()
                        .map(ShapeSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: e
                        .req("outputs")?
                        .as_arr()?
                        .iter()
                        .map(ShapeSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    num_groups: e.req("num_groups")?.as_usize()?,
                    feature_pad: e.req("feature_pad")?.as_usize()?,
                    candidate_pad: e.req("candidate_pad")?.as_usize()?,
                    num_vars: e.req("num_vars")?.as_usize()?,
                },
            );
        }
        let mut apps = BTreeMap::new();
        for (name, a) in v.req("apps")?.as_obj()? {
            apps.insert(
                name.clone(),
                AppEntry {
                    num_vars: a.req("num_vars")?.as_usize()?,
                    num_groups: a.req("num_groups")?.as_usize()?,
                    feature_pad: a.req("feature_pad")?.as_usize()?,
                    candidate_pad: a.req("candidate_pad")?.as_usize()?,
                    structured_features: a.req("structured_features")?.as_usize()?,
                    unstructured_features: a.req("unstructured_features")?.as_usize()?,
                },
            );
        }
        Ok(Manifest { artifacts, apps })
    }

    /// The artifact entry for (app, variant, op), with existence check.
    pub fn entry(
        &self,
        artifact_dir: impl AsRef<Path>,
        app: &str,
        variant: &str,
        op: &str,
    ) -> Result<(&ArtifactEntry, PathBuf)> {
        let key = format!("{app}_{variant}_{op}");
        let Some(e) = self.artifacts.get(&key) else {
            bail!("artifact {key} not in manifest");
        };
        let path = artifact_dir.as_ref().join(&e.file);
        if !path.is_file() {
            bail!("artifact file {} missing (run `make artifacts`)", path.display());
        }
        Ok((e, path))
    }
}

/// Locate the repo's `artifacts/` dir: explicit, `$IPTUNE_ARTIFACTS`, or
/// walk up from cwd/exe looking for `artifacts/manifest.json`.
pub fn find_artifact_dir(explicit: Option<&Path>) -> Result<PathBuf> {
    if let Some(p) = explicit {
        if p.join("manifest.json").is_file() {
            return Ok(p.to_path_buf());
        }
        bail!("no manifest.json under {}", p.display());
    }
    if let Ok(env) = std::env::var("IPTUNE_ARTIFACTS") {
        let p = PathBuf::from(env);
        if p.join("manifest.json").is_file() {
            return Ok(p);
        }
    }
    let mut candidates: Vec<PathBuf> = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        candidates.push(cwd);
    }
    if let Ok(exe) = std::env::current_exe() {
        candidates.push(exe);
    }
    candidates.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    for start in candidates {
        let mut cur: Option<&Path> = Some(start.as_path());
        while let Some(dir) = cur {
            let arts = dir.join("artifacts");
            if arts.join("manifest.json").is_file() {
                return Ok(arts);
            }
            cur = dir.parent();
        }
    }
    bail!("could not locate artifacts/ (run `make artifacts` or set IPTUNE_ARTIFACTS)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> Option<PathBuf> {
        find_artifact_dir(None).ok()
    }

    #[test]
    fn manifest_loads_if_built() {
        let Some(dir) = have_artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 12);
        for app in ["pose", "motion_sift"] {
            for variant in ["structured", "unstructured"] {
                for op in ["predict", "update", "solve"] {
                    let (e, path) = m.entry(&dir, app, variant, op).unwrap();
                    assert_eq!(e.op, op);
                    assert!(path.is_file());
                }
            }
        }
    }

    #[test]
    fn paper_feature_counts_in_manifest() {
        let Some(dir) = have_artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        let ms = &m.apps["motion_sift"];
        assert_eq!(ms.structured_features, 30);
        assert_eq!(ms.unstructured_features, 56);
    }

    #[test]
    fn missing_entry_rejected() {
        let Some(dir) = have_artifacts() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entry(&dir, "pose", "structured", "nope").is_err());
    }
}
