//! Predictor runtime backends.
//!
//! The tuner's hot path — batched latency prediction over the candidate
//! action set, the fused OGD weight update, and the constrained-argmax
//! solve — runs behind the [`Backend`] trait:
//!
//! * [`xla::XlaBackend`] executes the AOT-compiled HLO artifacts
//!   (`artifacts/*.hlo.txt`, produced once by `make artifacts`) on the
//!   PJRT CPU client. This is the production path: Python never runs.
//! * [`native::NativeBackend`] is the pure-Rust twin with *compact*
//!   per-group feature spaces (the 30-vs-56 economics of Sec. 4.3). It
//!   serves as the cross-check oracle for the artifacts and as the
//!   fallback when artifacts are absent.
//!
//! Both share identical math; `rust/tests/integration_runtime.rs` asserts
//! they agree to float32 tolerance.

pub mod manifest;
pub mod native;

// The PJRT-backed XLA backend needs the external `xla` crate (vendored in
// environments that run `make artifacts`); everywhere else a stub with an
// identical public surface keeps the workspace building offline — its
// constructors return Err, and every call site already falls back to the
// native backend on that path.
#[cfg(feature = "pjrt")]
pub mod xla;
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;

use crate::learner::GroupMap;

/// A latency-predictor backend: state (per-group weights + offset) plus
/// the three tuner operations.
///
/// Not `Send`: the XLA backend holds PJRT handles that are pinned to the
/// thread that created them; the controller is single-threaded anyway.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// The group decomposition this backend learns over.
    fn group_map(&self) -> &GroupMap;

    /// Predicted end-to-end latency (ms) for each normalized candidate.
    ///
    /// Contract: row-wise — the cost of candidate `i` depends only on
    /// candidate `i`, never on the rest of the batch, so callers may
    /// split or concatenate batches freely (the vectorized
    /// whole-ladder prediction in
    /// [`BudgetedController::utility_curve`] relies on this).
    ///
    /// [`BudgetedController::utility_curve`]:
    ///     crate::tuner::BudgetedController::utility_curve
    fn predict(&mut self, u_batch: &[Vec<f64>]) -> Vec<f64>;

    /// One OGD step: played action `u` (normalized), per-group observed
    /// latency targets `y_groups` (ms). The backend manages its own
    /// η_t = η₀/√t schedule.
    fn update(&mut self, u: &[f64], y_groups: &[f64]);

    /// Feed one observation of the non-critical-stage offset (ms).
    fn observe_offset(&mut self, offset_ms: f64);

    /// Constrained argmax (paper Eq. 2): index of the candidate with the
    /// highest reward among those predicted to satisfy `bound_ms`, or the
    /// predicted-fastest candidate when none are feasible.
    fn solve(&mut self, u_batch: &[Vec<f64>], rewards: &[f64], bound_ms: f64) -> usize {
        self.solve_with_costs(u_batch, rewards, bound_ms).0
    }

    /// [`solve`](Self::solve) that also returns the predicted latency of
    /// every candidate — the hot path uses this to avoid a second
    /// predictor dispatch per frame (the XLA solve artifact computes the
    /// costs anyway).
    fn solve_with_costs(
        &mut self,
        u_batch: &[Vec<f64>],
        rewards: &[f64],
        bound_ms: f64,
    ) -> (usize, Vec<f64>);

    /// Reset learned state (fresh weights, schedule, offset).
    fn reset(&mut self);
}

/// The constrained argmax of paper Eq. 2 over precomputed costs: highest
/// reward among candidates predicted feasible (first wins ties), else the
/// predicted-fastest candidate. Shared by [`solve_by_predict`] and the
/// controller's empirical-blend exploit so tie-breaking can never drift
/// between the two paths.
pub fn constrained_argmax(costs: &[f64], rewards: &[f64], bound_ms: f64) -> usize {
    let mut best: Option<usize> = None;
    for (i, &c) in costs.iter().enumerate() {
        if c <= bound_ms {
            match best {
                Some(b) if rewards[b] >= rewards[i] => {}
                _ => best = Some(i),
            }
        }
    }
    best.unwrap_or_else(|| {
        costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    })
}

/// Reference solve implementation shared by backends that expose
/// `predict` (native; also used to validate the XLA `solve` artifact).
pub fn solve_by_predict(
    backend: &mut dyn Backend,
    u_batch: &[Vec<f64>],
    rewards: &[f64],
    bound_ms: f64,
) -> (usize, Vec<f64>) {
    let costs = backend.predict(u_batch);
    let idx = constrained_argmax(&costs, rewards, bound_ms);
    (idx, costs)
}
