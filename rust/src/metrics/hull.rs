//! 2-D convex hull (Andrew's monotone chain) — the payoff region of
//! randomized strategies over the action space (paper Fig. 5: "the convex
//! hull represents payoffs which are feasible by playing a randomized
//! strategy over the 30 action configurations"; also the gray regions of
//! Fig. 8).

/// Convex hull of `points`, counter-clockwise starting at the lowest-x
/// point. Returns the input (deduplicated) when there are < 3 distinct
/// points.
pub fn convex_hull(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut pts: Vec<(f64, f64)> = points.to_vec();
    pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    pts.dedup();
    if pts.len() < 3 {
        return pts;
    }
    let cross = |o: (f64, f64), a: (f64, f64), b: (f64, f64)| {
        (a.0 - o.0) * (b.1 - o.1) - (a.1 - o.1) * (b.0 - o.0)
    };
    let mut lower: Vec<(f64, f64)> = Vec::new();
    for &p in &pts {
        while lower.len() >= 2 && cross(lower[lower.len() - 2], lower[lower.len() - 1], p) <= 0.0 {
            lower.pop();
        }
        lower.push(p);
    }
    let mut upper: Vec<(f64, f64)> = Vec::new();
    for &p in pts.iter().rev() {
        while upper.len() >= 2 && cross(upper[upper.len() - 2], upper[upper.len() - 1], p) <= 0.0 {
            upper.pop();
        }
        upper.push(p);
    }
    lower.pop();
    upper.pop();
    lower.extend(upper);
    lower
}

/// Is `p` inside (or on) the convex hull given as a CCW polygon?
pub fn hull_contains(hull: &[(f64, f64)], p: (f64, f64)) -> bool {
    if hull.len() < 3 {
        // degenerate: point-or-segment membership with tolerance
        return hull.iter().any(|&(x, y)| {
            ((x - p.0).powi(2) + (y - p.1).powi(2)).sqrt() < 1e-9
        }) || (hull.len() == 2 && on_segment(hull[0], hull[1], p));
    }
    let n = hull.len();
    for i in 0..n {
        let a = hull[i];
        let b = hull[(i + 1) % n];
        let cross = (b.0 - a.0) * (p.1 - a.1) - (b.1 - a.1) * (p.0 - a.0);
        if cross < -1e-9 {
            return false;
        }
    }
    true
}

fn on_segment(a: (f64, f64), b: (f64, f64), p: (f64, f64)) -> bool {
    let cross = (b.0 - a.0) * (p.1 - a.1) - (b.1 - a.1) * (p.0 - a.0);
    if cross.abs() > 1e-9 {
        return false;
    }
    p.0 >= a.0.min(b.0) - 1e-9
        && p.0 <= a.0.max(b.0) + 1e-9
        && p.1 >= a.1.min(b.1) - 1e-9
        && p.1 <= a.1.max(b.1) + 1e-9
}

/// The best reward achievable at violation ≤ `x` by mixing the given
/// (violation, reward) payoff points — the upper frontier of the hull.
/// Used to score policies against randomized strategies (Fig. 8).
pub fn best_mixture_reward(payoffs: &[(f64, f64)], x: f64) -> f64 {
    // upper concave envelope evaluated at x: maximize over pairs (i, j)
    // of mixtures with mixed violation <= x, plus pure strategies
    let mut best = f64::NEG_INFINITY;
    for &(vi, ri) in payoffs {
        if vi <= x + 1e-12 {
            best = best.max(ri);
        }
    }
    for (i, &(vi, ri)) in payoffs.iter().enumerate() {
        for &(vj, rj) in &payoffs[i + 1..] {
            let (lo, hi, rlo, rhi) = if vi <= vj { (vi, vj, ri, rj) } else { (vj, vi, rj, ri) };
            if x >= lo && x <= hi && hi > lo {
                let t = (x - lo) / (hi - lo);
                best = best.max(rlo + t * (rhi - rlo));
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_hull() {
        let pts = [(0.0, 0.0), (1.0, 0.0), (1.0, 1.0), (0.0, 1.0), (0.5, 0.5)];
        let h = convex_hull(&pts);
        assert_eq!(h.len(), 4);
        assert!(!h.contains(&(0.5, 0.5)));
    }

    #[test]
    fn hull_contains_all_inputs() {
        let pts: Vec<(f64, f64)> = (0..40)
            .map(|i| {
                let t = i as f64;
                ((t * 7.3) % 5.0, (t * 3.1) % 4.0)
            })
            .collect();
        let h = convex_hull(&pts);
        for &p in &pts {
            assert!(hull_contains(&h, p), "{p:?} outside hull");
        }
    }

    #[test]
    fn collinear_degenerate() {
        let pts = [(0.0, 0.0), (1.0, 1.0), (2.0, 2.0)];
        let h = convex_hull(&pts);
        assert!(h.len() <= 3);
        assert!(hull_contains(&h, (1.0, 1.0)) || h.len() == 2);
    }

    #[test]
    fn tiny_inputs() {
        assert_eq!(convex_hull(&[]).len(), 0);
        assert_eq!(convex_hull(&[(1.0, 2.0)]), vec![(1.0, 2.0)]);
        assert_eq!(convex_hull(&[(1.0, 2.0), (1.0, 2.0)]).len(), 1);
    }

    #[test]
    fn mixture_frontier() {
        // two pure strategies: (violation 0, reward 0.5), (10, 0.9)
        let payoffs = [(0.0, 0.5), (10.0, 0.9)];
        assert!((best_mixture_reward(&payoffs, 0.0) - 0.5).abs() < 1e-12);
        assert!((best_mixture_reward(&payoffs, 5.0) - 0.7).abs() < 1e-12);
        assert!((best_mixture_reward(&payoffs, 10.0) - 0.9).abs() < 1e-12);
        assert!((best_mixture_reward(&payoffs, 20.0) - 0.9).abs() < 1e-12);
    }
}
