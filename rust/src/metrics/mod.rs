//! Metrics: the error measures of Fig. 6/7 (cumulative expected and
//! max-norm prediction errors) and the payoff machinery of Fig. 5/8
//! (convex hulls of randomized-strategy payoffs, constraint violation).

pub mod hull;

pub use hull::convex_hull;

/// Tracks the paper's two prediction-error measures as a stream of
/// |prediction − observation| values arrives:
/// * expected error — cumulative average of |err| up to each frame;
/// * max-norm error — running max of |err| up to each frame.
#[derive(Debug, Clone, Default)]
pub struct ErrorTracker {
    sum_abs: f64,
    max_abs: f64,
    n: u64,
}

impl ErrorTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one |prediction − observation| and return the pair
    /// (cumulative expected error, cumulative max-norm error).
    pub fn observe(&mut self, abs_err: f64) -> (f64, f64) {
        debug_assert!(abs_err >= 0.0);
        self.sum_abs += abs_err;
        self.max_abs = self.max_abs.max(abs_err);
        self.n += 1;
        (self.expected(), self.max_norm())
    }

    pub fn expected(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_abs / self.n as f64
        }
    }

    pub fn max_norm(&self) -> f64 {
        self.max_abs
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Running policy-outcome accumulator for Fig. 8: average reward and
/// average constraint violation E[max(c − L, 0)].
#[derive(Debug, Clone, Default)]
pub struct PolicyStats {
    sum_reward: f64,
    sum_violation: f64,
    max_violation: f64,
    violated_frames: u64,
    n: u64,
}

impl PolicyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, reward: f64, latency_ms: f64, bound_ms: f64) {
        let v = (latency_ms - bound_ms).max(0.0);
        self.sum_reward += reward;
        self.sum_violation += v;
        self.max_violation = self.max_violation.max(v);
        if v > 0.0 {
            self.violated_frames += 1;
        }
        self.n += 1;
    }

    pub fn avg_reward(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_reward / self.n as f64
        }
    }

    /// E[max(c − L, 0)] in ms.
    pub fn avg_violation_ms(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_violation / self.n as f64
        }
    }

    pub fn max_violation_ms(&self) -> f64 {
        self.max_violation
    }

    pub fn violation_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.violated_frames as f64 / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Fold another accumulator into this one — the fleet runner merges
    /// per-app stats into cluster-wide aggregates with this.
    pub fn merge(&mut self, other: &PolicyStats) {
        self.sum_reward += other.sum_reward;
        self.sum_violation += other.sum_violation;
        self.max_violation = self.max_violation.max(other.max_violation);
        self.violated_frames += other.violated_frames;
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_tracker_cumulative() {
        let mut t = ErrorTracker::new();
        assert_eq!(t.observe(2.0), (2.0, 2.0));
        assert_eq!(t.observe(4.0), (3.0, 4.0));
        let (e, m) = t.observe(0.0);
        assert!((e - 2.0).abs() < 1e-12);
        assert_eq!(m, 4.0);
        assert_eq!(t.count(), 3);
    }

    #[test]
    fn error_tracker_empty() {
        let t = ErrorTracker::new();
        assert_eq!(t.expected(), 0.0);
        assert_eq!(t.max_norm(), 0.0);
    }

    #[test]
    fn policy_stats_violation_semantics() {
        let mut p = PolicyStats::new();
        p.observe(0.8, 45.0, 50.0); // no violation
        p.observe(0.6, 80.0, 50.0); // 30ms over
        assert!((p.avg_reward() - 0.7).abs() < 1e-12);
        assert!((p.avg_violation_ms() - 15.0).abs() < 1e-12);
        assert_eq!(p.max_violation_ms(), 30.0);
        assert!((p.violation_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn policy_stats_merge_equals_combined_stream() {
        let obs = [(0.8, 45.0), (0.6, 80.0), (0.9, 30.0), (0.5, 120.0)];
        let mut whole = PolicyStats::new();
        for &(r, l) in &obs {
            whole.observe(r, l, 50.0);
        }
        let mut a = PolicyStats::new();
        let mut b = PolicyStats::new();
        for &(r, l) in &obs[..2] {
            a.observe(r, l, 50.0);
        }
        for &(r, l) in &obs[2..] {
            b.observe(r, l, 50.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.avg_reward() - whole.avg_reward()).abs() < 1e-12);
        assert!((a.avg_violation_ms() - whole.avg_violation_ms()).abs() < 1e-12);
        assert_eq!(a.max_violation_ms(), whole.max_violation_ms());
        assert!((a.violation_rate() - whole.violation_rate()).abs() < 1e-12);
    }

    #[test]
    fn policy_stats_merge_empty_is_identity() {
        let mut a = PolicyStats::new();
        a.observe(0.7, 60.0, 50.0);
        let before = (a.avg_reward(), a.avg_violation_ms(), a.count());
        a.merge(&PolicyStats::new());
        assert_eq!((a.avg_reward(), a.avg_violation_ms(), a.count()), before);
    }
}
