//! `repro` — the iptune CLI (L3 leader entrypoint).
//!
//! ```text
//! repro spec [APP] [--graph]
//! repro trace --app APP [--out DIR] [--configs N] [--frames N] [--seed N]
//! repro tune --app APP [--epsilon E] [--bound MS] [--frames N]
//!            [--backend xla|native] [--trace-dir DIR]
//! repro figures (--all | --fig N | --claims) [--out DIR] [--frames N]
//! repro engine --app APP [--frames N] [--bound MS] [--period N]
//! ```
//!
//! Global flags: `--config FILE` (JSON run config), `--specs DIR`,
//! `--quiet` / `--verbose` (progress-log level; stderr only).
//! Argument parsing is in-tree (`cli` module below) — the workspace
//! builds offline without clap.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

use iptune::apps::registry::app_by_name;
use iptune::apps::spec::find_spec_dir;
use iptune::config::{BackendKind, RunConfig};
use iptune::engine::{spawn_stream, EngineConfig};
use iptune::experiments;
use iptune::learner::Variant;
use iptune::runtime::native::NativeBackend;
use iptune::runtime::xla::XlaBackend;
use iptune::runtime::Backend;
use iptune::trace::TraceSet;
use iptune::tuner::{EpsGreedyController, TunerConfig};

/// Minimal flag parser: positionals + `--key value` + `--switch`.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String], switches: &[&str]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), Some(v.to_string())));
                } else if switches.contains(&name) {
                    flags.push((name.to_string(), None));
                } else {
                    i += 1;
                    let v = argv
                        .get(i)
                        .with_context(|| format!("--{name} needs a value"))?;
                    flags.push((name.to_string(), Some(v.clone())));
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }
}

const USAGE: &str = "usage: repro [--config FILE] [--specs DIR] [--quiet|--verbose] <command>

commands:
  spec [APP] [--graph]                     print Tables 1-2 / DOT graphs
  trace --app APP [--out DIR] [--configs N] [--frames N] [--seed N]
  tune --app APP [--epsilon E] [--bound MS] [--frames N]
       [--backend xla|native] [--trace-dir DIR]
  figures (--all | --fig N | --claims) [--out DIR] [--frames N]
        [--gen SEEDS]
  engine --app APP [--frames N] [--bound MS] [--period N]
  fleet [--apps N] [--frames N] [--seed N] [--configs N] [--epsilon E]
        [--warmup N] [--headroom F] [--blend K] [--threads N] [--out FILE]
        [--mode static|dynamic] [--hetero] [--shift FRAME] [--shift-mult M]
        [--epoch N] [--floor CORES] [--priority W1,W2,..] [--hysteresis H]
        [--admission] [--admission-epoch] [--admission-hysteresis S]
        [--starvation-bound K] [--demand-confidence N] [--shards S]
        [--tier-shift FRAME:W1,W2,..|FRAME:auto]
        [--thrash MULT] [--dag] [--drift B] [--trace-out FILE]
  schedule [--apps N] [--frames N] [--seed N] [--epoch N] [--floor CORES]
        [--candidates N] [--realtime SCALE] [--uniform]
        [--priority W1,W2,..] [--hysteresis H] [--admission-epoch]
        [--admission-hysteresis S] [--starvation-bound K]
        [--demand-confidence N] [--tier-shift FRAME:W1,W2,..|FRAME:auto]
        [--dag] [--drift B] [--straggler IDX:MS] [--barrier-epochs]
        [--out FILE] [--trace-out FILE]
  inspect TIMELINE [--tenant N]            render a saved --trace-out trace
  alloc-epoch [--tenants N] [--epochs N] [--seed N] [--threads N]
        [--rungs N] [--cores-per-tenant N] [--demand-confidence N]
        [--shards S] [--out FILE]

APP is pose, motion-sift, gen:SEED, or gen-dag:SEED (procedurally
generated pipelines; see the workloads module — gen-dag emits general
DAGs with multi-level fan-out, diamond joins and skip connections, whose
specs declare the group-level graph the structured critical-path combine
consumes). `fleet` tunes N generated apps on ONE shared cluster (static
even shares, or --mode dynamic for marginal-utility core reallocation
every --epoch frames); `schedule` streams N generated apps live through
the threaded engine under the same scheduler. --dag switches both to the
gen-dag family; --drift B layers slow per-stage cost-coefficient drift (a
bounded random walk within [1-B, 1+B]) on any generated workload,
composable with --shift/--thrash. Scheduler v2 knobs: --priority weights
tenant tiers (missing entries default to 1), --hysteresis sets the
migration penalty a reallocation must out-earn, --admission parks the
lowest-priority apps when --floor x apps exceeds the pool (instead of
over-granting) and switches to exact fairness-floor accounting, --thrash
MULT cranks the generated scenarios' content wobble to stress allocation
churn. Scheduler v3 makes admission epoch-granular: --admission-epoch
re-decides parking every epoch from the tenants' learned core demands
(re-admitting parked tenants when the pool frees up, e.g. after
--shift-mult 0.55 load drops), rotating parking among equal-priority
tenants so nobody waits more than --starvation-bound K consecutive
epochs; --demand-confidence N only lets a ladder rung carry a tenant's
demand once it holds >= N observations (immature models reserve the
calibration share instead of optimistically under-reserving);
--tier-shift scripts a mid-run priority change (FRAME:auto draws the
generated upgrade/downgrade scenario); --admission-hysteresis S keeps a
parked, non-overdue tenant out until S idle cores remain beyond its
reservation, damping park/resume thrash. On `schedule`, epochs are
per-tenant progress
frontiers: decisions fire as the frontier's lower envelope advances, and
--admission-epoch parks live tenants by freezing their knob schedules
(frames are deferred, never dropped). --straggler IDX:MS injects MS of
wall-clock delay per source frame into tenant IDX (the straggler-
isolation regression hook), --barrier-epochs runs the legacy frame-count
barrier protocol for A/B comparison, and --out FILE writes the live
report (per-tenant epoch counts included) as JSON. Both fleet and
schedule stream per-tenant per-epoch latency histograms into their
reports (latency_ms / epoch_latency_ms) always; --trace-out FILE
additionally captures the full structured event trace — frame
completions, knob-schedule extensions, frontier advances, admission and
allocation decisions, park/resume transitions — stamped with logical
clocks only, so the saved timeline is byte-identical across thread
counts, pacing and stragglers. `inspect` renders a saved timeline as
per-tenant epoch/grant/knob tables, a per-stage latency table, and an
allocation-churn view. `alloc-epoch` is the allocator scale smoke: it
drives N synthetic tenants (deterministic utility curves, no simulator
or learner) through demand reservation (confidence-gated when
--demand-confidence is set, from a salted observation stream that never
perturbs a curve draw), epoch admission, the heap water-filling
allocator over a 2%-headroom budget, and the reservation top-up that
spends the held-back cores, for --epochs reallocation epochs; it writes
a JSON report whose bytes are independent of --threads — CI diffs the
1/2/4-thread reports against each other and asserts the epoch
invariants (quota sum <= pool, finite utilities,
admitted + parked == tenants, top-up spent every epoch). --shards S (on
fleet and alloc-epoch) partitions tenants contiguously across S shards,
each running the same admission/water-fill machinery over its own slice
while a hierarchical coordinator exchanges compact demand summaries and
water-fills budgets across shards (docs/ARCHITECTURE.md); sharding is
topology, not semantics — reports stay byte-identical across --shards
1/2/4 (CI's shard-smoke job diffs them), per docs/DETERMINISM.md.";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(
        &argv[1..],
        &[
            "graph",
            "all",
            "claims",
            "hetero",
            "uniform",
            "admission",
            "admission-epoch",
            "dag",
            "barrier-epochs",
            "quiet",
            "verbose",
        ],
    )?;

    if args.has("quiet") {
        iptune::util::log::set_level(iptune::util::log::QUIET);
    } else if args.has("verbose") {
        iptune::util::log::set_level(iptune::util::log::VERBOSE);
    }

    let run_cfg = RunConfig::load_or_default(args.get("config").map(std::path::Path::new))?;
    let spec_dir = find_spec_dir(args.get("specs").map(std::path::Path::new))?;

    match cmd.as_str() {
        "spec" => cmd_spec(&args, &spec_dir),
        "trace" => cmd_trace(&args, &spec_dir, &run_cfg),
        "tune" => cmd_tune(&args, &spec_dir, &run_cfg),
        "figures" => cmd_figures(&args),
        "engine" => cmd_engine(&args, &spec_dir),
        "fleet" => cmd_fleet(&args),
        "schedule" => cmd_schedule(&args),
        "inspect" => cmd_inspect(&args),
        "alloc-epoch" => cmd_alloc_epoch(&args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Parse a `--priority` weight list: comma-separated positive floats,
/// one per app index; apps past the end of the list default to 1.0.
/// A single trailing comma is tolerated; interior empty entries are
/// rejected — with admission control the weights decide who gets
/// parked, so a typo'd `3,,2` must not silently shift every later
/// weight onto the wrong tenant.
fn parse_priorities(s: &str) -> Result<Vec<f64>> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    let parts = if parts.last() == Some(&"") {
        &parts[..parts.len() - 1] // trailing comma
    } else {
        &parts[..]
    };
    let ws = parts
        .iter()
        .map(|p| {
            anyhow::ensure!(!p.is_empty(), "--priority '{s}': empty entry");
            p.parse::<f64>()
                .map_err(|e| anyhow::anyhow!("--priority '{p}': {e}"))
        })
        .collect::<Result<Vec<f64>>>()?;
    anyhow::ensure!(
        ws.iter().all(|w| w.is_finite() && *w > 0.0),
        "--priority weights must be finite and > 0: {ws:?}"
    );
    Ok(ws)
}

/// Parse a `--tier-shift FRAME:W1,W2,..` scripted mid-run tier change;
/// `FRAME:auto` draws the generated upgrade/downgrade scenario family
/// from the run seed.
fn parse_tier_shift(s: &str, seed: u64, apps: usize) -> Result<(usize, Vec<f64>)> {
    let (frame, ws) = s
        .split_once(':')
        .with_context(|| format!("--tier-shift '{s}': expected FRAME:W1,W2,.. or FRAME:auto"))?;
    let frame: usize =
        frame.parse().map_err(|e| anyhow::anyhow!("--tier-shift frame '{frame}': {e}"))?;
    let weights = if ws == "auto" {
        anyhow::ensure!(apps >= 2, "--tier-shift auto needs at least two tenants");
        iptune::workloads::tier_shift_weights(seed, apps)
    } else {
        parse_priorities(ws)?
    };
    Ok((frame, weights))
}

/// Tune N generated apps concurrently and write the aggregate JSON report.
fn cmd_fleet(args: &Args) -> Result<()> {
    let mut cfg = iptune::fleet::FleetConfig::default();
    if let Some(n) = args.get_parse::<usize>("apps")? {
        cfg.apps = n;
    }
    if let Some(n) = args.get_parse::<usize>("frames")? {
        cfg.frames = n;
    }
    if let Some(n) = args.get_parse::<u64>("seed")? {
        cfg.seed = n;
    }
    if let Some(n) = args.get_parse::<usize>("configs")? {
        cfg.configs_per_app = n;
    }
    if let Some(e) = args.get_parse::<f64>("epsilon")? {
        cfg.epsilon = Some(e);
    }
    if let Some(n) = args.get_parse::<usize>("warmup")? {
        cfg.warmup_frames = n;
    }
    if let Some(h) = args.get_parse::<f64>("headroom")? {
        cfg.bound_headroom = h;
    }
    if let Some(k) = args.get_parse::<f64>("blend")? {
        cfg.empirical_blend_k = k; // 0 = the paper's pure-model exploit
    }
    if let Some(n) = args.get_parse::<usize>("threads")? {
        cfg.threads = n;
    }
    if let Some(m) = args.get("mode") {
        cfg.mode = iptune::fleet::FleetMode::parse(m)?;
    }
    if args.has("hetero") {
        cfg.heterogeneous = true;
    }
    if let Some(f) = args.get_parse::<usize>("shift")? {
        cfg.load_shift_frame = Some(f);
    }
    if let Some(e) = args.get_parse::<usize>("epoch")? {
        cfg.scheduler.epoch_frames = e;
    }
    if let Some(f) = args.get_parse::<usize>("floor")? {
        cfg.scheduler.fairness_floor = f;
    }
    if let Some(p) = args.get("priority") {
        cfg.scheduler.priorities = parse_priorities(p)?;
    }
    if let Some(h) = args.get_parse::<f64>("hysteresis")? {
        anyhow::ensure!(h >= 0.0, "--hysteresis must be >= 0");
        cfg.scheduler.hysteresis = h;
    }
    if args.has("admission") {
        // implies exact fairness-floor accounting (see FleetConfig::workload_of)
        cfg.scheduler.admission = true;
    }
    if args.has("admission-epoch") {
        // epoch-granular admission also implies exact accounting and
        // needs the dynamic allocator (the decision consumes curves)
        cfg.scheduler.admission_epoch = true;
        cfg.mode = iptune::fleet::FleetMode::Dynamic;
    }
    if let Some(k) = args.get_parse::<usize>("starvation-bound")? {
        anyhow::ensure!(k >= 1, "--starvation-bound must be >= 1");
        cfg.scheduler.starvation_bound = k;
    }
    if let Some(m) = args.get_parse::<f64>("shift-mult")? {
        anyhow::ensure!(m > 0.0 && m.is_finite(), "--shift-mult must be > 0");
        cfg.load_shift_mult = m;
    }
    if let Some(ts) = args.get("tier-shift") {
        cfg.scheduler.tier_shift = Some(parse_tier_shift(ts, cfg.seed, cfg.apps)?);
    }
    if let Some(t) = args.get_parse::<f64>("thrash")? {
        anyhow::ensure!(t >= 1.0, "--thrash must be >= 1");
        cfg.workload.thrash = Some(t);
    }
    if args.has("dag") {
        cfg.workload.dag = Some(iptune::workloads::DagConfig::default());
    }
    if let Some(b) = args.get_parse::<f64>("drift")? {
        anyhow::ensure!(b > 0.0 && b < 1.0, "--drift bound must be in (0, 1)");
        cfg.workload.drift = Some(b);
    }
    if let Some(n) = args.get_parse::<usize>("demand-confidence")? {
        cfg.scheduler.demand_confidence = n;
    }
    if let Some(s) = args.get_parse::<usize>("admission-hysteresis")? {
        cfg.scheduler.admission_hysteresis = s;
    }
    if let Some(s) = args.get_parse::<usize>("shards")? {
        anyhow::ensure!(s >= 1, "--shards must be >= 1");
        cfg.shards = s;
    }
    if cfg.apps == 0
        || (!cfg.scheduler.admission_any() && cfg.apps > cfg.cluster.total_cores())
    {
        bail!(
            "--apps {} out of range: the shared {}-core cluster supports 1..={} co-tenants",
            cfg.apps,
            cfg.cluster.total_cores(),
            cfg.cluster.total_cores()
        );
    }
    if cfg.load_shift_frame.is_some() && !cfg.heterogeneous {
        bail!("--shift only affects heavy apps; pass --hetero so the fleet has some");
    }
    let out = PathBuf::from(args.get("out").unwrap_or("fleet_report.json"));
    let trace_out = args.get("trace-out").map(PathBuf::from);
    cfg.trace_events = trace_out.is_some();

    iptune::log_info!(
        "fleet[{}]: tuning {} generated apps x {} frames (seed {}, {} shared cores, even share {}) ...",
        cfg.mode.name(),
        cfg.apps,
        cfg.frames,
        cfg.seed,
        cfg.cluster.total_cores(),
        cfg.cluster.total_cores() / cfg.apps
    );
    let report = iptune::fleet::run_fleet(&cfg);
    println!(
        "{:<8} {:<9} {:>7} {:>6} {:>8} {:>7} {:>10} {:>10} {:>10} {:>12} {:>11}",
        "app",
        "profile",
        "stages",
        "knobs",
        "bound",
        "cores",
        "fidelity",
        "oracle",
        "%oracle",
        "bound-met%",
        "conv-frame"
    );
    for a in &report.apps {
        println!(
            "{:<8} {:<9} {:>7} {:>6} {:>8.1} {:>7.1} {:>10.3} {:>10.3} {:>9.1}% {:>11.1}% {:>11}",
            a.name,
            a.profile,
            a.stages,
            a.knobs,
            a.bound_ms,
            a.avg_cores,
            a.avg_fidelity,
            a.oracle_fidelity,
            100.0 * a.fidelity_vs_oracle,
            100.0 * a.post_warmup_bound_met_frac,
            a.convergence_frame.map(|f| f.to_string()).unwrap_or_else(|| "-".into()),
        );
    }
    println!(
        "fleet[{}]: avg {:.1}% of even-share oracle | min bound-met {:.1}% | {}/{} apps meet the {:.0}% SLO | {} reallocation epochs | churn {} cores over {} moves{}",
        report.mode.name(),
        100.0 * report.avg_fidelity_vs_oracle,
        100.0 * report.min_bound_met_frac,
        report.apps_meeting_slo,
        report.apps.len(),
        100.0 * iptune::fleet::FLEET_SLO_FRAC,
        report.allocations.len(),
        report.core_churn,
        report.realloc_moves,
        if report.parked_app_epochs > 0 {
            format!(
                " | parking: {} whole-run, {} app-epochs, {} park/unpark transitions",
                report.parked_apps, report.parked_app_epochs, report.park_transitions
            )
        } else {
            String::new()
        },
    );
    report.save(&out)?;
    iptune::log_info!("report -> {}", out.display());
    if let Some(path) = &trace_out {
        let tl = report.timeline.as_ref().expect("trace_events captures a timeline");
        tl.save(path)?;
        iptune::log_info!("timeline ({} events) -> {}", tl.events.len(), path.display());
    }
    if !report.all_apps_meet_slo() {
        bail!(
            "{} of {} apps missed the {:.0}% bound-met SLO (report saved to {})",
            report.apps.len() - report.apps_meeting_slo,
            report.apps.len(),
            100.0 * iptune::fleet::FLEET_SLO_FRAC,
            out.display()
        );
    }
    Ok(())
}

/// Live multi-app streaming under the fleet scheduler: N generated apps
/// run concurrently through the threaded engine; their latency models are
/// learned online from the live records, and the shared cores are
/// re-divided by marginal utility every epoch.
fn cmd_schedule(args: &Args) -> Result<()> {
    let mut cfg = iptune::scheduler::live::LiveConfig::default();
    if let Some(n) = args.get_parse::<usize>("apps")? {
        cfg.apps = n;
    }
    if let Some(n) = args.get_parse::<usize>("frames")? {
        cfg.frames = n;
    }
    if let Some(n) = args.get_parse::<u64>("seed")? {
        cfg.seed = n;
    }
    if let Some(e) = args.get_parse::<usize>("epoch")? {
        cfg.scheduler.epoch_frames = e;
    }
    if let Some(f) = args.get_parse::<usize>("floor")? {
        cfg.scheduler.fairness_floor = f;
    }
    if let Some(n) = args.get_parse::<usize>("candidates")? {
        cfg.candidates = n;
    }
    if let Some(s) = args.get_parse::<f64>("realtime")? {
        cfg.realtime_scale = s;
    }
    if args.has("uniform") {
        cfg.heterogeneous = false;
    }
    if let Some(p) = args.get("priority") {
        cfg.scheduler.priorities = parse_priorities(p)?;
    }
    if let Some(h) = args.get_parse::<f64>("hysteresis")? {
        anyhow::ensure!(h >= 0.0, "--hysteresis must be >= 0");
        cfg.scheduler.hysteresis = h;
    }
    if args.has("admission-epoch") {
        cfg.scheduler.admission_epoch = true;
    }
    if let Some(k) = args.get_parse::<usize>("starvation-bound")? {
        anyhow::ensure!(k >= 1, "--starvation-bound must be >= 1");
        cfg.scheduler.starvation_bound = k;
    }
    if let Some(ts) = args.get("tier-shift") {
        cfg.scheduler.tier_shift = Some(parse_tier_shift(ts, cfg.seed, cfg.apps)?);
    }
    if args.has("dag") {
        cfg.workload.dag = Some(iptune::workloads::DagConfig::default());
    }
    if let Some(b) = args.get_parse::<f64>("drift")? {
        anyhow::ensure!(b > 0.0 && b < 1.0, "--drift bound must be in (0, 1)");
        cfg.workload.drift = Some(b);
    }
    if let Some(n) = args.get_parse::<usize>("demand-confidence")? {
        cfg.scheduler.demand_confidence = n;
    }
    if let Some(s) = args.get_parse::<usize>("admission-hysteresis")? {
        cfg.scheduler.admission_hysteresis = s;
    }
    if let Some(spec) = args.get("straggler") {
        let (idx, ms) = spec
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("--straggler wants IDX:MS, got '{spec}'"))?;
        let idx: usize = idx
            .parse()
            .map_err(|e| anyhow::anyhow!("--straggler tenant '{idx}': {e}"))?;
        let ms: f64 = ms
            .parse()
            .map_err(|e| anyhow::anyhow!("--straggler delay '{ms}': {e}"))?;
        cfg.straggler = Some((idx, ms));
    }
    if args.has("barrier-epochs") {
        cfg.barrier = true;
    }
    let trace_out = args.get("trace-out").map(PathBuf::from);
    cfg.trace_events = trace_out.is_some();
    iptune::log_info!(
        "schedule: streaming {} generated apps x {} frames live (seed {}, epoch {} frames, {} shared cores, {} protocol) ...",
        cfg.apps,
        cfg.frames,
        cfg.seed,
        cfg.scheduler.epoch_frames,
        cfg.cluster.total_cores(),
        if cfg.barrier { "barrier" } else { "frontier" },
    );
    let report = iptune::scheduler::live::run_live(&cfg)?;
    println!(
        "{:<8} {:<9} {:>8} {:>8} {:>12} {:>10} {:>12} {:>11} {:>8} {:>8}",
        "app",
        "profile",
        "frames",
        "bound",
        "avg-latency",
        "fidelity",
        "bound-met%",
        "final-cores",
        "parked",
        "epochs"
    );
    for a in &report.apps {
        println!(
            "{:<8} {:<9} {:>8} {:>8.1} {:>10.1}ms {:>10.3} {:>11.1}% {:>11} {:>8} {:>8}",
            a.name,
            a.profile,
            a.frames,
            a.bound_ms,
            a.avg_latency_ms,
            a.avg_fidelity,
            100.0 * a.bound_met_frac,
            a.final_cores,
            a.parked_epochs,
            a.completed_epochs,
        );
    }
    for alloc in &report.allocations {
        println!(
            "epoch {:>3} @ frame {:>5}: cores {:?} (sum {} / {})",
            alloc.epoch,
            alloc.start_frame,
            alloc.cores,
            alloc.total_cores(),
            report.total_cores,
        );
    }
    println!(
        "schedule: {} protocol, ladder {:?}, fairness floor {} cores",
        report.protocol, report.levels, report.fairness_floor
    );
    if let Some(path) = args.get("out") {
        report.save(path)?;
        iptune::log_info!("schedule: wrote live report to {path}");
    }
    if let Some(path) = &trace_out {
        let tl = report.timeline.as_ref().expect("trace_events captures a timeline");
        tl.save(path)?;
        iptune::log_info!(
            "schedule: wrote timeline ({} events) to {}",
            tl.events.len(),
            path.display()
        );
    }
    Ok(())
}

/// Render a saved `--trace-out` timeline: per-tenant epoch/grant/knob
/// tables, a per-stage latency table, and the allocation-churn view.
/// Everything here reads the artifact only — no simulation state.
fn cmd_inspect(args: &Args) -> Result<()> {
    use iptune::obs::{EventKind, Timeline};

    let path = args
        .positional
        .first()
        .context("inspect: usage: repro inspect TIMELINE.json [--tenant N]")?;
    let tl = Timeline::load(path)?;
    let only = args.get_parse::<usize>("tenant")?;
    if let Some(t) = only {
        anyhow::ensure!(t < tl.apps, "--tenant {t} out of range (timeline has {})", tl.apps);
    }
    println!(
        "timeline {path}: {} run, seed {}, {} tenants x {} frames, epoch {} frames, {} events",
        tl.source,
        tl.seed,
        tl.apps,
        tl.frames,
        tl.epoch_frames,
        tl.events.len()
    );

    let n_epochs = tl.events.iter().map(|e| e.epoch + 1).max().unwrap_or(0);
    #[derive(Clone, Default)]
    struct EpochRow {
        frames: usize,
        ms_sum: f64,
        ms_max: f64,
        fid_sum: f64,
        cores: Option<usize>,
        parked: Option<bool>,
        transition: Option<&'static str>,
        knob_exts: usize,
    }
    let mut rows: Vec<Vec<EpochRow>> = vec![vec![EpochRow::default(); n_epochs]; tl.apps];
    let mut stage_sum: Vec<Vec<f64>> = vec![Vec::new(); tl.apps];
    let mut total_sum: Vec<f64> = vec![0.0; tl.apps];
    let mut total_n: Vec<usize> = vec![0; tl.apps];
    let mut allocs: Vec<(usize, Vec<usize>, usize)> = Vec::new();
    let mut frontier_epochs = 0usize;
    for e in &tl.events {
        if e.epoch >= n_epochs {
            continue;
        }
        match (&e.kind, e.tenant) {
            (EventKind::Frame { ms, stage_ms, fidelity }, Some(t)) if t < tl.apps => {
                let row = &mut rows[t][e.epoch];
                row.frames += 1;
                row.ms_sum += ms;
                row.ms_max = row.ms_max.max(*ms);
                row.fid_sum += fidelity;
                if stage_sum[t].len() < stage_ms.len() {
                    stage_sum[t].resize(stage_ms.len(), 0.0);
                }
                for (s, v) in stage_ms.iter().enumerate() {
                    stage_sum[t][s] += v;
                }
                total_sum[t] += ms;
                total_n[t] += 1;
            }
            (EventKind::Knobs { .. }, Some(t)) if t < tl.apps => {
                rows[t][e.epoch].knob_exts += 1;
            }
            (EventKind::Park, Some(t)) if t < tl.apps => {
                rows[t][e.epoch].transition = Some("park");
            }
            (EventKind::Resume { .. }, Some(t)) if t < tl.apps => {
                rows[t][e.epoch].transition = Some("resume");
            }
            (EventKind::Frontier { .. }, None) => frontier_epochs += 1,
            (EventKind::Alloc { cores, parked, churn_cores }, None) => {
                for t in 0..tl.apps.min(cores.len()) {
                    rows[t][e.epoch].cores = Some(cores[t]);
                    rows[t][e.epoch].parked = parked.get(t).copied();
                }
                allocs.push((e.epoch, cores.clone(), *churn_cores));
            }
            _ => {}
        }
    }

    // ---- view 1: per-tenant epoch timeline ----------------------------
    for t in 0..tl.apps {
        if only.is_some_and(|o| o != t) {
            continue;
        }
        println!("\n== tenant {t} timeline ==");
        println!(
            "{:>5} {:>7} {:>9} {:>9} {:>9} {:>6} {:>8} {:>10}",
            "epoch", "frames", "avg-ms", "max-ms", "fidelity", "cores", "state", "knob-exts"
        );
        for (ep, row) in rows[t].iter().enumerate() {
            if row.frames == 0 && row.cores.is_none() && row.transition.is_none() {
                continue;
            }
            let n = row.frames.max(1) as f64;
            let state = match (row.transition, row.parked) {
                (Some(tr), _) => tr,
                (None, Some(true)) => "parked",
                _ => "run",
            };
            println!(
                "{:>5} {:>7} {:>9.1} {:>9.1} {:>9.3} {:>6} {:>8} {:>10}",
                ep,
                row.frames,
                row.ms_sum / n,
                row.ms_max,
                row.fid_sum / n,
                row.cores.map(|c| c.to_string()).unwrap_or_else(|| "-".into()),
                state,
                row.knob_exts,
            );
        }
    }

    // ---- view 2: per-stage latency table ------------------------------
    if stage_sum.iter().any(|s| !s.is_empty()) {
        println!("\n== per-stage latency (avg self ms per frame; total = critical path) ==");
        println!("{:>6} {:>7}  {:<40} {:>9}", "tenant", "frames", "stages", "total-ms");
        for t in 0..tl.apps {
            if only.is_some_and(|o| o != t) || total_n[t] == 0 {
                continue;
            }
            let n = total_n[t] as f64;
            let stages: Vec<String> =
                stage_sum[t].iter().map(|s| format!("{:.1}", s / n)).collect();
            println!(
                "{:>6} {:>7}  {:<40} {:>9.1}",
                t,
                total_n[t],
                format!("[{}]", stages.join(", ")),
                total_sum[t] / n,
            );
        }
    }

    // ---- view 3: allocation churn -------------------------------------
    if !allocs.is_empty() {
        println!("\n== allocations ==");
        println!("{:>5} {:>7}  cores", "epoch", "churn");
        let mut churn_total = 0usize;
        for (ep, cores, churn) in &allocs {
            churn_total += churn;
            println!("{ep:>5} {churn:>7}  {cores:?}");
        }
        println!(
            "{} reallocation epochs ({} frontier-released), churn {} cores total",
            allocs.len(),
            frontier_epochs,
            churn_total
        );
    }
    Ok(())
}

/// Allocator scale smoke: synthetic tenants through demand reservation,
/// epoch admission and the heap water-filler; JSON report whose bytes
/// never depend on `--threads` (the CI determinism check relies on it).
fn cmd_alloc_epoch(args: &Args) -> Result<()> {
    let mut cfg = iptune::fleet::scale::ScaleConfig::default();
    if let Some(n) = args.get_parse::<usize>("tenants")? {
        cfg.tenants = n;
    }
    if let Some(n) = args.get_parse::<usize>("epochs")? {
        cfg.epochs = n;
    }
    if let Some(n) = args.get_parse::<u64>("seed")? {
        cfg.seed = n;
    }
    if let Some(n) = args.get_parse::<usize>("threads")? {
        cfg.threads = n;
    }
    if let Some(n) = args.get_parse::<usize>("rungs")? {
        cfg.rungs = n;
    }
    if let Some(n) = args.get_parse::<usize>("cores-per-tenant")? {
        cfg.cores_per_tenant = n;
    }
    if let Some(n) = args.get_parse::<usize>("demand-confidence")? {
        cfg.demand_confidence = n;
    }
    if let Some(s) = args.get_parse::<usize>("shards")? {
        anyhow::ensure!(s >= 1, "--shards must be >= 1");
        cfg.shards = s;
    }
    let report = iptune::fleet::scale::run(&cfg)?;
    let text = report.to_string();
    match args.get("out") {
        Some(path) => {
            std::fs::write(path, &text)
                .with_context(|| format!("writing alloc-epoch report to {path}"))?;
            iptune::log_info!(
                "alloc-epoch: {} tenants x {} epochs -> {path}",
                cfg.tenants,
                cfg.epochs
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_spec(args: &Args, spec_dir: &std::path::Path) -> Result<()> {
    let names: Vec<String> = match args.positional.first() {
        Some(a) => vec![a.clone()],
        None => iptune::apps::registry::APP_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    for name in names {
        let app = app_by_name(&name, spec_dir)?;
        if args.has("graph") {
            println!("{}", app.graph.to_dot(&app.spec.title));
            continue;
        }
        println!("# {} — {}", app.spec.name, app.spec.title);
        println!("{}", app.spec.description);
        println!(
            "latency bounds: {:?} ms | trace protocol: {} configs x {} frames\n",
            app.spec.latency_bounds_ms, app.spec.trace_configs, app.spec.trace_frames
        );
        println!(
            "{:<6} {:<24} {:<11} {:>14} {:>14} {:>12}  description",
            "symbol", "name", "type", "min", "max", "default"
        );
        for p in &app.spec.params {
            println!(
                "{:<6} {:<24} {:<11} {:>14} {:>14} {:>12}  {}",
                p.symbol, p.name, p.kind, p.min, p.max, p.default, p.description
            );
        }
        println!();
    }
    Ok(())
}

fn cmd_trace(args: &Args, spec_dir: &std::path::Path, run_cfg: &RunConfig) -> Result<()> {
    let app_name = args.get("app").context("trace: --app required")?;
    let app = app_by_name(app_name, spec_dir)?;
    let out = PathBuf::from(args.get("out").unwrap_or("traces"));
    let n_cfg = args.get_parse::<usize>("configs")?.unwrap_or(run_cfg.trace.configs);
    let n_frames = args.get_parse::<usize>("frames")?.unwrap_or(run_cfg.trace.frames);
    let seed = args.get_parse::<u64>("seed")?.unwrap_or(run_cfg.trace.seed);
    iptune::log_info!(
        "generating {n_cfg} configs x {n_frames} frames for {} (seed {seed}) ...",
        app.spec.name
    );
    let ts = TraceSet::generate(&app, n_cfg, n_frames, seed);
    let path = TraceSet::default_path(&out, &app.spec.name);
    ts.save(&path)?;
    let payoffs = ts.payoffs();
    let (lo, hi) = payoffs
        .iter()
        .fold((f64::INFINITY, 0.0f64), |(l, h), &(c, _)| (l.min(c), h.max(c)));
    println!(
        "wrote {} ({} configs, cost {lo:.1}..{hi:.1} ms)",
        path.display(),
        ts.num_configs()
    );
    Ok(())
}

fn cmd_tune(args: &Args, spec_dir: &std::path::Path, run_cfg: &RunConfig) -> Result<()> {
    let app_name = args.get("app").context("tune: --app required")?;
    let app = app_by_name(app_name, spec_dir)?;
    let trace_dir = PathBuf::from(args.get("trace-dir").unwrap_or("traces"));
    let frames = args.get_parse::<usize>("frames")?.unwrap_or(1000);
    let traces = TraceSet::load_or_generate(&app, &trace_dir, run_cfg.trace.seed)?;
    let eps = args
        .get_parse::<f64>("epsilon")?
        .or(run_cfg.tuner.epsilon)
        .unwrap_or_else(|| TunerConfig::epsilon_for_horizon(frames));
    let bound = args
        .get_parse::<f64>("bound")?
        .or(run_cfg.tuner.bound_ms)
        .unwrap_or(app.spec.latency_bounds_ms[0]);
    let kind = match args.get("backend") {
        Some(s) => BackendKind::parse(s)?,
        None => run_cfg.tuner.backend,
    };
    let be: Box<dyn Backend> = match kind {
        BackendKind::Native => Box::new(NativeBackend::structured(&app.spec)),
        BackendKind::Xla => {
            Box::new(XlaBackend::from_default_artifacts(&app.spec, Variant::Structured)?)
        }
    };
    iptune::log_info!(
        "tuning {} for {frames} frames: eps={eps:.3}, L={bound} ms, backend={}",
        app.spec.name,
        be.name()
    );
    let cfg = TunerConfig {
        epsilon: eps,
        bound_ms: bound,
        warmup_frames: run_cfg.tuner.warmup_frames,
    };
    let mut ctl = EpsGreedyController::new(&app.spec, &traces, be, cfg, run_cfg.tuner.seed);
    let out = ctl.run(frames);
    let oracle = iptune::tuner::policy::oracle_best(&traces, frames, bound);
    println!(
        "avg fidelity {:.3} ({:.1}% of oracle {:.3}) | avg violation {:.1} ms | max violation {:.1} ms | violation rate {:.1}% | explored {} / {frames}",
        out.avg_reward,
        100.0 * out.avg_reward / oracle.avg_reward.max(1e-9),
        oracle.avg_reward,
        out.avg_violation_ms,
        out.max_violation_ms,
        100.0 * out.violation_rate,
        out.explore_frames,
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let mut ctx = experiments::default_ctx(Some(&out))?;
    ctx.frames = args.get_parse::<usize>("frames")?.unwrap_or(1000);
    if let Some(gen) = args.get("gen") {
        // comma-separated seeds (or gen:SEED names) for the
        // scenario-diversity variants; empty disables them
        ctx.generated = gen
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if s.starts_with("gen") {
                    s.to_string()
                } else {
                    format!("gen:{s}")
                }
            })
            .collect();
    }
    let mut ran = false;
    if args.has("all") {
        experiments::run_all(&ctx)?;
        ran = true;
    } else {
        if let Some(n) = args.get_parse::<u32>("fig")? {
            match n {
                5 => experiments::fig5::run(&ctx)?,
                6 => experiments::fig6::run(&ctx)?,
                7 => experiments::fig7::run(&ctx)?,
                8 => experiments::fig8::run(&ctx)?,
                _ => bail!("unknown figure {n} (5..8)"),
            }
            ran = true;
        }
        if args.has("claims") {
            experiments::claims::run(&ctx)?;
            ran = true;
        }
    }
    if !ran {
        bail!("nothing to do: pass --all, --fig N or --claims");
    }
    Ok(())
}

fn cmd_engine(args: &Args, spec_dir: &std::path::Path) -> Result<()> {
    let app_name = args.get("app").context("engine: --app required")?;
    let app = Arc::new(app_by_name(app_name, spec_dir)?);
    let frames = args.get_parse::<usize>("frames")?.unwrap_or(300);
    let bound = args
        .get_parse::<f64>("bound")?
        .unwrap_or(app.spec.latency_bounds_ms[0]);
    let period = args.get_parse::<usize>("period")?.unwrap_or(25);
    run_engine_demo(app, frames, bound, period)
}

/// Closed loop: stream frames through the threaded engine, learn
/// per-stage latencies online, retune the running pipeline every
/// `period` frames.
fn run_engine_demo(
    app: Arc<iptune::apps::App>,
    frames: usize,
    bound: f64,
    period: usize,
) -> Result<()> {
    let handle = spawn_stream(
        Arc::clone(&app),
        app.spec.defaults(),
        EngineConfig { frames, realtime_scale: 1e-5, seed: 3, ..Default::default() },
    );

    let mut backend = NativeBackend::structured(&app.spec);
    let mut rng = iptune::util::Rng::new(17);
    // candidate grid: random valid configs + the defaults
    let mut candidates: Vec<Vec<f64>> = (0..48)
        .map(|_| (0..app.spec.num_vars()).map(|_| rng.f64()).collect())
        .collect();
    candidates.push(app.spec.normalize(&app.spec.defaults()));
    let content = app.model.content(0);
    let rewards: Vec<f64> = candidates
        .iter()
        .map(|u| app.model.fidelity(&app.spec.denormalize(u), &content))
        .collect();

    let mut lat_sum = 0.0;
    let mut fid_sum = 0.0;
    let mut over = 0usize;
    let mut n = 0usize;
    while let Ok(rec) = handle.records.recv() {
        let u = app.spec.normalize(&rec.knobs);
        let (y, off) = backend.group_map().targets(&rec.stage_ms, rec.end_to_end_ms);
        backend.update(&u, &y);
        backend.observe_offset(off);
        lat_sum += rec.end_to_end_ms;
        fid_sum += rec.fidelity;
        if rec.end_to_end_ms > bound {
            over += 1;
        }
        n += 1;
        if rec.frame % period == period - 1 {
            let pick = backend.solve(&candidates, &rewards, bound);
            let ks = app.spec.denormalize(&candidates[pick]);
            println!(
                "frame {:>4}: avg latency {:>7.1} ms, avg fidelity {:.3}, over-bound {:>3} -> retune to {:?}",
                rec.frame,
                lat_sum / n as f64,
                fid_sum / n as f64,
                over,
                ks.iter().map(|k| (k * 100.0).round() / 100.0).collect::<Vec<_>>()
            );
            handle.set_knobs(ks);
            lat_sum = 0.0;
            fid_sum = 0.0;
            over = 0;
            n = 0;
        }
    }
    if let Some(stats) = handle.stats() {
        let p = |q: f64| stats.latency.quantile(q).unwrap_or(0.0);
        println!(
            "latency percentiles over {} frames: p50 {:.1} ms | p95 {:.1} ms | p99 {:.1} ms",
            stats.frames,
            p(0.50),
            p(0.95),
            p(0.99),
        );
    }
    println!("engine demo complete ({frames} frames, L={bound} ms)");
    Ok(())
}
